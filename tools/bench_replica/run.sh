#!/bin/sh
# Build and run the C bench replica (see replica.c header for what it
# measures and why it exists). Compiles like rustc compiles the crate:
# baseline x86-64, AVX2 confined to target-attributed functions.
set -e
cd "$(dirname "$0")"
gcc -O3 -std=gnu11 -Wall -Wextra -o replica replica.c -lm
exec ./replica "$@"
