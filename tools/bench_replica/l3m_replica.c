/* L3m replica: C mirror of the zero-repack serving data path added by the
 * packed-weight-cache PR, measured the same way replica.c measures the
 * earlier sections (see its header for the methodology and why this file
 * exists: the build host has no Rust toolchain, so the checked-in
 * BENCH_serving.json figures come from this line-for-line port, and CI's
 * bench-json job re-measures the same keys with the real bench).
 *
 * Sections (mirroring benches/perf_hotpaths.rs L3m):
 *
 *   l3m_percall_mmacs   - systolic matmul at serving batch size, packing
 *                         the weight tiles on every call (the pre-PR
 *                         matmul_i8 entry point).
 *   l3m_prepacked_mmacs - same workload through a PackedWeights artifact
 *                         built once (the weight-stationary path).
 *   l3d replica         - the pre-PR serve loop: per-batch malloc of xq /
 *                         accumulator / output, dot-product (i8t) matmul.
 *   l3m_serve_infs      - the post-PR steady state: per-layer unit-block
 *                         interleaved weights packed once (PackedLayer),
 *                         every buffer from a reusable arena.
 *
 * Kernels are byte-for-byte the ones in replica.c (pack_tiles,
 * acc_tile_pairs_avx2, dot_i8_avx2); the fc_mnist shape is the real one
 * (784 -> 128 relu -> 10 linear, batch 64), quantize/dequant match
 * QuantMac::quantize_input / dequant.
 */
#include <immintrin.h>
#include <math.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec + 1e-9 * (double)ts.tv_nsec;
}

/* xoshiro256++ (input data only; exact port not needed for timing). */
static uint64_t rot(uint64_t x, int k) { return (x << k) | (x >> (64 - k)); }
typedef struct { uint64_t s[4]; } Xo;
static uint64_t xo_next(Xo *x) {
    uint64_t r = rot(x->s[0] + x->s[3], 23) + x->s[0];
    uint64_t t = x->s[1] << 17;
    x->s[2] ^= x->s[0];
    x->s[3] ^= x->s[1];
    x->s[1] ^= x->s[2];
    x->s[0] ^= x->s[3];
    x->s[2] ^= t;
    x->s[3] = rot(x->s[3], 45);
    return r;
}
static Xo xo_seed(uint64_t seed) {
    Xo x;
    for (int i = 0; i < 4; i++) {
        seed += 0x9E3779B97F4A7C15ULL;
        uint64_t z = seed;
        z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9ULL;
        z = (z ^ (z >> 27)) * 0x94D049BB133111EBULL;
        x.s[i] = z ^ (z >> 31);
    }
    return x;
}

#define TILE_K 128
#define TILE_N 256

typedef struct { size_t k0, kr, n0, nc, off; } Tile;

static size_t plan_tiles(size_t k, size_t n, int interleave, Tile *tiles, size_t *ntiles) {
    size_t off = 0, t = 0;
    for (size_t k0 = 0; k0 < k; k0 += TILE_K) {
        size_t kr = (k - k0) < TILE_K ? (k - k0) : TILE_K;
        for (size_t n0 = 0; n0 < n; n0 += TILE_N) {
            size_t nc = (n - n0) < TILE_N ? (n - n0) : TILE_N;
            tiles[t].k0 = k0; tiles[t].kr = kr; tiles[t].n0 = n0; tiles[t].nc = nc;
            tiles[t].off = off;
            off += interleave ? ((kr + 1) / 2) * nc * 2 : kr * nc;
            t++;
        }
    }
    *ntiles = t;
    return off;
}

static void pack_tiles(const int8_t *w, size_t n, int interleave, const Tile *tiles,
                       size_t ntiles, int8_t *packed) {
    for (size_t t = 0; t < ntiles; t++) {
        const Tile *ti = &tiles[t];
        if (interleave) {
            size_t kp = (ti->kr + 1) / 2;
            int8_t *dst = packed + ti->off;
            for (size_t p = 0; p < kp; p++) {
                const int8_t *r0 = w + (ti->k0 + 2 * p) * n + ti->n0;
                const int8_t *r1 =
                    (2 * p + 1 < ti->kr) ? w + (ti->k0 + 2 * p + 1) * n + ti->n0 : NULL;
                int8_t *drow = dst + p * ti->nc * 2;
                if (r1) {
                    for (size_t j = 0; j < ti->nc; j++) {
                        drow[2 * j] = r0[j];
                        drow[2 * j + 1] = r1[j];
                    }
                } else {
                    for (size_t j = 0; j < ti->nc; j++) {
                        drow[2 * j] = r0[j];
                        drow[2 * j + 1] = 0;
                    }
                }
            }
        } else {
            int8_t *dst = packed + ti->off;
            for (size_t r = 0; r < ti->kr; r++)
                memcpy(dst + r * ti->nc, w + (ti->k0 + r) * n + ti->n0, ti->nc);
        }
    }
}

__attribute__((target("avx2"))) static void acc_tile_pairs_avx2(
    const int8_t *a, size_t lda, size_t k0, size_t kr, const int8_t *packed, size_t nc,
    int32_t *out, size_t ldo, size_t n0, size_t m) {
    size_t kp = (kr + 1) / 2;
    size_t nvec = nc & ~(size_t)7;
    for (size_t s = 0; s < m; s++) {
        const int8_t *arow = a + s * lda + k0;
        int32_t *orow = out + s * ldo + n0;
        size_t j = 0;
        while (j < nvec) {
            __m256i acc = _mm256_loadu_si256((const __m256i *)(orow + j));
            for (size_t p = 0; p < kp; p++) {
                int32_t a0 = arow[2 * p];
                int32_t a1 = (2 * p + 1 < kr) ? arow[2 * p + 1] : 0;
                if (a0 == 0 && a1 == 0) continue;
                __m256i pair = _mm256_set1_epi32((a1 << 16) | (a0 & 0xFFFF));
                __m128i wbytes = _mm_loadu_si128((const __m128i *)(packed + (p * nc + j) * 2));
                __m256i w16 = _mm256_cvtepi8_epi16(wbytes);
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(w16, pair));
            }
            _mm256_storeu_si256((__m256i *)(orow + j), acc);
            j += 8;
        }
        for (j = nvec; j < nc; j++) {
            int32_t acc = orow[j];
            for (size_t p = 0; p < kp; p++) {
                int32_t a0 = arow[2 * p];
                int32_t a1 = (2 * p + 1 < kr) ? arow[2 * p + 1] : 0;
                if (a0 == 0 && a1 == 0) continue;
                acc += a0 * (int32_t)packed[(p * nc + j) * 2] +
                       a1 * (int32_t)packed[(p * nc + j) * 2 + 1];
            }
            orow[j] = acc;
        }
    }
}

__attribute__((target("avx2"))) static int32_t dot_i8_avx2(const int8_t *x, const int8_t *y,
                                                           size_t n) {
    size_t nvec = n & ~(size_t)15;
    __m256i acc = _mm256_setzero_si256();
    size_t i = 0;
    while (i < nvec) {
        __m256i xv = _mm256_cvtepi8_epi16(_mm_loadu_si128((const __m128i *)(x + i)));
        __m256i yv = _mm256_cvtepi8_epi16(_mm_loadu_si128((const __m128i *)(y + i)));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(xv, yv));
        i += 16;
    }
    __m128i s = _mm_add_epi32(_mm256_castsi256_si128(acc), _mm256_extracti128_si256(acc, 1));
    s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
    s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0x01));
    int32_t sum = _mm_cvtsi128_si32(s);
    for (i = nvec; i < n; i++) sum += (int32_t)x[i] * (int32_t)y[i];
    return sum;
}

/* Pack wt[n][k] into [ublock of 8][kchunk of 16][8][16] + per-unit tail.
 * kc = number of full 16-chunks; tail k%16 stored unit-major after. */
static size_t packed_size(size_t n, size_t k) {
    size_t ub = (n + 7) / 8;
    return ub * 8 * k; /* generous: full rows, zero-padded units */
}

static void pack_units(const int8_t *wt, size_t n, size_t k, int8_t *packed) {
    size_t kc = k / 16, tail = k % 16;
    size_t ub = (n + 7) / 8;
    memset(packed, 0, ub * 8 * k);
    for (size_t b = 0; b < ub; b++) {
        int8_t *base = packed + b * 8 * k;
        for (size_t c = 0; c < kc; c++) {
            for (size_t u = 0; u < 8; u++) {
                size_t unit = b * 8 + u;
                if (unit < n)
                    memcpy(base + (c * 8 + u) * 16, wt + unit * k + c * 16, 16);
            }
        }
        /* tail: after the chunks, 8 rows of `tail` bytes */
        int8_t *tbase = base + kc * 128;
        for (size_t u = 0; u < 8; u++) {
            size_t unit = b * 8 + u;
            if (unit < n) memcpy(tbase + u * tail, wt + unit * k + kc * 16, tail);
        }
    }
}

/* One activation row against one 8-unit block: shared a-load, 8 madds. */
__attribute__((target("avx2"))) static void dot8_avx2(const int8_t *a, const int8_t *blk,
                                                      size_t k, int32_t *out8, size_t nu) {
    size_t kc = k / 16, tail = k % 16;
    __m256i acc[8];
    for (int u = 0; u < 8; u++) acc[u] = _mm256_setzero_si256();
    for (size_t c = 0; c < kc; c++) {
        __m256i av = _mm256_cvtepi8_epi16(_mm_loadu_si128((const __m128i *)(a + c * 16)));
        const int8_t *wp = blk + c * 128;
        for (int u = 0; u < 8; u++)
            acc[u] = _mm256_add_epi32(
                acc[u], _mm256_madd_epi16(av, _mm256_cvtepi8_epi16(_mm_loadu_si128(
                                                  (const __m128i *)(wp + 16 * u)))));
    }
    const int8_t *tbase = blk + kc * 128;
    for (size_t u = 0; u < nu; u++) {
        __m128i s = _mm_add_epi32(_mm256_castsi256_si128(acc[u]),
                                  _mm256_extracti128_si256(acc[u], 1));
        s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
        s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0x01));
        int32_t sum = _mm_cvtsi128_si32(s);
        for (size_t i = 0; i < tail; i++)
            sum += (int32_t)a[kc * 16 + i] * (int32_t)tbase[u * tail + i];
        out8[u] = sum;
    }
}

static void run_tiles(const int8_t *a, size_t m, size_t k, size_t n, int32_t *out,
                      const int8_t *packed, const Tile *tiles, size_t ntiles) {
    memset(out, 0, m * n * sizeof(int32_t));
    for (size_t t = 0; t < ntiles; t++) {
        const Tile *ti = &tiles[t];
        acc_tile_pairs_avx2(a, k, ti->k0, ti->kr, packed + ti->off, ti->nc, out, n, ti->n0, m);
    }
}

/* QuantMac::quantize_input / dequant, exact semantics. */
static void quantize(const float *x, int8_t *out, size_t len, float x_scale) {
    float s = x_scale > 1e-12f ? x_scale : 1e-12f;
    for (size_t i = 0; i < len; i++) {
        float v = roundf(x[i] / s);
        if (v < -127.0f) v = -127.0f;
        if (v > 127.0f) v = 127.0f;
        out[i] = (int8_t)v;
    }
}

static volatile int64_t sink;

int main(void) {
    const size_t B = 64, K = 784, H = 128, O = 10;
    Xo rng = xo_seed(0xF00D);
    /* fc_mnist-scale data. wt layouts: w1t[H][K], w2t[O][H]; systolic
     * layouts w1[K][H] for the tile packer (built by transpose). */
    int8_t *w1t = malloc(H * K), *w2t = malloc(O * H);
    float *x = malloc(B * K * sizeof(float));
    for (size_t i = 0; i < H * K; i++) w1t[i] = (int8_t)(xo_next(&rng) % 255 - 127);
    for (size_t i = 0; i < O * H; i++) w2t[i] = (int8_t)(xo_next(&rng) % 255 - 127);
    for (size_t i = 0; i < B * K; i++)
        x[i] = (float)(int64_t)(xo_next(&rng) % 2000) / 1000.0f - 1.0f;
    int8_t *w1 = malloc(K * H), *w2 = malloc(H * O);
    for (size_t r = 0; r < K; r++)
        for (size_t c = 0; c < H; c++) w1[r * H + c] = w1t[c * K + r];
    for (size_t r = 0; r < H; r++)
        for (size_t c = 0; c < O; c++) w2[r * O + c] = w2t[c * H + r];
    const float xs1 = 0.01f, ws1 = 0.02f, xs2 = 0.05f, ws2 = 0.02f;
    float *bias1 = calloc(H, sizeof(float)), *bias2 = calloc(O, sizeof(float));

    /* --- prepacked vs per-call systolic matmul, serving batch (m=8) ----- */
    {
        const size_t m = 8;
        Tile tiles[64];
        size_t ntiles;
        size_t psz = plan_tiles(K, H, 1, tiles, &ntiles);
        int8_t *packed = malloc(psz);
        int8_t *a = malloc(m * K);
        for (size_t i = 0; i < m * K; i++) a[i] = (int8_t)(xo_next(&rng) % 255 - 127);
        int32_t *out = malloc(m * H * sizeof(int32_t));
        const int reps = 4000;
        double t0 = now_s();
        for (int r = 0; r < reps; r++) {
            plan_tiles(K, H, 1, tiles, &ntiles);
            pack_tiles(w1, H, 1, tiles, ntiles, packed);
            run_tiles(a, m, K, H, out, packed, tiles, ntiles);
            sink += out[0];
        }
        double dt_percall = now_s() - t0;
        plan_tiles(K, H, 1, tiles, &ntiles);
        pack_tiles(w1, H, 1, tiles, ntiles, packed);
        t0 = now_s();
        for (int r = 0; r < reps; r++) {
            run_tiles(a, m, K, H, out, packed, tiles, ntiles);
            sink += out[0];
        }
        double dt_prepacked = now_s() - t0;
        double macs = (double)reps * m * K * H;
        printf("l3m_percall_mmacs    %10.0f\n", macs / dt_percall / 1e6);
        printf("l3m_prepacked_mmacs  %10.0f\n", macs / dt_prepacked / 1e6);
        printf("l3m_pack_overhead_x  %10.3f\n", dt_percall / dt_prepacked);
        free(packed); free(a); free(out);
    }

    /* --- l3d replica: pre-PR serve loop (dot kernel, per-batch mallocs) -- */
    const int reps = 400;
    double dt_l3d, dt_l3m;
    {
        double t0 = now_s();
        for (int r = 0; r < reps; r++) {
            /* forward_with clones the input tensor before layer 0 */
            float *xc = malloc(B * K * sizeof(float));
            memcpy(xc, x, B * K * sizeof(float));
            int8_t *xq = malloc(B * K);
            quantize(xc, xq, B * K, xs1);
            /* matmul_i8t_into: out.clear() + resize(.., 0) zero-fills */
            int32_t *acc1 = calloc(B * H, sizeof(int32_t));
            for (size_t s = 0; s < B; s++)
                for (size_t u = 0; u < H; u++)
                    acc1[s * H + u] = dot_i8_avx2(xq + s * K, w1t + u * K, K);
            /* Tensor::zeros(&[batch, out]) zero-fills before dequant */
            float *y1 = calloc(B * H, sizeof(float));
            for (size_t s = 0; s < B; s++)
                for (size_t u = 0; u < H; u++) {
                    float v = (float)acc1[s * H + u] * ws1 * xs1 + bias1[u];
                    y1[s * H + u] = v > 0 ? v : 0; /* relu */
                }
            int8_t *xq2 = malloc(B * H);
            quantize(y1, xq2, B * H, xs2);
            int32_t *acc2 = calloc(B * O, sizeof(int32_t));
            for (size_t s = 0; s < B; s++)
                for (size_t u = 0; u < O; u++)
                    acc2[s * O + u] = dot_i8_avx2(xq2 + s * H, w2t + u * H, H);
            float *y2 = calloc(B * O, sizeof(float));
            for (size_t s = 0; s < B; s++)
                for (size_t u = 0; u < O; u++)
                    y2[s * O + u] = (float)acc2[s * O + u] * ws2 * xs2 + bias2[u];
            sink += (int64_t)y2[0];
            free(xc); free(xq); free(acc1); free(y1); free(xq2); free(acc2); free(y2);
        }
        dt_l3d = now_s() - t0;
    }

    /* --- l3m replica: prepacked tiles + arena, same math ----------------- */
    {
        int8_t *packed1 = malloc(packed_size(H, K));
        int8_t *packed2 = malloc(packed_size(O, H));
        pack_units(w1t, H, K, packed1);
        pack_units(w2t, O, H, packed2);
        /* arena: allocated once, reused every batch */
        int8_t *xq = malloc(B * K), *xq2 = malloc(B * H);
        int32_t *acc1 = malloc(B * H * sizeof(int32_t));
        int32_t *acc2 = malloc(B * O * sizeof(int32_t));
        float *y1 = malloc(B * H * sizeof(float));
        float *y2 = malloc(B * O * sizeof(float));
        double t0 = now_s();
        for (int r = 0; r < reps; r++) {
            quantize(x, xq, B * K, xs1);
            for (size_t s = 0; s < B; s++)
                for (size_t b = 0; b < H / 8; b++)
                    dot8_avx2(xq + s * K, packed1 + b * 8 * K, K, acc1 + s * H + b * 8, 8);
            for (size_t s = 0; s < B; s++)
                for (size_t u = 0; u < H; u++) {
                    float v = (float)acc1[s * H + u] * ws1 * xs1 + bias1[u];
                    y1[s * H + u] = v > 0 ? v : 0;
                }
            quantize(y1, xq2, B * H, xs2);
            for (size_t s = 0; s < B; s++) {
                for (size_t b = 0; b < O / 8; b++)
                    dot8_avx2(xq2 + s * H, packed2 + b * 8 * H, H, acc2 + s * O + b * 8, 8);
                dot8_avx2(xq2 + s * H, packed2 + (O / 8) * 8 * H, H,
                          acc2 + s * O + (O / 8) * 8, O % 8);
            }
            for (size_t s = 0; s < B; s++)
                for (size_t u = 0; u < O; u++)
                    y2[s * O + u] = (float)acc2[s * O + u] * ws2 * xs2 + bias2[u];
            sink += (int64_t)y2[0];
        }
        dt_l3m = now_s() - t0;
        free(packed1); free(packed2); free(xq); free(xq2);
        free(acc1); free(acc2); free(y1); free(y2);
    }

    double l3d_infs = (double)reps * B / dt_l3d;
    double l3m_infs = (double)reps * B / dt_l3m;
    printf("l3d_inferences_per_s %10.0f\n", l3d_infs);
    printf("l3m_serve_infs       %10.0f\n", l3m_infs);
    printf("l3m_speedup_vs_l3d   %10.3f\n", l3m_infs / l3d_infs);
    free(w1t); free(w2t); free(w1); free(w2); free(x); free(bias1); free(bias2);
    return 0;
}
