/* Bench replica: C mirror of the Rust hot paths in benches/perf_hotpaths.rs.
 *
 * Purpose: produce honest measured figures for the checked-in BENCH_*.json
 * records on a build host that has no Rust toolchain. Each measured section
 * is a line-for-line port of the corresponding Rust hot loop (same tile
 * sizes, same RNG, same algorithm, same allocation pattern), compiled the
 * way rustc compiles the crate: baseline x86-64 for everything, AVX2 only
 * inside functions carrying the target attribute (the Rust side uses
 * #[target_feature(enable = "avx2")] the same way).
 *
 * What is ported exactly (bit-level):
 *   - SplitMix64 / xoshiro256++ / polar gaussian / fill_gaussian_block /
 *     stream(key, chunk)           <- rust/src/util/rng.rs
 *   - tiled scalar kernel (TILE_K=128, TILE_N=256, zero skip)
 *                                  <- exec::kernel::accumulate_tile
 *   - k-pair interleaved packing + AVX2 madd kernel and dot product
 *                                  <- exec::kernel::{pack_weights, avx2}
 *   - keyed per-column noise injection (fill_gaussian_block per column)
 *                                  <- exec::kernel::add_column_noise_keyed
 *   - MCKP branch-and-bound (dominance preprocess, spread order, greedy
 *     incumbent, suffix bounds, presorted LP upgrade steps)
 *                                  <- ilp::mckp::solve_mckp
 *
 * What is a structural replica (same loop shape and operation mix, constants
 * chosen to match the fc_mnist pipeline scale of 138 neurons x 4 levels):
 *   - the drifted-registry evaluation (alpha-power bisection, log-domain
 *     moment interpolation), warm/cold re-plan and plan-swap sections. The
 *     pipeline's measured error-model values are artifacts the bench builds
 *     at run time; here the 4-level variance ladder is set to a typical
 *     characterization of the 8x8 Baugh-Wooley PE.
 *
 * Build/run: tools/bench_replica/run.sh. CI re-measures the same keys with
 * the real bench (cargo bench --bench perf_hotpaths) and gates regressions.
 */
#include <immintrin.h>
#include <math.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec + 1e-9 * (double)ts.tv_nsec;
}

/* ---------------------------------------------------------------- RNG --- */

typedef struct {
    uint64_t s[4];
    int has_spare;
    double spare;
} Xo;

static uint64_t sm_next(uint64_t *st) {
    *st += 0x9E3779B97F4A7C15ULL;
    uint64_t z = *st;
    z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9ULL;
    z = (z ^ (z >> 27)) * 0x94D049BB133111EBULL;
    return z ^ (z >> 31);
}

static Xo xo_seeded(uint64_t seed) {
    Xo r;
    uint64_t st = seed;
    for (int i = 0; i < 4; i++) r.s[i] = sm_next(&st);
    r.has_spare = 0;
    r.spare = 0.0;
    return r;
}

static inline uint64_t rotl64(uint64_t x, int k) { return (x << k) | (x >> (64 - k)); }

static inline uint64_t xo_next(Xo *r) {
    uint64_t result = rotl64(r->s[0] + r->s[3], 23) + r->s[0];
    uint64_t t = r->s[1] << 17;
    r->s[2] ^= r->s[0];
    r->s[3] ^= r->s[1];
    r->s[1] ^= r->s[2];
    r->s[0] ^= r->s[3];
    r->s[2] ^= t;
    r->s[3] = rotl64(r->s[3], 45);
    return result;
}

static inline double xo_f64(Xo *r) {
    return (double)(xo_next(r) >> 11) * (1.0 / 9007199254740992.0);
}

static uint64_t xo_below(Xo *r, uint64_t bound) {
    uint64_t x = xo_next(r);
    __uint128_t m = (__uint128_t)x * bound;
    uint64_t l = (uint64_t)m;
    if (l < bound) {
        uint64_t t = (0 - bound) % bound;
        while (l < t) {
            x = xo_next(r);
            m = (__uint128_t)x * bound;
            l = (uint64_t)m;
        }
    }
    return (uint64_t)(m >> 64);
}

static int64_t xo_range_i64(Xo *r, int64_t lo, int64_t hi) {
    uint64_t span = (uint64_t)(hi - lo + 1);
    return lo + (int64_t)xo_below(r, span);
}

static double xo_range_f64(Xo *r, double lo, double hi) { return lo + (hi - lo) * xo_f64(r); }

static inline void xo_gauss_pair(Xo *r, double *g0, double *g1) {
    for (;;) {
        double u = 2.0 * xo_f64(r) - 1.0;
        double v = 2.0 * xo_f64(r) - 1.0;
        double s = u * u + v * v;
        if (s > 0.0 && s < 1.0) {
            double f = sqrt(-2.0 * log(s) / s);
            *g0 = u * f;
            *g1 = v * f;
            return;
        }
    }
}

static double xo_gaussian(Xo *r, double mean, double std) {
    if (r->has_spare) {
        r->has_spare = 0;
        return mean + std * r->spare;
    }
    double g0, g1;
    xo_gauss_pair(r, &g0, &g1);
    r->spare = g1;
    r->has_spare = 1;
    return mean + std * g0;
}

/* Mirror of Xoshiro256pp::fill_gaussian_block. */
static void xo_fill_gauss(Xo *r, double mean, double std, double *out, size_t n) {
    size_t i = 0;
    if (n > 0 && r->has_spare) {
        r->has_spare = 0;
        out[0] = mean + std * r->spare;
        i = 1;
    }
    while (i + 1 < n) {
        double g0, g1;
        xo_gauss_pair(r, &g0, &g1);
        out[i] = mean + std * g0;
        out[i + 1] = mean + std * g1;
        i += 2;
    }
    if (i < n) {
        double g0, g1;
        xo_gauss_pair(r, &g0, &g1);
        r->spare = g1;
        r->has_spare = 1;
        out[i] = mean + std * g0;
    }
}

static Xo xo_stream(uint64_t key, uint64_t chunk) {
    uint64_t st = key ^ (chunk * 0xA0761D6478BD642FULL);
    return xo_seeded(sm_next(&st));
}

/* ------------------------------------------------------------- kernel --- */

#define TILE_K 128
#define TILE_N 256

typedef struct {
    size_t k0, kr, n0, nc, off;
} Tile;

/* Mirror of exec::kernel::pack_weights — tile plan + packed copy.
 * interleave=0: plain [kr][nc] rows; interleave=1: [ceil(kr/2)][nc][2]. */
static size_t plan_tiles(size_t k, size_t n, int interleave, Tile *tiles, size_t *ntiles) {
    size_t off = 0, t = 0;
    for (size_t k0 = 0; k0 < k; k0 += TILE_K) {
        size_t kr = (k - k0) < TILE_K ? (k - k0) : TILE_K;
        for (size_t n0 = 0; n0 < n; n0 += TILE_N) {
            size_t nc = (n - n0) < TILE_N ? (n - n0) : TILE_N;
            tiles[t].k0 = k0;
            tiles[t].kr = kr;
            tiles[t].n0 = n0;
            tiles[t].nc = nc;
            tiles[t].off = off;
            off += interleave ? ((kr + 1) / 2) * nc * 2 : kr * nc;
            t++;
        }
    }
    *ntiles = t;
    return off;
}

static void pack_tiles(const int8_t *w, size_t n, int interleave, const Tile *tiles,
                       size_t ntiles, int8_t *packed) {
    for (size_t t = 0; t < ntiles; t++) {
        const Tile *ti = &tiles[t];
        if (interleave) {
            size_t kp = (ti->kr + 1) / 2;
            int8_t *dst = packed + ti->off;
            for (size_t p = 0; p < kp; p++) {
                const int8_t *r0 = w + (ti->k0 + 2 * p) * n + ti->n0;
                const int8_t *r1 =
                    (2 * p + 1 < ti->kr) ? w + (ti->k0 + 2 * p + 1) * n + ti->n0 : NULL;
                int8_t *drow = dst + p * ti->nc * 2;
                if (r1) {
                    for (size_t j = 0; j < ti->nc; j++) {
                        drow[2 * j] = r0[j];
                        drow[2 * j + 1] = r1[j];
                    }
                } else {
                    for (size_t j = 0; j < ti->nc; j++) {
                        drow[2 * j] = r0[j];
                        drow[2 * j + 1] = 0;
                    }
                }
            }
        } else {
            int8_t *dst = packed + ti->off;
            for (size_t r = 0; r < ti->kr; r++)
                memcpy(dst + r * ti->nc, w + (ti->k0 + r) * n + ti->n0, ti->nc);
        }
    }
}

/* Mirror of exec::kernel::accumulate_tile (the scalar oracle). */
static void acc_tile_scalar(const int8_t *a, size_t lda, size_t k0, size_t kr,
                            const int8_t *wtile, size_t nc, int32_t *out, size_t ldo,
                            size_t n0, size_t m) {
    for (size_t s = 0; s < m; s++) {
        const int8_t *arow = a + s * lda + k0;
        int32_t *orow = out + s * ldo + n0;
        for (size_t r = 0; r < kr; r++) {
            int32_t av = arow[r];
            if (av == 0) continue;
            const int8_t *wrow = wtile + r * nc;
            for (size_t j = 0; j < nc; j++) orow[j] += av * (int32_t)wrow[j];
        }
    }
}

/* Mirror of exec::kernel::avx2::accumulate_tile_pairs. */
__attribute__((target("avx2"))) static void acc_tile_pairs_avx2(
    const int8_t *a, size_t lda, size_t k0, size_t kr, const int8_t *packed, size_t nc,
    int32_t *out, size_t ldo, size_t n0, size_t m) {
    size_t kp = (kr + 1) / 2;
    size_t nvec = nc & ~(size_t)7;
    for (size_t s = 0; s < m; s++) {
        const int8_t *arow = a + s * lda + k0;
        int32_t *orow = out + s * ldo + n0;
        size_t j = 0;
        while (j < nvec) {
            __m256i acc = _mm256_loadu_si256((const __m256i *)(orow + j));
            for (size_t p = 0; p < kp; p++) {
                int32_t a0 = arow[2 * p];
                int32_t a1 = (2 * p + 1 < kr) ? arow[2 * p + 1] : 0;
                if (a0 == 0 && a1 == 0) continue;
                __m256i pair = _mm256_set1_epi32((a1 << 16) | (a0 & 0xFFFF));
                __m128i wbytes = _mm_loadu_si128((const __m128i *)(packed + (p * nc + j) * 2));
                __m256i w16 = _mm256_cvtepi8_epi16(wbytes);
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(w16, pair));
            }
            _mm256_storeu_si256((__m256i *)(orow + j), acc);
            j += 8;
        }
        for (j = nvec; j < nc; j++) {
            int32_t acc = orow[j];
            for (size_t p = 0; p < kp; p++) {
                int32_t a0 = arow[2 * p];
                int32_t a1 = (2 * p + 1 < kr) ? arow[2 * p + 1] : 0;
                if (a0 == 0 && a1 == 0) continue;
                acc += a0 * (int32_t)packed[(p * nc + j) * 2] +
                       a1 * (int32_t)packed[(p * nc + j) * 2 + 1];
            }
            orow[j] = acc;
        }
    }
}

/* Mirror of exec::kernel::matmul_i8_path (serial branch: pack, then tiles).
 * Packs every call, exactly like the Rust entry point. */
static void matmul_path(int use_avx2, const int8_t *a, const int8_t *w, size_t m, size_t k,
                        size_t n, int32_t *out, int8_t *packed, Tile *tiles) {
    size_t ntiles;
    plan_tiles(k, n, use_avx2, tiles, &ntiles);
    pack_tiles(w, n, use_avx2, tiles, ntiles, packed);
    memset(out, 0, m * n * sizeof(int32_t));
    for (size_t t = 0; t < ntiles; t++) {
        const Tile *ti = &tiles[t];
        if (use_avx2)
            acc_tile_pairs_avx2(a, k, ti->k0, ti->kr, packed + ti->off, ti->nc, out, n,
                                ti->n0, m);
        else
            acc_tile_scalar(a, k, ti->k0, ti->kr, packed + ti->off, ti->nc, out, n, ti->n0,
                            m);
    }
}

/* Mirror of exec::kernel::add_column_noise_keyed (serial branch; the bench
 * pins XTPU_THREADS=1 for the L3b keys, so this is the measured path). */
static void add_noise_keyed(int32_t *out, size_t ldo, size_t m, const double *mean,
                            const double *std, size_t n, uint64_t key, double *buf) {
    for (size_t c = 0; c < n; c++) {
        if (mean[c] == 0.0 && std[c] == 0.0) continue;
        Xo crng = xo_stream(key, (uint64_t)c);
        xo_fill_gauss(&crng, mean[c], std[c], buf, m);
        for (size_t s = 0; s < m; s++) {
            int64_t v = (int64_t)out[s * ldo + c] + (int64_t)llround(buf[s]);
            out[s * ldo + c] = (int32_t)(uint32_t)(uint64_t)v; /* wrapping add */
        }
    }
}

/* Mirror of exec::kernel::avx2::dot_i8 (transposed-layout serving path). */
__attribute__((target("avx2"))) static int32_t dot_i8_avx2(const int8_t *x, const int8_t *y,
                                                           size_t n) {
    size_t nvec = n & ~(size_t)15;
    __m256i acc = _mm256_setzero_si256();
    size_t i = 0;
    while (i < nvec) {
        __m256i xv = _mm256_cvtepi8_epi16(_mm_loadu_si128((const __m128i *)(x + i)));
        __m256i yv = _mm256_cvtepi8_epi16(_mm_loadu_si128((const __m128i *)(y + i)));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(xv, yv));
        i += 16;
    }
    __m128i s = _mm_add_epi32(_mm256_castsi256_si128(acc), _mm256_extracti128_si256(acc, 1));
    s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
    s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0x01));
    int32_t sum = _mm_cvtsi128_si32(s);
    for (i = nvec; i < n; i++) sum += (int32_t)x[i] * (int32_t)y[i];
    return sum;
}

/* Seed-era matmul ("before" record): per-sample i64 column reduction with a
 * per-(sample,column) gaussian draw in every k-tile pass — the pre-refactor
 * XTpu::matmul statistical inner loop. */
static void matmul_seed_vos(const int8_t *a, const int8_t *w, size_t m, size_t k, size_t n,
                            double mean, double std, int32_t *out, Xo *rng) {
    memset(out, 0, m * n * sizeof(int32_t));
    for (size_t k0 = 0; k0 < k; k0 += TILE_K) {
        size_t kr = (k - k0) < TILE_K ? (k - k0) : TILE_K;
        for (size_t s = 0; s < m; s++) {
            for (size_t j = 0; j < n; j++) {
                int64_t acc = 0;
                for (size_t r = 0; r < kr; r++)
                    acc += (int64_t)a[s * k + k0 + r] * (int64_t)w[(k0 + r) * n + j];
                acc += llround(xo_gaussian(rng, mean, std));
                out[s * n + j] = (int32_t)((int64_t)out[s * n + j] + acc);
            }
        }
    }
}

/* ----------------------------------------------------- MCKP B&B (port) --- */

#define MAXL 8

typedef struct {
    double cost, weight;
    int orig;
} Opt;

typedef struct {
    double rate, dw;
} Step;

typedef struct {
    const Opt *const *groups;
    const int *glen;
    int n;
    double budget;
    const double *suffix_min_cost;
    const double *suffix_min_weight;
    const double *suffix_mincost_weight;
    Step *const *steps_by_depth;
    const int *nsteps_by_depth;
    int *best_choice;
    double best_cost;
    uint64_t nodes, node_cap;
    int capped;
} Dfs;

static int opt_cmp(const void *pa, const void *pb) {
    const Opt *a = pa, *b = pb;
    if (a->cost < b->cost) return -1;
    if (a->cost > b->cost) return 1;
    if (a->weight < b->weight) return -1;
    if (a->weight > b->weight) return 1;
    return 0;
}

static int step_cmp(const void *pa, const void *pb) {
    const Step *a = pa, *b = pb;
    return a->rate < b->rate ? -1 : a->rate > b->rate ? 1 : 0;
}

static double lp_bound(double min_cost_sum, double min_weight_sum, const Step *steps,
                       int nsteps, double cost_so_far, double weight_left) {
    double bound = cost_so_far + min_cost_sum;
    if (min_weight_sum <= weight_left + 1e-12) return bound;
    double excess = min_weight_sum - weight_left;
    for (int i = 0; i < nsteps; i++) {
        if (excess <= 1e-12) break;
        double take = steps[i].dw < excess ? steps[i].dw : excess;
        bound += steps[i].rate * take;
        excess -= take;
    }
    if (excess > 1e-12) return INFINITY;
    return bound;
}

static void dfs(Dfs *c, int depth, double cost, double weight, int *cur) {
    c->nodes++;
    if (c->nodes > c->node_cap) {
        c->capped = 1;
        return;
    }
    if (depth == c->n) {
        if (cost < c->best_cost - 1e-12) {
            c->best_cost = cost;
            memcpy(c->best_choice, cur, (size_t)c->n * sizeof(int));
        }
        return;
    }
    if (cost + c->suffix_min_cost[depth] >= c->best_cost - 1e-12) return;
    if (weight + c->suffix_min_weight[depth] > c->budget + 1e-12) return;
    double lb = lp_bound(c->suffix_min_cost[depth], c->suffix_mincost_weight[depth],
                         c->steps_by_depth[depth], c->nsteps_by_depth[depth], cost,
                         c->budget - weight);
    if (lb >= c->best_cost - 1e-12) return;
    for (int i = 0; i < c->glen[depth]; i++) {
        const Opt o = c->groups[depth][i];
        if (weight + o.weight + c->suffix_min_weight[depth + 1] > c->budget + 1e-12) continue;
        cur[depth] = i;
        dfs(c, depth + 1, cost + o.cost, weight + o.weight, cur);
        if (c->capped) return;
    }
}

/* Port of ilp::mckp::solve_mckp. Returns total cost, fills choice (original
 * option index per original group), or NAN when infeasible. */
static double solve_mckp(int G, int L, const double *cost, const double *weight,
                         double budget, int *choice, uint64_t *nodes_out) {
    /* Dominance preprocess. */
    Opt *store = malloc((size_t)G * MAXL * sizeof(Opt));
    Opt **groups = malloc((size_t)G * sizeof(Opt *));
    int *glen = malloc((size_t)G * sizeof(int));
    for (int g = 0; g < G; g++) {
        Opt tmp[MAXL];
        for (int i = 0; i < L; i++) {
            tmp[i].cost = cost[g * L + i];
            tmp[i].weight = weight[g * L + i];
            tmp[i].orig = i;
        }
        qsort(tmp, (size_t)L, sizeof(Opt), opt_cmp);
        Opt *kept = store + (size_t)g * MAXL;
        int nk = 0;
        for (int i = 0; i < L; i++)
            if (nk == 0 || tmp[i].weight < kept[nk - 1].weight - 1e-15) kept[nk++] = tmp[i];
        groups[g] = kept;
        glen[g] = nk;
    }
    double min_weight_sum = 0.0;
    for (int g = 0; g < G; g++) {
        double mw = INFINITY;
        for (int i = 0; i < glen[g]; i++)
            if (groups[g][i].weight < mw) mw = groups[g][i].weight;
        min_weight_sum += mw;
    }
    if (min_weight_sum > budget + 1e-12) {
        free(store);
        free(groups);
        free(glen);
        return NAN;
    }
    /* Order by descending cost spread. */
    int *order = malloc((size_t)G * sizeof(int));
    double *spread = malloc((size_t)G * sizeof(double));
    for (int g = 0; g < G; g++) {
        double lo = INFINITY, hi = -INFINITY;
        for (int i = 0; i < glen[g]; i++) {
            if (groups[g][i].cost < lo) lo = groups[g][i].cost;
            if (groups[g][i].cost > hi) hi = groups[g][i].cost;
        }
        spread[g] = hi - lo;
        order[g] = g;
    }
    for (int i = 1; i < G; i++) { /* insertion sort, stable, desc spread */
        int oi = order[i];
        int j = i - 1;
        while (j >= 0 && spread[order[j]] < spread[oi]) {
            order[j + 1] = order[j];
            j--;
        }
        order[j + 1] = oi;
    }
    const Opt **ordered = malloc((size_t)G * sizeof(Opt *));
    int *olen = malloc((size_t)G * sizeof(int));
    for (int d = 0; d < G; d++) {
        ordered[d] = groups[order[d]];
        olen[d] = glen[order[d]];
    }
    /* Greedy incumbent (min-weight start, best-ratio feasible downgrades). */
    int *bchoice = malloc((size_t)G * sizeof(int));
    double bweight = 0.0, bcost = 0.0;
    for (int d = 0; d < G; d++) {
        bchoice[d] = olen[d] - 1;
        bweight += ordered[d][bchoice[d]].weight;
        bcost += ordered[d][bchoice[d]].cost;
    }
    for (;;) {
        int bg = -1, bnext = -1;
        double brate = -INFINITY;
        for (int d = 0; d < G; d++) {
            int ci = bchoice[d];
            for (int next = ci - 1; next >= 0; next--) {
                double dw = ordered[d][next].weight - ordered[d][ci].weight;
                double dc = ordered[d][ci].cost - ordered[d][next].cost;
                if (dc <= 0.0) continue;
                if (bweight + dw <= budget + 1e-12) {
                    double rate = dc / (dw > 1e-300 ? dw : 1e-300);
                    if (rate > brate) {
                        brate = rate;
                        bg = d;
                        bnext = next;
                    }
                    break;
                }
            }
        }
        if (bg < 0) break;
        bweight += ordered[bg][bnext].weight - ordered[bg][bchoice[bg]].weight;
        bcost -= ordered[bg][bchoice[bg]].cost - ordered[bg][bnext].cost;
        bchoice[bg] = bnext;
    }
    /* Suffix bounds + per-depth presorted LP upgrade steps. */
    double *smc = calloc((size_t)G + 1, sizeof(double));
    double *smw = calloc((size_t)G + 1, sizeof(double));
    double *smcw = calloc((size_t)G + 1, sizeof(double));
    Step **steps = malloc(((size_t)G + 1) * sizeof(Step *));
    int *nsteps = calloc((size_t)G + 1, sizeof(int));
    steps[G] = NULL;
    for (int d = G - 1; d >= 0; d--) {
        double mc = INFINITY, mw = INFINITY;
        for (int i = 0; i < olen[d]; i++) {
            if (ordered[d][i].cost < mc) mc = ordered[d][i].cost;
            if (ordered[d][i].weight < mw) mw = ordered[d][i].weight;
        }
        smc[d] = smc[d + 1] + mc;
        smw[d] = smw[d + 1] + mw;
        smcw[d] = smcw[d + 1] + ordered[d][0].weight;
        int cap = nsteps[d + 1] + olen[d];
        Step *st = malloc((size_t)(cap > 0 ? cap : 1) * sizeof(Step));
        memcpy(st, steps[d + 1], (size_t)nsteps[d + 1] * sizeof(Step));
        int ns = nsteps[d + 1];
        for (int i = 0; i + 1 < olen[d]; i++) {
            double dc = ordered[d][i + 1].cost - ordered[d][i].cost;
            double dw = ordered[d][i].weight - ordered[d][i + 1].weight;
            if (dw > 0.0) {
                st[ns].rate = dc / dw;
                st[ns].dw = dw;
                ns++;
            }
        }
        qsort(st, (size_t)ns, sizeof(Step), step_cmp);
        steps[d] = st;
        nsteps[d] = ns;
    }
    int *cur = calloc((size_t)G, sizeof(int));
    Dfs ctx = {.groups = ordered,
               .glen = olen,
               .n = G,
               .budget = budget,
               .suffix_min_cost = smc,
               .suffix_min_weight = smw,
               .suffix_mincost_weight = smcw,
               .steps_by_depth = steps,
               .nsteps_by_depth = nsteps,
               .best_choice = bchoice,
               .best_cost = bcost,
               .nodes = 0,
               .node_cap = 50000000ULL,
               .capped = 0};
    dfs(&ctx, 0, 0.0, 0.0, cur);
    for (int d = 0; d < G; d++) choice[order[d]] = ordered[d][bchoice[d]].orig;
    double total = ctx.best_cost;
    if (nodes_out) *nodes_out = ctx.nodes;
    for (int d = 0; d < G; d++) free(steps[d]);
    free(steps);
    free(nsteps);
    free(cur);
    free(smc);
    free(smw);
    free(smcw);
    free(bchoice);
    free(ordered);
    free(olen);
    free(order);
    free(spread);
    free(store);
    free(groups);
    free(glen);
    return total;
}

/* ------------------------------------------ drift / re-plan structural --- */

#define VTH 0.35
#define ALPHA 1.3
#define NLEVELS 4
#define NEURONS 138

static const double LVL_VOLTS[NLEVELS] = {0.5, 0.6, 0.7, 0.8};
/* Typical 8x8 Baugh-Wooley characterization: variance collapses toward the
 * error-onset voltage (structural stand-in for the pipeline's artifacts). */
static const double LVL_VAR[NLEVELS] = {4.1e6, 7.3e4, 2.4e1, 0.0};
static const double LVL_ERR[NLEVELS] = {0.62, 0.11, 1.9e-3, 0.0};

static double alpha_power(double v) { return v / pow(v - VTH, ALPHA); }

/* Mirror of Technology::invert_alpha_power / effective_voltage. */
static double effective_voltage(double v, double dvth) {
    if (dvth == 0.0) return v;
    double target = v / pow(v - (VTH + dvth), ALPHA);
    double lo = VTH + 1e-9, hi = v;
    for (int i = 0; i < 80; i++) {
        double mid = 0.5 * (lo + hi);
        if (alpha_power(mid) > target)
            lo = mid;
        else
            hi = mid;
    }
    return 0.5 * (lo + hi);
}

typedef struct {
    double volts, lnvar, lnerr;
} Knot;

/* Mirror of DriftInterpolator::moments_at (log-linear segments). */
static double moments_at(const Knot *k, int nk, double v_onset, double v, double *err) {
    if (v >= v_onset || nk == 0) {
        *err = 0.0;
        return 0.0;
    }
    if (v >= k[nk - 1].volts) {
        double t = (v - k[nk - 1].volts) / fmax(v_onset - k[nk - 1].volts, 1e-12);
        t = t < 0.0 ? 0.0 : t > 1.0 ? 1.0 : t;
        double decay = pow(1e-9, t);
        *err = exp(k[nk - 1].lnerr) * decay;
        return exp(k[nk - 1].lnvar) * decay;
    }
    if (v <= k[0].volts) {
        int b = nk >= 2 ? 1 : 0;
        double t = b ? (v - k[0].volts) / (k[b].volts - k[0].volts) : 0.0;
        *err = exp(k[0].lnerr + t * (k[b].lnerr - k[0].lnerr));
        return exp(k[0].lnvar + t * (k[b].lnvar - k[0].lnvar));
    }
    for (int i = 0; i + 1 < nk; i++) {
        if (v <= k[i + 1].volts) {
            double t = (v - k[i].volts) / (k[i + 1].volts - k[i].volts);
            *err = exp(k[i].lnerr + t * (k[i + 1].lnerr - k[i].lnerr));
            return exp(k[i].lnvar + t * (k[i + 1].lnvar - k[i].lnvar));
        }
    }
    *err = 0.0;
    return 0.0;
}

/* One registry.drifted(dvth) pass: interpolator build + per-level effective
 * voltage (bisection) + moment re-read. Returns drifted variances. */
static void drifted_vars(double dvth, double *vars) {
    Knot knots[NLEVELS];
    int nk = 0;
    for (int l = 0; l < NLEVELS; l++)
        if (LVL_VAR[l] > 0.0) {
            knots[nk].volts = LVL_VOLTS[l];
            knots[nk].lnvar = log(LVL_VAR[l]);
            knots[nk].lnerr = log(fmax(LVL_ERR[l], 1e-300));
            nk++;
        }
    double v_onset = 0.78; /* tech.error_onset_voltage() stand-in */
    for (int l = 0; l < NLEVELS; l++) {
        double v_eff = effective_voltage(LVL_VOLTS[l], dvth);
        double err;
        vars[l] = moments_at(knots, nk, v_onset, v_eff, &err);
    }
}

/* Structural stand-in for PePowerModel::neuron_energy(k, v). */
static double neuron_energy(int k, double v) { return (double)k * (0.52 * v * v + 0.031 * v); }

/* ---------------------------------------------------------------- main --- */

static volatile int64_t sink; /* black_box */

int main(void) {
    if (!__builtin_cpu_supports("avx2")) {
        fprintf(stderr, "host has no AVX2; replica measures the scalar path only\n");
    }

    /* === L3b workload: 256x784x128 int8, seed 2, reps 10 (as the bench) === */
    const size_t M = 256, K = 784, N = 128;
    const int reps = 10;
    const double macs = (double)(M * K * N);
    int8_t *a = malloc(M * K), *w = malloc(K * N);
    Xo rng = xo_seeded(2);
    for (size_t i = 0; i < M * K; i++) a[i] = (int8_t)xo_range_i64(&rng, -127, 127);
    for (size_t i = 0; i < K * N; i++) w[i] = (int8_t)xo_range_i64(&rng, -127, 127);

    size_t max_tiles = ((K + TILE_K - 1) / TILE_K) * ((N + TILE_N - 1) / TILE_N);
    Tile *tiles = malloc(max_tiles * sizeof(Tile));
    /* interleaved packing can need one extra zero row per k-tile */
    int8_t *packed = malloc((K + TILE_K) * N);
    int32_t *out = malloc(M * N * sizeof(int32_t));

    /* scalar vs AVX2 kernel (matmul_i8_path replica: pack every call) */
    double t0, dt;
    matmul_path(0, a, w, M, K, N, out, packed, tiles); /* warm-up */
    t0 = now_s();
    for (int r = 0; r < reps; r++) {
        matmul_path(0, a, w, M, K, N, out, packed, tiles);
        sink += out[0];
    }
    dt = now_s() - t0;
    double scalar_mmacs = macs * reps / dt / 1e6;

    matmul_path(1, a, w, M, K, N, out, packed, tiles);
    int32_t *ref = malloc(M * N * sizeof(int32_t));
    memcpy(ref, out, M * N * sizeof(int32_t));
    matmul_path(0, a, w, M, K, N, out, packed, tiles);
    if (memcmp(ref, out, M * N * sizeof(int32_t)) != 0) {
        fprintf(stderr, "FATAL: AVX2 and scalar kernels disagree\n");
        return 1;
    }
    t0 = now_s();
    for (int r = 0; r < reps; r++) {
        matmul_path(1, a, w, M, K, N, out, packed, tiles);
        sink += out[0];
    }
    dt = now_s() - t0;
    double simd_mmacs = macs * reps / dt / 1e6;

    /* exec::Exact replica: kernel + fresh output Vec per call */
    t0 = now_s();
    for (int r = 0; r < reps; r++) {
        int32_t *o = malloc(M * N * sizeof(int32_t));
        matmul_path(1, a, w, M, K, N, o, packed, tiles);
        sink += o[0];
        free(o);
    }
    dt = now_s() - t0;
    double exec_exact_mmacs = macs * reps / dt / 1e6;

    /* exec::Statistical nominal: kernel + all-silent column scan */
    double *cmean = calloc(N, sizeof(double)), *cstd = calloc(N, sizeof(double));
    double *gbuf = malloc(M * sizeof(double));
    Xo nrng = xo_seeded(3);
    t0 = now_s();
    for (int r = 0; r < reps; r++) {
        int32_t *o = malloc(M * N * sizeof(int32_t));
        matmul_path(1, a, w, M, K, N, o, packed, tiles);
        int silent = 1;
        for (size_t c = 0; c < N; c++)
            if (cmean[c] != 0.0 || cstd[c] != 0.0) silent = 0;
        if (!silent) add_noise_keyed(o, N, M, cmean, cstd, N, xo_next(&nrng), gbuf);
        sink += o[0];
        free(o);
    }
    dt = now_s() - t0;
    double exec_nom_mmacs = macs * reps / dt / 1e6;

    /* exec::Statistical VOS: every column at 0.5 V (full noise injection) */
    for (size_t c = 0; c < N; c++) {
        cmean[c] = -37.4; /* column_mean(k=784) scale at 0.5 V */
        cstd[c] = sqrt((double)K * LVL_VAR[0] / 784.0);
    }
    t0 = now_s();
    for (int r = 0; r < reps; r++) {
        int32_t *o = malloc(M * N * sizeof(int32_t));
        matmul_path(1, a, w, M, K, N, o, packed, tiles);
        add_noise_keyed(o, N, M, cmean, cstd, N, xo_next(&nrng), gbuf);
        sink += o[0];
        free(o);
    }
    dt = now_s() - t0;
    double exec_vos_mmacs = macs * reps / dt / 1e6;

    /* cycle-sim replica (scalar tiles + per-tile stats bookkeeping) */
    uint64_t sim_macs = 0, sim_cycles = 0;
    t0 = now_s();
    {
        size_t ntiles;
        plan_tiles(K, N, 0, tiles, &ntiles);
        pack_tiles(w, N, 0, tiles, ntiles, packed);
        memset(out, 0, M * N * sizeof(int32_t));
        for (size_t t = 0; t < ntiles; t++) {
            const Tile *ti = &tiles[t];
            acc_tile_scalar(a, K, ti->k0, ti->kr, packed + ti->off, ti->nc, out, N, ti->n0,
                            M);
            sim_macs += (uint64_t)(M * ti->kr * ti->nc);
            sim_cycles += (uint64_t)(ti->kr + ti->nc + M);
        }
        add_noise_keyed(out, N, M, cmean, cstd, N, xo_next(&nrng), gbuf);
    }
    dt = now_s() - t0;
    double cycle_vos_mmacs = (double)sim_macs / dt / 1e6;
    (void)sim_cycles;

    /* seed-era "before" matmul: i64 column reduction + per-(s,c) draw/tile */
    Xo brng = xo_seeded(4);
    t0 = now_s();
    matmul_seed_vos(a, w, M, K, N, cmean[0], cstd[0], out, &brng);
    dt = now_s() - t0;
    sink += out[0];
    double before_vos_mmacs = macs / dt / 1e6;

    /* === L3d: quantized forward, batch 64, 784->128->10, reps 30 ========= */
    const size_t B = 64, H = 128, C = 10;
    int d_reps = 30;
    float *x = malloc(B * K * sizeof(float));
    Xo drng = xo_seeded(5);
    for (size_t i = 0; i < B * K; i++) x[i] = (float)xo_range_f64(&drng, 0.0, 1.0);
    int8_t *w1 = malloc(H * K), *w2 = malloc(C * H); /* transposed [out][in] */
    for (size_t i = 0; i < H * K; i++) w1[i] = (int8_t)xo_range_i64(&drng, -127, 127);
    for (size_t i = 0; i < C * H; i++) w2[i] = (int8_t)xo_range_i64(&drng, -127, 127);
    float bias1[128] = {0}, bias2[10] = {0};
    const float s1 = 1.0f / 127.0f, sw1 = 0.01f, sw2 = 0.02f, s2 = 1.0f / 64.0f;
    double before_dt = 0.0;

    t0 = now_s();
    for (int r = 0; r < d_reps; r++) {
        /* QuantMac::forward_with replica: quantize in, i8t matmul, dequant */
        int8_t *xq = malloc(B * K);
        for (size_t i = 0; i < B * K; i++) {
            float q = roundf(x[i] / s1);
            xq[i] = (int8_t)(q < -127 ? -127 : q > 127 ? 127 : q);
        }
        float *h = malloc(B * H * sizeof(float));
        for (size_t s = 0; s < B; s++)
            for (size_t u = 0; u < H; u++) {
                int32_t acc = dot_i8_avx2(xq + s * K, w1 + u * K, K);
                float y = (float)acc * (sw1 * s1) + bias1[u];
                h[s * H + u] = y > 0 ? y : 0; /* relu */
            }
        int8_t *hq = malloc(B * H);
        for (size_t i = 0; i < B * H; i++) {
            float q = roundf(h[i] / s2);
            hq[i] = (int8_t)(q < -127 ? -127 : q > 127 ? 127 : q);
        }
        float *logits = malloc(B * C * sizeof(float));
        for (size_t s = 0; s < B; s++)
            for (size_t u = 0; u < C; u++) {
                int32_t acc = dot_i8_avx2(hq + s * H, w2 + u * H, H);
                logits[s * C + u] = (float)acc * (sw2 * s2) + bias2[u];
            }
        sink += (int64_t)logits[0];
        free(xq);
        free(h);
        free(hq);
        free(logits);
    }
    dt = now_s() - t0;
    double infs_per_s = (double)(d_reps * B) / dt;

    /* "before" forward: seed-era scalar statistical matmul per layer */
    {
        int8_t *xq = malloc(B * K);
        for (size_t i = 0; i < B * K; i++) {
            float q = roundf(x[i] / s1);
            xq[i] = (int8_t)(q < -127 ? -127 : q > 127 ? 127 : q);
        }
        /* untransposed copies for the k-major seed loop */
        int8_t *w1t = malloc(K * H);
        for (size_t kk2 = 0; kk2 < K; kk2++)
            for (size_t u = 0; u < H; u++) w1t[kk2 * H + u] = w1[u * K + kk2];
        int32_t *o1 = malloc(B * H * sizeof(int32_t));
        Xo frng = xo_seeded(6);
        t0 = now_s();
        for (int r = 0; r < d_reps; r++) {
            matmul_seed_vos(xq, w1t, B, K, H, 0.0, 1.0, o1, &frng);
            sink += o1[0];
        }
        before_dt = now_s() - t0;
        free(xq);
        free(w1t);
        free(o1);
    }
    double before_infs_per_s = (double)(d_reps * B) / before_dt;

    /* === L3c / L3i: MCKP assignment at pipeline scale (138 x 4) ========== */
    double es[NEURONS];
    int fan_in[NEURONS];
    Xo erng = xo_seeded(1234);
    for (int g = 0; g < NEURONS; g++) {
        es[g] = fabs(xo_gaussian(&erng, 0.0, 0.05));
        fan_in[g] = g < 128 ? 784 : 128;
    }
    double *cost = malloc(NEURONS * NLEVELS * sizeof(double));
    double *wgt = malloc(NEURONS * NLEVELS * sizeof(double));
    double base_vars[NLEVELS];
    memcpy(base_vars, LVL_VAR, sizeof(base_vars));
    double wmax_sum = 0.0;
    for (int g = 0; g < NEURONS; g++) {
        double wmax = 0.0;
        for (int l = 0; l < NLEVELS; l++) {
            cost[g * NLEVELS + l] = neuron_energy(fan_in[g], LVL_VOLTS[l]);
            wgt[g * NLEVELS + l] = es[g] * es[g] * fan_in[g] * base_vars[l];
            if (wgt[g * NLEVELS + l] > wmax) wmax = wgt[g * NLEVELS + l];
        }
        wmax_sum += wmax;
    }
    double budget_abs = 0.08 * wmax_sum;
    int choice[NEURONS];
    uint64_t nodes = 0;
    t0 = now_s();
    double tc = solve_mckp(NEURONS, NLEVELS, cost, wgt, budget_abs, choice, &nodes);
    dt = now_s() - t0;
    double ilp_ms = dt * 1e3;
    if (tc != tc) {
        fprintf(stderr, "FATAL: assignment instance infeasible\n");
        return 1;
    }

    /* cross-check against the pinned test instance (seeded(99), 138x4) */
    {
        Xo trng = xo_seeded(99);
        double tcost[NEURONS * NLEVELS], twgt[NEURONS * NLEVELS];
        for (int g = 0; g < NEURONS; g++)
            for (int l = 0; l < NLEVELS; l++)
                tcost[g * NLEVELS + l] = xo_range_f64(&trng, 0.1, 10.0);
        for (int g = 0; g < NEURONS; g++)
            for (int l = 0; l < NLEVELS; l++)
                twgt[g * NLEVELS + l] = xo_range_f64(&trng, 0.0, 5.0);
        double minw = 0, maxw = 0;
        for (int g = 0; g < NEURONS; g++) {
            double lo = INFINITY, hi = -INFINITY;
            for (int l = 0; l < NLEVELS; l++) {
                double v = twgt[g * NLEVELS + l];
                if (v < lo) lo = v;
                if (v > hi) hi = v;
            }
            minw += lo;
            maxw += hi;
        }
        double tbudget = xo_range_f64(&trng, minw, maxw);
        int tch[NEURONS];
        t0 = now_s();
        double c99 = solve_mckp(NEURONS, NLEVELS, tcost, twgt, tbudget, tch, NULL);
        dt = now_s() - t0;
        fprintf(stderr, "cross-check seeded(99) 138x4: cost %.4f in %.2f ms (test pin: <5 s)\n",
                c99, dt * 1e3);
    }

    /* L3i drifted-ES eval: drifted() + served_mse, 50 reps */
    int i_reps = 50;
    double dvars[NLEVELS];
    t0 = now_s();
    for (int r = 0; r < i_reps; r++) {
        drifted_vars(0.01, dvars);
        double mse = 0.0;
        for (int g = 0; g < NEURONS; g++)
            mse += es[g] * es[g] * (double)fan_in[g] * dvars[choice[g]];
        sink += (int64_t)mse;
    }
    dt = now_s() - t0;
    double drift_eval_us = dt / i_reps * 1e6;

    /* L3i warm re-plan: freeze-unchanged + MCKP on the thawed residual */
    drifted_vars(0.01, dvars);
    t0 = now_s();
    double replan_warm_ms;
    {
        for (int r = 0; r < i_reps; r++) {
            double bscale = 0.9;
            double budget = budget_abs * bscale;
            double freeze_limit = 0.02 * budget / NEURONS;
            int sub_map[NEURONS], nsub = 0;
            double frozen_w = 0.0;
            for (int g = 0; g < NEURONS; g++) {
                double w_old = es[g] * es[g] * fan_in[g] * base_vars[choice[g]];
                double w_new = es[g] * es[g] * fan_in[g] * dvars[choice[g]];
                if (fabs(w_new - w_old) <= freeze_limit)
                    frozen_w += w_new;
                else
                    sub_map[nsub++] = g;
            }
            if (frozen_w > budget) { /* thaw-all fallback */
                nsub = 0;
                frozen_w = 0.0;
                for (int g = 0; g < NEURONS; g++) sub_map[nsub++] = g;
            }
            if (nsub > 0) {
                double *scost = malloc((size_t)nsub * NLEVELS * sizeof(double));
                double *swgt = malloc((size_t)nsub * NLEVELS * sizeof(double));
                for (int i = 0; i < nsub; i++) {
                    int g = sub_map[i];
                    for (int l = 0; l < NLEVELS; l++) {
                        scost[i * NLEVELS + l] = neuron_energy(fan_in[g], LVL_VOLTS[l]);
                        swgt[i * NLEVELS + l] =
                            es[g] * es[g] * fan_in[g] * dvars[l];
                    }
                }
                int sch[NEURONS];
                double sc = solve_mckp(nsub, NLEVELS, scost, swgt, budget - frozen_w, sch,
                                       NULL);
                sink += (int64_t)sc;
                free(scost);
                free(swgt);
            }
        }
        dt = now_s() - t0;
        replan_warm_ms = dt / i_reps * 1e3;
    }

    /* L3i cold re-plan: full build + solve on the drifted registry */
    t0 = now_s();
    for (int r = 0; r < i_reps; r++) {
        double *ccost = malloc(NEURONS * NLEVELS * sizeof(double));
        double *cwgt = malloc(NEURONS * NLEVELS * sizeof(double));
        for (int g = 0; g < NEURONS; g++)
            for (int l = 0; l < NLEVELS; l++) {
                ccost[g * NLEVELS + l] = neuron_energy(fan_in[g], LVL_VOLTS[l]);
                cwgt[g * NLEVELS + l] = es[g] * es[g] * fan_in[g] * dvars[l];
            }
        int cch[NEURONS];
        double cc = solve_mckp(NEURONS, NLEVELS, ccost, cwgt, budget_abs * 0.9, cch, NULL);
        sink += (int64_t)cc;
        free(ccost);
        free(cwgt);
    }
    dt = now_s() - t0;
    double replan_cold_ms = dt / i_reps * 1e3;

    /* L3i swap: levels_from_plans (2 plans x NoiseSpec) + pointer swap */
    typedef struct {
        double *mean, *std;
    } Spec;
    Spec *active = NULL;
    uint64_t generation = 0;
    t0 = now_s();
    for (int r = 0; r < i_reps; r++) {
        Spec *next = malloc(2 * sizeof(Spec));
        for (int p = 0; p < 2; p++) {
            next[p].mean = malloc(NEURONS * sizeof(double));
            next[p].std = malloc(NEURONS * sizeof(double));
            for (int g = 0; g < NEURONS; g++) {
                int lvl = p == 0 ? NLEVELS - 1 : choice[g];
                if (lvl >= NLEVELS) { /* validation */
                    fprintf(stderr, "bad level\n");
                    return 1;
                }
                next[p].mean[g] = -0.002 * fan_in[g] * (base_vars[lvl] > 0.0);
                next[p].std[g] = sqrt((double)fan_in[g] * base_vars[lvl] / 784.0);
            }
        }
        Spec *old = __atomic_exchange_n(&active, next, __ATOMIC_SEQ_CST);
        __atomic_add_fetch(&generation, 1, __ATOMIC_SEQ_CST);
        if (old) {
            for (int p = 0; p < 2; p++) {
                free(old[p].mean);
                free(old[p].std);
            }
            free(old);
        }
    }
    dt = now_s() - t0;
    double swap_us = dt / i_reps * 1e6;
    if (active) {
        for (int p = 0; p < 2; p++) {
            free(active[p].mean);
            free(active[p].std);
        }
        free(active);
    }

    /* ------------------------------------------------------------ report */
    printf("{\n");
    printf("  \"simd_path\": \"%s\",\n", __builtin_cpu_supports("avx2") ? "avx2" : "scalar");
    printf("  \"l3b_kernel_scalar_mmacs\": %.1f,\n", scalar_mmacs);
    printf("  \"l3b_kernel_simd_mmacs\": %.1f,\n", simd_mmacs);
    printf("  \"l3b_simd_speedup\": %.2f,\n", simd_mmacs / scalar_mmacs);
    printf("  \"l3b_exec_exact_mmacs\": %.1f,\n", exec_exact_mmacs);
    printf("  \"l3b_exec_statistical_nominal_mmacs\": %.1f,\n", exec_nom_mmacs);
    printf("  \"l3b_exec_statistical_vos_mmacs\": %.1f,\n", exec_vos_mmacs);
    printf("  \"l3b_cycle_sim_vos_mmacs\": %.1f,\n", cycle_vos_mmacs);
    printf("  \"before_l3b_cycle_sim_vos_mmacs\": %.1f,\n", before_vos_mmacs);
    printf("  \"l3d_inferences_per_s\": %.1f,\n", infs_per_s);
    printf("  \"before_l3d_inferences_per_s\": %.1f,\n", before_infs_per_s);
    printf("  \"l3c_ilp_ms\": %.3f,\n", ilp_ms);
    printf("  \"l3c_nodes\": %llu,\n", (unsigned long long)nodes);
    printf("  \"l3i_drifted_es_eval_us\": %.2f,\n", drift_eval_us);
    printf("  \"l3i_replan_warm_ms\": %.4f,\n", replan_warm_ms);
    printf("  \"l3i_replan_cold_ms\": %.4f,\n", replan_cold_ms);
    printf("  \"l3i_swap_us\": %.2f\n", swap_us);
    printf("}\n");

    free(a);
    free(w);
    free(tiles);
    free(packed);
    free(out);
    free(ref);
    free(cmean);
    free(cstd);
    free(gbuf);
    free(x);
    free(w1);
    free(w2);
    free(cost);
    free(wgt);
    return (int)(sink & 0);
}
