#!/usr/bin/env python3
"""Gate a perf_hotpaths JSON report against the checked-in BENCH records.

Usage: check_bench_regression.py <bench_report.json> [--repo-root DIR]

Three layers of checking, all driven by the "gates" sections of the
BENCH_*.json records (so thresholds live next to the numbers they guard):

1. Presence — every gated key must be emitted and non-null. A key that
   silently disappears from the bench is a gate bypass, not a pass.
2. Absolute bounds — throughput keys must be >= their recorded floor
   (BENCH_exec_refactor.json, BENCH_parallel_exec.json); latency keys must
   be <= their recorded ceiling (BENCH_adaptive_replan.json). Floors sit
   well under the recorded figures so runner-class differences don't trip
   them; ceilings are generous for the same reason.
3. Calibrated relative check — a >15% throughput regression fails even on
   a runner much faster than the record host. The runner's speed is
   calibrated by the scalar-kernel key (same workload, no SIMD, so it
   tracks the runner, not the optimization), and every other throughput
   key must reach 85% of its recorded value scaled by that calibration
   ratio. A uniform runner slowdown cancels out; an optimization-specific
   regression (SIMD path losing its edge, exec wrapper growing overhead,
   serving path re-allocating) does not.

The parallel-speedup gate applies only when the runner actually has
multiple cores (l3f_threads >= 2): the record host has one core, where a
speedup of 1.0 is the honest expected value.
"""

import argparse
import json
import pathlib
import sys

REGRESSION_TOLERANCE = 0.85  # fail below 85% of calibrated expectation
CALIBRATION_KEY = "l3b_kernel_scalar_mmacs"

# Throughput keys subject to the calibrated 15% rule, all from the
# "after" section of BENCH_exec_refactor.json (higher is better).
CALIBRATED_KEYS = [
    "l3b_kernel_simd_mmacs",
    "l3b_exec_exact_mmacs",
    "l3b_exec_statistical_nominal_mmacs",
    "l3b_exec_statistical_vos_mmacs",
    "l3d_inferences_per_s",
]

# Keys that must be emitted and numeric but have no recorded baseline yet
# (the TE-Drop backend and the evented serving frontend landed after the
# BENCH records were captured; the serving figures live in
# BENCH_serving.json). A key vanishing from the bench is a gate bypass even
# without a floor to hold it to; once a record host re-measures, these
# graduate to a gates section.
PRESENCE_ONLY_KEYS = [
    "l3j_tedrop_nominal_mmacs",
    "l3j_tedrop_vos_mmacs",
    "l3j_tedrop_drop_cost",
    "l3k_evented_rps",
    "l3k_p99_us_at_slo",
    "l3k_shed_fraction",
    "l3l_obs_hook_ns",
]


def load(path):
    with open(path) as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("report")
    ap.add_argument("--repo-root", default=str(pathlib.Path(__file__).resolve().parent.parent))
    args = ap.parse_args()

    root = pathlib.Path(args.repo_root)
    report = load(args.report)
    exec_rec = load(root / "BENCH_exec_refactor.json")
    par_rec = load(root / "BENCH_parallel_exec.json")
    adapt_rec = load(root / "BENCH_adaptive_replan.json")
    serving_rec = load(root / "BENCH_serving.json")

    failures = []
    checks = 0

    def emitted(key):
        v = report.get(key)
        if not isinstance(v, (int, float)):
            failures.append(f"missing/non-numeric key in bench report: {key}")
            return None
        return v

    # --- layer 2: absolute floors (throughput, higher is better) ---------
    floors = {}
    floors.update(exec_rec["gates"])
    floors.update(par_rec["gates"])
    special = {"comment", "l3f_parallel_speedup_min_if_multicore"}
    for key, floor in floors.items():
        if key in special:
            continue
        checks += 1
        v = emitted(key)
        if v is not None and v < floor:
            failures.append(f"{key} = {v:.1f} below floor {floor}")

    # --- layer 2: absolute ceilings (latency, lower is better) -----------
    for key, ceiling in adapt_rec["gates"].items():
        if key == "comment":
            continue
        checks += 1
        v = emitted(key)
        if v is not None and v > ceiling:
            failures.append(f"{key} = {v:.2f} above ceiling {ceiling}")

    # --- multicore-only scaling gate --------------------------------------
    threads = report.get("l3f_threads")
    min_speedup = par_rec["gates"]["l3f_parallel_speedup_min_if_multicore"]
    if isinstance(threads, (int, float)) and threads >= 2:
        checks += 1
        v = emitted("l3f_parallel_speedup")
        if v is not None and v < min_speedup:
            failures.append(
                f"l3f_parallel_speedup = {v:.2f} below {min_speedup} "
                f"on a {int(threads)}-thread runner"
            )

    # --- observability-overhead ceiling (same-run ratio, runner-independent)
    obs_cap = serving_rec["gates"].get("l3l_obs_overhead_pct_max")
    if obs_cap is not None:
        checks += 1
        v = emitted("l3l_obs_overhead_pct")
        if v is not None and v > obs_cap:
            failures.append(
                f"l3l_obs_overhead_pct = {v:.4f} above ceiling {obs_cap} "
                "(obs hooks with sampling off must be near-free)"
            )

    # --- zero-repack serving data path (L3m) ------------------------------
    # Floors sit several-fold under the replica record (runner classes
    # differ); the speedup and allocation gates are same-run ratios, so a
    # uniformly slower runner cancels out. The speedup minima assume a SIMD
    # interleaving path — the XTPU_SIMD=scalar CI leg skips this script and
    # asserts presence + zero allocations directly (the scalar layout has
    # no packing edge by design).
    l3m_gates = serving_rec["gates"]
    for key, floor in (
        ("l3m_prepacked_mmacs", l3m_gates.get("l3m_prepacked_mmacs_floor")),
        ("l3m_serve_infs", l3m_gates.get("l3m_serve_infs_floor")),
        ("l3m_prepacked_speedup", l3m_gates.get("l3m_prepacked_speedup_min")),
        ("l3m_serve_speedup_vs_l3d", l3m_gates.get("l3m_serve_speedup_min")),
    ):
        if floor is None:
            continue
        checks += 1
        v = emitted(key)
        if v is not None and v < floor:
            failures.append(f"{key} = {v:.2f} below floor {floor}")
    allocs_cap = l3m_gates.get("l3m_allocs_per_req_max")
    if allocs_cap is not None:
        checks += 1
        v = emitted("l3m_allocs_per_req")
        if v is not None and v > allocs_cap:
            failures.append(
                f"l3m_allocs_per_req = {v:.2f} above max {allocs_cap} "
                "(the warm prepacked serve loop must not allocate; "
                "build the bench with --features alloc-count)"
            )
    for key in ("l3m_percall_mmacs", "l3m_serve_baseline_infs"):
        checks += 1
        emitted(key)

    # --- layer 1: presence-only keys (no baseline recorded yet) -----------
    for key in PRESENCE_ONLY_KEYS:
        checks += 1
        emitted(key)

    # --- layer 3: calibrated 15% regression rule --------------------------
    recorded = exec_rec["after"]
    cal_meas = emitted(CALIBRATION_KEY)
    cal_rec = recorded[CALIBRATION_KEY]
    if cal_meas is not None and cal_rec:
        ratio = cal_meas / cal_rec
        for key in CALIBRATED_KEYS:
            checks += 1
            v = emitted(key)
            if v is None:
                continue
            expect = recorded[key] * ratio * REGRESSION_TOLERANCE
            if v < expect:
                failures.append(
                    f"{key} = {v:.1f}, below {expect:.1f} "
                    f"(85% of recorded {recorded[key]} x runner calibration {ratio:.2f})"
                )

    if failures:
        print(f"bench regression gate: {len(failures)} failure(s) / {checks} checks")
        for f in failures:
            print(f"  FAIL {f}")
        return 1
    print(f"bench regression gate: all {checks} checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
