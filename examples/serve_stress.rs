//! Closed-loop serving stress: thousands of concurrent connections against
//! the evented frontend, with SLO admission control and wear-leveled
//! shards — the ROADMAP's datacenter-scale acceptance run.
//!
//! One process plays both sides: an evented `xtpu` server (2 shards, one
//! pre-worn, wear-leveling routing, deadline shedding) and a nonblocking
//! closed-loop client driver (each connection keeps exactly one request in
//! flight). Traffic is 3:1 gentle (aggressive-VOS level) to harsh
//! (all-nominal level), so the wear-leveler's placement is visible in the
//! final `per_shard` counts: gentle traffic parks on the worn shard.
//!
//! Prints one JSON summary line (prefixed `STRESS_JSON `) asserting the
//! books: every sent request got exactly one reply (ok or typed shed),
//! the server's `requests`/`shed` counters agree with the client's count,
//! and served p99 stays under the stated SLO while shedding is active.
//!
//! ```sh
//! ulimit -n 65536   # 10k sockets on each side
//! cargo run --release --example serve_stress -- --conns 10000 --duration-s 5
//! ```

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use xtpu::config::ExperimentConfig;
use xtpu::fleet::WearLeveling;
use xtpu::nn::data::synth_mnist;
use xtpu::nn::layers::Activation;
use xtpu::nn::model::fc_mnist;
use xtpu::nn::quant::{NoiseSpec, QuantizedModel};
use xtpu::nn::train::{train, TrainConfig};
use xtpu::plan::VoltagePlan;
use xtpu::server::shard::WearConfig;
use xtpu::server::{
    BatchPolicy, Client, Engine, FrontendMode, FrontendOptions, QualityLevel, Server,
};
use xtpu::timing::voltage::VoltageLadder;
use xtpu::util::json::Json;
use xtpu::util::rng::Xoshiro256pp;
use xtpu::util::stats::LatencyHistogram;

fn arg(name: &str, default: f64) -> f64 {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Small deterministic engine (same construction as the serving tests).
fn build_engine() -> Engine {
    let mut rng = Xoshiro256pp::seeded(1);
    let mut model = fc_mnist(Activation::Relu, &mut rng);
    let train_set = synth_mnist(200, 5);
    train(&mut model, &train_set, &TrainConfig { epochs: 1, ..Default::default() });
    let calib = train_set.batch(&(0..16).collect::<Vec<_>>()).0;
    let q = QuantizedModel::quantize(&model, &calib);
    let n = q.num_neurons();
    let mut noisy = NoiseSpec::silent(n);
    for s in noisy.std.iter_mut().take(128) {
        *s = 2000.0;
    }
    let levels = vec![
        QualityLevel {
            name: "exact".into(),
            noise: NoiseSpec::silent(n),
            energy_saving: 0.0,
            energy: 10.0,
            predicted_mse: 0.0,
        },
        QualityLevel {
            name: "eco".into(),
            noise: noisy,
            energy_saving: 0.3,
            energy: 7.0,
            predicted_mse: 0.0,
        },
    ];
    Engine::new(q, levels, 784).unwrap()
}

/// Plans mirroring the two levels — level 0 all-nominal (harsh), level 1
/// all-bottom-rung (gentle) — so wear accounting and the wear-leveler see
/// the real intensity gap between the classes.
fn plans_for(engine: &Engine) -> Vec<VoltagePlan> {
    let q = &engine.quantized;
    let n = q.num_neurons();
    let cfg = ExperimentConfig::smoke();
    let volts: Vec<f64> =
        VoltageLadder::paper_default().levels().iter().map(|l| l.volts).collect();
    let top = volts.len() - 1;
    let mk = |name: &str, level: Vec<usize>, saving: f64| VoltagePlan {
        name: name.into(),
        mse_ub_fraction: 1.0,
        budget_abs: 0.1,
        baseline_mse: 0.1,
        fan_in: q.neuron_fan_in.clone(),
        es: vec![1.0; n],
        volts: volts.clone(),
        predicted_mse: 0.0,
        energy: 1.0,
        energy_saving: saving,
        optimal: true,
        solver: "ilp".into(),
        model_fingerprint: "fp".into(),
        config_hash: xtpu::plan::config_hash(&cfg),
        config: cfg.clone(),
        generation: 0,
        drift_delta_vth: 0.0,
        mode: "statistical".into(),
        level,
    };
    vec![mk("exact", vec![top; n], 0.0), mk("eco", vec![0; n], 0.35)]
}

struct Conn {
    stream: TcpStream,
    /// Unsent bytes of the current request.
    out: Vec<u8>,
    /// Reply bytes accumulated so far (no newline yet).
    inbuf: Vec<u8>,
    sent_at: Instant,
    alive: bool,
}

fn main() {
    let conns = arg("--conns", 10_000.0) as usize;
    let duration = Duration::from_secs_f64(arg("--duration-s", 5.0));
    let slo_ms = arg("--slo-ms", 200.0);

    let e0 = build_engine();
    let e1 = build_engine();
    let plans = plans_for(&e0);
    let wear = WearConfig {
        // Shard 0 arrives pre-worn: the wear-leveler must park gentle
        // traffic there and steer harsh traffic to the fresh shard 1.
        initial_age_years: vec![0.05, 0.0],
        initial_age_duty: 1.0,
        ..WearConfig::new(plans)
    };
    let opts = FrontendOptions {
        mode: FrontendMode::Evented,
        slo: Some(Duration::from_secs_f64(slo_ms / 1e3)),
        max_conns: conns + 64,
        max_queue: 256,
        route: Some(Box::new(WearLeveling::new(30.0, 16))),
        wear: Some(wear),
        ..Default::default()
    };
    let policy = BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(1), workers: 2 };
    let mut server =
        Server::spawn_opts(vec![Arc::new(e0), Arc::new(e1)], 0, policy, opts).unwrap();
    let addr = server.addr;
    eprintln!("serving on {addr}; opening {conns} connections…");

    // Two request lines, reused verbatim: 3:1 gentle (eco) to harsh.
    let pixels: Vec<f64> = (0..784).map(|i| (i % 17) as f64 / 16.0).collect();
    let mk_req = |quality: usize| {
        let mut line = Json::obj(vec![
            ("pixels", Json::arr_f64(&pixels)),
            ("quality", Json::Num(quality as f64)),
            ("deadline_ms", Json::Num(slo_ms)),
        ])
        .to_string();
        line.push('\n');
        line.into_bytes()
    };
    let req_harsh = mk_req(0);
    let req_gentle = mk_req(1);
    let req_for = |i: usize| if i % 4 == 0 { &req_harsh } else { &req_gentle };

    let mut pool: Vec<Conn> = Vec::with_capacity(conns);
    for i in 0..conns {
        match TcpStream::connect(addr) {
            Ok(stream) => {
                stream.set_nodelay(true).ok();
                stream.set_nonblocking(true).unwrap();
                pool.push(Conn {
                    stream,
                    out: req_for(i).clone(),
                    inbuf: Vec::new(),
                    sent_at: Instant::now(),
                    alive: true,
                });
            }
            Err(e) => {
                eprintln!("connect {i} failed: {e} (raise ulimit -n?)");
                break;
            }
        }
    }
    let opened = pool.len();

    let hist = LatencyHistogram::new();
    let (mut sent, mut ok, mut shed, mut errors) = (0u64, 0u64, 0u64, 0u64);
    let start = Instant::now();
    let mut issuing = true;
    let mut inflight = 0u64;
    // Seed: the initial request of every connection counts as sent when
    // its bytes finish leaving (tracked below via `out` emptying).
    let mut scratch = [0u8; 8192];
    loop {
        let now = Instant::now();
        if issuing && now.duration_since(start) >= duration {
            issuing = false; // stop issuing; drain what's in flight
        }
        if !issuing && inflight == 0 {
            break;
        }
        if !issuing && now.duration_since(start) > duration + Duration::from_secs(10) {
            eprintln!("drain timeout with {inflight} in flight");
            break;
        }
        let mut progressed = false;
        for (i, c) in pool.iter_mut().enumerate() {
            if !c.alive {
                continue;
            }
            // Push request bytes.
            while !c.out.is_empty() {
                match c.stream.write(&c.out) {
                    Ok(0) => {
                        c.alive = false;
                        errors += 1;
                        break;
                    }
                    Ok(n) => {
                        c.out.drain(..n);
                        progressed = true;
                        if c.out.is_empty() {
                            c.sent_at = Instant::now();
                            sent += 1;
                            inflight += 1;
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        c.alive = false;
                        errors += 1;
                        break;
                    }
                }
            }
            // Pull reply bytes.
            loop {
                match c.stream.read(&mut scratch) {
                    Ok(0) => {
                        c.alive = false;
                        if c.out.is_empty() && !c.inbuf.is_empty() {
                            errors += 1; // half a reply then EOF
                        }
                        break;
                    }
                    Ok(n) => {
                        progressed = true;
                        c.inbuf.extend_from_slice(&scratch[..n]);
                        while let Some(pos) = c.inbuf.iter().position(|&b| b == b'\n') {
                            let line: Vec<u8> = c.inbuf.drain(..=pos).collect();
                            inflight = inflight.saturating_sub(1);
                            const OK_NEEDLE: &[u8] = b"\"class\"";
                            if line.windows(OK_NEEDLE.len()).any(|w| w == OK_NEEDLE) {
                                ok += 1;
                                hist.record_us(
                                    c.sent_at.elapsed().as_micros().min(u64::MAX as u128)
                                        as u64,
                                );
                            } else {
                                shed += 1;
                            }
                            if issuing {
                                c.out = req_for(i).clone(); // next request
                            }
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        c.alive = false;
                        errors += 1;
                        break;
                    }
                }
            }
        }
        if !progressed {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let elapsed = start.elapsed().as_secs_f64();

    let server_stats = {
        let mut c = Client::connect(addr).unwrap();
        c.stats().unwrap()
    };
    let per_shard = server.stats.per_shard_counts();
    let server_requests = server.stats.requests.load(std::sync::atomic::Ordering::Relaxed);
    let server_shed = server.stats.shed.load(std::sync::atomic::Ordering::Relaxed);
    let p50 = hist.quantile_us(0.50);
    let p99 = hist.quantile_us(0.99);
    let answered = ok + shed;
    let conserved = answered + errors >= sent && server_requests + server_shed >= answered;
    let p99_within_slo = (p99 as f64) <= slo_ms * 1_000.0;
    let summary = Json::obj(vec![
        ("conns", Json::Num(opened as f64)),
        ("duration_s", Json::Num(elapsed)),
        ("sent", Json::Num(sent as f64)),
        ("ok", Json::Num(ok as f64)),
        ("shed", Json::Num(shed as f64)),
        ("errors", Json::Num(errors as f64)),
        ("rps", Json::Num(ok as f64 / elapsed)),
        ("p50_us", Json::Num(p50 as f64)),
        ("p99_us", Json::Num(p99 as f64)),
        ("slo_ms", Json::Num(slo_ms)),
        ("p99_within_slo", Json::Bool(p99_within_slo)),
        ("server_requests", Json::Num(server_requests as f64)),
        ("server_shed", Json::Num(server_shed as f64)),
        (
            "per_shard",
            Json::Arr(per_shard.iter().map(|&c| Json::Num(c as f64)).collect()),
        ),
        ("conserved", Json::Bool(conserved)),
    ]);
    println!("STRESS_JSON {summary}");
    eprintln!("server books: {server_stats}");
    server.shutdown();
    assert!(conserved, "request accounting must conserve");
    assert!(opened > 0 && ok > 0, "stress run served nothing");
}
