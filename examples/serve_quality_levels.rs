//! Serving demo: spin up the quality-adjustable inference server, then act
//! as a fleet of clients issuing requests at different quality levels —
//! the "runtime accuracy configuration" the X-TPU architecture enables
//! (voltage-selection bits in weight memory, Fig 7), measured for both
//! accuracy and latency/throughput.
//!
//! Run: `cargo run --release --example serve_quality_levels`

use anyhow::Result;
use std::time::Instant;
use xtpu::assign::AssignmentProblem;
use xtpu::config::ExperimentConfig;
use xtpu::coordinator::Pipeline;
use xtpu::nn::quant::NoiseSpec;
use xtpu::server::{BatchPolicy, Client, Engine, QualityLevel, Server};

fn main() -> Result<()> {
    let cfg = ExperimentConfig {
        train_samples: 1500,
        test_samples: 400,
        epochs: 3,
        characterize_samples: 100_000,
        validation_runs: 1,
        ..Default::default()
    };
    let pipeline = Pipeline::new(cfg);
    let sys = pipeline.prepare()?;

    // Pre-solve three quality levels: exact, balanced, eco.
    let mut levels = vec![QualityLevel {
        name: "exact".into(),
        noise: NoiseSpec::silent(sys.es.len()),
        energy_saving: 0.0,
    }];
    for (name, f) in [("balanced", 0.5f64), ("eco", 5.0)] {
        let r = pipeline.run_budget(&sys, f)?;
        let problem = AssignmentProblem::build(
            &sys.es,
            &sys.fan_in,
            &sys.registry,
            &sys.power,
            r.budget_abs,
        );
        levels.push(QualityLevel {
            name: name.into(),
            noise: problem.noise_spec(&r.assignment, &sys.registry),
            energy_saving: r.assignment.energy_saving,
        });
    }
    for (i, l) in levels.iter().enumerate() {
        println!("quality {i}: {:>8} → {:.1}% energy saving", l.name, l.energy_saving * 100.0);
    }

    // Share-nothing backend pool (the config-selected engine, one instance
    // per batch worker): each level's pre-solved NoiseSpec is injected on
    // top of the same shared kernel the validation pipeline used, and
    // batches at different quality levels execute concurrently.
    let workers = 2;
    let engine = Engine::new(sys.quantized.clone(), levels.clone(), 784)
        .with_backend_pool(pipeline.make_backend_pool(&sys.registry, workers)?);
    let mut server = Server::spawn(
        engine,
        0,
        BatchPolicy {
            max_batch: 16,
            max_wait: std::time::Duration::from_millis(3),
            workers,
        },
    )?;
    println!("\nserver on {}\n", server.addr);

    // Fleet: 4 concurrent clients × 50 requests each, mixed quality levels.
    let n_clients = 4;
    let per_client = 50;
    let addr = server.addr;
    let test = sys.test.clone();
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let test = test.clone();
            std::thread::spawn(move || -> Result<(usize, usize, Vec<u128>)> {
                let mut client = Client::connect(addr)?;
                let mut correct = 0;
                let mut lat = Vec::new();
                for i in 0..per_client {
                    let idx = (c * per_client + i) % test.len();
                    let quality = i % 3;
                    let t = Instant::now();
                    let (class, _) = client.infer(test.images.row(idx), quality)?;
                    lat.push(t.elapsed().as_micros());
                    if class == test.labels[idx] as usize {
                        correct += 1;
                    }
                }
                Ok((correct, per_client, lat))
            })
        })
        .collect();
    let mut correct = 0;
    let mut total = 0;
    let mut lats: Vec<u128> = Vec::new();
    for h in handles {
        let (c, t, l) = h.join().unwrap()?;
        correct += c;
        total += t;
        lats.extend(l);
    }
    let wall = t0.elapsed();
    lats.sort_unstable();
    println!(
        "{total} requests in {:.2}s → {:.0} req/s · accuracy {:.3} (mixed levels)",
        wall.as_secs_f64(),
        total as f64 / wall.as_secs_f64(),
        correct as f64 / total as f64
    );
    println!(
        "latency p50 {:.1} ms · p95 {:.1} ms · p99 {:.1} ms",
        lats[lats.len() / 2] as f64 / 1000.0,
        lats[lats.len() * 95 / 100] as f64 / 1000.0,
        lats[lats.len() * 99 / 100] as f64 / 1000.0
    );
    println!(
        "batches formed: {} (dynamic batching coalesced {:.1} req/batch)",
        server.stats.batches.load(std::sync::atomic::Ordering::Relaxed),
        total as f64 / server.stats.batches.load(std::sync::atomic::Ordering::Relaxed) as f64
    );
    server.shutdown();
    Ok(())
}
