//! Serving demo: spin up the quality-adjustable inference server, then act
//! as a fleet of clients issuing requests at different quality levels —
//! the "runtime accuracy configuration" the X-TPU architecture enables
//! (voltage-selection bits in weight memory, Fig 7), measured for both
//! accuracy and latency/throughput.
//!
//! Run: `cargo run --release --example serve_quality_levels`

use anyhow::Result;
use std::time::Instant;
use xtpu::config::ExperimentConfig;
use xtpu::plan::{make_backend_pool, Planner};
use xtpu::server::{BatchPolicy, Client, Engine, Server};

fn main() -> Result<()> {
    let cfg = ExperimentConfig {
        train_samples: 1500,
        test_samples: 400,
        epochs: 3,
        characterize_samples: 100_000,
        validation_runs: 1,
        ..Default::default()
    };

    // Offline: pre-solve three quality levels — exact, balanced, eco — as
    // deployable VoltagePlan artifacts (all budgets solved in parallel).
    // This is exactly what `xtpu plan` writes to disk.
    let mut planner = Planner::new(cfg);
    let mut plans = planner.solve_many(&[0.0, 0.5, 5.0])?;
    plans[1].name = "balanced".into();
    plans[2].name = "eco".into();
    for (i, p) in plans.iter().enumerate() {
        println!("quality {i}: {:>8} → {:.1}% energy saving", p.name, p.energy_saving * 100.0);
    }

    // Online: the engine derives its quality levels from the plans (noise
    // spec + energy saving from the solved assignment, not hand-rolled),
    // on a share-nothing backend pool: one instance per batch worker, so
    // batches at different quality levels execute concurrently.
    let workers = 2;
    let registry = planner.registry()?.clone();
    let quantized = planner.trained()?.quantized.clone();
    let test = planner.trained()?.test.clone();
    let pool = make_backend_pool(&planner.cfg, &registry, workers)?;
    let engine =
        Engine::from_plans(quantized, &registry, &plans, 784)?.with_backend_pool(pool);
    let mut server = Server::spawn(
        engine,
        0,
        BatchPolicy {
            max_batch: 16,
            max_wait: std::time::Duration::from_millis(3),
            workers,
        },
    )?;
    println!("\nserver on {}\n", server.addr);

    // Fleet: 4 concurrent clients × 50 requests each, mixed quality levels.
    let n_clients = 4;
    let per_client = 50;
    let addr = server.addr;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let test = test.clone();
            std::thread::spawn(move || -> Result<(usize, usize, Vec<u128>)> {
                let mut client = Client::connect(addr)?;
                let mut correct = 0;
                let mut lat = Vec::new();
                for i in 0..per_client {
                    let idx = (c * per_client + i) % test.len();
                    let quality = i % 3;
                    let t = Instant::now();
                    let (class, _) = client.infer(test.images.row(idx), quality)?;
                    lat.push(t.elapsed().as_micros());
                    if class == test.labels[idx] as usize {
                        correct += 1;
                    }
                }
                Ok((correct, per_client, lat))
            })
        })
        .collect();
    let mut correct = 0;
    let mut total = 0;
    let mut lats: Vec<u128> = Vec::new();
    for h in handles {
        let (c, t, l) = h.join().unwrap()?;
        correct += c;
        total += t;
        lats.extend(l);
    }
    let wall = t0.elapsed();
    lats.sort_unstable();
    println!(
        "{total} requests in {:.2}s → {:.0} req/s · accuracy {:.3} (mixed levels)",
        wall.as_secs_f64(),
        total as f64 / wall.as_secs_f64(),
        correct as f64 / total as f64
    );
    println!(
        "latency p50 {:.1} ms · p95 {:.1} ms · p99 {:.1} ms",
        lats[lats.len() / 2] as f64 / 1000.0,
        lats[lats.len() * 95 / 100] as f64 / 1000.0,
        lats[lats.len() * 99 / 100] as f64 / 1000.0
    );
    println!(
        "batches formed: {} (dynamic batching coalesced {:.1} req/batch)",
        server.stats.batches.load(std::sync::atomic::Ordering::Relaxed),
        total as f64 / server.stats.batches.load(std::sync::atomic::Ordering::Relaxed) as f64
    );
    let per_level = server.stats.per_level_counts();
    println!("requests per quality level (plan utilization): {per_level:?}");
    server.shutdown();
    Ok(())
}
