//! Aging study (paper §V.C, Fig 15): BTI threshold drift after ten years,
//! the induced path-delay degradation, the aged error variance of the PE
//! under a relaxed (aged-nominal) clock, and the lifetime benefit of mixed
//! voltage operation.
//!
//! Run: `cargo run --release --example aging_study`

use anyhow::Result;
use xtpu::aging::{AgedScenario, BtiModel, Device};
use xtpu::errormodel::{characterize_voltage, CharacterizeOptions};
use xtpu::timing::baugh_wooley_8x8;
use xtpu::timing::sta::{clock_period, ChipInstance};
use xtpu::timing::voltage::Technology;
use xtpu::util::rng::Xoshiro256pp;

fn main() -> Result<()> {
    let bti = BtiModel::default();
    let tech = Technology::default();
    let years = 10.0;

    println!("=== Fig 15a: ΔVth after {years} years ===");
    println!("{:>6} {:>12} {:>12}", "V", "PMOS %", "NMOS %");
    for v in [0.5, 0.6, 0.7, 0.8] {
        println!(
            "{v:>6.2} {:>12.3} {:>12.3}",
            bti.delta_vth_percent(Device::Pmos, &tech, v, years),
            bti.delta_vth_percent(Device::Nmos, &tech, v, years)
        );
    }

    println!("\n=== Fig 15b: path-delay degradation factor ===");
    for v in [0.5, 0.6, 0.7, 0.8] {
        println!("{v:>6.2} {:>10.4}", bti.delay_degradation(&tech, v, years));
    }

    println!("\n=== Fig 15c: aged error variance (clock re-provisioned to the");
    println!("    10-year 0.8 V critical path, worst-case always-nominal aging) ===");
    let netlist = baugh_wooley_8x8("bw_aging");
    let mut rng = Xoshiro256pp::seeded(0xA9ED);
    let chip = ChipInstance::sample(&netlist, &tech, &mut rng);
    let scenario = AgedScenario::worst_case(&bti, &tech, years);
    let fresh_clock = clock_period(&netlist, &chip, &tech);
    let aged_clock = fresh_clock * scenario.clock_stretch as f32;
    println!(
        "clock: fresh {:.2} → aged {:.2} (stretch {:.3}), ΔVth {:.4} V",
        fresh_clock, aged_clock, scenario.clock_stretch, scenario.delta_vth
    );
    println!("{:>6} {:>14} {:>14}", "V", "fresh var", "aged var");
    for v in [0.5, 0.6, 0.7] {
        let fresh = characterize_voltage(
            &netlist,
            &chip,
            &tech,
            v,
            &CharacterizeOptions { samples: 150_000, seed: 5, ..Default::default() },
        );
        let aged = characterize_voltage(
            &netlist,
            &chip,
            &tech,
            v,
            &CharacterizeOptions {
                samples: 150_000,
                seed: 5,
                delta_vth: scenario.delta_vth,
                clock_override: Some(aged_clock),
            },
        );
        println!("{v:>6.2} {:>14.4e} {:>14.4e}", fresh.variance, aged.variance);
    }
    println!("(paper pointer ⑨: the relaxed aged clock REDUCES low-voltage error rates)");

    println!("\n=== lifetime ===");
    let imp = bti.lifetime_improvement(&tech, &[0.5, 0.6, 0.7, 0.8], &[0.25; 4]);
    println!(
        "uniform voltage mix vs always-nominal: +{:.1}% lifetime (paper: +12 %)",
        imp * 100.0
    );
    let life = bti.lifetime_years(&tech, 0.8, 1.0);
    println!("time-to-guard-band at always-nominal full stress: {life:.1} years");
    Ok(())
}
