//! Quickstart: the X-TPU framework in ~60 lines.
//!
//! Characterizes the PE multiplier at four voltages, trains a small FC
//! model on synthetic MNIST, solves the ILP voltage assignment for a 200 %
//! MSE budget (the paper's headline operating point), and validates the
//! result with noise-injected quantized inference.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use xtpu::config::ExperimentConfig;
use xtpu::coordinator::Pipeline;

fn main() -> Result<()> {
    // Small-but-real configuration (the full pipeline example uses the
    // paper-scale one; see examples/mnist_fc_pipeline.rs).
    let cfg = ExperimentConfig {
        train_samples: 1500,
        test_samples: 400,
        epochs: 3,
        characterize_samples: 100_000,
        mse_ub_fractions: vec![2.0],
        ..Default::default()
    };
    let pipeline = Pipeline::new(cfg);

    println!("① preparing: train → characterize → error-sensitivity…");
    let sys = pipeline.prepare()?;
    println!(
        "   model {} · baseline accuracy {:.3} · nominal MSE {:.4}",
        sys.model.name, sys.baseline_accuracy, sys.baseline_mse
    );
    println!("   error models (PE multiplier):");
    for m in sys.registry.models() {
        println!(
            "     {:.1} V → var {:>12.3e}  err-rate {:>7.4}",
            m.volts, m.variance, m.error_rate
        );
    }

    println!("② solving the ILP voltage assignment (MSE_UB = 200 %)…");
    let report = pipeline.run_budget(&sys, 2.0)?;
    let hist = report.assignment.level_histogram(sys.registry.ladder.len());
    println!(
        "   levels {hist:?} (0.5 V → nominal) in {:.2}s, optimal={}",
        report.assignment.solve_seconds, report.assignment.optimal
    );

    println!("③ validation (noise-injected int8 inference):");
    println!(
        "   energy saving {:.1}%  ·  accuracy {:.3} (drop {:.3})  ·  \
         measured MSE {:.4} vs budget {:.4}",
        report.assignment.energy_saving * 100.0,
        report.accuracy,
        report.accuracy_drop,
        report.validated_mse,
        report.budget_abs
    );
    println!(
        "\npaper headline: 32 % energy saving for 0.6 % accuracy loss at \
         MSE_UB = 200 % (linear activation)"
    );
    Ok(())
}
