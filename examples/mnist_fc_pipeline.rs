//! End-to-end driver (DESIGN.md §6): the full Fig-4 flow on a real small
//! workload, proving all three layers compose:
//!
//!   rust training → int8 quantization → gate-level characterization →
//!   ES → ILP assignment → augmented weight memory → validation through
//!   BOTH (a) the rust quantized-inference path + cycle-level systolic
//!   simulator and (b) the AOT JAX/Pallas artifact executed via PJRT.
//!
//! Reproduces the paper's headline: ~32 % energy saving for <1 % accuracy
//! loss at MSE_UB = 200 % with linear activations. Results are recorded in
//! EXPERIMENTS.md.
//!
//! Run after `make artifacts`: `cargo run --release --example mnist_fc_pipeline`

use anyhow::Result;
use xtpu::config::ExperimentConfig;
use xtpu::coordinator::{systolic_cross_check, Pipeline};
use xtpu::nn::quant::NoiseSpec;
use xtpu::plan::VoltagePlan;
use xtpu::runtime::{artifacts_dir, FcExecutor, Runtime};
use xtpu::simulator::WeightMemory;
use xtpu::util::rng::Xoshiro256pp;

fn main() -> Result<()> {
    let cfg = ExperimentConfig {
        train_samples: 4000,
        test_samples: 1000,
        epochs: 6,
        characterize_samples: 1_000_000, // paper scale
        mse_ub_fractions: vec![0.01, 0.1, 0.5, 1.0, 2.0, 5.0, 10.0],
        validation_runs: 3,
        ..Default::default()
    };
    let pipeline = Pipeline::new(cfg);

    println!("=== X-TPU end-to-end pipeline (FC 128×10, linear) ===\n");
    let t_all = std::time::Instant::now();
    let sys = pipeline.prepare()?;
    println!(
        "prepared: train {:.1}s · characterize {:.1}s · ES {:.1}s",
        sys.train_seconds, sys.characterize_seconds, sys.es_seconds
    );
    println!(
        "baseline: accuracy {:.4} · nominal test MSE {:.4}\n",
        sys.baseline_accuracy, sys.baseline_mse
    );

    println!(
        "{:>8} {:>10} {:>10} {:>9} {:>9} {:>9}  (Fig 13a sweep)",
        "MSE_UB%", "predMSE", "measMSE", "acc", "drop", "saving%"
    );
    let mut headline = None;
    for &f in &pipeline.cfg.mse_ub_fractions.clone() {
        let r = pipeline.run_budget(&sys, f)?;
        println!(
            "{:>8.0} {:>10.4} {:>10.4} {:>9.4} {:>9.4} {:>9.2}",
            f * 100.0,
            r.assignment.predicted_mse,
            r.validated_mse,
            r.accuracy,
            r.accuracy_drop,
            r.assignment.energy_saving * 100.0
        );
        if (f - 2.0).abs() < 1e-9 {
            headline = Some(r);
        }
    }
    let headline = headline.expect("200 % budget in sweep");

    // --- deployable plan artifact (xtpu plan → xtpu serve --plan) --------
    // Every solve now yields a serializable VoltagePlan; round-trip the
    // headline through disk exactly as the serving workflow would.
    let plan_path =
        std::path::Path::new("artifacts").join(headline.plan.file_name());
    headline.plan.save(&plan_path)?;
    let plan = VoltagePlan::load(&plan_path)?;
    assert_eq!(plan.level, headline.assignment.level);
    println!(
        "\nplan artifact: {} (fingerprint {}, predicted saving {:.1}%)",
        plan_path.display(),
        plan.model_fingerprint,
        plan.energy_saving * 100.0
    );

    // --- augmented weight memory (Fig 7) --------------------------------
    let mac = match &sys.quantized.layers[0] {
        xtpu::nn::quant::QLayer::Dense(m) => m,
        _ => unreachable!(),
    };
    let mut w_colmajor = vec![0i8; mac.fan_in * mac.out];
    for u in 0..mac.out {
        for i in 0..mac.fan_in {
            w_colmajor[i * mac.out + u] = mac.wq[u * mac.fan_in + i];
        }
    }
    let mem = WeightMemory::encode(
        &w_colmajor,
        mac.fan_in,
        mac.out,
        &headline.assignment.level[..mac.out],
        sys.registry.ladder.selection_bits(),
    );
    println!(
        "\nweight memory: {} words × {} bits ({}% overhead for selection bits)",
        mem.words().len(),
        8 + mem.sel_bits,
        mem.overhead() * 100.0
    );
    assert_eq!(mem.column_levels().unwrap(), headline.assignment.level[..mac.out]);

    // --- cross-check 1: cycle-level systolic simulator -------------------
    let (measured, predicted) = systolic_cross_check(&sys, &headline.assignment, 2000, 42)?;
    println!(
        "systolic simulator: column error variance {measured:.3e} vs model {predicted:.3e} \
         (ratio {:.2})",
        measured / predicted.max(1e-12)
    );

    // --- cross-check 2: the PJRT / JAX / Pallas artifact ------------------
    if artifacts_dir().join("fc_mnist_linear_b32.hlo.txt").exists() {
        let mut rt = Runtime::new(&artifacts_dir())?;
        let mut exec = FcExecutor::from_quantized(&sys.quantized, "linear", 32)?;
        rt.load(&exec.artifact)?;
        // The noise spec comes straight from the round-tripped plan — the
        // same derivation the serving engine uses.
        exec.set_noise(NoiseSpec::from_plan(&plan, &sys.registry));
        let idx: Vec<usize> = (0..sys.test.len().min(960)).collect();
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut rng = Xoshiro256pp::seeded(77);
        let t0 = std::time::Instant::now();
        for chunk in idx.chunks(32) {
            if chunk.len() < 32 {
                break;
            }
            let (x, labels) = sys.test.batch(chunk);
            let logits = exec.run(&rt, &x.data, &mut rng)?;
            for r in 0..32 {
                let row = &logits[r * 10..(r + 1) * 10];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == labels[r] as usize {
                    correct += 1;
                }
                total += 1;
            }
        }
        let dt = t0.elapsed();
        println!(
            "PJRT (JAX/Pallas artifact): accuracy {:.4} on {} samples \
             ({:.1} inf/s through the compiled XLA executable)",
            correct as f64 / total as f64,
            total,
            total as f64 / dt.as_secs_f64()
        );
        println!(
            "  platform: {} · artifact: {}",
            rt.platform(),
            exec.artifact
        );
    } else {
        println!("PJRT cross-check skipped (run `make artifacts` first)");
    }

    println!(
        "\n=== headline @ MSE_UB=200%: {:.1}% energy saving, {:.2}% accuracy loss \
         (paper: 32 % / 0.6 %) — total {:.1}s ===",
        headline.assignment.energy_saving * 100.0,
        headline.accuracy_drop * 100.0,
        t_all.elapsed().as_secs_f64()
    );
    Ok(())
}
