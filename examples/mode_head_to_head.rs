//! Operating-regime head-to-head: tolerate (statistical) vs detect
//! (TE-Drop) on the same MNIST FC model, ladder, and MSE budgets.
//!
//! Part one solves the identical budget sweep twice — once pricing every
//! neuron's column error by the characterized error-moment model (the
//! paper's statistical regime), once by the TE-Drop recovery model, where
//! a detected timing error costs the dropped MAC's product instead of an
//! unbounded noise draw. A faulting MAC's conditional error is dominated
//! by the multiplier's longest (MSB) paths, so its second moment is far
//! above a *dropped* product's; at the same budget the TE-Drop constraint
//! is looser and the MCKP admits deeper ladder levels — strictly more
//! energy saving for at least one budget, never less for any.
//!
//! Part two is the fleet version of the same trade as a *drift response*:
//! a statistical deployment on a brutal wear clock either keeps serving
//! its boot-time plans until BTI drift pushes served MSE past the budget
//! (`never`), or re-plans on the margin threshold **and switches regime
//! to TE-Drop** — staying inside the budget while recovering energy
//! saving the statistical re-plan has to give back.
//!
//! Run: `cargo run --release --example mode_head_to_head`

use std::sync::Arc;

use anyhow::Result;
use xtpu::config::ExperimentConfig;
use xtpu::errormodel::PlanMode;
use xtpu::fleet::{AdaptiveContext, FleetConfig, ReplanPolicy, RoundRobin, Router, Trace};
use xtpu::plan::{make_backend_pool, Planner};
use xtpu::server::Engine;

fn main() -> Result<()> {
    let base = ExperimentConfig {
        train_samples: 1500,
        test_samples: 400,
        epochs: 3,
        characterize_samples: 100_000,
        validation_runs: 1,
        ..Default::default()
    };

    // ---- part one: the same budgets, priced in both regimes -------------
    //
    // `mode`/`backend` are serving-side knobs, not planning provenance, so
    // both planners share the model and characterization caches — the
    // second solve pays only for ES + MCKP.
    let fractions = [0.25, 0.5, 1.0, 2.0];
    let mut stat_planner = Planner::new(base.clone());
    let stat_plans = stat_planner.solve_many(&fractions)?;
    let te_cfg = ExperimentConfig {
        mode: "tedrop".into(),
        backend: "tedrop".into(),
        ..base.clone()
    };
    let mut te_planner = Planner::new(te_cfg);
    let te_plans = te_planner.solve_many(&fractions)?;

    println!(
        "{:>9} {:>12} {:>14} {:>14} {:>12} {:>12}",
        "MSE_UB%", "budget", "stat MSE", "tedrop MSE", "stat sav%", "tedrop sav%"
    );
    let mut strictly_better = false;
    for (s, t) in stat_plans.iter().zip(&te_plans) {
        println!(
            "{:>9.1} {:>12.4} {:>14.4} {:>14.4} {:>12.2} {:>12.2}",
            s.mse_ub_fraction * 100.0,
            s.budget_abs,
            s.predicted_mse,
            t.predicted_mse,
            s.energy_saving * 100.0,
            t.energy_saving * 100.0
        );
        anyhow::ensure!(
            s.predicted_mse <= s.budget_abs + 1e-9 && t.predicted_mse <= t.budget_abs + 1e-9,
            "both regimes must respect the MSE budget"
        );
        anyhow::ensure!(
            t.energy_saving >= s.energy_saving - 1e-12,
            "the statistical optimum stays feasible under the looser TE-Drop \
             pricing, so TE-Drop saving can never be less (budget {})",
            s.budget_abs
        );
        if t.energy_saving > s.energy_saving + 1e-9 {
            strictly_better = true;
        }
    }
    anyhow::ensure!(
        strictly_better,
        "TE-Drop must buy strictly more saving for at least one budget"
    );
    println!(
        "\ndetect-and-drop beats tolerate-and-average at every budget above \
         (strictly, wherever the statistical solve was budget-limited)."
    );

    // ---- part two: regime switch as a drift response --------------------
    //
    // Boot-time plans are *statistical* (budgets 0% and 100% of nominal
    // MSE); the wear clock burns BTI guard band fast enough for the served
    // MSE of the budgeted class to leave its budget within the trace.
    println!("\n— fleet: statistical deployment aging under a 4e6× wear clock —\n");
    let registry = stat_planner.registry()?.clone();
    let quantized = stat_planner.trained()?.quantized.clone();
    let power = *stat_planner.power();
    let plans2 = stat_planner.solve_many(&[0.0, 1.0])?;
    let loop_cfg = FleetConfig { devices: 2, wear_accel: 4.0e6, ..FleetConfig::default() };
    let trace = Trace::poisson(600.0, 2.0, &[1.0, 1.0], 0xADA97);

    let arms: [(&str, ReplanPolicy, Option<PlanMode>); 3] = [
        ("never (fixed)", ReplanPolicy::Never, None),
        ("threshold", ReplanPolicy::Threshold { guard_band: 0.05 }, None),
        (
            "threshold→tedrop",
            ReplanPolicy::Threshold { guard_band: 0.05 },
            Some(PlanMode::TeDrop),
        ),
    ];
    println!(
        "{:<18} {:>8} {:>14} {:>12}",
        "arm", "replans", "max MSE/budget", "saving %"
    );
    let mut results = Vec::new();
    for (label, replan, switch) in arms {
        let pool = make_backend_pool(&stat_planner.cfg, &registry, loop_cfg.devices)?;
        let engine = Arc::new(
            Engine::from_plans(quantized.clone(), &registry, &plans2, 784)?
                .with_backend_pool(pool),
        );
        let mut ctx = AdaptiveContext::new(registry.clone(), power, replan);
        ctx.resolve.switch_mode = switch;
        let mut fleet = Router::with_adaptation(
            engine,
            &plans2,
            Box::<RoundRobin>::default(),
            loop_cfg.clone(),
            ctx,
        )?;
        let t = fleet.run(&trace);
        println!(
            "{:<18} {:>8} {:>14.3} {:>12.1}",
            label,
            t.replan_events.len(),
            t.max_mse_ratio,
            t.energy_saving_vs_nominal * 100.0
        );
        results.push((label, t.max_mse_ratio, t.energy_saving_vs_nominal));
    }
    let (_, fixed_ratio, _) = results[0];
    let (_, stat_ratio, stat_saving) = results[1];
    let (_, te_ratio, te_saving) = results[2];
    anyhow::ensure!(
        fixed_ratio > 1.0,
        "the fixed-mode fleet must exit its quality budget under this wear clock \
         (got max ratio {fixed_ratio:.3})"
    );
    anyhow::ensure!(
        stat_ratio <= 1.0 + 1e-6 && te_ratio <= 1.0 + 1e-6,
        "both re-planning arms must hold served MSE inside the budget"
    );
    anyhow::ensure!(
        te_saving >= stat_saving - 1e-9,
        "switching the re-plans to TE-Drop must not save less than re-planning \
         in place ({te_saving:.4} vs {stat_saving:.4})"
    );
    println!(
        "\nthe fixed fleet silently leaves its budget; both adaptive arms stay \
         inside it,\nand the TE-Drop switch recovers {:.1}% saving vs {:.1}% for \
         the in-regime re-plan.",
        te_saving * 100.0,
        stat_saving * 100.0
    );
    Ok(())
}
