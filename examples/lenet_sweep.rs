//! CNN sweep (paper Fig 14a): LeNet-5 on synthetic MNIST under the
//! MSE-increment budgets 1 %…1000 %, reporting accuracy + energy saving.
//!
//! Run: `cargo run --release --example lenet_sweep`

use anyhow::Result;
use xtpu::config::ExperimentConfig;
use xtpu::coordinator::Pipeline;

fn main() -> Result<()> {
    let cfg = ExperimentConfig {
        model: "lenet5".into(),
        train_samples: 1200,
        test_samples: 300,
        epochs: 3,
        characterize_samples: 200_000,
        mse_ub_fractions: vec![0.01, 0.1, 0.5, 1.0, 2.0, 5.0, 10.0],
        validation_runs: 1,
        ..Default::default()
    };
    let pipeline = Pipeline::new(cfg);
    println!("=== LeNet-5 / synthetic MNIST sweep (Fig 14a) ===");
    let sys = pipeline.prepare()?;
    println!(
        "baseline accuracy {:.4} · {} neurons · nominal MSE {:.4}\n",
        sys.baseline_accuracy,
        sys.es.len(),
        sys.baseline_mse
    );
    println!("{:>8} {:>9} {:>9} {:>9}", "MSE_UB%", "acc", "drop", "saving%");
    for &f in &pipeline.cfg.mse_ub_fractions.clone() {
        let r = pipeline.run_budget(&sys, f)?;
        println!(
            "{:>8.0} {:>9.4} {:>9.4} {:>9.2}",
            f * 100.0,
            r.accuracy,
            r.accuracy_drop,
            r.assignment.energy_saving * 100.0
        );
    }
    println!(
        "\npaper shape: LeNet-5 keeps ≥0.9 accuracy up to ~18 % saving, drops \
         below 0.8 past MSE_UB ≈ 100 %"
    );
    Ok(())
}
