//! Fleet demo: the paper's lifetime claim (§V.C) as an *operational*
//! scheduling win.
//!
//! Solves two deployable plans (all-nominal `exact` + an aggressive-VOS
//! `eco`), spins up a heterogeneous six-device fleet (deployed in waves,
//! so the oldest device has already burned most of its BTI guard band),
//! and replays the identical Poisson trace under round-robin,
//! least-loaded, and aging-aware wear-leveled routing. Served quality is
//! identical by construction — only *which device* absorbs which voltage
//! mix changes — yet the minimum projected device lifetime moves
//! substantially, because the wear-leveler parks the near-stress-free
//! 0.5 V traffic on worn silicon and water-fills the nominal-voltage
//! stress across the devices with guard band to spare.
//!
//! Part two closes the loop: the same aged fleet replayed **with and
//! without threshold re-planning** on a brutal wear clock. Without it the
//! served-MSE-to-budget ratio of the deployed plans drifts past 1.0 (the
//! device silently serves below the quality bar the user paid for); with
//! it every device re-solves its plans as BTI wear consumes delay margin,
//! and the ratio never leaves the budget — at a visible but modest energy
//! cost.
//!
//! Run: `cargo run --release --example fleet_wear_leveling`

use std::sync::Arc;

use anyhow::Result;
use xtpu::config::ExperimentConfig;
use xtpu::fleet::{
    plan_stress_intensity, AdaptiveContext, FleetConfig, LeastLoaded, ReplanPolicy, RoundRobin,
    Router, RoutePolicy, Trace, WearLeveling,
};
use xtpu::plan::{make_backend_pool, Planner};
use xtpu::server::Engine;

fn main() -> Result<()> {
    let cfg = ExperimentConfig {
        train_samples: 1500,
        test_samples: 400,
        epochs: 3,
        characterize_samples: 100_000,
        validation_runs: 1,
        ..Default::default()
    };

    // Offline: two plans — what `xtpu plan --mse-ubs 0.0,10.0` would emit.
    let mut planner = Planner::new(cfg);
    let mut plans = planner.solve_many(&[0.0, 10.0])?;
    plans[1].name = "eco".into();
    let registry = planner.registry()?.clone();
    let quantized = planner.trained()?.quantized.clone();
    let fleet_cfg = FleetConfig {
        devices: 6,
        wear_accel: 1.5e6,
        // Deployed in waves: prior always-nominal service per device.
        initial_age_years: vec![0.02, 0.014, 0.009, 0.005, 0.002, 0.0],
        initial_age_duty: 1.0,
        ..FleetConfig::default()
    };
    for (i, p) in plans.iter().enumerate() {
        println!(
            "plan {i}: {:>6} — saving {:>5.1}% · aging intensity {:.3e} (x/year per busy-s)",
            p.name,
            p.energy_saving * 100.0,
            plan_stress_intensity(&fleet_cfg.bti, &fleet_cfg.tech, p)
        );
    }

    // One pooled engine, one slot per device (share-nothing execution).
    let pool = make_backend_pool(&planner.cfg, &registry, fleet_cfg.devices)?;
    let engine = Arc::new(
        Engine::from_plans(quantized, &registry, &plans, 784)?.with_backend_pool(pool),
    );

    // The identical trace for every policy: 3 s of Poisson traffic at
    // 600 req/s, 50/50 exact/eco.
    let trace = Trace::poisson(600.0, 3.0, &[1.0, 1.0], 0xF1EE7);
    println!("\ntrace: {} requests, fleet of {}\n", trace.request_count(), fleet_cfg.devices);

    let policies: Vec<Box<dyn RoutePolicy>> = vec![
        Box::<RoundRobin>::default(),
        Box::<LeastLoaded>::default(),
        Box::new(WearLeveling::new(0.05, 32)),
    ];
    let mut baseline_min = None;
    println!(
        "{:<14} {:>9} {:>9} {:>10} {:>12} {:>12}",
        "policy", "p50 ms", "p99 ms", "saving %", "min life y", "mean life y"
    );
    for policy in policies {
        let mut fleet = Router::new(engine.clone(), &plans, policy, fleet_cfg.clone())?;
        let t = fleet.run(&trace);
        println!(
            "{:<14} {:>9.2} {:>9.2} {:>10.1} {:>12.4} {:>12.4}",
            t.policy,
            t.latency_p50_ms,
            t.latency_p99_ms,
            t.energy_saving_vs_nominal * 100.0,
            t.min_lifetime_years,
            t.mean_lifetime_years
        );
        if t.policy == "round_robin" {
            baseline_min = Some(t.min_lifetime_years);
        } else if t.policy == "wear_leveling" {
            let base = baseline_min.expect("round robin ran first");
            println!(
                "\nwear leveling extends minimum projected device lifetime by {:.0}% \
                 over round robin at identical served quality\n(paper §V.C reports ≈ +12% \
                 for a *uniform* voltage mix on one device; steering the mix per device \
                 is strictly stronger)",
                (t.min_lifetime_years / base - 1.0) * 100.0
            );
            for d in &t.devices {
                println!(
                    "  device {}: {:>5} reqs ({:>4} exact / {:>4} eco) · margin {:>5.1}% · \
                     life {:>8.3} y",
                    d.id,
                    d.requests,
                    d.per_class[0],
                    d.per_class[1],
                    d.delay_margin * 100.0,
                    d.projected_lifetime_years
                );
            }
        }
    }

    // ---- part two: the closed loop (quality vs age, with/without re-plan)
    //
    // A fresh two-plan deployment with a *budgeted* quality class
    // (MSE_UB = 100% of nominal MSE) on a wear clock fast enough to
    // consume the whole BTI guard band within the trace. The `never` arm
    // measures its quality decay; the `threshold` arm re-solves whenever
    // 5% of the delay margin has been consumed since its last plan.
    println!("\n— closed loop: drift-aware re-planning —\n");
    let plans2 = planner.solve_many(&[0.0, 1.0])?;
    let quantized = planner.trained()?.quantized.clone();
    let power = *planner.power();
    let loop_cfg = FleetConfig {
        devices: 2,
        wear_accel: 4.0e6,
        ..FleetConfig::default()
    };
    let trace2 = Trace::poisson(600.0, 2.0, &[1.0, 1.0], 0xADA97);
    println!(
        "{:<12} {:>9} {:>14} {:>12} {:>10}",
        "replan", "events", "max MSE/budget", "saving %", "min margin"
    );
    for replan in [ReplanPolicy::Never, ReplanPolicy::Threshold { guard_band: 0.05 }] {
        let pool = make_backend_pool(&planner.cfg, &registry, loop_cfg.devices)?;
        let engine = Arc::new(
            Engine::from_plans(quantized.clone(), &registry, &plans2, 784)?
                .with_backend_pool(pool),
        );
        let mut fleet = Router::with_adaptation(
            engine,
            &plans2,
            Box::<RoundRobin>::default(),
            loop_cfg.clone(),
            AdaptiveContext::new(registry.clone(), power, replan),
        )?;
        let t = fleet.run(&trace2);
        let min_margin =
            t.devices.iter().map(|d| d.delay_margin).fold(f64::INFINITY, f64::min);
        println!(
            "{:<12} {:>9} {:>14.3} {:>12.1} {:>10.3}",
            t.replan_policy,
            t.replan_events.len(),
            t.max_mse_ratio,
            t.energy_saving_vs_nominal * 100.0,
            min_margin,
        );
        if replan != ReplanPolicy::Never {
            println!(
                "\nquality-vs-age (device 0): ΔVth → served-MSE/budget of '{}'",
                plans2[1].name
            );
            for s in t.quality_curve.iter().filter(|s| s.device == 0).step_by(8) {
                if let Some(r) = s.mse_ratio[1] {
                    println!(
                        "  ΔVth {:>7.4} V · margin {:>5.1}% · gen {} · ratio {:.3}",
                        s.delta_vth,
                        s.delay_margin * 100.0,
                        s.generation,
                        r
                    );
                }
            }
        }
    }
    println!(
        "\nthe static fleet exits the quality budget (ratio > 1) as BTI wear \
         accumulates;\nthreshold re-planning keeps every sample inside it while \
         still saving energy vs all-nominal."
    );
    Ok(())
}
