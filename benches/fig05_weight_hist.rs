//! Fig 5: distribution of (quantized) weight values of the FC 128×10
//! network trained on (synthetic) MNIST — heavy mass near zero (pointer ③).

#[path = "common.rs"]
mod common;

use xtpu::nn::quant::{QLayer, QuantizedModel};

fn main() {
    common::header(
        "Fig 5 — weight-value distribution, FC 128×10",
        "paper Fig 5: strong peak at/near zero weights",
    );
    let pipeline = common::bench_pipeline();
    let (model, _train, test) = pipeline.trained_model().unwrap();
    let calib = test.batch(&(0..64).collect::<Vec<_>>()).0;
    let q = QuantizedModel::quantize(&model, &calib);
    let mut hist = [0u64; 17]; // 17 bins over [-128, 128)
    let mut total = 0u64;
    let mut near_zero = 0u64;
    for layer in &q.layers {
        if let QLayer::Dense(m) = layer {
            for &w in &m.wq {
                let bin = (((w as i32) + 128) * 17 / 256) as usize;
                hist[bin.min(16)] += 1;
                total += 1;
                if (w as i32).abs() <= 4 {
                    near_zero += 1;
                }
            }
        }
    }
    let max = *hist.iter().max().unwrap();
    for (i, &h) in hist.iter().enumerate() {
        let lo = -128 + (i as i32) * 256 / 17;
        let bar = "#".repeat((h * 48 / max.max(1)) as usize);
        println!("{lo:>5}..{:>4} {h:>8} {bar}", lo + 256 / 17);
    }
    println!(
        "\n{:.1}% of weights within ±4 LSB of zero (paper pointer ③: dominant \
         zero-mass → non-important neurons waste energy at nominal voltage)",
        near_zero as f64 / total as f64 * 100.0
    );
}
