//! §Perf harness: throughput of every hot path in the stack (DESIGN.md §8
//! targets). Run before/after optimizations; numbers land in
//! EXPERIMENTS.md §Perf.
//!
//!   L3a gate-level timing sim   target ≥ 1 M vectors/s/core (characterization)
//!   L3b systolic-array matmul   target ≥ 100 M MAC/s
//!   L3c ILP assignment          target < 100 ms for 138×4 (paper: ≤ 54.7 s)
//!   L3d quantized inference     reported for the serving path
//!   L3e PJRT artifact exec      reported for the AOT path

#[path = "common.rs"]
mod common;

use xtpu::assign::{AssignmentProblem, Solver};
use xtpu::errormodel::{characterize_voltage, CharacterizeOptions};
use xtpu::nn::quant::QuantizedModel;
use xtpu::runtime::{artifacts_dir, FcExecutor, Runtime};
use xtpu::simulator::{ErrorInjector, XTpu};
use xtpu::timing::baugh_wooley_8x8;
use xtpu::timing::sta::ChipInstance;
use xtpu::timing::voltage::Technology;
use xtpu::util::rng::Xoshiro256pp;

fn main() {
    common::header("§Perf — hot-path throughput", "DESIGN.md §8 targets");
    let tech = Technology::default();

    // --- L3a: gate-level timing simulation ------------------------------
    let netlist = baugh_wooley_8x8("perf_pe");
    let mut rng = Xoshiro256pp::seeded(0x9E2F);
    let chip = ChipInstance::sample(&netlist, &tech, &mut rng);
    let samples = 400_000u64;
    let t0 = std::time::Instant::now();
    let m = characterize_voltage(
        &netlist,
        &chip,
        &tech,
        0.5,
        &CharacterizeOptions { samples, seed: 1, ..Default::default() },
    );
    let dt = t0.elapsed().as_secs_f64();
    let cores = xtpu::util::threadpool::worker_count();
    println!(
        "L3a timing sim    : {:>8.2} M vectors/s total ({:.2} M/s/core × {cores} cores) \
         [target ≥ 1 M/s/core]  (var={:.3e})",
        samples as f64 / dt / 1e6,
        samples as f64 / dt / 1e6 / cores as f64,
        m.variance
    );

    // --- L3b: systolic-array matmul --------------------------------------
    let pipeline = common::bench_pipeline();
    let reg = pipeline.error_models().unwrap();
    let mut tpu = XTpu::new(128, 128, reg.ladder.clone(), ErrorInjector::Statistical(reg));
    let (mm, kk, nn) = (256usize, 784usize, 128usize);
    let mut rng = Xoshiro256pp::seeded(2);
    let a: Vec<i8> = (0..mm * kk).map(|_| rng.range_i64(-127, 127) as i8).collect();
    let w: Vec<i8> = (0..kk * nn).map(|_| rng.range_i64(-127, 127) as i8).collect();
    for (label, level) in [("exact cols", 3usize), ("0.5V cols", 0)] {
        tpu.reset_stats();
        let t0 = std::time::Instant::now();
        let out = tpu.matmul(&a, &w, mm, kk, nn, &vec![level; nn], &mut rng);
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(&out);
        println!(
            "L3b systolic mm   : {:>8.1} M MAC/s ({label}) [target ≥ 100 M MAC/s]",
            tpu.stats.macs as f64 / dt / 1e6
        );
    }

    // --- L3c: ILP assignment ---------------------------------------------
    let sys = pipeline.prepare().unwrap();
    let budget = 2.0 * sys.baseline_mse;
    let problem =
        AssignmentProblem::build(&sys.es, &sys.fan_in, &sys.registry, &sys.power, budget);
    let t0 = std::time::Instant::now();
    let a = problem.solve(Solver::Ilp).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "L3c ILP assignment: {:>8.2} ms for {}×{} ({} nodes) [target < 100 ms; paper ≤ 54.7 s]",
        dt * 1000.0,
        sys.es.len(),
        sys.registry.ladder.len(),
        a.nodes_explored
    );

    // --- L3d: quantized inference (serving path) --------------------------
    let calib = sys.test.batch(&(0..32).collect::<Vec<_>>()).0;
    let q = QuantizedModel::quantize(&sys.model, &calib);
    let (x, _) = sys.test.batch(&(0..64).collect::<Vec<_>>());
    let mut rng = Xoshiro256pp::seeded(3);
    let reps = 30;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        std::hint::black_box(q.forward(&x, None, &mut rng));
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "L3d quantized fwd : {:>8.1} inferences/s (batch 64, rust int8 path)",
        (reps * 64) as f64 / dt
    );

    // --- L3e: PJRT artifact ------------------------------------------------
    if artifacts_dir().join("fc_mnist_linear_b32.hlo.txt").exists() {
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        let exec = FcExecutor::from_quantized(&q, "linear", 32).unwrap();
        rt.load(&exec.artifact).unwrap();
        let (xb, _) = sys.test.batch(&(0..32).collect::<Vec<_>>());
        let mut rng = Xoshiro256pp::seeded(4);
        let reps = 30;
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            std::hint::black_box(exec.run(&rt, &xb.data, &mut rng).unwrap());
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "L3e PJRT artifact : {:>8.1} inferences/s (batch 32, XLA CPU executable)",
            (reps * 32) as f64 / dt
        );
    } else {
        println!("L3e PJRT artifact : skipped (make artifacts)");
    }
}
