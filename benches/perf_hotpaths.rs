//! §Perf harness: throughput of every hot path in the stack (DESIGN.md §8
//! targets). Run before/after optimizations; numbers land in
//! EXPERIMENTS.md §Perf and (optionally) a JSON report.
//!
//!   L3a gate-level timing sim   target ≥ 1 M vectors/s/core (characterization)
//!   L3b batched matmul          target ≥ 100 M MAC/s (exec::Statistical backend;
//!                               the cycle-level simulator number is reported
//!                               alongside for the before/after comparison)
//!   L3c ILP assignment          target < 100 ms for 138×4 (paper: ≤ 54.7 s)
//!   L3d quantized inference     reported for the serving path
//!   L3e artifact exec           reported for the AOT path
//!
//! Set `XTPU_BENCH_JSON=<path>` to additionally write the numbers as JSON
//! (the exec-refactor before/after record lives in BENCH_exec_refactor.json).

#[path = "common.rs"]
mod common;

// With `--features alloc-count` the whole bench binary runs under a counting
// global allocator so L3m can report allocations/request in the warm
// prepacked serve loop as a measured number (the CI gate pins it to 0). The
// counter is process-wide, so L3m takes the minimum over several trials to
// shrug off unrelated background allocation. Without the feature the system
// allocator is untouched and L3m reports null for the key.
#[cfg(feature = "alloc-count")]
mod alloc_count {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    pub static ALLOCS: AtomicU64 = AtomicU64::new(0);

    pub struct Counting;

    // SAFETY: defers every operation to `System`; the counter is a relaxed
    // atomic with no other side effects.
    unsafe impl GlobalAlloc for Counting {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.alloc(layout)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.realloc(ptr, layout, new_size)
        }
    }

    #[global_allocator]
    static COUNTER: Counting = Counting;
}

use xtpu::assign::{AssignmentProblem, Solver};
use xtpu::errormodel::{characterize_voltage, CharacterizeOptions};
use xtpu::exec::{Backend, Exact, Statistical};
use xtpu::nn::quant::QuantizedModel;
use xtpu::runtime::{artifacts_dir, FcExecutor, Runtime};
use xtpu::simulator::{ErrorInjector, XTpu};
use xtpu::timing::baugh_wooley_8x8;
use xtpu::timing::sta::ChipInstance;
use xtpu::timing::voltage::Technology;
use xtpu::util::json::Json;
use xtpu::util::rng::Xoshiro256pp;

fn main() {
    common::header("§Perf — hot-path throughput", "DESIGN.md §8 targets");
    let tech = Technology::default();
    let mut report: Vec<(&str, Json)> = Vec::new();

    // --- L3a: gate-level timing simulation ------------------------------
    let netlist = baugh_wooley_8x8("perf_pe");
    let mut rng = Xoshiro256pp::seeded(0x9E2F);
    let chip = ChipInstance::sample(&netlist, &tech, &mut rng);
    let samples = 400_000u64;
    let t0 = std::time::Instant::now();
    let m = characterize_voltage(
        &netlist,
        &chip,
        &tech,
        0.5,
        &CharacterizeOptions { samples, seed: 1, ..Default::default() },
    );
    let dt = t0.elapsed().as_secs_f64();
    let cores = xtpu::util::threadpool::worker_count();
    println!(
        "L3a timing sim    : {:>8.2} M vectors/s total ({:.2} M/s/core × {cores} cores) \
         [target ≥ 1 M/s/core]  (var={:.3e})",
        samples as f64 / dt / 1e6,
        samples as f64 / dt / 1e6 / cores as f64,
        m.variance
    );
    report.push(("l3a_mvectors_per_s", Json::Num(samples as f64 / dt / 1e6)));

    // --- L3b: batched matmul through the exec backends -------------------
    let pipeline = common::bench_pipeline();
    let reg = pipeline.error_models().unwrap();
    let (mm, kk, nn) = (256usize, 784usize, 128usize);
    let mut rng = Xoshiro256pp::seeded(2);
    let a: Vec<i8> = (0..mm * kk).map(|_| rng.range_i64(-127, 127) as i8).collect();
    let w: Vec<i8> = (0..kk * nn).map(|_| rng.range_i64(-127, 127) as i8).collect();
    let macs = (mm * kk * nn) as f64;
    let reps = 10;

    let bench_backend = |label: &str, be: &dyn Backend, level: usize| -> f64 {
        let levels = vec![level; nn];
        let mut rng = Xoshiro256pp::seeded(3);
        // Warm-up pass, then timed reps.
        std::hint::black_box(be.matmul_i8(&a, &w, mm, kk, nn, &levels, &mut rng));
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            std::hint::black_box(be.matmul_i8(&a, &w, mm, kk, nn, &levels, &mut rng));
        }
        let dt = t0.elapsed().as_secs_f64();
        let mmacs = macs * reps as f64 / dt / 1e6;
        println!(
            "L3b exec matmul   : {mmacs:>8.1} M MAC/s ({label}, 1 thread) \
             [target ≥ 100 M MAC/s]"
        );
        mmacs
    };
    // L3b keys are pinned to one thread so they stay comparable with the
    // single-threaded BENCH_exec_refactor.json baselines; L3f below is the
    // section that measures thread scaling.
    let l3b_prior_threads = std::env::var("XTPU_THREADS").ok();
    std::env::set_var("XTPU_THREADS", "1");
    let exact_mmacs = bench_backend("Exact backend", &Exact, 3);
    let stat = Statistical::new(reg.clone());
    let stat_nom_mmacs = bench_backend("Statistical, nominal cols", &stat, 3);
    let stat_vos_mmacs = bench_backend("Statistical, 0.5V cols", &stat, 0);

    // Forced scalar vs. active-path kernel throughput on the same workload
    // (still pinned to one thread). The dispatch property tests prove the
    // outputs identical; this is the before/after the SIMD work buys.
    let active = xtpu::exec::dispatch::active();
    let bench_path = |path: xtpu::exec::dispatch::SimdPath| -> f64 {
        let mut scratch = xtpu::exec::kernel::KernelScratch::new();
        let mut out = Vec::new();
        xtpu::exec::kernel::matmul_i8_path(path, &a, &w, mm, kk, nn, &mut out, &mut scratch);
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            xtpu::exec::kernel::matmul_i8_path(path, &a, &w, mm, kk, nn, &mut out, &mut scratch);
            std::hint::black_box(&out);
        }
        macs * reps as f64 / t0.elapsed().as_secs_f64() / 1e6
    };
    let scalar_mmacs = bench_path(xtpu::exec::dispatch::SimdPath::Scalar);
    let simd_mmacs = bench_path(active);
    println!(
        "L3b kernel paths  : {scalar_mmacs:>8.1} M MAC/s scalar → {simd_mmacs:>8.1} M MAC/s \
         {} (×{:.2}, 1 thread)",
        active.name(),
        simd_mmacs / scalar_mmacs
    );
    match l3b_prior_threads {
        Some(v) => std::env::set_var("XTPU_THREADS", v),
        None => std::env::remove_var("XTPU_THREADS"),
    }
    report.push(("simd_path", Json::Str(active.name().to_string())));
    report.push(("l3b_kernel_scalar_mmacs", Json::Num(scalar_mmacs)));
    report.push(("l3b_kernel_simd_mmacs", Json::Num(simd_mmacs)));
    report.push(("l3b_simd_speedup", Json::Num(simd_mmacs / scalar_mmacs)));
    report.push(("l3b_exec_exact_mmacs", Json::Num(exact_mmacs)));
    report.push(("l3b_exec_statistical_nominal_mmacs", Json::Num(stat_nom_mmacs)));
    report.push(("l3b_exec_statistical_vos_mmacs", Json::Num(stat_vos_mmacs)));

    // --- L3j: TE-Drop backend matmul (detect + drop recovery) -------------
    // Same workload and single-thread pin as L3b, through exec::TeDrop.
    // Nominal columns price the detection machinery when no MAC ever
    // faults (rate 0 ⇒ the drop pass must be near-free); the 0.5 V number
    // includes the geometric skip-sampled drop pass at the ladder's worst
    // per-MAC error rate.
    let te = xtpu::exec::TeDrop::new(reg.clone());
    let l3j_prior_threads = std::env::var("XTPU_THREADS").ok();
    std::env::set_var("XTPU_THREADS", "1");
    let bench_tedrop = |label: &str, level: usize| -> f64 {
        let levels = vec![level; nn];
        let mut rng = Xoshiro256pp::seeded(5);
        std::hint::black_box(te.matmul_i8(&a, &w, mm, kk, nn, &levels, &mut rng));
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            std::hint::black_box(te.matmul_i8(&a, &w, mm, kk, nn, &levels, &mut rng));
        }
        let mmacs = macs * reps as f64 / t0.elapsed().as_secs_f64() / 1e6;
        println!("L3j tedrop matmul : {mmacs:>8.1} M MAC/s ({label}, 1 thread)");
        mmacs
    };
    let te_nom_mmacs = bench_tedrop("TE-Drop, nominal cols", 3);
    let te_vos_mmacs = bench_tedrop("TE-Drop, 0.5V cols", 0);
    match l3j_prior_threads {
        Some(v) => std::env::set_var("XTPU_THREADS", v),
        None => std::env::remove_var("XTPU_THREADS"),
    }
    report.push(("l3j_tedrop_nominal_mmacs", Json::Num(te_nom_mmacs)));
    report.push(("l3j_tedrop_vos_mmacs", Json::Num(te_vos_mmacs)));
    report.push(("l3j_tedrop_drop_cost", Json::Num(te_nom_mmacs / te_vos_mmacs)));

    // --- L3f: parallel exec scaling (threads=1 vs threads=N) --------------
    // The BENCH_parallel_exec.json record tracks these keys. Same seed at
    // both thread counts — the outputs must be bit-identical (the parallel
    // kernel's determinism guarantee), which is asserted, not assumed.
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let run_stat = |seed: u64| -> Vec<i32> {
        let mut rng = Xoshiro256pp::seeded(seed);
        stat.matmul_i8(&a, &w, mm, kk, nn, &vec![0usize; nn], &mut rng)
    };
    let time_stat = || -> f64 {
        let mut rng = Xoshiro256pp::seeded(6);
        let levels = vec![0usize; nn];
        std::hint::black_box(stat.matmul_i8(&a, &w, mm, kk, nn, &levels, &mut rng));
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            std::hint::black_box(stat.matmul_i8(&a, &w, mm, kk, nn, &levels, &mut rng));
        }
        macs * reps as f64 / t0.elapsed().as_secs_f64() / 1e6
    };
    let prior_threads = std::env::var("XTPU_THREADS").ok();
    std::env::set_var("XTPU_THREADS", "1");
    let t1_mmacs = time_stat();
    let out_t1 = run_stat(7);
    std::env::set_var("XTPU_THREADS", hw.to_string());
    let tn_mmacs = time_stat();
    let out_tn = run_stat(7);
    // Restore the caller's setting so the remaining sections run under the
    // configuration the bench was invoked with.
    match prior_threads {
        Some(v) => std::env::set_var("XTPU_THREADS", v),
        None => std::env::remove_var("XTPU_THREADS"),
    }
    assert_eq!(out_t1, out_tn, "parallel kernel must be bit-identical across thread counts");
    println!(
        "L3f parallel exec : {t1_mmacs:>8.1} M MAC/s @ 1 thread → {tn_mmacs:>8.1} M MAC/s @ \
         {hw} threads (×{:.2}, outputs bit-identical)",
        tn_mmacs / t1_mmacs
    );
    report.push(("l3f_threads", Json::Num(hw as f64)));
    report.push(("l3f_stat_vos_threads1_mmacs", Json::Num(t1_mmacs)));
    report.push(("l3f_stat_vos_threadsN_mmacs", Json::Num(tn_mmacs)));
    report.push(("l3f_parallel_speedup", Json::Num(tn_mmacs / t1_mmacs)));

    // Cycle-level simulator for the same workload (the pre-refactor "L3b"):
    // slower by design — it also books cycles/energy per tile pass.
    let mut tpu = XTpu::new(128, 128, reg.ladder.clone(), ErrorInjector::Statistical(reg));
    for (label, level, key) in [
        ("cycle sim, exact cols", 3usize, "l3b_cycle_sim_exact_mmacs"),
        ("cycle sim, 0.5V cols", 0, "l3b_cycle_sim_vos_mmacs"),
    ] {
        tpu.reset_stats();
        let mut rng = Xoshiro256pp::seeded(4);
        let t0 = std::time::Instant::now();
        let out = tpu.matmul(&a, &w, mm, kk, nn, &vec![level; nn], &mut rng);
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(&out);
        let mmacs = tpu.stats.macs as f64 / dt / 1e6;
        println!("L3b systolic mm   : {mmacs:>8.1} M MAC/s ({label})");
        report.push((key, Json::Num(mmacs)));
    }

    // --- L3c: ILP assignment ---------------------------------------------
    let sys = pipeline.prepare().unwrap();
    let budget = 2.0 * sys.baseline_mse;
    let problem =
        AssignmentProblem::build(&sys.es, &sys.fan_in, &sys.registry, &sys.power, budget);
    let t0 = std::time::Instant::now();
    let a_sol = problem.solve(Solver::Ilp).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "L3c ILP assignment: {:>8.2} ms for {}×{} ({} nodes) [target < 100 ms; paper ≤ 54.7 s]",
        dt * 1000.0,
        sys.es.len(),
        sys.registry.ladder.len(),
        a_sol.nodes_explored
    );
    report.push(("l3c_ilp_ms", Json::Num(dt * 1000.0)));

    // --- L3g: multi-budget plan sweep (sequential vs parallel solve) ------
    // The offline planner solves every MSE_UB budget into a deployable
    // VoltagePlan; solve_many fans the MCKPs out across the thread pool.
    let mut planner = xtpu::plan::Planner::new(common::bench_config());
    planner.warm().unwrap();
    let budgets: Vec<f64> = (1..=8).map(|i| i as f64 * 0.5).collect();
    let t0 = std::time::Instant::now();
    for &f in &budgets {
        std::hint::black_box(planner.solve(f).unwrap());
    }
    let seq_s = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    std::hint::black_box(planner.solve_many(&budgets).unwrap());
    let par_s = t0.elapsed().as_secs_f64();
    println!(
        "L3g plan sweep    : {:>8.2} ms sequential → {:>8.2} ms parallel \
         ({:.2}× on {} budgets)",
        seq_s * 1000.0,
        par_s * 1000.0,
        seq_s / par_s.max(1e-9),
        budgets.len()
    );
    report.push(("l3g_plan_seq_ms", Json::Num(seq_s * 1000.0)));
    report.push(("l3g_plan_par_ms", Json::Num(par_s * 1000.0)));
    report.push(("l3g_plan_speedup", Json::Num(seq_s / par_s.max(1e-9))));

    // --- L3h: fleet routing ablation (virtual-time, no inference) ---------
    // Same trace, three routing policies: throughput of the routing +
    // wear-accounting hot loop (requests simulated per second of wall
    // time) and the min-lifetime gain the aging-aware policy buys.
    {
        use std::sync::Arc;
        use xtpu::fleet::{policy_from_name, FleetConfig, Router, Trace};
        use xtpu::server::Engine;
        let fleet_plans = planner.solve_many(&[0.0, 10.0]).unwrap();
        let registry2 = planner.registry().unwrap().clone();
        let quantized = planner.trained().unwrap().quantized.clone();
        let engine =
            Arc::new(Engine::from_plans(quantized, &registry2, &fleet_plans, 784).unwrap());
        let fleet_cfg = FleetConfig {
            devices: 8,
            wear_accel: 4.0e5,
            initial_age_years: vec![0.02, 0.012, 0.006, 0.0],
            initial_age_duty: 1.0,
            ..FleetConfig::default()
        };
        let trace = Trace::poisson(3_000.0, 2.5, &[1.0, 1.0], 0xF1EE7);
        let n_req = trace.request_count() as f64;
        let mut rr_min_life = 0.0f64;
        for (name, key_rate, key_life) in [
            ("rr", "l3h_route_rr_kreq_per_s", "l3h_rr_min_life_y"),
            ("ll", "l3h_route_ll_kreq_per_s", "l3h_ll_min_life_y"),
            ("wl", "l3h_route_wl_kreq_per_s", "l3h_wl_min_life_y"),
        ] {
            // Same alias table (and thus same wear-level parameters) as
            // the `xtpu fleet --policy` flag.
            let policy = policy_from_name(name).unwrap();
            let mut fleet =
                Router::new(engine.clone(), &fleet_plans, policy, fleet_cfg.clone()).unwrap();
            let t0 = std::time::Instant::now();
            let t = fleet.run(&trace);
            let dt = t0.elapsed().as_secs_f64();
            let krps = n_req / dt / 1e3;
            println!(
                "L3h fleet routing : {krps:>8.1} k req/s simulated ({name}, {} devices) \
                 min life {:.4} y · p99 {:.2} ms",
                fleet_cfg.devices, t.min_lifetime_years, t.latency_p99_ms
            );
            report.push((key_rate, Json::Num(krps)));
            report.push((key_life, Json::Num(t.min_lifetime_years)));
            if name == "rr" {
                rr_min_life = t.min_lifetime_years;
            }
            if name == "wl" && rr_min_life > 0.0 {
                report.push((
                    "l3h_wl_min_life_gain",
                    Json::Num(t.min_lifetime_years / rr_min_life - 1.0),
                ));
            }
        }
    }

    // --- L3i: adaptive re-planning hot paths -------------------------------
    // The closed loop's three costs, per BENCH_adaptive_replan.json:
    //   drifted-ES eval  — deriving a DriftedRegistry + re-pricing every
    //                      neuron's MSE contribution under it (no solve);
    //   re-plan latency  — warm-started resolve_plan_from vs a cold MCKP;
    //   swap latency     — Engine::swap_plans on a live engine.
    {
        use xtpu::plan::{resolve_plan_from, ResolveOptions};
        use xtpu::server::Engine;
        let registry3 = planner.registry().unwrap().clone();
        let power = *planner.power();
        let deployed = planner.solve(1.0).unwrap();
        let quantized = planner.trained().unwrap().quantized.clone();
        let delta_vth = 0.01;
        let reps = 50;

        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            let drifted = registry3.drifted(delta_vth);
            let vars: Vec<f64> =
                drifted.registry().models().iter().map(|m| m.variance).collect();
            std::hint::black_box(deployed.served_mse(&vars));
        }
        let drift_eval_us = t0.elapsed().as_secs_f64() / reps as f64 * 1e6;

        let drifted = registry3.drifted(delta_vth);
        let opts = ResolveOptions { budget_scale: 0.9, ..Default::default() };
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            std::hint::black_box(
                resolve_plan_from(&deployed, &registry3, &drifted, &power, &opts).unwrap(),
            );
        }
        let replan_warm_ms = t0.elapsed().as_secs_f64() / reps as f64 * 1e3;
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            let problem = AssignmentProblem::build(
                &deployed.es,
                &deployed.fan_in,
                drifted.registry(),
                &power,
                deployed.budget_abs * 0.9,
            );
            std::hint::black_box(problem.solve(Solver::Ilp).unwrap());
        }
        let replan_cold_ms = t0.elapsed().as_secs_f64() / reps as f64 * 1e3;

        let plans_pair = vec![planner.solve(0.0).unwrap(), deployed.clone()];
        let engine =
            Engine::from_plans(quantized, &registry3, &plans_pair, 784).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            std::hint::black_box(engine.swap_plans(&registry3, &plans_pair).unwrap());
        }
        let swap_us = t0.elapsed().as_secs_f64() / reps as f64 * 1e6;

        println!(
            "L3i adaptive loop : {drift_eval_us:>8.1} µs drifted-ES eval · \
             {replan_warm_ms:>6.2} ms warm re-plan ({replan_cold_ms:.2} ms cold) · \
             {swap_us:>6.1} µs plan swap"
        );
        report.push(("l3i_drifted_es_eval_us", Json::Num(drift_eval_us)));
        report.push(("l3i_replan_warm_ms", Json::Num(replan_warm_ms)));
        report.push(("l3i_replan_cold_ms", Json::Num(replan_cold_ms)));
        report.push(("l3i_swap_us", Json::Num(swap_us)));
    }

    // --- L3d: quantized inference (serving path, exec backend) ------------
    let calib = sys.test.batch(&(0..32).collect::<Vec<_>>()).0;
    let q = QuantizedModel::quantize(&sys.model, &calib);
    let (x, _) = sys.test.batch(&(0..64).collect::<Vec<_>>());
    let backend = pipeline.make_backend(&sys.registry).unwrap();
    let mut rng = Xoshiro256pp::seeded(3);
    let reps = 30;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        std::hint::black_box(q.forward_with(backend.as_ref(), &x, None, &mut rng));
    }
    let dt = t0.elapsed().as_secs_f64();
    let infs = (reps * 64) as f64 / dt;
    // Clean forwards run the shared kernel on every backend, so this is
    // the serving-path number regardless of the configured engine.
    println!(
        "L3d quantized fwd : {infs:>8.1} inferences/s (batch 64, shared kernel via {} backend)",
        backend.name()
    );
    report.push(("l3d_inferences_per_s", Json::Num(infs)));

    // --- L3e: AOT artifact -------------------------------------------------
    if artifacts_dir().join("fc_mnist_linear_b32.hlo.txt").exists() {
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        let fc = FcExecutor::from_quantized(&q, "linear", 32).unwrap();
        rt.load(&fc.artifact).unwrap();
        let (xb, _) = sys.test.batch(&(0..32).collect::<Vec<_>>());
        let mut rng = Xoshiro256pp::seeded(4);
        let reps = 30;
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            std::hint::black_box(fc.run(&rt, &xb.data, &mut rng).unwrap());
        }
        let dt = t0.elapsed().as_secs_f64();
        let infs = (reps * 32) as f64 / dt;
        println!(
            "L3e AOT artifact  : {infs:>8.1} inferences/s (batch 32, {})",
            rt.platform()
        );
        report.push(("l3e_inferences_per_s", Json::Num(infs)));
    } else {
        println!("L3e AOT artifact  : skipped (make artifacts)");
        report.push(("l3e_inferences_per_s", Json::Null));
    }

    // --- L3k: evented serving frontend (closed-loop stress) ---------------
    // A modest in-process twin of examples/serve_stress.rs (the CI smoke
    // run drives 10k connections; the bench stays well under the default
    // fd ulimit): closed-loop clients with deadline tags against the
    // evented frontend. Reports admitted throughput, served p99 against
    // the SLO, and the shed fraction; the keys are presence-gated against
    // BENCH_serving.json by tools/check_bench_regression.py.
    let l3k_rps = {
        use std::io::{ErrorKind, Read, Write};
        use xtpu::nn::quant::NoiseSpec;
        use xtpu::server::{
            BatchPolicy, Engine, FrontendMode, FrontendOptions, QualityLevel, Server,
        };
        use xtpu::util::stats::LatencyHistogram;

        struct C {
            s: std::net::TcpStream,
            out: Vec<u8>,
            inbuf: Vec<u8>,
            sent_at: std::time::Instant,
            alive: bool,
        }

        let nq = q.num_neurons();
        let mut noisy = NoiseSpec::silent(nq);
        for s in noisy.std.iter_mut().take(128) {
            *s = 2000.0;
        }
        let levels = vec![
            QualityLevel {
                name: "exact".into(),
                noise: NoiseSpec::silent(nq),
                energy_saving: 0.0,
                energy: 10.0,
                predicted_mse: 0.0,
            },
            QualityLevel {
                name: "eco".into(),
                noise: noisy,
                energy_saving: 0.3,
                energy: 7.0,
                predicted_mse: 0.0,
            },
        ];
        let engine = Engine::new(q.clone(), levels, 784).unwrap();
        let slo = std::time::Duration::from_millis(100);
        let opts = FrontendOptions {
            mode: FrontendMode::Evented,
            slo: Some(slo),
            max_conns: 2048,
            max_queue: 64,
            ..Default::default()
        };
        let policy = BatchPolicy {
            max_batch: 32,
            max_wait: std::time::Duration::from_millis(1),
            workers: 2,
        };
        let mut server =
            Server::spawn_opts(vec![std::sync::Arc::new(engine)], 0, policy, opts).unwrap();

        let pixels: Vec<f64> = (0..784).map(|i| (i % 13) as f64 / 12.0).collect();
        let mut line = Json::obj(vec![
            ("pixels", Json::arr_f64(&pixels)),
            ("quality", Json::Num(1.0)),
            ("deadline_ms", Json::Num(slo.as_millis() as f64)),
        ])
        .to_string();
        line.push('\n');
        let req = line.into_bytes();

        let conns = 256usize;
        let mut pool: Vec<C> = Vec::with_capacity(conns);
        for _ in 0..conns {
            let s = std::net::TcpStream::connect(server.addr).unwrap();
            s.set_nodelay(true).ok();
            s.set_nonblocking(true).unwrap();
            pool.push(C {
                s,
                out: req.clone(),
                inbuf: Vec::new(),
                sent_at: std::time::Instant::now(),
                alive: true,
            });
        }

        let hist = LatencyHistogram::new();
        let (mut sent, mut served, mut shed) = (0u64, 0u64, 0u64);
        let t0 = std::time::Instant::now();
        let dur = std::time::Duration::from_millis(1500);
        let mut issuing = true;
        let mut inflight = 0u64;
        let mut buf = [0u8; 4096];
        loop {
            if issuing && t0.elapsed() >= dur {
                issuing = false;
            }
            if !issuing
                && (inflight == 0 || t0.elapsed() > dur + std::time::Duration::from_secs(5))
            {
                break;
            }
            let mut progressed = false;
            for c in pool.iter_mut() {
                if !c.alive {
                    continue;
                }
                while !c.out.is_empty() {
                    match c.s.write(&c.out) {
                        Ok(0) => {
                            c.alive = false;
                            break;
                        }
                        Ok(n) => {
                            c.out.drain(..n);
                            progressed = true;
                            if c.out.is_empty() {
                                c.sent_at = std::time::Instant::now();
                                sent += 1;
                                inflight += 1;
                            }
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(_) => {
                            c.alive = false;
                            break;
                        }
                    }
                }
                loop {
                    match c.s.read(&mut buf) {
                        Ok(0) => {
                            c.alive = false;
                            break;
                        }
                        Ok(n) => {
                            progressed = true;
                            c.inbuf.extend_from_slice(&buf[..n]);
                            while let Some(p) = c.inbuf.iter().position(|&b| b == b'\n') {
                                let reply: Vec<u8> = c.inbuf.drain(..=p).collect();
                                inflight = inflight.saturating_sub(1);
                                const NEEDLE: &[u8] = b"\"class\"";
                                if reply.windows(NEEDLE.len()).any(|w| w == NEEDLE) {
                                    served += 1;
                                    hist.record_us(
                                        c.sent_at.elapsed().as_micros().min(u64::MAX as u128)
                                            as u64,
                                    );
                                } else {
                                    shed += 1;
                                }
                                if issuing {
                                    c.out = req.clone();
                                }
                            }
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(_) => {
                            c.alive = false;
                            break;
                        }
                    }
                }
            }
            if !progressed {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        server.shutdown();
        let rps = served as f64 / dt;
        let p99 = hist.quantile_us(0.99) as f64;
        let shed_fraction = if sent > 0 { shed as f64 / sent as f64 } else { 0.0 };
        println!(
            "L3k evented serve : {rps:>8.1} req/s served ({conns} closed-loop conns, \
             p99 {p99:.0} µs vs {} ms SLO, {:.1}% shed)",
            slo.as_millis(),
            shed_fraction * 100.0
        );
        report.push(("l3k_evented_rps", Json::Num(rps)));
        report.push(("l3k_p99_us_at_slo", Json::Num(p99)));
        report.push(("l3k_shed_fraction", Json::Num(shed_fraction)));
        rps
    };

    // --- L3l: observability overhead (sampling off) ------------------------
    // What the obs layer costs a request when nothing is sampled: one
    // relaxed atomic load in Tracer::maybe_start plus the audit's disabled
    // check — the exact hook sequence on the serving hot path. Expressed
    // as a percentage of the measured per-request serving budget (the L3k
    // closed loop above) and gated ≤ 2% by tools/check_bench_regression.py:
    // "sampling 0 is measurably free" is a number, not a promise.
    {
        use xtpu::obs::audit::{AuditConfig, QualityAudit};
        use xtpu::obs::metrics::Registry;
        use xtpu::obs::trace::Tracer;
        let tracer = std::sync::Arc::new(Tracer::new(4096));
        tracer.set_sample_every(0);
        let audit =
            QualityAudit::new(AuditConfig::default(), std::sync::Arc::new(Registry::new()));
        let iters = 10_000_000u64;
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            std::hint::black_box(tracer.maybe_start());
            std::hint::black_box(audit.should_sample());
        }
        let hook_ns = t0.elapsed().as_secs_f64() * 1e9 / iters as f64;
        let req_ns = if l3k_rps > 0.0 { 1e9 / l3k_rps } else { f64::INFINITY };
        let overhead_pct = hook_ns / req_ns * 100.0;
        println!(
            "L3l obs overhead  : {hook_ns:>8.2} ns/req hooks (sampling off) = \
             {overhead_pct:.4}% of the {req_ns:.0} ns/req serving budget [gate ≤ 2%]"
        );
        report.push(("l3l_obs_hook_ns", Json::Num(hook_ns)));
        report.push(("l3l_obs_overhead_pct", Json::Num(overhead_pct)));
    }

    // --- L3m: zero-repack serving data path --------------------------------
    // The steady-state serve loop: weights SIMD-packed once per generation
    // (PackedModel, held in the PlanSet snapshot) and activations /
    // accumulators arena-reused across batches (ForwardArena). Three
    // numbers, all pinned to one thread so they stay comparable across runs
    // and with the single-threaded L3b kernel keys:
    //   (1) transposed-kernel MAC/s, per-call layout vs the persistent
    //       PackedLayer (bit-identical, asserted here);
    //   (2) steady-state inferences/s, the L3d per-call forward vs the
    //       prepacked+arena forward the batch workers run (bit-identical,
    //       asserted here);
    //   (3) allocations/request over the warm prepacked loop — measured
    //       only under `--features alloc-count`, null otherwise.
    {
        use xtpu::exec::kernel;
        use xtpu::nn::quant::{ForwardArena, PackedModel};

        let l3m_prior_threads = std::env::var("XTPU_THREADS").ok();
        std::env::set_var("XTPU_THREADS", "1");

        // (1) kernel: per-call vs prepacked on the serve layer shape.
        let (bm, bk, bn) = (64usize, 784usize, 128usize);
        let mut rng = Xoshiro256pp::seeded(6);
        let act: Vec<i8> = (0..bm * bk).map(|_| rng.range_i64(-127, 127) as i8).collect();
        let wt: Vec<i8> = (0..bn * bk).map(|_| rng.range_i64(-127, 127) as i8).collect();
        let kmacs = (bm * bk * bn) as f64;
        let kreps = 60;
        let mut out_call = Vec::new();
        kernel::matmul_i8t_path(active, &act, &wt, bm, bk, bn, &mut out_call);
        let t0 = std::time::Instant::now();
        for _ in 0..kreps {
            kernel::matmul_i8t_path(active, &act, &wt, bm, bk, bn, &mut out_call);
            std::hint::black_box(&out_call);
        }
        let percall_mmacs = kmacs * kreps as f64 / t0.elapsed().as_secs_f64() / 1e6;

        let pl = kernel::PackedLayer::pack(active, &wt, bk, bn);
        let mut out_pre = Vec::new();
        kernel::matmul_i8t_prepacked(&pl, &act, bm, &mut out_pre);
        assert_eq!(out_call, out_pre, "prepacked kernel must be bit-identical");
        let t0 = std::time::Instant::now();
        for _ in 0..kreps {
            kernel::matmul_i8t_prepacked(&pl, &act, bm, &mut out_pre);
            std::hint::black_box(&out_pre);
        }
        let prepacked_mmacs = kmacs * kreps as f64 / t0.elapsed().as_secs_f64() / 1e6;

        // (2) end-to-end: the exact L3d workload (clean forward, batch 64)
        // re-timed at one thread as the per-call baseline, then the
        // prepacked + arena path the batch workers actually run.
        let sreps = 30;
        let mut rng_a = Xoshiro256pp::seeded(3);
        let y_call = q.forward_with(backend.as_ref(), &x, None, &mut rng_a);
        let t0 = std::time::Instant::now();
        for _ in 0..sreps {
            std::hint::black_box(q.forward_with(backend.as_ref(), &x, None, &mut rng_a));
        }
        let serve_baseline_infs = (sreps * 64) as f64 / t0.elapsed().as_secs_f64();

        let packed = PackedModel::pack(&q, active);
        let mut arena = ForwardArena::default();
        let mut logits: Vec<f32> = Vec::new();
        let mut rng_b = Xoshiro256pp::seeded(3);
        q.forward_prepacked(
            backend.as_ref(),
            &x,
            None,
            None,
            &mut rng_b,
            &packed,
            &mut arena,
            &mut logits,
        );
        let call_bits: Vec<u32> = y_call.data.iter().map(|v| v.to_bits()).collect();
        let pre_bits: Vec<u32> = logits.iter().map(|v| v.to_bits()).collect();
        assert_eq!(call_bits, pre_bits, "prepacked forward must be bit-identical");
        let t0 = std::time::Instant::now();
        for _ in 0..sreps {
            q.forward_prepacked(
                backend.as_ref(),
                &x,
                None,
                None,
                &mut rng_b,
                &packed,
                &mut arena,
                &mut logits,
            );
            std::hint::black_box(&logits);
        }
        let serve_infs = (sreps * 64) as f64 / t0.elapsed().as_secs_f64();

        // (3) allocations/request over the (already warm) loop. Minimum over
        // trials: the counter is process-wide and a parked thread or OS
        // buffer can allocate concurrently; if the loop itself is
        // allocation-free, at least one trial observes exactly zero.
        #[cfg(feature = "alloc-count")]
        let allocs_per_req = {
            use std::sync::atomic::Ordering;
            let (trials, iters) = (5u32, 10u64);
            let mut best = u64::MAX;
            for _ in 0..trials {
                let before = alloc_count::ALLOCS.load(Ordering::Relaxed);
                for _ in 0..iters {
                    q.forward_prepacked(
                        backend.as_ref(),
                        &x,
                        None,
                        None,
                        &mut rng_b,
                        &packed,
                        &mut arena,
                        &mut logits,
                    );
                    std::hint::black_box(&logits);
                }
                best = best.min(alloc_count::ALLOCS.load(Ordering::Relaxed) - before);
            }
            Some(best as f64 / (iters * 64) as f64)
        };
        #[cfg(not(feature = "alloc-count"))]
        let allocs_per_req: Option<f64> = None;

        match l3m_prior_threads {
            Some(v) => std::env::set_var("XTPU_THREADS", v),
            None => std::env::remove_var("XTPU_THREADS"),
        }

        println!(
            "L3m zero-repack   : {percall_mmacs:>8.1} M MAC/s per-call → {prepacked_mmacs:>8.1} \
             M MAC/s prepacked (×{:.2}, {} layout, 1 thread)",
            prepacked_mmacs / percall_mmacs,
            active.name()
        );
        println!(
            "L3m steady serve  : {serve_baseline_infs:>8.1} inf/s per-call → {serve_infs:>8.1} \
             inf/s prepacked+arena (×{:.2}, batch 64, 1 thread, allocs/req {})",
            serve_infs / serve_baseline_infs,
            match allocs_per_req {
                Some(a) => format!("{a:.2}"),
                None => "unmeasured: build with --features alloc-count".to_string(),
            }
        );
        report.push(("l3m_percall_mmacs", Json::Num(percall_mmacs)));
        report.push(("l3m_prepacked_mmacs", Json::Num(prepacked_mmacs)));
        report.push(("l3m_prepacked_speedup", Json::Num(prepacked_mmacs / percall_mmacs)));
        report.push(("l3m_serve_baseline_infs", Json::Num(serve_baseline_infs)));
        report.push(("l3m_serve_infs", Json::Num(serve_infs)));
        report.push(("l3m_serve_speedup_vs_l3d", Json::Num(serve_infs / serve_baseline_infs)));
        report.push((
            "l3m_allocs_per_req",
            match allocs_per_req {
                Some(a) => Json::Num(a),
                None => Json::Null,
            },
        ));
    }

    if let Ok(path) = std::env::var("XTPU_BENCH_JSON") {
        let j = Json::obj(report);
        match xtpu::util::json::write_file(std::path::Path::new(&path), &j) {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => eprintln!("\nfailed to write {path}: {e:#}"),
        }
    }
}
