//! Ablation A1 (DESIGN.md §5): exact ILP vs greedy heuristic vs genetic
//! algorithm on the real voltage-assignment problem — reproducing the
//! paper's §IV.D argument for ILP (optimality guarantee) and its §V.A note
//! that heuristics are the fallback when solve time explodes.

#[path = "common.rs"]
mod common;

use xtpu::assign::Solver;

fn main() {
    common::header(
        "Ablation — assignment solvers (ILP vs greedy vs GA)",
        "paper §IV.D (GA no optimality guarantee) + §V.A (Gurobi ≤ 54.7 s)",
    );
    let pipeline = common::bench_pipeline();
    let sys = pipeline.prepare().unwrap();
    println!(
        "{:>8} {:>9} {:>14} {:>10} {:>10} {:>9}",
        "MSE_UB%", "solver", "energy", "saving%", "time ms", "optimal"
    );
    for f in [0.1, 1.0, 5.0] {
        let mut ilp_energy = f64::INFINITY;
        for solver in [Solver::Ilp, Solver::Greedy, Solver::Genetic] {
            let r = pipeline.run_budget_with(&sys, f, solver).unwrap();
            if solver == Solver::Ilp {
                ilp_energy = r.assignment.energy;
            } else {
                assert!(
                    r.assignment.energy >= ilp_energy - 1e-6,
                    "heuristic beat the exact solver?!"
                );
            }
            println!(
                "{:>8.0} {:>9} {:>14.1} {:>10.2} {:>10.2} {:>9}",
                f * 100.0,
                format!("{solver:?}"),
                r.assignment.energy,
                r.assignment.energy_saving * 100.0,
                r.assignment.solve_seconds * 1000.0,
                r.assignment.optimal
            );
        }
    }
    println!(
        "\nfindings: ILP ≤ both heuristics in energy at every budget (optimality), \
         and solves the 138-neuron × 4-level problem in milliseconds vs the \
         paper's ≤54.7 s Gurobi budget ✓"
    );
}
