//! Fig 10: the 16×16 matrix-multiplication verification benchmark —
//! simulated MSE vs the user-defined MSE-increment bound, plus power
//! saving, on the cycle-level X-TPU simulator (and cross-checked against
//! the AOT mm16 PJRT artifact when available).

#[path = "common.rs"]
mod common;

use xtpu::assign::{AssignmentProblem, Solver};
use xtpu::coordinator::measure_power_model;
use xtpu::runtime::{artifacts_dir, literal_f32, literal_i8, Runtime};
use xtpu::simulator::{ErrorInjector, XTpu};
use xtpu::util::rng::Xoshiro256pp;

fn main() {
    common::header(
        "Fig 10 — 16×16 MM: simulated MSE vs MSE_UB + power saving",
        "paper Fig 10: measured MSE tracks the bound (violations ≈ 0.3 %), saving 0–12 %",
    );
    let pipeline = common::bench_pipeline();
    let reg = pipeline.error_models().unwrap();
    let power = measure_power_model(0xF10);
    let k = 16usize;
    let n = 16usize;
    let m = 2000usize; // random input vectors per budget point

    // ES of an MM column = output scale per unit accumulator error = 1 (the
    // MM benchmark reads raw accumulators), so the constraint is
    // Σ k·var(e)_v ≤ MSE_UB directly.
    let es = vec![1.0f64; n];
    let fan_in = vec![k; n];

    // Budgets swept in accumulator-variance units.
    let budgets = [1e3, 1e4, 1e5, 5e5, 1e6, 5e6, 1e7];
    println!(
        "{:>12} {:>12} {:>12} {:>9} {:>8}",
        "MSE_UB", "pred MSE", "sim MSE", "saving%", "violated"
    );
    let mut violations = 0usize;
    for &budget in &budgets {
        let problem = AssignmentProblem::build(&es, &fan_in, &reg, &power, budget);
        let a = problem.solve(Solver::Ilp).unwrap();
        // Simulate on the cycle-level array.
        let mut tpu = XTpu::new(16, 16, reg.ladder.clone(), ErrorInjector::Statistical(reg.clone()))
            .with_power(power);
        let mut rng = Xoshiro256pp::seeded(0xF10A);
        let a_mat: Vec<i8> = (0..m * k).map(|_| rng.range_i64(-127, 127) as i8).collect();
        let w_mat: Vec<i8> = (0..k * n).map(|_| rng.range_i64(-127, 127) as i8).collect();
        let got = tpu.matmul(&a_mat, &w_mat, m, k, n, &a.level, &mut rng);
        let mut se = 0.0f64;
        for s in 0..m {
            for c in 0..n {
                let mut exact = 0i64;
                for r in 0..k {
                    exact += (a_mat[s * k + r] as i64) * (w_mat[r * n + c] as i64);
                }
                se += ((got[s * n + c] as i64 - exact) as f64).powi(2);
            }
        }
        let sim_mse = se / (m * n) as f64;
        let violated = sim_mse > budget * 1.05;
        violations += violated as usize;
        println!(
            "{budget:>12.2e} {:>12.3e} {sim_mse:>12.3e} {:>9.2} {:>8}",
            a.predicted_mse,
            tpu.stats.energy_saving() * 100.0,
            violated
        );
    }
    println!(
        "\nviolations: {violations}/{} budget points (paper: ≈0.3 % on average)",
        budgets.len()
    );

    // PJRT cross-check: one noisy mm16 through the AOT artifact.
    if artifacts_dir().join("mm16.hlo.txt").exists() {
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        rt.load("mm16").unwrap();
        let mut rng = Xoshiro256pp::seeded(3);
        let x: Vec<i8> = (0..256).map(|_| rng.range_i64(-127, 127) as i8).collect();
        let w: Vec<i8> = (0..256).map(|_| rng.range_i64(-127, 127) as i8).collect();
        let sd = reg.model(0).column_variance(16).sqrt();
        let noise: Vec<f32> = (0..256).map(|_| rng.gaussian(0.0, sd) as f32).collect();
        let out = rt
            .execute(
                "mm16",
                &[
                    literal_i8(&x, &[16, 16]).unwrap(),
                    literal_i8(&w, &[16, 16]).unwrap(),
                    literal_f32(&noise, &[16, 16]).unwrap(),
                ],
            )
            .unwrap();
        let got: Vec<i32> = out[0].to_vec().unwrap();
        let mut se = 0.0;
        for i in 0..16 {
            for j in 0..16 {
                let mut acc = 0i64;
                for p in 0..16 {
                    acc += (x[i * 16 + p] as i64) * (w[p * 16 + j] as i64);
                }
                se += ((got[i * 16 + j] as i64 - acc) as f64).powi(2);
            }
        }
        println!(
            "PJRT mm16 artifact @0.5 V-equivalent noise: MSE {:.3e} (model: {:.3e}) ✓",
            se / 256.0,
            reg.model(0).column_variance(16)
        );
    }
}
