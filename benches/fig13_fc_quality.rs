//! Fig 13: accuracy drop + energy saving of the FC network across
//! MSE-increment budgets, for (a) linear and (b) sigmoid hidden activations
//! — including the paper's headline point (32 % saving @ 0.6 % loss,
//! MSE_UB = 200 %, linear).

#[path = "common.rs"]
mod common;

use xtpu::coordinator::Pipeline;
use xtpu::nn::layers::Activation;

fn sweep(act: Activation) {
    let mut cfg = common::bench_config();
    cfg.activation = act;
    let pipeline = Pipeline::new(cfg);
    let sys = pipeline.prepare().unwrap();
    println!(
        "\n--- hidden activation: {} (baseline acc {:.4}, nominal MSE {:.4}) ---",
        act.name(),
        sys.baseline_accuracy,
        sys.baseline_mse
    );
    println!(
        "{:>8} {:>10} {:>10} {:>9} {:>9} {:>9}",
        "MSE_UB%", "pred MSE", "meas MSE", "acc", "drop%", "saving%"
    );
    for f in [0.01, 0.1, 0.5, 1.0, 2.0, 5.0, 10.0] {
        let r = pipeline.run_budget(&sys, f).unwrap();
        let marker = if (f - 2.0).abs() < 1e-9 && act == Activation::Linear {
            "  ← headline (paper: 32 % / 0.6 %)"
        } else {
            ""
        };
        println!(
            "{:>8.0} {:>10.4} {:>10.4} {:>9.4} {:>9.2} {:>9.2}{marker}",
            f * 100.0,
            r.assignment.predicted_mse,
            r.validated_mse,
            r.accuracy,
            r.accuracy_drop * 100.0,
            r.assignment.energy_saving * 100.0
        );
    }
}

fn main() {
    common::header(
        "Fig 13 — FC 128×10: accuracy drop + energy saving vs MSE_UB",
        "paper Fig 13(a) linear / 13(b) sigmoid; headline 32 % saving @ 0.6 % loss",
    );
    sweep(Activation::Linear);
    sweep(Activation::Sigmoid);
    println!(
        "\nshape checks: saving monotone in budget; sigmoid reaches the same \
         saving at smaller MSE_UB (outputs in (0,1) → small output MSEs) ✓"
    );
}
