//! Ablation A2 (DESIGN.md §5): VOS on the multiplier only vs the whole PE.
//!
//! The paper's §IV.A design choice: overscaling the entire PE lets errors
//! propagate through the chained partial-sum adders, correlating and
//! inflating column errors (and breaking the k·Var(e) model). We measure
//! exactly that on the gate-level PE datapath with chained psums.

#[path = "common.rs"]
mod common;

use xtpu::timing::circuits::pe_datapath;
use xtpu::timing::gate::{bits_to_i64, i64_to_bits};
use xtpu::timing::sta::{clock_period, ChipInstance};
use xtpu::timing::voltage::Technology;
use xtpu::timing::vos::VosSimulator;
use xtpu::util::rng::Xoshiro256pp;
use xtpu::util::stats::{pearson, variance};

/// Run a column of `k` chained PEs for `samples` vectors; returns
/// (column error variance, mean |lag-1 correlation| between per-PE error
/// contributions).
fn run_column(scope_whole_pe: bool, volts: f64, k: usize, samples: usize) -> (f64, f64) {
    let pe = pe_datapath(24);
    let tech = Technology::default();
    let chip = ChipInstance::ideal(&pe.netlist);
    let clock = clock_period(&pe.netlist, &chip, &tech);
    // Delay assignment: overscale either just the multiplier region or the
    // whole PE.
    let nominal = chip.delays_at(&pe.netlist, &tech, tech.v_nominal);
    let low = chip.delays_at(&pe.netlist, &tech, volts);
    let delays: Vec<f32> = (0..pe.netlist.num_gates())
        .map(|i| {
            if scope_whole_pe || pe.mult_gates.contains(&i) {
                low[i]
            } else {
                nominal[i]
            }
        })
        .collect();
    let mut sims: Vec<VosSimulator> =
        (0..k).map(|_| VosSimulator::new(&pe.netlist, delays.clone(), clock)).collect();
    let mut rng = Xoshiro256pp::seeded(0xAB2);
    let mut col_errs = Vec::with_capacity(samples);
    let mut pe_contrib: Vec<Vec<f64>> = vec![Vec::with_capacity(samples); k];
    let mask24 = (1i64 << 24) - 1;
    let signed24 = |v: i64| {
        let v = v & mask24;
        if v >= (1 << 23) {
            v - (1 << 24)
        } else {
            v
        }
    };
    for s in 0..=samples {
        let mut psum_captured = 0i64;
        let mut psum_exact = 0i64;
        let mut prev_err = 0i64;
        for (r, sim) in sims.iter_mut().enumerate() {
            let a = rng.range_i64(-128, 127);
            let w = rng.range_i64(-128, 127);
            // Chained: this PE's psum input is the previous PE's captured
            // output (the systolic column cascade).
            let packed = (a & 0xFF) | ((w & 0xFF) << 8) | ((psum_captured & mask24) << 16);
            sim.step(&i64_to_bits(packed, 40));
            let bools: Vec<bool> = sim.captured().iter().map(|&b| b != 0).collect();
            let captured = bits_to_i64(&bools);
            psum_exact = signed24(psum_exact + a * w);
            psum_captured = captured;
            if s > 0 {
                let err = signed24(captured - psum_exact) as f64;
                let delta = signed24(captured - psum_exact) - prev_err;
                pe_contrib[r].push(delta as f64);
                prev_err = signed24(captured - psum_exact);
                let _ = err;
            }
        }
        if s > 0 {
            col_errs.push(signed24(psum_captured - psum_exact) as f64);
        }
    }
    // Lag-1 correlation between successive PEs' incremental errors.
    let mut corr = 0.0f64;
    let mut pairs = 0.0f64;
    for r in 1..k {
        corr += pearson(&pe_contrib[r - 1], &pe_contrib[r]).abs();
        pairs += 1.0;
    }
    (variance(&col_errs), corr / pairs.max(1.0))
}

fn main() {
    common::header(
        "Ablation — VOS scope: multiplier-only vs whole-PE",
        "paper §IV.A: whole-PE VOS correlates/inflates errors through the psum chain",
    );
    let k = 8;
    let samples = 8000;
    println!("{:>8} {:>14} {:>14} {:>12} {:>12}", "V", "mult-only var", "whole-PE var", "blowup", "|corr|whole");
    for v in [0.6, 0.5] {
        let (var_mult, corr_mult) = run_column(false, v, k, samples);
        let (var_whole, corr_whole) = run_column(true, v, k, samples);
        println!(
            "{v:>8.2} {var_mult:>14.4e} {var_whole:>14.4e} {:>12.2} {:>12.3}",
            var_whole / var_mult.max(1e-9),
            corr_whole
        );
        let _ = corr_mult;
        assert!(
            var_whole > var_mult,
            "whole-PE VOS must inflate column error variance"
        );
    }
    println!(
        "\nfinding: overscaling the exact region too lets timing errors enter \
         the accumulate chain → variance blow-up, justifying the paper's \
         multiplier-only approximate region ✓"
    );
}
