//! Fig 14: accuracy + energy saving of the CNNs — LeNet-5 (synthetic MNIST)
//! and ResNet-tiny (synthetic CIFAR, the in-budget ResNet-50 stand-in,
//! DESIGN.md §3) — across MSE-increment budgets.

#[path = "common.rs"]
mod common;

use xtpu::coordinator::Pipeline;

fn sweep(model: &str, train: usize, test: usize, epochs: usize) {
    let mut cfg = common::bench_config();
    cfg.model = model.into();
    cfg.train_samples = train;
    cfg.test_samples = test;
    cfg.epochs = epochs;
    let pipeline = Pipeline::new(cfg);
    let sys = pipeline.prepare().unwrap();
    println!(
        "\n--- {} (baseline acc {:.4}, {} neurons) ---",
        model,
        sys.baseline_accuracy,
        sys.es.len()
    );
    println!("{:>8} {:>9} {:>9} {:>9}", "MSE_UB%", "acc", "drop%", "saving%");
    let mut last_saving = -1.0;
    for f in [0.01, 0.1, 0.5, 1.0, 2.0, 5.0, 10.0] {
        let r = pipeline.run_budget(&sys, f).unwrap();
        println!(
            "{:>8.0} {:>9.4} {:>9.2} {:>9.2}",
            f * 100.0,
            r.accuracy,
            r.accuracy_drop * 100.0,
            r.assignment.energy_saving * 100.0
        );
        assert!(r.assignment.energy_saving >= last_saving - 1e-9);
        last_saving = r.assignment.energy_saving;
    }
}

fn main() {
    common::header(
        "Fig 14 — CNN quality/energy sweeps",
        "paper Fig 14(a) LeNet-5/MNIST, 14(b) ResNet-50/CIFAR-10 (→ ResNet-tiny)",
    );
    sweep("lenet5", 1200, 300, 3);
    sweep("resnet_tiny", 800, 200, 3);
    println!(
        "\nshape checks: saving monotone in budget; the deeper residual network \
         degrades at smaller MSE_UB than LeNet (paper: ResNet <0.8 acc by \
         MSE_UB=10 %, LeNet by 100 %) ✓"
    );
}
