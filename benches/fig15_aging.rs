//! Fig 15: aging — (a) ΔVth per voltage after 10 years, (b) path-delay
//! degradation, (c) error variance under the aged/relaxed clock, plus the
//! mixed-voltage lifetime improvement.

#[path = "common.rs"]
mod common;

use xtpu::aging::{AgedScenario, BtiModel, Device};
use xtpu::errormodel::{characterize_voltage, CharacterizeOptions};
use xtpu::timing::baugh_wooley_8x8;
use xtpu::timing::sta::{clock_period, ChipInstance};
use xtpu::timing::voltage::Technology;
use xtpu::util::rng::Xoshiro256pp;

fn main() {
    let bti = BtiModel::default();
    let tech = Technology::default();
    let years = 10.0;

    common::header(
        "Fig 15a — ΔVth after 10 years (calibrated to the paper's anchors)",
        "paper: 23.7 % PMOS / 19 % NMOS at 0.8 V; ≈0.2 % at 0.5 V",
    );
    println!("{:>6} {:>10} {:>10}", "V", "PMOS %", "NMOS %");
    for v in [0.5, 0.6, 0.7, 0.8] {
        println!(
            "{v:>6.2} {:>10.3} {:>10.3}",
            bti.delta_vth_percent(Device::Pmos, &tech, v, years),
            bti.delta_vth_percent(Device::Nmos, &tech, v, years)
        );
    }

    common::header("Fig 15b — aged path-delay factor", "paper Fig 15(b)");
    for v in [0.5, 0.6, 0.7, 0.8] {
        println!("{v:>6.2} {:>10.4}", bti.delay_degradation(&tech, v, years));
    }

    common::header(
        "Fig 15c — error variance fresh vs aged (clock relaxed to aged nominal)",
        "paper Fig 15(c) pointer ⑨: lower VOS error severity after re-clocking",
    );
    let netlist = baugh_wooley_8x8("f15_pe");
    let mut rng = Xoshiro256pp::seeded(0xF15);
    let chip = ChipInstance::sample(&netlist, &tech, &mut rng);
    let scenario = AgedScenario::worst_case(&bti, &tech, years);
    let fresh_clock = clock_period(&netlist, &chip, &tech);
    let aged_clock = fresh_clock * scenario.clock_stretch as f32;
    let samples = if std::env::var("XTPU_BENCH_FULL").ok().as_deref() == Some("1") {
        1_000_000
    } else {
        150_000
    };
    println!("{:>6} {:>14} {:>14} {:>8}", "V", "fresh var", "aged var", "ratio");
    for v in [0.5, 0.6, 0.7] {
        let fresh = characterize_voltage(
            &netlist,
            &chip,
            &tech,
            v,
            &CharacterizeOptions { samples, seed: 5, ..Default::default() },
        );
        let aged = characterize_voltage(
            &netlist,
            &chip,
            &tech,
            v,
            &CharacterizeOptions {
                samples,
                seed: 5,
                delta_vth: scenario.delta_vth,
                clock_override: Some(aged_clock),
            },
        );
        println!(
            "{v:>6.2} {:>14.4e} {:>14.4e} {:>8.3}",
            fresh.variance,
            aged.variance,
            aged.variance / fresh.variance.max(1e-12)
        );
    }

    common::header("Lifetime — mixed-voltage operation", "paper §V.C: +12 %");
    let imp = bti.lifetime_improvement(&tech, &[0.5, 0.6, 0.7, 0.8], &[0.25; 4]);
    println!("uniform mix vs always-nominal: +{:.1} % (paper: +12 %)", imp * 100.0);
}
