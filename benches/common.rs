//! Shared helpers for the figure/table benches (harness = false: each bench
//! binary regenerates one table or figure of the paper as text output and
//! exits; `cargo bench` runs them all).

#![allow(dead_code)]

use xtpu::config::ExperimentConfig;
use xtpu::coordinator::Pipeline;

/// Standard bench-scale experiment config: large enough for stable
/// statistics, small enough to keep `cargo bench` minutes-scale.
/// `XTPU_BENCH_FULL=1` switches to paper-scale characterization.
pub fn bench_config() -> ExperimentConfig {
    let full = std::env::var("XTPU_BENCH_FULL").ok().as_deref() == Some("1");
    ExperimentConfig {
        train_samples: if full { 4000 } else { 1500 },
        test_samples: if full { 1000 } else { 400 },
        epochs: if full { 6 } else { 3 },
        characterize_samples: if full { 1_000_000 } else { 150_000 },
        validation_runs: if full { 3 } else { 1 },
        ..Default::default()
    }
}

pub fn bench_pipeline() -> Pipeline {
    Pipeline::new(bench_config())
}

pub fn header(title: &str, paper_ref: &str) {
    println!("\n================================================================");
    println!("{title}");
    println!("reproduces: {paper_ref}");
    println!("================================================================");
}
