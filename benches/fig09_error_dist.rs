//! Fig 9a: error distribution of a single PE at 0.5/0.6/0.7 V (histograms +
//! normality diagnostics), and Fig 9b: column variance vs column size.

#[path = "common.rs"]
mod common;

use xtpu::errormodel::{characterize_with_histogram, simulate_column_variance};
use xtpu::timing::baugh_wooley_8x8;
use xtpu::timing::sta::ChipInstance;
use xtpu::timing::voltage::Technology;
use xtpu::util::rng::Xoshiro256pp;
use xtpu::util::stats::Histogram;

fn main() {
    let tech = Technology::default();
    let netlist = baugh_wooley_8x8("fig9_pe");
    let mut rng = Xoshiro256pp::seeded(0xF9);
    let chip = ChipInstance::sample(&netlist, &tech, &mut rng);
    let full = std::env::var("XTPU_BENCH_FULL").ok().as_deref() == Some("1");
    let samples: u64 = if full { 1_000_000 } else { 200_000 };

    common::header(
        "Fig 9a — single-PE error distribution per voltage",
        "paper Fig 9(a): ≈ zero-mean, ≈ normal, variance ↑ as V ↓",
    );
    for v in [0.5, 0.6, 0.7] {
        let mut hist = Histogram::new(-24000.0, 24000.0, 48);
        let m = characterize_with_histogram(&netlist, &chip, &tech, v, samples, 0xF9A, &mut hist);
        println!(
            "\nV={v:.1}  var {:.4e}  mean {:+.2}  skew {:+.3}  kurt {:+.3}  err-rate {:.4}",
            m.variance, m.mean, m.skewness, m.kurtosis_excess, m.error_rate
        );
        println!("  [{}]", hist.sparkline());
    }

    common::header(
        "Fig 9b / Table 2 cross-check — column variance vs k (direct gate-level sim)",
        "paper Fig 9(b): Var(e_c) ≈ k · Var(e), eq. 13",
    );
    println!("{:>6} {:>5} {:>14} {:>14} {:>7}", "V", "k", "k·Var(e)", "direct sim", "ratio");
    for v in [0.5, 0.6] {
        let mut h = Histogram::new(-1.0, 1.0, 2);
        let m = characterize_with_histogram(&netlist, &chip, &tech, v, samples, 0xF9A, &mut h);
        for k in [2usize, 4, 8] {
            let direct =
                simulate_column_variance(&netlist, &chip, &tech, v, k, samples / 8, 0xF9B);
            let composed = m.column_variance(k);
            println!(
                "{v:>6.1} {k:>5} {composed:>14.4e} {direct:>14.4e} {:>7.2}",
                direct / composed.max(1e-12)
            );
        }
    }
}
