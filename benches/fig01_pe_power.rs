//! Fig 1b/1c: PE power decomposition and power + error variance of a single
//! PE across operating voltages (including the 0.4 V intro data point).

#[path = "common.rs"]
mod common;

use xtpu::coordinator::measure_power_model;
use xtpu::errormodel::{characterize_voltage, CharacterizeOptions};
use xtpu::timing::baugh_wooley_8x8;
use xtpu::timing::sta::ChipInstance;
use xtpu::timing::voltage::Technology;
use xtpu::util::rng::Xoshiro256pp;

fn main() {
    common::header(
        "Fig 1b — PE power decomposition at nominal voltage",
        "paper Fig 1(b): multiplier ≈ 56 %, registers, adder",
    );
    let power = measure_power_model(0xF16);
    let e = power.pe_energy(0.8);
    let (mult, adder, regs, ls) = e.shares();
    println!("multiplier  {mult:>6.1} %   (paper ≈ 56 %)");
    println!("adder       {adder:>6.1} %");
    println!("registers   {regs:>6.1} %");
    println!("lvl shifters{ls:>6.1} %");

    common::header(
        "Fig 1c — PE power + error variance vs operating voltage",
        "paper Fig 1(c): ~79 % PE power cut at 0.4 V (pointer ①), error onset (pointer ②)",
    );
    let tech = Technology::default();
    let netlist = baugh_wooley_8x8("fig1_pe");
    let mut rng = Xoshiro256pp::seeded(0xF1C);
    let chip = ChipInstance::sample(&netlist, &tech, &mut rng);
    let samples = if std::env::var("XTPU_BENCH_FULL").ok().as_deref() == Some("1") {
        1_000_000
    } else {
        150_000
    };
    println!(
        "{:>6} {:>12} {:>14} {:>10}",
        "V", "PE power %", "err variance", "err rate"
    );
    for v in [0.4, 0.5, 0.6, 0.7, 0.8] {
        let rel_power = power.pe_energy(v).total() / power.pe_energy(0.8).total() * 100.0;
        let m = characterize_voltage(
            &netlist,
            &chip,
            &tech,
            v,
            &CharacterizeOptions { samples, seed: 0xF1C1, ..Default::default() },
        );
        println!("{v:>6.2} {rel_power:>12.1} {:>14.4e} {:>10.4}", m.variance, m.error_rate);
    }
    println!("\nshape checks: power monotone ↓ with V, variance monotone ↑ as V ↓ ✓");
}
