//! Table 3: computational cost of the activation functions — average
//! processing time of ReLU / TanH / Sigmoid over identical workloads
//! (paper: ReLU 1.12 s, TanH 1.50 s, Sigmoid 1.48 s on their setup;
//! the *ordering* is the reproducible claim).

#[path = "common.rs"]
mod common;

use xtpu::nn::layers::Activation;

fn main() {
    common::header(
        "Table 3 — activation-function processing time",
        "paper Table 3: ReLU O(1) fastest; TanH/Sigmoid ≈ O(n^2.085) slower",
    );
    let n = 4_000_000usize;
    let data: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.001).sin() * 4.0).collect();
    let reps = 25;
    let mut results = Vec::new();
    for act in [Activation::Relu, Activation::Tanh, Activation::Sigmoid, Activation::Linear] {
        // Warm-up.
        let mut sink = 0f32;
        for &v in data.iter().take(1000) {
            sink += act.apply(v);
        }
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            for &v in &data {
                sink += act.apply(v);
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(sink);
        results.push((act, dt));
        println!(
            "{:>8}: {:.3} s for {} × {} elements ({:.1} M elem/s)",
            act.name(),
            dt,
            reps,
            n,
            (reps * n) as f64 / dt / 1e6
        );
    }
    let relu = results.iter().find(|(a, _)| *a == Activation::Relu).unwrap().1;
    let tanh = results.iter().find(|(a, _)| *a == Activation::Tanh).unwrap().1;
    let sigmoid = results.iter().find(|(a, _)| *a == Activation::Sigmoid).unwrap().1;
    println!(
        "\nratios vs ReLU: TanH ×{:.2}, Sigmoid ×{:.2} (paper: ×1.34, ×1.32)",
        tanh / relu,
        sigmoid / relu
    );
    assert!(tanh > relu && sigmoid > relu, "transcendental activations must cost more");
}
