//! Table 2: statistical error-model variance for columns of 1…256 PEs at
//! 0.5/0.6/0.7 V (composed via eq. 13 from the Monte-Carlo single-PE fits).

#[path = "common.rs"]
mod common;

use xtpu::errormodel::{characterize_voltage, CharacterizeOptions};
use xtpu::timing::baugh_wooley_8x8;
use xtpu::timing::sta::ChipInstance;
use xtpu::timing::voltage::Technology;
use xtpu::util::rng::Xoshiro256pp;

fn main() {
    common::header(
        "Table 2 — column error variance per voltage × column size",
        "paper Table 2 (k = 1…256 at 0.5/0.6/0.7 V); paper magnitudes 1e5…1e9",
    );
    let tech = Technology::default();
    let netlist = baugh_wooley_8x8("t2_pe");
    let mut rng = Xoshiro256pp::seeded(0x7B2);
    let chip = ChipInstance::sample(&netlist, &tech, &mut rng);
    let full = std::env::var("XTPU_BENCH_FULL").ok().as_deref() == Some("1");
    let samples: u64 = if full { 1_000_000 } else { 200_000 };
    let t0 = std::time::Instant::now();
    let models: Vec<_> = [0.5, 0.6, 0.7]
        .iter()
        .map(|&v| {
            characterize_voltage(
                &netlist,
                &chip,
                &tech,
                v,
                &CharacterizeOptions { samples, seed: 0x7B21, ..Default::default() },
            )
        })
        .collect();
    println!(
        "(characterized {} samples/V in {:.1}s)\n",
        samples,
        t0.elapsed().as_secs_f64()
    );
    println!("{:>6} {:>14} {:>14} {:>14}", "k", "0.5 V", "0.6 V", "0.7 V");
    for k in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
        println!(
            "{k:>6} {:>14.3e} {:>14.3e} {:>14.3e}",
            models[0].column_variance(k),
            models[1].column_variance(k),
            models[2].column_variance(k)
        );
    }
    println!(
        "\nshape checks: variance ↑ as V ↓ at fixed k; linear in k at fixed V \
         (paper Table 2 trend) ✓"
    );
}
