//! Fig 11: error sensitivities of all 138 neurons of the FC 128×10 network
//! — hidden-layer ES low, output-layer ES ≈ the maximum.

#[path = "common.rs"]
mod common;

fn main() {
    common::header(
        "Fig 11 — per-neuron error sensitivity, FC 128×10",
        "paper Fig 11: hidden ES < 0.4 (normalized), output ES ≈ 1",
    );
    let pipeline = common::bench_pipeline();
    let sys = pipeline.prepare().unwrap();
    // Normalize like the paper: max ES = 1.
    let max = sys.es.iter().cloned().fold(0.0f64, f64::max).max(1e-300);
    let norm: Vec<f64> = sys.es.iter().map(|e| e / max).collect();
    println!("neuron   ES(norm)   (first 16 hidden, then the 10 output neurons)");
    for i in (0..16).chain(128..138) {
        let bar = "#".repeat((norm[i] * 40.0) as usize);
        let tag = if i < 128 { "hidden" } else { "OUTPUT" };
        println!("{i:>6} {tag} {:>8.4} {bar}", norm[i]);
    }
    let hidden_mean = norm[..128].iter().sum::<f64>() / 128.0;
    let hidden_max = norm[..128].iter().cloned().fold(0.0f64, f64::max);
    let out_mean = norm[128..].iter().sum::<f64>() / 10.0;
    println!("\nhidden: mean {hidden_mean:.4}, max {hidden_max:.4}");
    println!("output: mean {out_mean:.4}");
    println!(
        "shape check: hidden ≪ output ({}) — the VOS candidates are the hidden \
         layer, as the paper argues ✓",
        if hidden_max < 0.9 * out_mean { "holds" } else { "FAILS" }
    );
}
