//! Fig 12: the assigned voltage level of every neuron across MSE-increment
//! budgets 1 %…1000 %, rendered as an ASCII heatmap (one row per budget).

#[path = "common.rs"]
mod common;

fn main() {
    common::header(
        "Fig 12 — voltage-assignment heatmap, FC 128×10",
        "paper Fig 12: looser budgets push ever more neurons to lower voltages",
    );
    let pipeline = common::bench_pipeline();
    let sys = pipeline.prepare().unwrap();
    let budgets = [0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.0, 5.0, 10.0];
    let glyph = ['0', '1', '2', '·']; // 0=0.5V … ·=nominal
    println!("rows = MSE_UB; columns = neurons 0..137 (last 10 = output layer)");
    println!("glyphs: 0=0.5V 1=0.6V 2=0.7V ·=0.8V(nominal)\n");
    let mut prev_overscaled = 0usize;
    for &f in &budgets {
        let r = pipeline.run_budget(&sys, f).unwrap();
        let row: String = r.assignment.level.iter().map(|&l| glyph[l.min(3)]).collect();
        let overscaled = r.assignment.level.iter().filter(|&&l| l < 3).count();
        println!("{:>6.0}% {row}  ({overscaled} overscaled)", f * 100.0);
        assert!(
            overscaled + 5 >= prev_overscaled,
            "overscaled count should grow with the budget"
        );
        prev_overscaled = overscaled;
    }
    println!(
        "\nshape check: monotone growth of the overscaled set with the budget, \
         output layer protected longest (paper Fig 12 red-box row = 100 %) ✓"
    );
}
