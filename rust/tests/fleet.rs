//! Fleet-level integration tests: real solved plans served by a simulated
//! multi-device fleet, proving the paper's lifetime claim operationally —
//! aging-aware wear-leveled routing strictly raises the minimum projected
//! device lifetime over round-robin on the *same trace at identical served
//! quality* — and that `xtpu fleet`-style telemetry round-trips through
//! `util::json`.

use std::sync::Arc;

use xtpu::config::ExperimentConfig;
use xtpu::fleet::{
    policy_from_name, AdaptiveContext, FleetConfig, LeastLoaded, ReplanPolicy, RoundRobin,
    Router, Trace, WearLeveling,
};
use xtpu::plan::{Planner, VoltagePlan};
use xtpu::server::Engine;
use xtpu::util::json::{read_file, write_file, Json};

fn smoke_cfg() -> ExperimentConfig {
    ExperimentConfig {
        seed: 0xF1EE7,
        artifacts_dir: std::env::temp_dir()
            .join(format!("xtpu_fleet_it_{}", std::process::id()))
            .to_string_lossy()
            .into_owned(),
        ..ExperimentConfig::smoke()
    }
}

/// Solve two real plans (all-nominal "exact" + an aggressive-VOS budget)
/// and build the pooled engine a fleet serves them through.
fn solved_fixture(devices: usize) -> (Arc<Engine>, Vec<VoltagePlan>, Planner) {
    let mut planner = Planner::new(smoke_cfg());
    let plans = planner.solve_many(&[0.0, 10.0]).unwrap();
    let registry = planner.registry().unwrap().clone();
    let trained = planner.trained().unwrap();
    let quantized = trained.quantized.clone();
    let input_dim = trained.model.input.numel();
    let pool = xtpu::plan::make_backend_pool(&planner.cfg, &registry, devices).unwrap();
    let engine = Engine::from_plans(quantized, &registry, &plans, input_dim)
        .unwrap()
        .with_backend_pool(pool);
    (Arc::new(engine), plans, planner)
}

/// A heterogeneous fleet: devices deployed in waves, the oldest already
/// well into its guard band.
fn aged_fleet_cfg(devices: usize) -> FleetConfig {
    FleetConfig {
        devices,
        service_seconds: 1.0e-3,
        wear_accel: 2.0e6,
        // Device 0 has burned ~3/4 of its guard band already; the wave
        // spread is large relative to the stress one trace adds, so the
        // min-lifetime comparison is insensitive to trace randomness.
        initial_age_years: vec![0.022, 0.009, 0.004, 0.0],
        initial_age_duty: 1.0,
        ..FleetConfig::default()
    }
}

#[test]
fn wear_leveling_extends_min_lifetime_vs_round_robin() {
    let devices = 4;
    let (engine, plans, _planner) = solved_fixture(devices);
    // One trace, replayed bit-identically under both policies.
    let trace = Trace::poisson(400.0, 2.0, &[1.0, 1.0], 0xDECAF);

    let mut rr =
        Router::new(engine.clone(), &plans, Box::<RoundRobin>::default(), aged_fleet_cfg(devices))
            .unwrap();
    let t_rr = rr.run(&trace);

    let mut wl = Router::new(
        engine,
        &plans,
        Box::new(WearLeveling::new(0.05, 16)),
        aged_fleet_cfg(devices),
    )
    .unwrap();
    let t_wl = wl.run(&trace);

    // Identical served quality: same trace ⇒ same per-class counts, same
    // total requests, and therefore the same energy books — the policies
    // differ only in *which device* absorbs each request.
    assert_eq!(t_rr.requests, t_wl.requests);
    assert_eq!(t_rr.per_class, t_wl.per_class);
    assert!(t_rr.per_class.iter().all(|&c| c > 0), "both classes exercised: {:?}", t_rr.per_class);
    // Same request multiset ⇒ same energy, up to summation order.
    xtpu::util::checks::assert_close(t_rr.energy_units, t_wl.energy_units, 1e-9);
    xtpu::util::checks::assert_close(
        t_rr.energy_saving_vs_nominal,
        t_wl.energy_saving_vs_nominal,
        1e-9,
    );
    assert!(t_rr.energy_saving_vs_nominal > 0.0, "the VOS plan must actually save energy");

    // The headline: wear leveling strictly extends the minimum projected
    // device lifetime, with a real margin, at identical served quality.
    assert!(
        t_wl.min_lifetime_years > t_rr.min_lifetime_years * 1.1,
        "wear leveling min lifetime {:.4} y must beat round robin {:.4} y by >10%",
        t_wl.min_lifetime_years,
        t_rr.min_lifetime_years
    );

    // Mechanism check: under round robin the pre-aged device keeps
    // serving nominal-voltage traffic; under wear leveling it serves
    // (almost) none of it, so its threshold drift advances less.
    let rr_d0 = &t_rr.devices[0];
    let wl_d0 = &t_wl.devices[0];
    assert!(rr_d0.per_class[0] > 0);
    assert!(
        wl_d0.per_class[0] < rr_d0.per_class[0] / 4,
        "worn device still absorbs nominal traffic under wear leveling: {} vs {}",
        wl_d0.per_class[0],
        rr_d0.per_class[0]
    );
    assert!(wl_d0.delta_vth <= rr_d0.delta_vth);
    assert!(wl_d0.delay_margin >= rr_d0.delay_margin);
}

#[test]
fn telemetry_report_roundtrips_through_util_json() {
    let devices = 2;
    let (engine, plans, mut planner) = solved_fixture(devices);
    let cfg = FleetConfig { devices, ..aged_fleet_cfg(devices) };
    let mut fleet =
        Router::new(engine, &plans, policy_from_name("wear-level").unwrap(), cfg).unwrap();
    let test = planner.trained().unwrap().test.clone();
    let trace = Trace::poisson(150.0, 1.0, &[1.0, 1.0], 7);
    let report = fleet.run_with_inference(&trace, &test, 3);
    assert_eq!(report.requests as usize, trace.request_count());
    let acc = report.accuracy.expect("inference run reports accuracy");
    assert!((0.0..=1.0).contains(&acc));

    // The exact round-trip the CLI performs: to_json → write_file →
    // read_file must reproduce the value bit-for-bit (Json is PartialEq;
    // util::json serializes deterministically).
    let j = report.to_json();
    let dir = std::env::temp_dir().join(format!("xtpu_fleet_report_{}", std::process::id()));
    let path = dir.join("fleet_report.json");
    write_file(&path, &j).unwrap();
    let back = read_file(&path).unwrap();
    assert_eq!(j, back, "report must round-trip losslessly through util::json");
    std::fs::remove_dir_all(&dir).ok();

    // Lifetime and energy keys the fleet-smoke CI job requires.
    for key in [
        "min_lifetime_years",
        "mean_lifetime_years",
        "energy_saving_vs_nominal",
        "energy_joules",
        "latency_p50_ms",
        "latency_p99_ms",
    ] {
        assert!(back.get(key).unwrap().as_f64().unwrap().is_finite(), "key {key}");
    }
    let devs = back.get("devices").unwrap().as_arr().unwrap();
    assert_eq!(devs.len(), devices);
    for d in devs {
        assert!(d.get("projected_lifetime_years").unwrap().as_f64().unwrap() >= 0.0);
        assert!(d.get("energy_joules").unwrap().as_f64().unwrap() >= 0.0);
        let duty = d.get("duty_seconds").unwrap().as_f64_vec().unwrap();
        assert_eq!(duty.len(), plans[0].volts.len());
    }
    // Request conservation device-side too.
    let sum: u64 = devs.iter().map(|d| d.get("requests").unwrap().as_u64().unwrap()).sum();
    assert_eq!(sum, report.requests);
}

#[test]
fn closed_loop_trace_and_least_loaded_behave() {
    let devices = 3;
    let (engine, plans, _planner) = solved_fixture(devices);
    let cfg = FleetConfig {
        devices,
        service_seconds: 2.0e-3,
        ..FleetConfig::default()
    };
    let mut fleet =
        Router::new(engine, &plans, Box::<LeastLoaded>::default(), cfg).unwrap();
    let trace = Trace::closed(6, 40, 0.001, &[2.0, 1.0], 0xC105ED);
    let t = fleet.run(&trace);
    assert_eq!(t.requests, 240);
    // Closed loop self-throttles: at most `clients` requests in flight, so
    // latency is bounded by population × service time (12 ms), which the
    // power-of-two histogram reports as its 16.383 ms bucket bound.
    assert!(t.latency_p99_ms <= 16.384, "p99 {} ms", t.latency_p99_ms);
    // Least-loaded keeps the fleet reasonably balanced under a symmetric
    // closed loop.
    let counts: Vec<u64> = t.devices.iter().map(|d| d.requests).collect();
    let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
    assert!(max - min <= 120, "pathological imbalance: {counts:?}");
    // Class mix follows the 2:1 weights (same seeded sequence every run).
    assert!(t.per_class[0] > t.per_class[1], "mix weights ignored: {:?}", t.per_class);
    // JSON emission parses on this path too.
    assert!(Json::parse(&t.to_json().to_string()).is_ok());
}

/// The closed loop, end to end (the PR's acceptance test): on an
/// accelerated wear clock, a fleet with threshold re-planning keeps its
/// served MSE inside the user quality budget for the whole run, while the
/// identical fleet without re-planning drifts out of it — and the
/// re-planned fleet still reports positive energy saving vs all-nominal
/// serving.
///
/// Quality is the analytic served-MSE-to-budget ratio the fleet samples
/// during the run (`Σ ES²·k·var_drift(level)` over the device's deployed
/// plan, re-priced under its accrued ΔVth — eq. 29 at age): exact,
/// deterministic, and the same observable `resolve_plan_from` solves
/// against.
#[test]
fn threshold_replanning_keeps_served_mse_in_budget_while_static_fleet_exits() {
    let devices = 2;
    // Budget 100% of nominal MSE: tight enough that the solver is
    // budget-constrained (high utilization) with contributions from the
    // steep 0.6/0.7 V levels, which is exactly where BTI drift bites.
    let mut planner = Planner::new(smoke_cfg());
    let plans = planner.solve_many(&[0.0, 1.0]).unwrap();
    let registry = planner.registry().unwrap().clone();
    let power = *planner.power();
    let trained = planner.trained().unwrap();
    let quantized = trained.quantized.clone();
    let input_dim = trained.model.input.numel();
    let budgeted = &plans[1];
    let util = budgeted.predicted_mse / budgeted.budget_abs;
    assert!(
        util > 0.8,
        "fixture assumption broken: the {} plan only fills {:.0}% of its budget — \
         pick a tighter budget fraction so drift can push it out",
        budgeted.name,
        util * 100.0
    );

    let fleet_cfg = FleetConfig {
        devices,
        service_seconds: 1.0e-3,
        // ≳0.07 deployed years per device over the 2 s trace: enough
        // nominal-voltage stress to consume the whole clock guard band.
        wear_accel: 4.0e6,
        ..FleetConfig::default()
    };
    // Identical trace for both arms; 50/50 exact (the stressor) and
    // budgeted (the quality observable) traffic.
    let trace = Trace::poisson(600.0, 2.0, &[1.0, 1.0], 0xADA97);

    let build = |replan: ReplanPolicy| -> Router {
        let pool =
            xtpu::plan::make_backend_pool(&planner.cfg, &registry, devices).unwrap();
        let engine = Arc::new(
            Engine::from_plans(quantized.clone(), &registry, &plans, input_dim)
                .unwrap()
                .with_backend_pool(pool),
        );
        Router::with_adaptation(
            engine,
            &plans,
            Box::<RoundRobin>::default(),
            fleet_cfg.clone(),
            AdaptiveContext::new(registry.clone(), power, replan),
        )
        .unwrap()
    };

    let mut adaptive = build(ReplanPolicy::Threshold { guard_band: 0.05 });
    let t_adapt = adaptive.run(&trace);
    let mut frozen = build(ReplanPolicy::Never);
    let t_never = frozen.run(&trace);

    // Same trace, same routing: both arms served the same request multiset.
    assert_eq!(t_adapt.requests, t_never.requests);
    assert_eq!(t_adapt.per_class, t_never.per_class);

    // The static fleet measurably exits the user budget as it ages…
    assert!(
        t_never.max_mse_ratio > 1.02,
        "no-replan fleet stayed in budget (max ratio {:.3}) — wear clock too slow \
         or boot utilization {util:.2} too low",
        t_never.max_mse_ratio
    );
    assert!(t_never.replan_events.is_empty());
    // …while the closed loop never leaves it (re-plans solve to 90% of
    // budget, and the threshold trigger bounds inter-replan drift).
    assert!(
        t_adapt.max_mse_ratio <= 1.0 + 1e-6,
        "re-planning fleet left the quality budget: max ratio {:.4}",
        t_adapt.max_mse_ratio
    );
    assert!(
        t_adapt.replan_events.len() >= 2,
        "threshold policy never fired ({} events)",
        t_adapt.replan_events.len()
    );
    // Re-plan provenance: generations advance 1, 2, … per device and land
    // in the device telemetry; solve/swap latency is recorded.
    for d in &t_adapt.devices {
        let evs: Vec<_> =
            t_adapt.replan_events.iter().filter(|e| e.device == d.id).collect();
        assert_eq!(d.generation, evs.len() as u64);
        for (i, e) in evs.iter().enumerate() {
            assert_eq!(e.generation, i as u64 + 1);
            assert!(e.delta_vth > 0.0 && e.solve_ms >= 0.0);
        }
    }
    assert!(t_never.devices.iter().all(|d| d.generation == 0));

    // The headline economics: adapting costs some saving (re-plans move
    // neurons up-ladder) but the fleet still beats all-nominal serving.
    assert!(
        t_adapt.energy_saving_vs_nominal > 0.0,
        "re-planned fleet lost its energy saving ({:.4})",
        t_adapt.energy_saving_vs_nominal
    );
    assert!(
        t_adapt.energy_saving_vs_nominal <= t_never.energy_saving_vs_nominal + 1e-9,
        "quality restoration cannot be free: adaptive saving {:.4} vs static {:.4}",
        t_adapt.energy_saving_vs_nominal,
        t_never.energy_saving_vs_nominal
    );

    // The full adaptive report round-trips through util::json with the
    // closed-loop keys the CI adaptive-smoke job asserts on.
    let j = t_adapt.to_json();
    let back = Json::parse(&j.to_string()).unwrap();
    assert_eq!(back.get("replan_policy").unwrap().as_str().unwrap(), "threshold");
    assert_eq!(
        back.get("replans").unwrap().as_u64().unwrap() as usize,
        t_adapt.replan_events.len()
    );
    assert!(!back.get("quality_curve").unwrap().as_arr().unwrap().is_empty());
    assert!(back.get("max_mse_ratio").unwrap().as_f64().unwrap() <= 1.0 + 1e-6);
}
