//! Serving-stack integration tests: the evented (reactor) frontend, SLO
//! admission control, and live shard routing — exercised over real TCP
//! through the public API.
//!
//! The load-bearing property is **frontend equivalence**: at a fixed seed
//! the evented frontend must produce byte-identical reply lines to the
//! threaded frontend, so operators can switch `--frontend` without any
//! numerical or protocol drift. On top of that: hostile-client bounds
//! (malformed lines, slow-loris), shed accounting that exactly conserves
//! requests, and round-robin shard placement visible in `per_shard`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use xtpu::nn::data::{synth_mnist, Dataset};
use xtpu::nn::layers::Activation;
use xtpu::nn::model::fc_mnist;
use xtpu::nn::quant::{NoiseSpec, QuantizedModel};
use xtpu::nn::train::{train, TrainConfig};
use xtpu::server::{
    BatchPolicy, Client, Engine, FrontendMode, FrontendOptions, QualityLevel, Server,
};
use xtpu::util::json::Json;
use xtpu::util::rng::Xoshiro256pp;

/// A small deterministic quantized model: fixed seed end to end, so two
/// calls produce bit-identical models (weights, quantization).
fn build_quantized() -> (QuantizedModel, Dataset) {
    let mut rng = Xoshiro256pp::seeded(1);
    let mut model = fc_mnist(Activation::Relu, &mut rng);
    let train_set = synth_mnist(200, 5);
    train(&mut model, &train_set, &TrainConfig { epochs: 1, ..Default::default() });
    let test = synth_mnist(20, 6);
    let calib = test.batch(&(0..16).collect::<Vec<_>>()).0;
    (QuantizedModel::quantize(&model, &calib), test)
}

/// The baseline level set: exact + an eco level noisy on the first 128
/// neurons.
fn levels_v1(n: usize) -> Vec<QualityLevel> {
    let mut noisy = NoiseSpec::silent(n);
    for s in noisy.std.iter_mut().take(128) {
        *s = 2000.0;
    }
    vec![
        QualityLevel {
            name: "exact".into(),
            noise: NoiseSpec::silent(n),
            energy_saving: 0.0,
            energy: 10.0,
            predicted_mse: 0.0,
        },
        QualityLevel {
            name: "eco".into(),
            noise: noisy,
            energy_saving: 0.3,
            energy: 7.0,
            predicted_mse: 0.0,
        },
    ]
}

/// A deliberately different level set for hot-swap tests: a different band
/// of neurons is noisy at a different std, so a stale packed cache or
/// noise-liveness table from [`levels_v1`] produces different logits.
fn levels_v2(n: usize) -> Vec<QualityLevel> {
    let mut noisy = NoiseSpec::silent(n);
    for s in noisy.std.iter_mut().skip(64).take(64) {
        *s = 1500.0;
    }
    vec![
        QualityLevel {
            name: "exact_v2".into(),
            noise: NoiseSpec::silent(n),
            energy_saving: 0.0,
            energy: 10.0,
            predicted_mse: 0.0,
        },
        QualityLevel {
            name: "eco_v2".into(),
            noise: noisy,
            energy_saving: 0.25,
            energy: 7.5,
            predicted_mse: 0.0,
        },
    ]
}

/// A small deterministic engine on [`levels_v1`]: fixed seed end to end,
/// so two calls produce bit-identical engines (weights, quantization,
/// noise specs).
fn build_engine() -> (Engine, Dataset) {
    let (q, test) = build_quantized();
    let n = q.num_neurons();
    (Engine::new(q, levels_v1(n), 784).unwrap(), test)
}

fn spawn(mode: FrontendMode, opts: FrontendOptions, policy: BatchPolicy) -> (Server, Dataset) {
    let (engine, test) = build_engine();
    let server = Server::spawn_opts(
        vec![Arc::new(engine)],
        0,
        policy,
        FrontendOptions { mode, ..opts },
    )
    .unwrap();
    (server, test)
}

fn one_worker() -> BatchPolicy {
    BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(2), workers: 1 }
}

/// Send one raw line, read one raw reply line (trailing newline stripped).
fn roundtrip(writer: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> String {
    writer.write_all(line.as_bytes()).unwrap();
    writer.write_all(b"\n").unwrap();
    writer.flush().unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    assert!(reply.ends_with('\n'), "truncated reply: {reply:?}");
    reply.trim_end().to_string()
}

fn connect_raw(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn request_line(pixels: &[f32], quality: usize) -> String {
    Json::obj(vec![
        (
            "pixels",
            Json::arr_f64(&pixels.iter().map(|&v| v as f64).collect::<Vec<_>>()),
        ),
        ("quality", Json::Num(quality as f64)),
    ])
    .to_string()
}

/// Acceptance: replies bit-identical between frontends at a fixed seed.
/// Sequential single-worker traffic pins the batch composition and RNG
/// stream, so any divergence is a real frontend difference, not noise.
#[test]
fn evented_replies_are_bit_identical_to_threaded() {
    let (mut threaded, test) =
        spawn(FrontendMode::Threaded, FrontendOptions::default(), one_worker());
    let (mut evented, _) =
        spawn(FrontendMode::Evented, FrontendOptions::default(), one_worker());
    let (mut tw, mut tr) = connect_raw(threaded.addr);
    let (mut ew, mut er) = connect_raw(evented.addr);
    for i in 0..6 {
        // Level 1 is the noisy level — RNG-dependent, the hard case.
        let req = request_line(test.images.row(i), i % 2);
        let a = roundtrip(&mut tw, &mut tr, &req);
        let b = roundtrip(&mut ew, &mut er, &req);
        assert_eq!(a, b, "request {i}: frontends disagree");
        assert!(a.contains("\"class\""), "not a success reply: {a}");
    }
    threaded.shutdown();
    evented.shutdown();
}

#[test]
fn evented_survives_malformed_and_partial_lines() {
    let (mut server, test) =
        spawn(FrontendMode::Evented, FrontendOptions::default(), one_worker());
    let (mut w, mut r) = connect_raw(server.addr);
    // Malformed JSON → typed error, connection stays open.
    let reply = roundtrip(&mut w, &mut r, "this is not json");
    assert!(reply.contains("bad request"), "{reply}");
    // Wrong pixel count → typed error naming the expected dimension.
    let reply = roundtrip(&mut w, &mut r, "{\"pixels\": [1.0, 2.0], \"quality\": 0}");
    assert!(reply.contains("784"), "{reply}");
    // Partial line: send a request in two chunks with a pause — the
    // reactor must buffer, not reply early and not drop bytes.
    let req = request_line(test.images.row(0), 0);
    let (head, tail) = req.split_at(req.len() / 2);
    w.write_all(head.as_bytes()).unwrap();
    w.flush().unwrap();
    std::thread::sleep(Duration::from_millis(60));
    w.write_all(tail.as_bytes()).unwrap();
    w.write_all(b"\n").unwrap();
    w.flush().unwrap();
    let mut reply = String::new();
    r.read_line(&mut reply).unwrap();
    assert!(reply.contains("\"class\""), "{reply}");
    // And the connection still serves after all of the above.
    let reply = roundtrip(&mut w, &mut r, &req);
    assert!(reply.contains("\"class\""), "{reply}");
    server.shutdown();
}

#[test]
fn slow_loris_writer_is_bounded_not_buffered_forever() {
    let (mut server, _) =
        spawn(FrontendMode::Evented, FrontendOptions::default(), one_worker());
    let (mut w, mut r) = connect_raw(server.addr);
    // Feed > 1 MiB without ever sending a newline: the reactor must cap
    // the buffer, answer with a typed error, and close — not grow forever.
    let chunk = vec![b'x'; 64 * 1024];
    let mut sent = 0usize;
    while sent <= (1 << 20) + chunk.len() {
        match w.write_all(&chunk) {
            Ok(()) => sent += chunk.len(),
            Err(_) => break, // server already closed on us — fine
        }
    }
    let mut reply = String::new();
    // Either we get the typed error line, or the server closed the socket
    // after shedding the buffer — both are bounded outcomes.
    match r.read_line(&mut reply) {
        Ok(0) => {}
        Ok(_) => assert!(reply.contains("too long"), "{reply}"),
        Err(_) => {}
    }
    server.shutdown();
}

/// Queue-depth shedding with exact conservation: every pipelined request
/// gets exactly one reply — ok or a typed shed — and the stats counters
/// account for each (`requests` + `shed` == sent).
#[test]
fn saturation_sheds_with_exact_accounting() {
    let opts = FrontendOptions { max_queue: 1, ..FrontendOptions::default() };
    let (mut server, test) = spawn(FrontendMode::Evented, opts, one_worker());
    let (mut w, mut r) = connect_raw(server.addr);
    let n = 30;
    let req = request_line(test.images.row(0), 0);
    let mut burst = String::new();
    for _ in 0..n {
        burst.push_str(&req);
        burst.push('\n');
    }
    // One write: the reactor submits the whole burst in a single
    // read-drain, far faster than the single worker can collect.
    w.write_all(burst.as_bytes()).unwrap();
    w.flush().unwrap();
    let (mut ok, mut shed) = (0u64, 0u64);
    for _ in 0..n {
        let mut reply = String::new();
        r.read_line(&mut reply).unwrap();
        if reply.contains("\"class\"") {
            ok += 1;
        } else {
            assert!(reply.contains("\"shed\""), "unexpected reply: {reply}");
            assert!(reply.contains("queue_full"), "{reply}");
            shed += 1;
        }
    }
    assert_eq!(ok + shed, n, "every request must get exactly one reply");
    assert!(ok > 0, "a max_queue=1 server still serves");
    assert!(shed > 0, "a 30-deep burst against max_queue=1 must shed");
    // The server's own books agree with what the client saw.
    let mut c = Client::connect(server.addr).unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("requests").unwrap().as_u64().unwrap(), ok);
    assert_eq!(stats.get("shed").unwrap().as_u64().unwrap(), shed);
    // New surfaces exist and are sane.
    assert!(stats.get("latency_p99_us").unwrap().as_u64().unwrap() > 0);
    assert!(stats.get("queued").unwrap().as_u64().unwrap() == 0);
    server.shutdown();
}

/// Counter conservation under load with the quality audit active: every
/// request the gate saw is served, shed, or lost to a *counted* worker
/// panic — `sent == requests + shed`, `requests == served + panicked`,
/// and `per_generation` re-conserves `requests`. The audit shadow-
/// executes on the same traffic without perturbing the books (and stays
/// quiet: the exact level's plan is honestly modeled at zero MSE).
#[test]
fn counters_conserve_under_pipelined_burst_with_audit_active() {
    let opts = FrontendOptions {
        max_queue: 2,
        audit: xtpu::obs::audit::AuditConfig { sample_every: 2, ..Default::default() },
        ..FrontendOptions::default()
    };
    let (mut server, test) = spawn(FrontendMode::Evented, opts, one_worker());
    let (mut w, mut r) = connect_raw(server.addr);
    let n = 40;
    let req = request_line(test.images.row(0), 0);
    let mut burst = String::new();
    for _ in 0..n {
        burst.push_str(&req);
        burst.push('\n');
    }
    w.write_all(burst.as_bytes()).unwrap();
    w.flush().unwrap();
    let (mut ok, mut shed) = (0u64, 0u64);
    for _ in 0..n {
        let mut reply = String::new();
        r.read_line(&mut reply).unwrap();
        if reply.contains("\"class\"") {
            ok += 1;
        } else {
            assert!(reply.contains("\"shed\""), "unexpected reply: {reply}");
            shed += 1;
        }
    }
    assert_eq!(ok + shed, n, "every request gets exactly one reply");
    assert!(ok > 0 && shed > 0, "the burst must both serve and shed");
    let mut c = Client::connect(server.addr).unwrap();
    let stats = c.stats().unwrap();
    let requests = stats.get("requests").unwrap().as_u64().unwrap();
    let shed_srv = stats.get("shed").unwrap().as_u64().unwrap();
    let panics = stats.get("worker_panics").unwrap().as_u64().unwrap();
    let served: u64 = stats
        .get("per_level")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_u64().unwrap())
        .sum();
    assert_eq!(requests + shed_srv, n, "admission books conserve the burst");
    assert_eq!(panics, 0, "no worker was lost");
    assert_eq!(served, requests - panics, "every collected request was served");
    let by_generation: u64 = match stats.get("per_generation").unwrap() {
        Json::Obj(map) => map.values().map(|v| v.as_u64().unwrap()).sum(),
        other => panic!("per_generation must be an object, got {other}"),
    };
    assert_eq!(by_generation, requests, "generation attribution conserves requests");
    // The audit sampled this traffic (shadow runs happen after replies —
    // poll briefly) and found the honest zero-MSE plan in band.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while server.stats.audit.audited_rows() == 0 {
        assert!(std::time::Instant::now() < deadline, "audit never sampled");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(server.stats.audit.alarm().is_none(), "honest plan must not alarm");
    server.shutdown();
}

/// Deadline-tagged requests are shed once the service-time estimator has
/// evidence: a zero budget can never be met, so after one warm-up request
/// every tagged request gets the typed deadline shed.
#[test]
fn deadline_tagged_requests_shed_when_unservable() {
    let (mut server, test) =
        spawn(FrontendMode::Evented, FrontendOptions::default(), one_worker());
    let (mut w, mut r) = connect_raw(server.addr);
    // Warm-up: untagged request seeds est_service_ns (a cold server never
    // deadline-sheds — it has no evidence it would miss).
    let warm = roundtrip(&mut w, &mut r, &request_line(test.images.row(0), 0));
    assert!(warm.contains("\"class\""), "{warm}");
    let tagged = format!(
        "{{\"pixels\": {}, \"quality\": 0, \"deadline_ms\": 0}}",
        Json::arr_f64(&test.images.row(0).iter().map(|&v| v as f64).collect::<Vec<_>>())
    );
    for _ in 0..5 {
        let reply = roundtrip(&mut w, &mut r, &tagged);
        assert!(reply.contains("\"shed\""), "{reply}");
        assert!(reply.contains("deadline"), "{reply}");
    }
    let mut c = Client::connect(server.addr).unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("shed").unwrap().as_u64().unwrap(), 5);
    server.shutdown();
}

/// Two shards behind the evented frontend with round-robin routing:
/// placement alternates, and `per_shard` proves both engines served.
#[test]
fn multi_shard_round_robin_splits_live_traffic() {
    let (e0, test) = build_engine();
    let (e1, _) = build_engine();
    let mut server = Server::spawn_opts(
        vec![Arc::new(e0), Arc::new(e1)],
        0,
        one_worker(),
        FrontendOptions { mode: FrontendMode::Evented, ..FrontendOptions::default() },
    )
    .unwrap();
    let (mut w, mut r) = connect_raw(server.addr);
    for i in 0..8 {
        let reply = roundtrip(&mut w, &mut r, &request_line(test.images.row(i), 0));
        assert!(reply.contains("\"class\""), "{reply}");
    }
    let per_shard = server.stats.per_shard_counts();
    assert_eq!(per_shard, vec![4, 4], "round-robin must alternate shards");
    // The same split is visible to clients through the stats line.
    let mut c = Client::connect(server.addr).unwrap();
    let stats = c.stats().unwrap();
    let arr = stats.get("per_shard").unwrap().as_arr().unwrap();
    assert_eq!(arr.len(), 2);
    server.shutdown();
}

/// The threaded frontend's connection cap: connections past `max_conns`
/// get a typed overloaded line instead of an unbounded thread spawn.
#[test]
fn threaded_frontend_caps_connections_with_typed_rejection() {
    let opts = FrontendOptions { max_conns: 1, ..FrontendOptions::default() };
    let (mut server, test) = spawn(FrontendMode::Threaded, opts, one_worker());
    // First connection occupies the only slot.
    let (mut w, mut r) = connect_raw(server.addr);
    let reply = roundtrip(&mut w, &mut r, &request_line(test.images.row(0), 0));
    assert!(reply.contains("\"class\""), "{reply}");
    // Second connection must be rejected with the typed line.
    let (_w2, mut r2) = connect_raw(server.addr);
    let mut line = String::new();
    r2.read_line(&mut line).unwrap();
    assert!(line.contains("overloaded"), "{line}");
    assert!(server.stats.conn_rejected.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    server.shutdown();
}

/// Packed-cache invalidation: after a mid-load hot swap, the server must
/// serve logits bit-identical to a cold server whose engine was *built* on
/// the swapped-in levels. The SIMD-packed weight tiles and noise-liveness
/// tables live inside the generation-tagged `PlanSet` snapshot — the swap
/// publishing a new snapshot IS the cache invalidation — so a stale cache
/// surviving the swap, or the swap-path pack diverging from the
/// construction-path pack, shows up as logit divergence here.
#[test]
fn hot_swap_invalidates_packed_cache_bit_identically() {
    let (engine_a, test) = build_engine();
    let engine_a = Arc::new(engine_a);
    let mut server_a = Server::spawn_opts(
        vec![engine_a.clone()],
        0,
        one_worker(),
        FrontendOptions { mode: FrontendMode::Evented, ..FrontendOptions::default() },
    )
    .unwrap();
    let (mut aw, mut ar) = connect_raw(server_a.addr);

    // Pre-swap traffic on the exact level only: silent levels draw no RNG
    // keys (the exec-layer schedule tests pin this), so the worker's
    // stream stays aligned with the cold server's fresh worker below.
    for i in 0..3 {
        let reply = roundtrip(&mut aw, &mut ar, &request_line(test.images.row(i), 0));
        let j = Json::parse(&reply).unwrap();
        assert_eq!(j.get("generation").unwrap().as_u64().unwrap(), 0, "{reply}");
    }

    // Swap in a different noise layout mid-load — generation 1, freshly
    // packed on the swap path.
    let (q2, _) = build_quantized();
    let n = q2.num_neurons();
    assert_eq!(engine_a.swap_levels(levels_v2(n)).unwrap(), 1);

    // The reference: a cold engine constructed directly on the new levels
    // (packed at Engine::new time, serving generation 0).
    let engine_b = Engine::new(q2, levels_v2(n), 784).unwrap();
    let mut server_b = Server::spawn_opts(
        vec![Arc::new(engine_b)],
        0,
        one_worker(),
        FrontendOptions { mode: FrontendMode::Evented, ..FrontendOptions::default() },
    )
    .unwrap();
    let (mut bw, mut br) = connect_raw(server_b.addr);

    for i in 0..6 {
        // Level 1 is the v2 noisy level — RNG-dependent, the hard case.
        let req = request_line(test.images.row(i), i % 2);
        let a = Json::parse(&roundtrip(&mut aw, &mut ar, &req)).unwrap();
        let b = Json::parse(&roundtrip(&mut bw, &mut br, &req)).unwrap();
        assert_eq!(a.get("generation").unwrap().as_u64().unwrap(), 1);
        assert_eq!(b.get("generation").unwrap().as_u64().unwrap(), 0);
        assert_eq!(
            a.get("quality").unwrap().as_u64().unwrap(),
            b.get("quality").unwrap().as_u64().unwrap(),
            "request {i}: applied quality diverges"
        );
        assert_eq!(
            a.get("class").unwrap().as_u64().unwrap(),
            b.get("class").unwrap().as_u64().unwrap(),
            "request {i}: predicted class diverges"
        );
        // Serialized float formatting is deterministic, so string equality
        // of the logits array is bit-identity of the payload.
        assert_eq!(
            a.get("logits").unwrap().to_string(),
            b.get("logits").unwrap().to_string(),
            "request {i}: swapped-in packed cache diverges from a cold pack"
        );
    }
    server_a.shutdown();
    server_b.shutdown();
}

/// The `--metrics-file` exporter contract: the file is published with an
/// atomic write-to-tmp + rename (`util::json::write_file`), so a concurrent
/// reader must *always* observe a complete, parseable JSON document — never
/// a partial write — while the exporter is rewriting it under live load.
/// This drives the exact loop `main.rs` runs for `--metrics-file`, just
/// without the 500 ms sleep, to maximize rename/read interleavings.
#[test]
fn metrics_file_export_is_atomic_under_concurrent_load() {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use xtpu::util::json::write_file;

    let (mut server, test) = spawn(
        FrontendMode::Evented,
        FrontendOptions::default(),
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1), workers: 2 },
    );
    let dir = std::env::temp_dir().join(format!("xtpu_metrics_atomicity_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("metrics.json");

    let stop = Arc::new(AtomicBool::new(false));
    let writes = Arc::new(AtomicU64::new(0));
    let good_reads = Arc::new(AtomicU64::new(0));

    // Writer: the exporter loop, hot.
    let writer = {
        let (stats, path, stop, writes) =
            (server.stats.clone(), path.clone(), stop.clone(), writes.clone());
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                write_file(&path, &stats.metrics_json()).unwrap();
                writes.fetch_add(1, Ordering::Relaxed);
            }
        })
    };

    // Readers: every observation of the file must parse. A reader that
    // catches a half-written document is the bug this test exists for.
    let readers: Vec<_> = (0..2)
        .map(|_| {
            let (path, stop, good_reads) = (path.clone(), stop.clone(), good_reads.clone());
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    match std::fs::read_to_string(&path) {
                        // Not yet published — the tmp file is invisible.
                        Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                        Err(e) => panic!("metrics file unreadable: {e}"),
                        Ok(text) => {
                            Json::parse(&text).unwrap_or_else(|e| {
                                panic!("metrics file not valid JSON ({e:#}): {text:?}")
                            });
                            good_reads.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            })
        })
        .collect();

    // Live load while the file churns, so the exported counters move.
    let (mut w, mut r) = connect_raw(server.addr);
    for i in 0..40 {
        let reply = roundtrip(&mut w, &mut r, &request_line(test.images.row(i % 20), i % 2));
        assert!(reply.contains("\"class\""), "{reply}");
    }
    // Keep racing until both sides have real coverage: plenty of renames
    // and plenty of successful reads overlapping them.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while writes.load(Ordering::Relaxed) < 200 || good_reads.load(Ordering::Relaxed) < 200 {
        assert!(std::time::Instant::now() < deadline, "exporter race never got coverage");
        std::thread::sleep(Duration::from_millis(5));
    }
    stop.store(true, Ordering::Relaxed);
    writer.join().unwrap();
    for h in readers {
        h.join().unwrap(); // propagates any reader panic = atomicity violation
    }

    // The last published document reflects the served load.
    let final_doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let served = final_doc
        .get("server")
        .unwrap()
        .get("server_requests_total")
        .unwrap()
        .as_f64()
        .unwrap();
    assert!(served >= 40.0, "exported requests_total = {served}, want >= 40");
    std::fs::remove_dir_all(&dir).ok();
    server.shutdown();
}
