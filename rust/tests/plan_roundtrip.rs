//! Plan-subsystem integration tests: the offline→online artifact contract.
//!
//! - A `VoltagePlan` written to disk and loaded back must drive
//!   [`Engine::from_plans`] to **bit-identical inference** vs an engine
//!   built from the in-memory assignment (the `xtpu plan` → `xtpu serve
//!   --plan` round trip).
//! - The parallel multi-budget sweep ([`Pipeline::run`]) must produce
//!   reports identical to the sequential reference
//!   ([`Pipeline::run_sequential`]) under a fixed seed.
//! - The assignment solvers must agree: greedy/GA solutions are feasible
//!   and never beat the exact branch-and-bound optimum (property test over
//!   random MCKP instances).
//! - Operating-regime compatibility: the checked-in pre-mode golden file
//!   (`rust/tests/data/pre_mode_plan.json`) must load with the statistical
//!   default and round-trip bit-exactly; tedrop-mode plans must survive
//!   `to_json`/`from_json`; and [`Engine::from_plans`] must refuse
//!   mode/backend-inconsistent plan sets with a typed [`ModeMismatch`].

use xtpu::config::ExperimentConfig;
use xtpu::coordinator::Pipeline;
use xtpu::errormodel::PlanMode;
use xtpu::exec::Statistical;
use xtpu::ilp::{solve_genetic, solve_greedy, solve_mckp, GaConfig, MckpInstance};
use xtpu::nn::quant::NoiseSpec;
use xtpu::plan::VoltagePlan;
use xtpu::server::{BatchPolicy, Client, Engine, ModeMismatch, QualityLevel, Server};
use xtpu::util::checks::property;
use xtpu::util::json::Json;
use xtpu::util::rng::Xoshiro256pp;

/// Path of the checked-in golden plan file, serialized before operating
/// regimes (and the adaptive loop) existed: no `mode`, `generation`, or
/// `drift_delta_vth` keys anywhere in the artifact.
fn golden_pre_mode_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/pre_mode_plan.json")
}

fn smoke_config() -> ExperimentConfig {
    ExperimentConfig {
        train_samples: 600,
        test_samples: 200,
        epochs: 2,
        characterize_samples: 40_000,
        mse_ub_fractions: vec![0.1, 2.0, 10.0],
        validation_runs: 1,
        seed: 0x9A7B,
        ..Default::default()
    }
}

#[test]
fn plan_files_serve_identically_to_in_memory_assignments() {
    let pipeline = Pipeline::new(smoke_config());
    let sys = pipeline.prepare().unwrap();

    // Solve two budgets, persist the plans, and load them back from disk.
    let reports: Vec<_> = [0.5, 5.0]
        .iter()
        .map(|&f| pipeline.run_budget(&sys, f).unwrap())
        .collect();
    let dir = std::env::temp_dir().join(format!("xtpu_plan_rt_{}", std::process::id()));
    let loaded: Vec<VoltagePlan> = reports
        .iter()
        .map(|r| {
            let path = dir.join(r.plan.file_name());
            r.plan.save(&path).unwrap();
            VoltagePlan::load(&path).unwrap()
        })
        .collect();

    // Engine A: from the round-tripped plan files.
    let engine_plans =
        Engine::from_plans(sys.quantized.clone(), &sys.registry, &loaded, 784).unwrap();
    // Engine B: quality levels hand-assembled from the in-memory
    // assignments (the pre-plan construction path).
    let levels: Vec<QualityLevel> = reports
        .iter()
        .map(|r| QualityLevel {
            name: r.plan.name.clone(),
            noise: NoiseSpec::from_levels(&r.assignment.level, &sys.fan_in, &sys.registry),
            energy_saving: r.assignment.energy_saving,
            energy: r.assignment.energy,
            predicted_mse: r.plan.predicted_mse,
        })
        .collect();
    let engine_mem = Engine::new(sys.quantized.clone(), levels, 784).unwrap();

    // The derived noise specs must match bit-exactly…
    let set_plans = engine_plans.plan_set();
    let set_mem = engine_mem.plan_set();
    for (a, b) in set_plans.levels.iter().zip(&set_mem.levels) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.energy_saving, b.energy_saving);
        assert_eq!(a.noise.mean, b.noise.mean);
        assert_eq!(a.noise.std, b.noise.std);
    }
    // …and so must actual noisy inference through the shared kernel.
    let backend = Statistical::new(sys.registry.clone());
    let (x, _) = sys.test.batch(&(0..16).collect::<Vec<_>>());
    for level in 0..set_plans.levels.len() {
        let mut rng_a = Xoshiro256pp::seeded(0xD15C ^ level as u64);
        let mut rng_b = Xoshiro256pp::seeded(0xD15C ^ level as u64);
        let ya = engine_plans.quantized.forward_with(
            &backend,
            &x,
            Some(&set_plans.levels[level].noise),
            &mut rng_a,
        );
        let yb = engine_mem.quantized.forward_with(
            &backend,
            &x,
            Some(&set_mem.levels[level].noise),
            &mut rng_b,
        );
        assert_eq!(ya.data, yb.data, "level {level} logits diverge");
    }

    // And the plan-built engine really serves: full TCP round trip.
    let engine = Engine::from_plans(sys.quantized.clone(), &sys.registry, &loaded, 784)
        .unwrap()
        .with_backend(Box::new(Statistical::new(sys.registry.clone())));
    let mut server = Server::spawn(engine, 0, BatchPolicy::default()).unwrap();
    let mut client = Client::connect(server.addr).unwrap();
    for q in 0..loaded.len() {
        let (_, logits, applied) = client.infer_full(sys.test.images.row(0), q).unwrap();
        assert_eq!(logits.len(), 10);
        assert_eq!(applied, q);
    }
    let stats = client.stats().unwrap();
    let per_level = stats.get("per_level").unwrap().as_arr().unwrap();
    assert_eq!(per_level.len(), loaded.len());
    for c in per_level {
        assert_eq!(c.as_u64().unwrap(), 1, "each level served exactly once");
    }
    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn parallel_sweep_matches_sequential_reference() {
    let pipeline = Pipeline::new(smoke_config());
    let (_, par) = pipeline.run().unwrap();
    let (_, seq) = pipeline.run_sequential().unwrap();
    assert_eq!(par.len(), seq.len());
    for (a, b) in par.iter().zip(&seq) {
        assert_eq!(a.mse_ub_fraction, b.mse_ub_fraction);
        assert_eq!(a.budget_abs, b.budget_abs);
        assert_eq!(a.assignment.level, b.assignment.level, "assignments diverge");
        assert_eq!(a.assignment.energy_saving, b.assignment.energy_saving);
        assert_eq!(a.assignment.predicted_mse, b.assignment.predicted_mse);
        assert_eq!(a.validated_mse, b.validated_mse, "validation diverges");
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.plan.to_json().to_string(), b.plan.to_json().to_string());
    }
}

/// Random MCKP instance with a guaranteed-feasible zero-weight option per
/// group (the "nominal voltage" structure of the real problem).
fn random_instance(rng: &mut Xoshiro256pp) -> MckpInstance {
    let groups = 1 + rng.index(6);
    let mut cost = Vec::with_capacity(groups);
    let mut weight = Vec::with_capacity(groups);
    let mut max_weight_sum = 0.0;
    for _ in 0..groups {
        let options = 2 + rng.index(4);
        let mut c: Vec<f64> = (0..options).map(|_| rng.range_f64(0.1, 100.0)).collect();
        let mut w: Vec<f64> = (0..options).map(|_| rng.range_f64(0.1, 50.0)).collect();
        // Option `options-1` mimics nominal: zero weight, highest cost.
        w[options - 1] = 0.0;
        c[options - 1] = 100.0 + rng.range_f64(0.0, 50.0);
        max_weight_sum += w.iter().cloned().fold(0.0, f64::max);
        cost.push(c);
        weight.push(w);
    }
    MckpInstance { cost, weight, budget: rng.range_f64(0.0, max_weight_sum * 1.2) }
}

#[test]
fn solvers_agree_on_random_instances() {
    property("greedy/GA feasible and never beat the exact optimum", 60, |rng, case| {
        let inst = random_instance(rng);
        let exact = solve_mckp(&inst).unwrap();
        let greedy = solve_greedy(&inst).unwrap();
        let ga = solve_genetic(
            &inst,
            &GaConfig { generations: 60, seed: 0xBEEF ^ case as u64, ..Default::default() },
        )
        .unwrap();
        assert!(exact.optimal, "branch-and-bound must prove optimality");
        let tol = 1e-9 * (1.0 + exact.total_cost.abs());
        for (name, sol) in [("exact", &exact), ("greedy", &greedy), ("ga", &ga)] {
            // Structural sanity: one in-range choice per group.
            assert_eq!(sol.choice.len(), inst.cost.len(), "{name}");
            for (g, &c) in sol.choice.iter().enumerate() {
                assert!(c < inst.cost[g].len(), "{name}: choice out of range");
            }
            // Feasibility: the budget constraint holds.
            let w: f64 =
                sol.choice.iter().enumerate().map(|(g, &c)| inst.weight[g][c]).sum();
            assert!(
                w <= inst.budget + 1e-9,
                "{name}: infeasible ({w} > {})",
                inst.budget
            );
            // Optimality: nothing beats the exact solver.
            assert!(
                sol.total_cost >= exact.total_cost - tol,
                "{name} cost {} beat exact optimum {}",
                sol.total_cost,
                exact.total_cost
            );
        }
    });
}

#[test]
fn golden_pre_mode_plan_file_loads_with_statistical_default() {
    // Guard the fixture itself first: it must stay genuinely pre-mode, or
    // this test silently stops exercising the compatibility path.
    let path = golden_pre_mode_path();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(
        !text.contains("\"mode\"") && !text.contains("\"generation\""),
        "golden fixture must not carry mode/lineage keys"
    );

    let plan = VoltagePlan::load(&path).unwrap();
    assert_eq!(plan.mode, "statistical", "pre-mode plans default to tolerate");
    assert_eq!(plan.plan_mode(), PlanMode::Statistical);
    assert_eq!(plan.config.mode, "statistical", "embedded config defaults too");
    assert_eq!(plan.generation, 0);
    assert_eq!(plan.drift_delta_vth, 0.0);
    // Spot-check the payload actually came through, not just the defaults.
    assert_eq!(plan.name, "mse_ub_200pct");
    assert_eq!(plan.level, vec![0, 1, 2, 3]);
    assert_eq!(plan.fan_in, vec![784, 784, 256, 256]);
    assert_eq!(plan.volts, vec![0.5, 0.6, 0.7, 0.8]);
    assert_eq!(plan.config.backend, "statistical");

    // A modern re-serialization emits the mode explicitly and the upgraded
    // artifact round-trips bit-exactly from there on.
    let j = plan.to_json();
    assert_eq!(j.get("mode").unwrap().as_str().unwrap(), "statistical");
    let back = VoltagePlan::from_json(&j).unwrap();
    assert_eq!(j.to_string(), back.to_json().to_string());
}

#[test]
fn tedrop_plans_round_trip_and_unknown_modes_are_refused() {
    // Flip the golden plan into the detect regime the way `xtpu plan
    // --mode tedrop` would: plan mode + embedded config mode + backend.
    let mut plan = VoltagePlan::load(&golden_pre_mode_path()).unwrap();
    plan.mode = "tedrop".into();
    plan.config.mode = "tedrop".into();
    plan.config.backend = "tedrop".into();

    let j = plan.to_json();
    let back = VoltagePlan::from_json(&j).unwrap();
    assert_eq!(back.mode, "tedrop");
    assert_eq!(back.plan_mode(), PlanMode::TeDrop);
    assert_eq!(back.config.mode, "tedrop");
    assert_eq!(back.config.backend, "tedrop");
    assert_eq!(j.to_string(), back.to_json().to_string(), "bit-exact round trip");

    // An unrecognized regime is refused at load — on the plan itself and
    // inside the embedded config — instead of being discovered mid-serve.
    let mut bad_plan = j.as_obj().unwrap().clone();
    bad_plan.insert("mode".into(), Json::Str("razor".into()));
    assert!(VoltagePlan::from_json(&Json::Obj(bad_plan)).is_err());
    let mut bad_cfg = j.as_obj().unwrap().clone();
    let mut cfg = bad_cfg.get("config").unwrap().as_obj().unwrap().clone();
    cfg.insert("mode".into(), Json::Str("razor".into()));
    bad_cfg.insert("config".into(), Json::Obj(cfg));
    assert!(VoltagePlan::from_json(&Json::Obj(bad_cfg)).is_err());
}

#[test]
fn engines_refuse_cross_regime_plan_sets_with_typed_errors() {
    let pipeline = Pipeline::new(smoke_config());
    let sys = pipeline.prepare().unwrap();
    let stat = pipeline.run_budget(&sys, 1.0).unwrap().plan;

    // A plan claiming the tedrop regime while its config still builds a
    // statistical backend is internally inconsistent: the served noise
    // would not match the priced noise.
    let mut inconsistent = stat.clone();
    inconsistent.mode = "tedrop".into();
    let err = Engine::from_plans(sys.quantized.clone(), &sys.registry, &[inconsistent], 784)
        .unwrap_err();
    assert!(
        matches!(err.downcast_ref::<ModeMismatch>(), Some(ModeMismatch::Backend { .. })),
        "expected ModeMismatch::Backend, got: {err}"
    );

    // A self-consistent tedrop plan builds an engine on its own…
    let mut te = stat.clone();
    te.mode = "tedrop".into();
    te.config.mode = "tedrop".into();
    te.config.backend = "tedrop".into();
    Engine::from_plans(sys.quantized.clone(), &sys.registry, &[te.clone()], 784).unwrap();

    // …but one engine serves one operating regime: mixing it with its
    // statistical sibling is refused even though fingerprint and planning
    // config hash (which excludes mode/backend) both match.
    stat.check_compatible(&te).unwrap();
    let err = Engine::from_plans(sys.quantized.clone(), &sys.registry, &[stat, te], 784)
        .unwrap_err();
    assert!(
        matches!(err.downcast_ref::<ModeMismatch>(), Some(ModeMismatch::CrossPlan { .. })),
        "expected ModeMismatch::CrossPlan, got: {err}"
    );
}
