//! Cross-layer integration tests: PJRT runtime ↔ rust quantized inference ↔
//! systolic simulator ↔ the full pipeline.
//!
//! These need `make artifacts` to have run (HLO files under artifacts/);
//! they skip gracefully when the artifacts are absent so `cargo test` stays
//! runnable in a fresh checkout.

use xtpu::assign::Solver;
use xtpu::config::ExperimentConfig;
use xtpu::coordinator::{backend_cross_check, systolic_cross_check, Pipeline};
use xtpu::nn::data::synth_mnist;
use xtpu::nn::layers::Activation;
use xtpu::nn::model::fc_mnist;
use xtpu::nn::quant::QuantizedModel;
use xtpu::nn::train::{train, TrainConfig};
use xtpu::runtime::{artifacts_dir, literal_f32, literal_i8, FcExecutor, Runtime};
use xtpu::util::rng::Xoshiro256pp;

fn artifacts_present() -> bool {
    artifacts_dir().join("mm16.hlo.txt").exists()
}

fn smoke_config() -> ExperimentConfig {
    ExperimentConfig {
        train_samples: 600,
        test_samples: 200,
        epochs: 2,
        characterize_samples: 40_000,
        mse_ub_fractions: vec![0.1, 2.0, 10.0],
        validation_runs: 1,
        seed: 0xFEED,
        ..Default::default()
    }
}

#[test]
fn pjrt_mm16_matches_integer_reference() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rt = Runtime::new(&artifacts_dir()).unwrap();
    rt.load("mm16").unwrap();
    let mut rng = Xoshiro256pp::seeded(7);
    let x: Vec<i8> = (0..256).map(|_| rng.range_i64(-127, 127) as i8).collect();
    let w: Vec<i8> = (0..256).map(|_| rng.range_i64(-127, 127) as i8).collect();
    let noise: Vec<f32> = (0..256).map(|_| rng.gaussian(0.0, 100.0) as f32).collect();
    let out = rt
        .execute(
            "mm16",
            &[
                literal_i8(&x, &[16, 16]).unwrap(),
                literal_i8(&w, &[16, 16]).unwrap(),
                literal_f32(&noise, &[16, 16]).unwrap(),
            ],
        )
        .unwrap();
    let got: Vec<i32> = out[0].to_vec().unwrap();
    for i in 0..16 {
        for j in 0..16 {
            let mut acc = 0i64;
            for p in 0..16 {
                acc += (x[i * 16 + p] as i64) * (w[p * 16 + j] as i64);
            }
            let e = noise[i * 16 + j] as f64;
            // jnp.round is round-half-even; only exact .5 values can differ
            // from rust's rounding, and the test noise avoids them.
            let expect = acc + e.round_ties_even() as i64;
            assert_eq!(got[i * 16 + j] as i64, expect, "({i},{j})");
        }
    }
}

#[test]
fn pjrt_fc_matches_rust_quantized_inference() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // Train a small FC model in rust, quantize, run the same inputs through
    // (a) the rust quantized forward and (b) the JAX/Pallas HLO artifact via
    // PJRT — logits must agree to float tolerance.
    let mut rng = Xoshiro256pp::seeded(21);
    let mut model = fc_mnist(Activation::Linear, &mut rng);
    let train_set = synth_mnist(600, 31);
    train(&mut model, &train_set, &TrainConfig { epochs: 2, ..Default::default() });
    let test = synth_mnist(64, 32);
    let calib = test.batch(&(0..32).collect::<Vec<_>>()).0;
    let q = QuantizedModel::quantize(&model, &calib);

    let mut rt = Runtime::new(&artifacts_dir()).unwrap();
    let exec = FcExecutor::from_quantized(&q, "linear", 32).unwrap();
    rt.load(&exec.artifact).unwrap();

    let (x, labels) = test.batch(&(0..32).collect::<Vec<_>>());
    let mut rng2 = Xoshiro256pp::seeded(1);
    let rust_logits = q.forward(&x, None, &mut rng2);
    let mut rng3 = Xoshiro256pp::seeded(2);
    let pjrt_logits = exec.run(&rt, &x.data, &mut rng3).unwrap();
    assert_eq!(pjrt_logits.len(), 320);

    let mut agree = 0;
    let mut max_rel = 0f32;
    for i in 0..320 {
        let (a, b) = (rust_logits.data[i], pjrt_logits[i]);
        let rel = (a - b).abs() / a.abs().max(b.abs()).max(1.0);
        max_rel = max_rel.max(rel);
        if rel < 1e-3 {
            agree += 1;
        }
    }
    // Round-half-even vs round-half-away can flip a rare borderline int8
    // quantization; demand near-universal agreement and tight max error.
    assert!(agree >= 315, "only {agree}/320 logits agree (max rel {max_rel})");

    // And the PJRT path must classify as well as the rust path.
    let mut correct = 0;
    for r in 0..32 {
        let row = &pjrt_logits[r * 10..(r + 1) * 10];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == labels[r] as usize {
            correct += 1;
        }
    }
    assert!(correct >= 20, "PJRT path accuracy {correct}/32");
}

#[test]
fn pjrt_fc_noise_injection_matches_prediction() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // Inject a large per-neuron noise through the PJRT path and verify the
    // measured output-MSE increment matches the ES-based prediction within
    // a factor of 2 (the framework's core quality-estimation claim).
    let cfg = smoke_config();
    let pipeline = Pipeline::new(cfg);
    let sys = pipeline.prepare().unwrap();
    let report = pipeline.run_budget(&sys, 2.0).unwrap();

    let exec_noise = {
        let problem = xtpu::assign::AssignmentProblem::build(
            &sys.es,
            &sys.fan_in,
            &sys.registry,
            &sys.power,
            report.budget_abs,
        );
        problem.noise_spec(&report.assignment, &sys.registry)
    };
    let mut rt = Runtime::new(&artifacts_dir()).unwrap();
    let mut exec = FcExecutor::from_quantized(&sys.quantized, "linear", 32).unwrap();
    rt.load(&exec.artifact).unwrap();
    let (x, _) = sys.test.batch(&(0..32).collect::<Vec<_>>());
    let mut rng = Xoshiro256pp::seeded(3);
    let clean = exec.run(&rt, &x.data, &mut rng).unwrap();
    exec.set_noise(exec_noise);
    // Average the measured MSE over several noise draws.
    let mut mse = 0.0;
    let runs = 5;
    for s in 0..runs {
        let mut rng = Xoshiro256pp::seeded(100 + s);
        let noisy = exec.run(&rt, &x.data, &mut rng).unwrap();
        mse += clean
            .iter()
            .zip(&noisy)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / clean.len() as f64;
    }
    mse /= runs as f64;
    let predicted = report.assignment.predicted_mse;
    if predicted > 0.0 {
        let ratio = mse / predicted;
        assert!(
            (0.3..3.0).contains(&ratio),
            "PJRT measured MSE {mse:.4e} vs predicted {predicted:.4e} (ratio {ratio:.2})"
        );
    }
}

#[test]
fn pipeline_end_to_end_smoke() {
    let cfg = smoke_config();
    let pipeline = Pipeline::new(cfg);
    let (sys, reports) = pipeline.run().unwrap();
    assert!(sys.baseline_accuracy > 0.6, "baseline accuracy {}", sys.baseline_accuracy);
    assert!(sys.baseline_mse > 0.0);
    // Energy saving must be monotone in the budget; accuracy must not
    // collapse at tight budgets.
    for w in reports.windows(2) {
        assert!(
            w[1].assignment.energy_saving >= w[0].assignment.energy_saving - 1e-9,
            "saving not monotone: {:?}",
            reports.iter().map(|r| r.assignment.energy_saving).collect::<Vec<_>>()
        );
    }
    let tight = &reports[0];
    assert!(tight.accuracy_drop < 0.05, "tight budget dropped accuracy {}", tight.accuracy_drop);
    // Predicted MSE respects each budget.
    for r in &reports {
        assert!(r.assignment.predicted_mse <= r.budget_abs + 1e-9);
    }
}

#[test]
fn systolic_simulator_agrees_with_error_models() {
    let cfg = smoke_config();
    let pipeline = Pipeline::new(cfg);
    let sys = pipeline.prepare().unwrap();
    let report = pipeline.run_budget(&sys, 10.0).unwrap();
    let overscaled =
        report.assignment.level.iter().take(128).filter(|&&l| l < 3).count();
    if overscaled == 0 {
        eprintln!("no overscaled columns at this budget; nothing to check");
        return;
    }
    let (measured, predicted) =
        systolic_cross_check(&sys, &report.assignment, 1500, 9).unwrap();
    assert!(measured > 0.0 && predicted > 0.0);
    let ratio = measured / predicted;
    assert!(
        (0.7..1.4).contains(&ratio),
        "systolic variance {measured:.3e} vs model {predicted:.3e} (ratio {ratio:.2})"
    );
}

#[test]
fn statistical_and_gate_level_backends_agree_on_16x16() {
    // The exec-layer cross-validation (extends systolic_cross_check down to
    // the gates): characterize a chip, then run the SAME 16×16 matmul
    // through the Statistical fast path and the cycle-level GateLevel
    // array. Per-column error mean and variance must agree within sampling
    // tolerance — the agreement that licenses the statistical backend as a
    // stand-in for gate-level simulation.
    use xtpu::errormodel::{CharacterizeOptions, ErrorModelRegistry};
    use xtpu::timing::baugh_wooley_8x8;
    use xtpu::timing::sta::ChipInstance;
    use xtpu::timing::voltage::{Technology, VoltageLadder};

    let netlist = baugh_wooley_8x8("bw_xcheck");
    let tech = Technology::default();
    let mut rng = Xoshiro256pp::seeded(4242);
    let chip = ChipInstance::sample(&netlist, &tech, &mut rng);
    let ladder = VoltageLadder::paper_default();
    // Sample counts sized for debug-profile `cargo test`: ~120k
    // characterization vectors + ~300k gate-level matmul steps keep the
    // per-column variance estimates within a few percent, far inside the
    // assertion windows below.
    let opts = CharacterizeOptions { samples: 40_000, seed: 99, ..Default::default() };
    let reg = ErrorModelRegistry::characterize(&netlist, &chip, &ladder, &opts);
    assert!(reg.model(0).variance > 0.0, "0.5 V must show errors");

    let (m, k, n) = (1200usize, 16usize, 16usize);
    let levels = vec![0usize; n]; // 0.5 V everywhere: strongest statistics
    let (stat, gate) = backend_cross_check(&netlist, &chip, &reg, m, k, n, &levels, 7);
    assert_eq!(stat.len(), n);
    assert_eq!(gate.len(), n);
    let composed_var = reg.model(0).column_variance(k);
    let composed_std = composed_var.sqrt();
    let mean_tol = 6.0 * composed_std / (m as f64).sqrt() + 0.05 * composed_std;
    for c in 0..n {
        let (sm, sv) = stat[c];
        let (gm, gv) = gate[c];
        let ratio = gv / sv.max(1e-12);
        assert!(
            (0.4..2.5).contains(&ratio),
            "col {c}: gate var {gv:.3e} vs stat var {sv:.3e} (ratio {ratio:.2})"
        );
        assert!(
            (sm - gm).abs() < mean_tol,
            "col {c}: stat mean {sm:.2} vs gate mean {gm:.2} (tol {mean_tol:.2})"
        );
        // Both must also track the registry's composed k·var(e) prediction.
        assert!(
            (0.5..2.0).contains(&(sv / composed_var)),
            "col {c}: stat var {sv:.3e} vs composed {composed_var:.3e}"
        );
        assert!(
            (0.4..2.5).contains(&(gv / composed_var)),
            "col {c}: gate var {gv:.3e} vs composed {composed_var:.3e}"
        );
    }
}

#[test]
fn tedrop_fast_path_agrees_with_naive_bernoulli_reference() {
    // The TE-Drop analogue of the Statistical↔GateLevel suite above: the
    // vectorized geometric skip-sampling fault pass must be statistically
    // indistinguishable from the obvious oracle — an independent
    // per-MAC Bernoulli(p) loop that subtracts each detected product —
    // on a 16×16 layer. Agreement is in per-column error moments (the
    // two draw different randomness), plus the analytic k·p·M2 pricing
    // the planner budgets with.
    use xtpu::errormodel::{ErrorModelRegistry, PlanMode, MAC_SECOND_MOMENT};
    use xtpu::exec::{self, TeDrop};
    use xtpu::timing::voltage::VoltageLadder;

    let ladder = VoltageLadder::paper_default();
    let reg = ErrorModelRegistry::synthetic_with_rates(
        &ladder,
        &[3.0e4, 1.0e4, 2.0e3, 0.0],
        &[0.05, 0.02, 0.005, 0.0],
    );
    let (m, k, n) = (4000usize, 16usize, 16usize);
    let p = reg.model(0).error_rate;
    // ±127 inputs so E[a²] = E[w²] = 127·128/3 — exactly the factors in
    // MAC_SECOND_MOMENT, making the analytic cross-check sharp.
    let mut rng = Xoshiro256pp::seeded(0x7E5D);
    let a: Vec<i8> = (0..m * k).map(|_| rng.range_i64(-127, 127) as i8).collect();
    let w: Vec<i8> = (0..k * n).map(|_| rng.range_i64(-127, 127) as i8).collect();
    // All but the last column at 0.5 V (rate p); the last nominal (silent).
    let mut levels = vec![0usize; n];
    levels[n - 1] = ladder.len() - 1;

    let te = TeDrop::new(reg.clone());
    let stats = exec::column_error_stats(&te, &a, &w, m, k, n, &levels, &mut rng);

    // Naive oracle: one Bernoulli(p) per MAC, drop = subtract the product.
    let mut nrng = Xoshiro256pp::seeded(0x0B5E);
    let mut naive = vec![(0.0f64, 0.0f64); n];
    let mut errs = vec![0.0f64; m];
    for (c, moments) in naive.iter_mut().enumerate().take(n - 1) {
        for (s, e) in errs.iter_mut().enumerate() {
            *e = 0.0;
            for r in 0..k {
                if nrng.chance(p) {
                    *e -= a[s * k + r] as f64 * w[r * n + c] as f64;
                }
            }
        }
        let mean = errs.iter().sum::<f64>() / m as f64;
        let var = errs.iter().map(|e| (e - mean) * (e - mean)).sum::<f64>() / m as f64;
        *moments = (mean, var);
    }

    let analytic = PlanMode::TeDrop.column_variance(reg.model(0), k);
    assert!((analytic - k as f64 * p * MAC_SECOND_MOMENT).abs() < 1e-6);
    let mean_tol = 8.0 * analytic.sqrt() / (m as f64).sqrt();
    for c in 0..n - 1 {
        let (tm, tv) = stats[c];
        let (nm, nv) = naive[c];
        let ratio = tv / nv.max(1e-12);
        assert!(
            (0.75..1.33).contains(&ratio),
            "col {c}: fast-path var {tv:.3e} vs naive {nv:.3e} (ratio {ratio:.2})"
        );
        assert!(
            (tm - nm).abs() < mean_tol,
            "col {c}: fast-path mean {tm:.2} vs naive {nm:.2} (tol {mean_tol:.2})"
        );
        // Both estimators must also track the planner's k·p·M2 pricing
        // (the naive loop's true variance carries a (1−p) factor the
        // bound intentionally ignores; the window absorbs it).
        for (label, v) in [("fast-path", tv), ("naive", nv)] {
            assert!(
                (0.6..1.6).contains(&(v / analytic)),
                "col {c}: {label} var {v:.3e} vs analytic {analytic:.3e}"
            );
        }
    }
    let (zm, zv) = stats[n - 1];
    assert_eq!((zm, zv), (0.0, 0.0), "nominal column must be untouched");
}

#[test]
fn clean_inference_identical_across_backends() {
    // With no noise spec, every backend must produce bit-identical logits:
    // they share one exec::kernel.
    use xtpu::errormodel::ErrorModelRegistry;
    use xtpu::exec::{Exact, Statistical};
    use xtpu::timing::voltage::VoltageLadder;

    let mut rng = Xoshiro256pp::seeded(51);
    let mut model = fc_mnist(Activation::Relu, &mut rng);
    let train_set = synth_mnist(300, 52);
    train(&mut model, &train_set, &TrainConfig { epochs: 1, ..Default::default() });
    let test = synth_mnist(32, 53);
    let calib = test.batch(&(0..16).collect::<Vec<_>>()).0;
    let q = QuantizedModel::quantize(&model, &calib);
    let (x, _) = test.batch(&(0..8).collect::<Vec<_>>());

    let reg = ErrorModelRegistry::synthetic(
        &VoltageLadder::paper_default(),
        &[3.0e4, 1.0e4, 2.0e3, 0.0],
    );

    let mut rng1 = Xoshiro256pp::seeded(1);
    let base = q.forward(&x, None, &mut rng1);
    let mut rng2 = Xoshiro256pp::seeded(1);
    let via_exact = q.forward_with(&Exact, &x, None, &mut rng2);
    let mut rng3 = Xoshiro256pp::seeded(1);
    let stat = Statistical::new(reg);
    let via_stat = q.forward_with(&stat, &x, None, &mut rng3);
    assert_eq!(base.data, via_exact.data);
    assert_eq!(base.data, via_stat.data);
}

#[test]
fn greedy_and_ga_feasible_ilp_optimal_on_real_problem() {
    let cfg = smoke_config();
    let pipeline = Pipeline::new(cfg);
    let sys = pipeline.prepare().unwrap();
    let budget = 2.0;
    let ilp = pipeline.run_budget_with(&sys, budget, Solver::Ilp).unwrap();
    let greedy = pipeline.run_budget_with(&sys, budget, Solver::Greedy).unwrap();
    let ga = pipeline.run_budget_with(&sys, budget, Solver::Genetic).unwrap();
    assert!(ilp.assignment.optimal);
    // Relative tolerance: energies are O(1e7) sums accumulated in different
    // orders by the two solvers.
    let tol = ilp.assignment.energy.abs() * 1e-9 + 1e-6;
    assert!(ilp.assignment.energy <= greedy.assignment.energy + tol);
    assert!(ilp.assignment.energy <= ga.assignment.energy + tol);
    for r in [&ilp, &greedy, &ga] {
        assert!(r.assignment.predicted_mse <= r.budget_abs + 1e-9);
    }
}
