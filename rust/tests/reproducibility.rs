//! Reproducibility test suite for the parallel exec layer and the
//! multi-worker serving engine:
//!
//! - property-style randomized kernel tests (~100 shapes, ragged/empty/
//!   1-row, int8 saturation corners) bit-matched against the naive oracle;
//! - bit-identical exact AND noisy outputs across every SIMD dispatch path
//!   the host can run (scalar vs AVX2/NEON), forced explicitly through the
//!   kernel's `*_path` seam so the check does not depend on `XTPU_SIMD`;
//! - bit-identical `Statistical` backend output across `XTPU_THREADS`
//!   (the deterministic per-shard RNG stream guarantee);
//! - per-column error moments still matching the registry predictions;
//! - a ≥16-client mixed-quality server stress test demonstrating correct
//!   per-request responses, real batching, and genuinely concurrent batch
//!   execution (no global backend mutex on the hot path).
//!
//! Environment note: the tests that mutate `XTPU_THREADS` serialize on
//! [`ENV_LOCK`] so their save/restore windows never interleave. Other
//! tests in this binary run concurrently with them, which is safe
//! precisely because of the property under test — kernel output does not
//! depend on the observed thread count.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use xtpu::errormodel::ErrorModelRegistry;
use xtpu::exec::{self, kernel, Backend, NoiseView, Statistical};
use xtpu::nn::data::synth_mnist;
use xtpu::nn::layers::Activation;
use xtpu::nn::model::fc_mnist;
use xtpu::nn::quant::{NoiseSpec, QuantMac, QuantizedModel};
use xtpu::nn::train::{train, TrainConfig};
use xtpu::server::{BatchPolicy, Client, Engine, QualityLevel, Server};
use xtpu::timing::voltage::VoltageLadder;
use xtpu::util::rng::Xoshiro256pp;

fn random_mats(m: usize, k: usize, n: usize, rng: &mut Xoshiro256pp) -> (Vec<i8>, Vec<i8>) {
    // Full int8 range including −128, with the leading entries pinned to
    // the saturation corners so every run exercises |a·w| = 128².
    let mut a: Vec<i8> = (0..m * k).map(|_| rng.range_i64(-128, 127) as i8).collect();
    let mut w: Vec<i8> = (0..k * n).map(|_| rng.range_i64(-128, 127) as i8).collect();
    for (j, v) in a.iter_mut().take(4).enumerate() {
        *v = if j % 2 == 0 { -128 } else { 127 };
    }
    for (j, v) in w.iter_mut().take(4).enumerate() {
        *v = if j % 2 == 0 { 127 } else { -128 };
    }
    (a, w)
}

fn synthetic_registry() -> ErrorModelRegistry {
    ErrorModelRegistry::synthetic(&VoltageLadder::paper_default(), &[3.0e4, 1.0e4, 2.0e3, 0.0])
}

/// Serializes the `XTPU_THREADS` save/mutate/restore windows of the tests
/// below (the kernel re-reads the variable per call, so only the windows
/// need exclusion, not the whole binary).
static ENV_LOCK: Mutex<()> = Mutex::new(());

#[test]
fn kernel_property_random_shapes_bit_match_reference() {
    let mut rng = Xoshiro256pp::seeded(0xF00D);
    // Pinned edge cases: empty dims, single rows, exact tile multiples and
    // off-by-one tile remainders — then ~100 random shapes.
    let mut shapes: Vec<(usize, usize, usize)> = vec![
        (0, 0, 0),
        (0, 5, 3),
        (4, 0, 6),
        (4, 7, 0),
        (1, 1, 1),
        (1, 784, 1),
        (1, 257, 130),
        (3, kernel::TILE_K, 64),
        (2, kernel::TILE_K + 1, 65),
        (2, kernel::TILE_K - 1, 63),
        (5, 2 * kernel::TILE_K + 17, 29),
    ];
    for _ in 0..100 {
        shapes.push((rng.index(33), rng.index(300), rng.index(120)));
    }
    for (i, &(m, k, n)) in shapes.iter().enumerate() {
        let (a, w) = random_mats(m, k, n, &mut rng);
        let expect = kernel::reference_matmul(&a, &w, m, k, n);
        assert_eq!(
            kernel::matmul_i8(&a, &w, m, k, n),
            expect,
            "shape {i}: {m}×{k}×{n} (systolic layout)"
        );
        // The transposed (QuantMac) entry point must agree on the same
        // problem.
        let mut wt = vec![0i8; n * k];
        for r in 0..k {
            for c in 0..n {
                wt[c * k + r] = w[r * n + c];
            }
        }
        assert_eq!(
            kernel::matmul_i8t(&a, &wt, m, k, n),
            expect,
            "shape {i}: {m}×{k}×{n} (transposed layout)"
        );
    }
}

#[test]
fn kernel_saturated_inputs_accumulate_exactly() {
    // All inputs at the extreme corners: k·128² stays far inside i32, and
    // the tiled kernel must carry it exactly.
    let (m, k, n) = (8, 512, 16);
    let a = vec![-128i8; m * k];
    let w = vec![-128i8; k * n];
    let out = kernel::matmul_i8(&a, &w, m, k, n);
    assert!(out.iter().all(|&v| v == (k as i32) * 128 * 128));
    let w2 = vec![127i8; k * n];
    let out2 = kernel::matmul_i8(&a, &w2, m, k, n);
    assert!(out2.iter().all(|&v| v == (k as i32) * -128 * 127));
}

#[test]
fn simd_dispatch_paths_bit_identical_on_ragged_shapes() {
    // The dispatch seam: whatever SIMD path the host offers must produce
    // byte-for-byte the scalar result — exact i32 outputs AND noisy outputs
    // at a fixed stream key — on random ragged shapes including the
    // TILE_K±1 / TILE_N±1 packing edge cases (odd k exercises the
    // zero-padded k-pair lane, odd n the vector tails).
    use xtpu::exec::dispatch;
    use xtpu::exec::kernel::{ColumnNoise, KernelScratch};

    let paths = dispatch::available();
    assert_eq!(paths[0], dispatch::SimdPath::Scalar);
    let mut rng = Xoshiro256pp::seeded(0x51D5);
    let mut shapes: Vec<(usize, usize, usize)> = vec![
        (1, 1, 1),
        (2, kernel::TILE_K - 1, kernel::TILE_N - 1),
        (2, kernel::TILE_K + 1, kernel::TILE_N + 1),
        (3, kernel::TILE_K, kernel::TILE_N),
        (1, 784, 138),
        (64, 784, 128),
    ];
    for _ in 0..40 {
        shapes.push((1 + rng.index(17), 1 + rng.index(300), 1 + rng.index(300)));
    }
    let mut scratch = KernelScratch::new();
    for (i, &(m, k, n)) in shapes.iter().enumerate() {
        let (a, w) = random_mats(m, k, n, &mut rng);
        let mut wt = vec![0i8; n * k];
        for r in 0..k {
            for c in 0..n {
                wt[c * k + r] = w[r * n + c];
            }
        }
        let noise: Vec<ColumnNoise> = (0..n)
            .map(|c| {
                if c % 3 == 0 {
                    ColumnNoise::SILENT
                } else {
                    ColumnNoise { mean: c as f64 * 0.5, std: 40.0 + c as f64 }
                }
            })
            .collect();
        let key = 0xD15F + i as u64;
        let mut per_path: Vec<(Vec<i32>, Vec<i32>, Vec<i32>)> = Vec::new();
        for &path in &paths {
            let mut exact = Vec::new();
            kernel::matmul_i8_path(path, &a, &w, m, k, n, &mut exact, &mut scratch);
            let mut noisy = exact.clone();
            kernel::add_column_noise_keyed(&mut noisy, n, m, 0, &noise, key);
            let mut t = Vec::new();
            kernel::matmul_i8t_path(path, &a, &wt, m, k, n, &mut t);
            per_path.push((exact, noisy, t));
        }
        let reference = kernel::reference_matmul(&a, &w, m, k, n);
        assert_eq!(per_path[0].0, reference, "shape {i}: {m}×{k}×{n} scalar vs oracle");
        assert_eq!(per_path[0].2, reference, "shape {i}: {m}×{k}×{n} scalar i8t vs oracle");
        for (p, got) in per_path.iter().enumerate().skip(1) {
            let name = paths[p].name();
            assert_eq!(got.0, per_path[0].0, "shape {i}: {m}×{k}×{n} exact {name} vs scalar");
            assert_eq!(got.1, per_path[0].1, "shape {i}: {m}×{k}×{n} noisy {name} vs scalar");
            assert_eq!(got.2, per_path[0].2, "shape {i}: {m}×{k}×{n} i8t {name} vs scalar");
        }
    }
}

#[test]
fn statistical_backend_bit_identical_across_thread_counts() {
    let reg = synthetic_registry();
    let be = Statistical::new(reg);
    // Sizes above the kernel's parallel threshold so sharding really kicks
    // in, and batches spanning several LAYER_ROW_CHUNK stream chunks.
    let (m, k, n) = (192, 96, 24);
    let mut mrng = Xoshiro256pp::seeded(0xABCD);
    let (a, w) = random_mats(m, k, n, &mut mrng);
    let levels: Vec<usize> = (0..n).map(|c| c % 4).collect();

    let (fan_in, out, batch) = (64, 40, 200);
    let wq: Vec<i8> = (0..out * fan_in).map(|_| mrng.range_i64(-127, 127) as i8).collect();
    let xq: Vec<i8> = (0..batch * fan_in).map(|_| mrng.range_i64(-127, 127) as i8).collect();
    let mac = QuantMac {
        wq,
        fan_in,
        out,
        w_scale: 1.0,
        x_scale: 1.0,
        bias: vec![0.0; out],
        act: Activation::Linear,
    };
    // Mixed live/silent units: determinism must hold with draw-skipping.
    let mean: Vec<f64> = (0..out).map(|u| if u % 3 == 0 { 2.0 } else { 0.0 }).collect();
    let std: Vec<f64> = (0..out).map(|u| if u % 2 == 0 { 500.0 } else { 0.0 }).collect();

    // Restore (not delete) any pre-set XTPU_THREADS afterwards — the CI
    // matrix pins it for the whole test run.
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prior = std::env::var("XTPU_THREADS").ok();
    let mut mm_outs = Vec::new();
    let mut layer_outs = Vec::new();
    for threads in ["1", "2", "8"] {
        std::env::set_var("XTPU_THREADS", threads);
        let mut r1 = Xoshiro256pp::seeded(7);
        mm_outs.push(be.matmul_i8(&a, &w, m, k, n, &levels, &mut r1));
        let mut r2 = Xoshiro256pp::seeded(9);
        layer_outs.push(be.execute_layer(
            &mac,
            &xq,
            batch,
            Some(NoiseView::new(&mean, &std)),
            &mut r2,
        ));
    }
    match prior {
        Some(v) => std::env::set_var("XTPU_THREADS", v),
        None => std::env::remove_var("XTPU_THREADS"),
    }
    assert_eq!(mm_outs[0], mm_outs[1], "matmul differs between 1 and 2 threads");
    assert_eq!(mm_outs[0], mm_outs[2], "matmul differs between 1 and 8 threads");
    assert_eq!(layer_outs[0], layer_outs[1], "execute_layer differs between 1 and 2 threads");
    assert_eq!(layer_outs[0], layer_outs[2], "execute_layer differs between 1 and 8 threads");
}

#[test]
fn tedrop_backend_matches_exact_when_error_rates_are_zero() {
    // Property (degenerate-regime identity): with `error_rate == 0` at
    // EVERY ladder level, the TE-Drop backend is the Exact backend —
    // bit-identical outputs on ragged random shapes across thread counts
    // and every SIMD path the host offers, and the RNG stream is left
    // untouched (a silent fault pass draws no key). The registry keeps
    // *positive* noise variances, proving TE-Drop keys off the detection
    // probability alone, never the tolerate-regime moments.
    use xtpu::exec::dispatch;
    use xtpu::exec::kernel::KernelScratch;

    let reg = ErrorModelRegistry::synthetic_with_rates(
        &VoltageLadder::paper_default(),
        &[3.0e4, 1.0e4, 2.0e3, 0.0],
        &[0.0, 0.0, 0.0, 0.0],
    );
    let te = exec::TeDrop::new(reg);
    let mut srng = Xoshiro256pp::seeded(0x7ED0);
    let mut shapes: Vec<(usize, usize, usize)> = vec![
        (1, 1, 1),
        (2, kernel::TILE_K - 1, kernel::TILE_N - 1),
        (3, kernel::TILE_K + 1, kernel::TILE_N + 1),
        (64, 784, 128),
    ];
    for _ in 0..24 {
        shapes.push((1 + srng.index(33), 1 + srng.index(300), 1 + srng.index(96)));
    }

    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prior = std::env::var("XTPU_THREADS").ok();
    let mut scratch = KernelScratch::new();
    for (i, &(m, k, n)) in shapes.iter().enumerate() {
        let (a, w) = random_mats(m, k, n, &mut srng);
        // Every ladder level appears, including non-nominal ones — all
        // silent because their rates are zero.
        let levels: Vec<usize> = (0..n).map(|c| c % 4).collect();
        let mut outs: Vec<Vec<i32>> = Vec::new();
        for threads in ["1", "4"] {
            std::env::set_var("XTPU_THREADS", threads);
            let mut r_te = Xoshiro256pp::seeded(0xA11 + i as u64);
            let mut r_ex = r_te.clone();
            let got = te.matmul_i8(&a, &w, m, k, n, &levels, &mut r_te);
            let want = exec::Exact.matmul_i8(&a, &w, m, k, n, &levels, &mut r_ex);
            assert_eq!(got, want, "shape {i}: {m}×{k}×{n} at {threads} threads");
            assert_eq!(
                r_te.next_u64(),
                r_ex.next_u64(),
                "shape {i}: a zero-rate fault pass must not consume randomness"
            );
            outs.push(got);
        }
        assert_eq!(outs[0], outs[1], "shape {i}: {m}×{k}×{n} differs between 1 and 4 threads");
        // SIMD axis, forced through the dispatch seam (the backend's own
        // path is process-cached): every available path plus a zero-rate
        // drop pass reproduces the same bits.
        for &path in &dispatch::available() {
            let mut out = Vec::new();
            kernel::matmul_i8_path(path, &a, &w, m, k, n, &mut out, &mut scratch);
            kernel::drop_column_macs_keyed(
                &mut out,
                &a,
                &w,
                m,
                k,
                n,
                &vec![0.0; n],
                0x5EED ^ i as u64,
            );
            assert_eq!(out, outs[0], "shape {i}: {m}×{k}×{n} via {}", path.name());
        }
    }
    match prior {
        Some(v) => std::env::set_var("XTPU_THREADS", v),
        None => std::env::remove_var("XTPU_THREADS"),
    }
}

#[test]
fn statistical_column_moments_match_registry_predictions() {
    // The keyed per-column draw streams must not change the composed
    // statistics: measured per-column error mean/variance through
    // column_error_stats still match the registry's eq 11–13 predictions.
    let reg = synthetic_registry();
    let be = Statistical::new(reg.clone());
    let (m, k, n) = (6000, 16, 3);
    let mut rng = Xoshiro256pp::seeded(0xBEEF);
    let (a, w) = random_mats(m, k, n, &mut rng);
    let levels = [0usize, 1, 3]; // two overscaled columns + one nominal
    let stats = exec::column_error_stats(&be, &a, &w, m, k, n, &levels, &mut rng);
    let nominal = reg.ladder.len() - 1;
    for (c, &lvl) in levels.iter().enumerate() {
        let (mean, var) = stats[c];
        if lvl == nominal {
            assert_eq!(mean, 0.0, "nominal column {c} corrupted");
            assert_eq!(var, 0.0, "nominal column {c} corrupted");
            continue;
        }
        let model = reg.model(lvl);
        let pred_var = model.column_variance(k);
        let ratio = var / pred_var;
        assert!(
            (0.85..1.15).contains(&ratio),
            "col {c}: var {var:.3e} vs predicted {pred_var:.3e} (ratio {ratio:.2})"
        );
        let mean_tol = 6.0 * pred_var.sqrt() / (m as f64).sqrt();
        assert!(
            (mean - model.column_mean(k)).abs() < mean_tol,
            "col {c}: mean {mean:.2} vs predicted {:.2} (tol {mean_tol:.2})",
            model.column_mean(k)
        );
    }
}

// ---------------------------------------------------------------------------
// Server stress test
// ---------------------------------------------------------------------------

/// A backend that computes exactly (via the shared kernel) but rendezvouses
/// in `execute_layer`: the first caller blocks until a second caller enters
/// concurrently (or a generous timeout passes, so a serialized engine fails
/// the assertion instead of deadlocking). With the old global
/// `Mutex<Box<dyn Backend>>` engine the peak could never exceed 1.
#[derive(Clone, Default)]
struct Rendezvous {
    shared: Arc<RendezvousState>,
}

#[derive(Default)]
struct RendezvousState {
    inside: Mutex<usize>,
    cv: Condvar,
    peak: AtomicU64,
}

impl Backend for Rendezvous {
    fn name(&self) -> &'static str {
        "rendezvous"
    }

    #[allow(clippy::too_many_arguments)]
    fn matmul_i8(
        &self,
        a: &[i8],
        w: &[i8],
        m: usize,
        k: usize,
        n: usize,
        col_levels: &[usize],
        rng: &mut Xoshiro256pp,
    ) -> Vec<i32> {
        exec::Exact.matmul_i8(a, w, m, k, n, col_levels, rng)
    }

    fn execute_layer(
        &self,
        mac: &QuantMac,
        xq: &[i8],
        batch: usize,
        noise: Option<NoiseView<'_>>,
        rng: &mut Xoshiro256pp,
    ) -> Vec<i32> {
        {
            let mut inside = self.shared.inside.lock().unwrap();
            *inside += 1;
            self.shared.peak.fetch_max(*inside as u64, Ordering::SeqCst);
            self.shared.cv.notify_all();
            let deadline = std::time::Instant::now() + Duration::from_secs(5);
            while self.shared.peak.load(Ordering::SeqCst) < 2 {
                let now = std::time::Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) =
                    self.shared.cv.wait_timeout(inside, deadline - now).unwrap();
                inside = guard;
            }
        }
        let out = exec::execute_layer_kernel(mac, xq, batch, noise, rng);
        let mut inside = self.shared.inside.lock().unwrap();
        *inside -= 1;
        out
    }
}

fn stress_engine() -> (Engine, xtpu::nn::data::Dataset) {
    let mut rng = Xoshiro256pp::seeded(71);
    let mut model = fc_mnist(Activation::Relu, &mut rng);
    let train_set = synth_mnist(400, 72);
    train(&mut model, &train_set, &TrainConfig { epochs: 2, ..Default::default() });
    let test = synth_mnist(64, 73);
    let calib = test.batch(&(0..32).collect::<Vec<_>>()).0;
    let q = QuantizedModel::quantize(&model, &calib);
    let n = q.num_neurons();
    let mut noisy = NoiseSpec::silent(n);
    for s in noisy.std.iter_mut().take(128) {
        *s = 1500.0;
    }
    let levels = vec![
        QualityLevel {
            name: "exact".into(),
            noise: NoiseSpec::silent(n),
            energy_saving: 0.0,
            energy: 0.0,
            predicted_mse: 0.0,
        },
        QualityLevel {
            name: "eco".into(),
            noise: noisy,
            energy_saving: 0.3,
            energy: 0.0,
            predicted_mse: 0.0,
        },
    ];
    (Engine::new(q, levels, 784).unwrap(), test)
}

#[test]
fn server_stress_mixed_quality_concurrent_batches() {
    let (engine, test) = stress_engine();
    // Exact reference logits per test image: quality-0 responses must match
    // them (silent noise → deterministic forward, independent of batch
    // composition and thread count).
    let expected: Vec<Vec<f32>> = {
        let idx: Vec<usize> = (0..test.len()).collect();
        let (x, _) = test.batch(&idx);
        let mut rng = Xoshiro256pp::seeded(1);
        let logits = engine.quantized.forward(&x, None, &mut rng);
        (0..test.len()).map(|r| logits.row(r).to_vec()).collect()
    };

    let rendezvous = Rendezvous::default();
    let shared = rendezvous.shared.clone();
    // Share-nothing pool: four workers, each with its own backend instance
    // (they share only the rendezvous instrumentation).
    let engine = engine.with_backend_pool(
        (0..4).map(|_| Box::new(rendezvous.clone()) as Box<dyn Backend>).collect(),
    );
    let mut server = Server::spawn(
        engine,
        0,
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5), workers: 4 },
    )
    .unwrap();
    let addr = server.addr;

    let n_clients = 16;
    let per_client = 5;
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let expected = expected.clone();
            let test = test.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                for r in 0..per_client {
                    let idx = (c * per_client + r) % test.len();
                    // Mixed quality levels, including out-of-range (2 → 1).
                    let quality = (c + r) % 3;
                    let (_, logits, applied) =
                        client.infer_full(test.images.row(idx), quality).unwrap();
                    assert_eq!(logits.len(), 10, "client {c} req {r}");
                    assert_eq!(applied, quality.min(1), "client {c} req {r} quality");
                    if quality == 0 {
                        for (g, e) in logits.iter().zip(&expected[idx]) {
                            assert!(
                                (g - e).abs() <= 1e-4 * e.abs().max(1.0),
                                "client {c} req {r}: exact-quality logits drifted \
                                 ({g} vs {e})"
                            );
                        }
                    }
                }
            })
        })
        .collect();

    // Join with a watchdog so a deadlocked engine fails loudly instead of
    // hanging the test binary forever.
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    for h in handles {
        while !h.is_finished() {
            assert!(std::time::Instant::now() < deadline, "server deadlocked under load");
            std::thread::sleep(Duration::from_millis(10));
        }
        h.join().unwrap();
    }

    let requests = server.stats.requests.load(Ordering::Relaxed);
    let batches = server.stats.batches.load(Ordering::Relaxed);
    assert_eq!(requests, (n_clients * per_client) as u64);
    assert!(
        batches < requests,
        "dynamic batching never coalesced ({batches} batches for {requests} requests)"
    );
    // The engine-level view of the same fact, recorded by the workers.
    let peak_engine = server.stats.peak_concurrent_batches.load(Ordering::Relaxed);
    // The backend-level proof: two execute_layer calls overlapped in time.
    let peak_backend = shared.peak.load(Ordering::SeqCst);
    assert!(
        peak_backend >= 2,
        "batches never executed concurrently (backend peak {peak_backend}, \
         engine peak {peak_engine})"
    );
    server.shutdown();
}

/// Hot-swap under concurrent load: 16 clients hammer one quality level
/// while the main thread swaps the plan set three times. The invariants:
///
/// - **never drops**: every request gets a well-formed reply (no hangs,
///   no disconnects, no error lines);
/// - **never mixes**: every reply is tagged with exactly one generation,
///   and the applied noise provably belongs to that generation —
///   generation 0's level 0 is silent (logits must bit-match the clean
///   reference), every later generation's level 0 carries heavy noise
///   (logits must NOT match);
/// - per sequential client the observed generation is monotone
///   non-decreasing (a request enqueued after a reply from generation `g`
///   can never be served by a generation older than `g`);
/// - the per-generation audit counters conserve the request count.
#[test]
fn hot_swap_under_concurrent_load_never_drops_or_mixes() {
    let mut rng = Xoshiro256pp::seeded(91);
    let mut model = fc_mnist(Activation::Relu, &mut rng);
    let train_set = synth_mnist(400, 92);
    train(&mut model, &train_set, &TrainConfig { epochs: 2, ..Default::default() });
    let test = synth_mnist(64, 93);
    let calib = test.batch(&(0..32).collect::<Vec<_>>()).0;
    let q = QuantizedModel::quantize(&model, &calib);
    let n = q.num_neurons();
    let levels = vec![QualityLevel {
        name: "exact".into(),
        noise: NoiseSpec::silent(n),
        energy_saving: 0.0,
        energy: 0.0,
        predicted_mse: 0.0,
    }];
    let engine = Arc::new(Engine::new(q, levels, 784).unwrap());

    // Clean reference logits: what generation 0 must reproduce exactly.
    let expected: Vec<Vec<f32>> = {
        let idx: Vec<usize> = (0..test.len()).collect();
        let (x, _) = test.batch(&idx);
        let mut r = Xoshiro256pp::seeded(1);
        let logits = engine.quantized.forward(&x, None, &mut r);
        (0..test.len()).map(|r| logits.row(r).to_vec()).collect()
    };

    let mut server = Server::spawn_shared(
        engine.clone(),
        0,
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5), workers: 4 },
    )
    .unwrap();
    let addr = server.addr;

    let n_clients = 16usize;
    let per_client = 10usize;
    let swaps = 3u64;
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let expected = expected.clone();
            let test = test.clone();
            std::thread::spawn(move || -> Vec<u64> {
                let mut client = Client::connect(addr).unwrap();
                let mut gens = Vec::with_capacity(per_client);
                for r in 0..per_client {
                    let idx = (c * per_client + r) % test.len();
                    let (_, logits, applied, gen) =
                        client.infer_tagged(test.images.row(idx), 0).unwrap();
                    assert_eq!(applied, 0, "client {c} req {r}");
                    assert_eq!(logits.len(), 10, "client {c} req {r}");
                    let matches_clean = logits
                        .iter()
                        .zip(&expected[idx])
                        .all(|(g, e)| (g - e).abs() <= 1e-4 * e.abs().max(1.0));
                    if gen == 0 {
                        assert!(
                            matches_clean,
                            "client {c} req {r}: generation-0 reply must carry \
                             generation-0 (silent) noise"
                        );
                    } else {
                        assert!(
                            !matches_clean,
                            "client {c} req {r}: generation-{gen} reply carried \
                             generation-0 noise — generations mixed"
                        );
                    }
                    gens.push(gen);
                    std::thread::sleep(Duration::from_millis(1));
                }
                gens
            })
        })
        .collect();

    // Swap three generations in while the clients run. Every post-swap
    // set's single level carries obvious noise, so a mixed generation is
    // detectable from the logits alone.
    for s in 1..=swaps {
        std::thread::sleep(Duration::from_millis(8));
        let mut levels = engine.plan_set().levels.clone();
        levels[0].name = format!("exact_g{s}");
        for sd in levels[0].noise.std.iter_mut().take(128) {
            *sd = 5000.0;
        }
        let got = engine.swap_levels(levels).unwrap();
        assert_eq!(got, s, "swap generations must be sequential");
    }

    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    let mut all_gens: Vec<Vec<u64>> = Vec::new();
    for h in handles {
        while !h.is_finished() {
            assert!(std::time::Instant::now() < deadline, "server deadlocked under swap load");
            std::thread::sleep(Duration::from_millis(10));
        }
        all_gens.push(h.join().unwrap());
    }
    for (c, gens) in all_gens.iter().enumerate() {
        assert_eq!(gens.len(), per_client, "client {c} dropped requests");
        for w in gens.windows(2) {
            assert!(
                w[1] >= w[0],
                "client {c}: generation went backwards ({} after {})",
                w[1],
                w[0]
            );
        }
        assert!(gens.iter().all(|&g| g <= swaps), "client {c} saw unknown generation");
    }

    // After the last swap drains, new requests land on the final set.
    let mut client = Client::connect(addr).unwrap();
    let (_, _, _, gen) = client.infer_tagged(test.images.row(0), 0).unwrap();
    assert_eq!(gen, swaps, "post-swap request must serve the latest generation");

    // Audit counters conserve: every request is attributed to exactly one
    // generation.
    let stats = client.stats().unwrap();
    let per_gen = stats.get("per_generation").unwrap().as_obj().unwrap();
    let attributed: u64 =
        per_gen.values().map(|v| v.as_u64().unwrap()).sum();
    let total = server.stats.requests.load(Ordering::Relaxed);
    assert_eq!(total, (n_clients * per_client) as u64 + 1);
    assert_eq!(attributed, total, "per-generation counters must conserve requests");
    assert_eq!(server.stats.worker_panics.load(Ordering::Relaxed), 0);
    server.shutdown();
}
