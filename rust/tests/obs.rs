//! Observability integration tests, exercised over real TCP through the
//! public API:
//!
//! - the `{"stats": true}` schema is pinned by a golden file,
//! - JSON and text metrics expositions agree series-for-series,
//! - sampled traces reconstruct the full request path
//!   (admission → route → queue wait → batch assembly → kernel → reply),
//! - the online quality audit fires a [`QualityAlarm`] on a plan whose
//!   predicted MSE understates the injected error, and stays quiet when
//!   the model is honest — the acceptance property of the audit loop.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use xtpu::nn::data::{synth_mnist, Dataset};
use xtpu::nn::layers::Activation;
use xtpu::nn::model::fc_mnist;
use xtpu::nn::quant::{NoiseSpec, QuantizedModel};
use xtpu::nn::train::{train, TrainConfig};
use xtpu::obs::audit::AuditConfig;
use xtpu::server::{
    BatchPolicy, Client, Engine, FrontendMode, FrontendOptions, QualityLevel, Server,
};
use xtpu::util::json::Json;
use xtpu::util::rng::Xoshiro256pp;

/// Deterministic two-level engine (same fixture as `tests/serving.rs`).
/// `eco_predicted_mse` is the *claimed* output MSE of the noisy level —
/// the quantity the online audit verifies against observed reality.
fn build_engine(eco_predicted_mse: f64) -> (Engine, Dataset) {
    let mut rng = Xoshiro256pp::seeded(1);
    let mut model = fc_mnist(Activation::Relu, &mut rng);
    let train_set = synth_mnist(200, 5);
    train(&mut model, &train_set, &TrainConfig { epochs: 1, ..Default::default() });
    let test = synth_mnist(20, 6);
    let calib = test.batch(&(0..16).collect::<Vec<_>>()).0;
    let q = QuantizedModel::quantize(&model, &calib);
    let n = q.num_neurons();
    let mut noisy = NoiseSpec::silent(n);
    for s in noisy.std.iter_mut().take(128) {
        *s = 2000.0;
    }
    let levels = vec![
        QualityLevel {
            name: "exact".into(),
            noise: NoiseSpec::silent(n),
            energy_saving: 0.0,
            energy: 10.0,
            predicted_mse: 0.0,
        },
        QualityLevel {
            name: "eco".into(),
            noise: noisy,
            energy_saving: 0.3,
            energy: 7.0,
            predicted_mse: eco_predicted_mse,
        },
    ];
    (Engine::new(q, levels, 784).unwrap(), test)
}

fn spawn(eco_predicted_mse: f64, opts: FrontendOptions) -> (Server, Dataset) {
    let (engine, test) = build_engine(eco_predicted_mse);
    let policy = BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(2), workers: 1 };
    let server = Server::spawn_opts(vec![Arc::new(engine)], 0, policy, opts).unwrap();
    (server, test)
}

/// Wait (bounded) for an asynchronous server-side effect: the audit's
/// shadow execution and a span's ring commit both happen *after* the
/// client reply goes out, so tests observe them with a short poll.
fn poll<F: FnMut() -> bool>(mut f: F, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline {
        if f() {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("timed out waiting for {what}");
}

/// The stats-line key set is a protocol surface: pinned by
/// `golden_stats_schema.txt`, so exposition keys can't silently vanish.
#[test]
fn stats_line_schema_matches_golden_file() {
    let (mut server, test) = spawn(0.0, FrontendOptions::default());
    let mut c = Client::connect(server.addr).unwrap();
    c.infer(test.images.row(0), 0).unwrap();
    let stats = c.stats().unwrap();
    let Json::Obj(map) = &stats else { panic!("stats reply must be an object") };
    let got: Vec<&str> = map.keys().map(|s| s.as_str()).collect();
    let want: Vec<&str> = include_str!("golden_stats_schema.txt")
        .lines()
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .collect();
    assert_eq!(
        got, want,
        "stats-line schema drifted; update rust/tests/golden_stats_schema.txt deliberately"
    );
    server.shutdown();
}

/// JSON and text expositions must agree: same series ids, same values
/// (both render through the same number formatter).
#[test]
fn metrics_json_and_text_expositions_agree() {
    let (mut server, test) = spawn(0.0, FrontendOptions::default());
    let mut c = Client::connect(server.addr).unwrap();
    for i in 0..4 {
        c.infer(test.images.row(i), i % 2).unwrap();
    }
    // The worker finishes its bookkeeping (latency record, inflight
    // decrement) just after the last reply; snapshot only once idle so
    // the two expositions below see identical values.
    poll(
        || {
            server.stats.latency.count() >= 4
                && server.stats.inflight_batches.load(std::sync::atomic::Ordering::SeqCst)
                    == 0
        },
        "worker bookkeeping to settle",
    );
    let wire = c.metrics().unwrap();
    let text = server.stats.metrics_text();

    let mut by_id: BTreeMap<String, f64> = BTreeMap::new();
    for line in text.lines() {
        let (id, val) = line.rsplit_once(' ').expect("text line is `series value`");
        by_id.insert(id.to_string(), val.parse::<f64>().expect("numeric value"));
    }
    let Json::Obj(series) = wire.get("server").unwrap() else {
        panic!("metrics reply must carry a server object")
    };
    assert!(!series.is_empty(), "server registry must not be empty");
    for (id, v) in series {
        let got = *by_id
            .get(id)
            .unwrap_or_else(|| panic!("series {id} missing from text exposition"));
        let want = v.as_f64().unwrap();
        assert_eq!(got, want, "series {id}: text {got} vs json {want}");
    }
    // Load-bearing series are present and agree with the traffic sent.
    assert_eq!(series["server_requests_total"].as_u64().unwrap(), 4);
    assert_eq!(series["server_served_total{level=\"0\"}"].as_u64().unwrap(), 2);
    assert_eq!(series["server_served_total{level=\"1\"}"].as_u64().unwrap(), 2);
    assert_eq!(series["server_request_latency_us_count"].as_u64().unwrap(), 4);
    // The process-wide registry rides along: the exec kernel's dispatch
    // counter has seen at least our four layered forwards.
    let process = wire.get("process").unwrap();
    assert!(process.get("exec_layer_calls_total").unwrap().as_u64().unwrap() > 0);
    server.shutdown();
}

/// With `trace_sample = 1` every request records a span, and the chrome-
/// trace dump reconstructs the full pipeline path per request id.
#[test]
fn traces_reconstruct_the_full_request_path() {
    let opts = FrontendOptions {
        mode: FrontendMode::Evented,
        trace_sample: 1,
        ..FrontendOptions::default()
    };
    let (mut server, test) = spawn(0.0, opts);
    let mut c = Client::connect(server.addr).unwrap();
    for i in 0..3 {
        c.infer(test.images.row(i), 0).unwrap();
    }
    // A span commits to the ring when its job drops, just after the reply.
    poll(|| server.stats.tracer.len() >= 3, "3 trace records");
    let dump = c.trace(16).unwrap();
    assert_eq!(dump.get("displayTimeUnit").unwrap().as_str().unwrap(), "ms");
    let events = dump.get("traceEvents").unwrap().as_arr().unwrap();
    let mut by_id: BTreeMap<u64, Vec<&str>> = BTreeMap::new();
    for e in events {
        assert_eq!(e.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(e.get("cat").unwrap().as_str().unwrap(), "request");
        let id = e.get("args").unwrap().get("id").unwrap().as_u64().unwrap();
        by_id.entry(id).or_default().push(e.get("name").unwrap().as_str().unwrap());
    }
    assert_eq!(by_id.len(), 3, "one span per request");
    for (id, names) in &by_id {
        assert_eq!(
            names[..],
            ["admission", "route", "queue_wait", "batch_assembly", "kernel", "reply"],
            "request {id} did not reconstruct the full path"
        );
    }
    server.shutdown();
}

/// Acceptance criterion for the audit loop: a plan whose `predicted_mse`
/// understates the injected error raises [`QualityAlarm`] within the
/// sampling window; the same traffic against an honestly-modeled plan
/// stays quiet even after every group has been audited.
#[test]
fn mismodeled_plan_fires_quality_alarm_and_honest_plan_stays_quiet() {
    let audit = AuditConfig { sample_every: 1, band: (0.0, 2.0), min_samples: 1 };
    let opts = |audit: AuditConfig| FrontendOptions {
        mode: FrontendMode::Evented,
        audit,
        ..FrontendOptions::default()
    };

    // Mis-modeled: the noisy level injects std-2000 accumulator noise but
    // claims 1e-9 output MSE — observed/predicted leaves (0, 2] at once.
    let (mut bad, test) = spawn(1e-9, opts(audit.clone()));
    let mut c = Client::connect(bad.addr).unwrap();
    for i in 0..8 {
        c.infer(test.images.row(i), 1).unwrap();
    }
    poll(|| bad.stats.audit.alarm().is_some(), "quality alarm on the mis-modeled plan");
    let alarm = bad.stats.audit.alarm().unwrap();
    assert_eq!(alarm.level, 1);
    assert_eq!(alarm.level_name, "eco");
    assert_eq!(alarm.generation, 0, "no hot swap happened");
    assert!(alarm.ratio > 2.0, "out-of-band ratio, got {}", alarm.ratio);
    assert!(alarm.observed_mse > alarm.predicted_mse);
    // The alarm is a wire surface too, not just an internal flag.
    let stats = c.stats().unwrap();
    let wire_alarm = stats.get("quality_alarm").unwrap();
    assert_eq!(wire_alarm.get("level").unwrap().as_u64().unwrap(), 1);
    assert!(wire_alarm.get("ratio").unwrap().as_f64().unwrap() > 2.0);
    bad.shutdown();

    // Honest model: a generous (but finite) predicted MSE keeps the ratio
    // inside the band; and the exact level agrees bit-for-bit with its
    // shadow run. Neither may alarm, even once all groups are audited.
    let (mut good, test) = spawn(1e12, opts(audit));
    let mut c = Client::connect(good.addr).unwrap();
    for i in 0..8 {
        c.infer(test.images.row(i), i % 2).unwrap();
    }
    poll(
        || good.stats.audit.audited_rows() >= 8,
        "all groups audited on the honest plan",
    );
    assert!(good.stats.audit.alarm().is_none(), "honest plan must stay quiet");
    let stats = c.stats().unwrap();
    assert!(
        matches!(stats.get("quality_alarm").unwrap(), Json::Null),
        "wire stats must carry no alarm"
    );
    // Both levels were audited and their ratios are in band (the exact
    // level has no ratio — zero predicted MSE, zero observed error).
    let ratios = good.stats.audit.ratios();
    assert_eq!(ratios.len(), 2, "both (level, generation) keys audited");
    for (level, generation, observed, ratio, rows) in ratios {
        assert_eq!(generation, 0);
        assert!(rows >= 1);
        match level {
            0 => {
                assert!(observed == 0.0, "exact level must shadow bit-identically");
                assert!(ratio.is_none());
            }
            1 => {
                let r = ratio.unwrap();
                assert!(r > 0.0 && r <= 2.0, "in-band ratio, got {r}");
            }
            other => panic!("unexpected audited level {other}"),
        }
    }
    good.shutdown();
}
