//! Experiment configuration: one JSON-serializable struct drives the whole
//! Fig-4 pipeline (model choice, ladder, characterization depth, budgets,
//! solver). The CLI and examples construct these; benches use presets.

use crate::assign::Solver;
use crate::nn::layers::Activation;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// "fc_mnist" | "lenet5" | "resnet_tiny".
    pub model: String,
    /// Hidden-layer activation for the FC model.
    pub activation: Activation,
    pub train_samples: usize,
    pub test_samples: usize,
    pub epochs: usize,
    /// Voltage ladder (ascending, last = nominal).
    pub voltages: Vec<f64>,
    /// Monte-Carlo vectors per voltage level (paper: 10^6).
    pub characterize_samples: u64,
    /// MSE-increment upper bounds, as *fractions* of the nominal test MSE
    /// (paper sweeps 1 %…1000 % → 0.01…10.0).
    pub mse_ub_fractions: Vec<f64>,
    pub solver: Solver,
    pub seed: u64,
    /// Directory for artifacts (models, error models, HLO).
    pub artifacts_dir: String,
    /// Validation repetitions per budget (noise is stochastic).
    pub validation_runs: usize,
    /// Execution backend for validation/serving inference: "exact" |
    /// "statistical" | "tedrop" | "pjrt" (see [`crate::exec`]). Selects the
    /// level-driven matmul/artifact engine; per-neuron noise specs from a
    /// voltage assignment are injected identically on every backend.
    pub backend: String,
    /// Operating regime the planner prices levels under: "statistical"
    /// (tolerate, the paper's default) | "tedrop" (detect + drop, see
    /// [`crate::errormodel::PlanMode`]). Absent in pre-mode configs/plans
    /// and defaults to "statistical" on load.
    pub mode: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            model: "fc_mnist".into(),
            activation: Activation::Linear,
            train_samples: 4000,
            test_samples: 1000,
            epochs: 6,
            voltages: vec![0.5, 0.6, 0.7, 0.8],
            characterize_samples: 200_000,
            mse_ub_fractions: vec![0.01, 0.1, 0.5, 1.0, 2.0, 5.0, 10.0],
            solver: Solver::Ilp,
            seed: 0xA11CE,
            artifacts_dir: "artifacts".into(),
            validation_runs: 3,
            backend: "statistical".into(),
            mode: "statistical".into(),
        }
    }
}

impl ExperimentConfig {
    /// Small/fast preset for tests and smoke runs.
    pub fn smoke() -> Self {
        Self {
            train_samples: 600,
            test_samples: 200,
            epochs: 2,
            characterize_samples: 30_000,
            mse_ub_fractions: vec![0.1, 2.0],
            validation_runs: 1,
            ..Default::default()
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("activation", Json::Str(self.activation.name().into())),
            ("train_samples", Json::Num(self.train_samples as f64)),
            ("test_samples", Json::Num(self.test_samples as f64)),
            ("epochs", Json::Num(self.epochs as f64)),
            ("voltages", Json::arr_f64(&self.voltages)),
            ("characterize_samples", Json::Num(self.characterize_samples as f64)),
            ("mse_ub_fractions", Json::arr_f64(&self.mse_ub_fractions)),
            (
                "solver",
                Json::Str(
                    match self.solver {
                        Solver::Ilp => "ilp",
                        Solver::Greedy => "greedy",
                        Solver::Genetic => "genetic",
                    }
                    .into(),
                ),
            ),
            ("seed", Json::Num(self.seed as f64)),
            ("artifacts_dir", Json::Str(self.artifacts_dir.clone())),
            ("validation_runs", Json::Num(self.validation_runs as f64)),
            ("backend", Json::Str(self.backend.clone())),
            ("mode", Json::Str(self.mode.clone())),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let d = Self::default();
        Ok(Self {
            model: j
                .opt("model")
                .map(|v| v.as_str().map(String::from))
                .transpose()?
                .unwrap_or(d.model),
            activation: match j.opt("activation") {
                Some(v) => Activation::from_name(v.as_str()?)?,
                None => d.activation,
            },
            train_samples: opt_usize(j, "train_samples", d.train_samples)?,
            test_samples: opt_usize(j, "test_samples", d.test_samples)?,
            epochs: opt_usize(j, "epochs", d.epochs)?,
            voltages: match j.opt("voltages") {
                Some(v) => v.as_f64_vec()?,
                None => d.voltages,
            },
            characterize_samples: opt_usize(
                j,
                "characterize_samples",
                d.characterize_samples as usize,
            )? as u64,
            mse_ub_fractions: match j.opt("mse_ub_fractions") {
                Some(v) => v.as_f64_vec()?,
                None => d.mse_ub_fractions,
            },
            solver: match j.opt("solver") {
                Some(v) => Solver::from_name(v.as_str()?)?,
                None => d.solver,
            },
            seed: opt_usize(j, "seed", d.seed as usize)? as u64,
            artifacts_dir: j
                .opt("artifacts_dir")
                .map(|v| v.as_str().map(String::from))
                .transpose()?
                .unwrap_or(d.artifacts_dir),
            validation_runs: opt_usize(j, "validation_runs", d.validation_runs)?,
            backend: j
                .opt("backend")
                .map(|v| v.as_str().map(String::from))
                .transpose()?
                .unwrap_or(d.backend),
            mode: {
                let mode = j
                    .opt("mode")
                    .map(|v| v.as_str().map(String::from))
                    .transpose()?
                    .unwrap_or(d.mode);
                crate::errormodel::PlanMode::from_name(&mode)?;
                mode
            },
        })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_json(&crate::util::json::read_file(path)?)
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        crate::util::json::write_file(path, &self.to_json())
    }
}

fn opt_usize(j: &Json, key: &str, default: usize) -> anyhow::Result<usize> {
    match j.opt(key) {
        Some(v) => Ok(v.as_usize()?),
        None => Ok(default),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_json() {
        let mut c = ExperimentConfig::default();
        c.model = "lenet5".into();
        c.solver = Solver::Greedy;
        c.mse_ub_fractions = vec![0.5];
        c.backend = "exact".into();
        let j = c.to_json();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.model, "lenet5");
        assert_eq!(back.solver, Solver::Greedy);
        assert_eq!(back.mse_ub_fractions, vec![0.5]);
        assert_eq!(back.voltages, c.voltages);
        assert_eq!(back.backend, "exact");
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"model": "resnet_tiny"}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.model, "resnet_tiny");
        assert_eq!(c.epochs, ExperimentConfig::default().epochs);
        assert_eq!(c.voltages, vec![0.5, 0.6, 0.7, 0.8]);
    }

    #[test]
    fn bad_solver_rejected() {
        let j = Json::parse(r#"{"solver": "quantum"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn mode_defaults_roundtrips_and_rejects_unknown() {
        // Pre-mode JSON (no "mode" key) loads with the statistical default.
        let j = Json::parse(r#"{"model": "fc_mnist"}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().mode, "statistical");
        let mut c = ExperimentConfig::smoke();
        c.mode = "tedrop".into();
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.mode, "tedrop");
        let bad = Json::parse(r#"{"mode": "razor"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&bad).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("xtpu_cfg_test");
        let path = dir.join("cfg.json");
        let c = ExperimentConfig::smoke();
        c.save(&path).unwrap();
        let back = ExperimentConfig::load(&path).unwrap();
        assert_eq!(back.train_samples, c.train_samples);
        std::fs::remove_dir_all(&dir).ok();
    }
}
