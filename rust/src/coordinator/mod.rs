//! The X-TPU framework coordinator — the paper's Fig-4 flow, end to end:
//!
//! ```text
//! user inputs (quality constraint, arch params, NN model)
//!   → architecture characterization (gate-level VOS simulation)
//!   → statistical error models per voltage          (errormodel)
//!   → neuron error sensitivities                    (sensitivity)
//!   → ILP voltage assignment                        (ilp/assign)
//!   → <neuron, voltage> tuples → augmented weights  (assign/memory)
//!   → validation: noise-injected quantized inference (nn/quant)
//! ```
//!
//! [`Pipeline::prepare`] runs the heavy, budget-independent stages once
//! (training, characterization, ES); [`Pipeline::run_budget`] then sweeps
//! quality constraints cheaply — the structure the runtime-adjustable
//! X-TPU needs, since re-selecting a quality level must not re-characterize
//! the hardware.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::assign::{AssignmentProblem, Solver, VoltageAssignment};
use crate::config::ExperimentConfig;
use crate::errormodel::{CharacterizeOptions, ErrorModelRegistry};
use crate::exec::{self, Backend};
use crate::nn::data::{synth_cifar, synth_mnist, Dataset};
use crate::nn::model::{fc_mnist, lenet5, resnet_tiny, Model};
use crate::nn::quant::QuantizedModel;
use crate::nn::tensor::Tensor;
use crate::nn::train::{train, TrainConfig};
use crate::power::PePowerModel;
use crate::quality;
use crate::runtime::Runtime;
use crate::sensitivity::{statistical_es, EsOptions};
use crate::timing::baugh_wooley_8x8;
use crate::timing::circuits::pe_datapath;
use crate::timing::gate::i64_to_bits;
use crate::timing::sta::{clock_period, ChipInstance};
use crate::timing::voltage::{Technology, VoltageLadder};
use crate::timing::vos::VosSimulator;
use crate::timing::Netlist;
use crate::util::rng::Xoshiro256pp;

/// Everything the budget sweep needs, computed once.
pub struct PreparedSystem {
    pub model: Model,
    pub quantized: QuantizedModel,
    pub test: Dataset,
    pub registry: ErrorModelRegistry,
    pub power: PePowerModel,
    pub es: Vec<f64>,
    pub fan_in: Vec<usize>,
    /// Clean (quantized, nominal-voltage) logits on the test set.
    pub clean_logits: Tensor,
    pub baseline_accuracy: f64,
    /// Nominal test MSE vs one-hot targets — the reference the paper's
    /// "MSE increment %" bounds are relative to.
    pub baseline_mse: f64,
    pub train_seconds: f64,
    pub characterize_seconds: f64,
    pub es_seconds: f64,
}

/// Result of one quality-constraint point (one row of Fig 10/13/14).
#[derive(Clone, Debug)]
pub struct BudgetReport {
    pub mse_ub_fraction: f64,
    pub budget_abs: f64,
    pub assignment: VoltageAssignment,
    /// Measured output-MSE increment (noisy vs clean logits).
    pub validated_mse: f64,
    pub accuracy: f64,
    pub accuracy_drop: f64,
    pub violated: bool,
}

pub struct Pipeline {
    pub cfg: ExperimentConfig,
}

impl Pipeline {
    pub fn new(cfg: ExperimentConfig) -> Self {
        Self { cfg }
    }

    fn model_cache_path(&self) -> PathBuf {
        PathBuf::from(&self.cfg.artifacts_dir).join(format!(
            "models/{}_{}_s{}_n{}.json",
            self.cfg.model,
            self.cfg.activation.name(),
            self.cfg.seed,
            self.cfg.train_samples
        ))
    }

    fn registry_cache_path(&self) -> PathBuf {
        PathBuf::from(&self.cfg.artifacts_dir).join(format!(
            "error_models_s{}_n{}.json",
            self.cfg.seed, self.cfg.characterize_samples
        ))
    }

    /// Build (or load from cache) the trained float model + datasets.
    pub fn trained_model(&self) -> Result<(Model, Dataset, Dataset)> {
        let cfg = &self.cfg;
        let (train_set, test_set) = match cfg.model.as_str() {
            "resnet_tiny" => (
                synth_cifar(cfg.train_samples, cfg.seed ^ 0x11),
                synth_cifar(cfg.test_samples, cfg.seed ^ 0x22),
            ),
            _ => (
                synth_mnist(cfg.train_samples, cfg.seed ^ 0x11),
                synth_mnist(cfg.test_samples, cfg.seed ^ 0x22),
            ),
        };
        let cache = self.model_cache_path();
        if cache.exists() {
            if let Ok(m) = Model::load(&cache) {
                return Ok((m, train_set, test_set));
            }
        }
        let mut rng = Xoshiro256pp::seeded(cfg.seed);
        let mut model = match cfg.model.as_str() {
            "fc_mnist" => fc_mnist(cfg.activation, &mut rng),
            "lenet5" => lenet5(&mut rng),
            "resnet_tiny" => resnet_tiny(&mut rng),
            other => anyhow::bail!("unknown model '{other}'"),
        };
        let tc = TrainConfig {
            epochs: cfg.epochs,
            batch_size: 32,
            // FC nets train paper-style: MSE vs one-hot, so "MSE_UB as % of
            // nominal MSE" operates on the [0,1] output scale the paper
            // assumes; CNNs keep softmax cross-entropy.
            lr: if cfg.model == "fc_mnist" { 0.05 } else { 0.02 },
            momentum: 0.9,
            seed: cfg.seed,
            loss: if cfg.model == "fc_mnist" {
                crate::nn::train::Loss::Mse
            } else {
                crate::nn::train::Loss::SoftmaxCrossEntropy
            },
            log_every: 0,
        };
        train(&mut model, &train_set, &tc);
        model.save(&cache).context("caching trained model")?;
        Ok((model, train_set, test_set))
    }

    /// Characterize the PE multiplier (or load the cached registry).
    pub fn error_models(&self) -> Result<ErrorModelRegistry> {
        let tech = Technology::default();
        let ladder = VoltageLadder::new(&self.cfg.voltages, tech);
        let cache = self.registry_cache_path();
        if cache.exists() {
            if let Ok(reg) = ErrorModelRegistry::load(&cache, tech) {
                if reg.ladder.len() == ladder.len() {
                    return Ok(reg);
                }
            }
        }
        let netlist = baugh_wooley_8x8("pe_multiplier");
        let mut rng = Xoshiro256pp::seeded(self.cfg.seed ^ 0xC41);
        let chip = ChipInstance::sample(&netlist, &tech, &mut rng);
        let opts = CharacterizeOptions {
            samples: self.cfg.characterize_samples,
            seed: self.cfg.seed ^ 0xE44,
            ..Default::default()
        };
        let reg = ErrorModelRegistry::characterize(&netlist, &chip, &ladder, &opts);
        reg.save(&cache).ok();
        Ok(reg)
    }

    /// Measure the PE power model from gate-level switching activity.
    pub fn power_model(&self) -> PePowerModel {
        measure_power_model(self.cfg.seed)
    }

    /// Construct the inference [`Backend`] the experiment config selects
    /// (`exact` | `statistical` | `pjrt`); validation and serving both run
    /// through this seam. The cycle/gate-accurate backend is constructed
    /// explicitly via [`exec::GateLevel`] (it needs a characterized chip
    /// and is orders of magnitude slower — see [`backend_cross_check`]).
    pub fn make_backend(&self, registry: &ErrorModelRegistry) -> Result<Box<dyn Backend>> {
        match self.cfg.backend.as_str() {
            "exact" => Ok(Box::new(exec::Exact)),
            "statistical" => Ok(Box::new(exec::Statistical::new(registry.clone()))),
            "pjrt" => {
                // Root the runtime at the experiment's artifacts dir (the
                // same one the model/registry caches use), not the global
                // default, so `--artifacts` is honored.
                let dir = PathBuf::from(&self.cfg.artifacts_dir);
                let rt = Runtime::new(&dir)?;
                Ok(Box::new(exec::Pjrt::new(rt).with_registry(registry.clone())))
            }
            other => anyhow::bail!("unknown backend '{other}' (exact|statistical|pjrt)"),
        }
    }

    /// One backend instance per serving worker — the share-nothing pool
    /// [`crate::server::Engine::with_backend_pool`] installs so concurrent
    /// batches never contend even on backends with interior state.
    pub fn make_backend_pool(
        &self,
        registry: &ErrorModelRegistry,
        workers: usize,
    ) -> Result<Vec<Box<dyn Backend>>> {
        (0..workers.max(1)).map(|_| self.make_backend(registry)).collect()
    }

    /// Run the budget-independent stages.
    pub fn prepare(&self) -> Result<PreparedSystem> {
        let t0 = std::time::Instant::now();
        let (model, _train_set, test) = self.trained_model()?;
        let train_seconds = t0.elapsed().as_secs_f64();

        let t0 = std::time::Instant::now();
        let registry = self.error_models()?;
        let power = self.power_model();
        let characterize_seconds = t0.elapsed().as_secs_f64();

        // Quantize with a calibration slice of the test distribution.
        let calib_n = test.len().min(64);
        let calib = test.batch(&(0..calib_n).collect::<Vec<_>>()).0;
        let quantized = QuantizedModel::quantize(&model, &calib);

        // ES per neuron (statistical injection, probe batch from test set).
        let t0 = std::time::Instant::now();
        let probe_n = test.len().min(16);
        let probe = test.batch(&(0..probe_n).collect::<Vec<_>>()).0;
        let es = statistical_es(
            &quantized,
            &probe,
            &EsOptions { trials: 2, ..Default::default() },
        );
        let es_seconds = t0.elapsed().as_secs_f64();

        let neurons = model.neurons();
        let fan_in: Vec<usize> = neurons.iter().map(|n| n.fan_in).collect();

        // Clean logits + baselines on the full test set, through the
        // configured execution backend.
        let backend = self.make_backend(&registry)?;
        let mut rng = Xoshiro256pp::seeded(self.cfg.seed ^ 0x7EA);
        let idx: Vec<usize> = (0..test.len()).collect();
        let (x, labels) = test.batch(&idx);
        let clean_logits = quantized.forward_with(backend.as_ref(), &x, None, &mut rng);
        let baseline_accuracy = quality::accuracy(&clean_logits, &labels);
        let baseline_mse = baseline_mse_vs_onehot(&clean_logits, &labels);

        Ok(PreparedSystem {
            model,
            quantized,
            test,
            registry,
            power,
            es,
            fan_in,
            clean_logits,
            baseline_accuracy,
            baseline_mse,
            train_seconds,
            characterize_seconds,
            es_seconds,
        })
    }

    /// Solve + validate one quality constraint.
    pub fn run_budget(&self, sys: &PreparedSystem, fraction: f64) -> Result<BudgetReport> {
        self.run_budget_with(sys, fraction, self.cfg.solver)
    }

    pub fn run_budget_with(
        &self,
        sys: &PreparedSystem,
        fraction: f64,
        solver: Solver,
    ) -> Result<BudgetReport> {
        let budget_abs = fraction * sys.baseline_mse;
        let problem =
            AssignmentProblem::build(&sys.es, &sys.fan_in, &sys.registry, &sys.power, budget_abs);
        let assignment = problem.solve(solver)?;
        let noise = problem.noise_spec(&assignment, &sys.registry);

        // Validation: noise-injected quantized inference over the test set,
        // on the configured execution backend.
        let backend = self.make_backend(&sys.registry)?;
        let idx: Vec<usize> = (0..sys.test.len()).collect();
        let (x, labels) = sys.test.batch(&idx);
        let mut mse_sum = 0.0;
        let mut acc_sum = 0.0;
        for run in 0..self.cfg.validation_runs.max(1) {
            let mut rng = Xoshiro256pp::seeded(self.cfg.seed ^ 0x9A11 ^ (run as u64) << 8);
            let noisy = sys.quantized.forward_with(backend.as_ref(), &x, Some(&noise), &mut rng);
            mse_sum += quality::batch_mse(&sys.clean_logits, &noisy);
            acc_sum += quality::accuracy(&noisy, &labels);
        }
        let runs = self.cfg.validation_runs.max(1) as f64;
        let validated_mse = mse_sum / runs;
        let accuracy = acc_sum / runs;
        Ok(BudgetReport {
            mse_ub_fraction: fraction,
            budget_abs,
            validated_mse,
            accuracy,
            accuracy_drop: sys.baseline_accuracy - accuracy,
            violated: validated_mse > budget_abs * 1.05 + 1e-12,
            assignment,
        })
    }

    /// The full sweep (Fig 10/13/14 rows).
    pub fn run(&self) -> Result<(PreparedSystem, Vec<BudgetReport>)> {
        let sys = self.prepare()?;
        let mut reports = Vec::new();
        for &f in &self.cfg.mse_ub_fractions {
            reports.push(self.run_budget(&sys, f)?);
        }
        Ok((sys, reports))
    }
}

/// Paper-style nominal MSE: quantized clean logits vs one-hot targets on
/// the test set (the "nominal value of the NN model … acquired using the
/// test dataset" that MSE_UB percentages are relative to).
pub fn baseline_mse_vs_onehot(logits: &Tensor, labels: &[u8]) -> f64 {
    let classes = logits.shape[1];
    let mut onehot = vec![0f32; logits.data.len()];
    for (r, &l) in labels.iter().enumerate() {
        onehot[r * classes + l as usize] = 1.0;
    }
    quality::mse(&onehot, &logits.data)
}

/// Measure the PE power model by running the gate-level PE datapath on a
/// random stimulus and attributing switching energy per region (Fig 1b).
pub fn measure_power_model(seed: u64) -> PePowerModel {
    let pe = pe_datapath(24);
    let tech = Technology::default();
    let chip = ChipInstance::ideal(&pe.netlist);
    let clock = clock_period(&pe.netlist, &chip, &tech);
    let mut sim =
        VosSimulator::new(&pe.netlist, chip.delays_at(&pe.netlist, &tech, tech.v_nominal), clock);
    let mut rng = Xoshiro256pp::seeded(seed ^ 0xA0);
    let cycles = 3000u64;
    for _ in 0..cycles {
        let a = rng.range_i64(-128, 127);
        let w = rng.range_i64(-128, 127);
        let p = rng.range_i64(-(1 << 20), 1 << 20);
        let packed: i64 = (a & 0xFF) | ((w & 0xFF) << 8) | ((p & 0xFF_FFFF) << 16);
        sim.step(&i64_to_bits(packed, 40));
    }
    PePowerModel::from_simulation(&pe, sim.toggle_counts(), cycles, tech)
}

/// Cross-validate an assignment on the statistical execution backend: run
/// the FC model's first layer as a batched matmul with the assignment's
/// column levels and compare measured column-error variance with the
/// registry's prediction. Returns (measured, predicted) summed over
/// overscaled columns.
pub fn systolic_cross_check(
    sys: &PreparedSystem,
    assignment: &VoltageAssignment,
    samples: usize,
    seed: u64,
) -> Result<(f64, f64)> {
    use crate::nn::quant::QLayer;
    let mac = sys
        .quantized
        .layers
        .iter()
        .find_map(|l| match l {
            QLayer::Dense(m) => Some(m),
            _ => None,
        })
        .context("needs a dense layer")?;
    let k = mac.fan_in;
    let n = mac.out;
    // Column-major weight matrix for the array (w[k×n]).
    let mut w = vec![0i8; k * n];
    for u in 0..n {
        for i in 0..k {
            w[i * n + u] = mac.wq[u * k + i];
        }
    }
    let levels: Vec<usize> = assignment.level[..n].to_vec();
    let backend = exec::Statistical::new(sys.registry.clone());
    let mut rng = Xoshiro256pp::seeded(seed);
    let a: Vec<i8> = (0..samples * k).map(|_| rng.range_i64(-127, 127) as i8).collect();
    let stats =
        exec::column_error_stats(&backend, &a, &w, samples, k, n, &levels, &mut rng);
    let mut measured = 0.0;
    let mut predicted = 0.0;
    let nominal = sys.registry.ladder.len() - 1;
    for (c, &lvl) in levels.iter().enumerate() {
        if lvl == nominal {
            continue;
        }
        measured += stats[c].1;
        predicted += sys.registry.model(lvl).column_variance(k);
    }
    Ok((measured, predicted))
}

/// Backend cross-validation (extends [`systolic_cross_check`] down to the
/// gates): run one `m×k×n` matmul through BOTH the [`exec::Statistical`]
/// fast path and the cycle-level [`exec::GateLevel`] array built from the
/// same characterized chip, and return the per-column `(mean, variance)`
/// of the injected error for each. The two must agree within sampling
/// tolerance — that agreement is what licenses the statistical backend as
/// a stand-in for gate-level simulation everywhere else.
#[allow(clippy::too_many_arguments)]
pub fn backend_cross_check(
    netlist: &Netlist,
    chip: &ChipInstance,
    registry: &ErrorModelRegistry,
    m: usize,
    k: usize,
    n: usize,
    col_levels: &[usize],
    seed: u64,
) -> (Vec<(f64, f64)>, Vec<(f64, f64)>) {
    let mut rng = Xoshiro256pp::seeded(seed);
    let a: Vec<i8> = (0..m * k).map(|_| rng.range_i64(-127, 127) as i8).collect();
    let w: Vec<i8> = (0..k * n).map(|_| rng.range_i64(-127, 127) as i8).collect();

    let stat = exec::Statistical::new(registry.clone());
    let mut stat_rng = Xoshiro256pp::seeded(seed ^ 0x57A7);
    let stat_stats =
        exec::column_error_stats(&stat, &a, &w, m, k, n, col_levels, &mut stat_rng);

    let gate = exec::GateLevel::new(
        k,
        n,
        netlist.clone(),
        chip.clone(),
        registry.ladder.clone(),
    );
    let mut gate_rng = Xoshiro256pp::seeded(seed ^ 0x6A7E);
    let gate_stats =
        exec::column_error_stats(&gate, &a, &w, m, k, n, col_levels, &mut gate_rng);

    (stat_stats, gate_stats)
}
