//! The X-TPU framework coordinator — a thin orchestration shell over the
//! staged [`crate::plan::Planner`], exposing the paper's Fig-4 flow as one
//! experiment-facing API:
//!
//! ```text
//! user inputs (quality constraint, arch params, NN model)
//!   → architecture characterization (gate-level VOS simulation)
//!   → statistical error models per voltage          (errormodel)
//!   → neuron error sensitivities                    (sensitivity)
//!   → ILP voltage assignment                        (ilp/assign/plan)
//!   → <neuron, voltage> tuples → augmented weights  (assign/memory)
//!   → validation: noise-injected quantized inference (nn/quant)
//! ```
//!
//! The heavy lifting lives in the planner's stages, each cached (in memory
//! and — for the trained model, error-model registry, and ES vector — on
//! disk): [`Pipeline::prepare`] warms every budget-independent stage once;
//! [`Pipeline::run_budget`] solves + validates one quality constraint; and
//! [`Pipeline::run`] sweeps all configured budgets with the **solves and
//! validations fanned out in parallel** on [`crate::util::threadpool`] —
//! each budget's work is deterministic given the prepared stages, so the
//! parallel sweep is bit-identical to [`Pipeline::run_sequential`].
//!
//! What the coordinator itself still owns is validation (noise-injected
//! inference vs clean logits) and the cross-checks that tie the fast
//! statistical path back to the gate level; everything producible offline
//! as an artifact is a [`crate::plan::VoltagePlan`].

use anyhow::{Context, Result};

use crate::assign::{Solver, VoltageAssignment};
use crate::config::ExperimentConfig;
use crate::errormodel::ErrorModelRegistry;
use crate::exec::{self, Backend};
use crate::nn::data::Dataset;
use crate::nn::model::Model;
use crate::nn::quant::QuantizedModel;
use crate::nn::tensor::Tensor;
use crate::plan::{Planner, VoltagePlan};
use crate::power::PePowerModel;
use crate::quality;
use crate::timing::sta::ChipInstance;
use crate::timing::Netlist;
use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool::{parallel_chunks_capped, worker_count};

// The stage implementations live with the planner; re-exported here for
// the benches/examples that used the coordinator paths.
pub use crate::plan::{baseline_mse_vs_onehot, measure_power_model};

/// Everything the budget sweep needs, computed once.
pub struct PreparedSystem {
    pub model: Model,
    pub quantized: QuantizedModel,
    pub test: Dataset,
    pub registry: ErrorModelRegistry,
    pub power: PePowerModel,
    pub es: Vec<f64>,
    pub fan_in: Vec<usize>,
    /// Clean (quantized, nominal-voltage) logits on the test set.
    pub clean_logits: Tensor,
    pub baseline_accuracy: f64,
    /// Nominal test MSE vs one-hot targets — the reference the paper's
    /// "MSE increment %" bounds are relative to.
    pub baseline_mse: f64,
    /// Fingerprint of the trained model (embedded in every plan).
    pub fingerprint: String,
    pub train_seconds: f64,
    pub characterize_seconds: f64,
    pub es_seconds: f64,
}

/// Result of one quality-constraint point (one row of Fig 10/13/14): the
/// deployable plan plus its measured validation.
#[derive(Clone, Debug)]
pub struct BudgetReport {
    pub mse_ub_fraction: f64,
    pub budget_abs: f64,
    pub assignment: VoltageAssignment,
    /// The serializable artifact of this solve (what `xtpu plan` writes).
    pub plan: VoltagePlan,
    /// Measured output-MSE increment (noisy vs clean logits).
    pub validated_mse: f64,
    pub accuracy: f64,
    pub accuracy_drop: f64,
    pub violated: bool,
}

pub struct Pipeline {
    pub cfg: ExperimentConfig,
}

impl Pipeline {
    pub fn new(cfg: ExperimentConfig) -> Self {
        Self { cfg }
    }

    /// A fresh staged planner for this experiment config.
    pub fn planner(&self) -> Planner {
        Planner::new(self.cfg.clone())
    }

    /// Build (or load from cache) the trained float model + datasets.
    pub fn trained_model(&self) -> Result<(Model, Dataset, Dataset)> {
        crate::plan::train_model(&self.cfg)
    }

    /// Characterize the PE multiplier (or load the cached registry).
    pub fn error_models(&self) -> Result<ErrorModelRegistry> {
        crate::plan::characterize_registry(&self.cfg)
    }

    /// Measure the PE power model from gate-level switching activity.
    pub fn power_model(&self) -> PePowerModel {
        measure_power_model(self.cfg.seed)
    }

    /// Construct the inference [`Backend`] the experiment config selects
    /// (`exact` | `statistical` | `pjrt`); validation and serving both run
    /// through this seam. The cycle/gate-accurate backend is constructed
    /// explicitly via [`exec::GateLevel`] (it needs a characterized chip
    /// and is orders of magnitude slower — see [`backend_cross_check`]).
    pub fn make_backend(&self, registry: &ErrorModelRegistry) -> Result<Box<dyn Backend>> {
        crate::plan::make_backend(&self.cfg, registry)
    }

    /// One backend instance per serving worker — the share-nothing pool
    /// [`crate::server::Engine::with_backend_pool`] installs so concurrent
    /// batches never contend even on backends with interior state.
    pub fn make_backend_pool(
        &self,
        registry: &ErrorModelRegistry,
        workers: usize,
    ) -> Result<Vec<Box<dyn Backend>>> {
        crate::plan::make_backend_pool(&self.cfg, registry, workers)
    }

    /// Run the budget-independent stages (planner stages 1–5).
    pub fn prepare(&self) -> Result<PreparedSystem> {
        let mut planner = self.planner();
        planner.warm()?;
        let (trained, registry, characterize_seconds, power, es, baseline) =
            planner.into_stages();
        Ok(PreparedSystem {
            model: trained.model,
            quantized: trained.quantized,
            test: trained.test,
            registry,
            power,
            es: es.es,
            fan_in: es.fan_in,
            clean_logits: baseline.clean_logits,
            baseline_accuracy: baseline.accuracy,
            baseline_mse: baseline.mse,
            fingerprint: trained.fingerprint,
            train_seconds: trained.seconds,
            characterize_seconds,
            es_seconds: es.seconds,
        })
    }

    /// Solve + validate one quality constraint.
    pub fn run_budget(&self, sys: &PreparedSystem, fraction: f64) -> Result<BudgetReport> {
        self.run_budget_with(sys, fraction, self.cfg.solver)
    }

    pub fn run_budget_with(
        &self,
        sys: &PreparedSystem,
        fraction: f64,
        solver: Solver,
    ) -> Result<BudgetReport> {
        // Shared with Planner::solve_many — one plan-assembly path, so the
        // plan in this report is identical to what `xtpu plan` emits.
        let (assignment, plan) = crate::plan::solve_one(
            &self.cfg,
            &sys.fingerprint,
            &sys.es,
            &sys.fan_in,
            &sys.registry,
            &sys.power,
            sys.baseline_mse,
            fraction,
            solver,
        )?;
        let budget_abs = plan.budget_abs;
        let noise = plan.noise_spec(&sys.registry);

        // Validation: noise-injected quantized inference over the test set,
        // on the configured execution backend.
        let backend = self.make_backend(&sys.registry)?;
        let idx: Vec<usize> = (0..sys.test.len()).collect();
        let (x, labels) = sys.test.batch(&idx);
        let mut mse_sum = 0.0;
        let mut acc_sum = 0.0;
        for run in 0..self.cfg.validation_runs.max(1) {
            let mut rng = Xoshiro256pp::seeded(self.cfg.seed ^ 0x9A11 ^ (run as u64) << 8);
            let noisy = sys.quantized.forward_with(backend.as_ref(), &x, Some(&noise), &mut rng);
            mse_sum += quality::batch_mse(&sys.clean_logits, &noisy);
            acc_sum += quality::accuracy(&noisy, &labels);
        }
        let runs = self.cfg.validation_runs.max(1) as f64;
        let validated_mse = mse_sum / runs;
        let accuracy = acc_sum / runs;
        Ok(BudgetReport {
            mse_ub_fraction: fraction,
            budget_abs,
            validated_mse,
            accuracy,
            accuracy_drop: sys.baseline_accuracy - accuracy,
            violated: validated_mse > budget_abs * 1.05 + 1e-12,
            assignment,
            plan,
        })
    }

    /// The full sweep (Fig 10/13/14 rows), with the per-budget solve +
    /// validation fanned out across the thread pool. Every budget seeds its
    /// own RNGs and owns its backend, so the reports are **bit-identical**
    /// to [`Pipeline::run_sequential`] regardless of worker count or
    /// completion order.
    pub fn run(&self) -> Result<(PreparedSystem, Vec<BudgetReport>)> {
        let sys = self.prepare()?;
        let fractions = self.cfg.mse_ub_fractions.clone();
        // Each budget's validation matmuls already shard across
        // `XTPU_THREADS`, so cap the outer fan-out (like
        // `BatchPolicy::workers` does for serving) instead of multiplying
        // the two thread populations to N×N.
        let outer = worker_count().clamp(1, 4);
        let parts = parallel_chunks_capped(fractions.len(), outer, |range, _| {
            range
                .map(|i| self.run_budget(&sys, fractions[i]))
                .collect::<Vec<Result<BudgetReport>>>()
        });
        let reports = parts
            .into_iter()
            .flatten()
            .collect::<Result<Vec<_>>>()
            .context("budget sweep")?;
        Ok((sys, reports))
    }

    /// The pre-refactor sweep shape: one budget after another on the
    /// calling thread. Kept as the reference the parallel [`Pipeline::run`]
    /// is tested against.
    pub fn run_sequential(&self) -> Result<(PreparedSystem, Vec<BudgetReport>)> {
        let sys = self.prepare()?;
        let mut reports = Vec::new();
        for &f in &self.cfg.mse_ub_fractions {
            reports.push(self.run_budget(&sys, f)?);
        }
        Ok((sys, reports))
    }
}

/// Cross-validate an assignment on the statistical execution backend: run
/// the FC model's first layer as a batched matmul with the assignment's
/// column levels and compare measured column-error variance with the
/// registry's prediction. Returns (measured, predicted) summed over
/// overscaled columns.
pub fn systolic_cross_check(
    sys: &PreparedSystem,
    assignment: &VoltageAssignment,
    samples: usize,
    seed: u64,
) -> Result<(f64, f64)> {
    use crate::nn::quant::QLayer;
    let mac = sys
        .quantized
        .layers
        .iter()
        .find_map(|l| match l {
            QLayer::Dense(m) => Some(m),
            _ => None,
        })
        .context("needs a dense layer")?;
    let k = mac.fan_in;
    let n = mac.out;
    // Column-major weight matrix for the array (w[k×n]).
    let mut w = vec![0i8; k * n];
    for u in 0..n {
        for i in 0..k {
            w[i * n + u] = mac.wq[u * k + i];
        }
    }
    let levels: Vec<usize> = assignment.level[..n].to_vec();
    let backend = exec::Statistical::new(sys.registry.clone());
    let mut rng = Xoshiro256pp::seeded(seed);
    let a: Vec<i8> = (0..samples * k).map(|_| rng.range_i64(-127, 127) as i8).collect();
    let stats =
        exec::column_error_stats(&backend, &a, &w, samples, k, n, &levels, &mut rng);
    let mut measured = 0.0;
    let mut predicted = 0.0;
    let nominal = sys.registry.ladder.len() - 1;
    for (c, &lvl) in levels.iter().enumerate() {
        if lvl == nominal {
            continue;
        }
        measured += stats[c].1;
        predicted += sys.registry.model(lvl).column_variance(k);
    }
    Ok((measured, predicted))
}

/// Backend cross-validation (extends [`systolic_cross_check`] down to the
/// gates): run one `m×k×n` matmul through BOTH the [`exec::Statistical`]
/// fast path and the cycle-level [`exec::GateLevel`] array built from the
/// same characterized chip, and return the per-column `(mean, variance)`
/// of the injected error for each. The two must agree within sampling
/// tolerance — that agreement is what licenses the statistical backend as
/// a stand-in for gate-level simulation everywhere else.
#[allow(clippy::too_many_arguments)]
pub fn backend_cross_check(
    netlist: &Netlist,
    chip: &ChipInstance,
    registry: &ErrorModelRegistry,
    m: usize,
    k: usize,
    n: usize,
    col_levels: &[usize],
    seed: u64,
) -> (Vec<(f64, f64)>, Vec<(f64, f64)>) {
    let mut rng = Xoshiro256pp::seeded(seed);
    let a: Vec<i8> = (0..m * k).map(|_| rng.range_i64(-127, 127) as i8).collect();
    let w: Vec<i8> = (0..k * n).map(|_| rng.range_i64(-127, 127) as i8).collect();

    let stat = exec::Statistical::new(registry.clone());
    let mut stat_rng = Xoshiro256pp::seeded(seed ^ 0x57A7);
    let stat_stats =
        exec::column_error_stats(&stat, &a, &w, m, k, n, col_levels, &mut stat_rng);

    let gate = exec::GateLevel::new(
        k,
        n,
        netlist.clone(),
        chip.clone(),
        registry.ladder.clone(),
    );
    let mut gate_rng = Xoshiro256pp::seeded(seed ^ 0x6A7E);
    let gate_stats =
        exec::column_error_stats(&gate, &a, &w, m, k, n, col_levels, &mut gate_rng);

    (stat_stats, gate_stats)
}
