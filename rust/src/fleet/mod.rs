//! Aging-aware multi-device fleet simulator: wear-leveled routing over a
//! pool of X-TPUs serving deployable [`VoltagePlan`]s.
//!
//! The paper's headline is double: quality-budgeted VOS saves energy
//! **and** extends lifetime, because lower V_DD exerts quadratically less
//! BTI oxide stress (§III.A eqs 1–2, §V.C Fig 15). A single simulated
//! device can demonstrate the first claim; the second only becomes an
//! *operational* lever at fleet scale, where a scheduler chooses **which**
//! device absorbs which voltage mix. This module builds that layer:
//!
//! - [`Device`] — one accelerator: a handle on the serving
//!   [`Engine`](crate::server::Engine) (device `i` executes on
//!   backend-pool slot `i`), a virtual-time queue, and a live
//!   [`StressAccount`](crate::aging::StressAccount) fed by the
//!   fan-in-weighted voltage shares of every plan it serves.
//! - [`Router`]/[`RoutePolicy`] — pluggable dispatch:
//!   [`RoundRobin`], [`LeastLoaded`], and the aging-aware
//!   [`WearLeveling`] policy.
//! - [`Trace`] — open-loop Poisson and closed-loop client populations
//!   with a configurable quality mix.
//! - [`ReplanPolicy`] / [`AdaptiveContext`] — the closed loop: each
//!   device watches its
//!   [`StressAccount::delay_margin`](crate::aging::StressAccount::delay_margin)
//!   and, policy permitting, re-solves its deployed plans against its
//!   accrued ΔVth through
//!   [`resolve_plan_from`](crate::plan::resolve_plan_from) on a
//!   drift-aware registry
//!   ([`ErrorModelRegistry::drifted`](crate::errormodel::ErrorModelRegistry::drifted)).
//! - [`FleetTelemetry`] — the JSON report: per-device requests / energy /
//!   duty histogram / projected lifetime / plan generation, fleet latency
//!   percentiles, aggregate energy saving vs all-nominal, and — for
//!   adaptive runs — re-plan events, quality-vs-age curves, and the worst
//!   served-MSE-to-budget ratio.
//!
//! ## The wear-leveling policy, and its relation to paper §V.C
//!
//! Section V.C evaluates a PE whose operating voltage is distributed over
//! the ladder instead of pinned at nominal and reads a ≈ 12 % lifetime
//! improvement off the aged-delay axis of Fig 15b. The fleet router turns
//! that passive observation into a control loop. In the transformed
//! stress coordinate `x = ΔVth^{1/α}`, eq. 1 becomes *linear* in time
//! (`dx = rate(V_DD)·dt`, [`BtiModel::stress_rate`]), so each device owns
//! a scalar wear level and a scalar headroom `x_crit − x` where `x_crit`
//! is the guard-band limit ([`BtiModel::critical_delta_vth`]). Because
//! `rate` scales like `E_OX^{γ/α}` (γ ≈ 4.3, α ≈ 0.2), the all-nominal
//! plan ages silicon ~10 orders of magnitude faster than an
//! aggressive-VOS plan — traffic classes are wildly unequal stressors.
//! Wear leveling exploits exactly that asymmetry: steer the
//! low-quality/low-voltage traffic (near-zero stress) to the most-worn
//! devices and the nominal-voltage traffic to the devices with the most
//! headroom, re-ranking every `rebalance_every` picks (the granularity at
//! which a deployment would re-flash which device holds the
//! aggressive-VOS voltage-selection bits, Fig 7). This water-fills
//! headroom across the fleet and maximizes the *minimum* projected device
//! lifetime — the fleet-scale version of the paper's §V.C claim, which
//! `rust/tests/fleet.rs` verifies against round-robin on identical
//! traces.
//!
//! Wear accrual runs on an accelerated clock (`wear_accel` deployed
//! seconds per virtual busy second) so a seconds-long trace can stand in
//! for months of deployment; energy/latency accounting stays in virtual
//! time.
//!
//! [`VoltagePlan`]: crate::plan::VoltagePlan
//! [`BtiModel::stress_rate`]: crate::aging::BtiModel::stress_rate
//! [`BtiModel::critical_delta_vth`]: crate::aging::BtiModel::critical_delta_vth

mod device;
mod loadgen;
mod router;
mod telemetry;

pub use device::{plan_level_shares, plan_stress_intensity, Device, ReplanEvent};
pub use loadgen::{pick_class, Request, Trace};
pub use router::{
    policy_from_name, LeastLoaded, NodeSnapshot, RoundRobin, RoutePolicy, WearLeveling,
};
pub use telemetry::{DeviceTelemetry, FleetTelemetry, QualitySample, JOULES_PER_ENERGY_UNIT};

use std::sync::Arc;

use anyhow::Result;

use crate::aging::{BtiModel, SECONDS_PER_YEAR};
use crate::errormodel::ErrorModelRegistry;
use crate::nn::data::Dataset;
use crate::nn::tensor::Tensor;
use crate::plan::{ResolveOptions, VoltagePlan};
use crate::power::PePowerModel;
use crate::server::Engine;
use crate::timing::voltage::Technology;
use crate::util::rng::Xoshiro256pp;
use crate::obs::metrics::LatencyHistogram;
use crate::util::stats::argmax_f32;

/// When (if ever) a device re-solves its deployed plans against its own
/// accrued drift. The trigger watches [`StressAccount::delay_margin`] —
/// the remaining fraction of the clock guard band — because that is the
/// physical quantity BTI wear consumes.
///
/// [`StressAccount::delay_margin`]: crate::aging::StressAccount::delay_margin
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ReplanPolicy {
    /// Serve the characterization-time plans forever (the paper's static
    /// deployment — and the baseline the closed-loop tests beat).
    Never,
    /// Re-plan when the delay margin has decayed `guard_band` (a fraction
    /// of the full guard band) below its value at the last re-plan.
    Threshold { guard_band: f64 },
    /// Re-plan every `deployed_years` of accrued wear-clock stress.
    Periodic { deployed_years: f64 },
    /// Re-plan on *measured* quality decay: fires when a device's observed
    /// (drift-priced) served-MSE-to-budget ratio — the same quantity the
    /// serving stack's online audit gauges as `audit_mse_ratio` — reaches
    /// `max_ratio`. Unlike the physics-side triggers this one watches the
    /// quality the fleet actually delivers, so a mis-modeled error spec
    /// trips it even while the delay margin still looks healthy.
    ObservedQuality { max_ratio: f64 },
}

impl ReplanPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            ReplanPolicy::Never => "never",
            ReplanPolicy::Threshold { .. } => "threshold",
            ReplanPolicy::Periodic { .. } => "periodic",
            ReplanPolicy::ObservedQuality { .. } => "observed",
        }
    }

    /// Construct from the CLI's `--replan` name plus its parameter flags.
    pub fn from_name(
        name: &str,
        guard_band: f64,
        every_years: f64,
        quality_ratio: f64,
    ) -> Result<Self> {
        match name {
            "never" => Ok(ReplanPolicy::Never),
            "threshold" => {
                anyhow::ensure!(
                    guard_band > 0.0 && guard_band <= 1.0,
                    "--guard-band must be in (0, 1], got {guard_band}"
                );
                Ok(ReplanPolicy::Threshold { guard_band })
            }
            "periodic" => {
                anyhow::ensure!(
                    every_years > 0.0,
                    "--replan-every-years must be positive, got {every_years}"
                );
                Ok(ReplanPolicy::Periodic { deployed_years: every_years })
            }
            "observed" => {
                anyhow::ensure!(
                    quality_ratio > 0.0,
                    "--replan-quality-ratio must be positive, got {quality_ratio}"
                );
                Ok(ReplanPolicy::ObservedQuality { max_ratio: quality_ratio })
            }
            other => {
                anyhow::bail!(
                    "unknown re-plan policy '{other}' (never|threshold|periodic|observed)"
                )
            }
        }
    }
}

/// Everything the closed loop needs beyond the static fleet: the fresh
/// characterization registry (drift re-derivation base), the power model
/// (re-solve energies), the trigger policy, the warm-start options, and
/// the quality-vs-age sampling density. Enabling adaptation with
/// [`ReplanPolicy::Never`] is meaningful: the fleet then *measures* its
/// quality decay without acting on it — the no-replan arm of every
/// with/without comparison.
#[derive(Clone, Debug)]
pub struct AdaptiveContext {
    pub registry: ErrorModelRegistry,
    pub power: PePowerModel,
    pub replan: ReplanPolicy,
    pub resolve: ResolveOptions,
    /// Target number of quality samples per device over the run.
    pub quality_samples: usize,
}

impl AdaptiveContext {
    pub fn new(
        registry: ErrorModelRegistry,
        power: PePowerModel,
        replan: ReplanPolicy,
    ) -> Self {
        // Re-plans solve to 90% of the budget so the drift accrued
        // *between* re-plans stays inside it too.
        let resolve = ResolveOptions { budget_scale: 0.9, ..Default::default() };
        Self { registry, power, replan, resolve, quality_samples: 32 }
    }
}

/// Fleet-wide simulation parameters.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Number of devices in the fleet.
    pub devices: usize,
    /// Virtual service time per request (VOS does not change the clock —
    /// the X-TPU keeps its nominal frequency — so service time is
    /// level-independent).
    pub service_seconds: f64,
    /// Deployed (wear-clock) seconds represented by one virtual busy
    /// second. The default compresses ~11.6 deployed days into each busy
    /// second so short traces produce observable BTI drift.
    pub wear_accel: f64,
    /// Prior service years per device (cycled when shorter than the
    /// fleet), modelling a heterogeneous fleet deployed in waves.
    pub initial_age_years: Vec<f64>,
    /// Activity duty factor of that prior service.
    pub initial_age_duty: f64,
    pub bti: BtiModel,
    pub tech: Technology,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            devices: 4,
            service_seconds: 1.0e-3,
            wear_accel: 1.0e6,
            initial_age_years: Vec::new(),
            initial_age_duty: 0.3,
            bti: BtiModel::default(),
            tech: Technology::default(),
        }
    }
}

/// The fleet simulator: devices + routing policy + virtual clock.
///
/// `run` replays a [`Trace`] through the router in virtual time (fast,
/// deterministic — used by routing ablations); `run_with_inference`
/// additionally executes every routed request through its device's
/// backend-pool slot and reports accuracy.
pub struct Router {
    cfg: FleetConfig,
    devices: Vec<Device>,
    policy: Box<dyn RoutePolicy>,
    /// Per-quality-class aging intensity (x-rate per busy second of
    /// serving that class), shared by all devices. Routing keys on the
    /// *boot-time* intensities: re-plans only ever move traffic toward
    /// higher voltages, so the boot ordering of classes by harshness is
    /// conservative and stable.
    class_intensity: Vec<f64>,
    /// The closed-loop machinery (None = static fleet, PR-4 behavior).
    adaptive: Option<AdaptiveContext>,
    /// Re-plan events accumulated during the last `run`/`run_with_inference`.
    replan_events: Vec<ReplanEvent>,
    /// Quality-vs-age samples accumulated during the last run.
    quality_curve: Vec<QualitySample>,
    /// Reusable scratch for the per-request [`NodeSnapshot`] slice handed
    /// to the policy — keeps the routing hot loop allocation-free.
    snap_buf: Vec<NodeSnapshot>,
}

/// Outcome of the virtual-time replay, before inference/telemetry.
struct SimOutcome {
    latencies_ms: Vec<f64>,
    per_class: Vec<u64>,
    /// Per device: the `(class, global request index)` list it served.
    assigned: Vec<Vec<(usize, usize)>>,
    /// First arrival → last completion (the span telemetry reports).
    duration_seconds: f64,
}

impl Router {
    /// Build a fleet of `cfg.devices` identical devices serving `plans`
    /// through `engine` under the given routing policy.
    pub fn new(
        engine: Arc<Engine>,
        plans: &[VoltagePlan],
        policy: Box<dyn RoutePolicy>,
        cfg: FleetConfig,
    ) -> Result<Self> {
        anyhow::ensure!(cfg.devices > 0, "fleet needs at least one device");
        anyhow::ensure!(!plans.is_empty(), "fleet needs at least one plan");
        anyhow::ensure!(
            cfg.service_seconds > 0.0 && cfg.wear_accel >= 0.0,
            "fleet needs service_seconds > 0 and wear_accel >= 0"
        );
        let class_intensity: Vec<f64> =
            plans.iter().map(|p| plan_stress_intensity(&cfg.bti, &cfg.tech, p)).collect();
        let mut devices = Vec::with_capacity(cfg.devices);
        for id in 0..cfg.devices {
            let mut d = Device::new(id, engine.clone(), plans, cfg.bti, cfg.tech)?;
            if !cfg.initial_age_years.is_empty() {
                let years = cfg.initial_age_years[id % cfg.initial_age_years.len()];
                d.pre_age(cfg.tech.v_nominal, years, cfg.initial_age_duty);
            }
            devices.push(d);
        }
        Ok(Self {
            cfg,
            devices,
            policy,
            class_intensity,
            adaptive: None,
            replan_events: Vec::new(),
            quality_curve: Vec::new(),
            snap_buf: Vec::new(),
        })
    }

    /// Build an *adaptive* fleet: same routing, plus per-device drift
    /// tracking, quality-vs-age sampling, and (policy permitting)
    /// drift-triggered incremental re-planning. The context's registry
    /// must be the one the plans were solved against.
    pub fn with_adaptation(
        engine: Arc<Engine>,
        plans: &[VoltagePlan],
        policy: Box<dyn RoutePolicy>,
        cfg: FleetConfig,
        adaptive: AdaptiveContext,
    ) -> Result<Self> {
        let ladder: Vec<f64> =
            adaptive.registry.ladder.levels().iter().map(|l| l.volts).collect();
        for p in plans {
            anyhow::ensure!(
                p.volts.len() == ladder.len()
                    && p.volts.iter().zip(&ladder).all(|(a, b)| (a - b).abs() < 1e-9),
                "plan '{}' was not solved against the adaptive context's registry",
                p.name
            );
        }
        let mut fleet = Self::new(engine, plans, policy, cfg)?;
        fleet.adaptive = Some(adaptive);
        Ok(fleet)
    }

    /// Re-plan device `d` if its policy says so; record the event.
    fn maybe_replan(&mut self, d: usize, now: f64) {
        let Some(ctx) = self.adaptive.as_ref() else { return };
        if !self.devices[d].wants_replan(&ctx.replan) {
            return;
        }
        // Infallible by construction (ladders are validated at build time);
        // a failure here is a bug worth stopping on, not telemetry.
        let event = self.devices[d]
            .replan(&ctx.registry, &ctx.power, &ctx.resolve, now)
            .expect("drift re-plan failed on a validated fleet");
        self.replan_events.push(event);
    }

    /// Push one quality-vs-age sample per device at virtual time `now`.
    fn sample_quality(&mut self, now: f64) {
        let Some(ctx) = self.adaptive.as_ref() else { return };
        let mut samples = Vec::with_capacity(self.devices.len());
        for d in &self.devices {
            let stress = d.stress();
            let drifted = ctx.registry.drifted(stress.delta_vth());
            let per_class = d.class_mse(drifted.registry());
            samples.push(QualitySample {
                virtual_seconds: now,
                device: d.id,
                generation: d.generation(),
                delta_vth: drifted.delta_vth,
                delay_margin: stress.delay_margin(),
                predicted_mse: per_class.iter().map(|&(m, _)| m).collect(),
                mse_ratio: per_class
                    .iter()
                    .map(|&(m, b)| if b > 0.0 { Some(m / b) } else { None })
                    .collect(),
            });
        }
        // Feed the measured re-plan trigger: each device notes the worst
        // budgeted-class ratio of its sample, so
        // [`ReplanPolicy::ObservedQuality`] fires on quality the fleet
        // actually exhibited rather than on a physics proxy.
        for (d, s) in self.devices.iter_mut().zip(&samples) {
            let worst = s.mse_ratio.iter().flatten().fold(0.0f64, |m, &r| m.max(r));
            if worst > 0.0 {
                d.note_observed_quality(worst);
            }
        }
        self.quality_curve.extend(samples);
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    fn rel_intensity(&self, class: usize) -> f64 {
        let max = self.class_intensity.iter().cloned().fold(0.0, f64::max);
        if max <= 0.0 {
            return 0.0;
        }
        self.class_intensity[class.min(self.class_intensity.len() - 1)] / max
    }

    fn dispatch(&mut self, arrival: f64, class: usize) -> (usize, f64) {
        let rel = self.rel_intensity(class);
        // Policies see plain snapshots (the same view the live shard
        // router feeds them), not the simulator's Devices.
        self.snap_buf.clear();
        self.snap_buf.extend(self.devices.iter().map(|d| NodeSnapshot {
            id: d.id,
            backlog_seconds: d.backlog_seconds(arrival),
            headroom_x: d.headroom_x(),
            generation: d.generation(),
        }));
        let d = self.policy.pick(arrival, class, rel, &self.snap_buf);
        let d = d.min(self.devices.len() - 1);
        let done =
            self.devices[d].serve(arrival, class, self.cfg.service_seconds, self.cfg.wear_accel);
        (d, done)
    }

    fn simulate(&mut self, trace: &Trace) -> SimOutcome {
        let n_classes = self.class_intensity.len();
        self.replan_events.clear();
        self.quality_curve.clear();
        let total = trace.request_count();
        // Quality-vs-age sampling grid (adaptive runs only): every
        // `sample_every` requests, plus one final end-of-run sample.
        let sample_every = self
            .adaptive
            .as_ref()
            .map(|ctx| (total / ctx.quality_samples.max(1)).max(1))
            .unwrap_or(usize::MAX);
        let mut out = SimOutcome {
            latencies_ms: Vec::with_capacity(trace.request_count()),
            per_class: vec![0; n_classes],
            assigned: vec![Vec::new(); self.devices.len()],
            duration_seconds: 0.0,
        };
        let mut first_arrival = f64::INFINITY;
        let mut last_done = 0.0f64;
        let mut record = |this: &mut Self, arrival: f64, class: usize, idx: usize| -> f64 {
            let class = class.min(n_classes - 1);
            let (d, done) = this.dispatch(arrival, class);
            out.latencies_ms.push((done - arrival) * 1000.0);
            out.per_class[class] += 1;
            out.assigned[d].push((class, idx));
            first_arrival = first_arrival.min(arrival);
            last_done = last_done.max(done);
            // The closed loop: wear just accrued on device `d` — check
            // its re-plan trigger, then sample quality on the grid.
            this.maybe_replan(d, arrival);
            if idx % sample_every == 0 {
                this.sample_quality(arrival);
            }
            done
        };
        match trace {
            Trace::Open(reqs) => {
                for (i, r) in reqs.iter().enumerate() {
                    record(self, r.arrival, r.class, i);
                }
            }
            Trace::Closed { clients, per_client, think_seconds, mix, seed } => {
                let mut next = vec![0.0f64; *clients];
                let mut left = vec![*per_client; *clients];
                let mut rngs: Vec<Xoshiro256pp> = (0..*clients)
                    .map(|c| Xoshiro256pp::stream(*seed, c as u64))
                    .collect();
                let mut idx = 0;
                loop {
                    // Next client to issue: earliest wake-up among those
                    // with requests left (ties → lowest id, deterministic).
                    let Some(c) = (0..*clients)
                        .filter(|&c| left[c] > 0)
                        .min_by(|&a, &b| next[a].total_cmp(&next[b]).then(a.cmp(&b)))
                    else {
                        break;
                    };
                    let class = pick_class(&mut rngs[c], mix);
                    let done = record(self, next[c], class, idx);
                    next[c] = done + think_seconds;
                    left[c] -= 1;
                    idx += 1;
                }
            }
        }
        if first_arrival.is_finite() {
            out.duration_seconds = (last_done - first_arrival).max(0.0);
        }
        // End-of-run sample so the curve always covers the final state.
        if self.adaptive.is_some() && total > 0 {
            self.sample_quality(last_done);
        }
        out
    }

    /// Replay the trace in virtual time (routing, queueing, wear, energy —
    /// no model execution) and report fleet telemetry.
    pub fn run(&mut self, trace: &Trace) -> FleetTelemetry {
        let outcome = self.simulate(trace);
        self.telemetry(&outcome, None)
    }

    /// Replay the trace *and* execute every request through its device's
    /// backend-pool slot: request `i` uses row `i % data.len()` of `data`,
    /// served at its assigned quality level, batched per (device, class).
    /// Accuracy lands in the telemetry.
    ///
    /// Static fleets execute against the engine's installed quality
    /// levels. Adaptive fleets execute under each device's *end-of-run*
    /// state instead: its (possibly re-planned) levels priced by its
    /// accrued drift — so the measured accuracy reflects what the aged
    /// fleet actually serves, stale noise included.
    pub fn run_with_inference(
        &mut self,
        trace: &Trace,
        data: &Dataset,
        seed: u64,
    ) -> FleetTelemetry {
        let outcome = self.simulate(trace);
        let mut correct = vec![0u64; self.devices.len()];
        let mut executed = vec![0u64; self.devices.len()];
        const EXEC_BATCH: usize = 64;
        for d in &self.devices {
            let mut rng = Xoshiro256pp::stream(seed ^ 0xF1EE7, d.id as u64);
            let engine = d.engine();
            let drift_specs = self
                .adaptive
                .as_ref()
                .map(|ctx| d.class_specs(&ctx.registry));
            let mut by_class: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
            for &(class, idx) in &outcome.assigned[d.id] {
                by_class.entry(class).or_default().push(idx);
            }
            for (class, idxs) in by_class {
                for chunk in idxs.chunks(EXEC_BATCH) {
                    let mut x = Tensor::zeros(&[chunk.len(), engine.input_dim]);
                    let mut labels = Vec::with_capacity(chunk.len());
                    for (r, &idx) in chunk.iter().enumerate() {
                        let row = idx % data.len();
                        x.row_mut(r).copy_from_slice(data.images.row(row));
                        labels.push(data.labels[row]);
                    }
                    let logits = match &drift_specs {
                        Some(specs) => {
                            let spec = &specs[class.min(specs.len() - 1)];
                            let noise = if spec.is_silent() { None } else { Some(spec) };
                            engine.execute_with_spec(d.id, &x, noise, &mut rng)
                        }
                        None => engine.execute_batch(d.id, &x, class, &mut rng),
                    };
                    for (r, &label) in labels.iter().enumerate() {
                        executed[d.id] += 1;
                        if argmax_f32(logits.row(r)) == label as usize {
                            correct[d.id] += 1;
                        }
                    }
                }
            }
        }
        let per_device: Vec<Option<f64>> = correct
            .iter()
            .zip(&executed)
            .map(|(&c, &n)| if n > 0 { Some(c as f64 / n as f64) } else { None })
            .collect();
        self.telemetry(&outcome, Some(per_device))
    }

    fn telemetry(
        &self,
        outcome: &SimOutcome,
        accuracy: Option<Vec<Option<f64>>>,
    ) -> FleetTelemetry {
        let observed_years = outcome.duration_seconds * self.cfg.wear_accel / SECONDS_PER_YEAR;
        let devices: Vec<DeviceTelemetry> = self
            .devices
            .iter()
            .map(|d| DeviceTelemetry {
                id: d.id,
                requests: d.requests,
                per_class: d.per_class.clone(),
                energy_units: d.energy_units,
                duty_seconds: d.stress().duty_seconds().to_vec(),
                delta_vth: d.stress().delta_vth(),
                delay_margin: d.stress().delay_margin(),
                projected_lifetime_years: d
                    .stress()
                    .projected_lifetime_years(d.accrued_x(), observed_years),
                accuracy: accuracy.as_ref().and_then(|a| a[d.id]),
                generation: d.generation(),
            })
            .collect();
        let requests: u64 = devices.iter().map(|d| d.requests).sum();
        let energy_units: f64 = devices.iter().map(|d| d.energy_units).sum();
        let nominal_unit = self
            .devices
            .first()
            .map(|d| d.engine().nominal_energy_estimate())
            .unwrap_or(0.0);
        let energy_saving_vs_nominal = if nominal_unit > 0.0 && requests > 0 {
            1.0 - energy_units / (requests as f64 * nominal_unit)
        } else {
            0.0
        };
        let (p50, p99, mean) = if outcome.latencies_ms.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            // Percentiles go through the shared power-of-two histogram —
            // the same machinery the serving stack's `ServerStats` reports
            // with — so fleet and server latency summaries share one
            // implementation (and one precision contract: values are
            // upper bucket bounds, within 2× of exact).
            let hist = LatencyHistogram::new();
            for &ms in &outcome.latencies_ms {
                hist.record_us((ms * 1e3).max(0.0).round() as u64);
            }
            (
                hist.quantile_us(0.5) as f64 / 1e3,
                hist.quantile_us(0.99) as f64 / 1e3,
                crate::util::stats::mean(&outcome.latencies_ms),
            )
        };
        let lifetimes: Vec<f64> =
            devices.iter().map(|d| d.projected_lifetime_years).collect();
        let min_life = lifetimes.iter().cloned().fold(f64::INFINITY, f64::min);
        let (acc_correct, acc_total) = devices.iter().fold((0.0, 0u64), |(c, n), d| {
            match d.accuracy {
                Some(a) => (c + a * d.requests as f64, n + d.requests),
                None => (c, n),
            }
        });
        let max_mse_ratio = self
            .quality_curve
            .iter()
            .flat_map(|s| s.mse_ratio.iter().flatten())
            .fold(0.0f64, |m, &r| m.max(r));
        // Budget violations surface as the same typed alarm the serving
        // stack's online audit raises: worst budgeted class over every
        // quality sample, reported only when it actually left the budget.
        let quality_alarm = self
            .quality_curve
            .iter()
            .flat_map(|s| {
                s.mse_ratio
                    .iter()
                    .enumerate()
                    .filter_map(move |(c, r)| r.map(|r| (s, c, r)))
            })
            .max_by(|a, b| a.2.total_cmp(&b.2))
            .filter(|&(_, _, r)| r > 1.0)
            .map(|(s, c, r)| crate::obs::audit::QualityAlarm {
                level: c,
                level_name: format!("class{c}"),
                generation: s.generation,
                observed_mse: s.predicted_mse[c],
                predicted_mse: s.predicted_mse[c] / r,
                ratio: r,
                samples: self.quality_curve.len() as u64,
            });
        FleetTelemetry {
            policy: self.policy.name().to_string(),
            requests,
            per_class: outcome.per_class.clone(),
            duration_seconds: outcome.duration_seconds,
            throughput_rps: if outcome.duration_seconds > 0.0 {
                requests as f64 / outcome.duration_seconds
            } else {
                0.0
            },
            latency_p50_ms: p50,
            latency_p99_ms: p99,
            latency_mean_ms: mean,
            energy_units,
            energy_saving_vs_nominal,
            min_lifetime_years: if min_life.is_finite() { min_life } else { 0.0 },
            mean_lifetime_years: crate::util::stats::mean(&lifetimes),
            accuracy: if acc_total > 0 { Some(acc_correct / acc_total as f64) } else { None },
            devices,
            replan_policy: self
                .adaptive
                .as_ref()
                .map(|ctx| ctx.replan.name())
                .unwrap_or("never")
                .to_string(),
            replan_events: self.replan_events.clone(),
            quality_curve: self.quality_curve.clone(),
            max_mse_ratio,
            quality_alarm,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::errormodel::ErrorModelRegistry;
    use crate::nn::layers::Activation;
    use crate::nn::model::fc_mnist;
    use crate::nn::quant::QuantizedModel;
    use crate::timing::voltage::VoltageLadder;

    /// Tiny untrained engine + two plans (all-nominal "exact" and
    /// all-lowest "eco") — enough structure to exercise routing and wear
    /// without paying for training.
    fn fixture() -> (Arc<Engine>, Vec<VoltagePlan>) {
        let mut rng = Xoshiro256pp::seeded(11);
        let model = fc_mnist(Activation::Relu, &mut rng);
        let calib = crate::nn::data::synth_mnist(32, 3).batch(&(0..32).collect::<Vec<_>>()).0;
        let q = QuantizedModel::quantize(&model, &calib);
        let reg = ErrorModelRegistry::synthetic(
            &VoltageLadder::paper_default(),
            &[3.0e4, 1.0e4, 2.0e3, 0.0],
        );
        let n = q.num_neurons();
        let cfg = ExperimentConfig::smoke();
        let mk = |name: &str, level: Vec<usize>, energy: f64, saving: f64| VoltagePlan {
            name: name.into(),
            mse_ub_fraction: 0.0,
            budget_abs: 0.0,
            baseline_mse: 0.1,
            fan_in: q.neuron_fan_in.clone(),
            es: vec![1.0; n],
            volts: reg.ladder.levels().iter().map(|l| l.volts).collect(),
            predicted_mse: 0.0,
            energy,
            energy_saving: saving,
            optimal: true,
            solver: "ilp".into(),
            model_fingerprint: "fp".into(),
            config_hash: crate::plan::config_hash(&cfg),
            config: cfg.clone(),
            generation: 0,
            drift_delta_vth: 0.0,
            mode: "statistical".into(),
            level,
        };
        let plans = vec![
            mk("exact", vec![3; n], 100.0, 0.0),
            mk("eco", vec![0; n], 60.0, 0.4),
        ];
        let engine = Engine::from_plans(q, &reg, &plans, 784).unwrap();
        (Arc::new(engine), plans)
    }

    fn small_cfg() -> FleetConfig {
        FleetConfig { devices: 3, ..FleetConfig::default() }
    }

    #[test]
    fn round_robin_spreads_requests_evenly() {
        let (engine, plans) = fixture();
        let mut fleet =
            Router::new(engine, &plans, Box::<RoundRobin>::default(), small_cfg()).unwrap();
        let trace = Trace::poisson(300.0, 1.0, &[1.0, 1.0], 5);
        let t = fleet.run(&trace);
        assert_eq!(t.requests as usize, trace.request_count());
        let counts: Vec<u64> = t.devices.iter().map(|d| d.requests).collect();
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(max - min <= 1, "round robin must spread evenly: {counts:?}");
        assert_eq!(t.per_class.iter().sum::<u64>(), t.requests);
        assert!(t.duration_seconds > 0.0 && t.throughput_rps > 0.0);
    }

    #[test]
    fn least_loaded_tracks_backlog() {
        let (engine, plans) = fixture();
        let cfg = FleetConfig { devices: 2, service_seconds: 0.01, ..FleetConfig::default() };
        let mut fleet =
            Router::new(engine, &plans, Box::<LeastLoaded>::default(), cfg).unwrap();
        // Burst of simultaneous arrivals: least-loaded must alternate.
        let reqs: Vec<Request> =
            (0..10).map(|_| Request { arrival: 0.0, class: 0 }).collect();
        let t = fleet.run(&Trace::Open(reqs));
        assert_eq!(t.devices[0].requests, 5);
        assert_eq!(t.devices[1].requests, 5);
        // 5 back-to-back 10 ms services: worst latency 50 ms, median 30 ms.
        // Percentiles report power-of-two bucket upper bounds: 30 ms →
        // 32.767 ms, 50 ms → 65.535 ms.
        assert!(
            (30.0..=32.768).contains(&t.latency_p50_ms),
            "p50 {}",
            t.latency_p50_ms
        );
        assert!(t.latency_p99_ms <= 65.536, "p99 {}", t.latency_p99_ms);
    }

    #[test]
    fn wear_leveling_steers_gentle_traffic_to_worn_device() {
        let (engine, plans) = fixture();
        let cfg = FleetConfig {
            devices: 2,
            initial_age_years: vec![0.02, 0.0],
            initial_age_duty: 1.0,
            ..FleetConfig::default()
        };
        let mut fleet =
            Router::new(engine, &plans, Box::new(WearLeveling::new(1.0, 1)), cfg).unwrap();
        // Plenty of capacity: 100 rps against 2 devices × 1 ms service.
        let trace = Trace::poisson(100.0, 2.0, &[1.0, 1.0], 9);
        let t = fleet.run(&trace);
        let d_worn = &t.devices[0];
        let d_fresh = &t.devices[1];
        // Gentle (eco, class 1) requests land on the worn device; harsh
        // (exact, class 0) on the fresh one.
        assert_eq!(d_worn.per_class[0], 0, "worn device must not serve nominal traffic");
        assert_eq!(d_fresh.per_class[1], 0, "fresh device must not serve eco traffic");
        assert_eq!(d_worn.per_class[1] + d_fresh.per_class[1], t.per_class[1]);
        // Duty histograms tell the same story: the worn device's only
        // nominal-voltage time is its pre-aging; everything it served in
        // the run sits in the 0.5 V bucket. The fresh device is the mirror.
        let pre_age_s = 0.02 * crate::aging::SECONDS_PER_YEAR;
        crate::util::checks::assert_close(d_worn.duty_seconds[3], pre_age_s, 1e-6);
        assert!(d_worn.duty_seconds[0] > 0.0, "eco traffic must stress the 0.5 V bucket");
        assert_eq!(d_fresh.duty_seconds[0], 0.0);
        assert!(d_fresh.duty_seconds[3] > 0.0);
    }

    #[test]
    fn closed_loop_self_throttles_and_conserves_requests() {
        let (engine, plans) = fixture();
        let mut fleet =
            Router::new(engine, &plans, Box::<LeastLoaded>::default(), small_cfg()).unwrap();
        let trace = Trace::closed(4, 25, 0.002, &[1.0, 1.0], 3);
        let t = fleet.run(&trace);
        assert_eq!(t.requests, 100);
        // A closed loop can never queue more than the client population:
        // worst-case latency is population × service time (4 ms), which
        // the histogram reports as its 4.095 ms bucket bound.
        assert!(t.latency_p99_ms <= 4.096, "p99 {}", t.latency_p99_ms);
    }

    #[test]
    fn replan_policy_parsing_and_names() {
        assert_eq!(
            ReplanPolicy::from_name("never", 0.0, 0.0, 0.0).unwrap(),
            ReplanPolicy::Never
        );
        assert_eq!(
            ReplanPolicy::from_name("threshold", 0.1, 0.0, 0.0).unwrap(),
            ReplanPolicy::Threshold { guard_band: 0.1 }
        );
        assert_eq!(
            ReplanPolicy::from_name("periodic", 0.0, 0.02, 0.0).unwrap(),
            ReplanPolicy::Periodic { deployed_years: 0.02 }
        );
        assert_eq!(
            ReplanPolicy::from_name("observed", 0.0, 0.0, 1.5).unwrap(),
            ReplanPolicy::ObservedQuality { max_ratio: 1.5 }
        );
        assert!(ReplanPolicy::from_name("threshold", 0.0, 0.0, 0.0).is_err());
        assert!(ReplanPolicy::from_name("periodic", 0.1, 0.0, 0.0).is_err());
        assert!(ReplanPolicy::from_name("observed", 0.1, 0.1, 0.0).is_err());
        assert!(ReplanPolicy::from_name("sometimes", 0.1, 0.1, 1.0).is_err());
        assert_eq!(ReplanPolicy::Never.name(), "never");
        assert_eq!(ReplanPolicy::Threshold { guard_band: 0.1 }.name(), "threshold");
        assert_eq!(ReplanPolicy::ObservedQuality { max_ratio: 1.5 }.name(), "observed");
    }

    /// An adaptive fleet with a synthetic (zero-variance-free) registry:
    /// the threshold policy must fire as wear accrues, generations must
    /// advance, and the quality curve must cover the run.
    #[test]
    fn threshold_policy_fires_and_advances_generations() {
        let (engine, plans) = fixture();
        let reg = ErrorModelRegistry::synthetic(
            &VoltageLadder::paper_default(),
            &[3.0e4, 1.0e4, 2.0e3, 0.0],
        );
        let power = crate::plan::measure_power_model(7);
        let cfg = FleetConfig {
            devices: 2,
            // Heavy wear clock: the exact class's nominal-voltage stress
            // consumes guard band fast enough for a 1-second trace.
            wear_accel: 5.0e6,
            ..FleetConfig::default()
        };
        let ctx = AdaptiveContext::new(
            reg.clone(),
            power,
            ReplanPolicy::Threshold { guard_band: 0.1 },
        );
        let mut fleet = Router::with_adaptation(
            engine,
            &plans,
            Box::<RoundRobin>::default(),
            cfg,
            ctx,
        )
        .unwrap();
        let trace = Trace::poisson(400.0, 1.0, &[1.0, 1.0], 11);
        let t = fleet.run(&trace);
        assert_eq!(t.replan_policy, "threshold");
        assert!(
            !t.replan_events.is_empty(),
            "nominal-voltage wear at 5e6× must trigger the threshold policy"
        );
        // Generations advance monotonically per device, and the device
        // telemetry reports the final one.
        for d in &t.devices {
            let evs: Vec<_> =
                t.replan_events.iter().filter(|e| e.device == d.id).collect();
            assert_eq!(d.generation, evs.len() as u64, "device {} generation", d.id);
            for (i, e) in evs.iter().enumerate() {
                assert_eq!(e.generation, i as u64 + 1);
                assert!(e.delta_vth > 0.0);
                assert!(e.solve_ms >= 0.0 && e.swap_ms >= 0.0);
            }
        }
        // The quality curve covers both devices and reports budget ratios
        // only for the budgeted class ("exact" has budget 0 → None/null).
        assert!(!t.quality_curve.is_empty());
        for s in &t.quality_curve {
            assert_eq!(s.predicted_mse.len(), 2);
            assert!(s.mse_ratio[0].is_none(), "exact class has no ratio");
        }
        // The no-replan arm of the same setup measures but never acts.
        let (engine2, plans2) = fixture();
        let ctx2 = AdaptiveContext::new(
            reg,
            crate::plan::measure_power_model(7),
            ReplanPolicy::Never,
        );
        let mut never = Router::with_adaptation(
            engine2,
            &plans2,
            Box::<RoundRobin>::default(),
            FleetConfig { devices: 2, wear_accel: 5.0e6, ..FleetConfig::default() },
            ctx2,
        )
        .unwrap();
        let tn = never.run(&trace);
        assert!(tn.replan_events.is_empty());
        assert!(tn.devices.iter().all(|d| d.generation == 0));
        assert!(!tn.quality_curve.is_empty(), "Never still measures quality");
    }

    #[test]
    fn telemetry_json_is_well_formed_and_roundtrips() {
        let (engine, plans) = fixture();
        let mut fleet =
            Router::new(engine, &plans, Box::<RoundRobin>::default(), small_cfg()).unwrap();
        let data = crate::nn::data::synth_mnist(40, 6);
        let t = fleet.run_with_inference(&Trace::poisson(150.0, 1.0, &[1.0, 1.0], 5), &data, 1);
        assert!(t.accuracy.is_some(), "inference run must report accuracy");
        let j = t.to_json();
        // Parse back the serialized form (well-formedness) and check the
        // keys operators and the CI smoke job rely on.
        let back = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("policy").unwrap().as_str().unwrap(), "round_robin");
        assert_eq!(back.get("requests").unwrap().as_u64().unwrap(), t.requests);
        assert!(back.get("min_lifetime_years").unwrap().as_f64().unwrap() >= 0.0);
        assert!(back.get("energy_saving_vs_nominal").unwrap().as_f64().is_ok());
        let devs = back.get("devices").unwrap().as_arr().unwrap();
        assert_eq!(devs.len(), 3);
        for d in devs {
            assert!(d.get("projected_lifetime_years").unwrap().as_f64().unwrap() >= 0.0);
            assert!(d.get("delay_margin").unwrap().as_f64().unwrap() <= 1.0);
            assert_eq!(
                d.get("duty_seconds").unwrap().as_arr().unwrap().len(),
                4,
                "one duty bucket per ladder level"
            );
        }
        // And the energy books must be consistent: mixed exact/eco traffic
        // saves something, but less than the eco plan's own saving.
        let saving = back.get("energy_saving_vs_nominal").unwrap().as_f64().unwrap();
        assert!(saving > 0.0 && saving < 0.4, "saving {saving}");
    }
}
