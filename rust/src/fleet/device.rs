//! One simulated accelerator in the fleet: a serving engine handle plus a
//! live BTI stress ledger and — since the adaptive loop — its *own* copy
//! of the deployed plans, which drift-triggered re-planning advances
//! independently of its fleet-mates.
//!
//! A [`Device`] is the unit the router dispatches over. It wraps the shared
//! [`Engine`] (device `i` executes on backend-pool slot `i`, so a fleet on
//! a pooled engine is share-nothing across devices), carries the
//! virtual-time queue state (`busy_until`), and accrues aging through an
//! [`StressAccount`]: every served request stresses the device's PMOS
//! transistors at the *voltage mix of the plan it served* — the per-neuron
//! voltage assignment, fan-in-weighted, exactly the share-weighted reading
//! of paper §V.C. When a [`ReplanPolicy`](crate::fleet::ReplanPolicy)
//! fires, [`Device::replan`] re-solves every deployed plan against the
//! device's accrued ΔVth ([`resolve_plan_from`]) and hot-swaps its local
//! plan state: shares, stress rates, and energy books all advance to the
//! new generation.

use std::sync::Arc;

use anyhow::Result;

use crate::aging::{BtiModel, StressAccount, SECONDS_PER_YEAR};
use crate::errormodel::ErrorModelRegistry;
use crate::nn::quant::NoiseSpec;
use crate::plan::{resolve_plan_from, ReplanOutcome, ResolveOptions, VoltagePlan};
use crate::power::PePowerModel;
use crate::server::Engine;
use crate::timing::voltage::Technology;

/// Fan-in-weighted share of PE columns per ladder level for one plan: how
/// much of a second of serving under this plan is spent stressing each
/// voltage. (A neuron with fan-in `k` is a column of `k` PEs, so it weighs
/// `k` times a single-PE neuron — same weighting the energy model uses.)
pub fn plan_level_shares(plan: &VoltagePlan) -> Vec<f64> {
    let mut weight = vec![0.0; plan.volts.len()];
    let mut total = 0.0;
    for (&l, &k) in plan.level.iter().zip(&plan.fan_in) {
        weight[l] += k as f64;
        total += k as f64;
    }
    if total > 0.0 {
        for w in &mut weight {
            *w /= total;
        }
    }
    weight
}

/// Aging intensity of serving one busy second under a plan: the x-space
/// stress rate (ΔVth^{1/α} per year, see [`BtiModel::stress_rate`])
/// averaged over the plan's voltage shares. The wear-leveling router sorts
/// quality classes by this — aggressive-VOS plans (mostly low voltage)
/// have intensities orders of magnitude below the all-nominal plan.
pub fn plan_stress_intensity(bti: &BtiModel, tech: &Technology, plan: &VoltagePlan) -> f64 {
    plan_level_shares(plan)
        .iter()
        .zip(&plan.volts)
        .map(|(&share, &v)| share * bti.stress_rate(tech, v))
        .sum()
}

/// One re-plan's worth of bookkeeping, bubbled up into
/// [`FleetTelemetry`](crate::fleet::FleetTelemetry).
#[derive(Clone, Debug)]
pub struct ReplanEvent {
    pub device: usize,
    /// Virtual time of the triggering request.
    pub virtual_seconds: f64,
    /// Deployed (wear-clock) years the device had accrued at the trigger.
    pub deployed_years: f64,
    /// The device's plan generation *after* this re-plan.
    pub generation: u64,
    /// Accrued ΔVth the re-solve saw.
    pub delta_vth: f64,
    /// Delay margin at the trigger (guard-band fraction remaining).
    pub delay_margin: f64,
    /// Neurons kept / re-solved, summed over the device's plans.
    pub frozen: usize,
    pub resolved: usize,
    /// `false` when any plan hit quality end-of-life (pinned all-nominal).
    pub feasible: bool,
    /// Wall-clock cost of the incremental re-solve (all plans).
    pub solve_ms: f64,
    /// Wall-clock cost of swapping the device's serving state (shares,
    /// stress rates, energy books) to the new generation.
    pub swap_ms: f64,
}

/// One fleet device: engine handle, queue state, wear ledger, its deployed
/// plans, counters.
pub struct Device {
    pub id: usize,
    engine: Arc<Engine>,
    stress: StressAccount,
    bti: BtiModel,
    tech: Technology,
    /// This device's deployed plans (one per quality class) — diverges
    /// from the fleet's boot-time plans once re-planning fires.
    plans: Vec<VoltagePlan>,
    /// Stress coordinate at simulation start — the baseline the observed
    /// aging rate (and thus the lifetime extrapolation) is measured from.
    x_start: f64,
    /// Virtual time at which the device finishes its current backlog.
    busy_until: f64,
    /// Per-quality-class voltage shares (ladder-level histogram weights).
    level_shares: Vec<Vec<f64>>,
    /// Per-quality-class aging intensity (x per year of serving, see
    /// [`plan_stress_intensity`]) — precomputed so the per-request wear
    /// accounting is pure multiply-add, no `powf` on the hot path.
    class_x_rate: Vec<f64>,
    /// Per-quality-class energy per request (the deployed plan's energy).
    class_energy: Vec<f64>,
    /// Delay margin when the current plan generation was installed — what
    /// the threshold re-plan policy measures decay against.
    margin_at_plan: f64,
    /// Stressed seconds when the current generation was installed — what
    /// the periodic re-plan policy measures elapsed wear against.
    duty_at_plan: f64,
    /// Local plan generation: 0 at boot, +1 per re-plan. The
    /// generation-aware wear-leveling router re-ranks when it moves.
    generation: u64,
    /// Worst drift-priced served-MSE-to-budget ratio observed since the
    /// current generation was installed (0 = no observation yet). Fed by
    /// the fleet's quality sampling grid; what
    /// [`ReplanPolicy::ObservedQuality`](super::ReplanPolicy::ObservedQuality)
    /// triggers on.
    observed_quality_ratio: f64,
    pub requests: u64,
    pub per_class: Vec<u64>,
    pub energy_units: f64,
}

impl Device {
    /// Build a device serving the given plans through `engine`. All plans
    /// must share one ladder (guaranteed upstream by
    /// [`Engine::from_plans`]'s compatibility checks).
    pub fn new(
        id: usize,
        engine: Arc<Engine>,
        plans: &[VoltagePlan],
        bti: BtiModel,
        tech: Technology,
    ) -> Result<Self> {
        anyhow::ensure!(!plans.is_empty(), "device {id} needs at least one plan");
        anyhow::ensure!(
            plans.len() == engine.num_levels(),
            "device {id}: {} plans but engine has {} levels",
            plans.len(),
            engine.num_levels()
        );
        let volts = plans[0].volts.clone();
        let level_shares = plans.iter().map(plan_level_shares).collect();
        let class_x_rate =
            plans.iter().map(|p| plan_stress_intensity(&bti, &tech, p)).collect();
        let class_energy = plans.iter().map(|p| p.energy).collect();
        let stress = StressAccount::new(bti, tech, &volts);
        let margin_at_plan = stress.delay_margin();
        Ok(Self {
            id,
            engine,
            stress,
            bti,
            tech,
            plans: plans.to_vec(),
            x_start: 0.0,
            busy_until: 0.0,
            level_shares,
            class_x_rate,
            class_energy,
            margin_at_plan,
            duty_at_plan: 0.0,
            generation: 0,
            observed_quality_ratio: 0.0,
            requests: 0,
            per_class: vec![0; plans.len()],
            energy_units: 0.0,
        })
    }

    /// Pre-age the device with `years` of prior always-on service at
    /// `v_dd` and the given duty factor, then re-baseline the observed-rate
    /// window so the projection only extrapolates *future* traffic. The
    /// re-plan baselines move too: the policy reacts to margin lost *in
    /// service*, not to the age the device arrived with.
    pub fn pre_age(&mut self, v_dd: f64, years: f64, duty: f64) {
        self.stress.pre_age(v_dd, years, duty);
        self.x_start = self.stress.x();
        self.margin_at_plan = self.stress.delay_margin();
        self.duty_at_plan = self.stress.total_duty_seconds();
    }

    /// Serve one request of quality `class` arriving at `arrival`:
    /// advance the queue, accrue wear (`service_seconds` of busy time ×
    /// `wear_accel` deployed seconds per virtual busy second, split across
    /// the plan's voltage shares), and book energy. Returns the completion
    /// time in virtual seconds.
    pub fn serve(
        &mut self,
        arrival: f64,
        class: usize,
        service_seconds: f64,
        wear_accel: f64,
    ) -> f64 {
        let start = self.busy_until.max(arrival);
        self.busy_until = start + service_seconds;
        self.requests += 1;
        let class = class.min(self.per_class.len() - 1);
        self.per_class[class] += 1;
        self.energy_units += self.class_energy[class];
        let stressed = service_seconds * wear_accel;
        let dx = self.class_x_rate[class] * (stressed / SECONDS_PER_YEAR);
        self.stress.accrue_weighted(dx, &self.level_shares[class], stressed);
        self.busy_until
    }

    /// Whether the given policy wants a re-plan *now* (margin decayed past
    /// the guard band, or the periodic wear interval elapsed).
    pub fn wants_replan(&self, policy: &super::ReplanPolicy) -> bool {
        match *policy {
            super::ReplanPolicy::Never => false,
            super::ReplanPolicy::Threshold { guard_band } => {
                self.margin_at_plan - self.stress.delay_margin() >= guard_band
            }
            super::ReplanPolicy::Periodic { deployed_years } => {
                (self.stress.total_duty_seconds() - self.duty_at_plan) / SECONDS_PER_YEAR
                    >= deployed_years
            }
            super::ReplanPolicy::ObservedQuality { max_ratio } => {
                self.observed_quality_ratio >= max_ratio
            }
        }
    }

    /// Record a measured served-MSE-to-budget ratio for this device (the
    /// fleet's quality sampling grid calls this with the worst budgeted
    /// class of each sample). Monotone per generation — re-planning
    /// resets it, so the observed-quality trigger measures the *current*
    /// plans, not history.
    pub fn note_observed_quality(&mut self, ratio: f64) {
        if ratio.is_finite() {
            self.observed_quality_ratio = self.observed_quality_ratio.max(ratio);
        }
    }

    /// Worst observed served-MSE-to-budget ratio since the last re-plan
    /// (0 when quality was never sampled).
    pub fn observed_quality_ratio(&self) -> f64 {
        self.observed_quality_ratio
    }

    /// Re-solve every deployed plan against this device's accrued drift
    /// (warm-started from the current generation, see
    /// [`resolve_plan_from`]) and swap the device's serving state to the
    /// result. Returns the telemetry event.
    pub fn replan(
        &mut self,
        base: &ErrorModelRegistry,
        power: &PePowerModel,
        opts: &ResolveOptions,
        now: f64,
    ) -> Result<ReplanEvent> {
        let delta_vth = self.stress.delta_vth();
        let margin = self.stress.delay_margin();
        let t0 = std::time::Instant::now();
        let drifted = base.drifted(delta_vth);
        let outcomes: Vec<ReplanOutcome> = self
            .plans
            .iter()
            .map(|p| resolve_plan_from(p, base, &drifted, power, opts))
            .collect::<Result<_>>()?;
        let solve_ms = t0.elapsed().as_secs_f64() * 1e3;

        let t1 = std::time::Instant::now();
        self.plans = outcomes.iter().map(|o| o.plan.clone()).collect();
        self.level_shares = self.plans.iter().map(plan_level_shares).collect();
        self.class_x_rate = self
            .plans
            .iter()
            .map(|p| plan_stress_intensity(&self.bti, &self.tech, p))
            .collect();
        self.class_energy = self.plans.iter().map(|p| p.energy).collect();
        self.generation += 1;
        self.margin_at_plan = margin;
        self.duty_at_plan = self.stress.total_duty_seconds();
        self.observed_quality_ratio = 0.0;
        let swap_ms = t1.elapsed().as_secs_f64() * 1e3;

        Ok(ReplanEvent {
            device: self.id,
            virtual_seconds: now,
            deployed_years: self.stress.total_duty_seconds() / SECONDS_PER_YEAR,
            generation: self.generation,
            delta_vth,
            delay_margin: margin,
            frozen: outcomes.iter().map(|o| o.frozen).sum(),
            resolved: outcomes.iter().map(|o| o.resolved).sum(),
            feasible: outcomes.iter().all(|o| o.feasible),
            solve_ms,
            swap_ms,
        })
    }

    /// Per-class noise specs under this device's *current* drift: the
    /// deployed levels of each plan, priced by `base.drifted(ΔVth)` — what
    /// an aged device actually injects when it serves. Used by the fleet's
    /// inference replay.
    pub fn class_specs(&self, base: &ErrorModelRegistry) -> Vec<NoiseSpec> {
        let drifted = base.drifted(self.stress.delta_vth());
        self.plans
            .iter()
            .map(|p| NoiseSpec::from_plan(p, drifted.registry()))
            .collect()
    }

    /// Per-class `(predicted served MSE, budget_abs)` under the given
    /// (usually drift-adjusted) registry — the quality-vs-age observable
    /// ([`VoltagePlan::served_mse`] per deployed plan). Each plan is priced
    /// in its own operating regime ([`VoltagePlan::plan_mode`]), so a fleet
    /// that mode-switched some devices to TE-Drop reads the right MSE for
    /// both regimes side by side.
    pub fn class_mse(&self, registry: &ErrorModelRegistry) -> Vec<(f64, f64)> {
        self.plans
            .iter()
            .map(|p| {
                let mode = p.plan_mode();
                let vars: Vec<f64> =
                    registry.models().iter().map(|m| mode.mac_variance(m)).collect();
                (p.served_mse(&vars), p.budget_abs)
            })
            .collect()
    }

    /// Seconds of queued work ahead of a request arriving `now`.
    pub fn backlog_seconds(&self, now: f64) -> f64 {
        (self.busy_until - now).max(0.0)
    }

    pub fn busy_until(&self) -> f64 {
        self.busy_until
    }

    /// Remaining stress headroom (see [`StressAccount::headroom_x`]).
    pub fn headroom_x(&self) -> f64 {
        self.stress.headroom_x()
    }

    /// The wear ledger (telemetry reads duty histogram / ΔVth / margin).
    pub fn stress(&self) -> &StressAccount {
        &self.stress
    }

    /// Stress accrued since the simulation-start baseline.
    pub fn accrued_x(&self) -> f64 {
        self.stress.x() - self.x_start
    }

    /// This device's current plans (advanced by [`Self::replan`]).
    pub fn plans(&self) -> &[VoltagePlan] {
        &self.plans
    }

    /// Local plan generation (0 at boot, +1 per re-plan).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }
}
