//! One simulated accelerator in the fleet: a serving engine handle plus a
//! live BTI stress ledger.
//!
//! A [`Device`] is the unit the router dispatches over. It wraps the shared
//! [`Engine`] (device `i` executes on backend-pool slot `i`, so a fleet on
//! a pooled engine is share-nothing across devices), carries the
//! virtual-time queue state (`busy_until`), and accrues aging through an
//! [`StressAccount`]: every served request stresses the device's PMOS
//! transistors at the *voltage mix of the plan it served* — the per-neuron
//! voltage assignment, fan-in-weighted, exactly the share-weighted reading
//! of paper §V.C.

use std::sync::Arc;

use anyhow::Result;

use crate::aging::{BtiModel, StressAccount, SECONDS_PER_YEAR};
use crate::plan::VoltagePlan;
use crate::server::Engine;
use crate::timing::voltage::Technology;

/// Fan-in-weighted share of PE columns per ladder level for one plan: how
/// much of a second of serving under this plan is spent stressing each
/// voltage. (A neuron with fan-in `k` is a column of `k` PEs, so it weighs
/// `k` times a single-PE neuron — same weighting the energy model uses.)
pub fn plan_level_shares(plan: &VoltagePlan) -> Vec<f64> {
    let mut weight = vec![0.0; plan.volts.len()];
    let mut total = 0.0;
    for (&l, &k) in plan.level.iter().zip(&plan.fan_in) {
        weight[l] += k as f64;
        total += k as f64;
    }
    if total > 0.0 {
        for w in &mut weight {
            *w /= total;
        }
    }
    weight
}

/// Aging intensity of serving one busy second under a plan: the x-space
/// stress rate (ΔVth^{1/α} per year, see [`BtiModel::stress_rate`])
/// averaged over the plan's voltage shares. The wear-leveling router sorts
/// quality classes by this — aggressive-VOS plans (mostly low voltage)
/// have intensities orders of magnitude below the all-nominal plan.
pub fn plan_stress_intensity(bti: &BtiModel, tech: &Technology, plan: &VoltagePlan) -> f64 {
    plan_level_shares(plan)
        .iter()
        .zip(&plan.volts)
        .map(|(&share, &v)| share * bti.stress_rate(tech, v))
        .sum()
}

/// One fleet device: engine handle, queue state, wear ledger, counters.
pub struct Device {
    pub id: usize,
    engine: Arc<Engine>,
    stress: StressAccount,
    /// Stress coordinate at simulation start — the baseline the observed
    /// aging rate (and thus the lifetime extrapolation) is measured from.
    x_start: f64,
    /// Virtual time at which the device finishes its current backlog.
    busy_until: f64,
    /// Per-quality-class voltage shares (ladder-level histogram weights).
    level_shares: Vec<Vec<f64>>,
    /// Per-quality-class aging intensity (x per year of serving, see
    /// [`plan_stress_intensity`]) — precomputed so the per-request wear
    /// accounting is pure multiply-add, no `powf` on the hot path.
    class_x_rate: Vec<f64>,
    pub requests: u64,
    pub per_class: Vec<u64>,
    pub energy_units: f64,
}

impl Device {
    /// Build a device serving the given plans through `engine`. All plans
    /// must share one ladder (guaranteed upstream by
    /// [`Engine::from_plans`]'s compatibility checks).
    pub fn new(
        id: usize,
        engine: Arc<Engine>,
        plans: &[VoltagePlan],
        bti: BtiModel,
        tech: Technology,
    ) -> Result<Self> {
        anyhow::ensure!(!plans.is_empty(), "device {id} needs at least one plan");
        anyhow::ensure!(
            plans.len() == engine.levels.len(),
            "device {id}: {} plans but engine has {} levels",
            plans.len(),
            engine.levels.len()
        );
        let volts = plans[0].volts.clone();
        let level_shares = plans.iter().map(plan_level_shares).collect();
        let class_x_rate =
            plans.iter().map(|p| plan_stress_intensity(&bti, &tech, p)).collect();
        Ok(Self {
            id,
            engine,
            stress: StressAccount::new(bti, tech, &volts),
            x_start: 0.0,
            busy_until: 0.0,
            level_shares,
            class_x_rate,
            requests: 0,
            per_class: vec![0; plans.len()],
            energy_units: 0.0,
        })
    }

    /// Pre-age the device with `years` of prior always-on service at
    /// `v_dd` and the given duty factor, then re-baseline the observed-rate
    /// window so the projection only extrapolates *future* traffic.
    pub fn pre_age(&mut self, v_dd: f64, years: f64, duty: f64) {
        self.stress.pre_age(v_dd, years, duty);
        self.x_start = self.stress.x();
    }

    /// Serve one request of quality `class` arriving at `arrival`:
    /// advance the queue, accrue wear (`service_seconds` of busy time ×
    /// `wear_accel` deployed seconds per virtual busy second, split across
    /// the plan's voltage shares), and book energy. Returns the completion
    /// time in virtual seconds.
    pub fn serve(
        &mut self,
        arrival: f64,
        class: usize,
        service_seconds: f64,
        wear_accel: f64,
    ) -> f64 {
        let start = self.busy_until.max(arrival);
        self.busy_until = start + service_seconds;
        self.requests += 1;
        let class = class.min(self.per_class.len() - 1);
        self.per_class[class] += 1;
        self.energy_units += self.engine.energy_estimate(class);
        let stressed = service_seconds * wear_accel;
        let dx = self.class_x_rate[class] * (stressed / SECONDS_PER_YEAR);
        self.stress.accrue_weighted(dx, &self.level_shares[class], stressed);
        self.busy_until
    }

    /// Seconds of queued work ahead of a request arriving `now`.
    pub fn backlog_seconds(&self, now: f64) -> f64 {
        (self.busy_until - now).max(0.0)
    }

    pub fn busy_until(&self) -> f64 {
        self.busy_until
    }

    /// Remaining stress headroom (see [`StressAccount::headroom_x`]).
    pub fn headroom_x(&self) -> f64 {
        self.stress.headroom_x()
    }

    /// The wear ledger (telemetry reads duty histogram / ΔVth / margin).
    pub fn stress(&self) -> &StressAccount {
        &self.stress
    }

    /// Stress accrued since the simulation-start baseline.
    pub fn accrued_x(&self) -> f64 {
        self.stress.x() - self.x_start
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }
}
