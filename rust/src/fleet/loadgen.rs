//! Trace-driven load generation for the fleet simulator.
//!
//! Two client models, both deterministic from a seed:
//!
//! - **Open loop** ([`Trace::poisson`]): requests arrive on a Poisson
//!   process at a fixed rate regardless of fleet state — the datacenter
//!   front-door model, and the one that exposes queueing behavior.
//! - **Closed loop** ([`Trace::closed`]): a fixed population of clients,
//!   each issuing its next request only after the previous one completed
//!   plus a think time — the benchmark-harness model, self-throttling by
//!   construction.
//!
//! Every request carries a quality class drawn from a configurable mix, so
//! one trace exercises several deployed [`VoltagePlan`]s at once. The
//! class sequence depends only on the seed and the mix — never on routing
//! or completion order — which is what lets the integration tests compare
//! policies "at identical served quality" on the same trace.
//!
//! [`VoltagePlan`]: crate::plan::VoltagePlan

use anyhow::Result;

use crate::util::rng::Xoshiro256pp;

/// One open-loop request: arrival instant (virtual seconds) + quality
/// class (index into the fleet's plan list).
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub arrival: f64,
    pub class: usize,
}

/// A load trace the fleet simulator can replay.
#[derive(Clone, Debug)]
pub enum Trace {
    /// Pre-materialized open-loop arrivals, sorted by arrival time.
    Open(Vec<Request>),
    /// Closed-loop population; arrivals are generated during simulation
    /// (issue → wait for completion → think → issue again). The class
    /// sequence of each client is fixed by `seed`, independent of timing.
    Closed { clients: usize, per_client: usize, think_seconds: f64, mix: Vec<f64>, seed: u64 },
}

impl Trace {
    /// Open-loop Poisson arrivals: `rps` requests/second for `seconds`,
    /// classes drawn i.i.d. from `mix` (weights over quality classes,
    /// normalized internally).
    pub fn poisson(rps: f64, seconds: f64, mix: &[f64], seed: u64) -> Trace {
        assert!(rps > 0.0 && seconds > 0.0);
        let mut rng = Xoshiro256pp::seeded(seed);
        let mut t = 0.0;
        let mut reqs = Vec::new();
        loop {
            // Exponential inter-arrival: −ln(1−U)/λ with U ∈ [0, 1).
            t += -(1.0 - rng.next_f64()).ln() / rps;
            if t >= seconds {
                break;
            }
            reqs.push(Request { arrival: t, class: pick_class(&mut rng, mix) });
        }
        Trace::Open(reqs)
    }

    /// Closed-loop population of `clients`, `per_client` requests each,
    /// with a fixed think time between completion and next issue.
    pub fn closed(
        clients: usize,
        per_client: usize,
        think_seconds: f64,
        mix: &[f64],
        seed: u64,
    ) -> Trace {
        assert!(clients > 0 && per_client > 0 && think_seconds >= 0.0);
        Trace::Closed { clients, per_client, think_seconds, mix: mix.to_vec(), seed }
    }

    /// Total number of requests this trace will issue.
    pub fn request_count(&self) -> usize {
        match self {
            Trace::Open(reqs) => reqs.len(),
            Trace::Closed { clients, per_client, .. } => clients * per_client,
        }
    }

    /// Parse a CLI trace spec:
    /// `poisson:rps=<f>,secs=<f>` or `closed:clients=<n>,reqs=<n>,think=<f>`.
    /// The quality `mix` and `seed` come from their own CLI options so the
    /// spec stays short. Unknown keys are rejected, not defaulted — a typo
    /// like `rsp=600` must not silently simulate the default rate.
    pub fn parse(spec: &str, mix: &[f64], seed: u64) -> Result<Trace> {
        let (kind, body) = spec.split_once(':').unwrap_or((spec, ""));
        let allowed: &[&str] = match kind {
            "poisson" => &["rps", "secs"],
            "closed" => &["clients", "reqs", "think"],
            other => anyhow::bail!("unknown trace kind '{other}' (poisson:…|closed:…)"),
        };
        let mut kv = std::collections::BTreeMap::new();
        for part in body.split(',').filter(|s| !s.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("trace spec entry '{part}' is not key=value"))?;
            let k = k.trim();
            anyhow::ensure!(
                allowed.contains(&k),
                "unknown {kind} trace key '{k}' (allowed: {allowed:?})"
            );
            let v: f64 = v
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("trace spec '{k}={v}': {e}"))?;
            kv.insert(k.to_string(), v);
        }
        let get = |key: &str, default: f64| kv.get(key).copied().unwrap_or(default);
        match kind {
            "poisson" => {
                let rps = get("rps", 200.0);
                let secs = get("secs", 2.0);
                anyhow::ensure!(rps > 0.0 && secs > 0.0, "poisson trace needs rps>0, secs>0");
                Ok(Trace::poisson(rps, secs, mix, seed))
            }
            "closed" => {
                let clients = get("clients", 8.0) as usize;
                let reqs = get("reqs", 50.0) as usize;
                let think = get("think", 0.002);
                anyhow::ensure!(clients > 0 && reqs > 0, "closed trace needs clients>0, reqs>0");
                anyhow::ensure!(think >= 0.0, "closed trace needs think>=0");
                Ok(Trace::closed(clients, reqs, think, mix, seed))
            }
            _ => unreachable!("kind validated above"),
        }
    }
}

/// Draw a class index from (unnormalized) weights. All-zero or empty
/// weights collapse to class 0.
pub fn pick_class(rng: &mut Xoshiro256pp, mix: &[f64]) -> usize {
    let total: f64 = mix.iter().filter(|w| w.is_finite() && **w > 0.0).sum();
    if total <= 0.0 {
        return 0;
    }
    let mut u = rng.next_f64() * total;
    for (i, &w) in mix.iter().enumerate() {
        if w.is_finite() && w > 0.0 {
            u -= w;
            if u < 0.0 {
                return i;
            }
        }
    }
    mix.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_trace_is_sorted_seeded_and_rate_plausible() {
        let mix = [0.5, 0.3, 0.2];
        let a = Trace::poisson(500.0, 4.0, &mix, 42);
        let b = Trace::poisson(500.0, 4.0, &mix, 42);
        let c = Trace::poisson(500.0, 4.0, &mix, 43);
        let (Trace::Open(ra), Trace::Open(rb), Trace::Open(rc)) = (&a, &b, &c) else {
            panic!("poisson must be an open trace");
        };
        assert_eq!(ra.len(), rb.len(), "same seed, same trace");
        assert_ne!(ra.len(), 0);
        assert!(ra.windows(2).all(|w| w[0].arrival <= w[1].arrival), "sorted arrivals");
        assert!(ra.iter().all(|r| r.arrival < 4.0 && r.class < 3));
        // λ·T = 2000 expected; Poisson std ≈ 45 — 5σ band.
        assert!((ra.len() as i64 - 2000).abs() < 250, "got {} arrivals", ra.len());
        assert_ne!(
            ra.iter().map(|r| r.class).collect::<Vec<_>>(),
            rc.iter().take(ra.len()).map(|r| r.class).collect::<Vec<_>>(),
            "different seeds should differ"
        );
    }

    #[test]
    fn class_mix_respected() {
        let mut rng = Xoshiro256pp::seeded(7);
        let mix = [0.7, 0.0, 0.3];
        let mut counts = [0usize; 3];
        let n = 50_000;
        for _ in 0..n {
            counts[pick_class(&mut rng, &mix)] += 1;
        }
        assert_eq!(counts[1], 0, "zero-weight class never drawn");
        let p0 = counts[0] as f64 / n as f64;
        assert!((p0 - 0.7).abs() < 0.02, "class-0 share {p0}");
        // Degenerate mixes collapse to class 0.
        assert_eq!(pick_class(&mut rng, &[]), 0);
        assert_eq!(pick_class(&mut rng, &[0.0, 0.0]), 0);
    }

    #[test]
    fn trace_spec_parsing() {
        let mix = [1.0, 1.0];
        let t = Trace::parse("poisson:rps=100,secs=1", &mix, 1).unwrap();
        assert!(matches!(&t, Trace::Open(r) if !r.is_empty()));
        let t = Trace::parse("closed:clients=4,reqs=10,think=0.001", &mix, 1).unwrap();
        assert_eq!(t.request_count(), 40);
        assert!(matches!(t, Trace::Closed { clients: 4, per_client: 10, .. }));
        // Defaults apply when keys are omitted.
        assert!(Trace::parse("poisson", &mix, 1).is_ok());
        // Malformed specs are rejected with context.
        assert!(Trace::parse("burst:rps=1", &mix, 1).is_err());
        assert!(Trace::parse("poisson:rps", &mix, 1).is_err());
        assert!(Trace::parse("poisson:rps=fast", &mix, 1).is_err());
        assert!(Trace::parse("poisson:rps=0", &mix, 1).is_err());
        // Typos must not silently fall back to defaults.
        let err = Trace::parse("poisson:rsp=600", &mix, 1).unwrap_err().to_string();
        assert!(err.contains("rsp") && err.contains("rps"), "{err}");
        assert!(Trace::parse("closed:rps=600", &mix, 1).is_err());
    }
}
