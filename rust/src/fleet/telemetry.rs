//! Fleet telemetry: the JSON report `xtpu fleet` emits.
//!
//! Everything an operator (or CI job) needs to judge a run: per-device
//! request/energy/wear accounting with projected lifetime, fleet-level
//! latency percentiles, throughput, aggregate energy saving vs all-nominal
//! serving, the minimum projected device lifetime — and, for adaptive
//! runs, the closed-loop observables: re-plan events (with solve/swap
//! latency), the quality-vs-age curve, and the worst served-MSE-to-budget
//! ratio the fleet ever exhibited.
//!
//! Reports serialize through [`crate::util::json`] (deterministic key
//! order) and round-trip losslessly through `write_file`/`read_file`.

pub use crate::power::JOULES_PER_ENERGY_UNIT;

use super::device::ReplanEvent;
use crate::util::json::Json;

/// Per-device slice of a fleet report.
#[derive(Clone, Debug)]
pub struct DeviceTelemetry {
    pub id: usize,
    pub requests: u64,
    /// Requests served per quality class.
    pub per_class: Vec<u64>,
    /// Energy booked against this device (normalized units).
    pub energy_units: f64,
    /// Deployed-time stressed seconds per ladder level (duty histogram).
    pub duty_seconds: Vec<f64>,
    /// Projected PMOS threshold shift (V) including pre-aging.
    pub delta_vth: f64,
    /// Remaining fraction of the clock guard band (1 fresh → 0 failing).
    pub delay_margin: f64,
    /// Extrapolated years until the guard band is consumed, at the aging
    /// rate observed during the run (capped, see
    /// [`crate::aging::LIFETIME_CAP_YEARS`]).
    pub projected_lifetime_years: f64,
    /// Classification accuracy over this device's executed requests
    /// (`None` when the run was timing/wear-only).
    pub accuracy: Option<f64>,
    /// The device's final plan generation (0 = never re-planned).
    pub generation: u64,
}

impl DeviceTelemetry {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("requests", Json::Num(self.requests as f64)),
            (
                "per_class",
                Json::Arr(self.per_class.iter().map(|&c| Json::Num(c as f64)).collect()),
            ),
            ("energy_units", Json::Num(self.energy_units)),
            ("energy_joules", Json::Num(self.energy_units * JOULES_PER_ENERGY_UNIT)),
            ("duty_seconds", Json::arr_f64(&self.duty_seconds)),
            ("delta_vth", Json::Num(self.delta_vth)),
            ("delay_margin", Json::Num(self.delay_margin)),
            ("projected_lifetime_years", Json::Num(self.projected_lifetime_years)),
            (
                "accuracy",
                self.accuracy.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("generation", Json::Num(self.generation as f64)),
        ])
    }
}

/// One point on the quality-vs-age curve: a device's predicted served MSE
/// per quality class under its drift at that instant, sampled on a fixed
/// request grid during the run.
#[derive(Clone, Debug)]
pub struct QualitySample {
    pub virtual_seconds: f64,
    pub device: usize,
    /// Device plan generation at the sample.
    pub generation: u64,
    /// Accrued ΔVth (V) at the sample.
    pub delta_vth: f64,
    /// Remaining guard-band fraction at the sample.
    pub delay_margin: f64,
    /// Per class: predicted served MSE under the drift (eq. 29 re-priced).
    pub predicted_mse: Vec<f64>,
    /// Per class: `predicted_mse / budget_abs`, `None` for zero-budget
    /// (exact) classes where the ratio is undefined.
    pub mse_ratio: Vec<Option<f64>>,
}

impl QualitySample {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("virtual_seconds", Json::Num(self.virtual_seconds)),
            ("device", Json::Num(self.device as f64)),
            ("generation", Json::Num(self.generation as f64)),
            ("delta_vth", Json::Num(self.delta_vth)),
            ("delay_margin", Json::Num(self.delay_margin)),
            ("predicted_mse", Json::arr_f64(&self.predicted_mse)),
            (
                "mse_ratio",
                Json::Arr(
                    self.mse_ratio
                        .iter()
                        .map(|r| r.map(Json::Num).unwrap_or(Json::Null))
                        .collect(),
                ),
            ),
        ])
    }
}

fn replan_event_json(e: &ReplanEvent) -> Json {
    Json::obj(vec![
        ("device", Json::Num(e.device as f64)),
        ("virtual_seconds", Json::Num(e.virtual_seconds)),
        ("deployed_years", Json::Num(e.deployed_years)),
        ("generation", Json::Num(e.generation as f64)),
        ("delta_vth", Json::Num(e.delta_vth)),
        ("delay_margin", Json::Num(e.delay_margin)),
        ("frozen", Json::Num(e.frozen as f64)),
        ("resolved", Json::Num(e.resolved as f64)),
        ("feasible", Json::Bool(e.feasible)),
        ("solve_ms", Json::Num(e.solve_ms)),
        ("swap_ms", Json::Num(e.swap_ms)),
    ])
}

/// The full fleet report.
#[derive(Clone, Debug)]
pub struct FleetTelemetry {
    /// Routing policy that produced this run.
    pub policy: String,
    pub devices: Vec<DeviceTelemetry>,
    pub requests: u64,
    /// Requests issued per quality class across the fleet.
    pub per_class: Vec<u64>,
    /// Virtual-time span of the run (first arrival to last completion).
    pub duration_seconds: f64,
    pub throughput_rps: f64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    pub latency_mean_ms: f64,
    pub energy_units: f64,
    /// Fractional saving vs serving every request on the all-nominal
    /// assignment (0 when the engine carries no energy model).
    pub energy_saving_vs_nominal: f64,
    pub min_lifetime_years: f64,
    pub mean_lifetime_years: f64,
    /// Fleet-wide accuracy (`None` for timing/wear-only runs).
    pub accuracy: Option<f64>,
    /// Re-plan policy name (`never` when adaptation was off).
    pub replan_policy: String,
    /// Every re-plan the run performed, in trigger order.
    pub replan_events: Vec<ReplanEvent>,
    /// Quality-vs-age samples (empty when adaptation was off).
    pub quality_curve: Vec<QualitySample>,
    /// Worst `predicted served MSE / budget` over every sample and every
    /// budgeted class — ≤ 1.0 means the fleet never left the user's
    /// quality budget. 0 when no samples were taken.
    pub max_mse_ratio: f64,
    /// Raised when the fleet's drift-priced served MSE exceeded a class's
    /// budget at any quality sample (the worst offender) — the same typed
    /// alarm the serving stack's online audit surfaces, so operators read
    /// one shape in both places. `None` while the fleet stayed in budget.
    pub quality_alarm: Option<crate::obs::audit::QualityAlarm>,
}

impl FleetTelemetry {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::Str(self.policy.clone())),
            ("devices", Json::Arr(self.devices.iter().map(|d| d.to_json()).collect())),
            ("requests", Json::Num(self.requests as f64)),
            (
                "per_class",
                Json::Arr(self.per_class.iter().map(|&c| Json::Num(c as f64)).collect()),
            ),
            ("duration_seconds", Json::Num(self.duration_seconds)),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("latency_p50_ms", Json::Num(self.latency_p50_ms)),
            ("latency_p99_ms", Json::Num(self.latency_p99_ms)),
            ("latency_mean_ms", Json::Num(self.latency_mean_ms)),
            ("energy_units", Json::Num(self.energy_units)),
            (
                "energy_joules",
                Json::Num(self.energy_units * JOULES_PER_ENERGY_UNIT),
            ),
            ("energy_saving_vs_nominal", Json::Num(self.energy_saving_vs_nominal)),
            ("min_lifetime_years", Json::Num(self.min_lifetime_years)),
            ("mean_lifetime_years", Json::Num(self.mean_lifetime_years)),
            (
                "accuracy",
                self.accuracy.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("replan_policy", Json::Str(self.replan_policy.clone())),
            ("replans", Json::Num(self.replan_events.len() as f64)),
            (
                "replan_events",
                Json::Arr(self.replan_events.iter().map(replan_event_json).collect()),
            ),
            (
                "quality_curve",
                Json::Arr(self.quality_curve.iter().map(|s| s.to_json()).collect()),
            ),
            ("max_mse_ratio", Json::Num(self.max_mse_ratio)),
            (
                "quality_alarm",
                self.quality_alarm.as_ref().map(|a| a.to_json()).unwrap_or(Json::Null),
            ),
        ])
    }

    /// One-screen operator summary (what `xtpu fleet` prints).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "policy {} · {} requests over {:.2}s virtual ({:.0} req/s)\n\
             latency p50 {:.2} ms · p99 {:.2} ms · energy saving vs nominal {:.1}%\n\
             fleet lifetime: min {:.3} y · mean {:.3} y\n",
            self.policy,
            self.requests,
            self.duration_seconds,
            self.throughput_rps,
            self.latency_p50_ms,
            self.latency_p99_ms,
            self.energy_saving_vs_nominal * 100.0,
            self.min_lifetime_years,
            self.mean_lifetime_years,
        );
        if self.replan_policy != "never" || !self.replan_events.is_empty() {
            s.push_str(&format!(
                "adaptive: policy {} · {} re-plan(s) · worst served-MSE/budget {:.3}\n",
                self.replan_policy,
                self.replan_events.len(),
                self.max_mse_ratio,
            ));
        }
        if let Some(a) = &self.quality_alarm {
            s.push_str(&format!(
                "QUALITY ALARM: class {} gen {} · served MSE {:.4} vs budget {:.4} \
                 (ratio {:.3})\n",
                a.level, a.generation, a.observed_mse, a.predicted_mse, a.ratio,
            ));
        }
        for d in &self.devices {
            s.push_str(&format!(
                "  device {}: {:>6} reqs · gen {} · ΔVth {:.4} V · margin {:>5.1}% · \
                 life {:>8.3} y\n",
                d.id,
                d.requests,
                d.generation,
                d.delta_vth,
                d.delay_margin * 100.0,
                d.projected_lifetime_years,
            ));
        }
        s
    }
}
