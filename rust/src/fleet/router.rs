//! Pluggable request-routing policies for the fleet.
//!
//! All policies are deterministic functions of the trace and the fleet
//! state, so a seeded simulation is exactly reproducible. Three are built
//! in:
//!
//! - [`RoundRobin`] — the classic baseline: devices take turns.
//! - [`LeastLoaded`] — route to the device with the shortest backlog.
//! - [`WearLeveling`] — the aging-aware policy (see module docs of
//!   [`crate::fleet`]): low-stress traffic is steered toward the most-worn
//!   devices and high-stress (high-voltage) traffic toward the devices
//!   with the most remaining guard-band headroom, re-ranking only every
//!   `rebalance_every` picks (rotating which devices hold the
//!   aggressive-VOS plans is a re-flash of the voltage-selection bits, not
//!   a free per-request decision).

use anyhow::Result;

/// What a routing policy sees of one routable node — a plain snapshot, so
/// the same policies drive both the fleet *simulator*'s [`Device`]s and the
/// serving stack's live [`server::shard`](crate::server::shard) engines.
/// The fleet fills these from virtual-time queue state; the shard router
/// fills them from real queue depths and real accrued wear.
///
/// [`Device`]: super::device::Device
#[derive(Clone, Copy, Debug)]
pub struct NodeSnapshot {
    /// Stable node id; ties and fallbacks resolve toward the lowest id so
    /// every policy stays deterministic.
    pub id: usize,
    /// Seconds of queued work ahead of a request arriving now.
    pub backlog_seconds: f64,
    /// Remaining stress headroom (see
    /// [`StressAccount::headroom_x`](crate::aging::StressAccount::headroom_x)).
    /// Nodes without a wear ledger report a constant (e.g. 1.0).
    pub headroom_x: f64,
    /// Plan generation. The generation-aware wear-leveler re-ranks
    /// immediately when any node's moves.
    pub generation: u64,
}

/// A routing policy: given the time (virtual or wall seconds), the
/// request's quality class and its *relative* stress intensity (this
/// class's aging rate divided by the harshest class's — 1.0 for the
/// all-nominal plan, ≈ 0 for an aggressive-VOS plan), pick the node to
/// serve it. Returns a node *id* (policies treat slice position and id as
/// interchangeable; callers pass nodes ordered by id).
pub trait RoutePolicy: Send {
    fn name(&self) -> &'static str;
    fn pick(&mut self, now: f64, class: usize, rel_intensity: f64, nodes: &[NodeSnapshot])
        -> usize;
}

/// Devices take strict turns, ignoring load and wear.
#[derive(Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoutePolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round_robin"
    }

    fn pick(&mut self, _now: f64, _class: usize, _rel: f64, nodes: &[NodeSnapshot]) -> usize {
        let d = nodes[self.next % nodes.len()].id;
        self.next = self.next.wrapping_add(1);
        d
    }
}

/// Route to the node with the smallest backlog (ties → lowest id).
#[derive(Default)]
pub struct LeastLoaded;

impl RoutePolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least_loaded"
    }

    fn pick(&mut self, _now: f64, _class: usize, _rel: f64, nodes: &[NodeSnapshot]) -> usize {
        argmin_backlog(nodes)
    }
}

fn argmin_backlog(nodes: &[NodeSnapshot]) -> usize {
    let mut best = 0;
    let mut best_b = f64::INFINITY;
    for n in nodes {
        if n.backlog_seconds < best_b {
            best_b = n.backlog_seconds;
            best = n.id;
        }
    }
    best
}

/// Aging-aware wear leveling.
///
/// Every `rebalance_every` picks the policy re-ranks devices by remaining
/// stress headroom (`ΔVth_crit^{1/α} − x`, ascending: most worn first).
/// Between rebalances the ranking is frozen — the "rotation" granularity:
/// in hardware, moving a plan between devices re-flashes the Fig-7
/// voltage-selection bits, so the mapping should not churn per request.
///
/// Two-tier steering, exploiting that the aging rate scales like
/// `E_OX^{γ/α}` (≈ 10 orders of magnitude between the 0.5 V and 0.8 V
/// plans):
///
/// - requests whose relative stress intensity is below
///   [`Self::GENTLE_THRESHOLD`] (aggressive-VOS traffic, negligible aging)
///   walk the ranking from the *worn* end — worn devices stay busy while
///   effectively resting;
/// - every stress-bearing class walks it from the *fresh* end, greedily
///   water-filling remaining headroom across the fleet, which is what
///   maximizes the minimum projected lifetime.
///
/// Load is a constraint, not the objective: devices whose backlog exceeds
/// the current minimum by more than `slack_seconds` are skipped, which
/// bounds queueing at a small steering cost.
pub struct WearLeveling {
    /// Maximum backlog above the fleet minimum a device may have and still
    /// receive steered traffic.
    pub slack_seconds: f64,
    /// Picks between headroom re-rankings (plan-rotation granularity).
    pub rebalance_every: u64,
    picks: u64,
    /// Node positions sorted by headroom ascending (most worn first).
    ranking: Vec<usize>,
    /// Sum of device plan generations at the last re-ranking. A re-plan
    /// changes a device's voltage mix (and thus how fast each traffic
    /// class wears it), so a frozen ranking goes stale the moment any
    /// device swaps generations — the generation-aware router re-ranks
    /// immediately instead of waiting out `rebalance_every`.
    gen_sum: u64,
}

impl WearLeveling {
    /// Relative intensity below which a class counts as "gentle" (its
    /// aging contribution is noise) and is parked on worn devices. The
    /// 0.5 V-heavy plans sit ~10 orders of magnitude below this; any plan
    /// with a meaningful nominal-voltage share sits well above it.
    pub const GENTLE_THRESHOLD: f64 = 0.05;

    pub fn new(slack_seconds: f64, rebalance_every: u64) -> Self {
        Self {
            slack_seconds,
            rebalance_every: rebalance_every.max(1),
            picks: 0,
            ranking: Vec::new(),
            gen_sum: 0,
        }
    }

    fn rerank(&mut self, nodes: &[NodeSnapshot]) {
        let mut ids: Vec<usize> = (0..nodes.len()).collect();
        // Total order: headroom, then id — deterministic and NaN-free.
        ids.sort_by(|&a, &b| {
            nodes[a]
                .headroom_x
                .total_cmp(&nodes[b].headroom_x)
                .then(a.cmp(&b))
        });
        self.ranking = ids;
        self.gen_sum = nodes.iter().map(|n| n.generation).sum();
    }
}

impl Default for WearLeveling {
    fn default() -> Self {
        Self::new(0.05, 64)
    }
}

impl RoutePolicy for WearLeveling {
    fn name(&self) -> &'static str {
        "wear_leveling"
    }

    fn pick(&mut self, _now: f64, _class: usize, rel: f64, nodes: &[NodeSnapshot]) -> usize {
        let gen_sum: u64 = nodes.iter().map(|n| n.generation).sum();
        if self.picks % self.rebalance_every == 0
            || self.ranking.len() != nodes.len()
            || gen_sum != self.gen_sum
        {
            self.rerank(nodes);
        }
        self.picks += 1;
        let min_backlog = nodes
            .iter()
            .map(|n| n.backlog_seconds)
            .fold(f64::INFINITY, f64::min);
        let limit = min_backlog + self.slack_seconds;
        let eligible = |i: usize| nodes[i].backlog_seconds <= limit;
        let pick = if rel >= Self::GENTLE_THRESHOLD {
            // Stress-bearing traffic → most headroom (fresh end).
            self.ranking.iter().rev().find(|&&i| eligible(i))
        } else {
            // Gentle traffic → most worn node that isn't overloaded.
            self.ranking.iter().find(|&&i| eligible(i))
        };
        // The argmin-backlog node is always eligible, so `pick` is Some;
        // the fallback only guards an empty fleet upstream bugs would hit.
        pick.map(|&i| nodes[i].id).unwrap_or(0)
    }
}

/// Construct a policy by CLI name: `round-robin` | `least-loaded` |
/// `wear-level` (underscores accepted).
pub fn policy_from_name(name: &str) -> Result<Box<dyn RoutePolicy>> {
    match name.replace('_', "-").as_str() {
        "round-robin" | "rr" => Ok(Box::<RoundRobin>::default()),
        "least-loaded" | "ll" => Ok(Box::<LeastLoaded>::default()),
        "wear-level" | "wear-leveling" | "wl" => Ok(Box::<WearLeveling>::default()),
        other => anyhow::bail!(
            "unknown routing policy '{other}' (round-robin|least-loaded|wear-level)"
        ),
    }
}
