//! `xtpu` — CLI for the X-TPU quality-aware voltage-overscaling framework.
//!
//! Subcommands mirror the Fig-4 pipeline stages plus operational tooling:
//!
//! ```text
//! xtpu characterize   extract per-voltage statistical error models
//! xtpu train          train + cache an evaluation model
//! xtpu sensitivity    compute per-neuron error sensitivities
//! xtpu assign         solve the ILP voltage assignment for one budget
//! xtpu plan           solve all budgets offline → VoltagePlan JSON files
//! xtpu pipeline       full sweep: train → characterize → ES → ILP → validate
//! xtpu aging          BTI aging study (Fig 15)
//! xtpu simulate       run a matmul on the cycle-level X-TPU simulator
//! xtpu serve          start the quality-adjustable inference server
//!                     (`--plan file.json` serves pre-solved plans with
//!                     zero solve latency at startup)
//! xtpu fleet          aging-aware multi-device fleet simulation: spin N
//!                     devices from plan files, replay a trace through a
//!                     routing policy, emit a JSON telemetry report
//!                     (`--replan threshold --guard-band 0.05` closes the
//!                     adaptive loop: devices re-solve their plans as BTI
//!                     drift consumes delay margin)
//! xtpu info           list artifacts + PJRT platform
//! ```

use anyhow::Result;
use xtpu::aging::{BtiModel, Device};
use xtpu::assign::Solver;
use xtpu::fleet::{
    policy_from_name, AdaptiveContext, FleetConfig, ReplanPolicy, Router, Trace, WearLeveling,
};
use xtpu::config::ExperimentConfig;
use xtpu::coordinator::Pipeline;
use xtpu::errormodel::{CharacterizeOptions, ErrorModelRegistry};
use xtpu::exec::Backend;
use xtpu::plan::{Planner, VoltagePlan};
use xtpu::server::shard::WearConfig;
use xtpu::server::{BatchPolicy, Client, Engine, FrontendMode, FrontendOptions, Server};
use xtpu::simulator::{ErrorInjector, XTpu};
use xtpu::timing::sta::ChipInstance;
use xtpu::timing::voltage::{Technology, VoltageLadder};
use xtpu::timing::baugh_wooley_8x8;
use xtpu::util::cli::{usage, Args, OptSpec};
use xtpu::util::rng::Xoshiro256pp;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "characterize" => cmd_characterize(rest),
        "train" => cmd_train(rest),
        "sensitivity" => cmd_sensitivity(rest),
        "assign" => cmd_assign(rest),
        "plan" => cmd_plan(rest),
        "pipeline" => cmd_pipeline(rest),
        "aging" => cmd_aging(rest),
        "simulate" => cmd_simulate(rest),
        "serve" => cmd_serve(rest),
        "fleet" => cmd_fleet(rest),
        "info" => cmd_info(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (try `xtpu help`)"),
    }
}

fn print_help() {
    println!(
        "xtpu — quality-aware voltage overscaling for TPUs (X-TPU reproduction)\n\n\
         Commands:\n\
           characterize  extract per-voltage statistical error models\n\
           train         train + cache an evaluation model\n\
           sensitivity   per-neuron error sensitivities\n\
           assign        solve the voltage assignment for one MSE budget\n\
           plan          solve all budgets offline into VoltagePlan files\n\
           pipeline      full framework sweep (train→characterize→ES→ILP→validate)\n\
           aging         BTI aging study (Fig 15)\n\
           simulate      matmul on the cycle-level X-TPU simulator\n\
           serve         quality-adjustable inference server (--plan = pre-solved)\n\
           fleet         aging-aware fleet simulation (--plan = pre-solved; --replan = adaptive)\n\
           info          list artifacts + PJRT platform\n\n\
         Run `xtpu <command> --help` for options."
    );
}

fn common_specs() -> Vec<OptSpec> {
    vec![
        OptSpec::opt("config", "", "path to an experiment-config JSON"),
        OptSpec::opt("model", "fc_mnist", "fc_mnist | lenet5 | resnet_tiny"),
        OptSpec::opt("activation", "linear", "linear | relu | sigmoid | tanh"),
        OptSpec::opt("seed", "684045", "experiment seed"),
        OptSpec::opt("artifacts", "artifacts", "artifacts directory"),
        OptSpec::opt(
            "backend",
            "statistical",
            "matmul engine: exact | statistical | tedrop | pjrt (per-neuron noise specs apply on all)",
        ),
        OptSpec::flag("help", "show usage"),
    ]
}

fn build_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = if args.str("config").is_empty() {
        ExperimentConfig::default()
    } else {
        ExperimentConfig::load(std::path::Path::new(args.str("config")))?
    };
    if !args.str("model").is_empty() {
        cfg.model = args.str("model").to_string();
    }
    cfg.activation = xtpu::nn::layers::Activation::from_name(args.str("activation"))?;
    cfg.seed = args.u64("seed")?;
    cfg.artifacts_dir = args.str("artifacts").to_string();
    cfg.backend = args.str("backend").to_string();
    Ok(cfg)
}

fn parse_or_help(
    argv: &[String],
    cmd: &str,
    about: &str,
    extra: Vec<OptSpec>,
) -> Result<Option<Args>> {
    let mut specs = common_specs();
    specs.extend(extra);
    let args = Args::parse(argv, &specs).map_err(|e| anyhow::anyhow!("{e}"))?;
    if args.flag("help") {
        println!("{}", usage("xtpu", cmd, about, &specs));
        return Ok(None);
    }
    Ok(Some(args))
}

fn cmd_characterize(argv: &[String]) -> Result<()> {
    let Some(args) = parse_or_help(
        argv,
        "characterize",
        "Monte-Carlo the PE multiplier per voltage, fit error models (Table 2).",
        vec![
            OptSpec::opt("samples", "1000000", "input vectors per voltage"),
            OptSpec::opt("voltages", "0.5,0.6,0.7,0.8", "voltage ladder"),
        ],
    )?
    else {
        return Ok(());
    };
    let tech = Technology::default();
    let ladder = VoltageLadder::new(&args.f64_list("voltages")?, tech);
    let netlist = baugh_wooley_8x8("pe_multiplier");
    let mut rng = Xoshiro256pp::seeded(args.u64("seed")? ^ 0xC41);
    let chip = ChipInstance::sample(&netlist, &tech, &mut rng);
    let opts = CharacterizeOptions {
        samples: args.u64("samples")?,
        seed: args.u64("seed")? ^ 0xE44,
        ..Default::default()
    };
    println!("characterizing {} gates × {} voltages × {} samples…",
        netlist.num_cells(), ladder.len(), opts.samples);
    let t0 = std::time::Instant::now();
    let reg = ErrorModelRegistry::characterize(&netlist, &chip, &ladder, &opts);
    println!("done in {:.1}s\n", t0.elapsed().as_secs_f64());
    println!("{:>8} {:>14} {:>12} {:>10} {:>10}", "V", "variance", "std", "err-rate", "skew");
    for m in reg.models() {
        println!(
            "{:>8.2} {:>14.4e} {:>12.2} {:>10.4} {:>10.3}",
            m.volts, m.variance, m.std_dev(), m.error_rate, m.skewness
        );
    }
    let out = std::path::Path::new(args.str("artifacts")).join("error_models.json");
    reg.save(&out)?;
    println!("\nsaved {}", out.display());
    Ok(())
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let Some(args) = parse_or_help(
        argv,
        "train",
        "Train + cache an evaluation model on the synthetic dataset.",
        vec![
            OptSpec::opt("epochs", "6", "training epochs"),
            OptSpec::opt("samples", "4000", "training set size"),
        ],
    )?
    else {
        return Ok(());
    };
    let mut cfg = build_config(&args)?;
    cfg.epochs = args.usize("epochs")?;
    cfg.train_samples = args.usize("samples")?;
    let pipeline = Pipeline::new(cfg);
    let t0 = std::time::Instant::now();
    let (mut model, _train, test) = pipeline.trained_model()?;
    let acc = xtpu::nn::train::evaluate(&mut model, &test, 64);
    let params = model.num_params();
    println!(
        "model {} trained ({} params) in {:.1}s — test accuracy {:.3}",
        model.name,
        params,
        t0.elapsed().as_secs_f64(),
        acc
    );
    Ok(())
}

fn cmd_sensitivity(argv: &[String]) -> Result<()> {
    let Some(args) = parse_or_help(
        argv,
        "sensitivity",
        "Per-neuron error sensitivities of the trained model (Fig 11).",
        vec![],
    )?
    else {
        return Ok(());
    };
    let cfg = build_config(&args)?;
    let pipeline = Pipeline::new(cfg);
    let sys = pipeline.prepare()?;
    println!("{} neurons (ES, fan-in):", sys.es.len());
    for (i, (&es, &k)) in sys.es.iter().zip(&sys.fan_in).enumerate() {
        println!("{i:>5} {es:>12.4e} {k:>6}");
    }
    Ok(())
}

fn cmd_assign(argv: &[String]) -> Result<()> {
    let Some(args) = parse_or_help(
        argv,
        "assign",
        "Solve the voltage assignment for one MSE-increment budget.",
        vec![
            OptSpec::opt("mse-ub", "2.0", "MSE increment bound (fraction of nominal MSE)"),
            OptSpec::opt("solver", "ilp", "ilp | greedy | genetic"),
        ],
    )?
    else {
        return Ok(());
    };
    let cfg = build_config(&args)?;
    let pipeline = Pipeline::new(cfg);
    let sys = pipeline.prepare()?;
    let fraction = args.f64("mse-ub")?;
    let solver = Solver::from_name(args.str("solver"))?;
    let report = pipeline.run_budget_with(&sys, fraction, solver)?;
    let hist = report.assignment.level_histogram(sys.registry.ladder.len());
    println!("budget       : {:.1}% of nominal MSE ({:.4})", fraction * 100.0, report.budget_abs);
    println!("solver       : {:?} (optimal={})", solver, report.assignment.optimal);
    println!("solve time   : {:.3}s", report.assignment.solve_seconds);
    println!("levels       : {hist:?} (0.5V → nominal)");
    println!("energy saving: {:.1}%", report.assignment.energy_saving * 100.0);
    println!("predicted MSE: {:.4}", report.assignment.predicted_mse);
    println!("measured MSE : {:.4} (violated: {})", report.validated_mse, report.violated);
    println!("accuracy     : {:.4} (drop {:.4})", report.accuracy, report.accuracy_drop);
    Ok(())
}

/// Shrink a config to the tiny smoke preset (CI-friendly sizes) while
/// keeping any model/seed/backend overrides from the CLI.
fn apply_smoke(cfg: &mut ExperimentConfig) {
    let s = ExperimentConfig::smoke();
    cfg.train_samples = s.train_samples;
    cfg.test_samples = s.test_samples;
    cfg.epochs = s.epochs;
    cfg.characterize_samples = s.characterize_samples;
    cfg.validation_runs = s.validation_runs;
}

fn cmd_plan(argv: &[String]) -> Result<()> {
    let Some(args) = parse_or_help(
        argv,
        "plan",
        "Solve MSE budgets offline into deployable VoltagePlan JSON files.",
        vec![
            OptSpec::opt("mse-ubs", "0.0,0.5,2.0,10.0", "budget fractions of nominal MSE"),
            OptSpec::opt("solver", "ilp", "ilp | greedy | genetic"),
            OptSpec::opt(
                "mode",
                "statistical",
                "operating regime to price levels in: statistical | tedrop \
                 (tedrop also selects the tedrop backend unless --backend is given)",
            ),
            OptSpec::opt("out", "plans", "output directory for plan files"),
            OptSpec::flag("smoke", "tiny synthetic config (CI smoke run)"),
        ],
    )?
    else {
        return Ok(());
    };
    let mut cfg = build_config(&args)?;
    if args.flag("smoke") {
        apply_smoke(&mut cfg);
    }
    cfg.mse_ub_fractions = args.f64_list("mse-ubs")?;
    cfg.solver = Solver::from_name(args.str("solver"))?;
    let mode = xtpu::errormodel::PlanMode::from_name(args.str("mode"))?;
    cfg.mode = mode.name().to_string();
    // TE-Drop plans should execute on the backend that actually drops
    // faulting MACs; an explicit --backend still wins.
    if mode == xtpu::errormodel::PlanMode::TeDrop && args.explicit("backend").is_none() {
        cfg.backend = "tedrop".to_string();
    }
    let t0 = std::time::Instant::now();
    let mut planner = Planner::new(cfg);
    let out = std::path::PathBuf::from(args.str("out"));
    let emitted = planner.emit_plans(&out)?;
    let es_seconds = planner.es_stage()?.seconds;
    let trained = planner.trained()?;
    println!(
        "model={} fingerprint={} ({} neurons; train {:.1}s · ES {:.1}s)",
        trained.model.name,
        trained.fingerprint,
        trained.quantized.num_neurons(),
        trained.seconds,
        es_seconds
    );
    println!(
        "{:>9} {:>12} {:>9} {:>8}  {}",
        "MSE_UB%", "pred MSE", "saving%", "optimal", "file"
    );
    for (plan, path) in &emitted {
        println!(
            "{:>9.1} {:>12.4} {:>9.2} {:>8}  {}",
            plan.mse_ub_fraction * 100.0,
            plan.predicted_mse,
            plan.energy_saving * 100.0,
            plan.optimal,
            path.display()
        );
    }
    println!(
        "\n{} plan(s) solved in parallel + written in {:.1}s — serve them with \
         `xtpu serve --plan <file>[,<file>…]` (zero solve latency at startup)",
        emitted.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_pipeline(argv: &[String]) -> Result<()> {
    let Some(args) = parse_or_help(
        argv,
        "pipeline",
        "Full framework sweep over MSE budgets (Figs 10/13/14).",
        vec![OptSpec::opt("mse-ubs", "0.01,0.1,0.5,1.0,2.0,5.0,10.0", "budget fractions")],
    )?
    else {
        return Ok(());
    };
    let mut cfg = build_config(&args)?;
    cfg.mse_ub_fractions = args.f64_list("mse-ubs")?;
    let pipeline = Pipeline::new(cfg);
    // The budget sweep fans out across the thread pool (bit-identical to
    // the sequential sweep — each budget seeds its own RNGs).
    let (sys, reports) = pipeline.run()?;
    println!(
        "model={} acc={:.3} nominal-MSE={:.4} (train {:.1}s, characterize {:.1}s, ES {:.1}s)",
        sys.model.name,
        sys.baseline_accuracy,
        sys.baseline_mse,
        sys.train_seconds,
        sys.characterize_seconds,
        sys.es_seconds
    );
    println!(
        "{:>9} {:>10} {:>10} {:>9} {:>9} {:>9}",
        "MSE_UB%", "pred MSE", "meas MSE", "acc", "acc drop", "saving%"
    );
    for r in &reports {
        println!(
            "{:>9.1} {:>10.4} {:>10.4} {:>9.4} {:>9.4} {:>9.2}",
            r.mse_ub_fraction * 100.0,
            r.assignment.predicted_mse,
            r.validated_mse,
            r.accuracy,
            r.accuracy_drop,
            r.assignment.energy_saving * 100.0
        );
    }
    Ok(())
}

fn cmd_aging(argv: &[String]) -> Result<()> {
    let Some(args) = parse_or_help(
        argv,
        "aging",
        "BTI aging study: ΔVth, delay degradation, lifetime (Fig 15).",
        vec![OptSpec::opt("years", "10", "stress duration")],
    )?
    else {
        return Ok(());
    };
    let years = args.f64("years")?;
    let bti = BtiModel::default();
    let tech = Technology::default();
    println!("{:>6} {:>12} {:>12} {:>14}", "V", "ΔVth% PMOS", "ΔVth% NMOS", "delay factor");
    for v in [0.5, 0.6, 0.7, 0.8] {
        println!(
            "{v:>6.2} {:>12.3} {:>12.3} {:>14.4}",
            bti.delta_vth_percent(Device::Pmos, &tech, v, years),
            bti.delta_vth_percent(Device::Nmos, &tech, v, years),
            bti.delay_degradation(&tech, v, years)
        );
    }
    let imp = bti.lifetime_improvement(&tech, &[0.5, 0.6, 0.7, 0.8], &[0.25; 4]);
    println!(
        "\nuniform voltage mix → lifetime improvement {:.1}% (paper: 12%)",
        imp * 100.0
    );
    Ok(())
}

fn cmd_simulate(argv: &[String]) -> Result<()> {
    let Some(args) = parse_or_help(
        argv,
        "simulate",
        "Random matmul on the cycle-level X-TPU simulator.",
        vec![
            OptSpec::opt("m", "64", "batch rows"),
            OptSpec::opt("k", "128", "inner dim"),
            OptSpec::opt("n", "16", "output columns"),
            OptSpec::opt("level", "0", "ladder level for all columns (0=0.5V, 3=nominal)"),
            OptSpec::opt("samples", "200000", "characterization samples"),
        ],
    )?
    else {
        return Ok(());
    };
    let cfg = build_config(&args)?;
    let pipeline = Pipeline::new(cfg);
    let reg = pipeline.error_models()?;
    let power = pipeline.power_model();
    let (m, k, n) = (args.usize("m")?, args.usize("k")?, args.usize("n")?);
    let level = args.usize("level")?;
    let ladder = reg.ladder.clone();
    let mut tpu =
        XTpu::new(128, 128, ladder, ErrorInjector::Statistical(reg)).with_power(power);
    let mut rng = Xoshiro256pp::seeded(args.u64("seed")?);
    let a: Vec<i8> = (0..m * k).map(|_| rng.range_i64(-127, 127) as i8).collect();
    let w: Vec<i8> = (0..k * n).map(|_| rng.range_i64(-127, 127) as i8).collect();
    let t0 = std::time::Instant::now();
    let out = tpu.matmul(&a, &w, m, k, n, &vec![level; n], &mut rng);
    let dt = t0.elapsed();
    let mut err = 0u64;
    for s in 0..m {
        for c in 0..n {
            let mut exact = 0i64;
            for r in 0..k {
                exact += (a[s * k + r] as i64) * (w[r * n + c] as i64);
            }
            if out[s * n + c] as i64 != exact {
                err += 1;
            }
        }
    }
    println!(
        "matmul {m}×{k}×{n} at level {level}: {} cycles, {} MACs, {:.1}% outputs erroneous",
        tpu.stats.cycles,
        tpu.stats.macs,
        err as f64 / (m * n) as f64 * 100.0
    );
    println!(
        "energy saving {:.1}%, wall {:.3}s ({:.1} MMAC/s)",
        tpu.stats.energy_saving() * 100.0,
        dt.as_secs_f64(),
        tpu.stats.macs as f64 / dt.as_secs_f64() / 1e6
    );
    Ok(())
}

/// Resolve the plans a serving-side command deploys: from `--plan` files
/// when given (fingerprint-checked against the rebuilt model — zero solve
/// latency), otherwise solved now from the experiment config's `--mse-ubs`
/// budgets. Shared by `xtpu serve` and `xtpu fleet`, so a plan artifact
/// behaves identically whether one engine or a whole fleet consumes it.
fn resolve_plans(args: &Args) -> Result<(Planner, Vec<VoltagePlan>)> {
    let plan_files = args.str_multi("plan");
    let (cfg, loaded) = if plan_files.is_empty() {
        let mut cfg = build_config(args)?;
        cfg.mse_ub_fractions = args.f64_list("mse-ubs")?;
        (cfg, None)
    } else {
        let plans: Vec<VoltagePlan> = plan_files
            .iter()
            .map(|p| VoltagePlan::load(std::path::Path::new(p)))
            .collect::<Result<_>>()?;
        // Compatibility across plans is enforced by Engine::from_plans;
        // here we only need a config to rebuild the model/registry from.
        // Serving-side knobs the user passed explicitly override the
        // plan-embedded config (planning-side fields always come from the
        // plan — changing those would break the fingerprint).
        let mut cfg = plans[0].config.clone();
        if let Some(dir) = args.explicit("artifacts") {
            cfg.artifacts_dir = dir.to_string();
        }
        if let Some(be) = args.explicit("backend") {
            cfg.backend = be.to_string();
        }
        (cfg, Some(plans))
    };
    let mut planner = Planner::new(cfg);
    let plans = match loaded {
        Some(plans) => {
            // Pre-solved path: only the (cached) model + registry are
            // needed — no ES estimation, no MCKP solve.
            let fingerprint = planner.trained()?.fingerprint.clone();
            anyhow::ensure!(
                plans[0].model_fingerprint == fingerprint,
                "plan '{}' was solved for model fingerprint {} but the \
                 artifacts here rebuild {} — re-run `xtpu plan` (or point \
                 --artifacts at the directory the plans were solved from)",
                plans[0].name,
                plans[0].model_fingerprint,
                fingerprint
            );
            plans
        }
        None => {
            let fractions = planner.cfg.mse_ub_fractions.clone();
            planner.solve_many(&fractions)?
        }
    };
    Ok((planner, plans))
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let Some(args) = parse_or_help(
        argv,
        "serve",
        "Quality-adjustable inference server (newline-JSON over TCP).",
        vec![
            OptSpec::opt("port", "7433", "TCP port (0 = ephemeral)"),
            OptSpec::opt(
                "mse-ubs",
                "0.0,0.5,2.0,10.0",
                "quality levels to solve at startup (ignored with --plan)",
            ),
            OptSpec::opt("max-batch", "16", "dynamic batch size"),
            OptSpec::opt("workers", "0", "batch worker threads per shard (0 = auto)"),
            OptSpec::opt(
                "plan",
                "",
                "pre-solved VoltagePlan file(s) from `xtpu plan`; repeat or \
                 comma-separate. Uses the plans' embedded config; no solving at startup",
            ),
            OptSpec::opt("frontend", "threaded", "connection frontend: threaded|evented"),
            OptSpec::opt(
                "slo-ms",
                "0",
                "latency SLO in milliseconds (0 = none): requests the admission \
                 gate cannot serve in time are shed with a typed error line",
            ),
            OptSpec::opt("shards", "1", "engine shards serving the model"),
            OptSpec::opt("max-conns", "1024", "concurrent connection cap"),
            OptSpec::opt("max-queue", "4096", "queued-request cap (admission gate)"),
            OptSpec::opt(
                "route",
                "round-robin",
                "shard routing policy: round-robin|least-loaded|wear-level",
            ),
            OptSpec::opt(
                "shard-ages",
                "",
                "prior service years per shard, comma-separated (enables live \
                 wear accounting; wear-level routing then steers on real headroom)",
            ),
            OptSpec::opt(
                "wear-accel",
                "1e6",
                "wear-clock acceleration for live stress accounting",
            ),
            OptSpec::opt(
                "trace-sample",
                "0",
                "trace 1-in-N requests through the full request path \
                 (0 = off); dump with {\"trace\": N}",
            ),
            OptSpec::opt(
                "audit-sample",
                "0",
                "shadow-execute 1-in-N batch groups on the exact backend and \
                 audit observed vs predicted MSE (0 = off)",
            ),
            OptSpec::opt(
                "audit-band",
                "2.0",
                "quality alarm threshold: observed/predicted MSE ratio above \
                 this raises a QualityAlarm",
            ),
            OptSpec::opt(
                "metrics-file",
                "",
                "write the JSON metrics exposition to this path every 500 ms",
            ),
            OptSpec::flag("smoke", "serve one self-issued request per level, then exit"),
        ],
    )?
    else {
        return Ok(());
    };
    let t0 = std::time::Instant::now();
    let (mut planner, plans) = resolve_plans(&args)?;
    let registry = planner.registry()?.clone();
    let trained = planner.trained()?;
    let quantized = trained.quantized.clone();
    let input_dim = trained.model.input.numel();
    let engine = Engine::from_plans(quantized.clone(), &registry, &plans, input_dim)?;
    for (i, l) in engine.plan_set().levels.iter().enumerate() {
        println!("quality {i}: {} (saving {:.1}%)", l.name, l.energy_saving * 100.0);
    }
    println!("levels ready in {:.2}s", t0.elapsed().as_secs_f64());
    let policy = BatchPolicy {
        max_batch: args.usize("max-batch")?,
        workers: args.usize("workers")?,
        ..Default::default()
    };
    // Share-nothing pools: one backend instance per batch worker per
    // shard, so concurrent batches never contend.
    let workers = policy.resolved_workers();
    let n_levels = engine.num_levels();
    let n_shards = args.usize("shards")?.max(1);
    let mut engines = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        let pool = xtpu::plan::make_backend_pool(&planner.cfg, &registry, workers)?;
        if engines.is_empty() {
            println!(
                "execution backend: {} × {workers} workers × {n_shards} shard(s)",
                pool[0].name()
            );
        }
        let e = Engine::from_plans(quantized.clone(), &registry, &plans, input_dim)?
            .with_backend_pool(pool);
        engines.push(std::sync::Arc::new(e));
    }
    let shard_ages = args.f64_list("shard-ages")?;
    let wear_accel = args.f64("wear-accel")?;
    let route_name = args.str("route").to_string();
    // Wear ledgers whenever the operator asked for them (ages) or the
    // routing policy needs them (wear-level steers on real headroom).
    let wear = (!shard_ages.is_empty() || route_name.contains("wear")).then(|| {
        let mut w = WearConfig::new(plans.clone());
        w.wear_accel = wear_accel;
        w.initial_age_years = shard_ages.clone();
        w
    });
    let slo_ms = args.f64("slo-ms")?;
    let audit_band = args.f64("audit-band")?;
    anyhow::ensure!(audit_band > 0.0, "--audit-band must be positive, got {audit_band}");
    let opts = FrontendOptions {
        mode: FrontendMode::from_name(args.str("frontend"))?,
        slo: (slo_ms > 0.0).then(|| std::time::Duration::from_secs_f64(slo_ms / 1e3)),
        max_conns: args.usize("max-conns")?,
        max_queue: args.usize("max-queue")?,
        route: Some(policy_from_name(&route_name)?),
        wear,
        trace_sample: args.u64("trace-sample")?,
        audit: xtpu::obs::audit::AuditConfig {
            sample_every: args.u64("audit-sample")?,
            band: (0.0, audit_band),
            ..Default::default()
        },
    };
    let frontend = opts.mode;
    let mut server = Server::spawn_opts(engines, args.usize("port")? as u16, policy, opts)?;
    // Periodic metrics exporter: snapshot the unified registry to disk so
    // dashboards (and the CI obs-smoke job) can scrape without a client.
    let metrics_path = args.str("metrics-file").to_string();
    if !metrics_path.is_empty() {
        let stats = server.stats.clone();
        let path = std::path::PathBuf::from(metrics_path.clone());
        std::thread::Builder::new()
            .name("metrics-export".into())
            .spawn(move || loop {
                std::thread::sleep(std::time::Duration::from_millis(500));
                let _ = xtpu::util::json::write_file(&path, &stats.metrics_json());
            })?;
    }
    println!(
        "serving on {} ({frontend:?} frontend, {n_shards} shard(s), {} routing{})",
        server.addr,
        server.shards.policy_name(),
        if slo_ms > 0.0 { format!(", SLO {slo_ms}ms") } else { String::new() }
    );
    println!("protocol: {{\"pixels\": [f32 × {input_dim}], \"quality\": idx}} per line");
    if args.flag("smoke") {
        // CI self-test: one request per quality level (plus, with the
        // audit on, enough traffic to push every level past the audit's
        // min-sample window), then the stats snapshot, then a clean
        // shutdown.
        let mut client = Client::connect(server.addr)?;
        let zeros = vec![0f32; input_dim];
        for q in 0..n_levels {
            let (class, logits, applied) = client.infer_full(&zeros, q)?;
            anyhow::ensure!(applied == q, "level {q} applied as {applied}");
            println!("smoke: quality {q} → class {class} ({} logits)", logits.len());
        }
        let audit_cfg = server.stats.audit.config().clone();
        if audit_cfg.sample_every > 0 {
            // One row per sampled group (sequential client, so every
            // request is its own batch): N·(min_samples + 2) requests per
            // level guarantee ≥ min_samples audited rows on each, however
            // the 1-in-N grid lands on the level boundaries.
            let per_level = audit_cfg.sample_every * (audit_cfg.min_samples + 2);
            for q in 0..n_levels {
                for _ in 0..per_level {
                    client.infer(&zeros, q)?;
                }
            }
            // Shadow runs land after the replies; wait for the books.
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
            loop {
                let ratios = server.stats.audit.ratios();
                let settled = ratios.len() >= n_levels
                    && ratios.iter().all(|&(.., rows)| rows >= audit_cfg.min_samples);
                if settled || std::time::Instant::now() >= deadline {
                    anyhow::ensure!(settled, "audit never reached its min-sample window");
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
        }
        println!("smoke: stats {}", client.stats()?);
        // Observability self-checks: the metrics exposition and (when
        // tracing is on) a chrome-trace dump must answer over the wire.
        let metrics = client.metrics()?;
        anyhow::ensure!(
            metrics.get("server").is_ok() && metrics.get("process").is_ok(),
            "metrics exposition missing server/process registries"
        );
        if args.u64("trace-sample")? > 0 {
            let trace = client.trace(64)?;
            let events = trace.get("traceEvents")?.as_arr()?;
            anyhow::ensure!(!events.is_empty(), "tracing on but the ring is empty");
            println!("SMOKE_TRACE {trace}");
        }
        if !metrics_path.is_empty() {
            // Synchronous write so the CI job can assert on the file
            // without racing the 500 ms exporter tick.
            xtpu::util::json::write_file(
                &std::path::PathBuf::from(&metrics_path),
                &server.stats.metrics_json(),
            )?;
            println!("smoke: wrote metrics to {metrics_path}");
        }
        server.shutdown();
        println!("smoke OK");
        return Ok(());
    }
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_fleet(argv: &[String]) -> Result<()> {
    let Some(args) = parse_or_help(
        argv,
        "fleet",
        "Aging-aware multi-device fleet simulation over deployed plans.",
        vec![
            OptSpec::opt(
                "plan",
                "",
                "pre-solved VoltagePlan file(s) from `xtpu plan`; repeat or comma-separate",
            ),
            OptSpec::opt(
                "mse-ubs",
                "0.0,2.0",
                "budgets to solve at startup when no --plan is given",
            ),
            OptSpec::opt("devices", "4", "fleet size"),
            OptSpec::opt(
                "trace",
                "poisson:rps=200,secs=2",
                "poisson:rps=..,secs=.. | closed:clients=..,reqs=..,think=..",
            ),
            OptSpec::opt("mix", "", "quality-class weights, e.g. 0.6,0.3,0.1 (default uniform)"),
            OptSpec::opt("policy", "wear-level", "round-robin | least-loaded | wear-level"),
            OptSpec::opt("rotate", "64", "wear-level: picks between plan-rotation re-rankings"),
            OptSpec::opt("slack-ms", "50", "wear-level: backlog slack over the fleet minimum"),
            OptSpec::opt("service-us", "1000", "virtual service time per request"),
            OptSpec::opt("wear-accel", "1e6", "deployed seconds of wear per virtual busy second"),
            OptSpec::opt(
                "initial-ages",
                "",
                "prior service years per device (cycled), e.g. 2.0,1.0,0",
            ),
            OptSpec::opt(
                "replan",
                "never",
                "drift-triggered re-planning: never | threshold | periodic | observed",
            ),
            OptSpec::opt(
                "guard-band",
                "0.05",
                "threshold re-plan: delay-margin decay (fraction) that triggers a re-solve",
            ),
            OptSpec::opt(
                "replan-every-years",
                "0.01",
                "periodic re-plan: deployed (wear-clock) years between re-solves",
            ),
            OptSpec::opt(
                "replan-quality-ratio",
                "1.0",
                "observed re-plan: measured served-MSE-to-budget ratio that \
                 triggers a re-solve",
            ),
            OptSpec::opt(
                "replan-mode",
                "",
                "switch operating regime at the first re-plan: statistical | tedrop \
                 (default: keep each plan's deployed mode)",
            ),
            OptSpec::opt("report", "", "write the JSON telemetry report to this path"),
            OptSpec::flag("smoke", "self-check the emitted report, then exit"),
        ],
    )?
    else {
        return Ok(());
    };
    let t0 = std::time::Instant::now();
    let (mut planner, plans) = resolve_plans(&args)?;
    let registry = planner.registry()?.clone();
    let trained = planner.trained()?;
    let quantized = trained.quantized.clone();
    let input_dim = trained.model.input.numel();
    let test = trained.test.clone();
    let devices = args.usize("devices")?;
    // Share-nothing across the fleet: one backend instance per device, the
    // same pool a `serve` worker pool would use.
    let pool = xtpu::plan::make_backend_pool(&planner.cfg, &registry, devices)?;
    let engine = std::sync::Arc::new(
        xtpu::server::Engine::from_plans(quantized, &registry, &plans, input_dim)?
            .with_backend_pool(pool),
    );
    let mix = {
        let m = args.f64_list("mix")?;
        if m.is_empty() {
            vec![1.0; plans.len()]
        } else {
            anyhow::ensure!(
                m.len() == plans.len(),
                "--mix has {} weights but {} plans are deployed",
                m.len(),
                plans.len()
            );
            m
        }
    };
    let seed = args.u64("seed")?;
    let trace = Trace::parse(args.str("trace"), &mix, seed ^ 0xF1EE)?;
    // One alias table (policy_from_name); the CLI only re-parameterizes
    // the wear-leveler with the --slack-ms/--rotate knobs afterwards.
    let mut policy = policy_from_name(args.str("policy"))?;
    if policy.name() == "wear_leveling" {
        policy = Box::new(WearLeveling::new(
            args.f64("slack-ms")? / 1000.0,
            args.u64("rotate")?,
        ));
    }
    let cfg = FleetConfig {
        devices,
        service_seconds: args.f64("service-us")? / 1e6,
        wear_accel: args.f64("wear-accel")?,
        initial_age_years: args.f64_list("initial-ages")?,
        ..FleetConfig::default()
    };
    // Adaptive loop: any --replan policy other than `never` closes the
    // characterize → plan → serve → age → re-plan cycle. The power model
    // and registry come from the same planner `serve` resolves plans with,
    // so re-solved energies stay comparable to the boot-time plans.
    let replan = ReplanPolicy::from_name(
        args.str("replan"),
        args.f64("guard-band")?,
        args.f64("replan-every-years")?,
        args.f64("replan-quality-ratio")?,
    )?;
    let adaptive = replan != ReplanPolicy::Never;
    let mut fleet = if adaptive {
        let power = *planner.power();
        let mut ctx = AdaptiveContext::new(registry.clone(), power, replan);
        if !args.str("replan-mode").is_empty() {
            // Drift-triggered regime switch: once a device re-plans, its
            // plans are re-solved (and re-priced) in this mode — e.g.
            // statistical fleets falling back to TE-Drop detection as BTI
            // drift erodes the guard band.
            ctx.resolve.switch_mode =
                Some(xtpu::errormodel::PlanMode::from_name(args.str("replan-mode"))?);
        }
        Router::with_adaptation(
            engine,
            &plans,
            policy,
            cfg,
            ctx,
        )?
    } else {
        Router::new(engine, &plans, policy, cfg)?
    };
    println!(
        "fleet: {} devices × {} plans ({} requests, policy {}, replan {}) ready in {:.1}s",
        devices,
        plans.len(),
        trace.request_count(),
        fleet.policy_name(),
        replan.name(),
        t0.elapsed().as_secs_f64()
    );
    let t1 = std::time::Instant::now();
    let report = fleet.run_with_inference(&trace, &test, seed);
    println!(
        "simulated + executed in {:.2}s wall\n\n{}",
        t1.elapsed().as_secs_f64(),
        report.summary()
    );
    let json = report.to_json();
    if !args.str("report").is_empty() {
        let path = std::path::PathBuf::from(args.str("report"));
        xtpu::util::json::write_file(&path, &json)?;
        println!("wrote {}", path.display());
    }
    if args.flag("smoke") {
        // CI self-check: the emitted report must parse back and carry the
        // keys operators and dashboards rely on.
        let back = xtpu::util::json::Json::parse(&json.to_string())?;
        for key in [
            "policy",
            "requests",
            "min_lifetime_years",
            "mean_lifetime_years",
            "energy_saving_vs_nominal",
            "latency_p99_ms",
        ] {
            anyhow::ensure!(back.get(key).is_ok(), "report is missing key '{key}'");
        }
        let devs = back.get("devices")?.as_arr()?;
        anyhow::ensure!(devs.len() == devices, "report covers {} devices", devs.len());
        for d in devs {
            anyhow::ensure!(
                d.get("projected_lifetime_years")?.as_f64()? >= 0.0,
                "device lifetime key missing or negative"
            );
        }
        let served: u64 = back.get("requests")?.as_u64()?;
        anyhow::ensure!(served as usize == trace.request_count(), "request conservation");
        if adaptive {
            // Adaptive smoke: the loop must have closed — re-plan events
            // recorded, quality curve sampled, and the report must carry
            // the keys the CI adaptive-smoke job asserts on.
            for key in ["replan_policy", "replans", "replan_events", "quality_curve", "max_mse_ratio"]
            {
                anyhow::ensure!(back.get(key).is_ok(), "adaptive report missing '{key}'");
            }
            anyhow::ensure!(
                back.get("replans")?.as_u64()? > 0,
                "adaptive smoke expected at least one re-plan event"
            );
            anyhow::ensure!(
                !back.get("quality_curve")?.as_arr()?.is_empty(),
                "adaptive smoke expected quality-vs-age samples"
            );
        }
        println!("fleet smoke OK");
    }
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let Some(args) = parse_or_help(argv, "info", "List artifacts and PJRT platform.", vec![])?
    else {
        return Ok(());
    };
    let dir = std::path::PathBuf::from(args.str("artifacts"));
    match xtpu::runtime::Runtime::new(&dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            match rt.available() {
                Ok(names) if !names.is_empty() => {
                    println!("artifacts in {}:", dir.display());
                    for n in names {
                        println!("  {n}");
                    }
                }
                _ => println!("no artifacts in {} (run `make artifacts`)", dir.display()),
            }
        }
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    Ok(())
}
