//! Threaded TCP inference server with runtime-adjustable quality — the
//! serving face of the X-TPU's "dynamic accuracy configuration" (paper
//! contribution 1): each request picks a quality level, the engine applies
//! the corresponding pre-solved voltage assignment's noise spec, and the
//! response reports the energy saving that level buys.
//!
//! Protocol: newline-delimited JSON.
//!   → {"pixels": [784 × f32], "quality": <level index>}
//!     (optional `"deadline_ms"`: the request's latency budget — requests
//!     the admission gate cannot serve in time get a typed
//!     `{"error": "shed", ...}` line instead of a late answer)
//!   ← {"class": c, "logits": [...], "quality": q, "generation": g}
//!   (or {"error": "..."} when the serving batch failed — the connection
//!   stays usable). `generation` is the hot-swappable plan set that served
//!   the request; `{"stats": true}` returns the audit counters.
//!
//! Requests are funneled through a dynamic batcher (size- or deadline-
//! triggered) so concurrent clients share quantized forward passes, like a
//! production serving stack would.
//!
//! ## Frontends
//!
//! Two interchangeable frontends accept traffic
//! ([`FrontendOptions::mode`]), both feeding the same shard queues through
//! the same [`shard::ShardSet`] admission gate, and producing bit-identical
//! replies for well-formed traffic:
//!
//! - **threaded** (default): one handler thread per connection — simple,
//!   debuggable, bounded by [`FrontendOptions::max_conns`] (excess accepts
//!   get a typed `{"error": "overloaded"}` line instead of an unbounded
//!   thread spawn);
//! - **evented** ([`reactor`]): one readiness-driven thread multiplexing
//!   thousands of nonblocking connections — the datacenter-scale mode.
//!
//! Multiple engine shards ([`Server::spawn_opts`]) serve one logical model
//! with placement governed by a live [`crate::fleet::RoutePolicy`] —
//! including wear-leveling over each shard's real accrued BTI stress (see
//! [`shard`]).
//!
//! ## Threading model
//!
//! Three thread populations cooperate, with **no global lock on the
//! inference hot path**:
//!
//! - the frontend threads above (I/O only);
//! - [`BatchPolicy::workers`] *batch workers per shard*, each owning its
//!   own [`Backend`] instance (from its [`Engine`]'s per-worker pool) and
//!   its own RNG. Workers contend only on their shard's job queue while
//!   *collecting* a batch; execution runs unlocked, so batches at
//!   different quality levels proceed concurrently
//!   ([`ServerStats::peak_concurrent_batches`] observes the overlap).
//!
//! Within one batch, the shared exec kernel additionally shards the matmul
//! across `XTPU_THREADS` with deterministic per-shard RNG streams — a fixed
//! seed produces bit-identical noisy outputs at any thread count (see
//! [`crate::exec::kernel`]).

pub mod reactor;
pub mod shard;

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::errormodel::{ErrorModelRegistry, PlanMode};
use crate::exec::{Backend, Exact};
use crate::fleet::RoutePolicy;
use crate::nn::quant::{ForwardArena, NoiseSpec, PackedModel, QuantizedModel};
use crate::nn::tensor::Tensor;
use crate::obs::audit::{AuditConfig, QualityAudit};
use crate::obs::metrics::{LatencyHistogram, Registry};
use crate::obs::trace::Tracer;
use crate::plan::VoltagePlan;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool;

use shard::ShardSet;

/// A quality level: pre-solved assignment → noise spec + energy saving.
#[derive(Clone, Debug)]
pub struct QualityLevel {
    pub name: String,
    pub noise: NoiseSpec,
    pub energy_saving: f64,
    /// Estimated energy of one inference at this level, in the normalized
    /// gate-energy units of [`crate::power`] (a plan's `energy` field).
    /// Zero when the level was hand-assembled without an energy model.
    pub energy: f64,
    /// The offline error model's predicted served output MSE at this level
    /// (a plan's `predicted_mse`). The online quality audit
    /// ([`crate::obs::audit`]) compares observed shadow-execution MSE
    /// against this; levels carrying 0 (the exact level, hand-assembled
    /// levels) are audited on an absolute epsilon instead of a ratio.
    pub predicted_mse: f64,
}

/// One generation of deployed quality levels: what a request executes
/// against, immutable once installed. The engine swaps whole `PlanSet`s
/// atomically ([`Engine::swap_levels`] / [`Engine::swap_plans`]); a batch
/// snapshots the active set once and finishes on it, so in-flight work
/// never observes a half-applied swap and every response is served by
/// exactly one generation.
#[derive(Clone, Debug)]
pub struct PlanSet {
    /// The engine's swap counter at install time (0 = the initial set).
    /// Distinct from [`VoltagePlan::generation`], which tracks a single
    /// plan's re-plan lineage.
    pub generation: u64,
    pub levels: Vec<QualityLevel>,
    /// The generation's packed-weight cache and precomputed noise
    /// liveness (see [`PackedCache`]): built once at install time, shared
    /// lock-free by every batch worker holding this snapshot. A hot swap
    /// publishes a whole new cache with the new set — the generation
    /// mechanism *is* the cache invalidation.
    pub packed: Arc<PackedCache>,
}

/// The once-per-generation precompute a [`PlanSet`] carries: the model's
/// weights SIMD-packed for the process-active path ([`PackedModel`]) plus
/// the per-level noise analysis ([`NoiseSpec`] silences) the per-batch hot
/// path would otherwise rediscover on every call. Immutable after
/// construction; batch workers reach it through their plan-set snapshot, so
/// no lock and no copy sits on the serving path.
#[derive(Debug)]
pub struct PackedCache {
    /// SIMD-packed weights of every dense layer (weight-stationary cache).
    pub model: PackedModel,
    /// `layer_live[level][mac_layer]`: does the level's noise spec touch
    /// that layer ([`NoiseSpec::layer_liveness`])? Lets silent layers skip
    /// the per-call scan without perturbing any RNG stream.
    pub layer_live: Vec<Vec<bool>>,
    /// `level_live[level] = !levels[level].noise.is_silent()` — the
    /// whole-spec scan [`Engine::execute_on`] performs per batch, hoisted.
    pub level_live: Vec<bool>,
}

impl PlanSet {
    /// Build one generation snapshot: pack the quantized weights for the
    /// process-active SIMD path and precompute every level's noise
    /// liveness. All the per-swap cost lives here — the per-batch path
    /// only follows `Arc`s.
    fn build(generation: u64, levels: Vec<QualityLevel>, quantized: &QuantizedModel) -> Self {
        let model = PackedModel::pack(quantized, crate::exec::dispatch::active());
        let widths = quantized.mac_widths();
        let layer_live =
            levels.iter().map(|l| l.noise.layer_liveness(&widths)).collect();
        let level_live = levels.iter().map(|l| !l.noise.is_silent()).collect();
        Self {
            generation,
            levels,
            packed: Arc::new(PackedCache { model, layer_live, level_live }),
        }
    }

    /// Clamp a requested quality index to a valid level of this set.
    pub fn clamp(&self, quality: usize) -> usize {
        quality.min(self.levels.len().saturating_sub(1))
    }
}

/// The inference engine shared by all connections: the quantized model,
/// the (hot-swappable) pre-solved quality levels, and a pool of per-worker
/// [`Backend`] instances. Backends are `Send + Sync` with `&self`
/// execution, so the pool needs no locks — each batch worker just holds
/// its own handle. The active [`PlanSet`] lives behind an `RwLock<Arc<…>>`:
/// readers take a snapshot (one `Arc` clone), writers swap the pointer —
/// the serving hot path never blocks on a swap in progress beyond that
/// pointer exchange.
pub struct Engine {
    pub quantized: QuantizedModel,
    pub input_dim: usize,
    active: RwLock<Arc<PlanSet>>,
    swap_counter: AtomicU64,
    backends: Vec<Arc<dyn Backend>>,
}

impl Engine {
    /// Build an engine from pre-solved quality levels. Errors on an empty
    /// level list — the request path clamps `quality` to the last level, so
    /// a level-less engine could never answer anything.
    pub fn new(
        quantized: QuantizedModel,
        levels: Vec<QualityLevel>,
        input_dim: usize,
    ) -> Result<Self> {
        anyhow::ensure!(
            !levels.is_empty(),
            "engine needs at least one quality level (got none)"
        );
        let set = PlanSet::build(0, levels, &quantized);
        Ok(Self {
            quantized,
            input_dim,
            active: RwLock::new(Arc::new(set)),
            swap_counter: AtomicU64::new(0),
            backends: Vec::new(),
        })
    }

    /// Build an engine whose quality levels come from deployable
    /// [`VoltagePlan`] artifacts (`xtpu plan` → `xtpu serve --plan`): the
    /// noise spec and energy saving of every level are derived from the
    /// solved assignment, not hand-rolled. Validates that every plan fits
    /// the model + registry and that all plans came from the same offline
    /// run, then serves with **zero solve latency**.
    pub fn from_plans(
        quantized: QuantizedModel,
        registry: &ErrorModelRegistry,
        plans: &[VoltagePlan],
        input_dim: usize,
    ) -> Result<Self> {
        let levels = levels_from_plans(&quantized, registry, plans)?;
        Self::new(quantized, levels, input_dim)
    }

    /// Snapshot the active [`PlanSet`]. Cheap (one `Arc` clone); the
    /// returned set stays valid across swaps — this is how in-flight
    /// batches finish on the generation they started with.
    pub fn plan_set(&self) -> Arc<PlanSet> {
        self.active.read().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Number of quality levels in the currently active set.
    pub fn num_levels(&self) -> usize {
        self.plan_set().levels.len()
    }

    /// The active set's generation (last completed swap).
    pub fn generation(&self) -> u64 {
        self.swap_counter.load(Ordering::SeqCst)
    }

    /// Atomically replace the active quality levels with a new
    /// generation. In-flight batches keep executing on the snapshot they
    /// already hold; every batch collected after this returns sees the new
    /// set. Returns the new generation number.
    pub fn swap_levels(&self, levels: Vec<QualityLevel>) -> Result<u64> {
        anyhow::ensure!(!levels.is_empty(), "cannot swap in an empty quality-level set");
        // Counter bump and pointer store happen under the write lock so
        // concurrent swappers cannot publish generations out of order. The
        // repack cost (PlanSet::build) is paid here, on the swap path — the
        // serving hot path only ever follows the published Arc.
        let mut guard = self.active.write().unwrap_or_else(|e| e.into_inner());
        let generation = self.swap_counter.fetch_add(1, Ordering::SeqCst) + 1;
        *guard = Arc::new(PlanSet::build(generation, levels, &self.quantized));
        Ok(generation)
    }

    /// [`Self::swap_levels`] from deployable plans: validates every plan
    /// against the engine's model and the given registry (which may be a
    /// drift-adjusted one — [`crate::errormodel::DriftedRegistry::registry`])
    /// before the swap, so a bad artifact can never replace a serving set.
    pub fn swap_plans(
        &self,
        registry: &ErrorModelRegistry,
        plans: &[VoltagePlan],
    ) -> Result<u64> {
        let levels = levels_from_plans(&self.quantized, registry, plans)?;
        self.swap_levels(levels)
    }

    /// Install one execution backend instance shared by every batch worker
    /// (e.g. a [`Statistical`](crate::exec::Statistical) or
    /// [`Pjrt`](crate::exec::Pjrt) backend from
    /// [`Pipeline::make_backend`](crate::coordinator::Pipeline::make_backend)).
    /// Safe for concurrent batches — backends execute through `&self`; a
    /// [`GateLevel`](crate::exec::GateLevel) backend serializes internally.
    pub fn with_backend(mut self, backend: Box<dyn Backend>) -> Self {
        self.backends = vec![Arc::from(backend)];
        self
    }

    /// Install a share-nothing pool: worker `i` executes on
    /// `backends[i % len]` (see
    /// [`Pipeline::make_backend_pool`](crate::coordinator::Pipeline::make_backend_pool)).
    pub fn with_backend_pool(mut self, backends: Vec<Box<dyn Backend>>) -> Self {
        self.backends = backends.into_iter().map(Arc::from).collect();
        self
    }

    /// The backend batch worker `worker` executes on ([`Exact`] when none
    /// was installed). The shared `Exact` fallback is a process-wide
    /// singleton — resolving a worker's backend never allocates.
    fn backend_for(&self, worker: usize) -> Arc<dyn Backend> {
        if self.backends.is_empty() {
            static EXACT: std::sync::OnceLock<Arc<dyn Backend>> = std::sync::OnceLock::new();
            EXACT.get_or_init(|| Arc::new(Exact)).clone()
        } else {
            self.backends[worker % self.backends.len()].clone()
        }
    }

    /// Public view of the worker → backend mapping, for callers that hold
    /// the backend across many batches (the batch workers resolve theirs
    /// once at startup; benches do the same).
    pub fn worker_backend(&self, worker: usize) -> Arc<dyn Backend> {
        self.backend_for(worker)
    }

    /// Clamp a requested quality index to a valid level of the *active*
    /// set (`Engine::new` guarantees at least one level exists). Batch
    /// workers clamp against their snapshot instead, so a mid-batch swap
    /// cannot shear the clamp from the execution.
    pub fn clamp_level(&self, quality: usize) -> usize {
        self.plan_set().clamp(quality)
    }

    /// Execute one batch of rows at the given (clamped) quality level on
    /// worker `worker`'s backend and return the logits. Snapshots the
    /// active plan set; use [`Self::execute_on`] to pin a batch to a
    /// generation across multiple calls.
    pub fn execute_batch(
        &self,
        worker: usize,
        x: &Tensor,
        quality: usize,
        rng: &mut Xoshiro256pp,
    ) -> Tensor {
        let set = self.plan_set();
        self.execute_on(&set, worker, x, quality, rng)
    }

    /// Execute one batch against an explicit [`PlanSet`] snapshot — the
    /// single inference entry the TCP batch workers, the hot-swap path and
    /// the fleet simulator's devices all go through.
    pub fn execute_on(
        &self,
        set: &PlanSet,
        worker: usize,
        x: &Tensor,
        quality: usize,
        rng: &mut Xoshiro256pp,
    ) -> Tensor {
        let spec = &set.levels[set.clamp(quality)].noise;
        let noise_opt = if spec.is_silent() { None } else { Some(spec) };
        self.execute_with_spec(worker, x, noise_opt, rng)
    }

    /// Zero-repack batch execution against a [`PlanSet`] snapshot: the
    /// steady-state entry the batch workers use. Consumes the snapshot's
    /// [`PackedCache`] (weights packed once per generation, per-level
    /// liveness precomputed) and the caller's [`ForwardArena`] + logits
    /// buffer, so a warm call performs no repacking and no heap
    /// allocation. Bit-identical to [`Self::execute_on`] for any seed:
    /// the prepacked kernels replicate the per-call paths' accumulation
    /// order and RNG key-draw schedule exactly.
    #[allow(clippy::too_many_arguments)]
    pub fn execute_packed(
        &self,
        set: &PlanSet,
        backend: &dyn Backend,
        x: &Tensor,
        quality: usize,
        rng: &mut Xoshiro256pp,
        arena: &mut ForwardArena,
        logits: &mut Vec<f32>,
    ) {
        let level = set.clamp(quality);
        let cache = &set.packed;
        let noise_opt =
            if cache.level_live[level] { Some(&set.levels[level].noise) } else { None };
        self.quantized.forward_prepacked(
            backend,
            x,
            noise_opt,
            Some(cache.layer_live[level].as_slice()),
            rng,
            &cache.model,
            arena,
            logits,
        );
    }

    /// Lowest-level execution seam: run one batch with an explicit noise
    /// spec (or none) on worker `worker`'s backend. The fleet simulator
    /// uses this to serve requests under *drift-adjusted* specs that never
    /// correspond to an installed level.
    pub fn execute_with_spec(
        &self,
        worker: usize,
        x: &Tensor,
        noise: Option<&crate::nn::quant::NoiseSpec>,
        rng: &mut Xoshiro256pp,
    ) -> Tensor {
        let backend = self.backend_for(worker);
        self.quantized.forward_with(backend.as_ref(), x, noise, rng)
    }

    /// Error-free reference execution on a dedicated [`Exact`] backend —
    /// the quality audit's shadow run. Bypasses the worker pool (whose
    /// backends realize the *deployed* regime) and injects no noise; a
    /// clean forward draws nothing from `rng`, so shadow-executing a
    /// sampled batch leaves the worker's noise stream — and with it every
    /// served output — bit-identical to an unaudited run.
    pub fn execute_exact(&self, x: &Tensor, rng: &mut Xoshiro256pp) -> Tensor {
        self.quantized.forward_with(&Exact, x, None, rng)
    }

    /// Estimated energy of one request at `quality` (clamped) on the
    /// active set, in the normalized gate-energy units of [`crate::power`].
    /// Zero when the levels carry no energy model (hand-assembled engines).
    pub fn energy_estimate(&self, quality: usize) -> f64 {
        let set = self.plan_set();
        set.levels[set.clamp(quality)].energy
    }

    /// Estimated energy one request would cost at the all-nominal
    /// assignment — the reference `energy_saving` fractions are relative
    /// to. Zero when the levels carry no energy model.
    pub fn nominal_energy_estimate(&self) -> f64 {
        self.plan_set()
            .levels
            .iter()
            .find(|l| l.energy > 0.0 && l.energy_saving < 1.0)
            .map(|l| l.energy / (1.0 - l.energy_saving))
            .unwrap_or(0.0)
    }
}

/// Typed deployment error: the operating regime (`mode`) of a plan set is
/// inconsistent — either a plan's `mode` disagrees with the backend family
/// its embedded config builds (TE-Drop recovery only happens on the
/// `tedrop` backend; moment-matched noise injection must not run on it),
/// or two plans in one set were solved under different regimes. Surfaced
/// through `anyhow`, so deployment tooling can
/// `err.downcast_ref::<ModeMismatch>()` and report it distinctly from
/// generic artifact corruption.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ModeMismatch {
    /// `plan` runs in `mode`, but its config selects `backend` — the pool
    /// [`Engine::with_backend_pool`] installs from that config cannot
    /// realize the regime the plan was priced for.
    Backend { plan: String, mode: String, backend: String },
    /// `plan` was solved in `mode`, but the set's first plan in
    /// `expected` — one engine serves one operating regime.
    CrossPlan { plan: String, mode: String, expected: String },
}

impl std::fmt::Display for ModeMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModeMismatch::Backend { plan, mode, backend } => write!(
                f,
                "plan '{plan}' runs in {mode} mode but its config builds the \
                 '{backend}' backend (tedrop mode requires the tedrop backend; \
                 statistical mode must not use it)"
            ),
            ModeMismatch::CrossPlan { plan, mode, expected } => write!(
                f,
                "plan '{plan}' was solved in {mode} mode but the deployed set \
                 is {expected}: one engine serves one operating regime"
            ),
        }
    }
}

impl std::error::Error for ModeMismatch {}

/// Derive the quality levels a set of deployable plans encodes under
/// `registry`, after validating plan ↔ model ↔ registry consistency,
/// cross-plan provenance, and operating-regime coherence (one `mode`
/// across the set, matched to the backend family each config builds).
/// Shared by [`Engine::from_plans`] and [`Engine::swap_plans`] so
/// boot-time and hot-swap deployment can never diverge.
fn levels_from_plans(
    quantized: &QuantizedModel,
    registry: &ErrorModelRegistry,
    plans: &[VoltagePlan],
) -> Result<Vec<QualityLevel>> {
    anyhow::ensure!(!plans.is_empty(), "engine needs at least one plan (got none)");
    for p in plans {
        p.validate_against(quantized, registry)?;
    }
    for p in &plans[1..] {
        plans[0].check_compatible(p)?;
    }
    let expected = plans[0].plan_mode();
    for p in plans {
        let mode = p.plan_mode();
        if mode != expected {
            return Err(ModeMismatch::CrossPlan {
                plan: p.name.clone(),
                mode: mode.name().to_string(),
                expected: expected.name().to_string(),
            }
            .into());
        }
        let backend_fits = match mode {
            PlanMode::TeDrop => p.config.backend == "tedrop",
            PlanMode::Statistical => p.config.backend != "tedrop",
        };
        if !backend_fits {
            return Err(ModeMismatch::Backend {
                plan: p.name.clone(),
                mode: mode.name().to_string(),
                backend: p.config.backend.clone(),
            }
            .into());
        }
    }
    Ok(plans
        .iter()
        .map(|p| QualityLevel {
            name: p.name.clone(),
            noise: p.noise_spec(registry),
            energy_saving: p.energy_saving,
            energy: p.energy,
            predicted_mse: p.predicted_mse,
        })
        .collect())
}

/// One queued inference request (both frontends produce these; the
/// [`shard::ShardSet`] admission gate is the only producer path).
pub(crate) struct Job {
    pub(crate) pixels: Vec<f32>,
    pub(crate) quality: usize,
    /// Absolute reply-by time (from the request's `deadline_ms` tag, or
    /// the server SLO). Late replies are still delivered, but counted in
    /// [`ServerStats::deadline_missed`].
    pub(crate) deadline: Option<Instant>,
    /// When the admission gate accepted the job — the latency clock.
    pub(crate) enqueued: Instant,
    pub(crate) reply: Reply,
    /// Sampled trace span riding the request (None for unsampled
    /// requests). Stage marks are stamped along the pipeline; dropping
    /// the job — replied, shed, or lost to a worker panic — commits the
    /// record to the tracer's ring.
    pub(crate) trace: Option<Box<crate::obs::trace::ActiveSpan>>,
}

/// Where a finished inference goes: the handler thread's blocking channel
/// (threaded frontend) or the reactor's completion queue (evented
/// frontend). Both carry `(applied level, plan-set generation, logits)`;
/// both surface a dropped-without-answer reply (worker panic, shutdown
/// drain) to the client as the same typed error line.
pub(crate) enum Reply {
    Channel(Sender<(usize, u64, Vec<f32>)>),
    Evented(reactor::CompletionSink),
}

impl Reply {
    fn send_ok(&mut self, level: usize, generation: u64, logits: Vec<f32>) {
        match self {
            Reply::Channel(tx) => {
                let _ = tx.send((level, generation, logits));
            }
            Reply::Evented(sink) => sink.complete_ok(level, generation, logits),
        }
    }

    /// Disarm any drop-side error delivery. The admission gate calls this
    /// before dropping a refused request's reply route: the frontend
    /// answers with the typed shed line itself, and an evented sink whose
    /// `Drop` still fired would enqueue a second, stray error line on the
    /// same connection. (The channel arm needs no disarming — the threaded
    /// handler never reads its receiver on the refused path.)
    pub(crate) fn defuse(&mut self) {
        if let Reply::Evented(sink) = self {
            sink.defuse();
        }
    }
}

/// How many trace records the per-server ring buffer retains.
const TRACE_RING_CAPACITY: usize = 4096;

/// A grow-on-demand vector of monotonic counters whose hot path is one
/// read-lock acquire plus one relaxed `fetch_add`, and whose reporting
/// path snapshots through an `Arc` instead of deep-cloning the counts
/// under a mutex (the old `Mutex<Vec<u64>>` did both per event *and* per
/// stats request). Growth replaces the whole vector under the write lock;
/// because increments only ever happen while the read guard is held, a
/// concurrent grow (which copies current values into the replacement)
/// can never lose an update.
pub struct CounterVec {
    cells: RwLock<Arc<Vec<AtomicU64>>>,
}

impl CounterVec {
    fn new(n: usize) -> Self {
        Self { cells: RwLock::new(Arc::new((0..n).map(|_| AtomicU64::new(0)).collect())) }
    }

    /// Replace the contents with exactly `n` zeroed cells.
    fn reset(&self, n: usize) {
        let mut guard = self.cells.write().unwrap_or_else(|e| e.into_inner());
        *guard = Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
    }

    /// Add `n` to cell `idx`, growing the vector when `idx` is past the
    /// end (a hot swap to a larger plan set keeps counting).
    fn add(&self, idx: usize, n: u64) {
        {
            let cells = self.cells.read().unwrap_or_else(|e| e.into_inner());
            if let Some(c) = cells.get(idx) {
                // Increment under the read guard — see the struct docs.
                c.fetch_add(n, Ordering::Relaxed);
                return;
            }
        }
        let guard = self.cells.write().unwrap_or_else(|e| e.into_inner());
        // Re-check: a racing grower may already have made room.
        if idx < guard.len() {
            guard[idx].fetch_add(n, Ordering::Relaxed);
            return;
        }
        let mut grown = guard;
        let replacement: Vec<AtomicU64> = (0..=idx)
            .map(|i| AtomicU64::new(grown.get(i).map_or(0, |c| c.load(Ordering::Relaxed))))
            .collect();
        replacement[idx].fetch_add(n, Ordering::Relaxed);
        *grown = Arc::new(replacement);
    }

    /// Snapshot the cells: one `Arc` clone, no per-cell copy. The stats
    /// and metrics expositions iterate this directly.
    pub fn snapshot(&self) -> Arc<Vec<AtomicU64>> {
        self.cells.read().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Materialized counts — the pre-existing public stats shape.
    pub fn counts(&self) -> Vec<u64> {
        self.snapshot().iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }
}

/// Server statistics (exposed for tests/benches, and to clients via a
/// `{"stats": true}` request line).
///
/// The atomic fields are the hot-path cells (one relaxed op per event);
/// [`Self::publish`] snapshots them into the server's obs
/// [`Registry`] — the single exposition surface behind the
/// `{"metrics": true}` protocol line and `--metrics-file` — where the
/// quality audit and the tracer register their own series directly.
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    /// Batches currently executing across all workers.
    pub inflight_batches: AtomicU64,
    /// High-water mark of `inflight_batches` — ≥ 2 demonstrates that the
    /// engine really executed batches concurrently (the property the old
    /// global backend mutex made impossible).
    pub peak_concurrent_batches: AtomicU64,
    /// Requests served per quality level (index = clamped level), so
    /// operators can see which deployed plans are actually exercised.
    /// Grows on demand: a hot swap to a larger plan set keeps counting.
    per_level: CounterVec,
    /// Requests attributed per plan-set generation — the audit trail of a
    /// hot swap: in-flight batches drain onto the old generation while new
    /// batches land on the new one. Failed (panicked) batches are
    /// attributed too, so the counters always conserve `requests`.
    pub per_generation: Mutex<BTreeMap<u64, u64>>,
    /// Batch-worker panics survived: the worker recovered (or a peer
    /// recovered its poisoned queue lock) instead of cascading the panic
    /// across the pool.
    pub worker_panics: AtomicU64,
    /// Requests refused by the admission gate (queue-depth or deadline) —
    /// each got a typed `{"error": "shed", ...}` line, never a hang.
    /// `shed + requests` conserves everything the gate accepted or
    /// refused.
    pub shed: AtomicU64,
    /// Replies delivered after their deadline (the reply still goes out;
    /// an SLO miss is an observable, not a drop).
    pub deadline_missed: AtomicU64,
    /// Connections refused at the frontend's concurrency cap (typed
    /// `{"error": "overloaded"}` line, then close).
    pub conn_rejected: AtomicU64,
    /// Jobs currently sitting in shard queues — the admission gate's
    /// queue-depth view (incremented on submit, decremented when a batch
    /// worker collects).
    pub queued: AtomicU64,
    /// EWMA per-request service time in nanoseconds (0 until the first
    /// batch completes) — the deadline gate's wait estimator.
    pub est_service_ns: AtomicU64,
    /// End-to-end request latency (admission → reply serialization),
    /// power-of-two µs buckets; p50/p99 are surfaced in stats replies.
    pub latency: LatencyHistogram,
    /// Requests routed per shard — the observable that shard placement
    /// (round-robin fairness, wear-leveling steering) actually happened.
    per_shard: CounterVec,
    /// The server's metrics registry (see the struct docs).
    pub registry: Arc<Registry>,
    /// Sampled per-request tracing ([`crate::obs::trace`]); sampling is
    /// off (rate 0) unless [`FrontendOptions::trace_sample`] enables it.
    pub tracer: Arc<Tracer>,
    /// The online quality audit ([`crate::obs::audit`]); disabled unless
    /// [`FrontendOptions::audit`] configures a sampling rate.
    pub audit: Arc<QualityAudit>,
}

impl Default for ServerStats {
    fn default() -> Self {
        let registry = Arc::new(Registry::new());
        Self {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            inflight_batches: AtomicU64::new(0),
            peak_concurrent_batches: AtomicU64::new(0),
            per_level: CounterVec::new(0),
            per_generation: Mutex::new(BTreeMap::new()),
            worker_panics: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            deadline_missed: AtomicU64::new(0),
            conn_rejected: AtomicU64::new(0),
            queued: AtomicU64::new(0),
            est_service_ns: AtomicU64::new(0),
            latency: LatencyHistogram::new(),
            per_shard: CounterVec::new(0),
            tracer: Arc::new(Tracer::new(TRACE_RING_CAPACITY)),
            audit: Arc::new(QualityAudit::new(AuditConfig::default(), registry.clone())),
            registry,
        }
    }
}

impl ServerStats {
    pub fn new(levels: usize) -> Self {
        Self { per_level: CounterVec::new(levels), ..Default::default() }
    }

    fn record_level(&self, level: usize, requests: u64) {
        self.per_level.add(level, requests);
    }

    /// Requests served per (clamped) quality level.
    pub fn per_level_counts(&self) -> Vec<u64> {
        self.per_level.counts()
    }

    fn record_generation(&self, generation: u64, requests: u64) {
        let mut map = self.per_generation.lock().unwrap_or_else(|e| e.into_inner());
        *map.entry(generation).or_insert(0) += requests;
    }

    pub(crate) fn init_shards(&self, n: usize) {
        self.per_shard.reset(n);
    }

    pub(crate) fn record_shard(&self, shard: usize) {
        self.per_shard.add(shard, 1);
    }

    /// Requests routed per shard (index = shard id).
    pub fn per_shard_counts(&self) -> Vec<u64> {
        self.per_shard.counts()
    }

    /// Fold one measured per-request service time into the EWMA the
    /// deadline gate uses (α = 1/8; single-writer precision is not needed
    /// — any worker's recent observation is a fine estimate).
    pub(crate) fn observe_service(&self, ns_per_request: u64) {
        let old = self.est_service_ns.load(Ordering::Relaxed);
        let new = if old == 0 { ns_per_request } else { old - old / 8 + ns_per_request / 8 };
        self.est_service_ns.store(new, Ordering::Relaxed);
    }

    /// Snapshot every hot-path cell into the obs registry: monotonic
    /// counters advance by their delta (so registry counters stay
    /// monotone), instantaneous values land in gauges. Called by the
    /// metrics expositions, never on the request path — the audit's and
    /// tracer's series live in the registry already and need no sync.
    pub fn publish(&self) {
        let reg = &self.registry;
        let counter = |name: &str, labels: &[(&str, &str)], v: u64| {
            let c = reg.counter(name, labels);
            c.add(v.saturating_sub(c.get()));
        };
        counter("server_requests_total", &[], self.requests.load(Ordering::Relaxed));
        counter("server_batches_total", &[], self.batches.load(Ordering::Relaxed));
        counter("server_worker_panics_total", &[], self.worker_panics.load(Ordering::Relaxed));
        counter("server_shed_total", &[], self.shed.load(Ordering::Relaxed));
        counter(
            "server_deadline_missed_total",
            &[],
            self.deadline_missed.load(Ordering::Relaxed),
        );
        counter("server_conn_rejected_total", &[], self.conn_rejected.load(Ordering::Relaxed));
        for (i, c) in self.per_level.snapshot().iter().enumerate() {
            let level = i.to_string();
            counter("server_served_total", &[("level", &level)], c.load(Ordering::Relaxed));
        }
        for (i, c) in self.per_shard.snapshot().iter().enumerate() {
            let shard = i.to_string();
            counter("server_routed_total", &[("shard", &shard)], c.load(Ordering::Relaxed));
        }
        {
            let map = self.per_generation.lock().unwrap_or_else(|e| e.into_inner());
            for (g, &n) in map.iter() {
                let generation = g.to_string();
                counter(
                    "server_requests_by_generation_total",
                    &[("generation", &generation)],
                    n,
                );
            }
        }
        reg.gauge("server_queued", &[]).set(self.queued.load(Ordering::Relaxed) as f64);
        reg.gauge("server_inflight_batches", &[])
            .set(self.inflight_batches.load(Ordering::Relaxed) as f64);
        reg.gauge("server_peak_concurrent_batches", &[])
            .set(self.peak_concurrent_batches.load(Ordering::Relaxed) as f64);
        reg.gauge("server_est_service_ns", &[])
            .set(self.est_service_ns.load(Ordering::Relaxed) as f64);
        reg.gauge("server_request_latency_us_count", &[]).set(self.latency.count() as f64);
        reg.gauge("server_request_latency_us_p50", &[])
            .set(self.latency.quantile_us(0.50) as f64);
        reg.gauge("server_request_latency_us_p99", &[])
            .set(self.latency.quantile_us(0.99) as f64);
        reg.gauge("trace_sample_every", &[]).set(self.tracer.sample_every() as f64);
        reg.gauge("trace_records", &[]).set(self.tracer.len() as f64);
    }

    /// The `{"metrics": true}` payload: this server's registry plus the
    /// process-wide one (where `exec` publishes), both freshly synced.
    pub fn metrics_json(&self) -> Json {
        self.publish();
        Json::obj(vec![
            ("server", self.registry.to_json()),
            ("process", crate::obs::metrics::global().to_json()),
        ])
    }

    /// Prometheus-style text over the same series as
    /// [`Self::metrics_json`] (server registry first, then the process
    /// registry; names do not collide).
    pub fn metrics_text(&self) -> String {
        self.publish();
        let mut s = self.registry.to_text();
        s.push_str(&crate::obs::metrics::global().to_text());
        s
    }

    /// Snapshot as JSON — what the server returns for a stats request.
    /// The key set is pinned by a golden-file test
    /// (`rust/tests/golden_stats_schema.txt`): every tracked counter is
    /// exported, and removing a key is a breaking protocol change.
    pub fn to_json(&self) -> Json {
        let per_generation = {
            let map = self.per_generation.lock().unwrap_or_else(|e| e.into_inner());
            Json::Obj(
                map.iter().map(|(g, n)| (g.to_string(), Json::Num(*n as f64))).collect(),
            )
        };
        Json::obj(vec![
            ("requests", Json::Num(self.requests.load(Ordering::Relaxed) as f64)),
            ("batches", Json::Num(self.batches.load(Ordering::Relaxed) as f64)),
            (
                "inflight_batches",
                Json::Num(self.inflight_batches.load(Ordering::Relaxed) as f64),
            ),
            (
                "est_service_ns",
                Json::Num(self.est_service_ns.load(Ordering::Relaxed) as f64),
            ),
            (
                "audit",
                self.audit.to_json(),
            ),
            (
                "quality_alarm",
                self.audit.alarm().map(|a| a.to_json()).unwrap_or(Json::Null),
            ),
            (
                "peak_concurrent_batches",
                Json::Num(self.peak_concurrent_batches.load(Ordering::Relaxed) as f64),
            ),
            (
                "per_level",
                Json::Arr(
                    self.per_level
                        .snapshot()
                        .iter()
                        .map(|c| Json::Num(c.load(Ordering::Relaxed) as f64))
                        .collect(),
                ),
            ),
            ("per_generation", per_generation),
            (
                "worker_panics",
                Json::Num(self.worker_panics.load(Ordering::Relaxed) as f64),
            ),
            ("shed", Json::Num(self.shed.load(Ordering::Relaxed) as f64)),
            (
                "deadline_missed",
                Json::Num(self.deadline_missed.load(Ordering::Relaxed) as f64),
            ),
            (
                "conn_rejected",
                Json::Num(self.conn_rejected.load(Ordering::Relaxed) as f64),
            ),
            ("queued", Json::Num(self.queued.load(Ordering::Relaxed) as f64)),
            ("latency_p50_us", Json::Num(self.latency.quantile_us(0.50) as f64)),
            ("latency_p99_us", Json::Num(self.latency.quantile_us(0.99) as f64)),
            (
                "per_shard",
                Json::Arr(
                    self.per_shard
                        .snapshot()
                        .iter()
                        .map(|c| Json::Num(c.load(Ordering::Relaxed) as f64))
                        .collect(),
                ),
            ),
        ])
    }
}

pub struct Server {
    pub addr: std::net::SocketAddr,
    pub stats: Arc<ServerStats>,
    /// The shard set serving this server — exposes per-shard wear
    /// (`Shard::headroom_x`) and the routing policy for introspection.
    pub shards: Arc<ShardSet>,
    shutdown: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    batch_handles: Vec<std::thread::JoinHandle<()>>,
}

/// Which frontend accepts traffic (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrontendMode {
    /// One handler thread per connection, capped at
    /// [`FrontendOptions::max_conns`].
    Threaded,
    /// One readiness-driven reactor thread ([`reactor`]) multiplexing all
    /// connections.
    Evented,
}

impl FrontendMode {
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "threaded" => Ok(Self::Threaded),
            "evented" => Ok(Self::Evented),
            other => anyhow::bail!("unknown frontend '{other}' (threaded|evented)"),
        }
    }
}

/// Frontend + admission-control configuration for [`Server::spawn_opts`].
/// The default reproduces the classic single-shard threaded server with
/// generous caps and no SLO, so existing callers change nothing.
pub struct FrontendOptions {
    pub mode: FrontendMode,
    /// Server-wide latency SLO: requests without their own `deadline_ms`
    /// inherit this budget at the admission gate. `None` = no deadline
    /// shedding (the queue-depth gate still applies).
    pub slo: Option<Duration>,
    /// Concurrent-connection cap (both frontends reject past it).
    pub max_conns: usize,
    /// Queue-depth cap across all shards — the backpressure gate.
    pub max_queue: usize,
    /// Shard routing policy (`None` = round-robin). Only consulted when
    /// more than one engine shard is spawned.
    pub route: Option<Box<dyn RoutePolicy>>,
    /// Wear accounting for the shards (enables wear-leveling routing on
    /// real accrued stress; see [`shard::WearConfig`]).
    pub wear: Option<shard::WearConfig>,
    /// Trace every n-th request through the full pipeline
    /// ([`crate::obs::trace`]); 0 (the default) is off and costs one
    /// relaxed atomic load per request.
    pub trace_sample: u64,
    /// Online quality-audit configuration ([`crate::obs::audit`]);
    /// `sample_every` 0 (the default) disables shadow execution entirely.
    pub audit: AuditConfig,
}

impl Default for FrontendOptions {
    fn default() -> Self {
        Self {
            mode: FrontendMode::Threaded,
            slo: None,
            max_conns: 1024,
            max_queue: 4096,
            route: None,
            wear: None,
            trace_sample: 0,
            audit: AuditConfig::default(),
        }
    }
}

/// Batching parameters.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Batch worker threads executing batches concurrently. 0 = auto
    /// (`min(worker_count(), 4)` — serving workers multiply with the
    /// kernel's own `XTPU_THREADS` sharding, so keep this modest).
    pub workers: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 16, max_wait: Duration::from_millis(5), workers: 0 }
    }
}

impl BatchPolicy {
    /// The number of batch worker threads [`Server::spawn`] will start for
    /// this policy (resolves the `workers == 0` auto default). Size backend
    /// pools with this so every worker gets its own instance.
    pub fn resolved_workers(&self) -> usize {
        if self.workers == 0 {
            threadpool::worker_count().clamp(1, 4)
        } else {
            self.workers
        }
    }
}

impl Server {
    /// Bind to `127.0.0.1:port` (0 = ephemeral) and start serving.
    pub fn spawn(engine: Engine, port: u16, policy: BatchPolicy) -> Result<Server> {
        Self::spawn_shared(Arc::new(engine), port, policy)
    }

    /// Like [`Self::spawn`] but the caller keeps a handle on the engine —
    /// the adaptive loop's entry point: hold the `Arc`, serve traffic, and
    /// [`Engine::swap_plans`] re-solved plans into the live server.
    pub fn spawn_shared(
        engine: Arc<Engine>,
        port: u16,
        policy: BatchPolicy,
    ) -> Result<Server> {
        Self::spawn_opts(vec![engine], port, policy, FrontendOptions::default())
    }

    /// Full-control entry point: several engine shards serving one logical
    /// model, an explicit frontend, and admission control. All engines
    /// must share input dimension and quality-level count.
    pub fn spawn_opts(
        engines: Vec<Arc<Engine>>,
        port: u16,
        policy: BatchPolicy,
        opts: FrontendOptions,
    ) -> Result<Server> {
        let listener =
            TcpListener::bind(("127.0.0.1", port)).context("binding server socket")?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        anyhow::ensure!(!engines.is_empty(), "server needs at least one engine shard");
        let mut stats = ServerStats::new(engines[0].num_levels());
        stats.tracer.set_sample_every(opts.trace_sample);
        if opts.audit.sample_every > 0 {
            stats.audit =
                Arc::new(QualityAudit::new(opts.audit.clone(), stats.registry.clone()));
        }
        let stats = Arc::new(stats);
        let workers = policy.resolved_workers();
        let route = opts
            .route
            .unwrap_or_else(|| Box::<crate::fleet::RoundRobin>::default());
        let shards = ShardSet::new(
            engines,
            route,
            opts.wear,
            stats.clone(),
            opts.max_queue,
            opts.slo,
            workers,
        )?;

        // Batch workers: `workers` per shard, each owning a backend handle
        // from its shard engine's pool and a private RNG; workers contend
        // only on their shard's job queue (collection) — execution is
        // lock-free and concurrent. The RNG seed depends on the *local*
        // worker index only, so a single-shard server is bit-identical to
        // the pre-shard code at any fixed seed.
        let mut batch_handles = Vec::with_capacity(shards.len() * workers);
        for shard_idx in 0..shards.len() {
            for worker in 0..workers {
                let shutdown = shutdown.clone();
                let stats = stats.clone();
                let shards = shards.clone();
                let rng = Xoshiro256pp::seeded(
                    (0x5E47E ^ 0x1234)
                        ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                );
                batch_handles.push(std::thread::spawn(move || {
                    batch_worker(shards, shard_idx, worker, policy, shutdown, stats, rng)
                }));
            }
        }

        let accept_handle = match opts.mode {
            // Threaded frontend: one handler thread per connection,
            // bounded by `max_conns`. Handlers are detached — they exit
            // when their client disconnects or the process ends; joining
            // them here would deadlock shutdown against clients that keep
            // their sockets open.
            FrontendMode::Threaded => {
                let shutdown = shutdown.clone();
                let stats = stats.clone();
                let shards = shards.clone();
                let max_conns = opts.max_conns.max(1);
                std::thread::spawn(move || {
                    let active = Arc::new(AtomicUsize::new(0));
                    while !shutdown.load(Ordering::Relaxed) {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                if active.load(Ordering::Relaxed) >= max_conns {
                                    stats.conn_rejected.fetch_add(1, Ordering::Relaxed);
                                    reject_overloaded(stream, max_conns);
                                    continue;
                                }
                                active.fetch_add(1, Ordering::SeqCst);
                                let guard = HandlerGuard(active.clone());
                                let shards = shards.clone();
                                let stats = stats.clone();
                                let shutdown = shutdown.clone();
                                std::thread::spawn(move || {
                                    let _guard = guard;
                                    let _ = handle_connection(
                                        stream, shards, stats, shutdown,
                                    );
                                });
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(2));
                            }
                            Err(_) => break,
                        }
                    }
                })
            }
            // Evented frontend: the reactor owns the listener and every
            // connection; batch workers hand results back through the
            // completion queue (which wakes the reactor).
            FrontendMode::Evented => {
                let completions = reactor::new_completion_queue()?;
                let shutdown = shutdown.clone();
                let stats = stats.clone();
                let shards = shards.clone();
                let cfg = reactor::ReactorConfig {
                    max_conns: opts.max_conns.max(1),
                    ..Default::default()
                };
                std::thread::spawn(move || {
                    reactor::run(listener, shards, completions, stats, shutdown, cfg)
                })
            }
        };
        Ok(Server {
            addr,
            stats,
            shards,
            shutdown,
            accept_handle: Some(accept_handle),
            batch_handles,
        })
    }

    pub fn shutdown(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        for h in self.batch_handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Collect one batch under the queue lock: block briefly for the first
/// job, then drain up to `max_batch` or until the deadline. The lock is
/// released before execution starts. A poisoned lock (a peer worker
/// panicked while holding it) is recovered, not propagated — the queue's
/// `Receiver` state is valid regardless of where the panicker died, so
/// cascading the poison would turn one bad batch into a dead pool.
fn collect_batch(rx: &Mutex<Receiver<Job>>, policy: &BatchPolicy) -> Vec<Job> {
    let rx = rx.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    let first = match rx.recv_timeout(Duration::from_millis(20)) {
        Ok(j) => j,
        Err(_) => return Vec::new(),
    };
    let mut jobs = vec![first];
    let deadline = std::time::Instant::now() + policy.max_wait;
    while jobs.len() < policy.max_batch {
        let now = std::time::Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(j) => jobs.push(j),
            Err(_) => break,
        }
    }
    jobs
}

/// One batch worker: collect → execute on this worker's own backend and
/// RNG → reply. No shared mutable state during execution, so workers run
/// batches (and thus different quality levels) concurrently.
///
/// Each collected batch pins the active [`PlanSet`] **once**: clamping,
/// execution and the generation tag on every reply all come from that one
/// snapshot, so a hot swap mid-batch can neither shear a request across
/// generations nor drop it. A panic inside execution (a backend bug, a
/// poisoned artifact) is caught per level-group: the affected requests'
/// reply channels drop (their handlers answer the client with an error
/// line), the panic is counted in [`ServerStats::worker_panics`], and the
/// worker keeps serving — it neither dies nor poisons the shared queue
/// lock for its peers.
fn batch_worker(
    shards: Arc<ShardSet>,
    shard_idx: usize,
    worker: usize,
    policy: BatchPolicy,
    shutdown: Arc<AtomicBool>,
    stats: Arc<ServerStats>,
    mut rng: Xoshiro256pp,
) {
    let shard = shards.shards()[shard_idx].clone();
    let engine = shard.engine.clone();
    // Steady-state reuse: this worker's backend handle, batch tensor,
    // forward arena and logits buffer live for the thread's lifetime —
    // once warm, assembling and executing a batch allocates nothing.
    let backend = engine.worker_backend(worker);
    let mut x = Tensor::zeros(&[0, engine.input_dim]);
    let mut arena = ForwardArena::default();
    let mut logits_buf: Vec<f32> = Vec::new();
    while !shutdown.load(Ordering::Relaxed) {
        let mut jobs = collect_batch(&shard.rx, &policy);
        if jobs.is_empty() {
            continue;
        }
        // The collected jobs left the queue — shrink the admission gate's
        // depth view before the (possibly long) execution.
        shards.note_collected(shard_idx, jobs.len() as u64);
        for j in jobs.iter_mut() {
            if let Some(t) = j.trace.as_mut() {
                t.mark_collected();
            }
        }
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.requests.fetch_add(jobs.len() as u64, Ordering::Relaxed);
        let inflight = stats.inflight_batches.fetch_add(1, Ordering::SeqCst) + 1;
        stats.peak_concurrent_batches.fetch_max(inflight, Ordering::SeqCst);
        // One snapshot for the whole batch — the hot-swap invariant.
        let set = engine.plan_set();
        // Group by quality level (each level has its own noise spec).
        let mut by_level: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
        for (i, j) in jobs.iter().enumerate() {
            by_level.entry(set.clamp(j.quality)).or_default().push(i);
        }
        for (level, idxs) in by_level {
            // Batch assembly is inside the catch too: a malformed request
            // (wrong pixel count) panics `copy_from_slice`, and that must
            // cost one error reply, not a worker thread.
            for &i in &idxs {
                if let Some(t) = jobs[i].trace.as_mut() {
                    t.mark_exec(level, set.generation);
                }
            }
            let started = Instant::now();
            let executed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                // Reuse the worker's batch tensor: every row is fully
                // overwritten, so clearing is just a resize.
                x.shape[0] = idxs.len();
                x.data.resize(idxs.len() * engine.input_dim, 0.0);
                for (r, &i) in idxs.iter().enumerate() {
                    x.row_mut(r).copy_from_slice(&jobs[i].pixels);
                }
                engine.execute_packed(
                    &set,
                    backend.as_ref(),
                    &x,
                    level,
                    &mut rng,
                    &mut arena,
                    &mut logits_buf,
                );
            }));
            match executed {
                Ok(()) => {}
                Err(_) => {
                    // Dropping the replies below (jobs go out of scope
                    // un-answered at the end of the batch — for evented
                    // requests the sink's `Drop` pushes an error
                    // completion) surfaces the failure to each affected
                    // client as an error line. The failed requests are
                    // still attributed to this generation so
                    // per_generation conserves `requests` (which counted
                    // them at collection); per_level only counts *served*
                    // requests, so it is skipped.
                    stats.worker_panics.fetch_add(1, Ordering::Relaxed);
                    stats.record_generation(set.generation, idxs.len() as u64);
                    continue;
                }
            };
            let exec = started.elapsed();
            // Feed the admission gate's estimators and this shard's wear
            // ledger with the measured execution cost.
            stats.observe_service(
                ((exec.as_nanos() / idxs.len().max(1) as u128).min(u64::MAX as u128)
                    as u64)
                    .max(1),
            );
            shard.record_service(level, exec.as_secs_f64());
            stats.record_level(level, idxs.len() as u64);
            stats.record_generation(set.generation, idxs.len() as u64);
            let replied = Instant::now();
            let out_dim = logits_buf.len() / idxs.len().max(1);
            for (r, &i) in idxs.iter().enumerate() {
                if let Some(t) = jobs[i].trace.as_mut() {
                    t.mark_exec_end();
                }
                jobs[i].reply.send_ok(
                    level,
                    set.generation,
                    logits_buf[r * out_dim..(r + 1) * out_dim].to_vec(),
                );
                if let Some(t) = jobs[i].trace.as_mut() {
                    t.mark_reply();
                }
                let waited = replied.duration_since(jobs[i].enqueued);
                stats
                    .latency
                    .record_us(waited.as_micros().min(u64::MAX as u128) as u64);
                if jobs[i].deadline.is_some_and(|d| replied > d) {
                    stats.deadline_missed.fetch_add(1, Ordering::Relaxed);
                }
            }
            // Online quality audit: shadow-execute this level group
            // error-free on the exact backend and compare. Runs *after*
            // the replies went out (audit cost never inflates client
            // latency) and draws nothing from the worker RNG (clean
            // forwards consume no stream), so served outputs stay
            // bit-identical whether or not the group was sampled.
            if stats.audit.should_sample() {
                let lvl = &set.levels[level];
                // The batch tensor is still assembled from execution above
                // — the shadow run reuses it instead of rebuilding.
                let shadow = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    engine.execute_exact(&x, &mut rng)
                }));
                if let Ok(exact) = shadow {
                    stats.audit.observe(
                        level,
                        &lvl.name,
                        set.generation,
                        lvl.predicted_mse,
                        &logits_buf,
                        &exact.data,
                        idxs.len(),
                    );
                }
            }
        }
        stats.inflight_batches.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Decrements the threaded frontend's active-handler count when a handler
/// thread exits, however it exits.
struct HandlerGuard(Arc<AtomicUsize>);

impl Drop for HandlerGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Best-effort typed rejection for a connection past the threaded
/// frontend's cap; blocking with a short timeout is fine because we close
/// immediately after.
fn reject_overloaded(mut stream: TcpStream, cap: usize) {
    let line = Json::obj(vec![
        ("error", Json::Str("overloaded".into())),
        ("max_conns", Json::Num(cap as f64)),
    ]);
    let _ = stream.set_write_timeout(Some(Duration::from_millis(50)));
    let _ = stream.write_all(line.to_string().as_bytes());
    let _ = stream.write_all(b"\n");
}

fn handle_connection(
    stream: TcpStream,
    shards: Arc<ShardSet>,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Read timeout so idle handlers notice shutdown instead of blocking
    // forever on a silent client.
    stream.set_read_timeout(Some(Duration::from_millis(250))).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        if shutdown.load(Ordering::Relaxed) {
            return Ok(());
        }
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        let req = Json::parse(&line)?;
        // `{"stats": true}` — operator introspection, answered inline
        // without touching the job queue. Strictly `true`: any other value
        // (or a stray key on an inference request) falls through.
        if matches!(req.opt("stats").map(|v| v.as_bool()), Some(Ok(true))) {
            let resp = Json::obj(vec![("stats", stats.to_json())]);
            writer.write_all(resp.to_string().as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            continue;
        }
        // `{"metrics": true}` — the unified registry exposition (server
        // series + the process-global registry), same snapshot the
        // `--metrics-file` exporter writes.
        if matches!(req.opt("metrics").map(|v| v.as_bool()), Some(Ok(true))) {
            let resp = Json::obj(vec![("metrics", stats.metrics_json())]);
            writer.write_all(resp.to_string().as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            continue;
        }
        // `{"trace": N}` — dump the most recent ≤N sampled request spans
        // as a chrome-trace JSON document (load it in a trace viewer).
        if let Some(n) = req.opt("trace").and_then(|v| v.as_usize().ok()) {
            let resp = Json::obj(vec![("trace", stats.tracer.dump(n))]);
            writer.write_all(resp.to_string().as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            continue;
        }
        let pixels: Vec<f32> = req
            .get("pixels")?
            .as_f64_vec()?
            .iter()
            .map(|&v| v as f32)
            .collect();
        let quality = req.opt("quality").map(|v| v.as_usize()).transpose()?.unwrap_or(0);
        let deadline_ms = req.opt("deadline_ms").and_then(|v| v.as_f64().ok());
        let (reply_tx, reply_rx) = channel();
        let trace = stats.tracer.maybe_start();
        match shards.submit(pixels, quality, deadline_ms, Reply::Channel(reply_tx), trace) {
            Ok(()) => {}
            Err(shard::Shed::Stopped) => anyhow::bail!("engine stopped"),
            Err(shed) => {
                // Admission refused: answer with the typed shed line and
                // keep the connection — the client may retry or back off.
                writer.write_all(shed.to_json().to_string().as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
                continue;
            }
        }
        let (level, generation, logits) = match reply_rx.recv_timeout(Duration::from_secs(30))
        {
            Ok(reply) => reply,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                // The batch worker dropped our sender without replying —
                // either it caught a panic executing this batch, or the
                // server is shutting down with this request still queued.
                // Tell the client instead of letting it time out, and
                // keep the connection alive.
                let resp = Json::obj(vec![(
                    "error",
                    Json::Str(
                        "inference failed (worker recovered from a panic, or server \
                         shutting down)"
                            .into(),
                    ),
                )]);
                writer.write_all(resp.to_string().as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
                continue;
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                anyhow::bail!("inference timed out")
            }
        };
        // NaN-safe argmax: a NaN logit (however it got there) must neither
        // panic the handler thread nor win the classification.
        let class = crate::util::stats::argmax_f32(&logits);
        let resp = Json::obj(vec![
            ("class", Json::Num(class as f64)),
            (
                "logits",
                Json::arr_f64(&logits.iter().map(|&v| v as f64).collect::<Vec<_>>()),
            ),
            ("quality", Json::Num(level as f64)),
            ("generation", Json::Num(generation as f64)),
        ]);
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}

/// Simple blocking client for tests/examples.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Self { stream })
    }

    pub fn infer(&mut self, pixels: &[f32], quality: usize) -> Result<(usize, Vec<f32>)> {
        let (class, logits, _) = self.infer_full(pixels, quality)?;
        Ok((class, logits))
    }

    /// Like [`Self::infer`] but also returns the quality level the server
    /// actually applied (out-of-range requests clamp to the last level).
    pub fn infer_full(
        &mut self,
        pixels: &[f32],
        quality: usize,
    ) -> Result<(usize, Vec<f32>, usize)> {
        let (class, logits, applied, _) = self.infer_tagged(pixels, quality)?;
        Ok((class, logits, applied))
    }

    /// Like [`Self::infer_full`] but also returns the plan-set generation
    /// that served the request — the observable a hot-swap test (or an
    /// auditing operator) keys on. Pre-swap servers report generation 0.
    pub fn infer_tagged(
        &mut self,
        pixels: &[f32],
        quality: usize,
    ) -> Result<(usize, Vec<f32>, usize, u64)> {
        let req = Json::obj(vec![
            (
                "pixels",
                Json::arr_f64(&pixels.iter().map(|&v| v as f64).collect::<Vec<_>>()),
            ),
            ("quality", Json::Num(quality as f64)),
        ]);
        self.stream.write_all(req.to_string().as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let resp = Json::parse(&line)?;
        if let Some(err) = resp.opt("error") {
            anyhow::bail!("server error: {}", err.as_str().unwrap_or("unknown"));
        }
        let class = resp.get("class")?.as_usize()?;
        let logits: Vec<f32> =
            resp.get("logits")?.as_f64_vec()?.iter().map(|&v| v as f32).collect();
        let applied = resp.get("quality")?.as_usize()?;
        let generation = resp.get("generation")?.as_u64()?;
        Ok((class, logits, applied, generation))
    }

    /// Send one raw request line (no trailing newline) and parse the
    /// single reply line — the escape hatch for protocol-level tests and
    /// deadline-tagged (`"deadline_ms"`) requests.
    pub fn request_line(&mut self, line: &str) -> Result<Json> {
        self.stream.write_all(line.as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut reply = String::new();
        reader.read_line(&mut reply)?;
        Json::parse(&reply)
    }

    /// Fetch the server's stats snapshot (`{"stats": true}` request).
    pub fn stats(&mut self) -> Result<Json> {
        self.stream.write_all(b"{\"stats\": true}\n")?;
        self.stream.flush()?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        Ok(Json::parse(&line)?.get("stats")?.clone())
    }

    /// Fetch the unified metrics exposition (`{"metrics": true}` request):
    /// `{"server": {...}, "process": {...}}` flat series maps.
    pub fn metrics(&mut self) -> Result<Json> {
        self.stream.write_all(b"{\"metrics\": true}\n")?;
        self.stream.flush()?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        Ok(Json::parse(&line)?.get("metrics")?.clone())
    }

    /// Fetch the most recent ≤`max` sampled request spans as a
    /// chrome-trace JSON document (`{"trace": N}` request).
    pub fn trace(&mut self, max: usize) -> Result<Json> {
        let req = Json::obj(vec![("trace", Json::Num(max as f64))]);
        self.stream.write_all(req.to_string().as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        Ok(Json::parse(&line)?.get("trace")?.clone())
    }
}

/// Shared fixtures for the server-side unit tests (`server::tests`,
/// `server::shard::tests`) — a small trained engine and matching voltage
/// plans, kept here so sibling modules don't each re-train a model.
#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::nn::data::synth_mnist;
    use crate::nn::layers::Activation;
    use crate::nn::model::fc_mnist;
    use crate::nn::train::{train, TrainConfig};

    pub(crate) fn test_engine() -> (Engine, crate::nn::data::Dataset) {
        let mut rng = Xoshiro256pp::seeded(1);
        let mut model = fc_mnist(Activation::Relu, &mut rng);
        let train_set = synth_mnist(400, 5);
        train(&mut model, &train_set, &TrainConfig { epochs: 2, ..Default::default() });
        let test = synth_mnist(50, 6);
        let calib = test.batch(&(0..32).collect::<Vec<_>>()).0;
        let q = QuantizedModel::quantize(&model, &calib);
        let n = q.num_neurons();
        let mut noisy = NoiseSpec::silent(n);
        for s in noisy.std.iter_mut().take(128) {
            *s = 2000.0;
        }
        let levels = vec![
            QualityLevel {
                name: "exact".into(),
                noise: NoiseSpec::silent(n),
                energy_saving: 0.0,
                energy: 10.0,
                predicted_mse: 0.0,
            },
            QualityLevel {
                name: "eco".into(),
                noise: noisy,
                energy_saving: 0.3,
                energy: 7.0,
                predicted_mse: 0.0,
            },
        ];
        (Engine::new(q, levels, 784).unwrap(), test)
    }

    /// Voltage plans mirroring the test engine's two levels: level 0 an
    /// all-nominal "exact" plan, level 1 an aggressive-VOS "eco" plan —
    /// just enough provenance (volts + per-neuron level + fan-in) for the
    /// wear accounting in [`shard::WearConfig`].
    pub(crate) fn test_plans(engine: &Engine) -> Vec<VoltagePlan> {
        use crate::config::ExperimentConfig;
        use crate::timing::voltage::VoltageLadder;
        let q = &engine.quantized;
        let n = q.num_neurons();
        let cfg = ExperimentConfig::smoke();
        let volts: Vec<f64> =
            VoltageLadder::paper_default().levels().iter().map(|l| l.volts).collect();
        let top = volts.len() - 1;
        let mk = |name: &str, level: Vec<usize>, saving: f64| VoltagePlan {
            name: name.into(),
            mse_ub_fraction: 1.0,
            budget_abs: 0.1,
            baseline_mse: 0.1,
            fan_in: q.neuron_fan_in.clone(),
            es: vec![1.0; n],
            volts: volts.clone(),
            predicted_mse: 0.0,
            energy: 1.0,
            energy_saving: saving,
            optimal: true,
            solver: "ilp".into(),
            model_fingerprint: "fp".into(),
            config_hash: crate::plan::config_hash(&cfg),
            config: cfg.clone(),
            generation: 0,
            drift_delta_vth: 0.0,
            mode: "statistical".into(),
            level,
        };
        vec![mk("exact", vec![top; n], 0.0), mk("eco", vec![0; n], 0.35)]
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::test_engine;
    use super::*;

    #[test]
    fn energy_estimates_follow_levels() {
        let (engine, _) = test_engine();
        assert_eq!(engine.energy_estimate(0), 10.0);
        assert_eq!(engine.energy_estimate(1), 7.0);
        // Out-of-range requests clamp, like the serving path does.
        assert_eq!(engine.energy_estimate(99), 7.0);
        // Nominal reference reconstructed from any level's saving: the
        // exact level has saving 0, so nominal == its own energy.
        crate::util::checks::assert_close(engine.nominal_energy_estimate(), 10.0, 1e-12);
    }

    #[test]
    fn empty_quality_levels_rejected() {
        let (engine, _) = test_engine();
        let err = Engine::new(engine.quantized.clone(), Vec::new(), 784).unwrap_err();
        assert!(err.to_string().contains("quality level"), "{err}");
    }

    #[test]
    fn serve_roundtrip_and_quality_levels() {
        let (engine, test) = test_engine();
        let mut server = Server::spawn(engine, 0, BatchPolicy::default()).unwrap();
        let mut client = Client::connect(server.addr).unwrap();
        let mut correct = 0;
        let n = 20;
        for i in 0..n {
            let (class, logits) = client.infer(test.images.row(i), 0).unwrap();
            assert_eq!(logits.len(), 10);
            if class == test.labels[i] as usize {
                correct += 1;
            }
        }
        assert!(correct > n / 2, "server accuracy too low: {correct}/{n}");
        // Quality level 1 exists and responds.
        let (_, logits) = client.infer(test.images.row(0), 1).unwrap();
        assert_eq!(logits.len(), 10);
        // Out-of-range quality clamps rather than erroring, and the reply
        // reports the level actually applied.
        let (_, logits, applied) = client.infer_full(test.images.row(0), 99).unwrap();
        assert_eq!(logits.len(), 10);
        assert_eq!(applied, 1);
        assert!(server.stats.requests.load(Ordering::Relaxed) >= n as u64 + 2);
        // Per-level counters: n requests at level 0; level 1 saw the
        // explicit + the clamped request.
        let counts = server.stats.per_level_counts();
        assert_eq!(counts.len(), 2);
        assert_eq!(counts[0], n as u64);
        assert_eq!(counts[1], 2);
        // And the same numbers are visible to clients via the stats request.
        let j = client.stats().unwrap();
        assert_eq!(j.get("requests").unwrap().as_u64().unwrap(), n as u64 + 2);
        let per_level = j.get("per_level").unwrap().as_arr().unwrap();
        assert_eq!(per_level.len(), 2);
        assert_eq!(per_level[0].as_u64().unwrap(), n as u64);
        assert_eq!(per_level[1].as_u64().unwrap(), 2);
        server.shutdown();
    }

    #[test]
    fn engine_serves_through_installed_backend() {
        use crate::errormodel::ErrorModelRegistry;
        use crate::timing::voltage::VoltageLadder;
        let (engine, test) = test_engine();
        // Install the statistical backend (fitted-variance fake registry):
        // requests must still round-trip at every quality level.
        let reg = ErrorModelRegistry::synthetic(
            &VoltageLadder::paper_default(),
            &[3.0e4, 1.0e4, 2.0e3, 0.0],
        );
        let levels = engine.plan_set().levels.clone();
        let engine = Engine::new(engine.quantized.clone(), levels, 784)
            .unwrap()
            .with_backend(Box::new(crate::exec::Statistical::new(reg)));
        let mut server = Server::spawn(engine, 0, BatchPolicy::default()).unwrap();
        let mut client = Client::connect(server.addr).unwrap();
        for quality in [0, 1] {
            let (_, logits) = client.infer(test.images.row(0), quality).unwrap();
            assert_eq!(logits.len(), 10);
        }
        server.shutdown();
    }

    #[test]
    fn engine_from_plans_derives_levels() {
        use crate::config::ExperimentConfig;
        use crate::errormodel::ErrorModelRegistry;
        use crate::timing::voltage::VoltageLadder;
        let (engine, _) = test_engine();
        let q = engine.quantized.clone();
        let reg = ErrorModelRegistry::synthetic(
            &VoltageLadder::paper_default(),
            &[3.0e4, 1.0e4, 2.0e3, 0.0],
        );
        let n = q.num_neurons();
        let cfg = ExperimentConfig::smoke();
        let mk = |name: &str, level: Vec<usize>, saving: f64| VoltagePlan {
            name: name.into(),
            mse_ub_fraction: 1.0,
            budget_abs: 0.1,
            baseline_mse: 0.1,
            fan_in: q.neuron_fan_in.clone(),
            es: vec![1.0; n],
            volts: reg.ladder.levels().iter().map(|l| l.volts).collect(),
            predicted_mse: 0.0,
            energy: 1.0,
            energy_saving: saving,
            optimal: true,
            solver: "ilp".into(),
            model_fingerprint: "fp".into(),
            config_hash: crate::plan::config_hash(&cfg),
            config: cfg.clone(),
            generation: 0,
            drift_delta_vth: 0.0,
            mode: "statistical".into(),
            level,
        };
        let nominal = mk("exact", vec![3; n], 0.0);
        let eco = mk("eco", vec![0; n], 0.35);
        let e = Engine::from_plans(q.clone(), &reg, &[nominal.clone(), eco.clone()], 784)
            .unwrap();
        let set = e.plan_set();
        assert_eq!(set.levels.len(), 2);
        assert_eq!(set.generation, 0);
        assert!(set.levels[0].noise.is_silent(), "nominal plan → silent spec");
        assert!(!set.levels[1].noise.is_silent());
        assert_eq!(set.levels[1].energy_saving, 0.35);
        // Expected composition: std = sqrt(k · var(0.5V)).
        for (u, &k) in q.neuron_fan_in.iter().enumerate() {
            crate::util::checks::assert_close(
                set.levels[1].noise.std[u],
                (k as f64 * 3.0e4).sqrt(),
                1e-12,
            );
        }
        // Guards: empty list, wrong neuron count, mismatched provenance.
        assert!(Engine::from_plans(q.clone(), &reg, &[], 784).is_err());
        let mut short = eco.clone();
        short.level.pop();
        assert!(Engine::from_plans(q.clone(), &reg, &[short], 784).is_err());
        let mut other = eco.clone();
        other.model_fingerprint = "other".into();
        assert!(Engine::from_plans(q.clone(), &reg, &[nominal.clone(), other], 784).is_err());
        // Operating-regime guards surface the typed ModeMismatch error: a
        // tedrop-mode plan whose config builds a non-tedrop backend pool,
        // and a set mixing the two regimes.
        let mut wrong_pool = eco.clone();
        wrong_pool.mode = "tedrop".into();
        let err = Engine::from_plans(q.clone(), &reg, &[wrong_pool], 784).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ModeMismatch>(),
            Some(ModeMismatch::Backend { .. })
        ));
        let mut te = eco.clone();
        te.mode = "tedrop".into();
        te.config.backend = "tedrop".into();
        let err = Engine::from_plans(q, &reg, &[nominal, te], 784).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ModeMismatch>(),
            Some(ModeMismatch::CrossPlan { .. })
        ));
    }

    #[test]
    fn hot_swap_is_atomic_and_generation_tagged() {
        let (engine, test) = test_engine();
        let engine = Arc::new(engine);
        let set0 = engine.plan_set();
        assert_eq!((set0.generation, engine.generation()), (0, 0));
        let mut server = Server::spawn_shared(engine.clone(), 0, BatchPolicy::default()).unwrap();
        let mut client = Client::connect(server.addr).unwrap();
        let (_, _, applied, gen) = client.infer_tagged(test.images.row(0), 1).unwrap();
        assert_eq!((applied, gen), (1, 0), "pre-swap requests serve generation 0");

        // Swap in a new set (same shape, renamed levels) mid-serve.
        let mut renamed = engine.plan_set().levels.clone();
        renamed[0].name = "exact_v2".into();
        let g1 = engine.swap_levels(renamed).unwrap();
        assert_eq!((g1, engine.generation()), (1, 1));
        let set1 = engine.plan_set();
        assert_eq!(set1.generation, 1);
        assert_eq!(set1.levels[0].name, "exact_v2");
        // The old snapshot is untouched — in-flight work on it is safe.
        assert_eq!(set0.generation, 0);
        // Post-swap requests are served (and tagged) by the new set.
        let (_, _, _, gen) = client.infer_tagged(test.images.row(1), 0).unwrap();
        assert_eq!(gen, 1);
        // Both generations appear in the audit counters.
        let j = client.stats().unwrap();
        let per_gen = j.get("per_generation").unwrap().as_obj().unwrap();
        assert_eq!(per_gen.get("0").unwrap().as_u64().unwrap(), 1);
        assert_eq!(per_gen.get("1").unwrap().as_u64().unwrap(), 1);
        // Empty sets are refused; the active set stays serviceable.
        assert!(engine.swap_levels(Vec::new()).is_err());
        assert_eq!(engine.generation(), 1);
        // A swap may GROW the level set; the per-level counters follow.
        let mut wider = engine.plan_set().levels.clone();
        let mut extra = wider[1].clone();
        extra.name = "ultra_eco".into();
        wider.push(extra);
        assert_eq!(engine.swap_levels(wider).unwrap(), 2);
        let (_, _, applied, gen) = client.infer_tagged(test.images.row(2), 2).unwrap();
        assert_eq!((applied, gen), (2, 2));
        let counts = server.stats.per_level_counts();
        assert_eq!(counts.len(), 3, "per-level counters must grow with the swap");
        assert_eq!(counts[2], 1);
        // Executing on a pinned old snapshot still works after the swap.
        let mut rng = Xoshiro256pp::seeded(5);
        let x = {
            let mut t = Tensor::zeros(&[1, 784]);
            t.row_mut(0).copy_from_slice(test.images.row(0));
            t
        };
        let y_old = engine.execute_on(&set0, 0, &x, 0, &mut rng);
        assert_eq!(y_old.shape, vec![1, 10]);
        server.shutdown();
    }

    #[test]
    fn worker_panic_is_recovered_not_cascaded() {
        // A single batch worker and a request whose pixel vector has the
        // wrong length: batch assembly panics. With the old
        // `rx.lock().unwrap()` worker loop the panic killed the worker
        // (and a panic under the collection lock poisoned it for every
        // peer) — the pool went dead and clients hung. Now the worker must
        // catch the panic, answer the bad request with an error line,
        // count it, and keep serving the same connection.
        let (engine, test) = test_engine();
        let mut server = Server::spawn(
            engine,
            0,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2), workers: 1 },
        )
        .unwrap();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        for round in 0..3 {
            writer
                .write_all(b"{\"pixels\": [0.5, 0.25, 0.125], \"quality\": 0}\n")
                .unwrap();
            writer.flush().unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = Json::parse(&line).unwrap();
            assert!(resp.opt("error").is_some(), "round {round}: want an error reply, got {line}");
        }
        assert_eq!(server.stats.worker_panics.load(Ordering::Relaxed), 3);
        // The same (sole) worker still serves well-formed requests — it
        // neither died nor poisoned the queue lock.
        let mut client = Client::connect(server.addr).unwrap();
        let (_, logits) = client.infer(test.images.row(0), 0).unwrap();
        assert_eq!(logits.len(), 10);
        // And the typed client surfaces the error as Err, not a hang.
        let err = client.infer(&[1.0, 2.0], 0).unwrap_err();
        assert!(err.to_string().contains("server error"), "{err}");
        // Audit conservation holds even across panics: every collected
        // request (served or failed) is attributed to a generation.
        let total = server.stats.requests.load(Ordering::Relaxed);
        let attributed: u64 = server
            .stats
            .per_generation
            .lock()
            .unwrap()
            .values()
            .sum();
        assert_eq!(attributed, total, "per-generation counters must conserve requests");
        // …while per-level only counts the successfully served one.
        assert_eq!(server.stats.per_level_counts().iter().sum::<u64>(), 1);
        server.shutdown();
    }

    #[test]
    fn concurrent_clients_batch() {
        let (engine, test) = test_engine();
        let mut server = Server::spawn(
            engine,
            0,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20), workers: 1 },
        )
        .unwrap();
        let addr = server.addr;
        let pixels: Vec<Vec<f32>> = (0..8).map(|i| test.images.row(i).to_vec()).collect();
        let handles: Vec<_> = pixels
            .into_iter()
            .map(|p| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    c.infer(&p, 0).unwrap().0
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let reqs = server.stats.requests.load(Ordering::Relaxed);
        let batches = server.stats.batches.load(Ordering::Relaxed);
        assert_eq!(reqs, 8);
        assert!(batches <= 8, "batching should coalesce ({batches} batches for 8 reqs)");
        server.shutdown();
    }
}
