//! Live shard routing: one logical model spread across several [`Engine`]s.
//!
//! The fleet layer (PR 4/5) *simulates* many devices; this module is the
//! serving-side counterpart — a [`ShardSet`] owns N real engines (each with
//! its own batch-worker queue and backend pool) and routes every incoming
//! request through a [`RoutePolicy`] snapshot, so round-robin,
//! least-loaded and **wear-leveling** govern real placement instead of a
//! virtual-time trace. Policies see [`NodeSnapshot`]s built from live
//! queue depths (backlog ≈ queued × EWMA service time / workers) and, when
//! a [`WearConfig`] is installed, from each shard's real accrued BTI
//! stress ledger — batch workers charge every executed batch to their
//! shard's [`StressAccount`] at the voltage mix of the level they served,
//! exactly the share-weighted accounting the fleet simulator uses.
//!
//! The set is also the admission-control seam shared by both frontends
//! ([`submit`](ShardSet::submit)): a queue-depth gate and an
//! SLO/deadline gate (estimated wait = EWMA service time × queue depth per
//! worker) shed over-capacity requests with a typed
//! `{"error":"shed",...}` reply *before* they consume a queue slot — a
//! saturated server answers cheaply instead of timing out expensively.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::{Engine, Job, Reply, ServerStats};
use crate::aging::{BtiModel, StressAccount, SECONDS_PER_YEAR};
use crate::fleet::{plan_level_shares, plan_stress_intensity, NodeSnapshot, RoutePolicy};
use crate::plan::VoltagePlan;
use crate::timing::voltage::Technology;
use crate::util::json::Json;

/// Wear-aware shard routing configuration: the deployed plans (one per
/// quality level — their voltage mixes determine how fast each level ages
/// a shard) plus the BTI model, so shards keep real stress ledgers and the
/// wear-leveling policy has headroom to rank on. `initial_age_years`
/// (cycled across shards) models a heterogeneous deployment — e.g. one
/// worn canary among fresh replacements.
#[derive(Clone)]
pub struct WearConfig {
    pub plans: Vec<VoltagePlan>,
    pub bti: BtiModel,
    pub tech: Technology,
    /// Deployed stress-seconds accrued per wall-clock busy second (same
    /// knob as the fleet simulator's `wear_accel` — lets a short stress
    /// run stand in for months of deployment).
    pub wear_accel: f64,
    /// Prior service years per shard (cycled; empty = all fresh).
    pub initial_age_years: Vec<f64>,
    /// Activity duty factor of that prior service.
    pub initial_age_duty: f64,
}

impl WearConfig {
    /// Wear config for the given plans with default silicon models, no
    /// pre-aging and a 1e6× wear clock (the fleet default).
    pub fn new(plans: Vec<VoltagePlan>) -> Self {
        Self {
            plans,
            bti: BtiModel::default(),
            tech: Technology::default(),
            wear_accel: 1.0e6,
            initial_age_years: Vec::new(),
            initial_age_duty: 0.3,
        }
    }
}

/// One shard's wear ledger + the per-level stress coefficients needed to
/// charge served batches to it (mirrors [`crate::fleet::Device::serve`]).
struct ShardWear {
    stress: StressAccount,
    /// Per-quality-level fan-in-weighted voltage shares.
    level_shares: Vec<Vec<f64>>,
    /// Per-quality-level aging intensity (x per deployed year of serving).
    class_x_rate: Vec<f64>,
    wear_accel: f64,
}

/// One shard: an engine, its private job queue, and (optionally) a live
/// wear ledger. Batch workers drain `rx`; frontends enqueue through the
/// owning [`ShardSet`] only, so admission control cannot be bypassed.
pub struct Shard {
    pub(crate) engine: Arc<Engine>,
    pub(crate) tx: Sender<Job>,
    pub(crate) rx: Arc<Mutex<Receiver<Job>>>,
    /// Jobs currently queued on this shard (enqueued − collected).
    pub(crate) queued: AtomicU64,
    wear: Option<Mutex<ShardWear>>,
}

impl Shard {
    /// Remaining stress headroom (1.0 when no wear ledger is installed).
    pub fn headroom_x(&self) -> f64 {
        match &self.wear {
            Some(w) => {
                w.lock().unwrap_or_else(|e| e.into_inner()).stress.headroom_x()
            }
            None => 1.0,
        }
    }

    /// Accrued ΔVth (0.0 when no wear ledger is installed).
    pub fn delta_vth(&self) -> f64 {
        match &self.wear {
            Some(w) => w.lock().unwrap_or_else(|e| e.into_inner()).stress.delta_vth(),
            None => 0.0,
        }
    }

    /// Charge `busy_seconds` of execution at quality `level` to this
    /// shard's wear ledger — called by batch workers per executed
    /// level-group, with the measured wall-clock execution time.
    pub(crate) fn record_service(&self, level: usize, busy_seconds: f64) {
        let Some(wear) = &self.wear else { return };
        let mut guard = wear.lock().unwrap_or_else(|e| e.into_inner());
        let w = &mut *guard;
        let level = level.min(w.class_x_rate.len().saturating_sub(1));
        let stressed = busy_seconds * w.wear_accel;
        let dx = w.class_x_rate[level] * (stressed / SECONDS_PER_YEAR);
        w.stress.accrue_weighted(dx, &w.level_shares[level], stressed);
    }
}

/// Why a request was refused admission (the typed shed reply).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Shed {
    /// Queue-depth gate: `queued` jobs were already waiting against a cap
    /// of `max`.
    QueueFull { queued: u64, max: u64 },
    /// Deadline gate: the estimated queueing delay exceeds the request's
    /// remaining latency budget — serving it would only produce a
    /// guaranteed-late reply.
    Deadline { est_wait_us: u64, budget_us: u64 },
    /// The batch workers are gone (server shutting down). Not counted as
    /// shed — there is no capacity decision to audit.
    Stopped,
}

impl Shed {
    /// The one-line JSON reply a shed request receives instead of logits.
    pub fn to_json(&self) -> Json {
        match *self {
            Shed::QueueFull { queued, max } => Json::obj(vec![
                ("error", Json::Str("shed".into())),
                ("reason", Json::Str("queue_full".into())),
                ("queued", Json::Num(queued as f64)),
                ("max_queue", Json::Num(max as f64)),
            ]),
            Shed::Deadline { est_wait_us, budget_us } => Json::obj(vec![
                ("error", Json::Str("shed".into())),
                ("reason", Json::Str("deadline".into())),
                ("est_wait_us", Json::Num(est_wait_us as f64)),
                ("budget_us", Json::Num(budget_us as f64)),
            ]),
            Shed::Stopped => {
                Json::obj(vec![("error", Json::Str("server stopping".into()))])
            }
        }
    }
}

/// A set of shards serving one logical model behind one admission gate and
/// one routing policy. Both frontends (threaded and evented) submit every
/// inference request through [`Self::submit`].
/// The routing policy plus its reusable [`NodeSnapshot`] scratch buffer,
/// guarded together: snapshot assembly happens under the same lock the
/// policy consultation needs anyway, so routing a request allocates
/// nothing once the buffer is warm.
struct PolicyState {
    policy: Box<dyn RoutePolicy>,
    nodes: Vec<NodeSnapshot>,
}

pub struct ShardSet {
    shards: Vec<Arc<Shard>>,
    policy: Mutex<PolicyState>,
    /// Per-quality-level relative stress intensity (this level's aging
    /// rate / the harshest level's) — what the wear-leveling policy steers
    /// on. All-1.0 without a wear config (every class assumed harsh).
    class_rel_intensity: Vec<f64>,
    max_queue: u64,
    /// Default latency budget applied to requests without a deadline tag.
    slo: Option<Duration>,
    workers_per_shard: usize,
    stats: Arc<ServerStats>,
    /// Wall-clock origin for the policy's `now` argument.
    start: Instant,
}

impl ShardSet {
    pub(crate) fn new(
        engines: Vec<Arc<Engine>>,
        policy: Box<dyn RoutePolicy>,
        wear: Option<WearConfig>,
        stats: Arc<ServerStats>,
        max_queue: usize,
        slo: Option<Duration>,
        workers_per_shard: usize,
    ) -> Result<Arc<Self>> {
        anyhow::ensure!(!engines.is_empty(), "shard set needs at least one engine");
        let input_dim = engines[0].input_dim;
        let levels = engines[0].num_levels();
        for e in &engines {
            anyhow::ensure!(
                e.input_dim == input_dim && e.num_levels() == levels,
                "all shards must serve the same logical model \
                 (input dim {input_dim} × {levels} levels)"
            );
        }
        let class_rel_intensity = match &wear {
            Some(cfg) => {
                anyhow::ensure!(
                    cfg.plans.len() == levels,
                    "wear config deploys {} plans but the engines serve {levels} levels",
                    cfg.plans.len()
                );
                let raw: Vec<f64> = cfg
                    .plans
                    .iter()
                    .map(|p| plan_stress_intensity(&cfg.bti, &cfg.tech, p))
                    .collect();
                let max = raw.iter().cloned().fold(0.0, f64::max);
                raw.iter()
                    .map(|&x| if max > 0.0 { x / max } else { 0.0 })
                    .collect()
            }
            None => vec![1.0; levels],
        };
        let shards: Vec<Arc<Shard>> = engines
            .into_iter()
            .enumerate()
            .map(|(i, engine)| {
                let (tx, rx) = channel::<Job>();
                let shard_wear = wear.as_ref().map(|cfg| {
                    let mut stress =
                        StressAccount::new(cfg.bti, cfg.tech, &cfg.plans[0].volts);
                    if !cfg.initial_age_years.is_empty() {
                        let years =
                            cfg.initial_age_years[i % cfg.initial_age_years.len()];
                        stress.pre_age(cfg.tech.v_nominal, years, cfg.initial_age_duty);
                    }
                    Mutex::new(ShardWear {
                        stress,
                        level_shares: cfg.plans.iter().map(plan_level_shares).collect(),
                        class_x_rate: cfg
                            .plans
                            .iter()
                            .map(|p| plan_stress_intensity(&cfg.bti, &cfg.tech, p))
                            .collect(),
                        wear_accel: cfg.wear_accel,
                    })
                });
                Arc::new(Shard {
                    engine,
                    tx,
                    rx: Arc::new(Mutex::new(rx)),
                    queued: AtomicU64::new(0),
                    wear: shard_wear,
                })
            })
            .collect();
        stats.init_shards(shards.len());
        Ok(Arc::new(Self {
            shards,
            policy: Mutex::new(PolicyState { policy, nodes: Vec::new() }),
            class_rel_intensity,
            max_queue: max_queue as u64,
            slo,
            workers_per_shard: workers_per_shard.max(1),
            stats,
            start: Instant::now(),
        }))
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    pub fn shards(&self) -> &[Arc<Shard>] {
        &self.shards
    }

    /// Input dimension of the (shared) logical model — the frontends use
    /// this to reject malformed pixel vectors before they reach a worker.
    pub fn input_dim(&self) -> usize {
        self.shards[0].engine.input_dim
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.lock().unwrap_or_else(|e| e.into_inner()).policy.name()
    }

    /// Admission control + routing: shed over-capacity work with a typed
    /// reason, otherwise pick a shard via the routing policy and enqueue.
    /// `deadline_ms` is the request's own latency tag; untagged requests
    /// inherit the server SLO (when one is configured).
    ///
    /// On `Err` the caller answers the client with the typed shed line —
    /// every refused `reply` is [`defuse`](Reply::defuse)d here first, so
    /// its drop-side error delivery never produces a second reply line.
    pub(crate) fn submit(
        &self,
        pixels: Vec<f32>,
        quality: usize,
        deadline_ms: Option<f64>,
        mut reply: Reply,
        mut trace: Option<Box<crate::obs::trace::ActiveSpan>>,
    ) -> Result<(), Shed> {
        let queued = self.stats.queued.load(Ordering::Relaxed);
        if queued >= self.max_queue {
            self.stats.shed.fetch_add(1, Ordering::Relaxed);
            reply.defuse();
            if let Some(t) = trace.as_mut() {
                t.mark_shed();
            }
            return Err(Shed::QueueFull { queued, max: self.max_queue });
        }
        let now = Instant::now();
        let budget = deadline_ms
            .filter(|ms| ms.is_finite())
            .map(|ms| Duration::from_secs_f64(ms.clamp(0.0, 86_400_000.0) / 1e3))
            .or(self.slo);
        if let Some(budget) = budget {
            // Estimated queueing delay: EWMA per-request service time ×
            // (queue depth per worker + our own service). Zero until the
            // first batch completes — a cold server never sheds on a
            // deadline it has no evidence it would miss.
            let est_ns = self.stats.est_service_ns.load(Ordering::Relaxed);
            if est_ns > 0 {
                let workers =
                    (self.shards.len() * self.workers_per_shard).max(1) as u64;
                let wait_ns = est_ns.saturating_mul(queued / workers + 1);
                if Duration::from_nanos(wait_ns) > budget {
                    self.stats.shed.fetch_add(1, Ordering::Relaxed);
                    reply.defuse();
                    if let Some(t) = trace.as_mut() {
                        t.mark_shed();
                    }
                    return Err(Shed::Deadline {
                        est_wait_us: wait_ns / 1_000,
                        budget_us: budget.as_micros() as u64,
                    });
                }
            }
        }
        if let Some(t) = trace.as_mut() {
            t.mark_admitted();
        }
        let class = quality.min(self.class_rel_intensity.len().saturating_sub(1));
        let s = self.pick_shard(class);
        if let Some(t) = trace.as_mut() {
            t.mark_routed(s);
            t.mark_enqueued();
        }
        let job = Job {
            pixels,
            quality,
            deadline: budget.map(|b| now + b),
            enqueued: now,
            reply,
            trace,
        };
        // Count before sending: a worker may collect (and decrement) the
        // instant the job lands, so incrementing afterwards could
        // underflow the gauge.
        self.stats.queued.fetch_add(1, Ordering::Relaxed);
        self.shards[s].queued.fetch_add(1, Ordering::Relaxed);
        if let Err(send_err) = self.shards[s].tx.send(job) {
            self.stats.queued.fetch_sub(1, Ordering::Relaxed);
            self.shards[s].queued.fetch_sub(1, Ordering::Relaxed);
            // The channel hands the unsent job back — defuse its reply
            // before it drops, like every other refused path.
            let mut job = send_err.0;
            job.reply.defuse();
            return Err(Shed::Stopped);
        }
        self.stats.record_shard(s);
        Ok(())
    }

    /// Route one request of the given quality class: snapshot every shard
    /// (live queue depth → backlog seconds, wear ledger → headroom) and
    /// ask the policy. Single-shard sets skip the policy entirely.
    pub(crate) fn pick_shard(&self, class: usize) -> usize {
        if self.shards.len() == 1 {
            return 0;
        }
        let est_s = self.stats.est_service_ns.load(Ordering::Relaxed) as f64 / 1e9;
        let per_worker = self.workers_per_shard as f64;
        let rel = self.class_rel_intensity.get(class).copied().unwrap_or(1.0);
        let now = self.start.elapsed().as_secs_f64();
        let mut state = self.policy.lock().unwrap_or_else(|e| e.into_inner());
        let state = &mut *state;
        state.nodes.clear();
        state.nodes.extend(self.shards.iter().enumerate().map(|(id, s)| NodeSnapshot {
            id,
            backlog_seconds: s.queued.load(Ordering::Relaxed) as f64 * est_s
                / per_worker,
            headroom_x: s.headroom_x(),
            generation: s.engine.generation(),
        }));
        state.policy.pick(now, class, rel, &state.nodes).min(self.shards.len() - 1)
    }

    /// Called by a batch worker after collecting `n` jobs from `shard` —
    /// they left the queue for a backend, so the admission gate's view of
    /// queued work shrinks.
    pub(crate) fn note_collected(&self, shard: usize, n: u64) {
        self.stats.queued.fetch_sub(n, Ordering::Relaxed);
        self.shards[shard].queued.fetch_sub(n, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::WearLeveling;
    use crate::server::testutil::{test_engine, test_plans};

    fn two_shard_set(
        ages: Vec<f64>,
        policy: Box<dyn RoutePolicy>,
    ) -> (Arc<ShardSet>, Arc<ServerStats>) {
        let (e0, _) = test_engine();
        let (e1, _) = test_engine();
        let stats = Arc::new(ServerStats::new(e0.num_levels()));
        let wear = WearConfig {
            initial_age_years: ages,
            initial_age_duty: 1.0,
            ..WearConfig::new(test_plans(&e0))
        };
        let set = ShardSet::new(
            vec![Arc::new(e0), Arc::new(e1)],
            policy,
            Some(wear),
            stats.clone(),
            4096,
            None,
            1,
        )
        .unwrap();
        (set, stats)
    }

    #[test]
    fn wear_leveling_places_gentle_traffic_on_the_worn_shard() {
        // Shard 0 arrives with 0.05 years of prior nominal-voltage service,
        // shard 1 fresh. Class 0 deploys the all-nominal plan (relative
        // intensity 1.0), class 1 the aggressive-VOS plan (≈ 0): the
        // wear-leveler must park gentle traffic on the worn shard and
        // steer stress-bearing traffic to the fresh one — live placement
        // following the headroom ranking, not load.
        let (set, _) =
            two_shard_set(vec![0.05, 0.0], Box::new(WearLeveling::new(10.0, 1)));
        let worn = &set.shards()[0];
        let fresh = &set.shards()[1];
        assert!(worn.headroom_x() < fresh.headroom_x(), "pre-aging must cost headroom");
        assert!(worn.delta_vth() > 0.0);
        for _ in 0..8 {
            assert_eq!(set.pick_shard(1), 0, "gentle class → worn shard");
            assert_eq!(set.pick_shard(0), 1, "harsh class → fresh shard");
        }
    }

    #[test]
    fn served_batches_accrue_real_wear() {
        let (set, _) = two_shard_set(Vec::new(), Box::<crate::fleet::RoundRobin>::default());
        let shard = &set.shards()[0];
        let before = shard.headroom_x();
        assert_eq!(shard.delta_vth(), 0.0, "fresh shard starts unstressed");
        // One simulated second of nominal-voltage serving under the 1e6×
        // wear clock ≈ 11.6 deployed days — must visibly consume headroom.
        shard.record_service(0, 1.0);
        assert!(shard.headroom_x() < before, "service must consume headroom");
        assert!(shard.delta_vth() > 0.0);
        // The untouched shard is unchanged.
        assert_eq!(set.shards()[1].delta_vth(), 0.0);
    }

    #[test]
    fn round_robin_spreads_live_traffic() {
        let (set, _) = two_shard_set(Vec::new(), Box::<crate::fleet::RoundRobin>::default());
        let picks: Vec<usize> = (0..6).map(|_| set.pick_shard(0)).collect();
        assert_eq!(picks, vec![0, 1, 0, 1, 0, 1]);
    }
}
