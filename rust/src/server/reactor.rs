//! Readiness-driven (evented) serving frontend.
//!
//! One reactor thread multiplexes thousands of nonblocking TCP
//! connections onto the existing batch-worker queues — no thread per
//! client, no blocking read anywhere on the data path. The loop is the
//! classic epoll shape, hand-rolled over `std::net` (the only FFI is the
//! three `epoll` syscalls on Linux; everywhere else a portable
//! scan-poller over nonblocking sockets keeps the exact same semantics):
//!
//! 1. wait for readiness events (or a waker byte from a batch worker),
//! 2. accept-drain the listener (over [`ReactorConfig::max_conns`] →
//!    typed `{"error":"overloaded"}` line and close),
//! 3. read-drain ready connections into per-connection buffers, split
//!    newline-delimited requests, parse the optional `"deadline_ms"` tag
//!    and hand each request to the shared [`ShardSet`] admission gate —
//!    shed requests are answered inline with the typed shed line,
//! 4. drain the completion queue batch workers fill, serialize replies
//!    (bit-identical to the threaded frontend's — same fields, same
//!    canonical key order) into per-connection write buffers,
//! 5. flush what the sockets will take, keeping `EPOLLOUT` interest only
//!    while a write buffer is non-empty.
//!
//! Slow or hostile clients cost memory, never a thread: a connection that
//! feeds bytes without a newline is capped at
//! [`ReactorConfig::max_line_bytes`] (slow-loris bound), and one that
//! stops reading its replies is closed once its write buffer exceeds
//! [`ReactorConfig::max_wbuf_bytes`].
//!
//! Divergence from the threaded frontend: a malformed line gets a typed
//! `{"error":"bad request...}` reply and the connection *stays open*
//! (the threaded path, which dedicates a thread, bails). Well-formed
//! traffic behaves identically on both.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::shard::ShardSet;
use super::ServerStats;
use crate::util::json::Json;
use crate::util::stats::argmax_f32;

/// Raw `epoll` bindings — Linux only, and only the three syscalls the
/// reactor needs. Kept private so the rest of the crate sees only the
/// portable [`Poller`].
#[cfg(target_os = "linux")]
mod epoll_sys {
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;
    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLL_CLOEXEC: i32 = 0o2000000;

    /// Mirror of the kernel's `struct epoll_event`; packed on x86-64 only
    /// (the kernel packs it there so 32/64-bit layouts agree).
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(
            epfd: i32,
            events: *mut EpollEvent,
            maxevents: i32,
            timeout_ms: i32,
        ) -> i32;
    }
}

/// One readiness report: a registered token plus what it is ready for.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Error/hangup — the connection should be torn down.
    pub closed: bool,
}

#[cfg(target_os = "linux")]
struct EpollPoller {
    epfd: std::os::fd::OwnedFd,
}

#[cfg(target_os = "linux")]
impl EpollPoller {
    fn new() -> Result<Self> {
        use std::os::fd::FromRawFd;
        // SAFETY: epoll_create1 returns a fresh fd (or -1); ownership is
        // transferred straight into OwnedFd, which closes it on drop.
        let fd = unsafe { epoll_sys::epoll_create1(epoll_sys::EPOLL_CLOEXEC) };
        anyhow::ensure!(fd >= 0, "epoll_create1: {}", std::io::Error::last_os_error());
        Ok(Self { epfd: unsafe { std::os::fd::OwnedFd::from_raw_fd(fd) } })
    }

    fn ctl(&self, op: i32, fd: i32, token: u64, interest: u32) -> Result<()> {
        use std::os::fd::AsRawFd;
        let mut ev = epoll_sys::EpollEvent { events: interest, data: token };
        // SAFETY: `ev` is a valid epoll_event for the duration of the call;
        // DEL ignores it.
        let rc = unsafe { epoll_sys::epoll_ctl(self.epfd.as_raw_fd(), op, fd, &mut ev) };
        anyhow::ensure!(rc == 0, "epoll_ctl: {}", std::io::Error::last_os_error());
        Ok(())
    }

    fn wait(&self, out: &mut Vec<Event>, timeout: Duration) -> Result<()> {
        use std::os::fd::AsRawFd;
        const CAP: usize = 1024;
        let mut buf = [epoll_sys::EpollEvent { events: 0, data: 0 }; CAP];
        let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
        // SAFETY: `buf` holds CAP writable epoll_event slots.
        let n = unsafe {
            epoll_sys::epoll_wait(self.epfd.as_raw_fd(), buf.as_mut_ptr(), CAP as i32, ms)
        };
        if n < 0 {
            let err = std::io::Error::last_os_error();
            if err.kind() == std::io::ErrorKind::Interrupted {
                return Ok(()); // EINTR: spurious wakeup, not an error
            }
            return Err(err).context("epoll_wait");
        }
        for ev in buf.iter().take(n as usize) {
            // Copy packed fields by value — never take references into a
            // possibly-packed struct.
            let events = { ev.events };
            let token = { ev.data };
            out.push(Event {
                token,
                readable: events & (epoll_sys::EPOLLIN | epoll_sys::EPOLLRDHUP) != 0,
                writable: events & epoll_sys::EPOLLOUT != 0,
                closed: events & (epoll_sys::EPOLLERR | epoll_sys::EPOLLHUP) != 0,
            });
        }
        Ok(())
    }
}

/// Portable fallback poller: sleeps briefly, then reports every
/// registered token as both readable and writable. Correctness comes from
/// the sockets being nonblocking — a "ready" socket with nothing to read
/// just returns `WouldBlock` — at the cost of wakeups proportional to
/// registered connections. Linux gets real epoll; this keeps every other
/// platform (and `XTPU_POLLER=scan` test runs) on identical semantics.
struct ScanPoller {
    tokens: Vec<u64>,
}

impl ScanPoller {
    fn new() -> Self {
        Self { tokens: Vec::new() }
    }

    fn wait(&self, out: &mut Vec<Event>, timeout: Duration) {
        std::thread::sleep(timeout.min(Duration::from_millis(2)));
        for &token in &self.tokens {
            out.push(Event { token, readable: true, writable: true, closed: false });
        }
    }
}

/// The reactor's readiness source: real epoll on Linux, the scan fallback
/// elsewhere (or anywhere, via `XTPU_POLLER=scan`).
enum Poller {
    #[cfg(target_os = "linux")]
    Epoll(EpollPoller),
    Scan(ScanPoller),
}

impl Poller {
    fn new() -> Result<Self> {
        if std::env::var("XTPU_POLLER").is_ok_and(|v| v == "scan") {
            return Ok(Poller::Scan(ScanPoller::new()));
        }
        #[cfg(target_os = "linux")]
        let poller = Poller::Epoll(EpollPoller::new()?);
        #[cfg(not(target_os = "linux"))]
        let poller = Poller::Scan(ScanPoller::new());
        Ok(poller)
    }

    fn register(&mut self, fd: i32, token: u64) -> Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.ctl(
                epoll_sys::EPOLL_CTL_ADD,
                fd,
                token,
                epoll_sys::EPOLLIN | epoll_sys::EPOLLRDHUP,
            ),
            Poller::Scan(p) => {
                p.tokens.push(token);
                Ok(())
            }
        }
    }

    /// Toggle write-readiness interest (read interest is permanent).
    fn set_writable(&mut self, fd: i32, token: u64, want_write: bool) -> Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => {
                let mut interest = epoll_sys::EPOLLIN | epoll_sys::EPOLLRDHUP;
                if want_write {
                    interest |= epoll_sys::EPOLLOUT;
                }
                p.ctl(epoll_sys::EPOLL_CTL_MOD, fd, token, interest)
            }
            Poller::Scan(_) => Ok(()),
        }
    }

    fn deregister(&mut self, fd: i32, token: u64) -> Result<()> {
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.ctl(epoll_sys::EPOLL_CTL_DEL, fd, token, 0),
            Poller::Scan(p) => {
                p.tokens.retain(|&t| t != token);
                Ok(())
            }
        }
    }

    fn wait(&self, out: &mut Vec<Event>, timeout: Duration) -> Result<()> {
        out.clear();
        match self {
            #[cfg(target_os = "linux")]
            Poller::Epoll(p) => p.wait(out, timeout),
            Poller::Scan(p) => {
                p.wait(out, timeout);
                Ok(())
            }
        }
    }
}

/// Wakes the reactor from `wait` when a batch worker finishes a job —
/// a loopback TCP pair, so it works with both pollers and needs no FFI.
/// Workers write one byte (best-effort; a full pipe already guarantees a
/// pending wakeup), the reactor drains.
pub(crate) struct Waker {
    tx: TcpStream,
    rx: TcpStream,
}

impl Waker {
    fn new() -> Result<Self> {
        let listener = TcpListener::bind("127.0.0.1:0").context("waker bind")?;
        let addr = listener.local_addr()?;
        let tx = TcpStream::connect(addr).context("waker connect")?;
        let ours = tx.local_addr().context("waker local addr")?;
        // Accept until the peer is our own connect: any local process can
        // race a connection onto the ephemeral listener, and pairing with
        // a foreign socket would leave the reactor deaf to its own wakes.
        // Our connect has already completed, so it is guaranteed to be in
        // the accept queue — the loop terminates.
        let rx = loop {
            let (stream, peer) = listener.accept().context("waker accept")?;
            if peer == ours {
                break stream;
            }
        };
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        tx.set_nodelay(true)?;
        Ok(Self { tx, rx })
    }

    pub(crate) fn wake(&self) {
        // `Write for &TcpStream` — shared-ref writes are thread-safe.
        // WouldBlock means the pipe is full: a wakeup is already pending.
        let _ = (&self.tx).write(&[1u8]);
    }

    fn drain(&self) {
        let mut buf = [0u8; 256];
        while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
    }
}

/// One finished (or failed) inference, keyed to the connection awaiting
/// it. `Err(())` means the worker died or the server is stopping — the
/// connection gets the same typed error line the threaded frontend sends.
pub(crate) struct Completion {
    pub conn: u64,
    pub result: Result<(usize, u64, Vec<f32>), ()>,
}

/// Where batch workers deposit evented completions; the reactor drains it
/// every tick.
pub(crate) struct CompletionQueue {
    pub(crate) done: Mutex<Vec<Completion>>,
    pub(crate) waker: Waker,
}

impl CompletionQueue {
    fn push(&self, c: Completion) {
        self.done.lock().unwrap_or_else(|e| e.into_inner()).push(c);
        self.waker.wake();
    }

    fn drain(&self) -> Vec<Completion> {
        let mut g = self.done.lock().unwrap_or_else(|e| e.into_inner());
        std::mem::take(&mut *g)
    }
}

/// The per-job reply route for evented requests. Guarantees exactly one
/// completion per *accepted* job: if the holder (a batch worker) drops it
/// without answering — worker panic, shutdown drain — `Drop` pushes the
/// error completion, mirroring the threaded path's `Disconnected` reply.
/// A request refused at the admission gate is *not* an accepted job: the
/// gate [`defuse`](Self::defuse)s the sink before dropping it, because
/// the frontend's own typed shed line is the one reply the client gets.
pub(crate) struct CompletionSink {
    queue: Arc<CompletionQueue>,
    conn: u64,
    done: bool,
}

impl CompletionSink {
    pub(crate) fn complete_ok(&mut self, level: usize, generation: u64, logits: Vec<f32>) {
        self.done = true;
        self.queue.push(Completion {
            conn: self.conn,
            result: Ok((level, generation, logits)),
        });
    }

    /// Disarm the drop-side error completion. Called by the admission gate
    /// when it refuses a request: the caller answers with the typed shed
    /// line itself, and an armed `Drop` here would push a second, stray
    /// error reply onto the same connection (desyncing pipelined clients).
    pub(crate) fn defuse(&mut self) {
        self.done = true;
    }
}

impl Drop for CompletionSink {
    fn drop(&mut self) {
        if !self.done {
            self.queue.push(Completion { conn: self.conn, result: Err(()) });
        }
    }
}

/// One live client connection.
struct Conn {
    stream: TcpStream,
    /// Bytes received but not yet terminated by a newline.
    rbuf: Vec<u8>,
    /// Persistent newline-scan cursor into `rbuf`: every byte of
    /// `rbuf[..scan_from]` has been scanned, and the first unconsumed
    /// newline (if any) is at or after `scan_from`. Keeps drip-fed lines
    /// and pipelined bursts O(bytes) instead of rescanning the whole
    /// buffer per 4 KiB chunk / per extracted line.
    scan_from: usize,
    /// Serialized replies not yet accepted by the socket.
    wbuf: Vec<u8>,
    /// Reusable scratch for serializing one reply line before it is
    /// appended to `wbuf` — keeps the per-reply `String` allocation the
    /// old `reply.to_string()` path paid off the write path entirely.
    sbuf: String,
    /// Whether EPOLLOUT interest is currently registered.
    want_write: bool,
    /// Replies submitted to workers and not yet answered. A connection
    /// closed by the peer stays tracked until these drain (completions
    /// for a gone connection are dropped, not delivered to a stranger).
    pending: usize,
    /// Peer closed or errored; tear down once `pending` reaches zero.
    closing: bool,
}

/// Evented-frontend tuning knobs (all have serviceable defaults).
#[derive(Clone, Debug)]
pub struct ReactorConfig {
    /// Concurrent connection cap; excess accepts get a typed
    /// `{"error":"overloaded"}` line and an immediate close.
    pub max_conns: usize,
    /// Per-connection cap on buffered bytes without a newline — the
    /// slow-loris bound.
    pub max_line_bytes: usize,
    /// Per-connection cap on unflushed reply bytes; a client that stops
    /// reading is disconnected rather than ballooning memory.
    pub max_wbuf_bytes: usize,
}

impl Default for ReactorConfig {
    fn default() -> Self {
        Self {
            max_conns: 16384,
            max_line_bytes: 1 << 20,
            max_wbuf_bytes: 4 << 20,
        }
    }
}

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const TOKEN_FIRST_CONN: u64 = 2;

/// Reactor entry point — runs on the frontend thread until `shutdown`.
/// Fatal setup/loop errors are reported on stderr; per-connection errors
/// only ever close that connection.
pub(crate) fn run(
    listener: TcpListener,
    shards: Arc<ShardSet>,
    completions: Arc<CompletionQueue>,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
    cfg: ReactorConfig,
) {
    if let Err(e) = run_inner(listener, shards, completions, stats, shutdown, cfg) {
        eprintln!("[server] evented frontend failed: {e:#}");
    }
}

fn run_inner(
    listener: TcpListener,
    shards: Arc<ShardSet>,
    completions: Arc<CompletionQueue>,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
    cfg: ReactorConfig,
) -> Result<()> {
    use std::os::fd::AsRawFd;

    listener.set_nonblocking(true)?;
    let mut poller = Poller::new()?;
    poller.register(listener.as_raw_fd(), TOKEN_LISTENER)?;
    poller.register(completions.waker.rx.as_raw_fd(), TOKEN_WAKER)?;
    let input_dim = shards.input_dim();
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_token = TOKEN_FIRST_CONN;
    let mut events: Vec<Event> = Vec::with_capacity(1024);
    let mut dead: Vec<u64> = Vec::new();

    while !shutdown.load(Ordering::SeqCst) {
        poller.wait(&mut events, Duration::from_millis(20))?;

        // Under the scan poller every tick reports everything; with epoll
        // we only touch what the kernel flagged.
        let (accept_ready, wake_ready) = match &poller {
            Poller::Scan(_) => (true, true),
            #[cfg(target_os = "linux")]
            _ => (
                events.iter().any(|e| e.token == TOKEN_LISTENER),
                events.iter().any(|e| e.token == TOKEN_WAKER),
            ),
        };
        if wake_ready {
            completions.waker.drain();
        }

        if accept_ready {
            loop {
                match listener.accept() {
                    Ok((stream, _addr)) => {
                        if conns.len() >= cfg.max_conns {
                            stats.conn_rejected.fetch_add(1, Ordering::Relaxed);
                            reject_overloaded(stream, conns.len(), cfg.max_conns);
                            continue;
                        }
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        let _ = stream.set_nodelay(true);
                        let token = next_token;
                        next_token += 1;
                        if poller.register(stream.as_raw_fd(), token).is_err() {
                            continue;
                        }
                        conns.insert(
                            token,
                            Conn {
                                stream,
                                rbuf: Vec::new(),
                                scan_from: 0,
                                wbuf: Vec::new(),
                                sbuf: String::new(),
                                want_write: false,
                                pending: 0,
                                closing: false,
                            },
                        );
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => break,
                }
            }
        }

        // Read-drain ready connections and process complete lines.
        for ev in events.iter().filter(|e| e.token >= TOKEN_FIRST_CONN) {
            let Some(conn) = conns.get_mut(&ev.token) else { continue };
            if ev.closed {
                conn.closing = true;
                continue;
            }
            if !ev.readable {
                continue;
            }
            let mut buf = [0u8; 4096];
            loop {
                match conn.stream.read(&mut buf) {
                    Ok(0) => {
                        conn.closing = true;
                        break;
                    }
                    Ok(n) => {
                        conn.rbuf.extend_from_slice(&buf[..n]);
                        // Incremental scan: only bytes appended since the
                        // last scan are examined. Once a newline is found
                        // the cursor parks on it (subsequent chunks re-check
                        // one byte); while none exists the cursor tracks the
                        // buffer tail, making the no-newline predicate O(1).
                        match conn.rbuf[conn.scan_from..]
                            .iter()
                            .position(|&b| b == b'\n')
                        {
                            Some(rel) => conn.scan_from += rel,
                            None => {
                                conn.scan_from = conn.rbuf.len();
                                if conn.rbuf.len() > cfg.max_line_bytes {
                                    // Slow-loris / oversized line: answer
                                    // and cut.
                                    push_reply(
                                        conn,
                                        Json::obj(vec![(
                                            "error",
                                            Json::Str("request line too long".into()),
                                        )]),
                                    );
                                    conn.closing = true;
                                    break;
                                }
                            }
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        conn.closing = true;
                        break;
                    }
                }
            }
            // Single-pass extraction: resume the newline search at the
            // parked cursor, copy each complete line out, and drain the
            // processed prefix once — no per-line buffer shifting.
            let mut consumed = 0;
            loop {
                let from = conn.scan_from.max(consumed);
                let Some(rel) =
                    conn.rbuf[from..].iter().position(|&b| b == b'\n')
                else {
                    break;
                };
                let pos = from + rel;
                let line: Vec<u8> = conn.rbuf[consumed..pos].to_vec();
                consumed = pos + 1;
                conn.scan_from = consumed;
                handle_line(
                    &line,
                    ev.token,
                    conn,
                    &shards,
                    &completions,
                    &stats,
                    input_dim,
                );
            }
            if consumed > 0 {
                conn.rbuf.drain(..consumed);
            }
            // Everything left is newline-free (the search above exhausted
            // the buffer), so the cursor parks on the tail.
            conn.scan_from = conn.rbuf.len();
        }

        // Deliver finished inferences into their connections' write buffers.
        for c in completions.drain() {
            let Some(conn) = conns.get_mut(&c.conn) else { continue }; // conn gone: drop
            conn.pending = conn.pending.saturating_sub(1);
            let reply = match c.result {
                Ok((level, generation, logits)) => ok_reply(level, generation, &logits),
                Err(()) => Json::obj(vec![(
                    "error",
                    Json::Str(
                        "inference failed (worker recovered from a panic, or server \
                         shutting down)"
                            .into(),
                    ),
                )]),
            };
            push_reply(conn, reply);
        }

        // Flush, maintain EPOLLOUT interest, reap finished connections.
        dead.clear();
        for (&token, conn) in conns.iter_mut() {
            if !conn.wbuf.is_empty() {
                flush(conn);
            }
            if conn.wbuf.len() > cfg.max_wbuf_bytes {
                conn.closing = true; // client stopped reading
                conn.wbuf.clear();
            }
            let want = !conn.wbuf.is_empty();
            if want != conn.want_write {
                conn.want_write = want;
                let _ = poller.set_writable(conn.stream.as_raw_fd(), token, want);
            }
            if conn.closing && conn.pending == 0 && conn.wbuf.is_empty() {
                dead.push(token);
            }
        }
        for token in &dead {
            if let Some(conn) = conns.remove(token) {
                let _ = poller.deregister(conn.stream.as_raw_fd(), *token);
            }
        }
    }
    Ok(())
}

/// Parse and dispatch one complete request line. Every outcome produces
/// exactly one eventual reply line: inline (stats, parse errors, shed) or
/// via a [`CompletionSink`] a batch worker must answer or drop.
fn handle_line(
    line: &[u8],
    token: u64,
    conn: &mut Conn,
    shards: &Arc<ShardSet>,
    completions: &Arc<CompletionQueue>,
    stats: &Arc<ServerStats>,
    input_dim: usize,
) {
    let text = String::from_utf8_lossy(line);
    if text.trim().is_empty() {
        return;
    }
    let req = match Json::parse(&text) {
        Ok(req) => req,
        Err(e) => {
            push_reply(
                conn,
                Json::obj(vec![("error", Json::Str(format!("bad request: {e}")))]),
            );
            return;
        }
    };
    if matches!(req.opt("stats").map(|v| v.as_bool()), Some(Ok(true))) {
        // Same shape as the threaded frontend: stats nested under "stats".
        push_reply(conn, Json::obj(vec![("stats", stats.to_json())]));
        return;
    }
    if matches!(req.opt("metrics").map(|v| v.as_bool()), Some(Ok(true))) {
        // Registry exposition — byte-identical to the threaded frontend.
        push_reply(conn, Json::obj(vec![("metrics", stats.metrics_json())]));
        return;
    }
    if let Some(n) = req.opt("trace").and_then(|v| v.as_usize().ok()) {
        push_reply(conn, Json::obj(vec![("trace", stats.tracer.dump(n))]));
        return;
    }
    let pixels: Vec<f32> = match req.get("pixels").and_then(|v| v.as_f64_vec()) {
        Ok(p) => p.iter().map(|&v| v as f32).collect(),
        Err(e) => {
            push_reply(
                conn,
                Json::obj(vec![("error", Json::Str(format!("bad request: {e}")))]),
            );
            return;
        }
    };
    let quality = match req.opt("quality").map(|v| v.as_usize()).transpose() {
        Ok(q) => q.unwrap_or(0),
        Err(e) => {
            push_reply(
                conn,
                Json::obj(vec![("error", Json::Str(format!("bad request: {e}")))]),
            );
            return;
        }
    };
    if pixels.len() != input_dim {
        // Rejected up front: the threaded path lets the backend panic on
        // this (and recovers); the reactor never wastes a batch slot.
        push_reply(
            conn,
            Json::obj(vec![(
                "error",
                Json::Str(format!(
                    "bad request: expected {input_dim} pixels, got {}",
                    pixels.len()
                )),
            )]),
        );
        return;
    }
    let deadline_ms = req.opt("deadline_ms").and_then(|v| v.as_f64().ok());
    let sink = CompletionSink { queue: completions.clone(), conn: token, done: false };
    let trace = stats.tracer.maybe_start();
    match shards.submit(pixels, quality, deadline_ms, super::Reply::Evented(sink), trace) {
        Ok(()) => conn.pending += 1,
        Err(shed) => push_reply(conn, shed.to_json()),
    }
}

/// The success reply — field-for-field identical to the threaded
/// frontend's, and `Json::Obj` keys serialize in canonical (BTreeMap)
/// order, so the bytes match too.
fn ok_reply(level: usize, generation: u64, logits: &[f32]) -> Json {
    Json::obj(vec![
        ("class", Json::Num(argmax_f32(logits) as f64)),
        (
            "logits",
            Json::arr_f64(&logits.iter().map(|&v| v as f64).collect::<Vec<_>>()),
        ),
        ("quality", Json::Num(level as f64)),
        ("generation", Json::Num(generation as f64)),
    ])
}

fn push_reply(conn: &mut Conn, reply: Json) {
    // Serialize into the connection's reusable scratch buffer
    // (`Json::write_compact` is byte-identical to `to_string()`), then
    // append — no per-reply String allocation once the buffer is warm.
    conn.sbuf.clear();
    reply.write_compact(&mut conn.sbuf);
    conn.wbuf.extend_from_slice(conn.sbuf.as_bytes());
    conn.wbuf.push(b'\n');
    // Opportunistic flush: most replies fit the socket buffer and never
    // need an EPOLLOUT round-trip.
    flush(conn);
}

fn flush(conn: &mut Conn) {
    let mut written = 0;
    let mut broken = false;
    while written < conn.wbuf.len() {
        match conn.stream.write(&conn.wbuf[written..]) {
            Ok(0) => {
                broken = true;
                break;
            }
            Ok(n) => written += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                broken = true;
                break;
            }
        }
    }
    if broken {
        // The peer is gone: drop the unsent bytes so the reap condition
        // (`closing && pending == 0 && wbuf empty`) can fire.
        conn.closing = true;
        conn.wbuf.clear();
    } else {
        conn.wbuf.drain(..written);
    }
}

/// Best-effort typed rejection for an over-cap accept: one nonblocking
/// write, then close. This runs on the reactor thread, so it must never
/// block — a freshly accepted socket's send buffer is empty, so the line
/// fits in practice; a peer that somehow can't take it just loses the
/// courtesy line (the immediate close is the real signal).
fn reject_overloaded(mut stream: TcpStream, active: usize, cap: usize) {
    let line = Json::obj(vec![
        ("error", Json::Str("overloaded".into())),
        ("active_conns", Json::Num(active as f64)),
        ("max_conns", Json::Num(cap as f64)),
    ]);
    let _ = stream.set_nonblocking(true);
    let mut bytes = line.to_string().into_bytes();
    bytes.push(b'\n');
    let _ = stream.write(&bytes);
}

pub(crate) fn new_completion_queue() -> Result<Arc<CompletionQueue>> {
    Ok(Arc::new(CompletionQueue {
        done: Mutex::new(Vec::new()),
        waker: Waker::new()?,
    }))
}
