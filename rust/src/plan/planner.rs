//! The staged offline planner: Fig 4 decomposed into cacheable stages.
//!
//! ```text
//! stage 1  trained model        (disk-cached model JSON + quantization)
//! stage 2  error-model registry (disk-cached characterization)
//! stage 3  power model          (gate-level switching measurement)
//! stage 4  ES estimate          (disk-cached, fingerprint-guarded)
//! stage 5  baseline             (clean logits + nominal accuracy/MSE)
//! stage 6  per-budget solve     (MCKP; all budgets solved in parallel)
//! ```
//!
//! Stages 1–5 are budget-independent and computed at most once per
//! [`Planner`]; [`Planner::solve_many`] then fans the per-budget MCKP
//! solves out across [`crate::util::threadpool`] — each solve is pure
//! (deterministic given the stage artifacts), so the parallel sweep is
//! bit-identical to a sequential one.

use std::path::PathBuf;

use anyhow::{Context, Result};

use super::{model_fingerprint, VoltagePlan};
use crate::assign::{AssignmentProblem, Solver, VoltageAssignment};
use crate::config::ExperimentConfig;
use crate::errormodel::{CharacterizeOptions, DriftedRegistry, ErrorModelRegistry, PlanMode};
use crate::ilp::{solve_mckp, MckpError, MckpInstance};
use crate::exec::{self, Backend};
use crate::nn::data::{synth_cifar, synth_mnist, Dataset};
use crate::nn::model::{fc_mnist, lenet5, resnet_tiny, Model};
use crate::nn::quant::QuantizedModel;
use crate::nn::tensor::Tensor;
use crate::nn::train::{train, TrainConfig};
use crate::power::PePowerModel;
use crate::quality;
use crate::runtime::Runtime;
use crate::sensitivity::{statistical_es, EsOptions};
use crate::timing::baugh_wooley_8x8;
use crate::timing::circuits::pe_datapath;
use crate::timing::gate::i64_to_bits;
use crate::timing::sta::{clock_period, ChipInstance};
use crate::timing::voltage::{Technology, VoltageLadder};
use crate::timing::vos::VosSimulator;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool::parallel_chunks;

/// ES-probe settings shared by the planner and its disk cache key.
const ES_TRIALS: usize = 2;

/// Stage-1 artifacts: the trained float model, its int8 quantization, and
/// the evaluation set — plus the fingerprint every downstream plan embeds.
pub struct TrainedStage {
    pub model: Model,
    pub quantized: QuantizedModel,
    pub test: Dataset,
    pub fingerprint: String,
    pub seconds: f64,
}

/// Stage-4 artifact: per-neuron error sensitivities and fan-ins.
pub struct EsStage {
    pub es: Vec<f64>,
    pub fan_in: Vec<usize>,
    pub seconds: f64,
}

/// Stage-5 artifact: clean logits + nominal baselines on the test set.
pub struct BaselineStage {
    pub clean_logits: Tensor,
    pub accuracy: f64,
    /// Nominal test MSE vs one-hot targets — the reference the paper's
    /// "MSE increment %" budgets are relative to.
    pub mse: f64,
}

/// The staged offline planner. Construct once per experiment config; every
/// stage accessor computes lazily and caches in memory (and on disk where
/// the artifact is expensive), so repeated solves never repeat work.
pub struct Planner {
    pub cfg: ExperimentConfig,
    trained: Option<TrainedStage>,
    registry: Option<ErrorModelRegistry>,
    characterize_seconds: f64,
    power: Option<PePowerModel>,
    es: Option<EsStage>,
    baseline: Option<BaselineStage>,
}

impl Planner {
    pub fn new(cfg: ExperimentConfig) -> Self {
        Self {
            cfg,
            trained: None,
            registry: None,
            characterize_seconds: 0.0,
            power: None,
            es: None,
            baseline: None,
        }
    }

    // --- stage accessors -------------------------------------------------

    /// Stage 1: trained model + quantization (disk-cached model JSON).
    pub fn trained(&mut self) -> Result<&TrainedStage> {
        if self.trained.is_none() {
            let t0 = std::time::Instant::now();
            let (model, _train_set, test) = train_model(&self.cfg)?;
            let calib_n = test.len().min(64);
            let calib = test.batch(&(0..calib_n).collect::<Vec<_>>()).0;
            let quantized = QuantizedModel::quantize(&model, &calib);
            let fingerprint = model_fingerprint(&model);
            self.trained = Some(TrainedStage {
                model,
                quantized,
                test,
                fingerprint,
                seconds: t0.elapsed().as_secs_f64(),
            });
        }
        Ok(self.trained.as_ref().unwrap())
    }

    /// Stage 2: per-voltage statistical error models (disk-cached).
    pub fn registry(&mut self) -> Result<&ErrorModelRegistry> {
        if self.registry.is_none() {
            let t0 = std::time::Instant::now();
            self.registry = Some(characterize_registry(&self.cfg)?);
            self.characterize_seconds = t0.elapsed().as_secs_f64();
        }
        Ok(self.registry.as_ref().unwrap())
    }

    /// Stage 3: the PE power model (gate-level switching measurement).
    pub fn power(&mut self) -> &PePowerModel {
        if self.power.is_none() {
            let t0 = std::time::Instant::now();
            self.power = Some(measure_power_model(self.cfg.seed));
            self.characterize_seconds += t0.elapsed().as_secs_f64();
        }
        self.power.as_ref().unwrap()
    }

    /// Stage 4: per-neuron error sensitivities, disk-cached keyed on the
    /// model fingerprint (a retrained model invalidates the cache).
    pub fn es_stage(&mut self) -> Result<&EsStage> {
        if self.es.is_none() {
            self.trained()?;
            let trained = self.trained.as_ref().unwrap();
            let fan_in: Vec<usize> =
                trained.model.neurons().iter().map(|n| n.fan_in).collect();
            let probe_n = trained.test.len().min(16);
            let cache = self.es_cache_path(probe_n);
            let t0 = std::time::Instant::now();
            let es = match load_es_cache(&cache, &trained.fingerprint, fan_in.len()) {
                Some(es) => es,
                None => {
                    let probe = trained.test.batch(&(0..probe_n).collect::<Vec<_>>()).0;
                    let es = statistical_es(
                        &trained.quantized,
                        &probe,
                        &EsOptions { trials: ES_TRIALS, ..Default::default() },
                    );
                    save_es_cache(&cache, &trained.fingerprint, &es);
                    es
                }
            };
            self.es = Some(EsStage { es, fan_in, seconds: t0.elapsed().as_secs_f64() });
        }
        Ok(self.es.as_ref().unwrap())
    }

    /// Stage 5: clean logits + nominal accuracy/MSE through the configured
    /// execution backend.
    pub fn baseline(&mut self) -> Result<&BaselineStage> {
        if self.baseline.is_none() {
            self.trained()?;
            self.registry()?;
            let trained = self.trained.as_ref().unwrap();
            let registry = self.registry.as_ref().unwrap();
            let backend = make_backend(&self.cfg, registry)?;
            let mut rng = Xoshiro256pp::seeded(self.cfg.seed ^ 0x7EA);
            let idx: Vec<usize> = (0..trained.test.len()).collect();
            let (x, labels) = trained.test.batch(&idx);
            let clean_logits =
                trained.quantized.forward_with(backend.as_ref(), &x, None, &mut rng);
            let accuracy = quality::accuracy(&clean_logits, &labels);
            let mse = baseline_mse_vs_onehot(&clean_logits, &labels);
            self.baseline = Some(BaselineStage { clean_logits, accuracy, mse });
        }
        Ok(self.baseline.as_ref().unwrap())
    }

    /// Compute every budget-independent stage.
    pub fn warm(&mut self) -> Result<()> {
        self.trained()?;
        self.registry()?;
        self.power();
        self.es_stage()?;
        self.baseline()?;
        Ok(())
    }

    fn es_cache_path(&self, probe_n: usize) -> PathBuf {
        PathBuf::from(&self.cfg.artifacts_dir).join(format!(
            "es_{}_{}_s{}_n{}_p{}_t{}.json",
            self.cfg.model,
            self.cfg.activation.name(),
            self.cfg.seed,
            self.cfg.train_samples,
            probe_n,
            ES_TRIALS
        ))
    }

    // --- solving ---------------------------------------------------------

    /// Solve one MSE_UB budget (fraction of nominal MSE) into a deployable
    /// plan, using the config's solver.
    pub fn solve(&mut self, fraction: f64) -> Result<VoltagePlan> {
        self.solve_with(fraction, self.cfg.solver)
    }

    pub fn solve_with(&mut self, fraction: f64, solver: Solver) -> Result<VoltagePlan> {
        self.warm()?;
        let es = self.es.as_ref().unwrap();
        solve_one(
            &self.cfg,
            &self.trained.as_ref().unwrap().fingerprint,
            &es.es,
            &es.fan_in,
            self.registry.as_ref().unwrap(),
            self.power.as_ref().unwrap(),
            self.baseline.as_ref().unwrap().mse,
            fraction,
            solver,
        )
        .map(|(_, plan)| plan)
    }

    /// Solve many budgets **in parallel** (one MCKP per worker). Each solve
    /// is deterministic given the shared stage artifacts, so the result is
    /// identical to solving the budgets one by one, in order.
    pub fn solve_many(&mut self, fractions: &[f64]) -> Result<Vec<VoltagePlan>> {
        self.solve_many_with(fractions, self.cfg.solver)
    }

    pub fn solve_many_with(
        &mut self,
        fractions: &[f64],
        solver: Solver,
    ) -> Result<Vec<VoltagePlan>> {
        self.warm()?;
        let cfg = &self.cfg;
        let fingerprint = &self.trained.as_ref().unwrap().fingerprint;
        let es = self.es.as_ref().unwrap();
        let registry = self.registry.as_ref().unwrap();
        let power = self.power.as_ref().unwrap();
        let baseline_mse = self.baseline.as_ref().unwrap().mse;
        let parts = parallel_chunks(fractions.len(), |range, _| {
            range
                .map(|i| {
                    solve_one(
                        cfg,
                        fingerprint,
                        &es.es,
                        &es.fan_in,
                        registry,
                        power,
                        baseline_mse,
                        fractions[i],
                        solver,
                    )
                    .map(|(_, plan)| plan)
                })
                .collect::<Vec<Result<VoltagePlan>>>()
        });
        parts.into_iter().flatten().collect()
    }

    /// Incrementally re-solve a deployed plan against a drift-aware
    /// registry (see [`resolve_plan_from`]): warm-started from the
    /// deployed assignment, it only re-solves neurons whose MSE
    /// contribution actually moved. Bit-for-bit the deployed assignment at
    /// zero drift. Uses [`ResolveOptions::default`]; pass budget-headroom
    /// or solver overrides through [`Planner::resolve_from_with`].
    pub fn resolve_from(
        &mut self,
        deployed: &VoltagePlan,
        drifted: &DriftedRegistry,
    ) -> Result<ReplanOutcome> {
        self.resolve_from_with(deployed, drifted, &ResolveOptions::default())
    }

    /// [`Planner::resolve_from`] with explicit [`ResolveOptions`] — e.g.
    /// the `budget_scale < 1.0` headroom an adaptive fleet re-plans with.
    pub fn resolve_from_with(
        &mut self,
        deployed: &VoltagePlan,
        drifted: &DriftedRegistry,
        opts: &ResolveOptions,
    ) -> Result<ReplanOutcome> {
        self.registry()?;
        self.power();
        let base = self.registry.as_ref().unwrap();
        let power = self.power.as_ref().unwrap();
        resolve_plan_from(deployed, base, drifted, power, opts)
    }

    /// Solve every budget in the config and write one plan file per budget
    /// into `dir`. Returns the plans and their paths.
    pub fn emit_plans(&mut self, dir: &std::path::Path) -> Result<Vec<(VoltagePlan, PathBuf)>> {
        let fractions = self.cfg.mse_ub_fractions.clone();
        let plans = self.solve_many(&fractions)?;
        let mut out = Vec::with_capacity(plans.len());
        for plan in plans {
            let path = dir.join(plan.file_name());
            plan.save(&path)?;
            out.push((plan, path));
        }
        Ok(out)
    }

    // --- decomposed accessors for the coordinator shell ------------------

    /// Tear the planner down into its stage artifacts:
    /// `(trained, registry, characterize_seconds, power, es, baseline)`.
    /// Call [`Planner::warm`] first; panics on an unwarmed planner.
    pub fn into_stages(
        self,
    ) -> (TrainedStage, ErrorModelRegistry, f64, PePowerModel, EsStage, BaselineStage) {
        (
            self.trained.expect("planner not warmed"),
            self.registry.expect("planner not warmed"),
            self.characterize_seconds,
            self.power.expect("planner not warmed"),
            self.es.expect("planner not warmed"),
            self.baseline.expect("planner not warmed"),
        )
    }
}

/// One budget → one solved assignment + its deployable plan. The single
/// place plan assembly happens: both the planner's sweep and the
/// coordinator's `run_budget` go through here, so `xtpu plan` artifacts
/// can never diverge from the plans embedded in a `BudgetReport`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn solve_one(
    cfg: &ExperimentConfig,
    fingerprint: &str,
    es: &[f64],
    fan_in: &[usize],
    registry: &ErrorModelRegistry,
    power: &PePowerModel,
    baseline_mse: f64,
    fraction: f64,
    solver: Solver,
) -> Result<(VoltageAssignment, VoltagePlan)> {
    let budget_abs = fraction * baseline_mse;
    let mode = PlanMode::from_name(&cfg.mode)?;
    let problem = AssignmentProblem::build_for_mode(es, fan_in, registry, power, budget_abs, mode);
    let assignment = problem.solve(solver)?;
    let plan = VoltagePlan::from_assignment(
        cfg,
        fingerprint,
        es,
        fan_in,
        registry,
        fraction,
        baseline_mse,
        &assignment,
        solver,
    );
    Ok((assignment, plan))
}

// --- incremental re-planning (the adaptive loop's solve step) -------------

/// Knobs for [`resolve_plan_from`].
#[derive(Clone, Copy, Debug)]
pub struct ResolveOptions {
    /// A neuron is *frozen* at its deployed level when the drift moved its
    /// MSE contribution by less than `freeze_tol × budget / neurons` — so
    /// the frozen set perturbs the total by at most `freeze_tol × budget`.
    /// At ΔVth = 0 every contribution is unchanged, everything freezes,
    /// and the result is bit-for-bit the deployed assignment.
    pub freeze_tol: f64,
    /// Scale applied to the plan's absolute budget when re-solving —
    /// < 1.0 leaves headroom for the drift that accrues *between*
    /// re-plans, so the served MSE stays inside the user budget for the
    /// whole inter-replan window, not just at the solve instant.
    pub budget_scale: f64,
    /// Solver for the non-frozen subproblem.
    pub solver: Solver,
    /// Re-solve into this operating regime instead of the deployed plan's
    /// own (`None` keeps the regime). `Some(PlanMode::TeDrop)` is the
    /// fleet's mode-switch lever: when BTI drift erodes the guard band
    /// faster than the tolerate regime can absorb, the re-plan re-prices
    /// every neuron under detect-and-drop weights — a regime change, so the
    /// warm-start freeze set collapses and the solve is effectively full.
    pub switch_mode: Option<PlanMode>,
}

impl Default for ResolveOptions {
    fn default() -> Self {
        // budget_scale defaults to 1.0 so the zero-drift warm path is
        // bit-for-bit (a scaled budget would thaw a deployed plan that
        // legitimately fills its full budget); adaptive fleets pass < 1.0
        // to buy inter-replan headroom.
        Self { freeze_tol: 0.02, budget_scale: 1.0, solver: Solver::Ilp, switch_mode: None }
    }
}

/// Result of one incremental re-solve.
#[derive(Clone, Debug)]
pub struct ReplanOutcome {
    /// The next-generation plan (generation incremented, drift recorded).
    pub plan: VoltagePlan,
    /// Neurons kept at their deployed level without re-solving.
    pub frozen: usize,
    /// Neurons the warm-started MCKP actually re-solved.
    pub resolved: usize,
    /// `false` when even the all-nominal assignment exceeds the budget
    /// under this drift — the device has reached *quality* end of life;
    /// the returned plan is pinned all-nominal (minimum achievable MSE).
    pub feasible: bool,
    pub solve_seconds: f64,
}

/// Warm-start an MCKP re-solve of `deployed` against a drift-aware
/// registry: freeze every neuron whose MSE contribution barely moved,
/// re-solve only the rest against the residual budget. `base` must be the
/// fresh (characterization-time) registry — the contributions the deployed
/// plan assumed are reconstructed from it via the plan's own drift
/// provenance (`base.drifted(deployed.drift_delta_vth)`), so re-planning
/// chains correctly across generations.
pub fn resolve_plan_from(
    deployed: &VoltagePlan,
    base: &ErrorModelRegistry,
    drifted: &DriftedRegistry,
    power: &PePowerModel,
    opts: &ResolveOptions,
) -> Result<ReplanOutcome> {
    let ladder: Vec<f64> =
        drifted.registry().ladder.levels().iter().map(|l| l.volts).collect();
    anyhow::ensure!(
        deployed.volts.len() == ladder.len()
            && deployed.volts.iter().zip(&ladder).all(|(a, b)| (a - b).abs() < 1e-9),
        "plan '{}' ladder {:?} does not match the drifted registry ladder {:?}",
        deployed.name,
        deployed.volts,
        ladder
    );
    let n = deployed.neurons();
    anyhow::ensure!(n > 0, "plan '{}' covers no neurons", deployed.name);
    let t0 = std::time::Instant::now();

    // The error models the deployed assignment was solved against.
    let old = base.drifted(deployed.drift_delta_vth);
    let budget = deployed.budget_abs * opts.budget_scale;
    // Operating regimes: the deployed plan's weights are reconstructed in
    // its own regime; the re-solve prices in the target regime (same one
    // unless the caller asked for a mode switch).
    let old_mode = deployed.plan_mode();
    let mode = opts.switch_mode.unwrap_or(old_mode);
    // Per-neuron per-level MSE contributions (eq. 29 weights, regime-
    // priced) under the new drift, plus the deployed level's old/new
    // contributions.
    let new_vars: Vec<f64> =
        drifted.registry().models().iter().map(|m| mode.mac_variance(m)).collect();
    let old_vars: Vec<f64> =
        old.registry().models().iter().map(|m| old_mode.mac_variance(m)).collect();
    let freeze_limit = opts.freeze_tol * budget / n as f64;
    let mut frozen = vec![false; n];
    let mut frozen_weight = 0.0;
    for u in 0..n {
        let (e, k, l) = (deployed.es[u], deployed.fan_in[u] as f64, deployed.level[u]);
        let w_old = e * e * k * old_vars[l];
        let w_new = e * e * k * new_vars[l];
        if (w_new - w_old).abs() <= freeze_limit {
            frozen[u] = true;
            frozen_weight += w_new;
        }
    }
    // The frozen set must leave a usable residual budget; if the drift
    // moved it past the budget the warm start is void — thaw everything.
    // (The 1e-9 slack admits a deployed plan that fills its budget to the
    // solver's own feasibility tolerance.)
    if frozen_weight > budget + 1e-9 {
        frozen.fill(false);
        frozen_weight = 0.0;
    }
    let mut active: Vec<usize> = (0..n).filter(|&u| !frozen[u]).collect();

    // Per-neuron rows for the (sub)instance builder.
    let cost_row = |u: usize| -> Vec<f64> {
        ladder.iter().map(|&v| power.neuron_energy(deployed.fan_in[u], v)).collect()
    };
    let weight_row = |u: usize| -> Vec<f64> {
        let ek = deployed.es[u] * deployed.es[u] * deployed.fan_in[u] as f64;
        new_vars.iter().map(|&v| ek * v).collect()
    };
    let solve_sub = |subset: &[usize], sub_budget: f64| {
        let inst = MckpInstance {
            cost: subset.iter().map(|&u| cost_row(u)).collect(),
            weight: subset.iter().map(|&u| weight_row(u)).collect(),
            budget: sub_budget,
        };
        match opts.solver {
            Solver::Ilp => solve_mckp(&inst),
            Solver::Greedy => crate::ilp::solve_greedy(&inst),
            Solver::Genetic => crate::ilp::solve_genetic(&inst, &crate::ilp::GaConfig::default()),
        }
    };

    let mut level = deployed.level.clone();
    let mut feasible = true;
    let mut optimal = true;
    if !active.is_empty() {
        let mut sub = solve_sub(&active, budget - frozen_weight);
        if matches!(sub, Err(MckpError::Infeasible(_))) && active.len() < n {
            // A residual budget can be unservable even when a full
            // re-solve is not (frozen neurons may sit on weight a full
            // solve would reassign): thaw everything and retry once.
            active = (0..n).collect();
            sub = solve_sub(&active, budget);
        }
        match sub {
            Ok(sol) => {
                optimal = sol.optimal;
                for (i, &u) in active.iter().enumerate() {
                    level[u] = sol.choice[i];
                }
            }
            Err(MckpError::Infeasible(_)) => {
                // Even all-nominal violates the (scaled) budget: quality
                // end of life. Pin to the minimum-MSE assignment.
                feasible = false;
                optimal = false;
                let nominal = ladder.len() - 1;
                level.iter_mut().for_each(|l| *l = nominal);
            }
            Err(e) => anyhow::bail!("re-plan MCKP failed: {e}"),
        }
    }

    // Re-price the merged assignment under the drifted models (summed in
    // neuron order, so the frozen-everything path reproduces the deployed
    // plan deterministically).
    let mut predicted_mse = 0.0;
    let mut energy = 0.0;
    let mut nominal_energy = 0.0;
    let v_nom = *ladder.last().unwrap();
    for u in 0..n {
        let (e, k) = (deployed.es[u], deployed.fan_in[u]);
        predicted_mse += e * e * k as f64 * new_vars[level[u]];
        energy += power.neuron_energy(k, ladder[level[u]]);
        nominal_energy += power.neuron_energy(k, v_nom);
    }
    let assignment = VoltageAssignment {
        volts: level.iter().map(|&l| ladder[l]).collect(),
        predicted_mse,
        energy,
        energy_saving: 1.0 - energy / nominal_energy,
        optimal,
        nodes_explored: 0,
        solve_seconds: t0.elapsed().as_secs_f64(),
        level,
    };
    // A fully-frozen pass kept the deployed solver's assignment; any
    // actual re-solve is attributed to the solver that ran it.
    let solver = if active.is_empty() {
        Solver::from_name(&deployed.solver).unwrap_or(opts.solver)
    } else {
        opts.solver
    };
    // A mode switch rides the re-plan into the embedded config (and flips
    // the level-driven backend selection with it), so the next generation
    // — and anything that re-serves the saved plan — stays self-consistent.
    let mut cfg = deployed.config.clone();
    if mode != old_mode {
        cfg.mode = mode.name().to_string();
        match mode {
            PlanMode::TeDrop => cfg.backend = "tedrop".to_string(),
            PlanMode::Statistical => {
                if cfg.backend == "tedrop" {
                    cfg.backend = "statistical".to_string();
                }
            }
        }
    }
    let mut plan = VoltagePlan::from_assignment(
        &cfg,
        &deployed.model_fingerprint,
        &deployed.es,
        &deployed.fan_in,
        drifted.registry(),
        deployed.mse_ub_fraction,
        deployed.baseline_mse,
        &assignment,
        solver,
    );
    // Preserve identity, advance lineage, record the drift.
    plan.name = deployed.name.clone();
    plan.generation = deployed.generation + 1;
    plan.drift_delta_vth = drifted.delta_vth;
    let frozen_count = n - active.len();
    Ok(ReplanOutcome {
        plan,
        frozen: frozen_count,
        resolved: active.len(),
        feasible,
        solve_seconds: assignment.solve_seconds,
    })
}

/// One backend instance per serving worker — the share-nothing pool
/// [`crate::server::Engine::with_backend_pool`] installs so concurrent
/// batches never contend even on backends with interior state.
pub fn make_backend_pool(
    cfg: &ExperimentConfig,
    registry: &ErrorModelRegistry,
    workers: usize,
) -> Result<Vec<Box<dyn Backend>>> {
    (0..workers.max(1)).map(|_| make_backend(cfg, registry)).collect()
}

// --- stage implementations (shared with the coordinator shell) -----------

/// Build (or load from cache) the trained float model + datasets.
pub fn train_model(cfg: &ExperimentConfig) -> Result<(Model, Dataset, Dataset)> {
    let (train_set, test_set) = match cfg.model.as_str() {
        "resnet_tiny" => (
            synth_cifar(cfg.train_samples, cfg.seed ^ 0x11),
            synth_cifar(cfg.test_samples, cfg.seed ^ 0x22),
        ),
        _ => (
            synth_mnist(cfg.train_samples, cfg.seed ^ 0x11),
            synth_mnist(cfg.test_samples, cfg.seed ^ 0x22),
        ),
    };
    let cache = model_cache_path(cfg);
    if cache.exists() {
        if let Ok(m) = Model::load(&cache) {
            return Ok((m, train_set, test_set));
        }
    }
    let mut rng = Xoshiro256pp::seeded(cfg.seed);
    let mut model = match cfg.model.as_str() {
        "fc_mnist" => fc_mnist(cfg.activation, &mut rng),
        "lenet5" => lenet5(&mut rng),
        "resnet_tiny" => resnet_tiny(&mut rng),
        other => anyhow::bail!("unknown model '{other}'"),
    };
    let tc = TrainConfig {
        epochs: cfg.epochs,
        batch_size: 32,
        // FC nets train paper-style: MSE vs one-hot, so "MSE_UB as % of
        // nominal MSE" operates on the [0,1] output scale the paper
        // assumes; CNNs keep softmax cross-entropy.
        lr: if cfg.model == "fc_mnist" { 0.05 } else { 0.02 },
        momentum: 0.9,
        seed: cfg.seed,
        loss: if cfg.model == "fc_mnist" {
            crate::nn::train::Loss::Mse
        } else {
            crate::nn::train::Loss::SoftmaxCrossEntropy
        },
        log_every: 0,
    };
    train(&mut model, &train_set, &tc);
    model.save(&cache).context("caching trained model")?;
    Ok((model, train_set, test_set))
}

fn model_cache_path(cfg: &ExperimentConfig) -> PathBuf {
    PathBuf::from(&cfg.artifacts_dir).join(format!(
        "models/{}_{}_s{}_n{}.json",
        cfg.model,
        cfg.activation.name(),
        cfg.seed,
        cfg.train_samples
    ))
}

/// Characterize the PE multiplier (or load the cached registry).
pub fn characterize_registry(cfg: &ExperimentConfig) -> Result<ErrorModelRegistry> {
    let tech = Technology::default();
    let ladder = VoltageLadder::new(&cfg.voltages, tech);
    let cache = PathBuf::from(&cfg.artifacts_dir)
        .join(format!("error_models_s{}_n{}.json", cfg.seed, cfg.characterize_samples));
    if cache.exists() {
        if let Ok(reg) = ErrorModelRegistry::load(&cache, tech) {
            if reg.ladder.len() == ladder.len() {
                return Ok(reg);
            }
        }
    }
    let netlist = baugh_wooley_8x8("pe_multiplier");
    let mut rng = Xoshiro256pp::seeded(cfg.seed ^ 0xC41);
    let chip = ChipInstance::sample(&netlist, &tech, &mut rng);
    let opts = CharacterizeOptions {
        samples: cfg.characterize_samples,
        seed: cfg.seed ^ 0xE44,
        ..Default::default()
    };
    let reg = ErrorModelRegistry::characterize(&netlist, &chip, &ladder, &opts);
    reg.save(&cache).ok();
    Ok(reg)
}

/// Construct the inference [`Backend`] the experiment config selects
/// (`exact` | `statistical` | `tedrop` | `pjrt`); validation and serving both run
/// through this seam. The cycle/gate-accurate backend is constructed
/// explicitly via [`exec::GateLevel`] (it needs a characterized chip and is
/// orders of magnitude slower).
pub fn make_backend(
    cfg: &ExperimentConfig,
    registry: &ErrorModelRegistry,
) -> Result<Box<dyn Backend>> {
    match cfg.backend.as_str() {
        "exact" => Ok(Box::new(exec::Exact)),
        "statistical" => Ok(Box::new(exec::Statistical::new(registry.clone()))),
        "tedrop" => Ok(Box::new(exec::TeDrop::new(registry.clone()))),
        "pjrt" => {
            // Root the runtime at the experiment's artifacts dir (the same
            // one the model/registry caches use), not the global default,
            // so `--artifacts` is honored.
            let dir = PathBuf::from(&cfg.artifacts_dir);
            let rt = Runtime::new(&dir)?;
            Ok(Box::new(exec::Pjrt::new(rt).with_registry(registry.clone())))
        }
        other => anyhow::bail!("unknown backend '{other}' (exact|statistical|tedrop|pjrt)"),
    }
}

/// Measure the PE power model by running the gate-level PE datapath on a
/// random stimulus and attributing switching energy per region (Fig 1b).
pub fn measure_power_model(seed: u64) -> PePowerModel {
    let pe = pe_datapath(24);
    let tech = Technology::default();
    let chip = ChipInstance::ideal(&pe.netlist);
    let clock = clock_period(&pe.netlist, &chip, &tech);
    let mut sim =
        VosSimulator::new(&pe.netlist, chip.delays_at(&pe.netlist, &tech, tech.v_nominal), clock);
    let mut rng = Xoshiro256pp::seeded(seed ^ 0xA0);
    let cycles = 3000u64;
    for _ in 0..cycles {
        let a = rng.range_i64(-128, 127);
        let w = rng.range_i64(-128, 127);
        let p = rng.range_i64(-(1 << 20), 1 << 20);
        let packed: i64 = (a & 0xFF) | ((w & 0xFF) << 8) | ((p & 0xFF_FFFF) << 16);
        sim.step(&i64_to_bits(packed, 40));
    }
    PePowerModel::from_simulation(&pe, sim.toggle_counts(), cycles, tech)
}

/// Paper-style nominal MSE: quantized clean logits vs one-hot targets on
/// the test set (the "nominal value of the NN model … acquired using the
/// test dataset" that MSE_UB percentages are relative to).
pub fn baseline_mse_vs_onehot(logits: &Tensor, labels: &[u8]) -> f64 {
    let classes = logits.shape[1];
    let mut onehot = vec![0f32; logits.data.len()];
    for (r, &l) in labels.iter().enumerate() {
        onehot[r * classes + l as usize] = 1.0;
    }
    quality::mse(&onehot, &logits.data)
}

// --- ES disk cache --------------------------------------------------------

fn load_es_cache(path: &std::path::Path, fingerprint: &str, neurons: usize) -> Option<Vec<f64>> {
    if !path.exists() {
        return None;
    }
    let j = crate::util::json::read_file(path).ok()?;
    if j.get("fingerprint").ok()?.as_str().ok()? != fingerprint {
        return None;
    }
    let es = j.get("es").ok()?.as_f64_vec().ok()?;
    (es.len() == neurons).then_some(es)
}

fn save_es_cache(path: &std::path::Path, fingerprint: &str, es: &[f64]) {
    let j = Json::obj(vec![
        ("fingerprint", Json::Str(fingerprint.to_string())),
        ("es", Json::arr_f64(es)),
    ]);
    crate::util::json::write_file(path, &j).ok();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_cfg() -> ExperimentConfig {
        ExperimentConfig {
            seed: 0x51AB,
            mse_ub_fractions: vec![0.0, 0.5, 2.0],
            ..ExperimentConfig::smoke()
        }
    }

    #[test]
    fn stages_compute_once_and_solves_are_consistent() {
        let mut planner = Planner::new(smoke_cfg());
        planner.warm().unwrap();
        let baseline_mse = planner.baseline().unwrap().mse;
        assert!(baseline_mse > 0.0);
        let neurons = planner.trained().unwrap().quantized.num_neurons();
        assert_eq!(planner.es_stage().unwrap().es.len(), neurons);

        // A single solve and the parallel sweep must agree bit-exactly.
        let single = planner.solve(2.0).unwrap();
        let many = planner.solve_many(&[0.0, 0.5, 2.0]).unwrap();
        assert_eq!(many.len(), 3);
        assert_eq!(many[2].level, single.level);
        assert_eq!(many[2].predicted_mse, single.predicted_mse);
        assert_eq!(many[2].energy_saving, single.energy_saving);
        // Zero budget = all nominal = the "exact" level.
        assert_eq!(many[0].name, "exact");
        assert!(many[0].level.iter().all(|&l| l == many[0].volts.len() - 1));
        assert_eq!(many[0].energy_saving, 0.0);
        // Budgets are monotone in saving.
        assert!(many[1].energy_saving <= many[2].energy_saving + 1e-12);
        // Provenance is consistent across the sweep.
        assert_eq!(many[0].model_fingerprint, many[2].model_fingerprint);
        assert_eq!(many[0].config_hash, many[2].config_hash);
        many[0].check_compatible(&many[2]).unwrap();
        let registry = planner.registry().unwrap().clone();
        many[2]
            .validate_against(&planner.trained().unwrap().quantized, &registry)
            .unwrap();

        // The Planner-level adaptive seam: zero drift is a frozen no-op
        // (bit-for-bit levels, lineage advanced), and an options override
        // with budget headroom stays feasible against the scaled budget.
        let out = planner.resolve_from(&single, &registry.drifted(0.0)).unwrap();
        assert_eq!(out.plan.level, single.level);
        assert_eq!((out.resolved, out.plan.generation), (0, 1));
        let opts = ResolveOptions { budget_scale: 0.9, ..Default::default() };
        let scaled = planner
            .resolve_from_with(&single, &registry.drifted(0.005), &opts)
            .unwrap();
        assert!(scaled.feasible);
        assert!(scaled.plan.predicted_mse <= single.budget_abs * 0.9 + 1e-9);
    }

    fn synthetic_problem() -> (
        Vec<f64>,
        Vec<usize>,
        crate::errormodel::ErrorModelRegistry,
        crate::power::PePowerModel,
    ) {
        use crate::power::RegionActivity;
        use crate::timing::voltage::{Technology, VoltageLadder};
        let es = vec![0.001, 0.002, 0.004, 0.01, 0.05, 0.3, 1.0, 0.8];
        let fan_in = vec![784, 784, 784, 784, 128, 128, 128, 128];
        let reg = crate::errormodel::ErrorModelRegistry::synthetic(
            &VoltageLadder::paper_default(),
            &[3.0e6, 1.4e6, 2.0e5, 0.0],
        );
        let power = crate::power::PePowerModel::new(
            RegionActivity { toggle_energy_per_cycle: 60.0, leakage_sum: 400.0 },
            RegionActivity { toggle_energy_per_cycle: 20.0, leakage_sum: 120.0 },
            Technology::default(),
        );
        (es, fan_in, reg, power)
    }

    fn cold_plan(budget_abs: f64) -> (VoltagePlan, crate::errormodel::ErrorModelRegistry, crate::power::PePowerModel) {
        let (es, fan_in, reg, power) = synthetic_problem();
        let baseline_mse = 1.0;
        let (_, plan) = solve_one(
            &ExperimentConfig::smoke(),
            "deadbeefdeadbeef",
            &es,
            &fan_in,
            &reg,
            &power,
            baseline_mse,
            budget_abs, // fraction of baseline 1.0 ⇒ budget_abs == fraction
            Solver::Ilp,
        )
        .unwrap();
        (plan, reg, power)
    }

    #[test]
    fn resolve_from_zero_drift_is_bit_for_bit() {
        let (plan, reg, power) = cold_plan(2000.0);
        assert!(plan.level.iter().any(|&l| l < 3), "budget must overscale something");
        let out = resolve_plan_from(
            &plan,
            &reg,
            &reg.drifted(0.0),
            &power,
            &ResolveOptions::default(),
        )
        .unwrap();
        // Zero drift: nothing re-solved, assignment bit-for-bit, lineage
        // advanced, drift provenance recorded.
        assert_eq!(out.plan.level, plan.level, "levels must match the cold solve exactly");
        assert_eq!(out.frozen, plan.neurons());
        assert_eq!(out.resolved, 0);
        assert!(out.feasible);
        assert_eq!(out.plan.generation, 1);
        assert_eq!(out.plan.drift_delta_vth, 0.0);
        crate::util::checks::assert_close(out.plan.predicted_mse, plan.predicted_mse, 1e-9);
        crate::util::checks::assert_close(out.plan.energy_saving, plan.energy_saving, 1e-9);
        // Provenance survives: the re-planned artifact still pairs with
        // its siblings from the original offline run.
        out.plan.check_compatible(&plan).unwrap();
    }

    #[test]
    fn resolve_from_drift_restores_the_budget() {
        let (plan, reg, power) = cold_plan(2000.0);
        let drifted = reg.drifted(0.015);
        // The deployed assignment re-priced under drift must have left the
        // budget (otherwise this test exercises nothing). Priced through
        // the canonical observable the fleet also samples.
        let aged_vars: Vec<f64> =
            drifted.registry().models().iter().map(|m| m.variance).collect();
        let aged_mse = plan.served_mse(&aged_vars);
        assert!(
            aged_mse > plan.budget_abs,
            "drift must push the stale plan out of budget ({aged_mse} ≤ {})",
            plan.budget_abs
        );
        let out = resolve_plan_from(
            &plan,
            &reg,
            &drifted,
            &power,
            &ResolveOptions::default(),
        )
        .unwrap();
        assert!(out.feasible);
        assert!(
            out.plan.predicted_mse <= plan.budget_abs + 1e-9,
            "re-plan must pull the served MSE back inside the budget"
        );
        assert_eq!(out.plan.generation, 1);
        assert_eq!(out.plan.drift_delta_vth, 0.015);
        // Quality costs energy: the re-plan can only move neurons up-ladder.
        assert!(out.plan.energy_saving <= plan.energy_saving + 1e-12);
        assert!(out.plan.energy_saving > 0.0, "saving must survive the re-plan");

        // Warm-start is never better than a cold re-solve (the cold ILP is
        // optimal) and both respect the budget.
        let (es, fan_in, _, _) = synthetic_problem();
        let cold = AssignmentProblem::build(
            &es,
            &fan_in,
            drifted.registry(),
            &power,
            plan.budget_abs,
        )
        .solve(Solver::Ilp)
        .unwrap();
        assert!(cold.predicted_mse <= plan.budget_abs + 1e-9);
        assert!(out.plan.energy >= cold.energy - 1e-9);
    }

    #[test]
    fn resolve_from_chains_generations_through_drift_provenance() {
        let (plan, reg, power) = cold_plan(2000.0);
        let opts = ResolveOptions::default();
        let gen1 = resolve_plan_from(&plan, &reg, &reg.drifted(0.008), &power, &opts)
            .unwrap()
            .plan;
        assert_eq!((gen1.generation, gen1.drift_delta_vth), (1, 0.008));
        // Re-planning the re-plan reconstructs gen1's registry from its own
        // provenance — and at unchanged drift the second hop is a no-op.
        let again = resolve_plan_from(&gen1, &reg, &reg.drifted(0.008), &power, &opts).unwrap();
        assert_eq!(again.plan.level, gen1.level, "same drift ⇒ same assignment");
        assert_eq!(again.resolved, 0, "unchanged drift must freeze everything");
        assert_eq!(again.plan.generation, 2);
        let gen2 = resolve_plan_from(&gen1, &reg, &reg.drifted(0.02), &power, &opts)
            .unwrap()
            .plan;
        assert_eq!((gen2.generation, gen2.drift_delta_vth), (2, 0.02));
        assert!(gen2.predicted_mse <= gen2.budget_abs + 1e-9);
    }

    #[test]
    fn resolve_from_flags_quality_end_of_life() {
        // An exact (zero-budget) plan past the guard band cannot be made
        // feasible: the outcome pins all-nominal and reports it.
        let (es, fan_in, reg, power) = synthetic_problem();
        let (_, exact) = solve_one(
            &ExperimentConfig::smoke(),
            "deadbeefdeadbeef",
            &es,
            &fan_in,
            &reg,
            &power,
            1.0,
            0.0,
            Solver::Ilp,
        )
        .unwrap();
        let tech = reg.ladder.tech;
        let crit = crate::aging::BtiModel::default().critical_delta_vth(&tech, tech.v_nominal);
        let out = resolve_plan_from(
            &exact,
            &reg,
            &reg.drifted(crit * 1.5),
            &power,
            &ResolveOptions::default(),
        )
        .unwrap();
        assert!(!out.feasible, "past the guard band the exact budget is unservable");
        assert!(out.plan.level.iter().all(|&l| l == 3), "EOL pins all-nominal");
        assert!(out.plan.predicted_mse > 0.0, "aged nominal is no longer error-free");
    }

    #[test]
    fn es_cache_is_fingerprint_guarded() {
        let dir = std::env::temp_dir().join(format!("xtpu_es_cache_{}", std::process::id()));
        let path = dir.join("es.json");
        save_es_cache(&path, "fp_a", &[1.0, 2.0, 3.0]);
        assert_eq!(load_es_cache(&path, "fp_a", 3), Some(vec![1.0, 2.0, 3.0]));
        assert_eq!(load_es_cache(&path, "fp_b", 3), None, "stale fingerprint");
        assert_eq!(load_es_cache(&path, "fp_a", 4), None, "wrong neuron count");
        std::fs::remove_dir_all(&dir).ok();
    }
}
