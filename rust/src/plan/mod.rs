//! The deployable voltage-plan artifact and the staged offline planner.
//!
//! The paper's contribution is an *offline* algorithm (Fig 4): statistical
//! error modeling + ILP fix per-neuron voltages **before** deployment, and
//! the X-TPU then serves millions of requests from the pre-solved
//! assignment (the voltage-selection bits live next to the weights, Fig 7).
//! This module makes that split explicit:
//!
//! - [`VoltagePlan`] — the serializable artifact one offline solve
//!   produces: per-neuron voltage-level indices, the ES vector and voltage
//!   ladder they were solved against, predicted MSE / energy saving, and
//!   provenance (model fingerprint + config hash + the full experiment
//!   config) so a serving process can verify it is deploying the plan
//!   against the network it was solved for. `to_json`/`from_json`
//!   round-trip bit-exactly via [`crate::util::json`].
//! - [`Planner`] — the staged offline solver: trained model → error-model
//!   registry → ES estimate → per-budget solve, each stage cached (in
//!   memory and, where the artifact is expensive, on disk), with
//!   [`Planner::solve_many`] solving all MSE_UB budgets in parallel on
//!   [`crate::util::threadpool`].
//!
//! The online side consumes plans without re-running any of it:
//! [`crate::server::Engine::from_plans`] derives its quality levels from
//! plan files (`xtpu plan` → `xtpu serve --plan`), and
//! [`crate::nn::quant::NoiseSpec::from_plan`] reconstructs the validated
//! noise spec from a plan + registry.

mod planner;

pub use planner::{
    baseline_mse_vs_onehot, characterize_registry, make_backend, make_backend_pool,
    measure_power_model, resolve_plan_from, train_model, BaselineStage, EsStage, Planner,
    ReplanOutcome, ResolveOptions, TrainedStage,
};
pub(crate) use planner::solve_one;

use anyhow::{Context, Result};

use crate::assign::{Solver, VoltageAssignment};
use crate::config::ExperimentConfig;
use crate::errormodel::{ErrorModelRegistry, PlanMode};
use crate::nn::model::Model;
use crate::nn::quant::{NoiseSpec, QuantizedModel};
use crate::util::json::Json;

/// One pre-solved, deployable <neuron → voltage level> policy: everything a
/// serving process needs to apply (and audit) a quality level, and nothing
/// that requires re-running the offline pipeline.
#[derive(Clone, Debug)]
pub struct VoltagePlan {
    /// Human-readable level name (`exact`, `mse_ub_200pct`, …).
    pub name: String,
    /// The MSE_UB this plan was solved for, as a fraction of nominal MSE.
    pub mse_ub_fraction: f64,
    /// Absolute MSE-increment budget (fraction × baseline MSE).
    pub budget_abs: f64,
    /// Nominal test MSE the fraction is relative to.
    pub baseline_mse: f64,
    /// Voltage-ladder level index per neuron (the Fig-7 selection bits).
    pub level: Vec<usize>,
    /// Fan-in (PE column height) per neuron — needed to recompose the
    /// column noise `N(k·μ_v, k·σ²_v)` from a registry.
    pub fan_in: Vec<usize>,
    /// Error sensitivity per neuron the solve used (audit trail).
    pub es: Vec<f64>,
    /// The voltage ladder (volts per level index, ascending, last=nominal).
    pub volts: Vec<f64>,
    /// Σ ES²·k·var(e)_v of the chosen assignment.
    pub predicted_mse: f64,
    /// Total energy of the assignment (normalized units).
    pub energy: f64,
    /// Fractional energy saving vs all-nominal.
    pub energy_saving: f64,
    /// Whether the solver proved optimality.
    pub optimal: bool,
    /// Solver that produced the assignment (`ilp` | `greedy` | `genetic`).
    pub solver: String,
    /// FNV-1a hash of the trained model's serialized form.
    pub model_fingerprint: String,
    /// Hash of the planning-relevant config fields (see [`config_hash`]).
    pub config_hash: String,
    /// The full experiment config, embedded so `xtpu serve --plan` can
    /// rebuild the (cached) model + registry without extra inputs.
    pub config: ExperimentConfig,
    /// Re-plan lineage: 0 for a fresh offline solve, incremented by every
    /// [`resolve_plan_from`] hop. Engines tag responses with the
    /// generation they served so operators can audit which era of the
    /// adaptive loop answered a request.
    pub generation: u64,
    /// The accrued ΔVth (V) this plan was (re-)solved under — 0 for fresh
    /// solves. Together with `generation` this is the drift provenance:
    /// `registry.drifted(drift_delta_vth)` reconstructs the exact error
    /// models the solve saw.
    pub drift_delta_vth: f64,
    /// Operating regime the assignment was priced under: "statistical"
    /// (tolerate) | "tedrop" (detect + drop). Determines which per-level
    /// column-moment formula reconstructs the plan's noise spec and served
    /// MSE (see [`PlanMode`]). Absent in pre-mode plan files and defaults
    /// to "statistical" on load.
    pub mode: String,
}

impl VoltagePlan {
    /// Assemble a plan from a solved assignment and its provenance.
    #[allow(clippy::too_many_arguments)]
    pub fn from_assignment(
        cfg: &ExperimentConfig,
        model_fingerprint: &str,
        es: &[f64],
        fan_in: &[usize],
        registry: &ErrorModelRegistry,
        fraction: f64,
        baseline_mse: f64,
        assignment: &VoltageAssignment,
        solver: Solver,
    ) -> Self {
        Self {
            name: budget_name(fraction),
            mse_ub_fraction: fraction,
            budget_abs: fraction * baseline_mse,
            baseline_mse,
            level: assignment.level.clone(),
            fan_in: fan_in.to_vec(),
            es: es.to_vec(),
            volts: registry.ladder.levels().iter().map(|l| l.volts).collect(),
            predicted_mse: assignment.predicted_mse,
            energy: assignment.energy,
            energy_saving: assignment.energy_saving,
            optimal: assignment.optimal,
            solver: solver_name(solver).to_string(),
            model_fingerprint: model_fingerprint.to_string(),
            config_hash: config_hash(cfg),
            config: cfg.clone(),
            generation: 0,
            drift_delta_vth: 0.0,
            mode: cfg.mode.clone(),
        }
    }

    /// The parsed operating regime of this plan. Plans built by
    /// [`Self::from_assignment`] or loaded via [`Self::from_json`] always
    /// carry a valid mode string; a hand-assembled invalid one falls back
    /// to the statistical regime rather than panicking mid-serve.
    pub fn plan_mode(&self) -> PlanMode {
        PlanMode::from_name(&self.mode).unwrap_or(PlanMode::Statistical)
    }

    /// Number of neurons this plan covers.
    pub fn neurons(&self) -> usize {
        self.level.len()
    }

    /// The noise spec this plan implies under `registry` (eqs 12–13) —
    /// exactly what the validation pass injected when the plan was solved.
    pub fn noise_spec(&self, registry: &ErrorModelRegistry) -> NoiseSpec {
        NoiseSpec::from_plan(self, registry)
    }

    /// Predicted served MSE of this plan under arbitrary per-level column
    /// variances: `Σ ES²·k·vars[level]` (eq. 29 re-priced). The **single**
    /// definition of the served-MSE observable — the warm-start re-planner
    /// prices candidate assignments with it, the fleet samples
    /// quality-vs-age curves with it (via drift-adjusted variances), and
    /// the L3i bench times it.
    pub fn served_mse(&self, vars: &[f64]) -> f64 {
        self.level
            .iter()
            .zip(self.es.iter().zip(&self.fan_in))
            .map(|(&l, (&e, &k))| e * e * k as f64 * vars[l.min(vars.len() - 1)])
            .sum()
    }

    /// Check this plan can be deployed on `quantized` under `registry`:
    /// neuron enumeration, ladder, and (when a fingerprint is supplied)
    /// model identity must all match.
    pub fn validate_against(
        &self,
        quantized: &QuantizedModel,
        registry: &ErrorModelRegistry,
    ) -> Result<()> {
        anyhow::ensure!(
            self.level.len() == quantized.num_neurons(),
            "plan '{}' covers {} neurons but model '{}' has {}",
            self.name,
            self.level.len(),
            quantized.name,
            quantized.num_neurons()
        );
        anyhow::ensure!(
            self.fan_in == quantized.neuron_fan_in,
            "plan '{}' fan-in vector disagrees with model '{}'",
            self.name,
            quantized.name
        );
        let ladder: Vec<f64> = registry.ladder.levels().iter().map(|l| l.volts).collect();
        anyhow::ensure!(
            self.volts.len() == ladder.len()
                && self.volts.iter().zip(&ladder).all(|(a, b)| (a - b).abs() < 1e-9),
            "plan '{}' voltage ladder {:?} does not match registry ladder {:?}",
            self.name,
            self.volts,
            ladder
        );
        for (&l, _) in self.level.iter().zip(&self.fan_in) {
            anyhow::ensure!(
                l < ladder.len(),
                "plan '{}' assigns level {} on a {}-level ladder",
                self.name,
                l,
                ladder.len()
            );
        }
        Ok(())
    }

    /// Check that two plans were produced by the same offline run (same
    /// model + same planning config) and can share one engine.
    pub fn check_compatible(&self, other: &VoltagePlan) -> Result<()> {
        anyhow::ensure!(
            self.model_fingerprint == other.model_fingerprint,
            "plans '{}' and '{}' were solved for different models ({} vs {})",
            self.name,
            other.name,
            self.model_fingerprint,
            other.model_fingerprint
        );
        anyhow::ensure!(
            self.config_hash == other.config_hash,
            "plans '{}' and '{}' carry different planning configs ({} vs {})",
            self.name,
            other.name,
            self.config_hash,
            other.config_hash
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("mse_ub_fraction", Json::Num(self.mse_ub_fraction)),
            ("budget_abs", Json::Num(self.budget_abs)),
            ("baseline_mse", Json::Num(self.baseline_mse)),
            (
                "level",
                Json::Arr(self.level.iter().map(|&l| Json::Num(l as f64)).collect()),
            ),
            (
                "fan_in",
                Json::Arr(self.fan_in.iter().map(|&k| Json::Num(k as f64)).collect()),
            ),
            ("es", Json::arr_f64(&self.es)),
            ("volts", Json::arr_f64(&self.volts)),
            ("predicted_mse", Json::Num(self.predicted_mse)),
            ("energy", Json::Num(self.energy)),
            ("energy_saving", Json::Num(self.energy_saving)),
            ("optimal", Json::Bool(self.optimal)),
            ("solver", Json::Str(self.solver.clone())),
            ("model_fingerprint", Json::Str(self.model_fingerprint.clone())),
            ("config_hash", Json::Str(self.config_hash.clone())),
            ("config", self.config.to_json()),
            ("generation", Json::Num(self.generation as f64)),
            ("drift_delta_vth", Json::Num(self.drift_delta_vth)),
            ("mode", Json::Str(self.mode.clone())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let level: Vec<usize> = j
            .get("level")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<std::result::Result<_, _>>()?;
        let fan_in: Vec<usize> = j
            .get("fan_in")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<std::result::Result<_, _>>()?;
        Ok(Self {
            name: j.get("name")?.as_str()?.to_string(),
            mse_ub_fraction: j.get("mse_ub_fraction")?.as_f64()?,
            budget_abs: j.get("budget_abs")?.as_f64()?,
            baseline_mse: j.get("baseline_mse")?.as_f64()?,
            level,
            fan_in,
            es: j.get("es")?.as_f64_vec()?,
            volts: j.get("volts")?.as_f64_vec()?,
            predicted_mse: j.get("predicted_mse")?.as_f64()?,
            energy: j.get("energy")?.as_f64()?,
            energy_saving: j.get("energy_saving")?.as_f64()?,
            optimal: j.get("optimal")?.as_bool()?,
            solver: j.get("solver")?.as_str()?.to_string(),
            model_fingerprint: j.get("model_fingerprint")?.as_str()?.to_string(),
            config_hash: j.get("config_hash")?.as_str()?.to_string(),
            config: ExperimentConfig::from_json(j.get("config")?)?,
            // Absent in pre-adaptive plan files: default to a fresh,
            // undrifted generation-0 artifact.
            generation: j.opt("generation").map(|v| v.as_u64()).transpose()?.unwrap_or(0),
            drift_delta_vth: j
                .opt("drift_delta_vth")
                .map(|v| v.as_f64())
                .transpose()?
                .unwrap_or(0.0),
            // Absent in pre-mode plan files: the tolerate regime was the
            // only one, so it is the compatible default.
            mode: {
                let mode = j
                    .opt("mode")
                    .map(|v| v.as_str().map(String::from))
                    .transpose()?
                    .unwrap_or_else(|| "statistical".to_string());
                PlanMode::from_name(&mode)?;
                mode
            },
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        crate::util::json::write_file(path, &self.to_json())
            .with_context(|| format!("writing plan '{}'", self.name))
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::from_json(&crate::util::json::read_file(path)?)
            .with_context(|| format!("loading plan {}", path.display()))
    }

    /// Canonical file name for this plan inside a plan directory.
    pub fn file_name(&self) -> String {
        format!("plan_{}.json", self.name)
    }
}

/// Canonical level name for an MSE_UB fraction: `exact` for 0, otherwise
/// `mse_ub_<pct>pct` with `.`/`-` made filename-safe.
pub fn budget_name(fraction: f64) -> String {
    if fraction == 0.0 {
        "exact".to_string()
    } else {
        let pct = format!("{}", fraction * 100.0).replace('.', "_").replace('-', "m");
        format!("mse_ub_{pct}pct")
    }
}

fn solver_name(s: Solver) -> &'static str {
    match s {
        Solver::Ilp => "ilp",
        Solver::Greedy => "greedy",
        Solver::Genetic => "genetic",
    }
}

/// FNV-1a 64-bit hash — stable, dependency-free content fingerprinting for
/// artifacts (not cryptographic; this is an integrity/identity check, not a
/// security boundary).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint a trained model: FNV-1a over its canonical JSON form
/// (deterministic key order + shortest-round-trip floats, so the same
/// weights always hash the same).
pub fn model_fingerprint(model: &Model) -> String {
    format!("{:016x}", fnv1a64(model.to_json().to_string().as_bytes()))
}

/// Hash of the *planning-relevant* config fields: the ones that change what
/// an offline solve produces (model identity, data sizes, ladder,
/// characterization depth, seed). Serving-side knobs (backend, artifacts
/// dir, validation runs, budget list) deliberately do not participate, so
/// plans solved for different budgets by the same run stay compatible.
pub fn config_hash(cfg: &ExperimentConfig) -> String {
    let j = Json::obj(vec![
        ("model", Json::Str(cfg.model.clone())),
        ("activation", Json::Str(cfg.activation.name().into())),
        ("train_samples", Json::Num(cfg.train_samples as f64)),
        ("test_samples", Json::Num(cfg.test_samples as f64)),
        ("epochs", Json::Num(cfg.epochs as f64)),
        ("voltages", Json::arr_f64(&cfg.voltages)),
        ("characterize_samples", Json::Num(cfg.characterize_samples as f64)),
        ("seed", Json::Num(cfg.seed as f64)),
    ]);
    format!("{:016x}", fnv1a64(j.to_string().as_bytes()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timing::voltage::VoltageLadder;
    use crate::util::checks::property;
    use crate::util::rng::Xoshiro256pp;

    fn fake_plan(rng: &mut Xoshiro256pp, neurons: usize) -> VoltagePlan {
        let ladder = VoltageLadder::paper_default();
        let reg = ErrorModelRegistry::synthetic(&ladder, &[3.0e6, 1.4e6, 2.0e5, 0.0]);
        let cfg = ExperimentConfig::smoke();
        let level: Vec<usize> = (0..neurons).map(|_| rng.index(4)).collect();
        let fan_in: Vec<usize> = (0..neurons).map(|_| 1 + rng.index(1024)).collect();
        let es: Vec<f64> = (0..neurons).map(|_| rng.gaussian(0.0, 1.0).abs()).collect();
        let assignment = VoltageAssignment {
            volts: level.iter().map(|&l| reg.ladder.level(l).volts).collect(),
            predicted_mse: rng.gaussian(10.0, 3.0).abs(),
            energy: rng.gaussian(1e6, 1e5).abs(),
            energy_saving: rng.gaussian(0.3, 0.1),
            optimal: true,
            nodes_explored: 0,
            solve_seconds: 0.0,
            level,
        };
        VoltagePlan::from_assignment(
            &cfg,
            "deadbeefdeadbeef",
            &es,
            &fan_in,
            &reg,
            2.0,
            0.042,
            &assignment,
            Solver::Ilp,
        )
    }

    #[test]
    fn json_roundtrip_is_bit_exact() {
        property("VoltagePlan JSON round-trips bit-exactly", 32, |rng, _| {
            let neurons = 1 + rng.index(64);
            let plan = fake_plan(rng, neurons);
            let back = VoltagePlan::from_json(&plan.to_json()).unwrap();
            assert_eq!(plan.level, back.level, "indices");
            assert_eq!(plan.fan_in, back.fan_in);
            assert_eq!(plan.volts, back.volts, "ladder");
            assert_eq!(plan.es, back.es);
            assert_eq!(plan.name, back.name, "metadata");
            assert_eq!(plan.mse_ub_fraction, back.mse_ub_fraction);
            assert_eq!(plan.budget_abs, back.budget_abs);
            assert_eq!(plan.baseline_mse, back.baseline_mse);
            assert_eq!(plan.predicted_mse, back.predicted_mse);
            assert_eq!(plan.energy, back.energy);
            assert_eq!(plan.energy_saving, back.energy_saving);
            assert_eq!(plan.optimal, back.optimal);
            assert_eq!(plan.solver, back.solver);
            assert_eq!(plan.model_fingerprint, back.model_fingerprint);
            assert_eq!(plan.config_hash, back.config_hash);
            assert_eq!(plan.config.model, back.config.model);
            assert_eq!(plan.config.seed, back.config.seed);
            assert_eq!(plan.generation, back.generation);
            assert_eq!(plan.drift_delta_vth, back.drift_delta_vth);
            assert_eq!(plan.mode, back.mode);
            // And a second hop through text is byte-identical.
            assert_eq!(plan.to_json().to_string(), back.to_json().to_string());
        });
    }

    #[test]
    fn pre_adaptive_plan_files_still_load() {
        // A plan serialized before the adaptive loop existed carries no
        // generation / drift keys; loading must default them rather than
        // refuse the artifact.
        let mut rng = Xoshiro256pp::seeded(77);
        let plan = fake_plan(&mut rng, 5);
        let j = plan.to_json();
        let mut obj = j.as_obj().unwrap().clone();
        obj.remove("generation");
        obj.remove("drift_delta_vth");
        obj.remove("mode");
        let legacy = Json::Obj(obj);
        let back = VoltagePlan::from_json(&legacy).unwrap();
        assert_eq!(back.generation, 0);
        assert_eq!(back.drift_delta_vth, 0.0);
        assert_eq!(back.mode, "statistical");
        assert_eq!(back.plan_mode(), PlanMode::Statistical);
        assert_eq!(back.level, plan.level);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("xtpu_plan_test_{}", std::process::id()));
        let mut rng = Xoshiro256pp::seeded(7);
        let plan = fake_plan(&mut rng, 12);
        let path = dir.join(plan.file_name());
        plan.save(&path).unwrap();
        let back = VoltagePlan::load(&path).unwrap();
        assert_eq!(plan.level, back.level);
        assert_eq!(plan.es, back.es);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn budget_names_are_filename_safe() {
        assert_eq!(budget_name(0.0), "exact");
        assert_eq!(budget_name(2.0), "mse_ub_200pct");
        assert_eq!(budget_name(0.005), "mse_ub_0_5pct");
        for f in [0.001, 0.01, 0.1, 0.5, 1.0, 2.0, 10.0] {
            let n = budget_name(f);
            assert!(n.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'), "{n}");
        }
    }

    #[test]
    fn config_hash_tracks_planning_fields_only() {
        let a = ExperimentConfig::default();
        let mut b = a.clone();
        b.mse_ub_fractions = vec![0.5]; // serving-side: must not change hash
        b.validation_runs = 9;
        b.artifacts_dir = "elsewhere".into();
        b.backend = "exact".into();
        assert_eq!(config_hash(&a), config_hash(&b));
        let mut c = a.clone();
        c.seed ^= 1; // planning-side: must change hash
        assert_ne!(config_hash(&a), config_hash(&c));
        let mut d = a.clone();
        d.voltages = vec![0.55, 0.8];
        assert_ne!(config_hash(&a), config_hash(&d));
    }

    #[test]
    fn budget_name_edge_cases() {
        // The two ends of the budget axis: 0 % is the reserved "exact"
        // level; 100 % must not collide with it or produce decimals.
        assert_eq!(budget_name(0.0), "exact");
        assert_eq!(budget_name(1.0), "mse_ub_100pct");
        // Fractions below 1 % stay filename-safe (no '.'), and a negative
        // fraction (nonsensical but representable) maps '-' to 'm' rather
        // than producing an invalid file name.
        assert_eq!(budget_name(0.0001), "mse_ub_0_01pct");
        assert_eq!(budget_name(-0.5), "mse_ub_m50pct");
        // Round-trip property: distinct budgets never alias.
        let budgets = [0.0, 0.0001, 0.005, 0.01, 0.1, 0.5, 1.0, 2.0, 10.0];
        let names: std::collections::BTreeSet<String> =
            budgets.iter().map(|&f| budget_name(f)).collect();
        assert_eq!(names.len(), budgets.len(), "budget names must be unique: {names:?}");
    }

    #[test]
    fn check_compatible_reports_what_differs() {
        let mut rng = Xoshiro256pp::seeded(21);
        let a = fake_plan(&mut rng, 6);
        // Fingerprint mismatch: the error must name both plans and both
        // fingerprints, so an operator can see *which* artifact is stale.
        let mut b = a.clone();
        b.name = "other_budget".into();
        b.model_fingerprint = "feedfacefeedface".into();
        let err = a.check_compatible(&b).unwrap_err().to_string();
        assert!(err.contains("different models"), "{err}");
        assert!(err.contains(&a.name) && err.contains("other_budget"), "{err}");
        assert!(err.contains("deadbeefdeadbeef") && err.contains("feedfacefeedface"), "{err}");
        // Config-hash mismatch is the second guard, with the same detail.
        let mut c = a.clone();
        c.config_hash = "0123456789abcdef".into();
        let err = a.check_compatible(&c).unwrap_err().to_string();
        assert!(err.contains("different planning configs"), "{err}");
        assert!(err.contains("0123456789abcdef"), "{err}");
    }

    #[test]
    fn validate_against_rejects_mismatched_ladder() {
        use crate::nn::layers::Activation;
        use crate::nn::model::fc_mnist;
        use crate::nn::quant::QuantizedModel;
        let mut rng = Xoshiro256pp::seeded(23);
        let model = fc_mnist(Activation::Relu, &mut rng);
        let calib = crate::nn::data::synth_mnist(16, 1).batch(&(0..16).collect::<Vec<_>>()).0;
        let q = QuantizedModel::quantize(&model, &calib);
        let ladder = VoltageLadder::paper_default();
        let reg = ErrorModelRegistry::synthetic(&ladder, &[3.0e6, 1.4e6, 2.0e5, 0.0]);
        let n = q.num_neurons();
        let mut plan = fake_plan(&mut rng, n);
        plan.fan_in = q.neuron_fan_in.clone();
        plan.validate_against(&q, &reg).unwrap();
        // A plan solved against a different ladder must be refused, and
        // the error must show both ladders.
        let mut wrong = plan.clone();
        wrong.volts = vec![0.55, 0.65, 0.75, 0.8];
        let err = wrong.validate_against(&q, &reg).unwrap_err().to_string();
        assert!(err.contains("voltage ladder"), "{err}");
        assert!(err.contains("0.55") && err.contains("0.5"), "{err}");
        // Ladder-length mismatch is the same refusal, not a panic.
        let mut short = plan.clone();
        short.volts = vec![0.5, 0.8];
        assert!(short.validate_against(&q, &reg).is_err());
        // Level index out of ladder range is caught per neuron.
        let mut oob = plan.clone();
        oob.level[0] = 4;
        let err = oob.validate_against(&q, &reg).unwrap_err().to_string();
        assert!(err.contains("assigns level 4"), "{err}");
    }

    #[test]
    fn fnv_is_stable() {
        // Pinned reference values: artifacts hashed on one machine must
        // verify on another.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn incompatible_plans_are_rejected() {
        let mut rng = Xoshiro256pp::seeded(9);
        let a = fake_plan(&mut rng, 8);
        let mut b = a.clone();
        b.model_fingerprint = "0000000000000000".into();
        assert!(a.check_compatible(&a).is_ok());
        assert!(a.check_compatible(&b).is_err());
    }
}
