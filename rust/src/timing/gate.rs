//! Gate-level netlist intermediate representation.
//!
//! The paper characterizes a synthesized 15-nm FinFET processing element
//! (Synopsys DC netlist + SDF, simulated in ModelSim). Offline we carry our
//! own structural netlists: a flat vector of two-input gates in topological
//! order (builders may only reference already-created signals, so the order
//! is correct by construction), which makes both functional evaluation and
//! timing propagation a single linear pass — fast enough for the 10^6-vector
//! Monte-Carlo characterization the paper performs.

/// Signal id: index into the netlist's gate vector.
pub type SignalId = u32;

/// Two-input gate vocabulary (plus sources). `a`/`b` are fanin signal ids;
/// unary gates use only `a`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GateKind {
    /// Primary input (value set externally).
    Input,
    /// Constant 0 / 1 sources (used for Baugh-Wooley correction bits).
    Const0,
    Const1,
    Not,
    Buf,
    And2,
    Or2,
    Nand2,
    Nor2,
    Xor2,
    Xnor2,
}

impl GateKind {
    /// Nominal propagation delay in normalized delay units (≈ FO4-ish
    /// ratios for a generic standard-cell library; absolute scale cancels
    /// out because the clock period is derived from the same numbers).
    pub fn base_delay(self) -> f32 {
        match self {
            GateKind::Input | GateKind::Const0 | GateKind::Const1 => 0.0,
            GateKind::Not => 0.6,
            GateKind::Buf => 0.7,
            GateKind::Nand2 => 1.0,
            GateKind::Nor2 => 1.1,
            GateKind::And2 => 1.4,
            GateKind::Or2 => 1.5,
            GateKind::Xor2 => 1.8,
            GateKind::Xnor2 => 1.8,
        }
    }

    /// Relative switching energy per output toggle (normalized to NAND2 = 1;
    /// roughly proportional to cell input capacitance + internal cap).
    pub fn toggle_energy(self) -> f32 {
        match self {
            GateKind::Input | GateKind::Const0 | GateKind::Const1 => 0.0,
            GateKind::Not => 0.6,
            GateKind::Buf => 0.8,
            GateKind::Nand2 => 1.0,
            GateKind::Nor2 => 1.0,
            GateKind::And2 => 1.3,
            GateKind::Or2 => 1.3,
            GateKind::Xor2 => 2.2,
            GateKind::Xnor2 => 2.2,
        }
    }

    /// Relative leakage power (normalized to NAND2 = 1).
    pub fn leakage(self) -> f32 {
        match self {
            GateKind::Input | GateKind::Const0 | GateKind::Const1 => 0.0,
            GateKind::Not => 0.5,
            GateKind::Buf => 0.9,
            GateKind::Nand2 => 1.0,
            GateKind::Nor2 => 1.0,
            GateKind::And2 => 1.4,
            GateKind::Or2 => 1.4,
            GateKind::Xor2 => 2.5,
            GateKind::Xnor2 => 2.5,
        }
    }

    pub fn is_source(self) -> bool {
        matches!(self, GateKind::Input | GateKind::Const0 | GateKind::Const1)
    }

    pub fn is_unary(self) -> bool {
        matches!(self, GateKind::Not | GateKind::Buf)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct Gate {
    pub kind: GateKind,
    pub a: SignalId,
    pub b: SignalId,
}

/// A combinational netlist with named primary inputs and outputs.
///
/// Invariant: for every gate `g` at index `i`, `g.a < i && g.b < i` (unless
/// `g` is a source). This makes the gate vector a valid topological order.
#[derive(Clone, Debug)]
pub struct Netlist {
    pub name: String,
    gates: Vec<Gate>,
    inputs: Vec<SignalId>,
    outputs: Vec<SignalId>,
}

impl Netlist {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), gates: Vec::new(), inputs: Vec::new(), outputs: Vec::new() }
    }

    fn push(&mut self, kind: GateKind, a: SignalId, b: SignalId) -> SignalId {
        let id = self.gates.len() as SignalId;
        if !kind.is_source() {
            assert!(a < id, "fanin a={a} must precede gate {id}");
            assert!(kind.is_unary() || b < id, "fanin b={b} must precede gate {id}");
        }
        self.gates.push(Gate { kind, a, b });
        id
    }

    // --- construction API --------------------------------------------------

    pub fn input(&mut self) -> SignalId {
        let id = self.push(GateKind::Input, 0, 0);
        self.inputs.push(id);
        id
    }

    pub fn const0(&mut self) -> SignalId {
        self.push(GateKind::Const0, 0, 0)
    }

    pub fn const1(&mut self) -> SignalId {
        self.push(GateKind::Const1, 0, 0)
    }

    pub fn not(&mut self, a: SignalId) -> SignalId {
        self.push(GateKind::Not, a, a)
    }

    pub fn buf(&mut self, a: SignalId) -> SignalId {
        self.push(GateKind::Buf, a, a)
    }

    pub fn and2(&mut self, a: SignalId, b: SignalId) -> SignalId {
        self.push(GateKind::And2, a, b)
    }

    pub fn or2(&mut self, a: SignalId, b: SignalId) -> SignalId {
        self.push(GateKind::Or2, a, b)
    }

    pub fn nand2(&mut self, a: SignalId, b: SignalId) -> SignalId {
        self.push(GateKind::Nand2, a, b)
    }

    pub fn nor2(&mut self, a: SignalId, b: SignalId) -> SignalId {
        self.push(GateKind::Nor2, a, b)
    }

    pub fn xor2(&mut self, a: SignalId, b: SignalId) -> SignalId {
        self.push(GateKind::Xor2, a, b)
    }

    pub fn xnor2(&mut self, a: SignalId, b: SignalId) -> SignalId {
        self.push(GateKind::Xnor2, a, b)
    }

    /// Mark an existing signal as a primary output (LSB-first convention for
    /// buses).
    pub fn mark_output(&mut self, id: SignalId) {
        assert!((id as usize) < self.gates.len());
        self.outputs.push(id);
    }

    // --- accessors ----------------------------------------------------------

    pub fn gates(&self) -> &[Gate] {
        &self.gates
    }

    pub fn num_gates(&self) -> usize {
        self.gates.len()
    }

    /// Count of logic gates (excluding sources) — the "cell count" a
    /// synthesis report would show.
    pub fn num_cells(&self) -> usize {
        self.gates.iter().filter(|g| !g.kind.is_source()).count()
    }

    pub fn inputs(&self) -> &[SignalId] {
        &self.inputs
    }

    pub fn outputs(&self) -> &[SignalId] {
        &self.outputs
    }

    // --- evaluation ----------------------------------------------------------

    /// Evaluate combinationally. `input_values[i]` corresponds to
    /// `inputs()[i]`; `values` is scratch of length `num_gates()`.
    /// Output values land in `values[outputs()[j]]`.
    pub fn eval_into(&self, input_values: &[bool], values: &mut [u8]) {
        assert_eq!(input_values.len(), self.inputs.len());
        assert_eq!(values.len(), self.gates.len());
        let mut next_input = 0;
        for (i, g) in self.gates.iter().enumerate() {
            let v = match g.kind {
                GateKind::Input => {
                    let v = input_values[next_input] as u8;
                    next_input += 1;
                    v
                }
                GateKind::Const0 => 0,
                GateKind::Const1 => 1,
                GateKind::Not => 1 - values[g.a as usize],
                GateKind::Buf => values[g.a as usize],
                GateKind::And2 => values[g.a as usize] & values[g.b as usize],
                GateKind::Or2 => values[g.a as usize] | values[g.b as usize],
                GateKind::Nand2 => 1 - (values[g.a as usize] & values[g.b as usize]),
                GateKind::Nor2 => 1 - (values[g.a as usize] | values[g.b as usize]),
                GateKind::Xor2 => values[g.a as usize] ^ values[g.b as usize],
                GateKind::Xnor2 => 1 - (values[g.a as usize] ^ values[g.b as usize]),
            };
            values[i] = v;
        }
    }

    /// Convenience: evaluate and return output bits (LSB-first).
    pub fn eval(&self, input_values: &[bool]) -> Vec<bool> {
        let mut values = vec![0u8; self.gates.len()];
        self.eval_into(input_values, &mut values);
        self.outputs.iter().map(|&o| values[o as usize] != 0).collect()
    }

    /// Evaluate with integer-packed input/output buses (helper for tests and
    /// oracles). `in_widths` gives the bit width of each logical input bus in
    /// the order the inputs were created (LSB first within a bus).
    pub fn eval_bus(&self, operands: &[(u64, usize)]) -> u64 {
        let mut bits = Vec::with_capacity(self.inputs.len());
        for &(val, width) in operands {
            for k in 0..width {
                bits.push((val >> k) & 1 == 1);
            }
        }
        let out = self.eval(&bits);
        let mut acc = 0u64;
        for (k, &b) in out.iter().enumerate() {
            if b {
                acc |= 1 << k;
            }
        }
        acc
    }

    /// Structural sanity check (used by property tests).
    pub fn validate(&self) -> Result<(), String> {
        for (i, g) in self.gates.iter().enumerate() {
            if !g.kind.is_source() {
                if g.a as usize >= i {
                    return Err(format!("gate {i}: fanin a={} not topological", g.a));
                }
                if !g.kind.is_unary() && g.b as usize >= i {
                    return Err(format!("gate {i}: fanin b={} not topological", g.b));
                }
            }
        }
        for &o in &self.outputs {
            if o as usize >= self.gates.len() {
                return Err(format!("output {o} out of range"));
            }
        }
        Ok(())
    }
}

/// A small helper representing a bus (vector of signals, LSB first).
#[derive(Clone, Debug)]
pub struct Bus(pub Vec<SignalId>);

impl Bus {
    /// Create `width` fresh primary inputs.
    pub fn inputs(n: &mut Netlist, width: usize) -> Bus {
        Bus((0..width).map(|_| n.input()).collect())
    }

    pub fn width(&self) -> usize {
        self.0.len()
    }

    pub fn bit(&self, i: usize) -> SignalId {
        self.0[i]
    }

    /// Mark every bit as a primary output.
    pub fn mark_outputs(&self, n: &mut Netlist) {
        for &b in &self.0 {
            n.mark_output(b);
        }
    }
}

/// Decode an LSB-first bool slice as a two's-complement integer.
pub fn bits_to_i64(bits: &[bool]) -> i64 {
    let mut v: i64 = 0;
    for (i, &b) in bits.iter().enumerate() {
        if b {
            v |= 1 << i;
        }
    }
    // Sign-extend.
    if bits.len() < 64 && bits[bits.len() - 1] {
        v -= 1 << bits.len();
    }
    v
}

/// Encode an integer into `width` LSB-first bits (two's complement).
pub fn i64_to_bits(v: i64, width: usize) -> Vec<bool> {
    (0..width).map(|i| (v >> i) & 1 == 1).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_truth_tables() {
        let mut n = Netlist::new("truth");
        let a = n.input();
        let b = n.input();
        let and = n.and2(a, b);
        let or = n.or2(a, b);
        let nand = n.nand2(a, b);
        let nor = n.nor2(a, b);
        let xor = n.xor2(a, b);
        let xnor = n.xnor2(a, b);
        let not = n.not(a);
        let buf = n.buf(b);
        for &s in &[and, or, nand, nor, xor, xnor, not, buf] {
            n.mark_output(s);
        }
        let truth = |va: bool, vb: bool| n.eval(&[va, vb]);
        for va in [false, true] {
            for vb in [false, true] {
                let out = truth(va, vb);
                assert_eq!(out[0], va && vb);
                assert_eq!(out[1], va || vb);
                assert_eq!(out[2], !(va && vb));
                assert_eq!(out[3], !(va || vb));
                assert_eq!(out[4], va ^ vb);
                assert_eq!(out[5], !(va ^ vb));
                assert_eq!(out[6], !va);
                assert_eq!(out[7], vb);
            }
        }
    }

    #[test]
    fn constants() {
        let mut n = Netlist::new("const");
        let c0 = n.const0();
        let c1 = n.const1();
        let x = n.xor2(c0, c1);
        n.mark_output(x);
        assert_eq!(n.eval(&[]), vec![true]);
    }

    #[test]
    #[should_panic(expected = "must precede")]
    fn forward_reference_panics() {
        let mut n = Netlist::new("bad");
        let a = n.input();
        // Manually forge a forward reference through the public API is
        // impossible; emulate by referencing a not-yet-created id.
        n.and2(a, 99);
    }

    #[test]
    fn validate_ok_and_bus_roundtrip() {
        let mut n = Netlist::new("bus");
        let a = Bus::inputs(&mut n, 4);
        let b = Bus::inputs(&mut n, 4);
        // Bitwise AND bus.
        let mut outs = Vec::new();
        for i in 0..4 {
            outs.push(n.and2(a.bit(i), b.bit(i)));
        }
        for &o in &outs {
            n.mark_output(o);
        }
        n.validate().unwrap();
        assert_eq!(n.eval_bus(&[(0b1100, 4), (0b1010, 4)]), 0b1000);
    }

    #[test]
    fn bits_int_roundtrip() {
        for v in [-128i64, -1, 0, 1, 77, 127] {
            assert_eq!(bits_to_i64(&i64_to_bits(v, 8)), v);
        }
        for v in [-16256i64, -1, 0, 16384] {
            assert_eq!(bits_to_i64(&i64_to_bits(v, 16)), v);
        }
    }

    #[test]
    fn cell_count_excludes_sources() {
        let mut n = Netlist::new("cells");
        let a = n.input();
        let b = n.input();
        let c = n.and2(a, b);
        n.mark_output(c);
        assert_eq!(n.num_gates(), 3);
        assert_eq!(n.num_cells(), 1);
    }
}
