//! Structural circuit generators: adders, the Baugh-Wooley signed
//! multiplier (column-reduction / Dadda style), and the PE arithmetic
//! datapath (multiplier + accumulator adder) the paper's TPU uses.
//!
//! The X-TPU quantizes to int8 weights/activations with wide accumulators
//! (paper §IV.A), so the central circuit is the 8×8 two's-complement
//! multiplier — the component the paper applies VOS to.

use super::gate::{Bus, Netlist, SignalId};

/// Half adder: returns (sum, carry).
pub fn half_adder(n: &mut Netlist, a: SignalId, b: SignalId) -> (SignalId, SignalId) {
    let s = n.xor2(a, b);
    let c = n.and2(a, b);
    (s, c)
}

/// Full adder: returns (sum, carry). 5 gates, XOR-chain critical path.
pub fn full_adder(n: &mut Netlist, a: SignalId, b: SignalId, cin: SignalId) -> (SignalId, SignalId) {
    let axb = n.xor2(a, b);
    let s = n.xor2(axb, cin);
    let t1 = n.and2(a, b);
    let t2 = n.and2(axb, cin);
    let c = n.or2(t1, t2);
    (s, c)
}

/// Ripple-carry adder over two equal-width buses; returns sum bus of width
/// `w + 1` (final carry appended as MSB).
pub fn ripple_carry_adder(n: &mut Netlist, a: &Bus, b: &Bus) -> Bus {
    assert_eq!(a.width(), b.width());
    let mut sum = Vec::with_capacity(a.width() + 1);
    let (s0, mut carry) = half_adder(n, a.bit(0), b.bit(0));
    sum.push(s0);
    for i in 1..a.width() {
        let (s, c) = full_adder(n, a.bit(i), b.bit(i), carry);
        sum.push(s);
        carry = c;
    }
    sum.push(carry);
    Bus(sum)
}

/// Two's-complement ripple adder with both operands sign-extended by one bit
/// and the result truncated to `width` bits (wrap-around semantics), used
/// for the PE accumulator.
pub fn adder_mod(n: &mut Netlist, a: &Bus, b: &Bus, width: usize) -> Bus {
    assert_eq!(a.width(), width);
    assert_eq!(b.width(), width);
    let full = ripple_carry_adder(n, a, b);
    Bus(full.0[..width].to_vec())
}

/// Reduce a partial-product matrix (per-output-column signal lists) to a
/// final two-row form with half/full adders, then ripple-add. This is the
/// classic Dadda/Wallace column-compression scheme; the exact compression
/// order follows a simple greedy (take three, emit sum+carry), which yields
/// the same depth class as Dadda for these sizes.
///
/// `columns[k]` holds all signals of weight 2^k. Returns the sum bus of
/// width `columns.len()` (extra carries beyond the top column are dropped —
/// callers arrange widths so that the result is exact or intentionally
/// modular).
pub fn reduce_columns(n: &mut Netlist, mut columns: Vec<Vec<SignalId>>) -> Bus {
    let width = columns.len();
    // Phase 1: compress until every column has ≤ 2 entries.
    loop {
        let mut busy = false;
        for k in 0..width {
            while columns[k].len() > 2 {
                busy = true;
                if columns[k].len() >= 3 {
                    let a = columns[k].pop().unwrap();
                    let b = columns[k].pop().unwrap();
                    let c = columns[k].pop().unwrap();
                    let (s, carry) = full_adder(n, a, b, c);
                    columns[k].push(s);
                    if k + 1 < width {
                        columns[k + 1].push(carry);
                    }
                }
            }
            // A column with exactly 2 entries is fine — the final adder
            // handles it.
        }
        if !busy {
            break;
        }
    }
    // Phase 2: final carry-propagate add of the two rows.
    let zero = n.const0();
    let mut row_a = Vec::with_capacity(width);
    let mut row_b = Vec::with_capacity(width);
    for k in 0..width {
        row_a.push(columns[k].first().copied().unwrap_or(zero));
        row_b.push(columns[k].get(1).copied().unwrap_or(zero));
    }
    let a = Bus(row_a);
    let b = Bus(row_b);
    adder_mod(n, &a, &b, width)
}

/// Baugh-Wooley 8×8 two's-complement multiplier producing the exact 16-bit
/// signed product. Partial-product matrix:
///
/// - `a_i·b_j`            for i<7, j<7 and for i=j=7
/// - `NOT(a_i·b_7)`       for i<7  (weight 2^{i+7})
/// - `NOT(a_7·b_j)`       for j<7  (weight 2^{j+7})
/// - correction constants +2^8 and +2^15
///
/// Verified exhaustively against `i8 * i8` in the tests.
pub fn baugh_wooley_8x8(name: &str) -> Netlist {
    let mut n = Netlist::new(name);
    let a = Bus::inputs(&mut n, 8);
    let b = Bus::inputs(&mut n, 8);
    let product = baugh_wooley_into(&mut n, &a, &b);
    product.mark_outputs(&mut n);
    n
}

/// Build the Baugh-Wooley multiplier inside an existing netlist (used by
/// the composite PE datapath). Returns the 16-bit product bus.
pub fn baugh_wooley_into(n: &mut Netlist, a: &Bus, b: &Bus) -> Bus {
    assert_eq!(a.width(), 8);
    assert_eq!(b.width(), 8);
    let mut columns: Vec<Vec<SignalId>> = vec![Vec::new(); 16];
    for i in 0..7 {
        for j in 0..7 {
            let pp = n.and2(a.bit(i), b.bit(j));
            columns[i + j].push(pp);
        }
    }
    // pp_{7,7} positive term.
    let pp77 = n.and2(a.bit(7), b.bit(7));
    columns[14].push(pp77);
    // Complemented cross terms.
    for i in 0..7 {
        let t = n.nand2(a.bit(i), b.bit(7));
        columns[i + 7].push(t);
    }
    for j in 0..7 {
        let t = n.nand2(a.bit(7), b.bit(j));
        columns[j + 7].push(t);
    }
    // Correction constants: +2^8 and +2^15.
    let one8 = n.const1();
    columns[8].push(one8);
    let one15 = n.const1();
    columns[15].push(one15);
    reduce_columns(n, columns)
}

/// The PE arithmetic datapath of the TPU (paper Fig 1a): an 8×8 signed
/// multiplier followed by the partial-sum accumulator adder.
///
/// Inputs (in creation order): activation[8], weight[8], psum_in[acc_width].
/// Outputs: psum_out[acc_width] = psum_in + sign_extend(a×w).
///
/// `mult_gate_range` / `adder_gate_range` let the power model attribute
/// toggles to the multiplier vs. the adder region — the paper's VOS is
/// applied to the *multiplier region only* (§IV.A).
pub struct PeDatapath {
    pub netlist: Netlist,
    /// Gate-index range belonging to the multiplier (approximate region).
    pub mult_gates: std::ops::Range<usize>,
    /// Gate-index range belonging to the accumulator adder (exact region).
    pub adder_gates: std::ops::Range<usize>,
    /// Product bit signals (the boundary crossing the level shifters).
    pub product: Bus,
    pub acc_width: usize,
}

pub fn pe_datapath(acc_width: usize) -> PeDatapath {
    assert!((17..=32).contains(&acc_width), "accumulator must cover the product range");
    let mut n = Netlist::new("pe_datapath");
    let act = Bus::inputs(&mut n, 8);
    let wgt = Bus::inputs(&mut n, 8);
    let psum = Bus::inputs(&mut n, acc_width);
    let mult_start = n.num_gates();
    let product = baugh_wooley_into(&mut n, &act, &wgt);
    let mult_end = n.num_gates();
    // Sign-extend the 16-bit product to acc_width (buffers replicate the MSB
    // through the level-shifter boundary).
    let mut ext = product.0.clone();
    let msb = product.bit(15);
    for _ in 16..acc_width {
        ext.push(n.buf(msb));
    }
    let ext = Bus(ext);
    let adder_start = n.num_gates();
    let out = adder_mod(&mut n, &psum, &ext, acc_width);
    let adder_end = n.num_gates();
    out.mark_outputs(&mut n);
    PeDatapath {
        netlist: n,
        mult_gates: mult_start..mult_end,
        adder_gates: adder_start..adder_end,
        product,
        acc_width,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timing::gate::bits_to_i64;
    use crate::util::checks::property;

    #[test]
    fn half_and_full_adder_truth() {
        let mut n = Netlist::new("ha_fa");
        let a = n.input();
        let b = n.input();
        let c = n.input();
        let (hs, hc) = half_adder(&mut n, a, b);
        let (fs, fc) = full_adder(&mut n, a, b, c);
        for &s in &[hs, hc, fs, fc] {
            n.mark_output(s);
        }
        for va in 0..2u8 {
            for vb in 0..2u8 {
                for vc in 0..2u8 {
                    let out = n.eval(&[va == 1, vb == 1, vc == 1]);
                    let h = va + vb;
                    let f = va + vb + vc;
                    assert_eq!(out[0] as u8, h & 1);
                    assert_eq!(out[1] as u8, h >> 1);
                    assert_eq!(out[2] as u8, f & 1);
                    assert_eq!(out[3] as u8, f >> 1);
                }
            }
        }
    }

    #[test]
    fn ripple_adder_exhaustive_6bit() {
        let mut n = Netlist::new("rca6");
        let a = Bus::inputs(&mut n, 6);
        let b = Bus::inputs(&mut n, 6);
        let sum = ripple_carry_adder(&mut n, &a, &b);
        sum.mark_outputs(&mut n);
        for x in 0..64u64 {
            for y in 0..64u64 {
                assert_eq!(n.eval_bus(&[(x, 6), (y, 6)]), x + y);
            }
        }
    }

    #[test]
    fn baugh_wooley_exhaustive_i8() {
        let n = baugh_wooley_8x8("bw8_test");
        n.validate().unwrap();
        // Full 65536-case exhaustive check against native i8 multiply.
        for a in -128i32..=127 {
            for b in -128i32..=127 {
                let bits = n.eval(&crate::timing::gate::i64_to_bits(
                    ((a as i64) & 0xFF) | ((((b as i64) & 0xFF) as i64) << 8),
                    16,
                ));
                let got = bits_to_i64(&bits);
                assert_eq!(got, (a * b) as i64, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn multiplier_size_is_plausible() {
        let n = baugh_wooley_8x8("bw8_size");
        // A synthesized 8×8 BW multiplier is a few hundred cells.
        assert!(n.num_cells() > 200 && n.num_cells() < 800, "cells={}", n.num_cells());
    }

    #[test]
    fn pe_datapath_accumulates() {
        let pe = pe_datapath(24);
        pe.netlist.validate().unwrap();
        property("pe accumulate matches i64 math", 200, |rng, _| {
            let a = rng.range_i64(-128, 127);
            let w = rng.range_i64(-128, 127);
            let p = rng.range_i64(-(1 << 20), 1 << 20);
            let packed: u64 = ((a as u64) & 0xFF)
                | (((w as u64) & 0xFF) << 8)
                | (((p as u64) & 0xFF_FFFF) << 16);
            let out = pe.netlist.eval(&crate::timing::gate::i64_to_bits(packed as i64, 40));
            let got = bits_to_i64(&out);
            let expect = (p + a * w) & ((1 << 24) - 1);
            let expect = if expect >= (1 << 23) { expect - (1 << 24) } else { expect };
            assert_eq!(got, expect, "a={a} w={w} p={p}");
        });
    }

    #[test]
    fn pe_regions_are_disjoint_and_ordered() {
        let pe = pe_datapath(24);
        assert!(pe.mult_gates.end <= pe.adder_gates.start);
        assert!(!pe.mult_gates.is_empty());
        assert!(!pe.adder_gates.is_empty());
        // Multiplier should dominate the cell count (paper Fig 1b: ~56 % of
        // PE power is the multiplier).
        let mult_cells = pe.mult_gates.len();
        let adder_cells = pe.adder_gates.len();
        assert!(mult_cells > 2 * adder_cells, "mult={mult_cells} adder={adder_cells}");
    }

    #[test]
    fn reduce_columns_handles_empty_columns() {
        let mut n = Netlist::new("sparse");
        let a = n.input();
        let b = n.input();
        let mut cols: Vec<Vec<SignalId>> = vec![Vec::new(); 4];
        cols[0].push(a);
        cols[2].push(b);
        let out = reduce_columns(&mut n, cols);
        out.mark_outputs(&mut n);
        // value = a + 4b
        assert_eq!(n.eval_bus(&[(1, 1), (1, 1)]), 0b101);
        assert_eq!(n.eval_bus(&[(0, 1), (1, 1)]), 0b100);
    }
}
