//! Gate-level timing substrate: netlist IR, circuit generators, static
//! timing, and dynamic voltage-overscaling error simulation.
//!
//! Together these replace the paper's commercial toolchain (Synopsys DC +
//! Cadence Liberate libraries + ModelSim SDF simulation, §V.A) with a
//! self-contained model that reproduces the phenomenology the framework
//! consumes: timing errors that appear when the supply voltage is scaled
//! below nominal at fixed clock, grow with the overscaling depth, hit the
//! MSB-side product bits hardest, and are ≈ zero-mean with voltage-
//! dependent variance (Table 2 / Fig 9).

pub mod circuits;
pub mod gate;
pub mod sta;
pub mod voltage;
pub mod vos;

pub use circuits::{baugh_wooley_8x8, pe_datapath, PeDatapath};
pub use gate::{Bus, Gate, GateKind, Netlist, SignalId};
pub use sta::{clock_period, static_timing, ChipInstance, StaReport};
pub use voltage::{Technology, VoltageLadder, VoltageLevel};
pub use vos::{StepStats, VosSimulator};
