//! Voltage levels and the alpha-power-law delay model (paper §III.B, eq. 3).
//!
//! The paper characterizes the PE at 15-nm FinFET with a nominal supply of
//! 0.8 V and overscaled levels 0.7/0.6/0.5 V (and 0.4 V in the Fig-1 intro
//! experiment). Delay follows `d ∝ V_DD / (V_DD − V_th)^α` with α = 1.3 for
//! sub-20-nm nodes; energy scales as `E ∝ V_DD²` (paper §IV.D).

/// Technology constants for the simulated 15-nm FinFET-class node.
#[derive(Clone, Copy, Debug)]
pub struct Technology {
    /// Nominal supply voltage (V).
    pub v_nominal: f64,
    /// Threshold voltage (V).
    pub v_th: f64,
    /// Alpha-power-law exponent (1.3 for sub-20-nm, paper §III.B).
    pub alpha: f64,
    /// Clock guard band applied on top of the nominal critical path.
    pub clock_guard: f64,
    /// Std-dev of the per-gate process-variation delay factor.
    pub process_sigma: f64,
}

impl Default for Technology {
    fn default() -> Self {
        Self {
            v_nominal: 0.8,
            v_th: 0.35,
            alpha: 1.3,
            clock_guard: 0.08,
            process_sigma: 0.05,
        }
    }
}

impl Technology {
    /// Raw alpha-power-law factor `V / (V − Vth)^α`. Units cancel in ratios.
    pub fn alpha_power(&self, v: f64) -> f64 {
        assert!(v > self.v_th, "supply {v} V must exceed Vth {} V", self.v_th);
        v / (v - self.v_th).powf(self.alpha)
    }

    /// Delay scale factor at supply `v`, normalized to 1.0 at nominal.
    /// Values > 1 mean slower gates (paper eq. 3).
    pub fn delay_scale(&self, v: f64) -> f64 {
        self.alpha_power(v) / self.alpha_power(self.v_nominal)
    }

    /// Delay scale with an aged threshold voltage (paper §V.C combines
    /// eq. 1's ΔVth with eq. 3).
    pub fn delay_scale_aged(&self, v: f64, delta_vth: f64) -> f64 {
        let vth = self.v_th + delta_vth;
        assert!(v > vth, "supply {v} V must exceed aged Vth {vth} V");
        (v / (v - vth).powf(self.alpha)) / self.alpha_power(self.v_nominal)
    }

    /// Dynamic-energy scale factor `（V/V_nom)²` (paper §IV.D: E ∝ V²).
    pub fn energy_scale(&self, v: f64) -> f64 {
        (v / self.v_nominal).powi(2)
    }

    /// The *effective* supply voltage of an aged device: the fresh-device
    /// voltage whose alpha-power delay equals the aged delay at supply `v`
    /// with threshold shift `delta_vth`, i.e. the unique `v_eff ≤ v` with
    ///
    /// ```text
    /// alpha_power(v_eff) = v / (v − (v_th + ΔVth))^α
    /// ```
    ///
    /// This is the bridge the drift-aware error models ride on: an aged PE
    /// at ladder voltage `v` mis-times like a fresh PE at `v_eff`, so its
    /// error statistics can be re-read off the fresh characterization
    /// curve instead of re-running gate-level Monte Carlo. Exact at
    /// `delta_vth == 0` (returns `v` bit-for-bit). Valid while the aged
    /// overdrive stays positive: `delta_vth < v − v_th` (asserted).
    pub fn effective_voltage(&self, v: f64, delta_vth: f64) -> f64 {
        assert!(delta_vth >= 0.0, "negative threshold drift");
        if delta_vth == 0.0 {
            return v;
        }
        assert!(
            v - (self.v_th + delta_vth) > 1e-9,
            "drift {delta_vth} V leaves no overdrive at {v} V (validity: ΔVth < v − Vth)"
        );
        let target = v / (v - (self.v_th + delta_vth)).powf(self.alpha);
        self.invert_alpha_power(target, v)
    }

    /// The unique `v ∈ (v_th, hi)` with `alpha_power(v) == target`, by
    /// bisection — well-defined because alpha_power is strictly decreasing
    /// on `(v_th, ∞)` for α > 1. Shared inverse of
    /// [`Self::effective_voltage`] and [`Self::error_onset_voltage`], so
    /// the drift model and the onset anchor can never diverge on
    /// convergence behavior.
    fn invert_alpha_power(&self, target: f64, hi: f64) -> f64 {
        let (mut lo, mut hi) = (self.v_th + 1e-9, hi);
        for _ in 0..80 {
            let mid = 0.5 * (lo + hi);
            if self.alpha_power(mid) > target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// The voltage below which timing errors begin on a fresh device: the
    /// supply whose delay stretch exactly consumes the clock guard band
    /// (`alpha_power(v) = (1 + clock_guard) · alpha_power(v_nominal)`).
    /// Above it the shipped clock still meets timing and the error model
    /// is exactly zero; below it late bits start being captured. Dual of
    /// [`crate::aging::BtiModel::critical_delta_vth`]: an aged nominal
    /// level crosses this onset exactly when ΔVth crosses the critical
    /// drift.
    pub fn error_onset_voltage(&self) -> f64 {
        let target = (1.0 + self.clock_guard) * self.alpha_power(self.v_nominal);
        self.invert_alpha_power(target, self.v_nominal)
    }
}

/// A discrete operating voltage level of the X-TPU.
///
/// `index` is the value encoded in the weight memory's voltage-selection
/// bits (0 = lowest voltage, last = nominal/exact), matching Fig 7.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VoltageLevel {
    pub index: usize,
    pub volts: f64,
}

impl VoltageLevel {
    pub fn new(index: usize, volts: f64) -> Self {
        Self { index, volts }
    }

    pub fn is_nominal(&self, tech: &Technology) -> bool {
        (self.volts - tech.v_nominal).abs() < 1e-9
    }
}

/// The voltage ladder available to the X-TPU (sorted ascending; the last
/// entry must be the nominal voltage). The paper uses {0.5, 0.6, 0.7, 0.8}.
#[derive(Clone, Debug)]
pub struct VoltageLadder {
    levels: Vec<VoltageLevel>,
    pub tech: Technology,
}

impl VoltageLadder {
    pub fn new(volts: &[f64], tech: Technology) -> Self {
        assert!(!volts.is_empty());
        let mut sorted = volts.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(
            (sorted.last().unwrap() - tech.v_nominal).abs() < 1e-9,
            "ladder must top out at the nominal voltage"
        );
        for w in sorted.windows(2) {
            assert!(w[1] - w[0] > 1e-9, "duplicate voltage level {}", w[0]);
        }
        let levels =
            sorted.iter().enumerate().map(|(i, &v)| VoltageLevel::new(i, v)).collect();
        Self { levels, tech }
    }

    /// The paper's ladder: 0.5/0.6/0.7 V overscaled + 0.8 V nominal.
    pub fn paper_default() -> Self {
        Self::new(&[0.5, 0.6, 0.7, 0.8], Technology::default())
    }

    pub fn levels(&self) -> &[VoltageLevel] {
        &self.levels
    }

    pub fn len(&self) -> usize {
        self.levels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.levels.is_empty()
    }

    pub fn nominal(&self) -> VoltageLevel {
        *self.levels.last().unwrap()
    }

    pub fn level(&self, index: usize) -> VoltageLevel {
        self.levels[index]
    }

    /// Number of voltage-selection bits appended to each weight word
    /// (paper §IV.A: ⌈log2(v_n)⌉; 2 bits for 4 levels).
    pub fn selection_bits(&self) -> usize {
        (usize::BITS - (self.levels.len() - 1).leading_zeros()) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::checks::assert_close;

    #[test]
    fn delay_scale_is_one_at_nominal_and_grows_below() {
        let t = Technology::default();
        assert_close(t.delay_scale(0.8), 1.0, 1e-12);
        let s7 = t.delay_scale(0.7);
        let s6 = t.delay_scale(0.6);
        let s5 = t.delay_scale(0.5);
        assert!(s7 > 1.0 && s6 > s7 && s5 > s6, "{s7} {s6} {s5}");
        // Sanity against hand-computed alpha-power values.
        assert_close(s7, 1.214, 0.01);
        assert_close(s6, 1.613, 0.01);
        assert_close(s5, 2.609, 0.01);
    }

    #[test]
    fn energy_scale_quadratic() {
        let t = Technology::default();
        assert_close(t.energy_scale(0.8), 1.0, 1e-12);
        assert_close(t.energy_scale(0.4), 0.25, 1e-12);
        // Paper Fig 1: 0.4 V cuts PE power by ~79 % — V² alone gives 75 %,
        // the remainder comes from reduced short-circuit/leakage; our model
        // attributes V² to dynamic and V to leakage (see power module).
        assert!(1.0 - t.energy_scale(0.4) > 0.7);
    }

    #[test]
    #[should_panic(expected = "must exceed Vth")]
    fn below_threshold_panics() {
        Technology::default().alpha_power(0.3);
    }

    #[test]
    fn aged_delay_slower() {
        let t = Technology::default();
        assert!(t.delay_scale_aged(0.8, 0.05) > t.delay_scale(0.8));
        assert!(t.delay_scale_aged(0.5, 0.01) > t.delay_scale(0.5));
    }

    #[test]
    fn ladder_ordering_and_bits() {
        let l = VoltageLadder::paper_default();
        assert_eq!(l.len(), 4);
        assert_eq!(l.selection_bits(), 2);
        assert_eq!(l.nominal().volts, 0.8);
        assert_eq!(l.level(0).volts, 0.5);
        assert!(l.level(0).index < l.level(3).index);
        assert!(l.level(3).is_nominal(&l.tech));
        assert!(!l.level(0).is_nominal(&l.tech));
        // 2 levels -> 1 bit; 3 levels -> 2 bits.
        let t = Technology::default();
        assert_eq!(VoltageLadder::new(&[0.6, 0.8], t).selection_bits(), 1);
        assert_eq!(VoltageLadder::new(&[0.5, 0.6, 0.8], t).selection_bits(), 2);
    }

    #[test]
    #[should_panic(expected = "top out at the nominal")]
    fn ladder_requires_nominal_top() {
        VoltageLadder::new(&[0.5, 0.6], Technology::default());
    }

    #[test]
    fn effective_voltage_inverts_aged_delay() {
        let t = Technology::default();
        // Exact at zero drift, strictly below v for positive drift.
        assert_eq!(t.effective_voltage(0.8, 0.0), 0.8);
        for v in [0.5, 0.6, 0.7, 0.8] {
            for dvth in [0.005, 0.01, 0.02] {
                let v_eff = t.effective_voltage(v, dvth);
                assert!(v_eff < v, "v_eff {v_eff} must drop below {v}");
                assert!(v_eff > t.v_th);
                // Defining property: fresh delay at v_eff = aged delay at v.
                assert_close(
                    t.alpha_power(v_eff),
                    v / (v - (t.v_th + dvth)).powf(t.alpha),
                    1e-9 * t.alpha_power(v_eff),
                );
            }
            // Monotone: more drift → lower effective voltage.
            assert!(t.effective_voltage(v, 0.02) < t.effective_voltage(v, 0.01));
        }
        // Low-overdrive levels shift further than ΔVth itself (the
        // alpha-power curve steepens toward Vth).
        assert!(0.5 - t.effective_voltage(0.5, 0.02) > 0.02);
    }

    #[test]
    #[should_panic(expected = "no overdrive")]
    fn effective_voltage_rejects_drift_past_overdrive() {
        Technology::default().effective_voltage(0.5, 0.2);
    }

    #[test]
    fn error_onset_sits_inside_the_guard_band() {
        let t = Technology::default();
        let v_on = t.error_onset_voltage();
        assert!(v_on < t.v_nominal && v_on > 0.7, "onset {v_on}");
        // Defining property: delay stretch at onset = 1 + guard band.
        assert_close(t.delay_scale(v_on), 1.0 + t.clock_guard, 1e-9);
        // Duality with the aging model: drifting the nominal level by the
        // critical ΔVth lands its effective voltage exactly on the onset.
        let bti = crate::aging::BtiModel::default();
        let crit = bti.critical_delta_vth(&t, t.v_nominal);
        assert_close(t.effective_voltage(t.v_nominal, crit), v_on, 1e-6);
    }
}
