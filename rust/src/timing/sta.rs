//! Static timing analysis over gate netlists.
//!
//! Replaces the paper's Synopsys DC timing reports: per-gate delays are the
//! library base delays (see [`GateKind::base_delay`]) times a frozen
//! per-gate process-variation factor, scaled to the operating voltage with
//! the alpha-power law. Arrival times propagate in one topological pass.

use super::gate::{GateKind, Netlist};
use super::voltage::Technology;
use crate::util::rng::Xoshiro256pp;

/// A "chip instance": per-gate process-variation factors frozen at
/// fabrication time. The same instance is reused across voltages and aging
/// scenarios so comparisons isolate the voltage effect.
#[derive(Clone, Debug)]
pub struct ChipInstance {
    variation: Vec<f32>,
}

impl ChipInstance {
    /// Sample per-gate variation factors ~ N(1, σ) clamped to [0.8, 1.25].
    pub fn sample(netlist: &Netlist, tech: &Technology, rng: &mut Xoshiro256pp) -> Self {
        let variation = netlist
            .gates()
            .iter()
            .map(|g| {
                if g.kind.is_source() {
                    1.0
                } else {
                    rng.gaussian(1.0, tech.process_sigma).clamp(0.8, 1.25) as f32
                }
            })
            .collect();
        Self { variation }
    }

    /// An idealized chip with no process variation (useful for tests).
    pub fn ideal(netlist: &Netlist) -> Self {
        Self { variation: vec![1.0; netlist.num_gates()] }
    }

    /// Per-gate delays at operating voltage `v` (normalized delay units).
    pub fn delays_at(&self, netlist: &Netlist, tech: &Technology, v: f64) -> Vec<f32> {
        let scale = tech.delay_scale(v) as f32;
        self.scaled_delays(netlist, scale)
    }

    /// Per-gate delays at voltage `v` with an aged threshold (paper §V.C).
    pub fn delays_at_aged(
        &self,
        netlist: &Netlist,
        tech: &Technology,
        v: f64,
        delta_vth: f64,
    ) -> Vec<f32> {
        let scale = tech.delay_scale_aged(v, delta_vth) as f32;
        self.scaled_delays(netlist, scale)
    }

    fn scaled_delays(&self, netlist: &Netlist, scale: f32) -> Vec<f32> {
        netlist
            .gates()
            .iter()
            .zip(&self.variation)
            .map(|(g, &var)| g.kind.base_delay() * var * scale)
            .collect()
    }
}

/// Result of a static timing pass.
#[derive(Clone, Debug)]
pub struct StaReport {
    /// Worst-case arrival time per signal.
    pub arrival: Vec<f32>,
    /// Worst arrival over primary outputs = critical-path delay.
    pub critical_path: f32,
    /// Output index realizing the critical path.
    pub critical_output: usize,
}

/// Compute worst-case arrival times: `t(g) = d(g) + max(t(fanins))`.
pub fn static_timing(netlist: &Netlist, delays: &[f32]) -> StaReport {
    assert_eq!(delays.len(), netlist.num_gates());
    let gates = netlist.gates();
    let mut arrival = vec![0f32; gates.len()];
    for (i, g) in gates.iter().enumerate() {
        arrival[i] = match g.kind {
            GateKind::Input | GateKind::Const0 | GateKind::Const1 => 0.0,
            k if k.is_unary() => arrival[g.a as usize] + delays[i],
            _ => arrival[g.a as usize].max(arrival[g.b as usize]) + delays[i],
        };
    }
    let (critical_output, critical_path) = netlist
        .outputs()
        .iter()
        .enumerate()
        .map(|(j, &o)| (j, arrival[o as usize]))
        .fold((0, f32::NEG_INFINITY), |acc, x| if x.1 > acc.1 { x } else { acc });
    StaReport { arrival, critical_path, critical_output }
}

/// The clock period of the (X-)TPU at nominal voltage.
///
/// Commercial silicon is *speed-binned*: the shipping clock tracks measured
/// dynamic timing, not the (hugely pessimistic) static worst case — random
/// multiplier stimuli activate the full static critical path with
/// vanishing probability, so an STA-derived clock would never produce the
/// overscaling errors the paper measures at 0.7/0.6 V. We therefore
/// calibrate: run a fixed PRBS at nominal voltage, take the largest dynamic
/// output arrival, add the guard band. Nominal operation stays error-free
/// by construction (the guard covers stimulus beyond the calibration set —
/// validated by the `nominal_model_is_exact` tests at 10^6 vectors), and
/// VOS then misses timing exactly the way the paper's Fig 1c/Table 2 show.
pub fn clock_period(netlist: &Netlist, chip: &ChipInstance, tech: &Technology) -> f32 {
    use crate::timing::vos::VosSimulator;
    use crate::util::rng::Xoshiro256pp;
    let delays = chip.delays_at(netlist, tech, tech.v_nominal);
    let mut sim = VosSimulator::new(netlist, delays, f32::INFINITY);
    let mut rng = Xoshiro256pp::seeded(0xC10C);
    let n_inputs = netlist.inputs().len();
    let mut max_arrival = 0f32;
    let mut bits = vec![false; n_inputs];
    for _ in 0..4096 {
        for b in bits.iter_mut() {
            *b = rng.chance(0.5);
        }
        sim.step(&bits);
        if sim.last_max_arrival() > max_arrival {
            max_arrival = sim.last_max_arrival();
        }
    }
    max_arrival * (1.0 + tech.clock_guard as f32)
}

/// Static-STA clock (worst-case critical path + guard) — kept for
/// comparison and for the aging study's margin accounting.
pub fn clock_period_static(netlist: &Netlist, chip: &ChipInstance, tech: &Technology) -> f32 {
    let delays = chip.delays_at(netlist, tech, tech.v_nominal);
    let report = static_timing(netlist, &delays);
    report.critical_path * (1.0 + tech.clock_guard as f32)
}

/// Per-output slack at a given voltage (positive = meets timing).
pub fn output_slacks(netlist: &Netlist, delays: &[f32], clock: f32) -> Vec<f32> {
    let report = static_timing(netlist, delays);
    netlist.outputs().iter().map(|&o| clock - report.arrival[o as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timing::circuits::baugh_wooley_8x8;
    use crate::timing::gate::Netlist;

    fn chain_netlist(len: usize) -> Netlist {
        let mut n = Netlist::new("chain");
        let mut sig = n.input();
        for _ in 0..len {
            let other = n.input();
            sig = n.nand2(sig, other);
        }
        n.mark_output(sig);
        n
    }

    #[test]
    fn chain_arrival_is_sum_of_delays() {
        let n = chain_netlist(10);
        let chip = ChipInstance::ideal(&n);
        let tech = Technology::default();
        let delays = chip.delays_at(&n, &tech, tech.v_nominal);
        let report = static_timing(&n, &delays);
        // 10 NAND2 gates at base delay 1.0 each.
        assert!((report.critical_path - 10.0).abs() < 1e-5);
    }

    #[test]
    fn voltage_scaling_stretches_arrivals() {
        let n = chain_netlist(5);
        let chip = ChipInstance::ideal(&n);
        let tech = Technology::default();
        let nom = static_timing(&n, &chip.delays_at(&n, &tech, 0.8)).critical_path;
        let low = static_timing(&n, &chip.delays_at(&n, &tech, 0.5)).critical_path;
        assert!((low / nom - tech.delay_scale(0.5) as f32).abs() < 1e-4);
    }

    #[test]
    fn multiplier_msb_paths_longest() {
        let n = baugh_wooley_8x8("bw_sta");
        let chip = ChipInstance::ideal(&n);
        let tech = Technology::default();
        let delays = chip.delays_at(&n, &tech, tech.v_nominal);
        let report = static_timing(&n, &delays);
        let outs = netlist_output_arrivals(&n, &report);
        // Product MSB region should arrive later than the LSBs (carry
        // propagation), which is why VOS errors are large-magnitude.
        assert!(outs[0] < outs[12], "lsb={} msb12={}", outs[0], outs[12]);
        assert!(report.critical_output >= 8, "critical bit {}", report.critical_output);
    }

    fn netlist_output_arrivals(n: &Netlist, r: &StaReport) -> Vec<f32> {
        n.outputs().iter().map(|&o| r.arrival[o as usize]).collect()
    }

    #[test]
    fn binned_clock_below_static_but_dynamically_safe() {
        let n = baugh_wooley_8x8("bw_clk");
        let tech = Technology::default();
        let mut rng = crate::util::rng::Xoshiro256pp::seeded(101);
        let chip = ChipInstance::sample(&n, &tech, &mut rng);
        let binned = clock_period(&n, &chip, &tech);
        let static_clk = clock_period_static(&n, &chip, &tech);
        // Speed binning must be meaningfully tighter than static STA…
        assert!(binned < static_clk, "binned {binned} vs static {static_clk}");
        assert!(binned > 0.3 * static_clk, "binned clock implausibly small");
        // …while nominal operation stays dynamically error-free.
        let delays = chip.delays_at(&n, &tech, tech.v_nominal);
        let mut sim = crate::timing::vos::VosSimulator::new(&n, delays, binned);
        let mut rng = crate::util::rng::Xoshiro256pp::seeded(777);
        sim.step(&crate::timing::gate::i64_to_bits(0, 16));
        for _ in 0..20_000 {
            let a = rng.range_i64(-128, 127);
            let w = rng.range_i64(-128, 127);
            let mut bits = crate::timing::gate::i64_to_bits(a, 8);
            bits.extend(crate::timing::gate::i64_to_bits(w, 8));
            let st = sim.step(&bits);
            assert_eq!(st.late_outputs, 0, "nominal voltage must be error-free");
        }
    }

    #[test]
    fn process_variation_bounded_and_reproducible() {
        let n = baugh_wooley_8x8("bw_var");
        let tech = Technology::default();
        let mut r1 = crate::util::rng::Xoshiro256pp::seeded(7);
        let mut r2 = crate::util::rng::Xoshiro256pp::seeded(7);
        let c1 = ChipInstance::sample(&n, &tech, &mut r1);
        let c2 = ChipInstance::sample(&n, &tech, &mut r2);
        let d1 = c1.delays_at(&n, &tech, 0.6);
        let d2 = c2.delays_at(&n, &tech, 0.6);
        assert_eq!(d1, d2);
        for (g, &d) in n.gates().iter().zip(&d1) {
            let base = g.kind.base_delay() * tech.delay_scale(0.6) as f32;
            if base > 0.0 {
                assert!(d >= base * 0.8 - 1e-5 && d <= base * 1.25 + 1e-5);
            }
        }
    }

    #[test]
    fn aged_critical_path_longer() {
        let n = baugh_wooley_8x8("bw_aged");
        let tech = Technology::default();
        let chip = ChipInstance::ideal(&n);
        let fresh = static_timing(&n, &chip.delays_at(&n, &tech, 0.8)).critical_path;
        let aged =
            static_timing(&n, &chip.delays_at_aged(&n, &tech, 0.8, 0.08)).critical_path;
        assert!(aged > fresh);
    }
}
