//! Dynamic timing-error simulation under voltage overscaling.
//!
//! This is the in-repo replacement for the paper's post-synthesis SDF
//! simulation in ModelSim (§V.A): consecutive input vectors are applied to
//! a netlist whose gate delays are scaled to the operating voltage while
//! the clock period stays fixed at the nominal-voltage critical path. An
//! output flip-flop captures whatever logic value is present at the clock
//! edge; if the last transition on an output net arrives *after* the edge,
//! the flip-flop keeps the previously settled value — a stale capture,
//! which is exactly the timing-error mechanism VOS induces.
//!
//! Transition times use the standard transition-delay approximation:
//! a gate whose output value does not change contributes no transition;
//! a gate whose output changes becomes valid `delay` after the latest
//! transition among its *changed* fanins. Glitch propagation is ignored
//! (same simplification post-synthesis SDF simulators make in inertial
//! mode for single-vector-per-cycle stimuli).

use super::gate::{GateKind, Netlist};

/// Per-step observation returned by [`VosSimulator::step`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepStats {
    /// Number of output bits captured stale this cycle.
    pub late_outputs: u32,
    /// Number of gate output toggles this cycle (for the power model).
    pub toggles: u32,
}

/// Cycle-by-cycle simulator of one combinational block feeding a register
/// stage (the PE multiplier or full PE datapath).
pub struct VosSimulator<'a> {
    netlist: &'a Netlist,
    delays: Vec<f32>,
    pub clock_period: f32,
    /// Settled (functionally correct) value per signal, previous cycle.
    settled_prev: Vec<u8>,
    /// Settled value per signal, current cycle (scratch).
    settled_now: Vec<u8>,
    /// Transition time per signal this cycle (NEG_INFINITY = no transition).
    trans: Vec<f32>,
    /// Captured output bits (what the registers actually latched).
    captured: Vec<u8>,
    /// Latest output transition time of the last step (−∞ if none).
    last_max_arrival: f32,
    /// Per-gate cumulative toggle counts (power accounting). Tracking is
    /// optional: the characterization hot loop disables it (§Perf).
    toggle_counts: Vec<u64>,
    track_toggles: bool,
    steps: u64,
}

impl<'a> VosSimulator<'a> {
    pub fn new(netlist: &'a Netlist, delays: Vec<f32>, clock_period: f32) -> Self {
        assert_eq!(delays.len(), netlist.num_gates());
        let n = netlist.num_gates();
        Self {
            netlist,
            delays,
            clock_period,
            settled_prev: vec![0; n],
            settled_now: vec![0; n],
            trans: vec![f32::NEG_INFINITY; n],
            captured: vec![0; netlist.outputs().len()],
            last_max_arrival: f32::NEG_INFINITY,
            toggle_counts: vec![0; n],
            track_toggles: true,
            steps: 0,
        }
    }

    /// Disable per-gate toggle accounting (used by the characterization hot
    /// loop, which only needs captured outputs — ~10-15 % faster).
    pub fn without_toggle_tracking(mut self) -> Self {
        self.track_toggles = false;
        self
    }

    /// Replace the delay assignment (e.g. switch operating voltage or apply
    /// aging) without losing circuit state.
    pub fn set_delays(&mut self, delays: Vec<f32>) {
        assert_eq!(delays.len(), self.netlist.num_gates());
        self.delays = delays;
    }

    /// Apply one input vector at a clock edge; returns per-step stats.
    ///
    /// The first step after construction settles the circuit without timing
    /// errors (power-up initialization), mirroring testbench practice of
    /// discarding the first vector.
    pub fn step(&mut self, input_bits: &[bool]) -> StepStats {
        let gates = self.netlist.gates();
        assert_eq!(input_bits.len(), self.netlist.inputs().len());
        let first = self.steps == 0;
        let mut toggles = 0u32;
        let mut next_input = 0usize;
        for (i, g) in gates.iter().enumerate() {
            let (new_val, tr) = match g.kind {
                GateKind::Input => {
                    let v = input_bits[next_input] as u8;
                    next_input += 1;
                    let changed = v != self.settled_prev[i];
                    (v, if changed && !first { 0.0 } else { f32::NEG_INFINITY })
                }
                GateKind::Const0 => (0, f32::NEG_INFINITY),
                GateKind::Const1 => (1, f32::NEG_INFINITY),
                _ => {
                    let va = self.settled_now[g.a as usize];
                    let (v, in_tr) = match g.kind {
                        GateKind::Not => (1 - va, self.trans[g.a as usize]),
                        GateKind::Buf => (va, self.trans[g.a as usize]),
                        _ => {
                            let vb = self.settled_now[g.b as usize];
                            let v = match g.kind {
                                GateKind::And2 => va & vb,
                                GateKind::Or2 => va | vb,
                                GateKind::Nand2 => 1 - (va & vb),
                                GateKind::Nor2 => 1 - (va | vb),
                                GateKind::Xor2 => va ^ vb,
                                GateKind::Xnor2 => 1 - (va ^ vb),
                                _ => unreachable!(),
                            };
                            (v, self.trans[g.a as usize].max(self.trans[g.b as usize]))
                        }
                    };
                    if v != self.settled_prev[i] {
                        toggles += 1;
                        if self.track_toggles {
                            self.toggle_counts[i] += 1;
                        }
                        (v, if first { f32::NEG_INFINITY } else { in_tr + self.delays[i] })
                    } else {
                        (v, f32::NEG_INFINITY)
                    }
                }
            };
            self.settled_now[i] = new_val;
            self.trans[i] = tr;
        }
        // Capture at the clock edge.
        let mut late_outputs = 0u32;
        self.last_max_arrival = f32::NEG_INFINITY;
        for (j, &o) in self.netlist.outputs().iter().enumerate() {
            let oi = o as usize;
            if self.trans[oi] > self.last_max_arrival {
                self.last_max_arrival = self.trans[oi];
            }
            if self.trans[oi] <= self.clock_period {
                self.captured[j] = self.settled_now[oi];
            } else {
                // Transition missed the edge: the register re-latches the
                // previously settled net value.
                self.captured[j] = self.settled_prev[oi];
                late_outputs += 1;
            }
        }
        std::mem::swap(&mut self.settled_prev, &mut self.settled_now);
        self.steps += 1;
        StepStats { late_outputs, toggles }
    }

    /// Register outputs actually captured last cycle (LSB-first).
    pub fn captured(&self) -> &[u8] {
        &self.captured
    }

    /// Functionally correct outputs of the last cycle.
    pub fn settled_outputs(&self) -> Vec<u8> {
        self.netlist.outputs().iter().map(|&o| self.settled_prev[o as usize]).collect()
    }

    /// Captured output bus decoded as two's complement.
    pub fn captured_i64(&self) -> i64 {
        decode_twos_complement(&self.captured)
    }

    /// Settled output bus decoded as two's complement.
    pub fn settled_i64(&self) -> i64 {
        let v = self.settled_outputs();
        decode_twos_complement(&v)
    }

    pub fn toggle_counts(&self) -> &[u64] {
        &self.toggle_counts
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Latest output transition time of the last step (−∞ when no output
    /// toggled). Used by the speed-binning clock calibration.
    pub fn last_max_arrival(&self) -> f32 {
        self.last_max_arrival
    }

    /// Sum of toggles within a gate-index range (power attribution).
    pub fn toggles_in(&self, range: &std::ops::Range<usize>) -> u64 {
        self.toggle_counts[range.clone()].iter().sum()
    }
}

fn decode_twos_complement(bits: &[u8]) -> i64 {
    let mut v: i64 = 0;
    for (i, &b) in bits.iter().enumerate() {
        if b != 0 {
            v |= 1 << i;
        }
    }
    if bits.len() < 64 && bits[bits.len() - 1] != 0 {
        v -= 1 << bits.len();
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timing::circuits::baugh_wooley_8x8;
    use crate::timing::gate::i64_to_bits;
    use crate::timing::sta::{clock_period, ChipInstance};
    use crate::timing::voltage::Technology;
    use crate::util::rng::Xoshiro256pp;

    fn mult_inputs(a: i64, w: i64) -> Vec<bool> {
        let mut bits = i64_to_bits(a, 8);
        bits.extend(i64_to_bits(w, 8));
        bits
    }

    #[test]
    fn nominal_voltage_is_error_free() {
        let n = baugh_wooley_8x8("bw_vos_nom");
        let tech = Technology::default();
        let mut rng = Xoshiro256pp::seeded(1);
        let chip = ChipInstance::sample(&n, &tech, &mut rng);
        let clock = clock_period(&n, &chip, &tech);
        let mut sim = VosSimulator::new(&n, chip.delays_at(&n, &tech, 0.8), clock);
        for _ in 0..2000 {
            let a = rng.range_i64(-128, 127);
            let w = rng.range_i64(-128, 127);
            let stats = sim.step(&mult_inputs(a, w));
            assert_eq!(stats.late_outputs, 0);
            assert_eq!(sim.captured_i64(), a * w, "a={a} w={w}");
        }
    }

    #[test]
    fn overscaled_voltage_produces_errors() {
        let n = baugh_wooley_8x8("bw_vos_low");
        let tech = Technology::default();
        let mut rng = Xoshiro256pp::seeded(2);
        let chip = ChipInstance::sample(&n, &tech, &mut rng);
        let clock = clock_period(&n, &chip, &tech);
        let mut sim = VosSimulator::new(&n, chip.delays_at(&n, &tech, 0.5), clock);
        let mut errors = 0u32;
        for _ in 0..2000 {
            let a = rng.range_i64(-128, 127);
            let w = rng.range_i64(-128, 127);
            sim.step(&mult_inputs(a, w));
            if sim.captured_i64() != a * w {
                errors += 1;
            }
            // The settled value must always be correct regardless of voltage.
            assert_eq!(sim.settled_i64(), a * w);
        }
        assert!(errors > 0, "0.5 V should cause timing errors");
    }

    #[test]
    fn error_rate_monotone_in_voltage() {
        let n = baugh_wooley_8x8("bw_vos_mono");
        let tech = Technology::default();
        let mut seed_rng = Xoshiro256pp::seeded(3);
        let chip = ChipInstance::sample(&n, &tech, &mut seed_rng);
        let clock = clock_period(&n, &chip, &tech);
        let mut rates = Vec::new();
        for v in [0.8, 0.7, 0.6, 0.5] {
            let mut rng = Xoshiro256pp::seeded(99);
            let mut sim = VosSimulator::new(&n, chip.delays_at(&n, &tech, v), clock);
            let mut errors = 0u32;
            let total = 3000;
            for _ in 0..total {
                let a = rng.range_i64(-128, 127);
                let w = rng.range_i64(-128, 127);
                sim.step(&mult_inputs(a, w));
                if sim.captured_i64() != a * w {
                    errors += 1;
                }
            }
            rates.push(errors as f64 / total as f64);
        }
        assert_eq!(rates[0], 0.0);
        assert!(rates[3] >= rates[2] && rates[2] >= rates[1], "rates={rates:?}");
        assert!(rates[3] > 0.0);
    }

    #[test]
    fn first_step_initializes_cleanly() {
        let n = baugh_wooley_8x8("bw_vos_first");
        let tech = Technology::default();
        let chip = ChipInstance::ideal(&n);
        let clock = clock_period(&n, &chip, &tech);
        let mut sim = VosSimulator::new(&n, chip.delays_at(&n, &tech, 0.5), clock);
        let stats = sim.step(&mult_inputs(-77, 113));
        assert_eq!(stats.late_outputs, 0, "power-up step must not count errors");
        assert_eq!(sim.captured_i64(), -77 * 113);
    }

    #[test]
    fn toggles_accumulate_and_attribute() {
        let n = baugh_wooley_8x8("bw_vos_tgl");
        let tech = Technology::default();
        let chip = ChipInstance::ideal(&n);
        let clock = clock_period(&n, &chip, &tech);
        let mut sim = VosSimulator::new(&n, chip.delays_at(&n, &tech, 0.8), clock);
        let mut rng = Xoshiro256pp::seeded(5);
        let mut total = 0u64;
        for _ in 0..100 {
            let a = rng.range_i64(-128, 127);
            let w = rng.range_i64(-128, 127);
            total += sim.step(&mult_inputs(a, w)).toggles as u64;
        }
        assert_eq!(sim.toggle_counts().iter().sum::<u64>(), total);
        assert!(total > 0);
        let full = 0..n.num_gates();
        assert_eq!(sim.toggles_in(&full), total);
    }

    #[test]
    fn constant_inputs_cause_no_toggles_after_settle() {
        let n = baugh_wooley_8x8("bw_vos_const");
        let tech = Technology::default();
        let chip = ChipInstance::ideal(&n);
        let clock = clock_period(&n, &chip, &tech);
        let mut sim = VosSimulator::new(&n, chip.delays_at(&n, &tech, 0.5), clock);
        sim.step(&mult_inputs(55, -44));
        for _ in 0..10 {
            let stats = sim.step(&mult_inputs(55, -44));
            assert_eq!(stats.toggles, 0);
            assert_eq!(stats.late_outputs, 0);
            assert_eq!(sim.captured_i64(), 55 * -44);
        }
    }

    #[test]
    fn stale_capture_matches_previous_settled_value() {
        // Build a tiny circuit with one slow path we can force to miss
        // timing: out = NOT(NOT(...NOT(in)...)) chain.
        let mut n = Netlist::new("chain");
        let a = n.input();
        let mut s = a;
        for _ in 0..10 {
            s = n.not(s);
        }
        n.mark_output(s);
        let delays = vec![1.0f32; n.num_gates()];
        // Chain takes 10.0; clock 5.0 → every change misses the edge.
        let mut sim = VosSimulator::new(&n, delays, 5.0);
        sim.step(&[false]); // settle: out = false (even # of inverters)
        assert_eq!(sim.captured()[0], 0);
        let st = sim.step(&[true]); // transition arrives at t=10 > 5
        assert_eq!(st.late_outputs, 1);
        assert_eq!(sim.captured()[0], 0, "stale value retained");
        let st = sim.step(&[true]); // stable now
        assert_eq!(st.late_outputs, 0);
        assert_eq!(sim.captured()[0], 1);
    }

    use crate::timing::gate::Netlist;
}
