//! PJRT runtime: loads the AOT artifacts emitted by `python/compile/aot.py`
//! and executes them from rust. Python never runs on this path — the HLO
//! text is parsed and compiled by the XLA CPU plugin in-process.
//!
//! See /opt/xla-example/README.md for the interchange-format constraints
//! (HLO text, `return_tuple=True`, interpret-mode Pallas).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::nn::quant::{NoiseSpec, QLayer, QuantizedModel};
use crate::util::rng::Xoshiro256pp;

/// A loaded artifact registry + PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, dir: artifacts_dir.to_path_buf(), executables: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) one artifact by name (`<name>.hlo.txt`).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).context("compiling artifact")?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute a loaded artifact; unwraps the tuple the lowering produces.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        Ok(tuple)
    }

    /// List artifact names present on disk.
    pub fn available(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let path = entry?.path();
            if let Some(fname) = path.file_name().and_then(|s| s.to_str()) {
                if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }
}

/// Build an int8 literal of the given dimensions. The `xla` crate has no
/// `NativeType` impl for `i8`, so the bytes go through the untyped-data
/// constructor (two's-complement `i8` bytes are exactly XLA `S8`).
pub fn literal_i8(data: &[i8], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal size mismatch");
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S8,
        dims,
        bytes,
    )?)
}

/// Build an f32 literal of the given dimensions.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal size mismatch");
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )?)
}

/// The FC-MNIST executor: binds a rust-trained quantized model's weights to
/// the generic `fc_mnist_<act>_b<m>` artifact and serves logits.
pub struct FcExecutor {
    pub artifact: String,
    pub batch: usize,
    w1: xla::Literal,
    b1: xla::Literal,
    s1: xla::Literal,
    sx2: xla::Literal,
    w2: xla::Literal,
    b2: xla::Literal,
    s2: xla::Literal,
    /// Quantization scale for raw input pixels.
    pub x_scale: f32,
    /// Per-neuron noise (mean, std), enumeration order = hidden then output.
    pub noise: NoiseSpec,
}

impl FcExecutor {
    /// Extract weights/scales from a quantized FC model (two dense layers).
    pub fn from_quantized(q: &QuantizedModel, activation: &str, batch: usize) -> Result<Self> {
        let macs: Vec<&crate::nn::quant::QuantMac> = q
            .layers
            .iter()
            .filter_map(|l| match l {
                QLayer::Dense(m) => Some(m),
                _ => None,
            })
            .collect();
        anyhow::ensure!(macs.len() == 2, "FC executor needs exactly 2 dense layers");
        let (l1, l2) = (macs[0], macs[1]);
        anyhow::ensure!(l1.fan_in == 784 && l1.out == 128 && l2.out == 10, "FC shape");
        // jax layout: w[fan_in, out] with column j = neuron j; rust stores
        // [out, fan_in] row-major → transpose.
        let mut w1t = vec![0i8; 784 * 128];
        for u in 0..128 {
            for i in 0..784 {
                w1t[i * 128 + u] = l1.wq[u * 784 + i];
            }
        }
        let mut w2t = vec![0i8; 128 * 10];
        for u in 0..10 {
            for i in 0..128 {
                w2t[i * 10 + u] = l2.wq[u * 128 + i];
            }
        }
        Ok(Self {
            artifact: format!("fc_mnist_{activation}_b{batch}"),
            batch,
            w1: literal_i8(&w1t, &[784, 128])?,
            b1: literal_f32(&l1.bias, &[128])?,
            s1: literal_f32(&[l1.w_scale * l1.x_scale], &[1])?,
            sx2: literal_f32(&[l2.x_scale], &[1])?,
            w2: literal_i8(&w2t, &[128, 10])?,
            b2: literal_f32(&l2.bias, &[10])?,
            s2: literal_f32(&[l2.w_scale * l2.x_scale], &[1])?,
            x_scale: l1.x_scale,
            noise: NoiseSpec::silent(138),
        })
    }

    /// Set the per-neuron noise implied by a voltage assignment.
    pub fn set_noise(&mut self, noise: NoiseSpec) {
        assert_eq!(noise.mean.len(), 138);
        self.noise = noise;
    }

    /// Run one batch of raw images (f32 pixels, `batch × 784`); returns
    /// logits (`batch × 10`). Noise is sampled fresh per call — this is the
    /// request path: rust-side RNG, no python.
    pub fn run(&self, rt: &Runtime, images: &[f32], rng: &mut Xoshiro256pp) -> Result<Vec<f32>> {
        anyhow::ensure!(images.len() == self.batch * 784, "batch size mismatch");
        let s = self.x_scale.max(1e-12);
        let xq: Vec<i8> = images
            .iter()
            .map(|&v| (v / s).round().clamp(-127.0, 127.0) as i8)
            .collect();
        let mut noise1 = vec![0f32; self.batch * 128];
        let mut noise2 = vec![0f32; self.batch * 10];
        for b in 0..self.batch {
            for u in 0..128 {
                let (m, sd) = (self.noise.mean[u], self.noise.std[u]);
                if sd > 0.0 || m != 0.0 {
                    noise1[b * 128 + u] = rng.gaussian(m, sd) as f32;
                }
            }
            for u in 0..10 {
                let (m, sd) = (self.noise.mean[128 + u], self.noise.std[128 + u]);
                if sd > 0.0 || m != 0.0 {
                    noise2[b * 10 + u] = rng.gaussian(m, sd) as f32;
                }
            }
        }
        let inputs = vec![
            literal_i8(&xq, &[self.batch, 784])?,
            self.w1.clone(),
            self.b1.clone(),
            self.s1.clone(),
            self.sx2.clone(),
            self.w2.clone(),
            self.b2.clone(),
            self.s2.clone(),
            literal_f32(&noise1, &[self.batch, 128])?,
            literal_f32(&noise2, &[self.batch, 10])?,
        ];
        let out = rt.execute(&self.artifact, &inputs)?;
        anyhow::ensure!(out.len() == 1, "expected 1-tuple output");
        Ok(out[0].to_vec::<f32>()?)
    }
}

/// Locate the repo's artifacts directory (env override → ./artifacts).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("XTPU_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from("artifacts")
}
