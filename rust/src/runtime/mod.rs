//! Artifact runtime: loads the AOT artifacts emitted by
//! `python/compile/aot.py` and executes them from rust. Python never runs
//! on this path.
//!
//! This tree executes the known artifact programs (`mm16`,
//! `fc_mnist_<act>_b<m>`) **natively** through [`crate::exec::kernel`]
//! with bit-identical semantics to the lowered HLO — int8 matmul
//! accumulated in i32, `jnp.round` (round-half-even) noise injection, f32
//! dequantization. The artifact *file* must still exist (`make
//! artifacts`), preserving the AOT discipline: you can only execute what
//! was actually compiled. The `pjrt` cargo feature is *reserved* for
//! builds that link the out-of-tree `xla` PJRT bindings (unavailable
//! offline); it currently gates no code, and [`Runtime::platform`]
//! reports the native engine unconditionally.
//!
//! Either way, [`FcExecutor`] is the serving face: it binds a rust-trained
//! quantized model's weights to the generic FC artifact and backs the
//! [`crate::exec::Pjrt`] backend.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::nn::quant::{NoiseSpec, QLayer, QuantizedModel};
use crate::util::rng::Xoshiro256pp;

/// Element types the artifacts traffic in (a subset of XLA's).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    S8,
    S32,
    F32,
}

impl ElementType {
    pub fn byte_width(self) -> usize {
        match self {
            ElementType::S8 => 1,
            ElementType::S32 => 4,
            ElementType::F32 => 4,
        }
    }
}

/// Scalar types a [`Literal`] can be viewed as.
pub trait LiteralNative: Copy {
    const TY: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
    fn write_le(self, out: &mut Vec<u8>);
}

impl LiteralNative for i8 {
    const TY: ElementType = ElementType::S8;
    fn from_le(bytes: &[u8]) -> Self {
        bytes[0] as i8
    }
    fn write_le(self, out: &mut Vec<u8>) {
        out.push(self as u8);
    }
}

impl LiteralNative for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

impl LiteralNative for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

/// A typed, shaped, densely-packed host buffer — the interchange value
/// between the coordinator and an executable artifact (mirrors
/// `xla::Literal` closely enough that call sites are engine-agnostic).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn from_slice<T: LiteralNative>(data: &[T], dims: &[usize]) -> Result<Self> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(n == data.len(), "literal size mismatch: {} vs dims {dims:?}", data.len());
        let mut bytes = Vec::with_capacity(n * T::TY.byte_width());
        for &v in data {
            v.write_le(&mut bytes);
        }
        Ok(Self { ty: T::TY, dims: dims.to_vec(), bytes })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    /// Copy out as a typed vector (errors on element-type mismatch).
    pub fn to_vec<T: LiteralNative>(&self) -> Result<Vec<T>> {
        anyhow::ensure!(
            self.ty == T::TY,
            "literal type mismatch: stored {:?}, requested {:?}",
            self.ty,
            T::TY
        );
        let w = self.ty.byte_width();
        Ok(self.bytes.chunks_exact(w).map(T::from_le).collect())
    }
}

/// Build an int8 literal of the given dimensions.
pub fn literal_i8(data: &[i8], dims: &[usize]) -> Result<Literal> {
    Literal::from_slice(data, dims)
}

/// Build an f32 literal of the given dimensions.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    Literal::from_slice(data, dims)
}

/// The artifact programs the native engine understands — exactly the ones
/// `python/compile/aot.py` emits (see python/compile/model.py for the
/// source-of-truth semantics).
#[derive(Clone, Debug)]
enum Program {
    /// `mm16`: int8[16,16] × int8[16,16] + round(noise) → i32[16,16].
    Mm16,
    /// `fc_mnist_<act>_b<m>`: the 784→128→10 quantized FC forward.
    Fc { activation: String, batch: usize },
}

fn parse_artifact_name(name: &str) -> Option<Program> {
    if name == "mm16" {
        return Some(Program::Mm16);
    }
    let rest = name.strip_prefix("fc_mnist_")?;
    let (activation, batch) = rest.rsplit_once("_b")?;
    let batch: usize = batch.parse().ok()?;
    if !matches!(activation, "linear" | "relu" | "sigmoid" | "tanh") {
        return None;
    }
    Some(Program::Fc { activation: activation.to_string(), batch })
}

/// A loaded artifact registry + execution engine.
pub struct Runtime {
    dir: PathBuf,
    programs: HashMap<String, Program>,
}

impl Runtime {
    /// Create a runtime rooted at an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        Ok(Self { dir: artifacts_dir.to_path_buf(), programs: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        // The `pjrt` cargo feature reserves the XLA-plugin build for
        // environments that have the out-of-tree bindings; this tree always
        // executes artifacts through the native interpreter, so report that
        // honestly regardless of features.
        "native-exec".to_string()
    }

    /// Load (and cache) one artifact by name (`<name>.hlo.txt`). The HLO
    /// file must exist on disk — the native engine refuses to conjure
    /// programs that were never AOT-compiled.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.programs.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        anyhow::ensure!(
            path.exists(),
            "artifact '{}' not found (run `make artifacts`)",
            path.display()
        );
        let program = parse_artifact_name(name)
            .with_context(|| format!("artifact '{name}' is not a known program"))?;
        self.programs.insert(name.to_string(), program);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.programs.contains_key(name)
    }

    /// Execute a loaded artifact; returns the elements of the tuple the
    /// lowering produces.
    pub fn execute(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let program = self
            .programs
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        match program {
            Program::Mm16 => execute_mm16(inputs),
            Program::Fc { activation, batch } => execute_fc(activation, *batch, inputs),
        }
    }

    /// List artifact names present on disk.
    pub fn available(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let path = entry?.path();
            if let Some(fname) = path.file_name().and_then(|s| s.to_str()) {
                if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }
}

/// `jnp.round` rounds half to even; keep that exact behavior so native and
/// PJRT execution agree bit-for-bit on the noise path.
#[inline]
fn round_ties_even_i32(x: f32) -> i32 {
    (x as f64).round_ties_even() as i32
}

fn execute_mm16(inputs: &[Literal]) -> Result<Vec<Literal>> {
    anyhow::ensure!(inputs.len() == 3, "mm16 takes (x, w, noise), got {}", inputs.len());
    let x = inputs[0].to_vec::<i8>().context("mm16 x operand")?;
    let w = inputs[1].to_vec::<i8>().context("mm16 w operand")?;
    let noise = inputs[2].to_vec::<f32>().context("mm16 noise operand")?;
    anyhow::ensure!(x.len() == 256 && w.len() == 256 && noise.len() == 256, "mm16 shape");
    let mut out = crate::exec::kernel::matmul_i8(&x, &w, 16, 16, 16);
    for (o, &e) in out.iter_mut().zip(&noise) {
        *o = o.wrapping_add(round_ties_even_i32(e));
    }
    let lit = Literal::from_slice(&out, &[16, 16])?;
    Ok(vec![lit])
}

fn apply_activation(name: &str, y: f32) -> f32 {
    match name {
        "linear" => y,
        "relu" => y.max(0.0),
        "sigmoid" => 1.0 / (1.0 + (-y).exp()),
        "tanh" => y.tanh(),
        other => panic!("unknown activation {other}"),
    }
}

/// The FC artifact program (python/compile/model.py::fc_forward): two
/// quantized dense layers with per-neuron noise operands.
fn execute_fc(activation: &str, batch: usize, inputs: &[Literal]) -> Result<Vec<Literal>> {
    anyhow::ensure!(inputs.len() == 10, "fc artifact takes 10 operands, got {}", inputs.len());
    let xq = inputs[0].to_vec::<i8>().context("fc x_q")?;
    let w1 = inputs[1].to_vec::<i8>().context("fc w1_q")?;
    let b1 = inputs[2].to_vec::<f32>().context("fc b1")?;
    let s1 = inputs[3].to_vec::<f32>().context("fc s1")?[0];
    let sx2 = inputs[4].to_vec::<f32>().context("fc sx2")?[0];
    let w2 = inputs[5].to_vec::<i8>().context("fc w2_q")?;
    let b2 = inputs[6].to_vec::<f32>().context("fc b2")?;
    let s2 = inputs[7].to_vec::<f32>().context("fc s2")?[0];
    let noise1 = inputs[8].to_vec::<f32>().context("fc noise1")?;
    let noise2 = inputs[9].to_vec::<f32>().context("fc noise2")?;
    let m = batch;
    anyhow::ensure!(xq.len() == m * 784, "fc x_q shape");
    anyhow::ensure!(w1.len() == 784 * 128 && w2.len() == 128 * 10, "fc weight shapes");
    anyhow::ensure!(noise1.len() == m * 128 && noise2.len() == m * 10, "fc noise shapes");

    // Layer 1: vos_matmul + dequant + activation.
    let mut acc1 = crate::exec::kernel::matmul_i8(&xq, &w1, m, 784, 128);
    for (o, &e) in acc1.iter_mut().zip(&noise1) {
        *o = o.wrapping_add(round_ties_even_i32(e));
    }
    // Requantize the hidden activations with jnp.round semantics.
    let sx2 = sx2.max(1e-12);
    let mut x2q = vec![0i8; m * 128];
    for s in 0..m {
        for u in 0..128 {
            let h = apply_activation(activation, acc1[s * 128 + u] as f32 * s1 + b1[u]);
            let q = (h / sx2).clamp(-127.0, 127.0);
            x2q[s * 128 + u] = (q as f64).round_ties_even() as i8;
        }
    }

    // Layer 2.
    let mut acc2 = crate::exec::kernel::matmul_i8(&x2q, &w2, m, 128, 10);
    for (o, &e) in acc2.iter_mut().zip(&noise2) {
        *o = o.wrapping_add(round_ties_even_i32(e));
    }
    let mut logits = vec![0f32; m * 10];
    for s in 0..m {
        for u in 0..10 {
            logits[s * 10 + u] = acc2[s * 10 + u] as f32 * s2 + b2[u];
        }
    }
    Ok(vec![literal_f32(&logits, &[m, 10])?])
}

/// The FC-MNIST executor: binds a rust-trained quantized model's weights to
/// the generic `fc_mnist_<act>_b<m>` artifact and serves logits.
pub struct FcExecutor {
    pub artifact: String,
    pub batch: usize,
    w1: Literal,
    b1: Literal,
    s1: Literal,
    sx2: Literal,
    w2: Literal,
    b2: Literal,
    s2: Literal,
    /// Quantization scale for raw input pixels.
    pub x_scale: f32,
    /// Per-neuron noise (mean, std), enumeration order = hidden then output.
    pub noise: NoiseSpec,
}

impl FcExecutor {
    /// Extract weights/scales from a quantized FC model (two dense layers).
    pub fn from_quantized(q: &QuantizedModel, activation: &str, batch: usize) -> Result<Self> {
        let macs: Vec<&crate::nn::quant::QuantMac> = q
            .layers
            .iter()
            .filter_map(|l| match l {
                QLayer::Dense(m) => Some(m),
                _ => None,
            })
            .collect();
        anyhow::ensure!(macs.len() == 2, "FC executor needs exactly 2 dense layers");
        let (l1, l2) = (macs[0], macs[1]);
        anyhow::ensure!(l1.fan_in == 784 && l1.out == 128 && l2.out == 10, "FC shape");
        // jax layout: w[fan_in, out] with column j = neuron j; rust stores
        // [out, fan_in] row-major → transpose.
        let mut w1t = vec![0i8; 784 * 128];
        for u in 0..128 {
            for i in 0..784 {
                w1t[i * 128 + u] = l1.wq[u * 784 + i];
            }
        }
        let mut w2t = vec![0i8; 128 * 10];
        for u in 0..10 {
            for i in 0..128 {
                w2t[i * 10 + u] = l2.wq[u * 128 + i];
            }
        }
        Ok(Self {
            artifact: format!("fc_mnist_{activation}_b{batch}"),
            batch,
            w1: literal_i8(&w1t, &[784, 128])?,
            b1: literal_f32(&l1.bias, &[128])?,
            s1: literal_f32(&[l1.w_scale * l1.x_scale], &[1])?,
            sx2: literal_f32(&[l2.x_scale], &[1])?,
            w2: literal_i8(&w2t, &[128, 10])?,
            b2: literal_f32(&l2.bias, &[10])?,
            s2: literal_f32(&[l2.w_scale * l2.x_scale], &[1])?,
            x_scale: l1.x_scale,
            noise: NoiseSpec::silent(138),
        })
    }

    /// Set the per-neuron noise implied by a voltage assignment.
    pub fn set_noise(&mut self, noise: NoiseSpec) {
        assert_eq!(noise.mean.len(), 138);
        self.noise = noise;
    }

    /// Run one batch of raw images (f32 pixels, `batch × 784`); returns
    /// logits (`batch × 10`). Noise is sampled fresh per call — this is the
    /// request path: rust-side RNG, no python.
    pub fn run(&self, rt: &Runtime, images: &[f32], rng: &mut Xoshiro256pp) -> Result<Vec<f32>> {
        anyhow::ensure!(images.len() == self.batch * 784, "batch size mismatch");
        let s = self.x_scale.max(1e-12);
        let xq: Vec<i8> = images
            .iter()
            .map(|&v| (v / s).round().clamp(-127.0, 127.0) as i8)
            .collect();
        let mut noise1 = vec![0f32; self.batch * 128];
        let mut noise2 = vec![0f32; self.batch * 10];
        for b in 0..self.batch {
            for u in 0..128 {
                let (m, sd) = (self.noise.mean[u], self.noise.std[u]);
                if sd > 0.0 || m != 0.0 {
                    noise1[b * 128 + u] = rng.gaussian(m, sd) as f32;
                }
            }
            for u in 0..10 {
                let (m, sd) = (self.noise.mean[128 + u], self.noise.std[128 + u]);
                if sd > 0.0 || m != 0.0 {
                    noise2[b * 10 + u] = rng.gaussian(m, sd) as f32;
                }
            }
        }
        let inputs = vec![
            literal_i8(&xq, &[self.batch, 784])?,
            self.w1.clone(),
            self.b1.clone(),
            self.s1.clone(),
            self.sx2.clone(),
            self.w2.clone(),
            self.b2.clone(),
            self.s2.clone(),
            literal_f32(&noise1, &[self.batch, 128])?,
            literal_f32(&noise2, &[self.batch, 10])?,
        ];
        let out = rt.execute(&self.artifact, &inputs)?;
        anyhow::ensure!(out.len() == 1, "expected 1-tuple output");
        out[0].to_vec::<f32>()
    }
}

/// Locate the repo's artifacts directory (env override → ./artifacts).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("XTPU_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = literal_i8(&[1, -2, 3, -4], &[2, 2]).unwrap();
        assert_eq!(l.element_type(), ElementType::S8);
        assert_eq!(l.dims(), &[2, 2]);
        assert_eq!(l.to_vec::<i8>().unwrap(), vec![1, -2, 3, -4]);
        assert!(l.to_vec::<f32>().is_err());
        let f = literal_f32(&[0.5, -1.25], &[2]).unwrap();
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![0.5, -1.25]);
        assert!(literal_i8(&[1], &[3]).is_err());
    }

    #[test]
    fn artifact_names_parse() {
        assert!(matches!(parse_artifact_name("mm16"), Some(Program::Mm16)));
        match parse_artifact_name("fc_mnist_linear_b32") {
            Some(Program::Fc { activation, batch }) => {
                assert_eq!(activation, "linear");
                assert_eq!(batch, 32);
            }
            other => panic!("{other:?}"),
        }
        assert!(parse_artifact_name("fc_mnist_quantum_b32").is_none());
        assert!(parse_artifact_name("unknown").is_none());
    }

    #[test]
    fn native_mm16_matches_reference() {
        let dir = std::env::temp_dir().join("xtpu_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("mm16.hlo.txt"), "HloModule mm16 (native test stub)").unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        rt.load("mm16").unwrap();
        let mut rng = Xoshiro256pp::seeded(7);
        let x: Vec<i8> = (0..256).map(|_| rng.range_i64(-127, 127) as i8).collect();
        let w: Vec<i8> = (0..256).map(|_| rng.range_i64(-127, 127) as i8).collect();
        let noise: Vec<f32> = (0..256).map(|_| rng.gaussian(0.0, 100.0) as f32).collect();
        let out = rt
            .execute(
                "mm16",
                &[
                    literal_i8(&x, &[16, 16]).unwrap(),
                    literal_i8(&w, &[16, 16]).unwrap(),
                    literal_f32(&noise, &[16, 16]).unwrap(),
                ],
            )
            .unwrap();
        let got: Vec<i32> = out[0].to_vec().unwrap();
        for i in 0..16 {
            for j in 0..16 {
                let mut acc = 0i64;
                for p in 0..16 {
                    acc += (x[i * 16 + p] as i64) * (w[p * 16 + j] as i64);
                }
                let expect = acc + (noise[i * 16 + j] as f64).round_ties_even() as i64;
                assert_eq!(got[i * 16 + j] as i64, expect, "({i},{j})");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_requires_artifact_file() {
        let mut rt = Runtime::new(std::path::Path::new("/nonexistent-artifacts")).unwrap();
        assert!(rt.load("mm16").is_err());
        assert!(!rt.is_loaded("mm16"));
    }
}
