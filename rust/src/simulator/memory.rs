//! The X-TPU weight memory with voltage-selection bits (paper Fig 7).
//!
//! Each word stores the int8 weight plus `sel_bits` MSB-side voltage-
//! selection bits. Loading a tile decodes the weights and drives the
//! per-column voltage switch boxes; the paper requires all words of a
//! column (= one neuron's weights) to agree on the level, which this
//! module enforces.

use crate::assign::{decode_weight_word, encode_weight_word};

/// Weight memory for a `k × n` weight matrix (column-major neuron layout:
/// column `j` holds neuron `j`'s weights).
#[derive(Clone, Debug)]
pub struct WeightMemory {
    pub k: usize,
    pub n: usize,
    pub sel_bits: usize,
    words: Vec<u16>,
}

#[derive(Debug)]
pub enum MemoryError {
    InconsistentColumn { col: usize, a: usize, b: usize },
    Dimension { expected: usize, got: usize },
}

impl std::fmt::Display for MemoryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MemoryError::InconsistentColumn { col, a, b } => write!(
                f,
                "column {col} has inconsistent voltage-selection bits ({a} vs {b})"
            ),
            MemoryError::Dimension { expected, got } => {
                write!(f, "dimension mismatch: expected {expected} words, got {got}")
            }
        }
    }
}

impl std::error::Error for MemoryError {}

impl WeightMemory {
    /// Encode a weight matrix `w[k×n]` (row-major) + per-column levels.
    pub fn encode(w: &[i8], k: usize, n: usize, levels: &[usize], sel_bits: usize) -> Self {
        assert_eq!(w.len(), k * n);
        assert_eq!(levels.len(), n);
        let mut words = Vec::with_capacity(k * n);
        for r in 0..k {
            for c in 0..n {
                words.push(encode_weight_word(w[r * n + c], levels[c], sel_bits));
            }
        }
        Self { k, n, sel_bits, words }
    }

    /// Raw augmented words (what the DDR/weight-FIFO would carry).
    pub fn words(&self) -> &[u16] {
        &self.words
    }

    /// Construct from raw words, validating column consistency.
    pub fn from_words(
        words: Vec<u16>,
        k: usize,
        n: usize,
        sel_bits: usize,
    ) -> Result<Self, MemoryError> {
        if words.len() != k * n {
            return Err(MemoryError::Dimension { expected: k * n, got: words.len() });
        }
        let mem = Self { k, n, sel_bits, words };
        mem.column_levels()?;
        Ok(mem)
    }

    /// Decode the weight matrix (row-major `k × n`).
    pub fn weights(&self) -> Vec<i8> {
        self.words.iter().map(|&w| decode_weight_word(w, self.sel_bits).0).collect()
    }

    /// Decode per-column voltage levels, checking that every word in a
    /// column agrees (the switch box has a single setting per column).
    pub fn column_levels(&self) -> Result<Vec<usize>, MemoryError> {
        let mut levels = vec![0usize; self.n];
        for c in 0..self.n {
            let first = decode_weight_word(self.words[c], self.sel_bits).1;
            for r in 1..self.k {
                let l = decode_weight_word(self.words[r * self.n + c], self.sel_bits).1;
                if l != first {
                    return Err(MemoryError::InconsistentColumn { col: c, a: first, b: l });
                }
            }
            levels[c] = first;
        }
        Ok(levels)
    }

    /// Memory footprint in bits (paper §IV.A overhead discussion): the
    /// augmented word costs `8 + sel_bits` per weight.
    pub fn footprint_bits(&self) -> usize {
        self.words.len() * (8 + self.sel_bits)
    }

    /// Overhead fraction vs. plain 8-bit weight storage.
    pub fn overhead(&self) -> f64 {
        self.sel_bits as f64 / 8.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn encode_decode_roundtrip() {
        let mut rng = Xoshiro256pp::seeded(1);
        let (k, n) = (16, 8);
        let w: Vec<i8> = (0..k * n).map(|_| rng.range_i64(-128, 127) as i8).collect();
        let levels: Vec<usize> = (0..n).map(|_| rng.index(4)).collect();
        let mem = WeightMemory::encode(&w, k, n, &levels, 2);
        assert_eq!(mem.weights(), w);
        assert_eq!(mem.column_levels().unwrap(), levels);
        assert_eq!(mem.footprint_bits(), k * n * 10);
        assert!((mem.overhead() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn inconsistent_column_detected() {
        let w = vec![1i8, 2, 3, 4];
        let mem = WeightMemory::encode(&w, 2, 2, &[0, 1], 2);
        let mut words = mem.words().to_vec();
        // Corrupt one word's selection bits in column 0.
        words[2] = crate::assign::encode_weight_word(3, 3, 2);
        let err = WeightMemory::from_words(words, 2, 2, 2);
        assert!(matches!(err, Err(MemoryError::InconsistentColumn { col: 0, .. })));
    }

    #[test]
    fn dimension_checked() {
        assert!(matches!(
            WeightMemory::from_words(vec![0; 5], 2, 2, 2),
            Err(MemoryError::Dimension { .. })
        ));
    }

    #[test]
    fn roundtrip_through_raw_words() {
        let w = vec![-128i8, 127, 0, -1, 55, -77];
        let mem = WeightMemory::encode(&w, 3, 2, &[2, 0], 2);
        let mem2 = WeightMemory::from_words(mem.words().to_vec(), 3, 2, 2).unwrap();
        assert_eq!(mem2.weights(), w);
        assert_eq!(mem2.column_levels().unwrap(), vec![2, 0]);
    }
}
