//! Cycle-level simulator of the X-TPU systolic array (paper §III.D, §IV.A,
//! Figs 3/6/7).
//!
//! Weight-stationary dataflow: int8 weights (with their voltage-selection
//! bits, Fig 7) are pre-loaded into the PE grid; activations stream in from
//! the left with the classic diagonal skew; partial sums cascade down each
//! column into the accumulators. Each *column* runs its multipliers at the
//! voltage selected by the column's weight words (voltage switch boxes),
//! while adders/registers stay at nominal — so injected timing errors are
//! per-multiply, independent, and additive along the column, exactly the
//! structure eqs 10–13 assume.
//!
//! Two error-injection modes:
//! - [`ErrorInjector::Statistical`]: composed per-column Gaussian draws
//!   from the fitted error models, fused into the shared
//!   [`crate::exec::kernel`] tile (the fast path — the same kernel every
//!   [`crate::exec::Backend`] uses, including its deterministic per-column
//!   draw streams, so simulator output is reproducible at any
//!   `XTPU_THREADS`).
//! - [`ErrorInjector::GateLevel`]: every PE owns a real
//!   [`VosSimulator`] over the Baugh-Wooley netlist (slow, used to
//!   cross-validate the statistical backend — see tests and
//!   [`crate::exec::GateLevel`], which wraps this array as a backend).

pub mod memory;

use crate::errormodel::{mult_input_bits, ErrorModelRegistry};
use crate::exec::kernel::{self, ColumnNoise};
use crate::power::PePowerModel;
use crate::timing::sta::{clock_period, ChipInstance};
use crate::timing::voltage::VoltageLadder;
use crate::timing::vos::VosSimulator;
use crate::timing::Netlist;
use crate::util::rng::Xoshiro256pp;

pub use memory::WeightMemory;

/// How PE multiply errors are produced.
pub enum ErrorInjector {
    /// Exact operation (all-nominal or functional runs).
    None,
    /// Per-multiply Gaussian from the per-voltage error models.
    Statistical(ErrorModelRegistry),
    /// Gate-level Baugh-Wooley simulation per PE (validation backend).
    GateLevel {
        netlist: Box<Netlist>,
        chip: ChipInstance,
        ladder: VoltageLadder,
    },
}

/// Aggregate counters of a simulation run.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimStats {
    /// Clock cycles consumed (fill + stream + drain, per tile pass).
    pub cycles: u64,
    /// MAC operations performed.
    pub macs: u64,
    /// Energy in normalized gate-energy units (needs a power model).
    pub energy: f64,
    /// Energy an all-nominal run would have used.
    pub energy_nominal: f64,
    /// Weight-load operations.
    pub weight_loads: u64,
}

impl SimStats {
    pub fn energy_saving(&self) -> f64 {
        if self.energy_nominal == 0.0 {
            0.0
        } else {
            1.0 - self.energy / self.energy_nominal
        }
    }
}

/// The X-TPU: an `rows × cols` systolic array with per-column voltage.
pub struct XTpu {
    pub rows: usize,
    pub cols: usize,
    pub ladder: VoltageLadder,
    /// Gate-level PE simulators (lazily built, one per grid position).
    /// Declared before `injector` so they drop first (they borrow the
    /// injector's boxed netlist).
    gate_sims: Vec<Option<Box<GatePe>>>,
    pub injector: ErrorInjector,
    pub power: Option<PePowerModel>,
    pub stats: SimStats,
}

struct GatePe {
    sim: VosSimulator<'static>,
    level: usize,
}

impl XTpu {
    pub fn new(rows: usize, cols: usize, ladder: VoltageLadder, injector: ErrorInjector) -> Self {
        assert!(rows > 0 && cols > 0);
        Self {
            rows,
            cols,
            ladder,
            gate_sims: Vec::new(),
            injector,
            power: None,
            stats: SimStats::default(),
        }
    }

    pub fn with_power(mut self, power: PePowerModel) -> Self {
        self.power = Some(power);
        self
    }

    pub fn reset_stats(&mut self) {
        self.stats = SimStats::default();
    }

    /// Full matrix multiply `A[m,k] × W[k,n] → i32[m,n]`, tiling over the
    /// array. `col_levels[j]` is the ladder level of output column `j`
    /// (the neuron's voltage). Weight loads + streaming are cycle-counted.
    pub fn matmul(
        &mut self,
        a: &[i8],
        w: &[i8],
        m: usize,
        k: usize,
        n: usize,
        col_levels: &[usize],
        rng: &mut Xoshiro256pp,
    ) -> Vec<i32> {
        assert_eq!(a.len(), m * k);
        assert_eq!(w.len(), k * n);
        assert_eq!(col_levels.len(), n);
        let nominal = self.ladder.len() - 1;
        for &l in col_levels {
            assert!(l < self.ladder.len(), "level {l} out of ladder");
        }
        let mut out = vec![0i32; m * n];
        // Tile over k (rows of the array) and n (columns).
        let mut k0 = 0;
        while k0 < k {
            let kr = (k - k0).min(self.rows);
            let mut n0 = 0;
            while n0 < n {
                let nc = (n - n0).min(self.cols);
                self.run_tile(a, w, m, k, n, k0, kr, n0, nc, col_levels, &mut out, rng);
                n0 += nc;
                let _ = nominal;
            }
            k0 += kr;
        }
        out
    }

    /// One weight-stationary pass of a `kr × nc` tile.
    #[allow(clippy::too_many_arguments)]
    fn run_tile(
        &mut self,
        a: &[i8],
        w: &[i8],
        m: usize,
        k: usize,
        n: usize,
        k0: usize,
        kr: usize,
        n0: usize,
        nc: usize,
        col_levels: &[usize],
        out: &mut [i32],
        rng: &mut Xoshiro256pp,
    ) {
        // --- weight prefetch (kr cycles, one row per cycle; Fig 3) --------
        let mut wtile = vec![0i8; kr * nc];
        for r in 0..kr {
            for c in 0..nc {
                wtile[r * nc + c] = w[(k0 + r) * n + (n0 + c)];
            }
        }
        self.stats.cycles += kr as u64;
        self.stats.weight_loads += (kr * nc) as u64;
        if matches!(self.injector, ErrorInjector::GateLevel { .. }) {
            self.prepare_gate_pes(kr, nc, col_levels, n0);
        }
        // --- streaming phase ----------------------------------------------
        // Cycle-level register state: activation pipeline (skewed) and the
        // psum cascade. Cycle accounting follows the systolic schedule
        // (m + kr + nc cycles for the pass, paper §III.D); the arithmetic
        // itself goes through the shared exec::kernel tile — the statistical
        // composition (one N(k_r·μ, k_r·σ²) draw per sample·column, eqs
        // 11–13) is fused there. Only the gate-level backend still resolves
        // every multiply, because that *is* its job.
        let nominal = self.ladder.len() - 1;
        let is_gate = matches!(self.injector, ErrorInjector::GateLevel { .. });
        if !is_gate {
            kernel::accumulate_tile(a, k, k0, kr, &wtile, nc, out, n, n0, m);
            let tile_noise: Vec<ColumnNoise> = (0..nc)
                .map(|c| {
                    let level = col_levels[n0 + c];
                    match &self.injector {
                        ErrorInjector::Statistical(reg) if level != nominal => {
                            let model = reg.model(level);
                            ColumnNoise {
                                mean: model.column_mean(kr),
                                std: model.column_variance(kr).sqrt(),
                            }
                        }
                        _ => ColumnNoise::SILENT,
                    }
                })
                .collect();
            kernel::add_column_noise(out, n, m, n0, &tile_noise, rng);
        } else {
            for s in 0..m {
                for c in 0..nc {
                    let level = col_levels[n0 + c];
                    let overscaled = level != nominal;
                    let mut psum = 0i64;
                    if !overscaled {
                        // Nominal columns are exact even on the gate array.
                        for r in 0..kr {
                            let act = a[s * k + (k0 + r)];
                            let wgt = wtile[r * nc + c];
                            psum += (act as i64) * (wgt as i64);
                        }
                    } else {
                        // Gate-level backend: every PE really computes.
                        for r in 0..kr {
                            let act = a[s * k + (k0 + r)];
                            let wgt = wtile[r * nc + c];
                            let pe = self.gate_sims[r * nc + c]
                                .as_mut()
                                .expect("gate PEs prepared");
                            pe.sim.step(&mult_input_bits(act as i64, wgt as i64));
                            psum += pe.sim.captured_i64();
                        }
                    }
                    out[s * n + (n0 + c)] =
                        out[s * n + (n0 + c)].wrapping_add(psum as i32);
                }
            }
        }
        self.stats.macs += (m * kr * nc) as u64;
        self.stats.cycles += (m + kr + nc) as u64;
        // --- energy accounting ---------------------------------------------
        if let Some(power) = &self.power {
            for c in 0..nc {
                let v = self.ladder.level(col_levels[n0 + c]).volts;
                let per_pe = power.pe_energy(v).total();
                let per_pe_nom = power.pe_energy(power.tech.v_nominal).total();
                self.stats.energy += per_pe * (m * kr) as f64;
                self.stats.energy_nominal += per_pe_nom * (m * kr) as f64;
            }
        }
    }

    /// (Re)build gate-level PE simulators for a tile footprint.
    fn prepare_gate_pes(&mut self, kr: usize, nc: usize, col_levels: &[usize], n0: usize) {
        let ErrorInjector::GateLevel { netlist, chip, ladder } = &self.injector else {
            return;
        };
        let clock = clock_period(netlist, chip, &ladder.tech);
        // SAFETY-free 'static trick: we own the netlist in the injector for
        // the lifetime of self; rebuild sims against a leaked reference is
        // avoided by cloning delays per PE and keeping the netlist boxed.
        // VosSimulator borrows the netlist; to store them alongside we use a
        // raw pointer promoted to 'static — sound because `netlist` is
        // heap-boxed, never moved or dropped while `gate_sims` is populated
        // (gate_sims is cleared before any mutation of the injector).
        let net_ref: &'static Netlist =
            unsafe { &*(netlist.as_ref() as *const Netlist) };
        self.gate_sims.clear();
        for r in 0..kr {
            let _ = r;
            for c in 0..nc {
                let level = col_levels[n0 + c];
                let volts = ladder.level(level).volts;
                let delays = chip.delays_at(net_ref, &ladder.tech, volts);
                let sim = VosSimulator::new(net_ref, delays, clock);
                self.gate_sims.push(Some(Box::new(GatePe { sim, level })));
            }
        }
        let _ = self.gate_sims.iter().flatten().map(|p| p.level).count();
    }

    /// Clock frequency is fixed by the nominal critical path; report the
    /// wall-clock-equivalent "simulated time" in clock periods.
    pub fn simulated_cycles(&self) -> u64 {
        self.stats.cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::errormodel::{CharacterizeOptions, ErrorModelRegistry};
    use crate::timing::baugh_wooley_8x8;
    use crate::timing::voltage::Technology;
    use crate::util::stats::variance;

    fn reference_matmul(a: &[i8], w: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for s in 0..m {
            for j in 0..n {
                let mut acc = 0i64;
                for r in 0..k {
                    acc += (a[s * k + r] as i64) * (w[r * n + j] as i64);
                }
                out[s * n + j] = acc as i32;
            }
        }
        out
    }

    fn random_mats(m: usize, k: usize, n: usize, seed: u64) -> (Vec<i8>, Vec<i8>) {
        let mut rng = Xoshiro256pp::seeded(seed);
        let a = (0..m * k).map(|_| rng.range_i64(-128, 127) as i8).collect();
        let w = (0..k * n).map(|_| rng.range_i64(-128, 127) as i8).collect();
        (a, w)
    }

    #[test]
    fn exact_mode_matches_reference_with_tiling() {
        let ladder = VoltageLadder::paper_default();
        // Array smaller than the problem → tiling in both k and n.
        let mut tpu = XTpu::new(8, 8, ladder.clone(), ErrorInjector::None);
        let (m, k, n) = (5, 20, 13);
        let (a, w) = random_mats(m, k, n, 1);
        let mut rng = Xoshiro256pp::seeded(2);
        let levels = vec![ladder.len() - 1; n];
        let got = tpu.matmul(&a, &w, m, k, n, &levels, &mut rng);
        assert_eq!(got, reference_matmul(&a, &w, m, k, n));
        assert!(tpu.stats.cycles > 0);
        assert_eq!(tpu.stats.macs, (m * k * n) as u64);
    }

    #[test]
    fn nominal_columns_are_exact_even_with_injector() {
        let ladder = VoltageLadder::paper_default();
        let reg = fake_registry(&ladder);
        let mut tpu = XTpu::new(16, 16, ladder.clone(), ErrorInjector::Statistical(reg));
        let (m, k, n) = (10, 16, 8);
        let (a, w) = random_mats(m, k, n, 3);
        let mut rng = Xoshiro256pp::seeded(4);
        let levels = vec![ladder.len() - 1; n];
        let got = tpu.matmul(&a, &w, m, k, n, &levels, &mut rng);
        assert_eq!(got, reference_matmul(&a, &w, m, k, n));
    }

    fn fake_registry(ladder: &VoltageLadder) -> ErrorModelRegistry {
        ErrorModelRegistry::synthetic(ladder, &[3.0e4, 1.0e4, 2.0e3, 0.0])
    }

    #[test]
    fn statistical_injection_variance_scales_with_column_height() {
        let ladder = VoltageLadder::paper_default();
        let reg = fake_registry(&ladder);
        for k in [4usize, 16] {
            let mut tpu =
                XTpu::new(16, 4, ladder.clone(), ErrorInjector::Statistical(reg.clone()));
            let m = 4000;
            let (a, w) = random_mats(m, k, 1, k as u64);
            let mut rng = Xoshiro256pp::seeded(9);
            let got = tpu.matmul(&a, &w, m, k, 1, &[0], &mut rng); // 0.5 V column
            let exact = reference_matmul(&a, &w, m, k, 1);
            let errs: Vec<f64> =
                got.iter().zip(&exact).map(|(&g, &e)| (g - e) as f64).collect();
            let var = variance(&errs);
            let expect = k as f64 * 3.0e4; // k·var(e) (eq. 13)
            let ratio = var / expect;
            assert!(
                (0.85..1.15).contains(&ratio),
                "k={k}: var {var:.3e} vs k·var(e) {expect:.3e}"
            );
        }
    }

    #[test]
    fn mixed_columns_only_corrupt_overscaled_ones() {
        let ladder = VoltageLadder::paper_default();
        let reg = fake_registry(&ladder);
        let mut tpu = XTpu::new(8, 8, ladder.clone(), ErrorInjector::Statistical(reg));
        let (m, k, n) = (200, 8, 4);
        let (a, w) = random_mats(m, k, n, 5);
        let mut rng = Xoshiro256pp::seeded(6);
        let levels = vec![0, 3, 1, 3]; // columns 1 and 3 nominal
        let got = tpu.matmul(&a, &w, m, k, n, &levels, &mut rng);
        let exact = reference_matmul(&a, &w, m, k, n);
        let mut col_err = [0i64; 4];
        for s in 0..m {
            for c in 0..n {
                col_err[c] += ((got[s * n + c] - exact[s * n + c]).abs()) as i64;
            }
        }
        assert_eq!(col_err[1], 0);
        assert_eq!(col_err[3], 0);
        assert!(col_err[0] > 0);
        assert!(col_err[2] > 0);
    }

    #[test]
    fn gate_level_backend_matches_statistical_variance() {
        // Characterize the multiplier, then check the gate-level array
        // produces column error variance consistent with k·var(e).
        let netlist = baugh_wooley_8x8("bw_sim");
        let tech = Technology::default();
        let mut crng = Xoshiro256pp::seeded(1234);
        let chip = ChipInstance::sample(&netlist, &tech, &mut crng);
        let ladder = VoltageLadder::paper_default();
        let opts = CharacterizeOptions { samples: 40_000, seed: 77, ..Default::default() };
        let reg = ErrorModelRegistry::characterize(&netlist, &chip, &ladder, &opts);
        let single_var = reg.model(0).variance; // 0.5 V
        assert!(single_var > 0.0);

        let k = 4usize;
        let mut tpu = XTpu::new(
            k,
            1,
            ladder.clone(),
            ErrorInjector::GateLevel {
                netlist: Box::new(netlist.clone()),
                chip: chip.clone(),
                ladder: ladder.clone(),
            },
        );
        let m = 6000;
        let (a, w) = random_mats(m, k, 1, 8);
        let mut rng = Xoshiro256pp::seeded(10);
        let got = tpu.matmul(&a, &w, m, k, 1, &[0], &mut rng);
        let exact = reference_matmul(&a, &w, m, k, 1);
        let errs: Vec<f64> =
            got.iter().zip(&exact).map(|(&g, &e)| (g - e) as f64).collect();
        let var = variance(&errs);
        let expect = k as f64 * single_var;
        let ratio = var / expect;
        assert!(
            (0.5..2.0).contains(&ratio),
            "gate-level column var {var:.3e} vs composed {expect:.3e} (ratio {ratio:.2})"
        );
    }

    #[test]
    fn energy_accounting_reflects_levels() {
        let ladder = VoltageLadder::paper_default();
        let reg = fake_registry(&ladder);
        let power = {
            use crate::power::RegionActivity;
            PePowerModel::new(
                RegionActivity { toggle_energy_per_cycle: 60.0, leakage_sum: 400.0 },
                RegionActivity { toggle_energy_per_cycle: 20.0, leakage_sum: 120.0 },
                Technology::default(),
            )
        };
        let (m, k, n) = (50, 8, 8);
        let (a, w) = random_mats(m, k, n, 11);
        // All nominal.
        let mut tpu = XTpu::new(8, 8, ladder.clone(), ErrorInjector::Statistical(reg.clone()))
            .with_power(power);
        let mut rng = Xoshiro256pp::seeded(12);
        tpu.matmul(&a, &w, m, k, n, &vec![3; n], &mut rng);
        assert!(tpu.stats.energy_saving().abs() < 1e-12);
        // All at 0.5 V.
        tpu.reset_stats();
        tpu.matmul(&a, &w, m, k, n, &vec![0; n], &mut rng);
        let saving = tpu.stats.energy_saving();
        assert!(saving > 0.2, "saving {saving}");
    }

    #[test]
    fn cycle_count_follows_systolic_schedule() {
        let ladder = VoltageLadder::paper_default();
        let mut tpu = XTpu::new(16, 16, ladder.clone(), ErrorInjector::None);
        let (m, k, n) = (100, 16, 16);
        let (a, w) = random_mats(m, k, n, 13);
        let mut rng = Xoshiro256pp::seeded(14);
        tpu.matmul(&a, &w, m, k, n, &vec![3; n], &mut rng);
        // Single tile: prefetch k + stream (m + k + n).
        assert_eq!(tpu.stats.cycles, (k + m + k + n) as u64);
    }
}
