//! Neural-network layers with explicit forward/backward passes.
//!
//! The framework needs to *train* its evaluation networks in-repo (no
//! dataset/model downloads offline), so each layer carries its backward
//! pass; gradients are verified against finite differences in the tests.
//! Layers are an enum (not trait objects) so the optimizer, quantizer and
//! neuron-enumeration passes can pattern-match on structure.

use super::tensor::{matmul, matmul_nt, matmul_tn, Tensor};
use crate::util::rng::Xoshiro256pp;

/// Activation functions evaluated elementwise after a MAC layer.
/// The paper studies Linear, Sigmoid, ReLU and TanH (Table 3, Fig 13).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Linear,
    Relu,
    Sigmoid,
    Tanh,
}

impl Activation {
    #[inline]
    pub fn apply(self, x: f32) -> f32 {
        match self {
            Activation::Linear => x,
            Activation::Relu => x.max(0.0),
            Activation::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            Activation::Tanh => x.tanh(),
        }
    }

    /// Derivative dy/dx expressed through the *output* y (all four have
    /// this property: 1, step, y(1−y), 1−y²).
    #[inline]
    pub fn derivative_from_output(self, y: f32) -> f32 {
        match self {
            Activation::Linear => 1.0,
            Activation::Relu => {
                if y > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Sigmoid => y * (1.0 - y),
            Activation::Tanh => 1.0 - y * y,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Activation::Linear => "linear",
            Activation::Relu => "relu",
            Activation::Sigmoid => "sigmoid",
            Activation::Tanh => "tanh",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<Self> {
        Ok(match name {
            "linear" => Activation::Linear,
            "relu" => Activation::Relu,
            "sigmoid" => Activation::Sigmoid,
            "tanh" => Activation::Tanh,
            other => anyhow::bail!("unknown activation '{other}'"),
        })
    }
}

fn apply_activation(act: Activation, t: &mut Tensor) {
    for v in t.data.iter_mut() {
        *v = act.apply(*v);
    }
}

/// Fully connected layer `y = act(W·x + b)`, `w` stored `[out, in]`
/// row-major (one row per output neuron — a TPU column).
#[derive(Clone, Debug)]
pub struct Dense {
    pub in_f: usize,
    pub out_f: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub act: Activation,
    pub gw: Vec<f32>,
    pub gb: Vec<f32>,
    cache_x: Tensor,
    cache_y: Tensor,
}

impl Dense {
    pub fn new(in_f: usize, out_f: usize, act: Activation, rng: &mut Xoshiro256pp) -> Self {
        // He/Glorot-ish init.
        let scale = (2.0 / in_f as f64).sqrt();
        let w = (0..in_f * out_f).map(|_| rng.gaussian(0.0, scale) as f32).collect();
        Self {
            in_f,
            out_f,
            w,
            b: vec![0.0; out_f],
            act,
            gw: vec![0.0; in_f * out_f],
            gb: vec![0.0; out_f],
            cache_x: Tensor::zeros(&[0]),
            cache_y: Tensor::zeros(&[0]),
        }
    }

    pub fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let batch = x.shape[0];
        assert_eq!(x.shape[1], self.in_f);
        let wt = Tensor::from_vec(&[self.out_f, self.in_f], self.w.clone());
        let mut y = matmul_nt(x, &wt); // [batch, out]
        for r in 0..batch {
            let row = y.row_mut(r);
            for (v, &bias) in row.iter_mut().zip(&self.b) {
                *v += bias;
            }
        }
        apply_activation(self.act, &mut y);
        if train {
            self.cache_x = x.clone();
            self.cache_y = y.clone();
        }
        y
    }

    pub fn backward(&mut self, grad_out: &Tensor) -> Tensor {
        let batch = grad_out.shape[0];
        // dL/dpre = dL/dy * act'(y)
        let mut gpre = grad_out.clone();
        for (g, &y) in gpre.data.iter_mut().zip(&self.cache_y.data) {
            *g *= self.act.derivative_from_output(y);
        }
        // gw[out, in] += gpreᵀ[out, batch] × x[batch, in]
        let gw = matmul_tn(&gpre, &self.cache_x); // [out, in]
        for (acc, g) in self.gw.iter_mut().zip(&gw.data) {
            *acc += g;
        }
        for r in 0..batch {
            for (acc, &g) in self.gb.iter_mut().zip(gpre.row(r)) {
                *acc += g;
            }
        }
        // dL/dx = gpre[batch, out] × w[out, in]
        let wt = Tensor::from_vec(&[self.out_f, self.in_f], self.w.clone());
        matmul(&gpre, &wt)
    }
}

/// 2-D convolution (valid or same padding, stride 1) via im2col.
/// Weights `[cout, cin*kh*kw]`, inputs `[batch, cin, h, w]` flattened.
#[derive(Clone, Debug)]
pub struct Conv2d {
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub pad: usize,
    pub act: Activation,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub gw: Vec<f32>,
    pub gb: Vec<f32>,
    cache_cols: Vec<Tensor>,
    cache_y: Tensor,
    cache_in_hw: (usize, usize),
}

impl Conv2d {
    pub fn new(
        cin: usize,
        cout: usize,
        k: usize,
        pad: usize,
        act: Activation,
        rng: &mut Xoshiro256pp,
    ) -> Self {
        let fan_in = cin * k * k;
        let scale = (2.0 / fan_in as f64).sqrt();
        let w = (0..cout * fan_in).map(|_| rng.gaussian(0.0, scale) as f32).collect();
        Self {
            cin,
            cout,
            k,
            pad,
            act,
            w,
            b: vec![0.0; cout],
            gw: vec![0.0; cout * fan_in],
            gb: vec![0.0; cout],
            cache_cols: Vec::new(),
            cache_y: Tensor::zeros(&[0]),
            cache_in_hw: (0, 0),
        }
    }

    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (h + 2 * self.pad + 1 - self.k, w + 2 * self.pad + 1 - self.k)
    }

    fn im2col(&self, img: &[f32], h: usize, w: usize) -> Tensor {
        let (ho, wo) = self.out_hw(h, w);
        let fan_in = self.cin * self.k * self.k;
        let mut cols = Tensor::zeros(&[fan_in, ho * wo]);
        let pad = self.pad as isize;
        for c in 0..self.cin {
            for ky in 0..self.k {
                for kx in 0..self.k {
                    let row = (c * self.k + ky) * self.k + kx;
                    let dst = &mut cols.data[row * ho * wo..(row + 1) * ho * wo];
                    for oy in 0..ho {
                        let iy = oy as isize + ky as isize - pad;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for ox in 0..wo {
                            let ix = ox as isize + kx as isize - pad;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            dst[oy * wo + ox] =
                                img[(c * h + iy as usize) * w + ix as usize];
                        }
                    }
                }
            }
        }
        cols
    }

    pub fn forward(&mut self, x: &Tensor, h: usize, w: usize, train: bool) -> Tensor {
        let batch = x.shape[0];
        let (ho, wo) = self.out_hw(h, w);
        let mut y = Tensor::zeros(&[batch, self.cout * ho * wo]);
        let wmat = Tensor::from_vec(&[self.cout, self.cin * self.k * self.k], self.w.clone());
        if train {
            self.cache_cols.clear();
            self.cache_in_hw = (h, w);
        }
        for s in 0..batch {
            let cols = self.im2col(x.row(s), h, w);
            let out = matmul(&wmat, &cols); // [cout, ho*wo]
            let dst = y.row_mut(s);
            for c in 0..self.cout {
                for p in 0..ho * wo {
                    dst[c * ho * wo + p] = out.data[c * ho * wo + p] + self.b[c];
                }
            }
            if train {
                self.cache_cols.push(cols);
            }
        }
        apply_activation(self.act, &mut y);
        if train {
            self.cache_y = y.clone();
        }
        y
    }

    pub fn backward(&mut self, grad_out: &Tensor) -> Tensor {
        let batch = grad_out.shape[0];
        let (h, w) = self.cache_in_hw;
        let (ho, wo) = self.out_hw(h, w);
        let fan_in = self.cin * self.k * self.k;
        let mut gx = Tensor::zeros(&[batch, self.cin * h * w]);
        let mut gpre = grad_out.clone();
        for (g, &y) in gpre.data.iter_mut().zip(&self.cache_y.data) {
            *g *= self.act.derivative_from_output(y);
        }
        let wmat = Tensor::from_vec(&[self.cout, fan_in], self.w.clone());
        for s in 0..batch {
            let g = Tensor::from_vec(&[self.cout, ho * wo], gpre.row(s).to_vec());
            // gw += g × colsᵀ
            let cols = &self.cache_cols[s];
            let gw = matmul_nt(&g, cols); // [cout, fan_in]
            for (acc, &v) in self.gw.iter_mut().zip(&gw.data) {
                *acc += v;
            }
            for c in 0..self.cout {
                self.gb[c] += g.row(c).iter().sum::<f32>();
            }
            // gcols = wᵀ × g : [fan_in, ho*wo]
            let gcols = matmul_tn(&wmat, &g);
            // col2im scatter-add.
            let img = gx.row_mut(s);
            let pad = self.pad as isize;
            for c in 0..self.cin {
                for ky in 0..self.k {
                    for kx in 0..self.k {
                        let row = (c * self.k + ky) * self.k + kx;
                        let src = &gcols.data[row * ho * wo..(row + 1) * ho * wo];
                        for oy in 0..ho {
                            let iy = oy as isize + ky as isize - pad;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for ox in 0..wo {
                                let ix = ox as isize + kx as isize - pad;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                img[(c * h + iy as usize) * w + ix as usize] +=
                                    src[oy * wo + ox];
                            }
                        }
                    }
                }
            }
        }
        gx
    }
}

/// 2×2 max pooling, stride 2.
#[derive(Clone, Debug)]
pub struct MaxPool2 {
    pub channels: usize,
    cache_mask: Vec<u32>,
    cache_in_hw: (usize, usize),
    cache_batch: usize,
}

impl MaxPool2 {
    pub fn new(channels: usize) -> Self {
        Self { channels, cache_mask: Vec::new(), cache_in_hw: (0, 0), cache_batch: 0 }
    }

    pub fn forward(&mut self, x: &Tensor, h: usize, w: usize, train: bool) -> Tensor {
        let batch = x.shape[0];
        let (ho, wo) = (h / 2, w / 2);
        let c = self.channels;
        let mut y = Tensor::zeros(&[batch, c * ho * wo]);
        if train {
            self.cache_mask = vec![0; batch * c * ho * wo];
            self.cache_in_hw = (h, w);
            self.cache_batch = batch;
        }
        for s in 0..batch {
            let img = x.row(s);
            let dst = y.row_mut(s);
            for ch in 0..c {
                for oy in 0..ho {
                    for ox in 0..wo {
                        let mut best = f32::NEG_INFINITY;
                        let mut best_idx = 0u32;
                        for dy in 0..2 {
                            for dx in 0..2 {
                                let iy = oy * 2 + dy;
                                let ix = ox * 2 + dx;
                                let idx = (ch * h + iy) * w + ix;
                                if img[idx] > best {
                                    best = img[idx];
                                    best_idx = idx as u32;
                                }
                            }
                        }
                        let o = (ch * ho + oy) * wo + ox;
                        dst[o] = best;
                        if train {
                            self.cache_mask[s * c * ho * wo + o] = best_idx;
                        }
                    }
                }
            }
        }
        y
    }

    pub fn backward(&mut self, grad_out: &Tensor) -> Tensor {
        let (h, w) = self.cache_in_hw;
        let c = self.channels;
        let (ho, wo) = (h / 2, w / 2);
        let batch = self.cache_batch;
        let mut gx = Tensor::zeros(&[batch, c * h * w]);
        for s in 0..batch {
            let g = grad_out.row(s);
            let dst = gx.row_mut(s);
            for o in 0..c * ho * wo {
                dst[self.cache_mask[s * c * ho * wo + o] as usize] += g[o];
            }
        }
        gx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::checks::assert_close;

    /// Numerical gradient check for Dense.
    #[test]
    fn dense_gradients_match_finite_difference() {
        let mut rng = Xoshiro256pp::seeded(3);
        for act in [Activation::Linear, Activation::Relu, Activation::Sigmoid, Activation::Tanh]
        {
            let mut layer = Dense::new(5, 4, act, &mut rng);
            let x = Tensor::from_vec(
                &[2, 5],
                (0..10).map(|_| rng.gaussian(0.0, 1.0) as f32).collect(),
            );
            // Loss = sum(y²)/2 → grad_out = y.
            let y = layer.forward(&x, true);
            let gin = layer.backward(&y.clone());
            let eps = 1e-3f32;
            // Check dL/dw for a few weights.
            for &wi in &[0usize, 7, 19] {
                let mut lp = layer.clone();
                lp.w[wi] += eps;
                let yp = lp.forward(&x, false);
                let mut lm = layer.clone();
                lm.w[wi] -= eps;
                let ym = lm.forward(&x, false);
                let lossp: f32 = yp.data.iter().map(|v| v * v / 2.0).sum();
                let lossm: f32 = ym.data.iter().map(|v| v * v / 2.0).sum();
                let numeric = (lossp - lossm) / (2.0 * eps);
                assert_close(layer.gw[wi] as f64, numeric as f64, 2e-2);
            }
            // Check dL/dx.
            for &xi in &[0usize, 4, 9] {
                let mut xp = x.clone();
                xp.data[xi] += eps;
                let mut xm = x.clone();
                xm.data[xi] -= eps;
                let mut l2 = layer.clone();
                let yp = l2.forward(&xp, false);
                let ym = l2.forward(&xm, false);
                let lossp: f32 = yp.data.iter().map(|v| v * v / 2.0).sum();
                let lossm: f32 = ym.data.iter().map(|v| v * v / 2.0).sum();
                let numeric = (lossp - lossm) / (2.0 * eps);
                assert_close(gin.data[xi] as f64, numeric as f64, 2e-2);
            }
        }
    }

    #[test]
    fn conv_forward_known_values() {
        let mut rng = Xoshiro256pp::seeded(4);
        let mut conv = Conv2d::new(1, 1, 3, 0, Activation::Linear, &mut rng);
        conv.w = vec![0., 0., 0., 0., 1., 0., 0., 0., 0.]; // identity kernel
        conv.b = vec![0.5];
        let x = Tensor::from_vec(&[1, 16], (0..16).map(|v| v as f32).collect());
        let y = conv.forward(&x, 4, 4, false);
        // Valid 3x3 on 4x4 → 2x2 centers: pixels (1,1),(1,2),(2,1),(2,2).
        assert_eq!(y.data, vec![5.5, 6.5, 9.5, 10.5]);
    }

    #[test]
    fn conv_gradients_match_finite_difference() {
        let mut rng = Xoshiro256pp::seeded(5);
        let mut conv = Conv2d::new(2, 3, 3, 1, Activation::Tanh, &mut rng);
        let x = Tensor::from_vec(
            &[1, 2 * 5 * 5],
            (0..50).map(|_| rng.gaussian(0.0, 0.5) as f32).collect(),
        );
        let y = conv.forward(&x, 5, 5, true);
        let gin = conv.backward(&y.clone());
        let eps = 1e-3f32;
        for &wi in &[0usize, 10, 30, 53] {
            let mut cp = conv.clone();
            cp.w[wi] += eps;
            let yp = cp.forward(&x, 5, 5, false);
            let mut cm = conv.clone();
            cm.w[wi] -= eps;
            let ym = cm.forward(&x, 5, 5, false);
            let lossp: f32 = yp.data.iter().map(|v| v * v / 2.0).sum();
            let lossm: f32 = ym.data.iter().map(|v| v * v / 2.0).sum();
            let numeric = (lossp - lossm) / (2.0 * eps);
            assert_close(conv.gw[wi] as f64, numeric as f64, 3e-2);
        }
        for &xi in &[0usize, 12, 49] {
            let mut xp = x.clone();
            xp.data[xi] += eps;
            let mut xm = x.clone();
            xm.data[xi] -= eps;
            let mut c2 = conv.clone();
            let yp = c2.forward(&xp, 5, 5, false);
            let ym = c2.forward(&xm, 5, 5, false);
            let lossp: f32 = yp.data.iter().map(|v| v * v / 2.0).sum();
            let lossm: f32 = ym.data.iter().map(|v| v * v / 2.0).sum();
            let numeric = (lossp - lossm) / (2.0 * eps);
            assert_close(gin.data[xi] as f64, numeric as f64, 3e-2);
        }
    }

    #[test]
    fn maxpool_forward_backward() {
        let mut pool = MaxPool2::new(1);
        let x = Tensor::from_vec(
            &[1, 16],
            vec![
                1., 2., 5., 6., //
                3., 4., 7., 8., //
                9., 10., 13., 14., //
                11., 12., 15., 16.,
            ],
        );
        let y = pool.forward(&x, 4, 4, true);
        assert_eq!(y.data, vec![4., 8., 12., 16.]);
        let g = Tensor::from_vec(&[1, 4], vec![1., 2., 3., 4.]);
        let gx = pool.backward(&g);
        assert_eq!(gx.data[5], 1.); // position of the 4
        assert_eq!(gx.data[7], 2.); // position of the 8
        assert_eq!(gx.data[13], 3.);
        assert_eq!(gx.data[15], 4.);
        assert_eq!(gx.data.iter().sum::<f32>(), 10.);
    }

    #[test]
    fn activation_roundtrip_names() {
        for a in [Activation::Linear, Activation::Relu, Activation::Sigmoid, Activation::Tanh] {
            assert_eq!(Activation::from_name(a.name()).unwrap(), a);
        }
        assert!(Activation::from_name("softmax9").is_err());
    }
}
