//! Training loop: minibatch SGD with momentum on softmax cross-entropy.
//!
//! The paper assumes *pre-trained* 8-bit-quantized models; since no weights
//! can be downloaded offline, the framework trains its evaluation networks
//! on the synthetic datasets, then quantizes (see [`super::quant`]).

use super::data::Dataset;
use super::model::Model;
use super::tensor::Tensor;
use crate::util::rng::Xoshiro256pp;

/// Softmax + cross-entropy over a logits batch; returns (loss, dL/dlogits).
pub fn softmax_cross_entropy(logits: &Tensor, labels: &[u8]) -> (f64, Tensor) {
    let batch = logits.shape[0];
    let classes = logits.shape[1];
    assert_eq!(labels.len(), batch);
    let mut grad = Tensor::zeros(&[batch, classes]);
    let mut loss = 0.0f64;
    for r in 0..batch {
        let row = logits.row(r);
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = row.iter().map(|&v| (v - maxv).exp()).collect();
        let sum: f32 = exps.iter().sum();
        let label = labels[r] as usize;
        let p = exps[label] / sum;
        loss += -(p.max(1e-12) as f64).ln();
        let g = grad.row_mut(r);
        for c in 0..classes {
            g[c] = (exps[c] / sum - if c == label { 1.0 } else { 0.0 }) / batch as f32;
        }
    }
    (loss / batch as f64, grad)
}

/// Classification accuracy of a logits batch.
pub fn batch_accuracy(logits: &Tensor, labels: &[u8]) -> f64 {
    let mut correct = 0usize;
    for r in 0..logits.shape[0] {
        let row = logits.row(r);
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap();
        if pred == labels[r] as usize {
            correct += 1;
        }
    }
    correct as f64 / logits.shape[0].max(1) as f64
}

/// Loss function for training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Loss {
    /// Softmax cross-entropy (CNN classifiers).
    SoftmaxCrossEntropy,
    /// MSE against one-hot targets — the paper's regression-style objective
    /// for the linear/sigmoid-output FC network (keeps output magnitudes
    /// ≈ [0,1], so the "MSE increment % of nominal MSE" budgets behave like
    /// the paper's Fig 10/13 sweeps).
    Mse,
}

/// MSE-vs-one-hot loss; returns (loss, dL/dlogits).
pub fn mse_onehot(logits: &Tensor, labels: &[u8]) -> (f64, Tensor) {
    let batch = logits.shape[0];
    let classes = logits.shape[1];
    let mut grad = Tensor::zeros(&[batch, classes]);
    let mut loss = 0.0f64;
    let norm = (batch * classes) as f32;
    for r in 0..batch {
        let row = logits.row(r);
        let g = grad.row_mut(r);
        for c in 0..classes {
            let target = if c == labels[r] as usize { 1.0 } else { 0.0 };
            let e = row[c] - target;
            loss += (e * e) as f64;
            g[c] = 2.0 * e / norm;
        }
    }
    (loss / norm as f64, grad)
}

/// Training hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch_size: usize,
    pub lr: f64,
    pub momentum: f64,
    pub seed: u64,
    pub loss: Loss,
    /// Print a log line every N batches (0 = silent).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 5,
            batch_size: 32,
            lr: 0.05,
            momentum: 0.9,
            seed: 1,
            loss: Loss::SoftmaxCrossEntropy,
            log_every: 0,
        }
    }
}

/// Epoch-level training record (for EXPERIMENTS.md loss curves).
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub loss: f64,
    pub train_accuracy: f64,
}

/// Train `model` on `train` with SGD+momentum; returns per-epoch stats.
pub fn train(model: &mut Model, train_set: &Dataset, cfg: &TrainConfig) -> Vec<EpochStats> {
    let mut rng = Xoshiro256pp::seeded(cfg.seed);
    let n = train_set.len();
    // One velocity buffer per parameter tensor.
    let mut velocities: Vec<Vec<f32>> = Vec::new();
    model.visit_params(|p, _| velocities.push(vec![0.0; p.len()]));
    let mut stats = Vec::new();
    for epoch in 0..cfg.epochs {
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0;
        let mut epoch_acc = 0.0;
        let mut batches = 0.0;
        for (bi, chunk) in order.chunks(cfg.batch_size).enumerate() {
            let (x, y) = train_set.batch(chunk);
            let logits = model.forward(&x, true);
            let (loss, grad) = match cfg.loss {
                Loss::SoftmaxCrossEntropy => softmax_cross_entropy(&logits, &y),
                Loss::Mse => mse_onehot(&logits, &y),
            };
            epoch_loss += loss;
            epoch_acc += batch_accuracy(&logits, &y);
            batches += 1.0;
            // Zero grads, backprop, apply update.
            model.visit_params(|_, g| g.iter_mut().for_each(|v| *v = 0.0));
            model.backward(&grad);
            let (lr, mom) = (cfg.lr as f32, cfg.momentum as f32);
            let mut vi = 0;
            model.visit_params(|p, g| {
                let v = &mut velocities[vi];
                for ((pv, gv), vv) in p.iter_mut().zip(g.iter()).zip(v.iter_mut()) {
                    *vv = mom * *vv - lr * *gv;
                    *pv += *vv;
                }
                vi += 1;
            });
            if cfg.log_every > 0 && bi % cfg.log_every == 0 {
                eprintln!("epoch {epoch} batch {bi}: loss {loss:.4}");
            }
        }
        stats.push(EpochStats {
            epoch,
            loss: epoch_loss / batches,
            train_accuracy: epoch_acc / batches,
        });
    }
    stats
}

/// Evaluate accuracy on a dataset (float model, batched).
pub fn evaluate(model: &mut Model, ds: &Dataset, batch_size: usize) -> f64 {
    let mut correct = 0.0;
    let mut total = 0.0;
    let idx: Vec<usize> = (0..ds.len()).collect();
    for chunk in idx.chunks(batch_size) {
        let (x, y) = ds.batch(chunk);
        let logits = model.forward(&x, false);
        correct += batch_accuracy(&logits, &y) * y.len() as f64;
        total += y.len() as f64;
    }
    correct / total.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::data::synth_mnist;
    use crate::nn::layers::Activation;
    use crate::nn::model::fc_mnist;

    #[test]
    fn softmax_ce_gradient_sums_to_zero_per_row() {
        let logits = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 0.5, -1.0, 0.0, 1.0]);
        let (loss, grad) = softmax_cross_entropy(&logits, &[1, 2]);
        assert!(loss > 0.0);
        for r in 0..2 {
            let s: f32 = grad.row(r).iter().sum();
            assert!(s.abs() < 1e-6);
        }
        // Correct-class gradient is negative.
        assert!(grad.data[1] < 0.0);
        assert!(grad.data[5] < 0.0);
    }

    #[test]
    fn accuracy_counts_argmax() {
        let logits = Tensor::from_vec(&[2, 3], vec![0.1, 0.9, 0.0, 0.8, 0.1, 0.1]);
        assert_eq!(batch_accuracy(&logits, &[1, 0]), 1.0);
        assert_eq!(batch_accuracy(&logits, &[0, 0]), 0.5);
    }

    #[test]
    fn fc_learns_synthetic_digits() {
        let mut rng = Xoshiro256pp::seeded(11);
        let mut model = fc_mnist(Activation::Relu, &mut rng);
        let train_set = synth_mnist(600, 21);
        let test_set = synth_mnist(200, 22);
        let before = evaluate(&mut model, &test_set, 64);
        let cfg = TrainConfig { epochs: 4, batch_size: 32, lr: 0.08, ..Default::default() };
        let stats = train(&mut model, &train_set, &cfg);
        let after = evaluate(&mut model, &test_set, 64);
        assert!(
            stats.last().unwrap().loss < stats.first().unwrap().loss,
            "loss must decrease: {stats:?}"
        );
        assert!(after > before + 0.3, "accuracy before={before:.3} after={after:.3}");
        assert!(after > 0.7, "test accuracy {after:.3} too low");
    }
}
