//! Composable model definition: a stack of layers with shape tracking,
//! training-mode forward/backward, neuron enumeration (the paper's unit of
//! voltage assignment), and JSON persistence.

use super::layers::{Activation, Conv2d, Dense, MaxPool2};
use super::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256pp;

/// A residual block (ResNet-tiny): `y = relu(conv2(relu(conv1(x))) + skip)`
/// where `skip` is identity or a 1×1 projection when channel counts differ.
#[derive(Clone, Debug)]
pub struct ResBlock {
    pub conv1: Conv2d,
    pub conv2: Conv2d,
    pub proj: Option<Conv2d>,
    cache_sum_y: Tensor,
}

impl ResBlock {
    pub fn new(cin: usize, cout: usize, rng: &mut Xoshiro256pp) -> Self {
        let conv1 = Conv2d::new(cin, cout, 3, 1, Activation::Relu, rng);
        let conv2 = Conv2d::new(cout, cout, 3, 1, Activation::Linear, rng);
        let proj = if cin != cout {
            Some(Conv2d::new(cin, cout, 1, 0, Activation::Linear, rng))
        } else {
            None
        };
        Self { conv1, conv2, proj, cache_sum_y: Tensor::zeros(&[0]) }
    }

    pub fn forward(&mut self, x: &Tensor, h: usize, w: usize, train: bool) -> Tensor {
        let a = self.conv1.forward(x, h, w, train);
        let mut y = self.conv2.forward(&a, h, w, train);
        let skip = match &mut self.proj {
            Some(p) => p.forward(x, h, w, train),
            None => x.clone(),
        };
        for (v, &s) in y.data.iter_mut().zip(&skip.data) {
            *v = (*v + s).max(0.0); // final ReLU on the sum
        }
        if train {
            self.cache_sum_y = y.clone();
        }
        y
    }

    pub fn backward(&mut self, grad_out: &Tensor) -> Tensor {
        let mut g = grad_out.clone();
        for (gv, &y) in g.data.iter_mut().zip(&self.cache_sum_y.data) {
            if y <= 0.0 {
                *gv = 0.0;
            }
        }
        let g_main = self.conv2.backward(&g);
        let g_in_main = self.conv1.backward(&g_main);
        let g_in_skip = match &mut self.proj {
            Some(p) => p.backward(&g),
            None => g.clone(),
        };
        let mut gx = g_in_main;
        for (v, &s) in gx.data.iter_mut().zip(&g_in_skip.data) {
            *v += s;
        }
        gx
    }
}

/// One layer of a model.
#[derive(Clone, Debug)]
pub enum Layer {
    Dense(Dense),
    Conv(Conv2d),
    Pool(MaxPool2),
    Res(ResBlock),
}

/// Shape of the data entering a layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataShape {
    /// Flat feature vector.
    Flat(usize),
    /// Channels × height × width.
    Spatial(usize, usize, usize),
}

impl DataShape {
    pub fn numel(&self) -> usize {
        match *self {
            DataShape::Flat(n) => n,
            DataShape::Spatial(c, h, w) => c * h * w,
        }
    }
}

/// A MAC "neuron" — the paper's unit of voltage assignment (an FC output
/// unit or a CNN kernel; §IV.A "each column in the TPU represents a neuron
/// in a fully connected network or a kernel in a CNN").
#[derive(Clone, Debug)]
pub struct Neuron {
    /// Index of the MAC layer this neuron belongs to (0-based over MAC
    /// layers only, in forward order).
    pub mac_layer: usize,
    /// Unit (output-feature / filter) index within the layer.
    pub unit: usize,
    /// Fan-in `k`: MAC count per output value — the PE column height.
    pub fan_in: usize,
    /// L2 norm of the neuron's weight vector (ES surrogate for linear
    /// activations, paper §IV.D).
    pub weight_l2: f64,
    /// Whether the neuron sits in the final (output) layer.
    pub is_output: bool,
}

/// A feed-forward model with tracked shapes.
#[derive(Clone, Debug)]
pub struct Model {
    pub name: String,
    pub input: DataShape,
    pub layers: Vec<Layer>,
    /// Shape entering each layer (computed at build time).
    shapes: Vec<DataShape>,
    pub output_dim: usize,
}

pub struct ModelBuilder {
    name: String,
    input: DataShape,
    layers: Vec<Layer>,
    shapes: Vec<DataShape>,
    cur: DataShape,
}

impl ModelBuilder {
    pub fn new(name: &str, input: DataShape) -> Self {
        Self { name: name.to_string(), input, layers: Vec::new(), shapes: Vec::new(), cur: input }
    }

    pub fn dense(mut self, out_f: usize, act: Activation, rng: &mut Xoshiro256pp) -> Self {
        let in_f = self.cur.numel();
        self.shapes.push(self.cur);
        self.layers.push(Layer::Dense(Dense::new(in_f, out_f, act, rng)));
        self.cur = DataShape::Flat(out_f);
        self
    }

    pub fn conv(
        mut self,
        cout: usize,
        k: usize,
        pad: usize,
        act: Activation,
        rng: &mut Xoshiro256pp,
    ) -> Self {
        let (c, h, w) = match self.cur {
            DataShape::Spatial(c, h, w) => (c, h, w),
            _ => panic!("conv requires spatial input"),
        };
        let conv = Conv2d::new(c, cout, k, pad, act, rng);
        let (ho, wo) = conv.out_hw(h, w);
        self.shapes.push(self.cur);
        self.layers.push(Layer::Conv(conv));
        self.cur = DataShape::Spatial(cout, ho, wo);
        self
    }

    pub fn pool(mut self) -> Self {
        let (c, h, w) = match self.cur {
            DataShape::Spatial(c, h, w) => (c, h, w),
            _ => panic!("pool requires spatial input"),
        };
        self.shapes.push(self.cur);
        self.layers.push(Layer::Pool(MaxPool2::new(c)));
        self.cur = DataShape::Spatial(c, h / 2, w / 2);
        self
    }

    pub fn res_block(mut self, cout: usize, rng: &mut Xoshiro256pp) -> Self {
        let (c, h, w) = match self.cur {
            DataShape::Spatial(c, h, w) => (c, h, w),
            _ => panic!("res_block requires spatial input"),
        };
        self.shapes.push(self.cur);
        self.layers.push(Layer::Res(ResBlock::new(c, cout, rng)));
        self.cur = DataShape::Spatial(cout, h, w);
        self
    }

    pub fn build(self) -> Model {
        let output_dim = self.cur.numel();
        Model {
            name: self.name,
            input: self.input,
            layers: self.layers,
            shapes: self.shapes,
            output_dim,
        }
    }
}

impl Model {
    /// Forward pass over a batch `[batch, input_numel]`.
    pub fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let mut cur = x.clone();
        for (i, layer) in self.layers.iter_mut().enumerate() {
            let shape = self.shapes[i];
            cur = match layer {
                Layer::Dense(d) => d.forward(&cur, train),
                Layer::Conv(c) => {
                    let (_, h, w) = spatial(shape);
                    c.forward(&cur, h, w, train)
                }
                Layer::Pool(p) => {
                    let (_, h, w) = spatial(shape);
                    p.forward(&cur, h, w, train)
                }
                Layer::Res(r) => {
                    let (_, h, w) = spatial(shape);
                    r.forward(&cur, h, w, train)
                }
            };
        }
        cur
    }

    /// Backward pass (after a `forward(..., train=true)`), accumulating
    /// parameter gradients.
    pub fn backward(&mut self, grad_out: &Tensor) {
        let mut g = grad_out.clone();
        for layer in self.layers.iter_mut().rev() {
            g = match layer {
                Layer::Dense(d) => d.backward(&g),
                Layer::Conv(c) => c.backward(&g),
                Layer::Pool(p) => p.backward(&g),
                Layer::Res(r) => r.backward(&g),
            };
        }
    }

    /// Visit every (param, grad) pair (optimizer hook).
    pub fn visit_params(&mut self, mut f: impl FnMut(&mut [f32], &mut [f32])) {
        for layer in self.layers.iter_mut() {
            match layer {
                Layer::Dense(d) => {
                    f(&mut d.w, &mut d.gw);
                    f(&mut d.b, &mut d.gb);
                }
                Layer::Conv(c) => {
                    f(&mut c.w, &mut c.gw);
                    f(&mut c.b, &mut c.gb);
                }
                Layer::Pool(_) => {}
                Layer::Res(r) => {
                    f(&mut r.conv1.w, &mut r.conv1.gw);
                    f(&mut r.conv1.b, &mut r.conv1.gb);
                    f(&mut r.conv2.w, &mut r.conv2.gw);
                    f(&mut r.conv2.b, &mut r.conv2.gb);
                    if let Some(p) = &mut r.proj {
                        f(&mut p.w, &mut p.gw);
                        f(&mut p.b, &mut p.gb);
                    }
                }
            }
        }
    }

    pub fn num_params(&mut self) -> usize {
        let mut n = 0;
        self.visit_params(|p, _| n += p.len());
        n
    }

    /// Enumerate MAC layers in forward order as (weights, fan_in, out_units).
    fn mac_layers(&self) -> Vec<(&[f32], usize, usize)> {
        let mut out = Vec::new();
        for layer in &self.layers {
            match layer {
                Layer::Dense(d) => out.push((d.w.as_slice(), d.in_f, d.out_f)),
                Layer::Conv(c) => {
                    out.push((c.w.as_slice(), c.cin * c.k * c.k, c.cout));
                }
                Layer::Pool(_) => {}
                Layer::Res(r) => {
                    out.push((
                        r.conv1.w.as_slice(),
                        r.conv1.cin * r.conv1.k * r.conv1.k,
                        r.conv1.cout,
                    ));
                    out.push((
                        r.conv2.w.as_slice(),
                        r.conv2.cin * r.conv2.k * r.conv2.k,
                        r.conv2.cout,
                    ));
                    if let Some(p) = &r.proj {
                        out.push((p.w.as_slice(), p.cin, p.cout));
                    }
                }
            }
        }
        out
    }

    /// Enumerate all neurons (the voltage-assignment domain).
    pub fn neurons(&self) -> Vec<Neuron> {
        let macs = self.mac_layers();
        let last = macs.len().saturating_sub(1);
        let mut out = Vec::new();
        for (li, (w, fan_in, units)) in macs.iter().enumerate() {
            for u in 0..*units {
                let row = &w[u * fan_in..(u + 1) * fan_in];
                let l2 = row.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
                out.push(Neuron {
                    mac_layer: li,
                    unit: u,
                    fan_in: *fan_in,
                    weight_l2: l2,
                    is_output: li == last,
                });
            }
        }
        out
    }

    pub fn num_mac_layers(&self) -> usize {
        self.mac_layers().len()
    }

    // --- persistence --------------------------------------------------------

    pub fn to_json(&self) -> Json {
        fn conv_json(c: &Conv2d) -> Json {
            Json::obj(vec![
                ("cin", Json::Num(c.cin as f64)),
                ("cout", Json::Num(c.cout as f64)),
                ("k", Json::Num(c.k as f64)),
                ("pad", Json::Num(c.pad as f64)),
                ("act", Json::Str(c.act.name().into())),
                ("w", Json::arr_f64(&c.w.iter().map(|&v| v as f64).collect::<Vec<_>>())),
                ("b", Json::arr_f64(&c.b.iter().map(|&v| v as f64).collect::<Vec<_>>())),
            ])
        }
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| match l {
                Layer::Dense(d) => Json::obj(vec![
                    ("type", Json::Str("dense".into())),
                    ("in", Json::Num(d.in_f as f64)),
                    ("out", Json::Num(d.out_f as f64)),
                    ("act", Json::Str(d.act.name().into())),
                    (
                        "w",
                        Json::arr_f64(&d.w.iter().map(|&v| v as f64).collect::<Vec<_>>()),
                    ),
                    (
                        "b",
                        Json::arr_f64(&d.b.iter().map(|&v| v as f64).collect::<Vec<_>>()),
                    ),
                ]),
                Layer::Conv(c) => {
                    let mut obj = conv_json(c);
                    if let Json::Obj(m) = &mut obj {
                        m.insert("type".into(), Json::Str("conv".into()));
                    }
                    obj
                }
                Layer::Pool(p) => Json::obj(vec![
                    ("type", Json::Str("pool".into())),
                    ("channels", Json::Num(p.channels as f64)),
                ]),
                Layer::Res(r) => {
                    let mut fields = vec![
                        ("type", Json::Str("res".into())),
                        ("conv1", conv_json(&r.conv1)),
                        ("conv2", conv_json(&r.conv2)),
                    ];
                    if let Some(p) = &r.proj {
                        fields.push(("proj", conv_json(p)));
                    }
                    Json::obj(fields)
                }
            })
            .collect();
        let input = match self.input {
            DataShape::Flat(n) => Json::arr_f64(&[n as f64]),
            DataShape::Spatial(c, h, w) => Json::arr_f64(&[c as f64, h as f64, w as f64]),
        };
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("input", input),
            ("layers", Json::Arr(layers)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Model> {
        fn conv_from(j: &Json) -> anyhow::Result<Conv2d> {
            let mut rng = Xoshiro256pp::seeded(0);
            let mut c = Conv2d::new(
                j.get("cin")?.as_usize()?,
                j.get("cout")?.as_usize()?,
                j.get("k")?.as_usize()?,
                j.get("pad")?.as_usize()?,
                Activation::from_name(j.get("act")?.as_str()?)?,
                &mut rng,
            );
            c.w = j.get("w")?.as_f64_vec()?.iter().map(|&v| v as f32).collect();
            c.b = j.get("b")?.as_f64_vec()?.iter().map(|&v| v as f32).collect();
            anyhow::ensure!(c.w.len() == c.cout * c.cin * c.k * c.k, "conv weight size");
            Ok(c)
        }
        let input_v = j.get("input")?.as_f64_vec()?;
        let input = match input_v.len() {
            1 => DataShape::Flat(input_v[0] as usize),
            3 => DataShape::Spatial(
                input_v[0] as usize,
                input_v[1] as usize,
                input_v[2] as usize,
            ),
            n => anyhow::bail!("bad input shape rank {n}"),
        };
        let mut b = ModelBuilder::new(j.get("name")?.as_str()?, input);
        for lj in j.get("layers")?.as_arr()? {
            match lj.get("type")?.as_str()? {
                "dense" => {
                    let mut rng = Xoshiro256pp::seeded(0);
                    let in_f = lj.get("in")?.as_usize()?;
                    let out_f = lj.get("out")?.as_usize()?;
                    let mut d = Dense::new(
                        in_f,
                        out_f,
                        Activation::from_name(lj.get("act")?.as_str()?)?,
                        &mut rng,
                    );
                    d.w = lj.get("w")?.as_f64_vec()?.iter().map(|&v| v as f32).collect();
                    d.b = lj.get("b")?.as_f64_vec()?.iter().map(|&v| v as f32).collect();
                    anyhow::ensure!(d.w.len() == in_f * out_f, "dense weight size");
                    anyhow::ensure!(b.cur.numel() == in_f, "dense input mismatch");
                    b.shapes.push(b.cur);
                    b.layers.push(Layer::Dense(d));
                    b.cur = DataShape::Flat(out_f);
                }
                "conv" => {
                    let c = conv_from(lj)?;
                    let (cc, h, w) = spatial(b.cur);
                    anyhow::ensure!(cc == c.cin, "conv input channels");
                    let (ho, wo) = c.out_hw(h, w);
                    let cout = c.cout;
                    b.shapes.push(b.cur);
                    b.layers.push(Layer::Conv(c));
                    b.cur = DataShape::Spatial(cout, ho, wo);
                }
                "pool" => {
                    let (c, h, w) = spatial(b.cur);
                    b.shapes.push(b.cur);
                    b.layers.push(Layer::Pool(MaxPool2::new(c)));
                    b.cur = DataShape::Spatial(c, h / 2, w / 2);
                }
                "res" => {
                    let conv1 = conv_from(lj.get("conv1")?)?;
                    let conv2 = conv_from(lj.get("conv2")?)?;
                    let proj = match lj.opt("proj") {
                        Some(p) => Some(conv_from(p)?),
                        None => None,
                    };
                    let (c, h, w) = spatial(b.cur);
                    anyhow::ensure!(c == conv1.cin, "res input channels");
                    let cout = conv2.cout;
                    b.shapes.push(b.cur);
                    b.layers.push(Layer::Res(ResBlock {
                        conv1,
                        conv2,
                        proj,
                        cache_sum_y: Tensor::zeros(&[0]),
                    }));
                    b.cur = DataShape::Spatial(cout, h, w);
                }
                other => anyhow::bail!("unknown layer type '{other}'"),
            }
        }
        Ok(b.build())
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        crate::util::json::write_file(path, &self.to_json())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Model> {
        Self::from_json(&crate::util::json::read_file(path)?)
    }
}

fn spatial(s: DataShape) -> (usize, usize, usize) {
    match s {
        DataShape::Spatial(c, h, w) => (c, h, w),
        _ => panic!("expected spatial shape"),
    }
}

/// The paper's FC benchmark: 784 → 128 hidden → 10 out (Fig 5/11/12/13).
pub fn fc_mnist(hidden_act: Activation, rng: &mut Xoshiro256pp) -> Model {
    ModelBuilder::new("fc_mnist", DataShape::Flat(784))
        .dense(128, hidden_act, rng)
        .dense(10, Activation::Linear, rng)
        .build()
}

/// LeNet-5-style CNN for 28×28 grayscale (Fig 14a).
pub fn lenet5(rng: &mut Xoshiro256pp) -> Model {
    ModelBuilder::new("lenet5", DataShape::Spatial(1, 28, 28))
        .conv(6, 5, 0, Activation::Relu, rng) // 24×24
        .pool() // 12×12
        .conv(16, 5, 0, Activation::Relu, rng) // 8×8
        .pool() // 4×4
        .dense(120, Activation::Relu, rng)
        .dense(84, Activation::Relu, rng)
        .dense(10, Activation::Linear, rng)
        .build()
}

/// ResNet-tiny for 32×32×3 (CIFAR-like) — the in-budget stand-in for the
/// paper's ResNet-50 (substitution documented in DESIGN.md §3).
pub fn resnet_tiny(rng: &mut Xoshiro256pp) -> Model {
    ModelBuilder::new("resnet_tiny", DataShape::Spatial(3, 32, 32))
        .conv(8, 3, 1, Activation::Relu, rng) // 32×32
        .res_block(8, rng)
        .pool() // 16×16
        .res_block(16, rng)
        .pool() // 8×8
        .res_block(16, rng)
        .pool() // 4×4
        .dense(10, Activation::Linear, rng)
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fc_shapes_and_neurons() {
        let mut rng = Xoshiro256pp::seeded(1);
        let mut m = fc_mnist(Activation::Linear, &mut rng);
        assert_eq!(m.output_dim, 10);
        assert_eq!(m.num_params(), 784 * 128 + 128 + 128 * 10 + 10);
        let neurons = m.neurons();
        assert_eq!(neurons.len(), 138); // 128 hidden + 10 output
        assert_eq!(neurons[0].fan_in, 784);
        assert_eq!(neurons[128].fan_in, 128);
        assert!(neurons[137].is_output);
        assert!(!neurons[0].is_output);
        assert!(neurons.iter().all(|n| n.weight_l2 > 0.0));
    }

    #[test]
    fn forward_shapes_fc_and_lenet() {
        let mut rng = Xoshiro256pp::seeded(2);
        let mut fc = fc_mnist(Activation::Sigmoid, &mut rng);
        let x = Tensor::zeros(&[3, 784]);
        assert_eq!(fc.forward(&x, false).shape, vec![3, 10]);

        let mut ln = lenet5(&mut rng);
        let x = Tensor::zeros(&[2, 784]);
        let y = ln.forward(&x, false);
        assert_eq!(y.shape, vec![2, 10]);
        // LeNet neurons: 6 + 16 + 120 + 84 + 10.
        assert_eq!(ln.neurons().len(), 236);
    }

    #[test]
    fn resnet_tiny_forward_and_neurons() {
        let mut rng = Xoshiro256pp::seeded(3);
        let mut rn = resnet_tiny(&mut rng);
        let x = Tensor::zeros(&[1, 3 * 32 * 32]);
        let y = rn.forward(&x, false);
        assert_eq!(y.shape, vec![1, 10]);
        let n = rn.neurons();
        // conv(8) + res(8,8) + res(8→16: 16+16+proj16) + res(16,16) + dense10
        assert_eq!(n.len(), 8 + (8 + 8) + (16 + 16 + 16) + (16 + 16) + 10);
        assert!(n.last().unwrap().is_output);
    }

    #[test]
    fn model_json_roundtrip_preserves_forward() {
        let mut rng = Xoshiro256pp::seeded(4);
        let mut m = lenet5(&mut rng);
        let x = Tensor::from_vec(
            &[1, 784],
            (0..784).map(|i| ((i * 37) % 256) as f32 / 255.0).collect(),
        );
        let y1 = m.forward(&x, false);
        let j = m.to_json();
        let mut m2 = Model::from_json(&j).unwrap();
        let y2 = m2.forward(&x, false);
        crate::util::checks::assert_allclose(&y1.data, &y2.data, 1e-6);
        assert_eq!(m.neurons().len(), m2.neurons().len());
    }

    #[test]
    fn resblock_gradcheck() {
        let mut rng = Xoshiro256pp::seeded(5);
        let mut rb = ResBlock::new(2, 3, &mut rng);
        let x = Tensor::from_vec(
            &[1, 2 * 4 * 4],
            (0..32).map(|_| rng.gaussian(0.0, 0.5) as f32).collect(),
        );
        let y = rb.forward(&x, 4, 4, true);
        let gin = rb.backward(&y.clone());
        let eps = 1e-3f32;
        for &xi in &[0usize, 15, 31] {
            let mut xp = x.clone();
            xp.data[xi] += eps;
            let mut xm = x.clone();
            xm.data[xi] -= eps;
            let mut rb2 = rb.clone();
            let yp = rb2.forward(&xp, 4, 4, false);
            let ym = rb2.forward(&xm, 4, 4, false);
            let lossp: f32 = yp.data.iter().map(|v| v * v / 2.0).sum();
            let lossm: f32 = ym.data.iter().map(|v| v * v / 2.0).sum();
            let numeric = (lossp - lossm) / (2.0 * eps);
            crate::util::checks::assert_close(gin.data[xi] as f64, numeric as f64, 5e-2);
        }
    }

    #[test]
    fn from_json_rejects_garbage() {
        let j = Json::parse(r#"{"name":"x","input":[4],"layers":[{"type":"warp"}]}"#).unwrap();
        assert!(Model::from_json(&j).is_err());
    }
}
