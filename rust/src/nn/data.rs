//! Procedural synthetic datasets.
//!
//! The offline environment cannot download MNIST/CIFAR-10, so the framework
//! generates stand-ins with the same shapes and difficulty character
//! (substitution documented in DESIGN.md §3):
//!
//! - [`synth_mnist`]: 28×28 grayscale digits rendered from stroke glyphs
//!   with random affine jitter, thickness and pixel noise — same tensor
//!   layout as MNIST, accuracy phenomenology preserved (a ~95 %+ FC model,
//!   higher for CNNs, degrades smoothly under injected MAC noise).
//! - [`synth_cifar`]: 32×32×3 class-conditional textures (stripes, blobs,
//!   checkers … with color/frequency/phase jitter) as a 10-class stand-in
//!   for CIFAR-10.

use super::tensor::Tensor;
use crate::util::rng::Xoshiro256pp;

/// A labelled dataset: `images` is `[n, features]`, `labels[i]` ∈ 0..10.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub images: Tensor,
    pub labels: Vec<u8>,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Batch view: rows `range` of the image matrix + labels.
    pub fn batch(&self, idx: &[usize]) -> (Tensor, Vec<u8>) {
        let f = self.images.cols();
        let mut out = Tensor::zeros(&[idx.len(), f]);
        let mut labels = Vec::with_capacity(idx.len());
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.images.row(i));
            labels.push(self.labels[i]);
        }
        (out, labels)
    }
}

/// Stroke skeletons for digits 0–9 in a unit box (x right, y down).
/// Each stroke is a polyline; digits follow seven-segment-like topology
/// with diagonals where it helps disambiguation.
fn digit_strokes(d: u8) -> Vec<Vec<(f32, f32)>> {
    let p = |x: f32, y: f32| (x, y);
    match d {
        0 => vec![vec![
            p(0.25, 0.15),
            p(0.75, 0.15),
            p(0.75, 0.85),
            p(0.25, 0.85),
            p(0.25, 0.15),
        ]],
        1 => vec![vec![p(0.35, 0.25), p(0.55, 0.12), p(0.55, 0.88)]],
        2 => vec![vec![
            p(0.25, 0.25),
            p(0.5, 0.12),
            p(0.75, 0.3),
            p(0.3, 0.85),
            p(0.78, 0.85),
        ]],
        3 => vec![vec![
            p(0.25, 0.15),
            p(0.72, 0.15),
            p(0.45, 0.45),
            p(0.75, 0.68),
            p(0.45, 0.88),
            p(0.24, 0.78),
        ]],
        4 => vec![
            vec![p(0.62, 0.88), p(0.62, 0.12), p(0.22, 0.6), p(0.8, 0.6)],
        ],
        5 => vec![vec![
            p(0.75, 0.14),
            p(0.3, 0.14),
            p(0.28, 0.48),
            p(0.68, 0.48),
            p(0.74, 0.7),
            p(0.5, 0.88),
            p(0.25, 0.8),
        ]],
        6 => vec![vec![
            p(0.7, 0.15),
            p(0.35, 0.4),
            p(0.27, 0.7),
            p(0.5, 0.88),
            p(0.73, 0.7),
            p(0.6, 0.5),
            p(0.3, 0.6),
        ]],
        7 => vec![vec![p(0.22, 0.15), p(0.78, 0.15), p(0.42, 0.88)]],
        8 => vec![
            vec![
                p(0.5, 0.12),
                p(0.72, 0.3),
                p(0.3, 0.62),
                p(0.5, 0.88),
                p(0.7, 0.62),
                p(0.28, 0.3),
                p(0.5, 0.12),
            ],
        ],
        9 => vec![vec![
            p(0.7, 0.4),
            p(0.45, 0.5),
            p(0.28, 0.3),
            p(0.48, 0.12),
            p(0.7, 0.3),
            p(0.68, 0.6),
            p(0.5, 0.88),
        ]],
        _ => panic!("digit must be 0..9"),
    }
}

fn dist_to_segment(px: f32, py: f32, ax: f32, ay: f32, bx: f32, by: f32) -> f32 {
    let (dx, dy) = (bx - ax, by - ay);
    let len2 = dx * dx + dy * dy;
    let t = if len2 == 0.0 {
        0.0
    } else {
        (((px - ax) * dx + (py - ay) * dy) / len2).clamp(0.0, 1.0)
    };
    let (cx, cy) = (ax + t * dx, ay + t * dy);
    ((px - cx).powi(2) + (py - cy).powi(2)).sqrt()
}

/// Render one jittered digit into a 28×28 grayscale image in [0,1].
pub fn render_digit(d: u8, rng: &mut Xoshiro256pp) -> Vec<f32> {
    let strokes = digit_strokes(d);
    let angle = rng.range_f64(-0.22, 0.22) as f32; // ±12.6°
    let scale = rng.range_f64(0.85, 1.12) as f32;
    let tx = rng.range_f64(-0.08, 0.08) as f32;
    let ty = rng.range_f64(-0.08, 0.08) as f32;
    let thickness = rng.range_f64(0.045, 0.085) as f32;
    let (sin, cos) = angle.sin_cos();
    // Transform stroke points once.
    let tstrokes: Vec<Vec<(f32, f32)>> = strokes
        .iter()
        .map(|poly| {
            poly.iter()
                .map(|&(x, y)| {
                    let (cx, cy) = (x - 0.5, y - 0.5);
                    let rx = (cx * cos - cy * sin) * scale + 0.5 + tx;
                    let ry = (cx * sin + cy * cos) * scale + 0.5 + ty;
                    (rx, ry)
                })
                .collect()
        })
        .collect();
    let mut img = vec![0f32; 28 * 28];
    for yy in 0..28 {
        for xx in 0..28 {
            let px = (xx as f32 + 0.5) / 28.0;
            let py = (yy as f32 + 0.5) / 28.0;
            let mut dmin = f32::INFINITY;
            for poly in &tstrokes {
                for seg in poly.windows(2) {
                    let d = dist_to_segment(px, py, seg[0].0, seg[0].1, seg[1].0, seg[1].1);
                    if d < dmin {
                        dmin = d;
                    }
                }
            }
            // Soft brush: 1 inside the stroke, smooth falloff at the edge.
            let v = (1.0 - (dmin - thickness) / 0.02).clamp(0.0, 1.0);
            img[yy * 28 + xx] = v;
        }
    }
    // Pixel noise + occasional dead pixels.
    for v in img.iter_mut() {
        *v = (*v + rng.gaussian(0.0, 0.04) as f32).clamp(0.0, 1.0);
    }
    img
}

/// Generate `n` synthetic MNIST-like samples (balanced classes).
pub fn synth_mnist(n: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256pp::seeded(seed);
    let mut images = Tensor::zeros(&[n, 784]);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let d = (i % 10) as u8;
        let img = render_digit(d, &mut rng);
        images.row_mut(i).copy_from_slice(&img);
        labels.push(d);
    }
    // Shuffle so batches are class-mixed.
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let (images, labels) = reorder(&images, &labels, &order);
    Dataset { images, labels, classes: 10 }
}

/// Class-conditional 32×32×3 texture (CIFAR-10 stand-in).
pub fn render_texture(class: u8, rng: &mut Xoshiro256pp) -> Vec<f32> {
    let mut img = vec![0f32; 3 * 32 * 32];
    let freq = rng.range_f64(0.8, 1.3) as f32;
    let phase = rng.range_f64(0.0, std::f32::consts::TAU as f64) as f32;
    let base: [f32; 3] = [
        0.3 + 0.4 * ((class as f32 * 0.7).sin() * 0.5 + 0.5),
        0.3 + 0.4 * ((class as f32 * 1.3 + 1.0).sin() * 0.5 + 0.5),
        0.3 + 0.4 * ((class as f32 * 2.1 + 2.0).sin() * 0.5 + 0.5),
    ];
    for y in 0..32 {
        for x in 0..32 {
            let (fx, fy) = (x as f32 / 32.0, y as f32 / 32.0);
            let pattern = match class % 5 {
                // stripes at class-dependent angle
                0 => (fx * 8.0 * freq + fy * 3.0 + phase).sin() * 0.5 + 0.5,
                // checkerboard
                1 => {
                    let s = ((fx * 6.0 * freq + phase).sin()
                        * (fy * 6.0 * freq + phase).sin())
                        * 0.5
                        + 0.5;
                    s
                }
                // radial blob
                2 => {
                    let d = ((fx - 0.5).powi(2) + (fy - 0.5).powi(2)).sqrt();
                    (1.0 - d * 2.2 * freq).clamp(0.0, 1.0)
                }
                // diagonal gradient + ripples
                3 => ((fx + fy) * 0.5 + 0.18 * (fx * 20.0 * freq + phase).sin()).clamp(0.0, 1.0),
                // vertical bars
                _ => (fy * 10.0 * freq + phase).sin() * 0.5 + 0.5,
            };
            // Second half of the classes invert the pattern so all ten are
            // distinguishable.
            let pattern = if class >= 5 { 1.0 - pattern } else { pattern };
            for c in 0..3 {
                let v = (base[c] * pattern + rng.gaussian(0.0, 0.05) as f32).clamp(0.0, 1.0);
                img[(c * 32 + y) * 32 + x] = v;
            }
        }
    }
    img
}

/// Generate `n` synthetic CIFAR-like samples.
pub fn synth_cifar(n: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256pp::seeded(seed);
    let mut images = Tensor::zeros(&[n, 3 * 32 * 32]);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = (i % 10) as u8;
        let img = render_texture(c, &mut rng);
        images.row_mut(i).copy_from_slice(&img);
        labels.push(c);
    }
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let (images, labels) = reorder(&images, &labels, &order);
    Dataset { images, labels, classes: 10 }
}

fn reorder(images: &Tensor, labels: &[u8], order: &[usize]) -> (Tensor, Vec<u8>) {
    let f = images.cols();
    let mut out = Tensor::zeros(&[order.len(), f]);
    let mut lab = Vec::with_capacity(order.len());
    for (r, &i) in order.iter().enumerate() {
        out.row_mut(r).copy_from_slice(images.row(i));
        lab.push(labels[i]);
    }
    (out, lab)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_shapes_and_balance() {
        let ds = synth_mnist(200, 1);
        assert_eq!(ds.images.shape, vec![200, 784]);
        assert_eq!(ds.labels.len(), 200);
        let mut counts = [0usize; 10];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
        // Pixels in [0,1] and digits have visible ink.
        assert!(ds.images.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let ink: f32 = ds.images.row(0).iter().sum();
        assert!(ink > 10.0, "digit should have ink, got {ink}");
    }

    #[test]
    fn digits_are_distinguishable() {
        // Mean images of different digits should differ substantially.
        let mut rng = Xoshiro256pp::seeded(7);
        let mean_img = |d: u8, rng: &mut Xoshiro256pp| {
            let mut acc = vec![0f32; 784];
            for _ in 0..20 {
                for (a, v) in acc.iter_mut().zip(render_digit(d, rng)) {
                    *a += v / 20.0;
                }
            }
            acc
        };
        let m1 = mean_img(1, &mut rng);
        let m8 = mean_img(8, &mut rng);
        let dist: f32 = m1.iter().zip(&m8).map(|(a, b)| (a - b).abs()).sum();
        assert!(dist > 20.0, "digits 1 and 8 too similar: {dist}");
    }

    #[test]
    fn jitter_produces_variation() {
        let mut rng = Xoshiro256pp::seeded(8);
        let a = render_digit(3, &mut rng);
        let b = render_digit(3, &mut rng);
        let dist: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(dist > 1.0, "two renders of the same digit should differ");
    }

    #[test]
    fn cifar_shapes_and_class_separation() {
        let ds = synth_cifar(100, 2);
        assert_eq!(ds.images.shape, vec![100, 3072]);
        let mut rng = Xoshiro256pp::seeded(9);
        let t0 = render_texture(0, &mut rng);
        let t2 = render_texture(2, &mut rng);
        let dist: f32 = t0.iter().zip(&t2).map(|(a, b)| (a - b).abs()).sum();
        assert!(dist > 50.0, "textures of classes 0 and 2 too similar: {dist}");
    }

    #[test]
    fn batch_extraction() {
        let ds = synth_mnist(50, 3);
        let (x, y) = ds.batch(&[0, 10, 49]);
        assert_eq!(x.shape, vec![3, 784]);
        assert_eq!(y.len(), 3);
        assert_eq!(x.row(1), ds.images.row(10));
        assert_eq!(y[2], ds.labels[49]);
    }

    #[test]
    fn determinism_by_seed() {
        let a = synth_mnist(30, 42);
        let b = synth_mnist(30, 42);
        assert_eq!(a.images.data, b.images.data);
        assert_eq!(a.labels, b.labels);
        let c = synth_mnist(30, 43);
        assert_ne!(a.images.data, c.images.data);
    }
}
