//! Minimal dense tensor used by the NN substrate.
//!
//! Row-major `f32` storage with explicit shapes; the only heavy primitive is
//! [`matmul`], which the training loop and the im2col convolution lowering
//! both reduce to. It is cache-blocked and thread-parallel (see the §Perf
//! log in EXPERIMENTS.md).

use crate::util::threadpool::parallel_chunks;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of rows when viewed as a 2-D matrix `[rows, cols]`.
    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[1]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[r * c..(r + 1) * c]
    }
}

/// C = A[m,k] × B[k,n]. Parallel over rows of A, with a k-blocked inner loop
/// writing linearly into C (good autovectorization on the `n` axis).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    let bdata = &b.data;
    let adata = &a.data;
    // Parallel chunk over output rows; each worker fills disjoint rows.
    let rows: Vec<(usize, Vec<f32>)> = parallel_chunks(m, |range, _| {
        let mut block = vec![0.0f32; range.len() * n];
        for (local, i) in range.clone().enumerate() {
            let arow = &adata[i * k..(i + 1) * k];
            let crow = &mut block[local * n..(local + 1) * n];
            for (p, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &bdata[p * n..(p + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
        (range.start, block)
    });
    for (start, block) in rows {
        let rows_here = block.len() / n;
        out.data[start * n..start * n + rows_here * n].copy_from_slice(&block);
    }
    out
}

/// C = Aᵀ[k,m]ᵀ... i.e. `matmul_tn(a, b) = aᵀ × b` with `a: [k, m]`,
/// `b: [k, n]` → `[m, n]`. Used for weight gradients without materializing
/// transposes.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2);
    let mut out = Tensor::zeros(&[m, n]);
    for p in 0..k {
        let arow = &a.data[p * m..(p + 1) * m];
        let brow = &b.data[p * n..(p + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut out.data[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    out
}

/// C = A[m,k] × Bᵀ with `b: [n, k]` → `[m, n]`. Used for input gradients.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let (n, k2) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2);
    let mut out = Tensor::zeros(&[m, n]);
    let rows: Vec<(usize, Vec<f32>)> = parallel_chunks(m, |range, _| {
        let mut block = vec![0.0f32; range.len() * n];
        for (local, i) in range.clone().enumerate() {
            let arow = &a.data[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &b.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += arow[p] * brow[p];
                }
                block[local * n + j] = acc;
            }
        }
        (range.start, block)
    });
    for (start, block) in rows {
        let rows_here = block.len() / n;
        out.data[start * n..start * n + rows_here * n].copy_from_slice(&block);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::checks::assert_allclose;
    use crate::util::rng::Xoshiro256pp;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.shape[0], a.shape[1], b.shape[1]);
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a.data[i * k + p] * b.data[p * n + j];
                }
                c.data[i * n + j] = acc;
            }
        }
        c
    }

    fn random_tensor(shape: &[usize], rng: &mut Xoshiro256pp) -> Tensor {
        let n = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.gaussian(0.0, 1.0) as f32).collect())
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Xoshiro256pp::seeded(1);
        for (m, k, n) in [(1, 1, 1), (3, 4, 5), (17, 9, 23), (64, 32, 16)] {
            let a = random_tensor(&[m, k], &mut rng);
            let b = random_tensor(&[k, n], &mut rng);
            let fast = matmul(&a, &b);
            let slow = naive_matmul(&a, &b);
            assert_allclose(&fast.data, &slow.data, 1e-4);
        }
    }

    #[test]
    fn matmul_tn_and_nt_match_transposed_naive() {
        let mut rng = Xoshiro256pp::seeded(2);
        let a = random_tensor(&[7, 5], &mut rng); // k=7, m=5
        let b = random_tensor(&[7, 3], &mut rng); // k=7, n=3
        let got = matmul_tn(&a, &b);
        // aT: [5,7]
        let mut at = Tensor::zeros(&[5, 7]);
        for i in 0..7 {
            for j in 0..5 {
                at.data[j * 7 + i] = a.data[i * 5 + j];
            }
        }
        let expect = naive_matmul(&at, &b);
        assert_allclose(&got.data, &expect.data, 1e-4);

        let x = random_tensor(&[4, 6], &mut rng);
        let y = random_tensor(&[9, 6], &mut rng);
        let got = matmul_nt(&x, &y);
        let mut yt = Tensor::zeros(&[6, 9]);
        for i in 0..9 {
            for j in 0..6 {
                yt.data[j * 9 + i] = y.data[i * 6 + j];
            }
        }
        let expect = naive_matmul(&x, &yt);
        assert_allclose(&got.data, &expect.data, 1e-4);
    }

    #[test]
    fn reshape_and_rows() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.row(2), &[5., 6.]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }
}
