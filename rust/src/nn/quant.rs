//! Post-training int8 quantization and VOS-noise-aware quantized inference.
//!
//! The baseline TPU runs 8-bit fixed-point inference (paper §IV.A). This
//! module converts a trained float [`Model`] into symmetric-int8 form
//! (per-layer weight scale + calibrated activation scale) and provides the
//! quantized forward pass with **per-neuron error injection in the integer
//! product domain** — the exact domain where the gate-level multiplier
//! errors live, so the statistical error models plug in without unit
//! conversion: a neuron at voltage `v` with fan-in `k` receives additive
//! noise `N(k·μ_v, k·σ²_v)` on its accumulator (paper eqs 10–13).
//!
//! The MAC arithmetic itself lives in [`crate::exec`]: every layer is
//! lowered to one batched [`Backend::execute_layer`] call (dense layers
//! directly, convolutions via im2col over all samples × spatial positions),
//! so quantized inference shares the tiled kernel with the simulator and
//! the serving engine instead of carrying its own per-unit loops.

use super::layers::Activation;
use super::model::{DataShape, Layer, Model};
use super::tensor::Tensor;
use crate::exec::dispatch::SimdPath;
use crate::exec::kernel::PackedLayer;
use crate::exec::{Backend, Exact, NoiseView};
use crate::util::rng::Xoshiro256pp;

/// Per-neuron injected-noise specification, indexed like
/// [`Model::neurons`]. `mean`/`std` are in integer-product units.
#[derive(Clone, Debug, Default)]
pub struct NoiseSpec {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

impl NoiseSpec {
    pub fn silent(n: usize) -> Self {
        Self { mean: vec![0.0; n], std: vec![0.0; n] }
    }

    pub fn is_silent(&self) -> bool {
        self.std.iter().all(|&s| s == 0.0) && self.mean.iter().all(|&m| m == 0.0)
    }

    /// The noise spec implied by a per-neuron voltage-level assignment
    /// (paper eqs 12–13): neuron `n` at level `l` with fan-in `k` receives
    /// `N(k·μ_l, k·σ²_l)` on its accumulator.
    pub fn from_levels(
        levels: &[usize],
        fan_in: &[usize],
        registry: &crate::errormodel::ErrorModelRegistry,
    ) -> Self {
        Self::from_levels_for_mode(
            levels,
            fan_in,
            registry,
            crate::errormodel::PlanMode::Statistical,
        )
    }

    /// [`Self::from_levels`] with the column moments priced under an
    /// explicit operating regime: the statistical regime composes the
    /// characterized `(μ_v, σ²_v)`, the TE-Drop regime composes the
    /// dropped-product moments `(0, p_v·M₂)` — the same moment-matched
    /// Gaussian approximation the serving path uses for either regime.
    pub fn from_levels_for_mode(
        levels: &[usize],
        fan_in: &[usize],
        registry: &crate::errormodel::ErrorModelRegistry,
        mode: crate::errormodel::PlanMode,
    ) -> Self {
        assert_eq!(levels.len(), fan_in.len(), "one fan-in per neuron");
        let mut spec = Self::silent(levels.len());
        for (n, (&lvl, &k)) in levels.iter().zip(fan_in).enumerate() {
            let m = registry.model(lvl);
            spec.mean[n] = mode.column_mean(m, k);
            spec.std[n] = mode.column_variance(m, k).sqrt();
        }
        spec
    }

    /// Reconstruct the noise spec a deployable
    /// [`VoltagePlan`](crate::plan::VoltagePlan) encodes, under the given
    /// registry — the online half of the offline-solve / online-serve
    /// split. Priced under the plan's operating regime, so a TE-Drop plan
    /// serves with the (bounded) dropped-product moments its solve assumed.
    pub fn from_plan(
        plan: &crate::plan::VoltagePlan,
        registry: &crate::errormodel::ErrorModelRegistry,
    ) -> Self {
        Self::from_levels_for_mode(&plan.level, &plan.fan_in, registry, plan.plan_mode())
    }

    /// Per-MAC-layer liveness of this spec over the given layer widths
    /// (from [`QuantizedModel::mac_widths`]): `true` iff the layer's slice
    /// carries any nonzero mean or std — exactly the predicate the layer
    /// executor's per-call scan applies, hoisted to once per generation so
    /// the serving loop can skip both the scan and the key draw on silent
    /// layers without perturbing any RNG stream.
    pub fn layer_liveness(&self, widths: &[usize]) -> Vec<bool> {
        let mut base = 0;
        widths
            .iter()
            .map(|&w| {
                let live = self.mean[base..base + w].iter().any(|&v| v != 0.0)
                    || self.std[base..base + w].iter().any(|&v| v != 0.0);
                base += w;
                live
            })
            .collect()
    }
}

/// A quantized MAC layer: weights int8, `w[u]·x ≈ Σ wq·xq · (sw·sx)`.
#[derive(Clone, Debug)]
pub struct QuantMac {
    /// int8 weights `[out, fan_in]` row-major.
    pub wq: Vec<i8>,
    pub fan_in: usize,
    pub out: usize,
    pub w_scale: f32,
    /// Calibrated input activation scale.
    pub x_scale: f32,
    pub bias: Vec<f32>,
    pub act: Activation,
}

impl QuantMac {
    fn quantize_weights(w: &[f32], fan_in: usize, out: usize) -> (Vec<i8>, f32) {
        let max_abs = w.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1e-12);
        let scale = max_abs / 127.0;
        let wq = w.iter().map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8).collect();
        let _ = (fan_in, out);
        (wq, scale)
    }

    /// Quantize an input row to int8 with this layer's activation scale.
    #[inline]
    fn quantize_input(&self, x: &[f32], out: &mut [i8]) {
        let s = self.x_scale.max(1e-12);
        for (o, &v) in out.iter_mut().zip(x) {
            *o = (v / s).round().clamp(-127.0, 127.0) as i8;
        }
    }

    /// Dequantize an accumulator value.
    #[inline]
    fn dequant(&self, acc: f64, unit: usize) -> f32 {
        (acc as f32) * self.w_scale * self.x_scale + self.bias[unit]
    }
}

/// Structure of the quantized network (mirrors [`Model`] layer-for-layer).
#[derive(Clone, Debug)]
pub enum QLayer {
    Dense(QuantMac),
    Conv {
        mac: QuantMac,
        cin: usize,
        k: usize,
        pad: usize,
        h: usize,
        w: usize,
    },
    Pool {
        channels: usize,
        h: usize,
        w: usize,
    },
    /// Residual block: conv1, conv2, optional projection; spatial dims.
    Res {
        conv1: Box<QLayer>,
        conv2: Box<QLayer>,
        proj: Option<Box<QLayer>>,
    },
}

/// Quantized model with the neuron enumeration aligned to [`Model::neurons`].
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub name: String,
    pub layers: Vec<QLayer>,
    pub input: DataShape,
    pub output_dim: usize,
    /// fan_in per neuron (flat enumeration), for assignment bookkeeping.
    pub neuron_fan_in: Vec<usize>,
}

/// Calibrate activation scales: run `calib` through the float model and
/// record the max |input| entering each MAC layer (including those inside
/// residual blocks, in enumeration order).
fn calibrate_scales(model: &mut Model, calib: &Tensor) -> Vec<f32> {
    // Forward manually, mirroring Model::forward, recording scales.
    let mut scales = Vec::new();
    let mut cur = calib.clone();
    let mut shape = model.input;
    let max_abs = |t: &Tensor| t.data.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1e-6);
    for layer in model.layers.iter_mut() {
        match layer {
            Layer::Dense(d) => {
                scales.push(max_abs(&cur) / 127.0);
                cur = d.forward(&cur, false);
                shape = DataShape::Flat(d.out_f);
            }
            Layer::Conv(c) => {
                let (_, h, w) = spatial(shape);
                scales.push(max_abs(&cur) / 127.0);
                cur = c.forward(&cur, h, w, false);
                let (ho, wo) = c.out_hw(h, w);
                shape = DataShape::Spatial(c.cout, ho, wo);
            }
            Layer::Pool(p) => {
                let (c, h, w) = spatial(shape);
                cur = p.forward(&cur, h, w, false);
                shape = DataShape::Spatial(c, h / 2, w / 2);
            }
            Layer::Res(r) => {
                let (_, h, w) = spatial(shape);
                let s_in = max_abs(&cur) / 127.0;
                scales.push(s_in); // conv1 input
                let a = r.conv1.forward(&cur, h, w, false);
                scales.push(max_abs(&a) / 127.0); // conv2 input
                if r.proj.is_some() {
                    scales.push(s_in); // proj input = block input
                }
                cur = r.forward(&cur, h, w, false);
                shape = DataShape::Spatial(r.conv2.cout, h, w);
            }
        }
    }
    scales
}

fn spatial(s: DataShape) -> (usize, usize, usize) {
    match s {
        DataShape::Spatial(c, h, w) => (c, h, w),
        _ => panic!("expected spatial shape"),
    }
}

impl QuantizedModel {
    /// Quantize a trained model, calibrating activation scales on `calib`
    /// (a representative input batch).
    pub fn quantize(model: &Model, calib: &Tensor) -> Self {
        let mut m = model.clone();
        let scales = calibrate_scales(&mut m, calib);
        let mut si = 0usize;
        let mut next_scale = || {
            let s = scales[si];
            si += 1;
            s
        };
        let mut layers = Vec::new();
        let mut shape = model.input;
        let mut neuron_fan_in = Vec::new();
        let conv_to_q = |c: &super::layers::Conv2d,
                             h: usize,
                             w: usize,
                             x_scale: f32,
                             fan_acc: &mut Vec<usize>| {
            let fan_in = c.cin * c.k * c.k;
            let (wq, w_scale) = QuantMac::quantize_weights(&c.w, fan_in, c.cout);
            for _ in 0..c.cout {
                fan_acc.push(fan_in);
            }
            QLayer::Conv {
                mac: QuantMac {
                    wq,
                    fan_in,
                    out: c.cout,
                    w_scale,
                    x_scale,
                    bias: c.b.clone(),
                    act: c.act,
                },
                cin: c.cin,
                k: c.k,
                pad: c.pad,
                h,
                w,
            }
        };
        for layer in &model.layers {
            match layer {
                Layer::Dense(d) => {
                    let (wq, w_scale) = QuantMac::quantize_weights(&d.w, d.in_f, d.out_f);
                    for _ in 0..d.out_f {
                        neuron_fan_in.push(d.in_f);
                    }
                    layers.push(QLayer::Dense(QuantMac {
                        wq,
                        fan_in: d.in_f,
                        out: d.out_f,
                        w_scale,
                        x_scale: next_scale(),
                        bias: d.b.clone(),
                        act: d.act,
                    }));
                    shape = DataShape::Flat(d.out_f);
                }
                Layer::Conv(c) => {
                    let (_, h, w) = spatial(shape);
                    let s = next_scale();
                    layers.push(conv_to_q(c, h, w, s, &mut neuron_fan_in));
                    let (ho, wo) = c.out_hw(h, w);
                    shape = DataShape::Spatial(c.cout, ho, wo);
                }
                Layer::Pool(p) => {
                    let (c, h, w) = spatial(shape);
                    layers.push(QLayer::Pool { channels: p.channels, h, w });
                    shape = DataShape::Spatial(c, h / 2, w / 2);
                }
                Layer::Res(r) => {
                    let (_, h, w) = spatial(shape);
                    let s1 = next_scale();
                    let q1 = conv_to_q(&r.conv1, h, w, s1, &mut neuron_fan_in);
                    let s2 = next_scale();
                    let q2 = conv_to_q(&r.conv2, h, w, s2, &mut neuron_fan_in);
                    let qp = r.proj.as_ref().map(|p| {
                        let sp = next_scale();
                        Box::new(conv_to_q(p, h, w, sp, &mut neuron_fan_in))
                    });
                    layers.push(QLayer::Res { conv1: Box::new(q1), conv2: Box::new(q2), proj: qp });
                    shape = DataShape::Spatial(r.conv2.cout, h, w);
                }
            }
        }
        QuantizedModel {
            name: model.name.clone(),
            layers,
            input: model.input,
            output_dim: model.output_dim,
            neuron_fan_in,
        }
    }

    pub fn num_neurons(&self) -> usize {
        self.neuron_fan_in.len()
    }

    /// Output widths of every MAC layer in neuron-enumeration order
    /// (recursing into residual blocks: conv1, conv2, projection) — the
    /// spans [`NoiseSpec::layer_liveness`] is computed over.
    pub fn mac_widths(&self) -> Vec<usize> {
        fn walk(l: &QLayer, acc: &mut Vec<usize>) {
            match l {
                QLayer::Dense(m) => acc.push(m.out),
                QLayer::Conv { mac, .. } => acc.push(mac.out),
                QLayer::Pool { .. } => {}
                QLayer::Res { conv1, conv2, proj } => {
                    walk(conv1, acc);
                    walk(conv2, acc);
                    if let Some(p) = proj {
                        walk(p, acc);
                    }
                }
            }
        }
        let mut acc = Vec::new();
        for l in &self.layers {
            walk(l, &mut acc);
        }
        acc
    }

    /// Quantized forward pass with optional per-neuron noise injection on
    /// the default [`Exact`] kernel backend. `noise` must be indexed like
    /// [`Model::neurons`]; `rng` is used only when noise is present.
    pub fn forward(
        &self,
        x: &Tensor,
        noise: Option<&NoiseSpec>,
        rng: &mut Xoshiro256pp,
    ) -> Tensor {
        self.forward_with(&Exact, x, noise, rng)
    }

    /// Quantized forward pass on an explicit execution [`Backend`] — the
    /// seam the coordinator and the serving engine select backends through.
    /// Backends are `Sync` and taken by `&self`, so concurrent forward
    /// passes (e.g. the serving engine's batch workers) can share one.
    pub fn forward_with(
        &self,
        backend: &dyn Backend,
        x: &Tensor,
        noise: Option<&NoiseSpec>,
        rng: &mut Xoshiro256pp,
    ) -> Tensor {
        if let Some(ns) = noise {
            assert_eq!(ns.mean.len(), self.num_neurons(), "noise spec length");
            assert_eq!(ns.std.len(), self.num_neurons(), "noise spec length");
        }
        let batch = x.shape[0];
        let mut cur = x.clone();
        // Process layer by layer; track the neuron base index.
        let mut neuron_base = 0;
        for layer in &self.layers {
            cur = self.forward_layer(backend, layer, &cur, batch, &mut neuron_base, noise, rng);
        }
        cur
    }

    /// The per-neuron noise slice of one MAC layer, if any of it is live.
    fn layer_noise<'a>(
        noise: Option<&'a NoiseSpec>,
        base: usize,
        out: usize,
    ) -> Option<NoiseView<'a>> {
        noise.map(|ns| NoiseView::new(&ns.mean[base..base + out], &ns.std[base..base + out]))
    }

    #[allow(clippy::too_many_arguments)]
    fn forward_layer(
        &self,
        backend: &dyn Backend,
        layer: &QLayer,
        cur: &Tensor,
        batch: usize,
        neuron_base: &mut usize,
        noise: Option<&NoiseSpec>,
        rng: &mut Xoshiro256pp,
    ) -> Tensor {
        match layer {
            QLayer::Dense(mac) => {
                // Quantize the whole batch, then one backend call.
                let mut xq = vec![0i8; batch * mac.fan_in];
                for r in 0..batch {
                    mac.quantize_input(
                        cur.row(r),
                        &mut xq[r * mac.fan_in..(r + 1) * mac.fan_in],
                    );
                }
                let nv = Self::layer_noise(noise, *neuron_base, mac.out);
                let acc = backend.execute_layer(mac, &xq, batch, nv, rng);
                let mut y = Tensor::zeros(&[batch, mac.out]);
                for r in 0..batch {
                    let dst = y.row_mut(r);
                    for (u, d) in dst.iter_mut().enumerate() {
                        *d = mac.act.apply(mac.dequant(acc[r * mac.out + u] as f64, u));
                    }
                }
                *neuron_base += mac.out;
                y
            }
            QLayer::Conv { mac, cin, k, pad, h, w } => {
                let y = self.conv_forward(
                    backend,
                    mac,
                    *cin,
                    *k,
                    *pad,
                    *h,
                    *w,
                    cur,
                    batch,
                    *neuron_base,
                    noise,
                    rng,
                );
                *neuron_base += mac.out;
                y
            }
            QLayer::Pool { channels, h, w } => {
                let (ho, wo) = (h / 2, w / 2);
                let c = *channels;
                let mut y = Tensor::zeros(&[batch, c * ho * wo]);
                for s in 0..batch {
                    let img = cur.row(s);
                    let dst = y.row_mut(s);
                    for ch in 0..c {
                        for oy in 0..ho {
                            for ox in 0..wo {
                                let mut best = f32::NEG_INFINITY;
                                for dy in 0..2 {
                                    for dx in 0..2 {
                                        let v = img[(ch * h + oy * 2 + dy) * w + ox * 2 + dx];
                                        if v > best {
                                            best = v;
                                        }
                                    }
                                }
                                dst[(ch * ho + oy) * wo + ox] = best;
                            }
                        }
                    }
                }
                y
            }
            QLayer::Res { conv1, conv2, proj } => {
                let a = self.forward_layer(backend, conv1, cur, batch, neuron_base, noise, rng);
                let mut y =
                    self.forward_layer(backend, conv2, &a, batch, neuron_base, noise, rng);
                let skip = match proj {
                    Some(p) => {
                        self.forward_layer(backend, p, cur, batch, neuron_base, noise, rng)
                    }
                    None => cur.clone(),
                };
                for (v, &s) in y.data.iter_mut().zip(&skip.data) {
                    *v = (*v + s).max(0.0);
                }
                y
            }
        }
    }

    /// Convolution as batched MAC-layer executions: quantized im2col over
    /// (sample, output position) rows, driven through
    /// [`Backend::execute_layer`] in bounded row blocks (noise is per
    /// output *channel*, one draw per row × channel; the block size is a
    /// fixed constant, so the per-block keyed draw streams are independent
    /// of `XTPU_THREADS`), then a scatter back into channel-major layout.
    #[allow(clippy::too_many_arguments)]
    fn conv_forward(
        &self,
        backend: &dyn Backend,
        mac: &QuantMac,
        cin: usize,
        k: usize,
        pad: usize,
        h: usize,
        w: usize,
        cur: &Tensor,
        batch: usize,
        neuron_base: usize,
        noise: Option<&NoiseSpec>,
        rng: &mut Xoshiro256pp,
    ) -> Tensor {
        let ho = h + 2 * pad + 1 - k;
        let wo = w + 2 * pad + 1 - k;
        let fan_in = cin * k * k;
        let total_rows = batch * ho * wo;
        // Bound the im2col working set (block × fan_in i8 + block × out
        // i32) instead of materializing every row of the whole batch.
        const ROW_BLOCK: usize = 4096;
        let block = ROW_BLOCK.min(total_rows.max(1));
        let mut patches = vec![0i8; block * fan_in];
        let s_in = mac.x_scale.max(1e-12);
        let nv = Self::layer_noise(noise, neuron_base, mac.out);
        let mut y = Tensor::zeros(&[batch, mac.out * ho * wo]);
        let mut row0 = 0;
        while row0 < total_rows {
            let rows = (total_rows - row0).min(block);
            for r in 0..rows {
                let row = row0 + r;
                let s = row / (ho * wo);
                let rem = row % (ho * wo);
                let (oy, ox) = (rem / wo, rem % wo);
                let img = cur.row(s);
                let patch = &mut patches[r * fan_in..(r + 1) * fan_in];
                let mut pi = 0;
                for c in 0..cin {
                    for ky in 0..k {
                        let iy = oy as isize + ky as isize - pad as isize;
                        for kx in 0..k {
                            let ix = ox as isize + kx as isize - pad as isize;
                            patch[pi] = if iy < 0
                                || iy >= h as isize
                                || ix < 0
                                || ix >= w as isize
                            {
                                0
                            } else {
                                (img[(c * h + iy as usize) * w + ix as usize] / s_in)
                                    .round()
                                    .clamp(-127.0, 127.0)
                                    as i8
                            };
                            pi += 1;
                        }
                    }
                }
            }
            let acc = backend.execute_layer(mac, &patches[..rows * fan_in], rows, nv, rng);
            for r in 0..rows {
                let row = row0 + r;
                let s = row / (ho * wo);
                let rem = row % (ho * wo);
                let (oy, ox) = (rem / wo, rem % wo);
                let dst = y.row_mut(s);
                for u in 0..mac.out {
                    dst[(u * ho + oy) * wo + ox] =
                        mac.act.apply(mac.dequant(acc[r * mac.out + u] as f64, u));
                }
            }
            row0 += rows;
        }
        y
    }

    /// Quantized forward pass against a persistent [`PackedModel`], with
    /// every intermediate buffer drawn from a caller-owned [`ForwardArena`]
    /// and the logits written into `out` — the zero-repack, (near)
    /// allocation-free serving path. Bit-identical to [`forward_with`] on
    /// the same backend: quantization, accumulation, noise streams, and
    /// dequantization are shared step for step, only the weight layout work
    /// and the per-call buffers disappear.
    ///
    /// `layer_live`, when given, must hold the per-MAC-layer liveness of
    /// `noise` ([`NoiseSpec::layer_liveness`] over [`Self::mac_widths`]) —
    /// the once-per-generation precompute that lets silent layers skip the
    /// per-call scan without touching any RNG stream. Models that are not a
    /// pure dense chain fall back to [`forward_with`] (convolutions re-run
    /// im2col per call anyway); the arena still absorbs the output copy.
    ///
    /// [`forward_with`]: Self::forward_with
    #[allow(clippy::too_many_arguments)]
    pub fn forward_prepacked(
        &self,
        backend: &dyn Backend,
        x: &Tensor,
        noise: Option<&NoiseSpec>,
        layer_live: Option<&[bool]>,
        rng: &mut Xoshiro256pp,
        packed: &PackedModel,
        arena: &mut ForwardArena,
        out: &mut Vec<f32>,
    ) {
        if let Some(ns) = noise {
            assert_eq!(ns.mean.len(), self.num_neurons(), "noise spec length");
            assert_eq!(ns.std.len(), self.num_neurons(), "noise spec length");
        }
        if !packed.dense_chain() {
            let y = self.forward_with(backend, x, noise, rng);
            out.clear();
            out.extend_from_slice(&y.data);
            return;
        }
        let batch = x.shape[0];
        arena.cur.clear();
        arena.cur.extend_from_slice(&x.data);
        let mut neuron_base = 0;
        for (i, layer) in self.layers.iter().enumerate() {
            let QLayer::Dense(mac) = layer else {
                unreachable!("dense_chain model holds only dense layers")
            };
            let pl = packed.layer(i).expect("packed dense layer");
            arena.xq.clear();
            arena.xq.resize(batch * mac.fan_in, 0);
            for r in 0..batch {
                mac.quantize_input(
                    &arena.cur[r * mac.fan_in..(r + 1) * mac.fan_in],
                    &mut arena.xq[r * mac.fan_in..(r + 1) * mac.fan_in],
                );
            }
            // A stale liveness flag would desynchronize the key draw from
            // the per-call path, so the contract is equality, not a hint.
            let live = layer_live.map_or(true, |lv| lv[i]);
            let nv = if live { Self::layer_noise(noise, neuron_base, mac.out) } else { None };
            backend.execute_layer_prepacked(mac, pl, &arena.xq, batch, nv, rng, &mut arena.acc);
            arena.next.clear();
            arena.next.extend(
                arena
                    .acc
                    .iter()
                    .enumerate()
                    .map(|(j, &a)| mac.act.apply(mac.dequant(a as f64, j % mac.out))),
            );
            std::mem::swap(&mut arena.cur, &mut arena.next);
            neuron_base += mac.out;
        }
        out.clear();
        out.extend_from_slice(&arena.cur);
    }
}

/// Persistent SIMD-packed weights for a whole [`QuantizedModel`]: one
/// [`PackedLayer`] per dense layer, built **once** per (model, path) —
/// at engine construction or plan hot-swap, never per batch. Immutable
/// after construction, so serving snapshots share it through an `Arc` with
/// no lock on the batch path.
#[derive(Debug)]
pub struct PackedModel {
    path: SimdPath,
    /// Indexed like [`QuantizedModel::layers`]; `None` for non-dense layers.
    layers: Vec<Option<PackedLayer>>,
    dense_chain: bool,
}

impl PackedModel {
    /// Pack every dense layer of `q` for `path` (sanitized to the host's
    /// abilities, like every kernel entry).
    pub fn pack(q: &QuantizedModel, path: SimdPath) -> Self {
        let path = crate::exec::dispatch::sanitize(path);
        let layers = q
            .layers
            .iter()
            .map(|l| match l {
                QLayer::Dense(mac) => {
                    Some(PackedLayer::pack(path, &mac.wq, mac.fan_in, mac.out))
                }
                _ => None,
            })
            .collect();
        let dense_chain = q.layers.iter().all(|l| matches!(l, QLayer::Dense(_)));
        Self { path, layers, dense_chain }
    }

    pub fn path(&self) -> SimdPath {
        self.path
    }

    /// Is the model a pure dense chain (the shape the repack-free forward
    /// serves; anything else falls back to the general path)?
    pub fn dense_chain(&self) -> bool {
        self.dense_chain
    }

    /// The packed weights of layer `i`, if it is dense.
    pub fn layer(&self, i: usize) -> Option<&PackedLayer> {
        self.layers.get(i).and_then(|l| l.as_ref())
    }
}

/// Reusable per-worker buffers for [`QuantizedModel::forward_prepacked`]:
/// quantized activations, raw accumulators, and the ping-pong float
/// activation pair. Capacity is retained across batches, so a warm worker
/// loop runs the whole forward pass without heap traffic.
#[derive(Debug, Default)]
pub struct ForwardArena {
    xq: Vec<i8>,
    acc: Vec<i32>,
    cur: Vec<f32>,
    next: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::data::synth_mnist;
    use crate::nn::model::{fc_mnist, lenet5, resnet_tiny};
    use crate::nn::train::{evaluate, train, TrainConfig};
    use crate::util::checks::assert_allclose;

    fn trained_fc() -> (Model, crate::nn::data::Dataset) {
        let mut rng = Xoshiro256pp::seeded(31);
        let mut model = fc_mnist(Activation::Relu, &mut rng);
        let train_set = synth_mnist(600, 51);
        train(
            &mut model,
            &train_set,
            &TrainConfig { epochs: 3, lr: 0.08, ..Default::default() },
        );
        (model, synth_mnist(200, 52))
    }

    #[test]
    fn quantized_matches_float_closely() {
        let (mut model, test) = trained_fc();
        let calib = test.batch(&(0..64).collect::<Vec<_>>()).0;
        let q = QuantizedModel::quantize(&model, &calib);
        let mut rng = Xoshiro256pp::seeded(1);
        let (x, _) = test.batch(&(0..32).collect::<Vec<_>>());
        let yf = model.forward(&x, false);
        let yq = q.forward(&x, None, &mut rng);
        // int8 quantization error is small relative to logit magnitudes.
        let max_logit = yf.data.iter().fold(0f32, |m, &v| m.max(v.abs()));
        for (a, b) in yf.data.iter().zip(&yq.data) {
            assert!((a - b).abs() < 0.1 * max_logit + 0.5, "float {a} vs quant {b}");
        }
    }

    #[test]
    fn quantized_accuracy_close_to_float() {
        let (mut model, test) = trained_fc();
        let calib = test.batch(&(0..64).collect::<Vec<_>>()).0;
        let q = QuantizedModel::quantize(&model, &calib);
        let float_acc = evaluate(&mut model, &test, 64);
        let mut rng = Xoshiro256pp::seeded(2);
        let idx: Vec<usize> = (0..test.len()).collect();
        let mut correct = 0usize;
        for chunk in idx.chunks(64) {
            let (x, y) = test.batch(chunk);
            let logits = q.forward(&x, None, &mut rng);
            correct +=
                (crate::nn::train::batch_accuracy(&logits, &y) * y.len() as f64) as usize;
        }
        let q_acc = correct as f64 / test.len() as f64;
        assert!((float_acc - q_acc).abs() < 0.05, "float {float_acc} quant {q_acc}");
    }

    #[test]
    fn silent_noise_equals_no_noise() {
        let (model, test) = trained_fc();
        let calib = test.batch(&(0..32).collect::<Vec<_>>()).0;
        let q = QuantizedModel::quantize(&model, &calib);
        let (x, _) = test.batch(&[0, 1, 2]);
        let mut rng1 = Xoshiro256pp::seeded(3);
        let mut rng2 = Xoshiro256pp::seeded(3);
        let a = q.forward(&x, None, &mut rng1);
        let spec = NoiseSpec::silent(q.num_neurons());
        assert!(spec.is_silent());
        let b = q.forward(&x, Some(&spec), &mut rng2);
        assert_allclose(&a.data, &b.data, 1e-9);
    }

    #[test]
    fn noise_degrades_output_monotonically() {
        let (model, test) = trained_fc();
        let calib = test.batch(&(0..32).collect::<Vec<_>>()).0;
        let q = QuantizedModel::quantize(&model, &calib);
        let (x, _) = test.batch(&(0..16).collect::<Vec<_>>());
        let mut rng = Xoshiro256pp::seeded(4);
        let clean = q.forward(&x, None, &mut rng);
        let mse = |a: &Tensor, b: &Tensor| {
            a.data.iter().zip(&b.data).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>()
                / a.data.len() as f64
        };
        let mut last = 0.0;
        for std in [50.0, 500.0, 5000.0] {
            let mut spec = NoiseSpec::silent(q.num_neurons());
            spec.std.iter_mut().for_each(|s| *s = std);
            let mut rng = Xoshiro256pp::seeded(5);
            let noisy = q.forward(&x, Some(&spec), &mut rng);
            let m = mse(&clean, &noisy);
            assert!(m > last, "MSE must grow with noise std: {m} vs {last}");
            last = m;
        }
    }

    #[test]
    fn neuron_enumeration_matches_model() {
        let mut rng = Xoshiro256pp::seeded(6);
        for model in [lenet5(&mut rng), resnet_tiny(&mut rng)] {
            let input_len = model.input.numel();
            let calib = Tensor::zeros(&[2, input_len]);
            let q = QuantizedModel::quantize(&model, &calib);
            let neurons = model.neurons();
            assert_eq!(q.num_neurons(), neurons.len(), "{}", model.name);
            for (qf, n) in q.neuron_fan_in.iter().zip(&neurons) {
                assert_eq!(*qf, n.fan_in);
            }
        }
    }

    #[test]
    fn forward_prepacked_bit_matches_forward_with() {
        let (model, test) = trained_fc();
        let calib = test.batch(&(0..32).collect::<Vec<_>>()).0;
        let q = QuantizedModel::quantize(&model, &calib);
        let (x, _) = test.batch(&(0..24).collect::<Vec<_>>());
        let mut spec = NoiseSpec::silent(q.num_neurons());
        for (i, s) in spec.std.iter_mut().enumerate() {
            if i % 5 == 0 {
                *s = 300.0;
            }
        }
        let widths = q.mac_widths();
        assert_eq!(widths, vec![128, 10]);
        for path in crate::exec::dispatch::available() {
            let packed = PackedModel::pack(&q, path);
            assert!(packed.dense_chain());
            let mut arena = ForwardArena::default();
            let mut out = Vec::new();
            for noise in [None, Some(&spec)] {
                let live = noise.map(|ns| ns.layer_liveness(&widths));
                let mut rng_a = Xoshiro256pp::seeded(60);
                let mut rng_b = Xoshiro256pp::seeded(60);
                let want = q.forward_with(&Exact, &x, noise, &mut rng_a);
                q.forward_prepacked(
                    &Exact,
                    &x,
                    noise,
                    live.as_deref(),
                    &mut rng_b,
                    &packed,
                    &mut arena,
                    &mut out,
                );
                assert_eq!(want.data.len(), out.len());
                for (a, b) in want.data.iter().zip(&out) {
                    assert_eq!(a.to_bits(), b.to_bits(), "path {}", path.name());
                }
                // Both paths must leave the stream in the same position.
                assert_eq!(rng_a.next_u64(), rng_b.next_u64());
            }
        }
    }

    #[test]
    fn forward_prepacked_falls_back_on_conv_models() {
        let mut rng = Xoshiro256pp::seeded(61);
        let model = lenet5(&mut rng);
        let input_len = model.input.numel();
        let calib = Tensor::zeros(&[2, input_len]);
        let q = QuantizedModel::quantize(&model, &calib);
        let packed = PackedModel::pack(&q, crate::exec::dispatch::active());
        assert!(!packed.dense_chain());
        let x = Tensor::zeros(&[2, input_len]);
        let mut rng_a = Xoshiro256pp::seeded(62);
        let mut rng_b = Xoshiro256pp::seeded(62);
        let want = q.forward_with(&Exact, &x, None, &mut rng_a);
        let (mut arena, mut out) = (ForwardArena::default(), Vec::new());
        q.forward_prepacked(&Exact, &x, None, None, &mut rng_b, &packed, &mut arena, &mut out);
        assert_eq!(want.data, out);
    }

    #[test]
    fn layer_liveness_matches_slices() {
        let widths = [4usize, 3, 2];
        let mut spec = NoiseSpec::silent(9);
        spec.std[5] = 1.0; // second layer (indices 4..7)
        assert_eq!(spec.layer_liveness(&widths), vec![false, true, false]);
        spec.mean[8] = -0.5; // third layer (indices 7..9)
        assert_eq!(spec.layer_liveness(&widths), vec![false, true, true]);
    }

    #[test]
    fn noise_on_single_output_neuron_only_moves_that_logit() {
        let (model, test) = trained_fc();
        let calib = test.batch(&(0..32).collect::<Vec<_>>()).0;
        let q = QuantizedModel::quantize(&model, &calib);
        let (x, _) = test.batch(&[0]);
        let mut rng = Xoshiro256pp::seeded(7);
        let clean = q.forward(&x, None, &mut rng);
        let mut spec = NoiseSpec::silent(q.num_neurons());
        // Neuron 128+3 is output logit 3 in the FC enumeration.
        spec.std[128 + 3] = 10000.0;
        let mut rng = Xoshiro256pp::seeded(8);
        let noisy = q.forward(&x, Some(&spec), &mut rng);
        for c in 0..10 {
            if c == 3 {
                assert!((clean.data[c] - noisy.data[c]).abs() > 1e-3);
            } else {
                assert!((clean.data[c] - noisy.data[c]).abs() < 1e-6, "logit {c} moved");
            }
        }
    }
}
