//! Quantized-NN substrate: tensors, trainable layers, model composition,
//! synthetic datasets, training, and int8 inference with VOS noise
//! injection.

pub mod data;
pub mod layers;
pub mod model;
pub mod quant;
pub mod tensor;
pub mod train;

pub use data::{synth_cifar, synth_mnist, Dataset};
pub use layers::Activation;
pub use model::{fc_mnist, lenet5, resnet_tiny, DataShape, Model, Neuron};
pub use quant::{NoiseSpec, QuantizedModel};
pub use tensor::Tensor;
