//! Output-quality metrics (paper §III.C eqs 5–8 and §IV.D eqs 23–26).

use crate::nn::tensor::Tensor;

/// Mean absolute error (eq. 5).
pub fn mae(target: &[f32], output: &[f32]) -> f64 {
    assert_eq!(target.len(), output.len());
    if target.is_empty() {
        return 0.0;
    }
    target.iter().zip(output).map(|(&t, &o)| (t - o).abs() as f64).sum::<f64>()
        / target.len() as f64
}

/// Mean squared error (eq. 6).
pub fn mse(target: &[f32], output: &[f32]) -> f64 {
    assert_eq!(target.len(), output.len());
    if target.is_empty() {
        return 0.0;
    }
    target.iter().zip(output).map(|(&t, &o)| ((t - o) as f64).powi(2)).sum::<f64>()
        / target.len() as f64
}

/// Mean relative error distance (eq. 7); guards against division by ~0.
pub fn mred(target: &[f32], output: &[f32]) -> f64 {
    assert_eq!(target.len(), output.len());
    if target.is_empty() {
        return 0.0;
    }
    target
        .iter()
        .zip(output)
        .map(|(&t, &o)| {
            let denom = (t as f64).abs().max(1e-9);
            ((t - o) as f64).abs() / denom
        })
        .sum::<f64>()
        / target.len() as f64
}

/// Cross-entropy of softmaxed logits vs a one-hot class (eq. 8).
pub fn cross_entropy(logits: &[f32], class: usize) -> f64 {
    let maxv = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let sum: f64 = logits.iter().map(|&v| ((v - maxv) as f64).exp()).sum();
    -(((logits[class] - maxv) as f64).exp() / sum).max(1e-300).ln()
}

/// Batch MSE between two logits tensors (clean vs noisy inference) — the
/// quantity Fig 10/13 sweeps against the user bound MSE_UB.
pub fn batch_mse(a: &Tensor, b: &Tensor) -> f64 {
    mse(&a.data, &b.data)
}

/// Error variance of the network output under noise, with Bessel's
/// correction (paper eqs 24–26): `var(e) = Σ(e_i − ē)² / (n−1)`.
pub fn output_error_variance(clean: &Tensor, noisy: &Tensor) -> f64 {
    assert_eq!(clean.data.len(), noisy.data.len());
    let n = clean.data.len();
    if n < 2 {
        return 0.0;
    }
    let errs: Vec<f64> =
        clean.data.iter().zip(&noisy.data).map(|(&c, &x)| (x - c) as f64).collect();
    let mean = errs.iter().sum::<f64>() / n as f64;
    errs.iter().map(|e| (e - mean) * (e - mean)).sum::<f64>() / (n as f64 - 1.0)
}

/// Top-1 accuracy of logits vs labels.
pub fn accuracy(logits: &Tensor, labels: &[u8]) -> f64 {
    crate::nn::train::batch_accuracy(logits, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::checks::assert_close;

    #[test]
    fn metrics_zero_for_identical() {
        let t = [1.0f32, -2.0, 3.0];
        assert_eq!(mae(&t, &t), 0.0);
        assert_eq!(mse(&t, &t), 0.0);
        assert_eq!(mred(&t, &t), 0.0);
    }

    #[test]
    fn metric_values_known() {
        let t = [1.0f32, 2.0];
        let o = [2.0f32, 0.0];
        assert_close(mae(&t, &o), 1.5, 1e-12);
        assert_close(mse(&t, &o), 2.5, 1e-12);
        assert_close(mred(&t, &o), 1.0, 1e-12);
    }

    #[test]
    fn cross_entropy_prefers_correct_class() {
        let logits = [3.0f32, 0.0, 0.0];
        assert!(cross_entropy(&logits, 0) < cross_entropy(&logits, 1));
        // Uniform logits → CE = ln(3).
        assert_close(cross_entropy(&[0.0; 3], 1), 3f64.ln(), 1e-9);
    }

    #[test]
    fn output_error_variance_bessel() {
        let clean = Tensor::from_vec(&[1, 3], vec![0.0, 0.0, 0.0]);
        let noisy = Tensor::from_vec(&[1, 3], vec![1.0, -1.0, 0.0]);
        // errors: 1, -1, 0; mean 0; var = (1+1+0)/2 = 1.
        assert_close(output_error_variance(&clean, &noisy), 1.0, 1e-12);
    }

    #[test]
    fn mred_guards_zero_target() {
        let t = [0.0f32];
        let o = [1.0f32];
        assert!(mred(&t, &o).is_finite());
    }
}
