//! Power / energy model of the (X-)TPU processing element.
//!
//! The paper's numbers come from Synopsys DC power reports on the
//! synthesized 15-nm FinFET PE. Our model reconstructs them from first
//! principles on the same netlists the timing simulator uses:
//!
//! - **dynamic energy** = Σ over toggling gates of `toggle_energy · V²`
//!   (switched-capacitance model; per-gate toggle counts come straight from
//!   the [`crate::timing::vos::VosSimulator`]),
//! - **register/clock energy** = per-bit constant each cycle (registers are
//!   in the exact region and never overscaled),
//! - **leakage** = per-gate `leakage · V` per cycle,
//! - **level shifters** = fixed per-bit overhead on the product bus, charged
//!   only when the column runs below nominal voltage (paper §IV.A notes this
//!   as the cost of VOS support).
//!
//! All energies are in normalized "gate-energy units" (NAND2 toggle at
//! nominal voltage = 1); the paper's claims are all *relative* (% savings),
//! which this normalization preserves.

use crate::timing::circuits::PeDatapath;
use crate::timing::gate::Netlist;
use crate::timing::voltage::Technology;

/// Ballpark Joules per normalized gate-energy unit: one NAND2 toggle at
/// nominal voltage is of the order of a femtojoule at a 15-nm-class node.
/// All in-model claims are relative (% savings) and independent of this
/// constant; it only anchors absolute-energy telemetry (fleet reports in
/// Joules next to normalized units).
pub const JOULES_PER_ENERGY_UNIT: f64 = 1.0e-15;

/// Per-cycle clock/register energy per register bit (normalized units).
/// Calibrated so the PE decomposition lands near the paper's Fig 1b
/// (multiplier ≈ 56 %, registers ≈ 30 %, adder ≈ 14 %).
pub const REGISTER_ENERGY_PER_BIT: f64 = 1.35;

/// Per-cycle level-shifter energy per product bit when a column is
/// overscaled (the LS cells of Fig 6b/c).
pub const LEVEL_SHIFTER_ENERGY_PER_BIT: f64 = 0.4;

/// Leakage weight per cycle (fraction of a gate's leakage constant charged
/// each cycle; keeps leakage a realistic ~10 % of PE energy at nominal).
pub const LEAKAGE_WEIGHT: f64 = 0.02;

/// Register bits in one PE: 8 weight + 8 activation pipeline + 24 psum.
pub const PE_REGISTER_BITS: usize = 8 + 8 + 24;

/// Static (activity-independent) energy description of one PE cycle.
#[derive(Clone, Copy, Debug)]
pub struct PeEnergyBreakdown {
    /// Multiplier dynamic + leakage energy (the approximate region).
    pub multiplier: f64,
    /// Accumulator adder energy (exact region).
    pub adder: f64,
    /// Register/clock energy (exact region).
    pub registers: f64,
    /// Level-shifter overhead (zero when running at nominal voltage).
    pub level_shifters: f64,
}

impl PeEnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.multiplier + self.adder + self.registers + self.level_shifters
    }

    /// Percentage shares `(multiplier, adder, registers, shifters)`.
    pub fn shares(&self) -> (f64, f64, f64, f64) {
        let t = self.total();
        (
            self.multiplier / t * 100.0,
            self.adder / t * 100.0,
            self.registers / t * 100.0,
            self.level_shifters / t * 100.0,
        )
    }
}

/// Average switching activity of a netlist region: expected toggle energy
/// per cycle at nominal voltage (before V² scaling), plus leakage constant.
#[derive(Clone, Copy, Debug)]
pub struct RegionActivity {
    /// Mean toggle energy per cycle (Σ toggle_energy over toggles / cycles).
    pub toggle_energy_per_cycle: f64,
    /// Σ leakage constants over gates in the region.
    pub leakage_sum: f64,
}

/// Compute a region's activity from cumulative toggle counts.
pub fn region_activity(
    netlist: &Netlist,
    toggle_counts: &[u64],
    range: &std::ops::Range<usize>,
    cycles: u64,
) -> RegionActivity {
    assert!(cycles > 0);
    let gates = netlist.gates();
    let mut toggle_energy = 0.0;
    let mut leakage = 0.0;
    for i in range.clone() {
        toggle_energy += gates[i].kind.toggle_energy() as f64 * toggle_counts[i] as f64;
        leakage += gates[i].kind.leakage() as f64;
    }
    RegionActivity {
        toggle_energy_per_cycle: toggle_energy / cycles as f64,
        leakage_sum: leakage,
    }
}

/// Calibrated per-cycle energy model of one PE, derived from measured
/// switching activity of the multiplier and adder regions.
#[derive(Clone, Copy, Debug)]
pub struct PePowerModel {
    pub mult: RegionActivity,
    pub adder: RegionActivity,
    pub tech: Technology,
}

impl PePowerModel {
    pub fn new(mult: RegionActivity, adder: RegionActivity, tech: Technology) -> Self {
        Self { mult, adder, tech }
    }

    /// Build from a finished VOS simulation of the PE datapath.
    pub fn from_simulation(
        pe: &PeDatapath,
        toggle_counts: &[u64],
        cycles: u64,
        tech: Technology,
    ) -> Self {
        let mult = region_activity(&pe.netlist, toggle_counts, &pe.mult_gates, cycles);
        let adder = region_activity(&pe.netlist, toggle_counts, &pe.adder_gates, cycles);
        Self::new(mult, adder, tech)
    }

    /// Per-cycle energy of one PE whose multiplier runs at `v_mult` while
    /// the exact region stays at nominal voltage.
    pub fn pe_energy(&self, v_mult: f64) -> PeEnergyBreakdown {
        let vn = self.tech.v_nominal;
        let dyn_scale = self.tech.energy_scale(v_mult);
        let overscaled = (v_mult - vn).abs() > 1e-9;
        let mult_dynamic = self.mult.toggle_energy_per_cycle * dyn_scale;
        let mult_leak = self.mult.leakage_sum * LEAKAGE_WEIGHT * (v_mult / vn);
        let adder_dynamic = self.adder.toggle_energy_per_cycle;
        let adder_leak = self.adder.leakage_sum * LEAKAGE_WEIGHT;
        PeEnergyBreakdown {
            multiplier: mult_dynamic + mult_leak,
            adder: adder_dynamic + adder_leak,
            registers: REGISTER_ENERGY_PER_BIT * PE_REGISTER_BITS as f64,
            level_shifters: if overscaled {
                LEVEL_SHIFTER_ENERGY_PER_BIT * 16.0
            } else {
                0.0
            },
        }
    }

    /// Fractional PE energy saving of running the multiplier at `v_mult`
    /// (0.0 = none, 1.0 = everything).
    pub fn pe_saving(&self, v_mult: f64) -> f64 {
        let nominal = self.pe_energy(self.tech.v_nominal).total();
        1.0 - self.pe_energy(v_mult).total() / nominal
    }

    /// Energy of a *neuron* = column of `k` PEs at multiplier voltage `v`.
    pub fn neuron_energy(&self, k: usize, v_mult: f64) -> f64 {
        self.pe_energy(v_mult).total() * k as f64
    }
}

/// Energy accounting for a whole voltage assignment: `columns[i]` is the
/// PE count (fan-in) of neuron `i`, `volts[i]` its multiplier voltage.
pub fn total_energy(model: &PePowerModel, columns: &[usize], volts: &[f64]) -> f64 {
    assert_eq!(columns.len(), volts.len());
    columns.iter().zip(volts).map(|(&k, &v)| model.neuron_energy(k, v)).sum()
}

/// Fractional saving of an assignment vs. running everything at nominal.
pub fn assignment_saving(model: &PePowerModel, columns: &[usize], volts: &[f64]) -> f64 {
    let nominal: f64 = columns
        .iter()
        .map(|&k| model.neuron_energy(k, model.tech.v_nominal))
        .sum();
    1.0 - total_energy(model, columns, volts) / nominal
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timing::circuits::pe_datapath;
    use crate::timing::gate::i64_to_bits;
    use crate::timing::sta::{clock_period, ChipInstance};
    use crate::timing::vos::VosSimulator;
    use crate::util::rng::Xoshiro256pp;

    fn measured_model() -> PePowerModel {
        let pe = pe_datapath(24);
        let tech = Technology::default();
        let chip = ChipInstance::ideal(&pe.netlist);
        let clock = clock_period(&pe.netlist, &chip, &tech);
        let mut sim =
            VosSimulator::new(&pe.netlist, chip.delays_at(&pe.netlist, &tech, 0.8), clock);
        let mut rng = Xoshiro256pp::seeded(42);
        let cycles = 2000u64;
        for _ in 0..cycles {
            let a = rng.range_i64(-128, 127);
            let w = rng.range_i64(-128, 127);
            let p = rng.range_i64(-(1 << 20), 1 << 20);
            let packed: i64 = (a & 0xFF) | ((w & 0xFF) << 8) | ((p & 0xFF_FFFF) << 16);
            sim.step(&i64_to_bits(packed, 40));
        }
        PePowerModel::from_simulation(&pe, sim.toggle_counts(), cycles, tech)
    }

    #[test]
    fn decomposition_matches_paper_shape() {
        let m = measured_model();
        let e = m.pe_energy(0.8);
        let (mult, adder, regs, ls) = e.shares();
        // Fig 1b: multiplier ≈ 56 % — dominant, registers next, adder small.
        assert!(mult > 45.0 && mult < 70.0, "multiplier share {mult:.1}%");
        assert!(mult > adder && mult > regs, "multiplier must dominate");
        assert!(adder < 30.0, "adder share {adder:.1}%");
        assert_eq!(ls, 0.0, "no level shifters at nominal");
    }

    #[test]
    fn saving_monotone_and_near_paper_at_04() {
        let m = measured_model();
        let s7 = m.pe_saving(0.7);
        let s6 = m.pe_saving(0.6);
        let s5 = m.pe_saving(0.5);
        let s4 = m.pe_saving(0.4);
        assert!(s4 > s5 && s5 > s6 && s6 > s7 && s7 > 0.0, "{s7} {s6} {s5} {s4}");
        // Paper pointer ①: ~79 % *PE power* cut at 0.4 V refers to the PE
        // measured in the Fig-1 intro experiment; our whole-PE model keeps
        // exact-region energy, so expect the multiplier-driven saving to be
        // a large fraction of the multiplier share (>30 % of total).
        assert!(s4 > 0.3, "saving at 0.4 V = {s4}");
    }

    #[test]
    fn nominal_assignment_saves_nothing() {
        let m = measured_model();
        let cols = vec![128usize; 10];
        let volts = vec![0.8f64; 10];
        assert!(assignment_saving(&m, &cols, &volts).abs() < 1e-12);
    }

    #[test]
    fn mixed_assignment_saving_between_extremes() {
        let m = measured_model();
        let cols = vec![100usize; 8];
        let all_low = vec![0.5f64; 8];
        let mut mixed = vec![0.8f64; 8];
        for v in mixed.iter_mut().take(4) {
            *v = 0.5;
        }
        let s_low = assignment_saving(&m, &cols, &all_low);
        let s_mixed = assignment_saving(&m, &cols, &mixed);
        assert!(s_low > s_mixed && s_mixed > 0.0);
        assert!((s_mixed - s_low / 2.0).abs() < 1e-9, "uniform columns halve the saving");
    }

    #[test]
    fn level_shifter_overhead_reduces_saving() {
        let m = measured_model();
        // At a voltage very close to nominal the V² gain is tiny but the
        // level-shifter tax is charged → saving can go negative.
        let s = m.pe_saving(0.799);
        assert!(s < 0.01);
    }

    #[test]
    fn neuron_energy_scales_with_column_height() {
        let m = measured_model();
        let e1 = m.neuron_energy(1, 0.6);
        let e128 = m.neuron_energy(128, 0.6);
        assert!((e128 / e1 - 128.0).abs() < 1e-9);
    }
}
