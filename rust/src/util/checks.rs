//! Lightweight property-based testing helper (proptest is unavailable
//! offline).
//!
//! [`property`] runs a closure over `n` randomized cases from a seeded
//! generator. On failure it retries with progressively simpler cases drawn
//! from fresh seeds (a shrinking-lite strategy) and reports the seed so the
//! failure is reproducible: rerun with `XTPU_PROP_SEED=<seed>`.

use crate::util::rng::Xoshiro256pp;

/// Default case count per property (override with `XTPU_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("XTPU_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(128)
}

fn base_seed() -> u64 {
    std::env::var("XTPU_PROP_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(0xA11CE)
}

/// Run `prop(rng, case_index)`; panic with the reproducing seed on failure.
///
/// `prop` should panic (assert!) on property violation.
pub fn property<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Xoshiro256pp, usize),
{
    let seed = base_seed();
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Xoshiro256pp::seeded(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed on case {case} \
                 (rerun with XTPU_PROP_SEED={case_seed}): {msg}"
            );
        }
    }
}

/// Assert two floats are close in absolute-or-relative terms.
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64) {
    let diff = (a - b).abs();
    let scale = a.abs().max(b.abs()).max(1.0);
    assert!(diff <= tol * scale, "assert_close failed: {a} vs {b} (diff={diff}, tol={tol})");
}

/// Assert two f32 slices are elementwise close.
#[track_caller]
pub fn assert_allclose(a: &[f32], b: &[f32], tol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let diff = (x - y).abs();
        let scale = x.abs().max(y.abs()).max(1.0);
        assert!(
            diff <= tol * scale,
            "assert_allclose failed at index {i}: {x} vs {y} (diff={diff})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_passes_trivially() {
        property("addition commutes", 64, |rng, _| {
            let a = rng.range_i64(-1000, 1000);
            let b = rng.range_i64(-1000, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn property_reports_seed_on_failure() {
        let result = std::panic::catch_unwind(|| {
            property("always fails", 4, |_, _| {
                panic!("intentional");
            });
        });
        let msg = match result {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("XTPU_PROP_SEED="), "msg={msg}");
        assert!(msg.contains("intentional"), "msg={msg}");
    }

    #[test]
    fn property_is_deterministic_per_case() {
        let mut first: Vec<u64> = Vec::new();
        property("collect", 8, |rng, _| {
            first.push(rng.next_u64());
        });
        let mut second: Vec<u64> = Vec::new();
        property("collect", 8, |rng, _| {
            second.push(rng.next_u64());
        });
        assert_eq!(first, second);
    }

    #[test]
    fn close_helpers() {
        assert_close(1.0, 1.0 + 1e-9, 1e-6);
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5);
        assert!(std::panic::catch_unwind(|| assert_close(1.0, 2.0, 1e-6)).is_err());
    }
}
