//! Descriptive statistics used across the framework.
//!
//! The error-model extraction (paper §IV.B) needs running moments, Bessel-
//! corrected variance (paper eq. 24), histograms for the error-distribution
//! figures (Fig 9a), quantiles, and a lightweight normality check used to
//! validate the paper's "errors are ≈ normally distributed" assumption.

/// Online running moments (Welford). Numerically stable single pass.
#[derive(Clone, Debug, Default)]
pub struct RunningMoments {
    n: u64,
    mean: f64,
    m2: f64,
    m3: f64,
    m4: f64,
    min: f64,
    max: f64,
}

impl RunningMoments {
    pub fn new() -> Self {
        Self { min: f64::INFINITY, max: f64::NEG_INFINITY, ..Default::default() }
    }

    pub fn push(&mut self, x: f64) {
        let n1 = self.n as f64;
        self.n += 1;
        let n = self.n as f64;
        let delta = x - self.mean;
        let delta_n = delta / n;
        let delta_n2 = delta_n * delta_n;
        let term1 = delta * delta_n * n1;
        self.mean += delta_n;
        self.m4 += term1 * delta_n2 * (n * n - 3.0 * n + 3.0)
            + 6.0 * delta_n2 * self.m2
            - 4.0 * delta_n * self.m3;
        self.m3 += term1 * delta_n * (n - 2.0) - 3.0 * delta_n * self.m2;
        self.m2 += term1;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn extend<I: IntoIterator<Item = f64>>(&mut self, it: I) {
        for x in it {
            self.push(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Bessel-corrected sample variance (paper eq. 24 uses n−1 because the
    /// 10^6 random vectors are a sample of the input space).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n as f64 - 1.0)
        }
    }

    /// Population variance (divide by n).
    pub fn variance_population(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Sample skewness g1.
    pub fn skewness(&self) -> f64 {
        if self.n < 2 || self.m2 == 0.0 {
            return 0.0;
        }
        let n = self.n as f64;
        (n.sqrt() * self.m3) / self.m2.powf(1.5)
    }

    /// Excess kurtosis g2 (normal → 0).
    pub fn kurtosis_excess(&self) -> f64 {
        if self.n < 2 || self.m2 == 0.0 {
            return 0.0;
        }
        let n = self.n as f64;
        n * self.m4 / (self.m2 * self.m2) - 3.0
    }

    /// Merge another accumulator (parallel reduction; Chan et al.).
    pub fn merge(&mut self, other: &RunningMoments) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let (na, nb) = (self.n as f64, other.n as f64);
        let n = na + nb;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * nb / n;
        let m2 = self.m2 + other.m2 + delta * delta * na * nb / n;
        let m3 = self.m3
            + other.m3
            + delta.powi(3) * na * nb * (na - nb) / (n * n)
            + 3.0 * delta * (na * other.m2 - nb * self.m2) / n;
        let m4 = self.m4
            + other.m4
            + delta.powi(4) * na * nb * (na * na - na * nb + nb * nb) / (n * n * n)
            + 6.0 * delta * delta * (na * na * other.m2 + nb * nb * self.m2) / (n * n)
            + 4.0 * delta * (na * other.m3 - nb * self.m3) / n;
        self.n += other.n;
        self.mean = mean;
        self.m2 = m2;
        self.m3 = m3;
        self.m4 = m4;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-range histogram (for Fig 9a error-distribution plots).
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    underflow: u64,
    overflow: u64,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Self { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0, count: 0 }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let idx = ((x - self.lo) / (self.hi - self.lo) * self.bins.len() as f64) as usize;
            let idx = idx.min(self.bins.len() - 1);
            self.bins[idx] += 1;
        }
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn underflow(&self) -> u64 {
        self.underflow
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Center of bin `i`.
    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }

    /// Normalized density of bin `i`.
    pub fn density(&self, i: usize) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.bins[i] as f64 / (self.count as f64 * w)
    }

    /// Render an ASCII sparkline of the histogram (for bench reports).
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.bins.iter().copied().max().unwrap_or(0).max(1);
        self.bins
            .iter()
            .map(|&b| GLYPHS[(b as f64 / max as f64 * 7.0).round() as usize])
            .collect()
    }
}

/// Thread-safe power-of-two latency histogram for the serving hot path.
/// The implementation moved to [`crate::obs::metrics`] (it is the µs
/// façade over [`crate::obs::metrics::Pow2Histogram`], the single
/// histogram in the tree); this re-export keeps the historical
/// `util::stats::LatencyHistogram` path working.
pub use crate::obs::metrics::LatencyHistogram;

/// NaN-safe argmax over f32 logits: ignores NaN entries entirely (a NaN
/// logit must never win the classification, and — unlike
/// `partial_cmp(..).unwrap()` — must never panic the serving thread
/// either). All-NaN or empty input falls back to index 0.
pub fn argmax_f32(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Sample quantile (linear interpolation). Sorts a copy.
pub fn quantile(data: &[f64], q: f64) -> f64 {
    assert!(!data.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let mut v: Vec<f64> = data.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn mean(data: &[f64]) -> f64 {
    if data.is_empty() {
        return 0.0;
    }
    data.iter().sum::<f64>() / data.len() as f64
}

/// Bessel-corrected sample variance (eq. 24 with n−1).
pub fn variance(data: &[f64]) -> f64 {
    if data.len() < 2 {
        return 0.0;
    }
    let m = mean(data);
    data.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (data.len() as f64 - 1.0)
}

pub fn std_dev(data: &[f64]) -> f64 {
    variance(data).sqrt()
}

/// Pearson correlation coefficient — used to validate the paper's claim
/// that multiplier-only VOS keeps PE errors uncorrelated (cov(e_i,e_j)≈0).
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.len() < 2 {
        return 0.0;
    }
    let (ma, mb) = (mean(a), mean(b));
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for i in 0..a.len() {
        let (xa, xb) = (a[i] - ma, b[i] - mb);
        num += xa * xb;
        da += xa * xa;
        db += xb * xb;
    }
    if da == 0.0 || db == 0.0 {
        0.0
    } else {
        num / (da * db).sqrt()
    }
}

/// Jarque–Bera normality statistic: JB = n/6·(S² + K²/4).
/// Under H0 (normal) JB ~ χ²(2); JB below ~5.99 ≈ cannot reject at 5 %.
/// For the huge simulation samples we report the statistic itself and use a
/// loose skew/kurtosis gate instead of a strict p-value.
pub fn jarque_bera(m: &RunningMoments) -> f64 {
    let n = m.count() as f64;
    let s = m.skewness();
    let k = m.kurtosis_excess();
    n / 6.0 * (s * s + k * k / 4.0)
}

/// Loose "approximately normal" check used in error-model extraction: the
/// paper only needs symmetry (|skew| small) and non-pathological tails.
pub fn roughly_normal(m: &RunningMoments) -> bool {
    m.count() >= 100 && m.skewness().abs() < 1.0 && m.kurtosis_excess().abs() < 10.0
}

/// Standard normal PDF (for overlaying fits on histograms).
pub fn normal_pdf(x: f64, mean: f64, std_dev: f64) -> f64 {
    if std_dev <= 0.0 {
        return 0.0;
    }
    let z = (x - mean) / std_dev;
    (-0.5 * z * z).exp() / (std_dev * (2.0 * std::f64::consts::PI).sqrt())
}

/// Linear regression y = a + b·x over paired samples; returns (a, b, r²).
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let (mx, my) = (mean(x), mean(y));
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for i in 0..x.len() {
        let dx = x[i] - mx;
        let dy = y[i] - my;
        sxx += dx * dx;
        sxy += dx * dy;
        syy += dy * dy;
    }
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let r2 = if sxx == 0.0 || syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn argmax_ignores_nan_and_never_panics() {
        assert_eq!(argmax_f32(&[0.1, 3.0, 2.0]), 1);
        // A NaN logit must not win (total_cmp alone would rank +NaN above
        // +inf) and must not panic (partial_cmp().unwrap() did).
        assert_eq!(argmax_f32(&[f32::NAN, 1.0, 0.5]), 1);
        assert_eq!(argmax_f32(&[2.0, f32::NAN, 0.5]), 0);
        assert_eq!(argmax_f32(&[f32::NEG_INFINITY, f32::NAN]), 0);
        // Degenerate inputs fall back to 0.
        assert_eq!(argmax_f32(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax_f32(&[]), 0);
        // -0.0 vs +0.0 is well-defined under total order.
        assert_eq!(argmax_f32(&[-0.0, 0.0]), 1);
    }

    #[test]
    fn welford_matches_direct() {
        let data = [1.0, 2.0, 4.0, 8.0, 16.0, -3.5];
        let mut m = RunningMoments::new();
        m.extend(data.iter().copied());
        assert!((m.mean() - mean(&data)).abs() < 1e-12);
        assert!((m.variance() - variance(&data)).abs() < 1e-10);
        assert_eq!(m.count(), 6);
        assert_eq!(m.min(), -3.5);
        assert_eq!(m.max(), 16.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let mut rng = Xoshiro256pp::seeded(1);
        let data: Vec<f64> = (0..1000).map(|_| rng.gaussian(3.0, 2.0)).collect();
        let mut whole = RunningMoments::new();
        whole.extend(data.iter().copied());
        let mut a = RunningMoments::new();
        let mut b = RunningMoments::new();
        a.extend(data[..400].iter().copied());
        b.extend(data[400..].iter().copied());
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-7);
        assert!((a.skewness() - whole.skewness()).abs() < 1e-6);
        assert!((a.kurtosis_excess() - whole.kurtosis_excess()).abs() < 1e-5);
    }

    #[test]
    fn gaussian_sample_is_roughly_normal() {
        let mut rng = Xoshiro256pp::seeded(2);
        let mut m = RunningMoments::new();
        for _ in 0..50_000 {
            m.push(rng.next_gaussian());
        }
        assert!(roughly_normal(&m));
        assert!(m.skewness().abs() < 0.05);
        assert!(m.kurtosis_excess().abs() < 0.1);
    }

    #[test]
    fn uniform_sample_has_negative_kurtosis() {
        let mut rng = Xoshiro256pp::seeded(3);
        let mut m = RunningMoments::new();
        for _ in 0..50_000 {
            m.push(rng.next_f64());
        }
        // Uniform excess kurtosis = -1.2.
        assert!((m.kurtosis_excess() + 1.2).abs() < 0.1);
    }

    #[test]
    fn histogram_counts_and_flows() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(11.0);
        assert_eq!(h.count(), 12);
        assert_eq!(h.underflow(), 1);
        assert_eq!(h.overflow(), 1);
        assert!(h.bins().iter().all(|&b| b == 1));
        assert!((h.bin_center(0) - 0.5).abs() < 1e-12);
        assert!((h.density(0) - 1.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_endpoints_and_median() {
        let data = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&data, 0.0), 1.0);
        assert_eq!(quantile(&data, 1.0), 5.0);
        assert_eq!(quantile(&data, 0.5), 3.0);
    }

    #[test]
    fn pearson_perfect_and_none() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z = [2.0, -2.0, 2.0, -2.0];
        assert!(pearson(&x, &z).abs() < 0.5);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 + 2.5 * v).collect();
        let (a, b, r2) = linear_fit(&x, &y);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.5).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn jarque_bera_small_for_normal() {
        let mut rng = Xoshiro256pp::seeded(4);
        let mut m = RunningMoments::new();
        for _ in 0..20_000 {
            m.push(rng.next_gaussian());
        }
        assert!(jarque_bera(&m) < 20.0, "jb={}", jarque_bera(&m));
    }

    #[test]
    fn normal_pdf_peak() {
        assert!((normal_pdf(0.0, 0.0, 1.0) - 0.39894228).abs() < 1e-6);
        assert!(normal_pdf(0.0, 0.0, -1.0) == 0.0);
    }

    #[test]
    fn latency_histogram_buckets_and_quantiles() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_us(0.99), 0, "empty histogram reports 0");
        // 99 fast samples at ~100 µs, one slow outlier at ~100 ms.
        for _ in 0..99 {
            h.record_us(100);
        }
        h.record_us(100_000);
        assert_eq!(h.count(), 100);
        // p50 lands in the 100 µs bucket: [64, 127].
        assert_eq!(h.quantile_us(0.5), 127);
        // p99 still in the fast bucket (99/100 samples), p100 in the slow.
        assert_eq!(h.quantile_us(0.99), 127);
        assert!(h.quantile_us(1.0) >= 100_000);
        // Degenerates: 0 µs lands in bucket 0; huge values saturate.
        let h2 = LatencyHistogram::new();
        h2.record_us(0);
        h2.record_us(u64::MAX);
        assert_eq!(h2.quantile_us(0.0), 0);
        assert!(h2.quantile_us(1.0) > 1u64 << 60);
    }

    #[test]
    fn latency_histogram_is_shareable_across_threads() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        h.record_us(i);
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }

    #[test]
    fn sparkline_shape() {
        let mut h = Histogram::new(-4.0, 4.0, 16);
        let mut rng = Xoshiro256pp::seeded(5);
        for _ in 0..10_000 {
            h.push(rng.next_gaussian());
        }
        let s = h.sparkline();
        assert_eq!(s.chars().count(), 16);
    }
}
