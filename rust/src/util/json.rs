//! Minimal JSON parser/serializer.
//!
//! serde is not available offline (see Cargo.toml note), so the framework
//! carries its own JSON implementation. It covers the full RFC 8259 value
//! model (objects, arrays, strings with escapes, numbers, booleans, null),
//! which is all the config system, error-model registry, and the
//! python↔rust weight-metadata interchange need.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — important for artifact diffing and golden tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub enum JsonError {
    Parse { pos: usize, msg: String },
    Type { expected: &'static str, found: &'static str },
    MissingKey(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse { pos, msg } => {
                write!(f, "json parse error at byte {pos}: {msg}")
            }
            JsonError::Type { expected, found } => {
                write!(f, "json type error: expected {expected}, found {found}")
            }
            JsonError::MissingKey(key) => write!(f, "json missing key: {key}"),
        }
    }
}

impl std::error::Error for JsonError {}

pub type Result<T> = std::result::Result<T, JsonError>;

impl Json {
    pub fn type_name(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // --- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(JsonError::Type { expected: "number", found: other.type_name() }),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            return Err(JsonError::Type { expected: "unsigned integer", found: "number" });
        }
        Ok(f as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 {
            return Err(JsonError::Type { expected: "integer", found: "number" });
        }
        Ok(f as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError::Type { expected: "bool", found: other.type_name() }),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError::Type { expected: "string", found: other.type_name() }),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(JsonError::Type { expected: "array", found: other.type_name() }),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(JsonError::Type { expected: "object", found: other.type_name() }),
        }
    }

    /// Object member lookup.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?.get(key).ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }

    /// Optional object member (missing or null → None).
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => match o.get(key) {
                Some(Json::Null) | None => None,
                Some(v) => Some(v),
            },
            _ => None,
        }
    }

    /// Array of f64 helper (dense numeric payloads).
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // --- constructors -----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(values: &[f64]) -> Json {
        Json::Arr(values.iter().map(|&v| Json::Num(v)).collect())
    }

    pub fn arr_str(values: &[&str]) -> Json {
        Json::Arr(values.iter().map(|&v| Json::Str(v.to_string())).collect())
    }

    // --- serialization ----------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    /// Serialize compactly into a caller-provided buffer (appended, not
    /// cleared). Byte-identical to `to_string()` — same single-line,
    /// canonical-key-order form — but reuses the caller's allocation, so
    /// per-reply serialization on a hot path costs no fresh `String`.
    pub fn write_compact(&self, out: &mut String) {
        self.write(out, None);
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_number(out, *n),
            Json::Str(s) => write_string(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                let child = indent.map(|i| i + 1);
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, child);
                    v.write(out, child);
                }
                newline_indent(out, indent);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                let child = indent.map(|i| i + 1);
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, child);
                    write_string(out, k);
                    out.push_str(if indent.is_some() { ": " } else { ":" });
                    v.write(out, child);
                }
                newline_indent(out, indent);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>) {
    if let Some(i) = indent {
        out.push('\n');
        for _ in 0..i {
            out.push_str("  ");
        }
    }
}

fn write_number(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; store as null like most tolerant encoders.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("invalid literal, expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("invalid \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("short \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

/// Read + parse a JSON file.
pub fn read_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    Ok(Json::parse(&text)?)
}

/// Serialize + write a JSON file (pretty, trailing newline). The write is
/// atomic (temp file + rename) so concurrent readers — e.g. parallel tests
/// sharing a cache — never observe a partial file.
pub fn write_file(path: &std::path::Path, value: &Json) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, value.to_string_pretty() + "\n")?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.25e2").unwrap(), Json::Num(-325.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert!(j.get("a").unwrap().as_arr().unwrap()[2].opt("b").is_none());
    }

    #[test]
    fn parse_string_escapes() {
        let j = Json::parse(r#""A\t\"\\é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "A\t\"\\é");
    }

    #[test]
    fn parse_surrogate_pair() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "😀");
    }

    #[test]
    fn roundtrip_preserves_value() {
        let src = r#"{"model": "fc_mnist", "voltages": [0.5, 0.6, 0.7, 0.8],
                      "n": 138, "nested": {"ok": true, "none": null}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j2);
        assert_eq!(j, j3);
    }

    #[test]
    fn reject_malformed() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "\"unterminated", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn typed_accessor_errors() {
        let j = Json::parse("[1]").unwrap();
        assert!(j.as_obj().is_err());
        assert!(j.as_arr().unwrap()[0].as_str().is_err());
        assert!(Json::parse("1.5").unwrap().as_i64().is_err());
        assert!(Json::parse("-1").unwrap().as_u64().is_err());
        assert!(Json::parse("{}").unwrap().get("missing").is_err());
    }

    #[test]
    fn deterministic_key_order() {
        let j = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        assert_eq!(j.to_string(), r#"{"a":2,"m":3,"z":1}"#);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("xtpu_json_test");
        let path = dir.join("t.json");
        let j = Json::obj(vec![("x", Json::arr_f64(&[1.0, 2.5])), ("s", Json::Str("v".into()))]);
        write_file(&path, &j).unwrap();
        let back = read_file(&path).unwrap();
        assert_eq!(j, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn f64_vec_helper() {
        let j = Json::parse("[1, 2, 3.5]").unwrap();
        assert_eq!(j.as_f64_vec().unwrap(), vec![1.0, 2.0, 3.5]);
        assert!(Json::parse("[1, \"x\"]").unwrap().as_f64_vec().is_err());
    }
}
