//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports the subset the `xtpu` binary needs: subcommands, `--flag`,
//! `--key value` / `--key=value` options, positional arguments, typed
//! accessors with defaults, and auto-generated usage text.

use std::collections::BTreeMap;

#[derive(Debug)]
pub enum CliError {
    UnknownOption(String),
    MissingValue(String),
    InvalidValue { key: String, value: String, reason: String },
    UnexpectedPositional(String),
    MissingRequired(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownOption(name) => write!(f, "unknown option '--{name}'"),
            CliError::MissingValue(name) => write!(f, "option '--{name}' requires a value"),
            CliError::InvalidValue { key, value, reason } => {
                write!(f, "invalid value for '--{key}': {value} ({reason})")
            }
            CliError::UnexpectedPositional(arg) => {
                write!(f, "unexpected positional argument '{arg}'")
            }
            CliError::MissingRequired(name) => {
                write!(f, "missing required option '--{name}'")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Declarative option spec used for parsing and `--help` output.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// `true` for boolean flags (no value).
    pub is_flag: bool,
    pub default: Option<&'static str>,
    pub required: bool,
}

impl OptSpec {
    pub fn flag(name: &'static str, help: &'static str) -> Self {
        Self { name, help, is_flag: true, default: None, required: false }
    }

    pub fn opt(name: &'static str, default: &'static str, help: &'static str) -> Self {
        Self { name, help, is_flag: false, default: Some(default), required: false }
    }

    pub fn required(name: &'static str, help: &'static str) -> Self {
        Self { name, help, is_flag: false, default: None, required: true }
    }
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    /// Every explicitly-passed occurrence of an option, in order (defaults
    /// are not recorded here) — the backing store for repeatable options
    /// like `xtpu serve --plan a.json --plan b.json`.
    multi: BTreeMap<String, Vec<String>>,
    flags: BTreeMap<String, bool>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program/subcommand name) against `specs`.
    pub fn parse(argv: &[String], specs: &[OptSpec]) -> Result<Args, CliError> {
        let mut args = Args::default();
        // Seed defaults.
        for s in specs {
            if s.is_flag {
                args.flags.insert(s.name.to_string(), false);
            } else if let Some(d) = s.default {
                args.values.insert(s.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| CliError::UnknownOption(key.clone()))?;
                if spec.is_flag {
                    args.flags.insert(key, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i).cloned().ok_or(CliError::MissingValue(key.clone()))?
                        }
                    };
                    args.multi.entry(key.clone()).or_default().push(val.clone());
                    args.values.insert(key, val);
                }
            } else {
                args.positionals.push(a.clone());
            }
            i += 1;
        }
        for s in specs {
            if s.required && !args.values.contains_key(s.name) {
                return Err(CliError::MissingRequired(s.name.to_string()));
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn str(&self, name: &str) -> &str {
        self.values.get(name).map(String::as_str).unwrap_or("")
    }

    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    /// The last *explicitly passed* value of an option (raw, no comma
    /// splitting); `None` when only the default applies. Lets a command
    /// distinguish "user said `--artifacts x`" from "spec default".
    pub fn explicit(&self, name: &str) -> Option<&str> {
        self.multi.get(name).and_then(|v| v.last()).map(String::as_str)
    }

    /// Every explicitly-passed value of a repeatable option, with each
    /// occurrence additionally split on commas and empties dropped:
    /// `--plan a.json --plan b.json,c.json` → `[a.json, b.json, c.json]`.
    /// Defaults never appear here — an untouched option yields `[]`.
    pub fn str_multi(&self, name: &str) -> Vec<String> {
        self.multi
            .get(name)
            .into_iter()
            .flatten()
            .flat_map(|v| v.split(','))
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect()
    }

    fn typed<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.values.get(name).ok_or_else(|| CliError::MissingRequired(name.into()))?;
        raw.parse::<T>().map_err(|e| CliError::InvalidValue {
            key: name.into(),
            value: raw.clone(),
            reason: e.to_string(),
        })
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        self.typed(name)
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        self.typed(name)
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.typed(name)
    }

    /// Comma-separated f64 list, e.g. `--voltages 0.5,0.6,0.7,0.8`.
    pub fn f64_list(&self, name: &str) -> Result<Vec<f64>, CliError> {
        let raw = self.str(name);
        raw.split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim().parse::<f64>().map_err(|e| CliError::InvalidValue {
                    key: name.into(),
                    value: raw.into(),
                    reason: e.to_string(),
                })
            })
            .collect()
    }
}

/// Render usage text for a subcommand.
pub fn usage(program: &str, command: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{about}\n\nUsage: {program} {command} [OPTIONS]\n\nOptions:\n");
    for spec in specs {
        let lhs = if spec.is_flag {
            format!("--{}", spec.name)
        } else if let Some(d) = spec.default {
            format!("--{} <value: {d}>", spec.name)
        } else {
            format!("--{} <value, required>", spec.name)
        };
        s.push_str(&format!("  {lhs:<36} {}\n", spec.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec::opt("voltage", "0.8", "operating voltage"),
            OptSpec::opt("samples", "1000", "sample count"),
            OptSpec::flag("verbose", "print more"),
            OptSpec::required("model", "model path"),
        ]
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = Args::parse(&sv(&["--model", "m.json"]), &specs()).unwrap();
        assert_eq!(a.str("voltage"), "0.8");
        assert_eq!(a.usize("samples").unwrap(), 1000);
        assert!(!a.flag("verbose"));
        let a = Args::parse(
            &sv(&["--model=m.json", "--voltage", "0.5", "--verbose", "--samples=42"]),
            &specs(),
        )
        .unwrap();
        assert_eq!(a.f64("voltage").unwrap(), 0.5);
        assert_eq!(a.usize("samples").unwrap(), 42);
        assert!(a.flag("verbose"));
        assert_eq!(a.str("model"), "m.json");
    }

    #[test]
    fn missing_required_rejected() {
        assert!(matches!(
            Args::parse(&sv(&["--voltage", "0.5"]), &specs()),
            Err(CliError::MissingRequired(_))
        ));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(
            Args::parse(&sv(&["--model", "m", "--bogus"]), &specs()),
            Err(CliError::UnknownOption(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            Args::parse(&sv(&["--model"]), &specs()),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn invalid_typed_value() {
        let a = Args::parse(&sv(&["--model", "m", "--samples", "abc"]), &specs()).unwrap();
        assert!(matches!(a.usize("samples"), Err(CliError::InvalidValue { .. })));
    }

    #[test]
    fn repeated_options_accumulate() {
        let a = Args::parse(
            &sv(&["--model", "a.json", "--model", "b.json,c.json", "--model="]),
            &specs(),
        )
        .unwrap();
        assert_eq!(a.str_multi("model"), vec!["a.json", "b.json", "c.json"]);
        // Last occurrence wins for the scalar view.
        assert_eq!(a.str("model"), "");
        // Defaults never leak into the multi view.
        assert!(a.str_multi("voltage").is_empty());
        assert!(a.str_multi("nonexistent").is_empty());
        // `explicit` distinguishes user-passed values from spec defaults.
        assert_eq!(a.explicit("model"), Some(""));
        assert_eq!(a.explicit("voltage"), None);
        let b = Args::parse(&sv(&["--model", "m", "--voltage", "0.6"]), &specs()).unwrap();
        assert_eq!(b.explicit("voltage"), Some("0.6"));
    }

    #[test]
    fn positionals_collected() {
        let a = Args::parse(&sv(&["--model", "m", "pos1", "pos2"]), &specs()).unwrap();
        assert_eq!(a.positionals, vec!["pos1", "pos2"]);
    }

    #[test]
    fn f64_list_parsing() {
        let mut s = specs();
        s.push(OptSpec::opt("voltages", "0.5,0.6,0.7,0.8", "levels"));
        let a = Args::parse(&sv(&["--model", "m"]), &s).unwrap();
        assert_eq!(a.f64_list("voltages").unwrap(), vec![0.5, 0.6, 0.7, 0.8]);
        let a = Args::parse(&sv(&["--model", "m", "--voltages", "0.55, 0.65"]), &s).unwrap();
        assert_eq!(a.f64_list("voltages").unwrap(), vec![0.55, 0.65]);
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("xtpu", "characterize", "Extract error models.", &specs());
        assert!(u.contains("--voltage"));
        assert!(u.contains("required"));
    }
}
