//! Scoped data-parallel helpers built on `std::thread` (no rayon offline).
//!
//! The characterization pass simulates millions of input vectors through the
//! gate-level timing model; [`parallel_chunks`] and [`parallel_map_reduce`]
//! spread that across cores with plain scoped threads — no queues, no
//! allocation in the hot loop.

/// Number of worker threads to use (respects `XTPU_THREADS`).
pub fn worker_count() -> usize {
    if let Ok(v) = std::env::var("XTPU_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Split `0..n` into at most `workers` contiguous ranges of near-equal size.
pub fn split_ranges(n: usize, workers: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    let base = n / workers;
    let rem = n % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for i in 0..workers {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// [`split_ranges`] with every boundary (except the final end) snapped to a
/// multiple of `align`. The parallel exec kernel shards on fixed-size RNG
/// chunks; aligned worker ranges guarantee each chunk is processed whole by
/// exactly one worker, so the draw streams are thread-count-independent.
pub fn split_ranges_aligned(n: usize, workers: usize, align: usize) -> Vec<std::ops::Range<usize>> {
    let align = align.max(1);
    if align == 1 {
        return split_ranges(n, workers);
    }
    let blocks = n.div_ceil(align);
    split_ranges(blocks, workers)
        .into_iter()
        .map(|r| (r.start * align)..(r.end * align).min(n))
        .collect()
}

/// Split a `[rows, row_len]` row-major matrix into contiguous row bands
/// (boundaries aligned to `align` rows) and run `f(row_range, band)` on each
/// band in parallel. Disjoint mutable bands — no locks, no copies.
pub fn parallel_rows<T, F>(out: &mut [T], rows: usize, row_len: usize, align: usize, f: F)
where
    T: Send,
    F: Fn(std::ops::Range<usize>, &mut [T]) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_len);
    if rows == 0 {
        return;
    }
    // Single-worker fast path: no range vector, no scope — the serving loop
    // runs this per batch, and at XTPU_THREADS=1 it must stay off the
    // allocator entirely.
    if worker_count() == 1 {
        f(0..rows, out);
        return;
    }
    let ranges = split_ranges_aligned(rows, worker_count(), align);
    if ranges.len() <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            f(r, out);
        }
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = out;
        for r in ranges {
            let (band, tail) = rest.split_at_mut(r.len() * row_len);
            rest = tail;
            let f = &f;
            scope.spawn(move || f(r, band));
        }
    });
}

/// Run `f(range, worker_index)` over a partition of `0..n` in parallel and
/// collect the per-worker results in order.
pub fn parallel_chunks<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(std::ops::Range<usize>, usize) -> R + Sync,
{
    parallel_chunks_capped(n, worker_count(), f)
}

/// [`parallel_chunks`] with an explicit worker cap. Use this for *outer*
/// fan-outs whose items themselves parallelize on the pool (e.g. the
/// budget sweep, whose validation matmuls shard across `XTPU_THREADS`):
/// capping the outer width keeps the multiplied thread count bounded
/// instead of oversubscribing cores `N×N`.
pub fn parallel_chunks_capped<R, F>(n: usize, workers: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(std::ops::Range<usize>, usize) -> R + Sync,
{
    let ranges = split_ranges(n, workers.max(1));
    if ranges.len() <= 1 {
        return ranges.into_iter().enumerate().map(|(i, r)| f(r, i)).collect();
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                let f = &f;
                scope.spawn(move || f(r, i))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

/// Map `0..n` in parallel and fold worker results with `reduce`.
pub fn parallel_map_reduce<R, F, G>(n: usize, init: R, map: F, reduce: G) -> R
where
    R: Send,
    F: Fn(std::ops::Range<usize>, usize) -> R + Sync,
    G: Fn(R, R) -> R,
{
    parallel_chunks(n, map).into_iter().fold(init, reduce)
}

/// Fill `out[i] = f(i)` in parallel (disjoint chunk writes).
pub fn parallel_fill<T, F>(out: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let n = out.len();
    let ranges = split_ranges(n, worker_count());
    if ranges.len() <= 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(i);
        }
        return;
    }
    // Split the output into disjoint mutable chunks matching the ranges.
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut offset = 0;
        for r in ranges {
            let (chunk, tail) = rest.split_at_mut(r.len());
            rest = tail;
            let start = offset;
            offset += r.len();
            let f = &f;
            scope.spawn(move || {
                for (j, slot) in chunk.iter_mut().enumerate() {
                    *slot = f(start + j);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_covers_everything_disjointly() {
        for n in [0usize, 1, 7, 16, 1000] {
            for w in [1usize, 2, 3, 8, 64] {
                let ranges = split_ranges(n, w);
                let mut covered = vec![false; n];
                for r in &ranges {
                    for i in r.clone() {
                        assert!(!covered[i], "overlap at {i}");
                        covered[i] = true;
                    }
                }
                assert!(covered.iter().all(|&c| c), "n={n} w={w} uncovered");
                // Balance: sizes differ by at most 1.
                if !ranges.is_empty() {
                    let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                    let (min, max) =
                        (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                    assert!(max - min <= 1);
                }
            }
        }
    }

    #[test]
    fn map_reduce_sums_correctly() {
        let total = parallel_map_reduce(
            10_000,
            0u64,
            |range, _| range.map(|i| i as u64).sum::<u64>(),
            |a, b| a + b,
        );
        assert_eq!(total, 10_000u64 * 9_999 / 2);
    }

    #[test]
    fn fill_matches_serial() {
        let mut out = vec![0usize; 777];
        parallel_fill(&mut out, |i| i * 3 + 1);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * 3 + 1);
        }
    }

    #[test]
    fn chunks_preserve_worker_order() {
        let parts = parallel_chunks(100, |r, _| (r.start, r.end));
        for w in parts.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    #[test]
    fn capped_chunks_respect_the_cap() {
        for cap in [1usize, 2, 3] {
            let parts = parallel_chunks_capped(10, cap, |r, _| r.len());
            assert_eq!(parts.len(), cap.min(10));
            assert_eq!(parts.iter().sum::<usize>(), 10);
        }
    }

    #[test]
    fn aligned_split_covers_everything_on_chunk_boundaries() {
        for n in [0usize, 1, 63, 64, 65, 1000] {
            for w in [1usize, 2, 3, 8] {
                for align in [1usize, 16, 64] {
                    let ranges = split_ranges_aligned(n, w, align);
                    let mut next = 0;
                    for r in &ranges {
                        assert_eq!(r.start, next, "n={n} w={w} align={align}");
                        assert_eq!(r.start % align, 0, "unaligned start");
                        assert!(r.end > r.start);
                        next = r.end;
                    }
                    assert_eq!(next, n, "n={n} w={w} align={align} uncovered tail");
                }
            }
        }
    }

    #[test]
    fn parallel_rows_matches_serial() {
        let (rows, row_len) = (129, 7);
        let mut out = vec![0u32; rows * row_len];
        parallel_rows(&mut out, rows, row_len, 16, |range, band| {
            for (i, r) in range.clone().enumerate() {
                for c in 0..row_len {
                    band[i * row_len + c] = (r * row_len + c) as u32;
                }
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
        // Degenerate shapes.
        let mut empty: Vec<u32> = vec![];
        parallel_rows(&mut empty, 0, 5, 8, |_, _| panic!("no rows"));
    }

    #[test]
    fn empty_input_ok() {
        let parts: Vec<u32> = parallel_chunks(0, |_, _| 0u32);
        assert!(parts.is_empty());
        let mut v: Vec<u8> = vec![];
        parallel_fill(&mut v, |_| 0);
    }
}
