//! Deterministic pseudo-random number generation.
//!
//! The offline environment has no `rand` crate, so this module provides the
//! generators the framework needs: a [`SplitMix64`] seeder, the
//! [`Xoshiro256pp`] engine (xoshiro256++ 1.0, Blackman & Vigna), uniform
//! integer/float helpers, and Gaussian sampling via the Marsaglia polar
//! method ([`Xoshiro256pp::next_gaussian`]).
//!
//! Every stochastic component of the framework (error-model extraction,
//! ES noise injection, dataset synthesis, GA baseline) takes an explicit
//! `&mut Xoshiro256pp` so experiments are reproducible from a single seed.

/// SplitMix64: used to expand a single `u64` seed into the xoshiro state.
///
/// Reference: Steele, Lea, Flood — "Fast splittable pseudorandom number
/// generators" (OOPSLA 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 — the framework's workhorse generator.
///
/// 256-bit state, period 2^256 − 1, passes BigCrush. Not cryptographic.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
    /// Cached second output of the polar method.
    gauss_spare: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Xoshiro256pp {
    /// Seed from a single `u64` via SplitMix64 (the recommended procedure).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, gauss_spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as `f32`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` via Lemire's rejection method
    /// (unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is meaningless");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.below(span) as i64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Random boolean with probability `p` of `true`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// One accepted polar-method sample pair (both outputs, no spare
    /// caching). The shared core of [`Self::next_gaussian`] and
    /// [`Self::fill_gaussian_block`] — keeping it in one place is what
    /// guarantees the block fill consumes the raw stream identically to
    /// repeated single draws.
    #[inline]
    fn gauss_pair(&mut self) -> (f64, f64) {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                return (u * f, v * f);
            }
        }
    }

    /// Standard normal N(0, 1) via the Marsaglia polar method.
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        let (g0, g1) = self.gauss_pair();
        self.gauss_spare = Some(g1);
        g0
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn gaussian(&mut self, mean: f64, std_dev: f64) -> f64 {
        mean + std_dev * self.next_gaussian()
    }

    /// Fill `out` with N(mean, std_dev²) draws — **bit-identical** to
    /// calling [`Self::gaussian`] `out.len()` times, including the final
    /// generator state (raw stream position *and* polar spare cache), but
    /// without the per-call spare bookkeeping: the body consumes whole
    /// accepted pairs, so the branchy acceptance loop runs once per *two*
    /// samples and the scale/offset fuses into a tight block loop. This is
    /// the batched path the exec kernel's per-column statistical noise
    /// injection runs on.
    pub fn fill_gaussian_block(&mut self, mean: f64, std_dev: f64, out: &mut [f64]) {
        let mut i = 0;
        if !out.is_empty() {
            if let Some(g) = self.gauss_spare.take() {
                out[0] = mean + std_dev * g;
                i = 1;
            }
        }
        while i + 1 < out.len() {
            let (g0, g1) = self.gauss_pair();
            out[i] = mean + std_dev * g0;
            out[i + 1] = mean + std_dev * g1;
            i += 2;
        }
        if i < out.len() {
            // Odd tail: draw a pair and cache the second half, exactly like
            // a trailing single-sample call would.
            let (g0, g1) = self.gauss_pair();
            self.gauss_spare = Some(g1);
            out[i] = mean + std_dev * g0;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.index(i + 1);
            slice.swap(i, j);
        }
    }

    /// Split off an independent generator (jump-free split via reseed; fine
    /// for simulation reproducibility, not for parallel stream guarantees).
    pub fn fork(&mut self) -> Self {
        Self::seeded(self.next_u64())
    }

    /// Derive the deterministic generator for shard `chunk` of a parallel
    /// region keyed by `key`. The same `(key, chunk)` pair always yields the
    /// same stream, independent of thread count or scheduling — this is the
    /// contract the parallel exec kernel's bit-reproducibility rests on
    /// (`key` is typically one [`Self::next_u64`] drawn from the parent, so
    /// the parent advances identically at any `XTPU_THREADS`).
    pub fn stream(key: u64, chunk: u64) -> Self {
        // An odd-multiplier chunk offset keeps distinct chunks on distinct
        // SplitMix64 inputs; seeded() then diffuses into full 256-bit state.
        Self::seeded(SplitMix64::new(key ^ chunk.wrapping_mul(0xA076_1D64_78BD_642F)).next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 (from the public-domain C code).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_seeds() {
        let mut r1 = Xoshiro256pp::seeded(42);
        let mut r2 = Xoshiro256pp::seeded(42);
        let mut r3 = Xoshiro256pp::seeded(43);
        let v1: Vec<u64> = (0..16).map(|_| r1.next_u64()).collect();
        let v2: Vec<u64> = (0..16).map(|_| r2.next_u64()).collect();
        let v3: Vec<u64> = (0..16).map(|_| r3.next_u64()).collect();
        assert_eq!(v1, v2);
        assert_ne!(v1, v3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256pp::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_bound() {
        let mut r = Xoshiro256pp::seeded(9);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 5.0;
            assert!((c as f64 - expect).abs() < expect * 0.05, "counts={counts:?}");
        }
    }

    #[test]
    fn range_i64_inclusive_bounds_hit() {
        let mut r = Xoshiro256pp::seeded(11);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let x = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&x));
            saw_lo |= x == -3;
            saw_hi |= x == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256pp::seeded(13);
        let n = 200_000;
        let samples: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn gaussian_scaled() {
        let mut r = Xoshiro256pp::seeded(17);
        let n = 100_000;
        let samples: Vec<f64> = (0..n).map(|_| r.gaussian(5.0, 2.0)).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn fill_gaussian_block_bit_matches_sequential_draws() {
        // The block fill must be indistinguishable from repeated single
        // draws: same values bit-for-bit AND same generator state after
        // (raw stream position and polar spare cache), for every parity of
        // length and spare-cache starting condition.
        for warmup in [0usize, 1, 2, 3] {
            for len in [0usize, 1, 2, 3, 7, 8, 17, 64, 1000] {
                let mut seq = Xoshiro256pp::seeded(0xB10C + warmup as u64);
                for _ in 0..warmup {
                    seq.next_gaussian(); // odd warmup leaves a cached spare
                }
                let mut blk = seq.clone();
                let expect: Vec<f64> = (0..len).map(|_| seq.gaussian(2.5, 7.0)).collect();
                let mut got = vec![0.0f64; len];
                blk.fill_gaussian_block(2.5, 7.0, &mut got);
                for (i, (e, g)) in expect.iter().zip(&got).enumerate() {
                    assert_eq!(e.to_bits(), g.to_bits(), "warmup={warmup} len={len} i={i}");
                }
                // Post-state: both continue to identical gaussians AND
                // identical raw u64s (catches a desynced spare cache).
                assert_eq!(
                    seq.next_gaussian().to_bits(),
                    blk.next_gaussian().to_bits(),
                    "spare cache desynced at warmup={warmup} len={len}"
                );
                assert_eq!(seq.next_u64(), blk.next_u64(), "warmup={warmup} len={len}");
            }
        }
    }

    #[test]
    fn fill_gaussian_block_moments() {
        let mut r = Xoshiro256pp::seeded(29);
        let mut samples = vec![0.0f64; 200_000];
        r.fill_gaussian_block(0.0, 1.0, &mut samples);
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seeded(19);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>()); // astronomically unlikely
    }

    #[test]
    fn stream_is_deterministic_per_chunk() {
        // Same (key, chunk) → same stream; distinct chunks → distinct
        // streams; chunk order of construction is irrelevant.
        let key = 0xDEAD_BEEF_u64;
        let take8 = |mut r: Xoshiro256pp| -> Vec<u64> { (0..8).map(|_| r.next_u64()).collect() };
        for chunk in [0u64, 1, 2, 63, 1 << 40] {
            let a = take8(Xoshiro256pp::stream(key, chunk));
            let b = take8(Xoshiro256pp::stream(key, chunk));
            assert_eq!(a, b);
        }
        let mut r0 = Xoshiro256pp::stream(key, 0);
        let mut r1 = Xoshiro256pp::stream(key, 1);
        let mut rk = Xoshiro256pp::stream(key ^ 1, 0);
        let v0: Vec<u64> = (0..8).map(|_| r0.next_u64()).collect();
        let v1: Vec<u64> = (0..8).map(|_| r1.next_u64()).collect();
        let vk: Vec<u64> = (0..8).map(|_| rk.next_u64()).collect();
        assert_ne!(v0, v1);
        assert_ne!(v0, vk);
    }

    #[test]
    fn fork_gives_independent_stream() {
        let mut r = Xoshiro256pp::seeded(23);
        let mut f = r.fork();
        let a: Vec<u64> = (0..8).map(|_| r.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| f.next_u64()).collect();
        assert_ne!(a, b);
    }
}
