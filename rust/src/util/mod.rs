//! Infrastructure substrates built in-tree because the environment is
//! offline (no rand / serde / clap / rayon / proptest). See DESIGN.md §3.

pub mod checks;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;
